//! Metric-substrate benchmarks: BLEU and the Hungarian matcher (these run
//! inside every experiment sweep; they must never dominate eval time).

use lutmax::benchkit::{flush_json, Bench};
use lutmax::eval::{bleu_corpus, hungarian_min};
use lutmax::testkit::Rng;

fn main() {
    let mut rng = Rng::new(9);

    // BLEU over a 200-sentence corpus (the Table 2 evaluation shape)
    let pairs: Vec<(Vec<i32>, Vec<i32>)> = (0..200)
        .map(|_| {
            let rf: Vec<i32> = (0..14).map(|_| rng.int(4, 63) as i32).collect();
            let mut hyp = rf.clone();
            if rng.bool(0.5) {
                let i = rng.usize(0, hyp.len() - 1);
                hyp[i] = rng.int(4, 63) as i32;
            }
            (hyp, rf)
        })
        .collect();
    Bench::new("bleu_corpus/200x14")
        .items(200)
        .run(|| {
            std::hint::black_box(bleu_corpus(&pairs));
        });

    // Hungarian matching at DETR scales (queries x objects)
    for (q, o) in [(8usize, 4usize), (16, 8), (100, 20)] {
        let cost: Vec<f64> = (0..q * o).map(|_| rng.f64() * 10.0).collect();
        Bench::new(format!("hungarian/{q}x{o}")).run(|| {
            std::hint::black_box(hungarian_min(&cost, q, o));
        });
    }

    if let Some(path) = flush_json().expect("write BENCH_JSON") {
        println!("\n[bench] wrote {}", path.display());
    }
}
