//! Coordinator benchmarks: batcher hot path (no PJRT) and end-to-end
//! serving overhead vs raw pipeline calls (requires artifacts).

use std::time::{Duration, Instant};

use lutmax::benchkit::{flush_json, Bench};
use lutmax::config::ServerConfig;
use lutmax::coordinator::{Batcher, Coordinator, Payload, Reply, RouteTable};
use lutmax::testkit::Rng;
use lutmax::workload;

fn main() {
    // 1. batcher data-structure hot path
    Bench::new("batcher push+pop (batch=8)").items(8).run(|| {
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(1));
        for i in 0..8 {
            b.push(i);
        }
        std::hint::black_box(b.pop_ready(Instant::now()));
    });

    // 2. end-to-end serving: classify through the coordinator
    let dir = lutmax::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("coordinator_bench: no artifacts; skipping serving section");
        flush_json().expect("write BENCH_JSON");
        return;
    }
    let cfg = ServerConfig {
        artifacts: dir,
        max_batch: 8,
        batch_timeout_us: 200,
        workers: 1,
        queue_depth: 512,
        trace: false,
    };
    let routes = RouteTable {
        classify: Some("sst2__ptqd__rexp__uint8".into()),
        ..Default::default()
    };
    let c = Coordinator::start(cfg, routes).unwrap();
    let mut rng = Rng::new(4);

    // closed-loop batched: submit 8, wait all — measures amortized latency
    Bench::new("serve classify x8 (closed loop)")
        .items(8)
        .min_time_ms(1500)
        .run(|| {
            let rxs: Vec<_> = (0..8)
                .map(|_| {
                    c.submit(Payload::Classify(workload::random_cls_row(&mut rng, 24, 64)))
                        .unwrap()
                })
                .collect();
            for rx in rxs {
                match rx.recv().unwrap() {
                    Reply::Classify(_) => {}
                    other => panic!("unexpected {other:?}"),
                }
            }
        });

    let stats = c.stats().unwrap();
    let m = &stats.per_task["classify"];
    println!(
        "\nserved {} requests in {} batches (mean batch {:.2}); queue wait p50 {} us p99 {} us",
        m.requests,
        m.batches,
        m.mean_batch_size(),
        m.queue_wait.percentile_us(0.50),
        m.queue_wait.percentile_us(0.99)
    );
    c.shutdown().unwrap();

    if let Some(path) = flush_json().expect("write BENCH_JSON") {
        println!("\n[bench] wrote {}", path.display());
    }
}
