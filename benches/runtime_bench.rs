//! PJRT runtime benchmarks: artifact execute latency for the standalone
//! softmax kernels and the model steps (the serving inner loops).
//! Requires `make artifacts`.

use lutmax::benchkit::{flush_json, Bench, Suite};
use lutmax::coordinator::{ClsPipeline, NmtPipeline};
use lutmax::lut::{lut2d_tables, rexp_tables, Precision, SIGMA_ROWS};
use lutmax::runtime::{Engine, Tensor};
use lutmax::testkit::Rng;
use lutmax::workload;

fn main() {
    let dir = lutmax::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("runtime_bench: no artifacts; run `make artifacts` first");
        flush_json().expect("write BENCH_JSON");
        return;
    }
    let engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(1);

    let mut suite = Suite::new("standalone softmax artifacts (PJRT execute)");
    let meta = engine.manifest.artifact("softmax__rexp__uint8").unwrap();
    let (rows, cols) = (meta.inputs[0].0[0], meta.inputs[0].0[1]);
    let x = Tensor::f32(vec![rows, cols], rng.normal_vec(rows * cols, 2.0));

    let rt = rexp_tables(Precision::Uint8, None);
    let recip = Tensor::i32(vec![rt.recip_e.len()], rt.recip_e.clone());
    let alpha = Tensor::i32(vec![rt.alpha.len()], rt.alpha.clone());
    engine
        .execute("softmax__rexp__uint8", &[x.clone(), recip.clone(), alpha.clone()])
        .unwrap();
    suite.add(
        Bench::new("softmax__rexp__uint8")
            .items(rows * cols)
            .run(|| {
                engine
                    .execute("softmax__rexp__uint8", &[x.clone(), recip.clone(), alpha.clone()])
                    .unwrap();
            }),
    );

    let lt = lut2d_tables(Precision::Uint8, None);
    let exp_t = Tensor::i32(vec![lt.exp.len()], lt.exp.clone());
    let row_t = Tensor::i32(vec![lt.row.len()], lt.row.clone());
    let sigma_t = Tensor::i32(vec![SIGMA_ROWS, lt.cols], lt.sigma.clone());
    engine
        .execute(
            "softmax__lut2d__uint8",
            &[x.clone(), exp_t.clone(), row_t.clone(), sigma_t.clone()],
        )
        .unwrap();
    suite.add(
        Bench::new("softmax__lut2d__uint8")
            .items(rows * cols)
            .run(|| {
                engine
                    .execute(
                        "softmax__lut2d__uint8",
                        &[x.clone(), exp_t.clone(), row_t.clone(), sigma_t.clone()],
                    )
                    .unwrap();
            }),
    );
    suite.add(
        Bench::new("softmax__exact__fp32")
            .items(rows * cols)
            .run(|| {
                engine.execute("softmax__exact__fp32", &[x.clone()]).unwrap();
            }),
    );

    let mut suite = Suite::new("model steps (serving inner loops)");
    let cls = ClsPipeline::load(&engine, "sst2__ptqd__rexp__uint8").unwrap();
    let cls_rows: Vec<Vec<i32>> = (0..cls.batch)
        .map(|_| workload::random_cls_row(&mut rng, cls.max_len, 64))
        .collect();
    suite.add(
        Bench::new("bert classify (batch=8)")
            .items(cls.batch)
            .run(|| {
                cls.classify(&engine, &cls_rows).unwrap();
            }),
    );

    let nmt = NmtPipeline::load(&engine, "nmt14__ptqd__rexp__uint8").unwrap();
    let srcs: Vec<Vec<i32>> = (0..nmt.batch)
        .map(|_| workload::random_src_row(&mut rng, nmt.max_src, 64))
        .collect();
    suite.add(
        Bench::new("nmt translate (batch=8, full decode)")
            .items(nmt.batch)
            .min_time_ms(1500)
            .run(|| {
                nmt.translate(&engine, &srcs).unwrap();
            }),
    );
    println!("\npjrt executions: {}", engine.exec_count.borrow());

    if let Some(path) = flush_json().expect("write BENCH_JSON") {
        println!("\n[bench] wrote {}", path.display());
    }
}
