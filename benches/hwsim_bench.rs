//! HW-sim benchmarks: regenerates the paper's architectural comparison
//! (cycles / area / energy per design) across geometries, and measures
//! the simulator's own throughput.

use lutmax::benchkit::{flush_json, Bench};
use lutmax::hwsim::{all_designs, simulate, simulate_row_parallel, Design, DesignKind, SimConfig};
use lutmax::lut::Precision;

fn main() {
    println!("\n=== HW design comparison (the paper's §2/§3 claims) ===");
    for (n, lanes) in [(64usize, 1usize), (128, 4), (512, 8)] {
        println!("\n-- n={n}, lanes={lanes}, 1024 rows --");
        println!(
            "{:<20} {:>11} {:>11} {:>9} {:>8}",
            "design", "cycles/elem", "energy/elem", "area", "LUT B"
        );
        let mut base = None;
        for d in all_designs(Precision::Uint8) {
            let r = simulate(&d, SimConfig { n, rows: 1024, lanes });
            if d.name().starts_with("exact") {
                base = Some(r.cycles);
            }
            let speedup = base
                .map(|b| format!("  ({:.2}x)", b as f64 / r.cycles as f64))
                .unwrap_or_default();
            println!(
                "{:<20} {:>11.2} {:>11.2} {:>9.1} {:>8}{}",
                r.design,
                r.cycles_per_elem(),
                r.energy_per_elem(),
                r.area,
                r.lut_bytes,
                speedup
            );
        }
    }

    println!("\n=== row-parallel units (rexp uint8, n=128, 1024 rows) ===");
    println!("{:<8} {:>11} {:>9} {:>9}", "units", "cycles/elem", "area", "LUT B");
    let d = Design::new(DesignKind::Rexp, Precision::Uint8);
    for units in [1usize, 2, 4, 8] {
        let r = simulate_row_parallel(&d, SimConfig { n: 128, rows: 1024, lanes: 4 }, units);
        println!(
            "{:<8} {:>11.2} {:>9.1} {:>9}",
            units,
            r.cycles_per_elem(),
            r.area,
            r.lut_bytes
        );
    }

    println!("\n=== simulator throughput ===");
    let designs = all_designs(Precision::Uint8);
    for d in &designs {
        let cfg = SimConfig { n: 128, rows: 4096, lanes: 4 };
        Bench::new(format!("simulate/{}", d.name()))
            .items(cfg.n * cfg.rows)
            .run(|| {
                std::hint::black_box(simulate(d, cfg));
            });
    }

    if let Some(path) = flush_json().expect("write BENCH_JSON") {
        println!("\n[bench] wrote {}", path.display());
    }
}
