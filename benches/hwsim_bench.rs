//! HW-sim benchmarks: regenerates the paper's architectural comparison
//! (cycles / area / energy per design) across geometries, and measures
//! the simulator's own throughput.

use lutmax::benchkit::{flush_json, Bench};
use lutmax::hwsim::{
    all_designs, simulate, simulate_attention, simulate_decode, simulate_decode_batched,
    simulate_row_parallel, AttnSimConfig, DecodeSimConfig, Design, DesignKind, SimConfig,
};
use lutmax::lut::Precision;

fn main() {
    println!("\n=== HW design comparison (the paper's §2/§3 claims) ===");
    for (n, lanes) in [(64usize, 1usize), (128, 4), (512, 8)] {
        println!("\n-- n={n}, lanes={lanes}, 1024 rows --");
        println!(
            "{:<20} {:>11} {:>11} {:>9} {:>8}",
            "design", "cycles/elem", "energy/elem", "area", "LUT B"
        );
        let mut base = None;
        for d in all_designs(Precision::Uint8) {
            let r = simulate(&d, SimConfig { n, rows: 1024, lanes });
            if d.name().starts_with("exact") {
                base = Some(r.cycles);
            }
            let speedup = base
                .map(|b| format!("  ({:.2}x)", b as f64 / r.cycles as f64))
                .unwrap_or_default();
            println!(
                "{:<20} {:>11.2} {:>11.2} {:>9.1} {:>8}{}",
                r.design,
                r.cycles_per_elem(),
                r.energy_per_elem(),
                r.area,
                r.lut_bytes,
                speedup
            );
        }
    }

    println!("\n=== row-parallel units (rexp uint8, n=128, 1024 rows) ===");
    println!("{:<8} {:>11} {:>9} {:>9}", "units", "cycles/elem", "area", "LUT B");
    let d = Design::new(DesignKind::Rexp, Precision::Uint8);
    for units in [1usize, 2, 4, 8] {
        let r = simulate_row_parallel(&d, SimConfig { n: 128, rows: 1024, lanes: 4 }, units);
        println!(
            "{:<8} {:>11.2} {:>9.1} {:>9}",
            units,
            r.cycles_per_elem(),
            r.area,
            r.lut_bytes
        );
    }

    println!("\n=== attention block: fused vs unfused (cycle model) ===");
    println!("{:<20} {:>12} {:>12} {:>9}", "design", "fused c/e", "unfused c/e", "ratio");
    for kind in [DesignKind::Rexp, DesignKind::Lut2d] {
        let d = Design::new(kind, Precision::Uint8);
        // mirrors the software bench's attn/h8/L128 shape (d_head 64)
        let cfg = AttnSimConfig { heads: 8, len_q: 128, len_k: 128, d_head: 64, lanes: 4 };
        let f = simulate_attention(&d, cfg, true);
        let u = simulate_attention(&d, cfg, false);
        println!(
            "{:<20} {:>12.2} {:>12.2} {:>8.2}x",
            d.name(),
            f.cycles_per_elem(),
            u.cycles_per_elem(),
            u.cycles as f64 / f.cycles as f64
        );
    }

    println!("\n=== streaming decode: paged KV + grouped heads (cycle model) ===");
    println!(
        "{:<20} {:>4} {:>12} {:>12} {:>9}",
        "design", "G", "cycles/elem", "energy/elem", "vs MHA"
    );
    for kind in [DesignKind::Rexp, DesignKind::Lut2d] {
        let d = Design::new(kind, Precision::Uint8);
        // mirrors the software bench's decode/h8/g{8,2}/L128 pair
        let base = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 8,
            seq_len: 128,
            d_head: 64,
            page_size: 16,
            lanes: 4,
        };
        let mha = simulate_decode(&d, base);
        for g in [8usize, 2] {
            let r = simulate_decode(&d, DecodeSimConfig { kv_heads: g, ..base });
            println!(
                "{:<20} {:>4} {:>12.2} {:>12.2} {:>8.2}x",
                d.name(),
                g,
                r.cycles_per_elem(),
                r.energy_per_elem(),
                mha.cycles as f64 / r.cycles as f64
            );
        }
    }

    println!("\n=== batched decode rounds: wave-setup amortization (cycle model) ===");
    println!("{:<8} {:>14} {:>14} {:>9}", "sessions", "batched c/e", "serial c/e", "saved");
    {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 2,
            seq_len: 128,
            d_head: 64,
            page_size: 16,
            lanes: 4,
        };
        for s in [1usize, 4, 16] {
            let b = simulate_decode_batched(&d, cfg, s, true);
            let ser = simulate_decode_batched(&d, cfg, s, false);
            println!(
                "{:<8} {:>14.4} {:>14.4} {:>8}c",
                s,
                b.cycles_per_elem(),
                ser.cycles_per_elem(),
                ser.cycles - b.cycles
            );
        }
    }

    println!("\n=== simulator throughput ===");
    let designs = all_designs(Precision::Uint8);
    for d in &designs {
        let cfg = SimConfig { n: 128, rows: 4096, lanes: 4 };
        Bench::new(format!("simulate/{}", d.name()))
            .items(cfg.n * cfg.rows)
            .run(|| {
                std::hint::black_box(simulate(d, cfg));
            });
    }
    // decode row in the trajectory file: the L-step model itself
    let d = Design::new(DesignKind::Rexp, Precision::Uint8);
    let dcfg = DecodeSimConfig {
        q_heads: 8,
        kv_heads: 2,
        seq_len: 128,
        d_head: 64,
        page_size: 16,
        lanes: 4,
    };
    Bench::new("simulate_decode/rexp")
        .items(8 * 128 * 129 / 2)
        .run(|| {
            std::hint::black_box(simulate_decode(&d, dcfg));
        });
    // batched-rounds row: 16 sessions, one wave per round
    Bench::new("simulate_decode_batched/rexp")
        .items(16 * 8 * 128 * 129 / 2)
        .run(|| {
            std::hint::black_box(simulate_decode_batched(&d, dcfg, 16, true));
        });

    if let Some(path) = flush_json().expect("write BENCH_JSON") {
        println!("\n[bench] wrote {}", path.display());
    }
}
