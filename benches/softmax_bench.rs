//! Softmax software-model benchmarks: the paper's datapaths vs baselines
//! on the rust hot path (per-element throughput, Table-1-adjacent).
//!
//! Labels are STABLE — `BENCH_JSON=BENCH_softmax.json` makes this binary
//! the repo's perf trajectory file (refreshed by `make bench-smoke`):
//!   uint8/<mode>          fused single-thread hot path (256 rows x 128)
//!   rexp/<prec>           precision sweep
//!   lut2d/n=<n>           row-length scaling
//!   par/<mode>/w<k>       row-parallel scaling over worker counts

use std::sync::Arc;

use lutmax::benchkit::{flush_json, Bench, Suite};
use lutmax::lut::Precision;
use lutmax::softmax::{engine, Mode, ParSoftmax, Scratch, SoftmaxEngine};
use lutmax::testkit::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let n = 128usize;
    let rows = 256usize;
    let x = rng.normal_vec(rows * n, 2.0);
    let mut out = vec![0.0f32; x.len()];
    let mut scratch = Scratch::new();

    let mut suite = Suite::new("softmax SW models (256 rows x 128, fused run_with)");
    for mode in [
        Mode::Exact,
        Mode::PriorartEq2Plus,
        Mode::Rexp,
        Mode::Lut2d,
        Mode::Aggressive,
    ] {
        let e = engine(mode, Precision::Uint8, None);
        let r = Bench::new(format!("uint8/{}", mode.name()))
            .items(x.len())
            .run(|| e.run_with(&x, n, &mut out, &mut scratch));
        suite.add(r);
    }
    suite.ratio("uint8/rexp", "uint8/exact");
    suite.ratio("uint8/lut2d", "uint8/exact");

    let mut suite = Suite::new("softmax SW models across precisions (rexp)");
    for p in lutmax::lut::ALL_PRECISIONS {
        let e = engine(Mode::Rexp, p, None);
        suite.add(
            Bench::new(format!("rexp/{}", p.name()))
                .items(x.len())
                .run(|| e.run_with(&x, n, &mut out, &mut scratch)),
        );
    }

    let mut suite = Suite::new("row-length scaling (uint8 lut2d)");
    for n in [16usize, 64, 256, 1024] {
        let x = rng.normal_vec(64 * n, 2.0);
        let mut out = vec![0.0f32; x.len()];
        let e = engine(Mode::Lut2d, Precision::Uint8, None);
        suite.add(
            Bench::new(format!("lut2d/n={n}"))
                .items(x.len())
                .run(|| e.run_with(&x, n, &mut out, &mut scratch)),
        );
    }

    // row-parallel scaling: the same 256x128 batch sharded across workers
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let title = format!("row-parallel softmax (256 rows x 128, {cores} cores available)");
    let mut suite = Suite::new(&title);
    // dedup so 2- or 4-core machines don't emit duplicate trajectory labels
    let mut worker_counts = vec![2usize, 4, cores];
    worker_counts.sort_unstable();
    worker_counts.dedup();
    for mode in [Mode::Rexp, Mode::Lut2d] {
        for &w in &worker_counts {
            let p = ParSoftmax::with_workers(Arc::from(engine(mode, Precision::Uint8, None)), w);
            suite.add(
                Bench::new(format!("par/{}/w{w}", mode.name()))
                    .items(x.len())
                    .run(|| p.run_with(&x, n, &mut out, &mut scratch)),
            );
        }
        if let (Some(&lo), Some(&hi)) = (worker_counts.first(), worker_counts.last()) {
            if lo != hi {
                suite.ratio(
                    &format!("par/{}/w{hi}", mode.name()),
                    &format!("par/{}/w{lo}", mode.name()),
                );
            }
        }
    }

    if let Some(path) = flush_json().expect("write BENCH_JSON") {
        println!("\n[bench] wrote {}", path.display());
    }
}
