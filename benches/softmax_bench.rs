//! Softmax software-model benchmarks: the paper's datapaths vs baselines
//! on the rust hot path (per-element throughput, Table-1-adjacent).
//!
//! Labels are STABLE — `BENCH_JSON=BENCH_softmax.json` makes this binary
//! the repo's perf trajectory file (refreshed by `make bench-smoke`):
//!   uint8/<mode>          fused single-thread hot path (256 rows x 128)
//!   i8/<mode>             integer pass-1 ingestion on the same rows
//!                         (quantized) — must beat uint8/<mode>
//!   rexp/<prec>           precision sweep
//!   lut2d/n=<n>           row-length scaling
//!   par/<mode>/w<k>       row-parallel scaling over worker counts
//!   attn/h<H>/L<L>        fused integer QK^T→softmax(LUT)→×V (uint8 rexp)
//!   attn_unfused/h<H>/L<L>  the separate-pass compose (dequant, f32
//!                         QK^T, softmax, ×V) — attn/* must be >= 1.3x
//!   decode/h<H>/g<G>/L<L> L-step streaming decode over the paged i8 KV
//!                         cache (uint8 rexp, page 16) — pinned to the
//!                         HEAD-major sweep (pages re-read once per
//!                         query head), the pre-PR-5 reference
//!   decode_groupmajor/h<H>/g<G>/L<L>  the same fleet swept GROUP-major
//!                         (pages read once per KV group per step, the
//!                         product path) — identical MAC work and
//!                         bit-identical outputs, so the delta vs
//!                         decode/* is pure K/V read amplification
//!                         (expect ≥ decode/* wherever G < H)
//!   decode_gqa_vs_mha     the grouped-query config of the decode pair
//!                         (h8/g2/L128) under a stable semantic label —
//!                         compare against decode/h8/g8/L128 across
//!                         commits (GQA reads 1/4 the K/V bytes)
//!   decode_split/h<H>/g<G>/L<L>  long-context streaming decode through
//!                         the prefix-split partial-softmax path
//!                         (step_split, spans from the serving policy at
//!                         split_min_tokens 128) — h8/g1 is the MQA case
//!                         where the split is the only fan-out axis,
//!                         h8/g8 prices the span merge where group
//!                         fan-out already exists
//!   decode_batch/s<S>/h<H>/L<L>  S concurrent sessions, every serving
//!                         round ONE DecodeBatch wave of S×H head rows
//!   decode_batch_serial/s<S>/h<H>/L<L>  the same fleet as S per-session
//!                         step_par scatters (PR 3's scheduling) — the
//!                         decode_batch/* side amortizes the pool wakes
//!   decode_sched/s<S>/p<P>/<case>  full open→prefill→step→close session
//!                         lifecycles through DecodePipeline::run_batch
//!                         (the continuous-batching scheduler): `mixed`
//!                         submits one all-sessions batch per round on an
//!                         uncontended arena, `evict` overcommits a
//!                         P-page arena so rounds carry eviction/restore
//!                         churn as well
//!   decode_sched_barrier/s<S>/p<P>/mixed  the same mixed fleet, one
//!                         run_batch call per payload — the scheduler
//!                         never sees a coalescible queue, so the
//!                         decode_sched/* side measures what round
//!                         assembly + one-wave-per-round buys
//!   decode_sched_fault/s<S>/p<P>/f<F>  the same fleets with the route's
//!                         `:fF` chaos schedule live — spurious KV alloc
//!                         failures, contained worker panics/slowdowns,
//!                         injected deadline sheds. Typed degradation
//!                         replies are legal; lost replies are not. The
//!                         ratio against the matching decode_sched/*
//!                         label prices the armed fault plan + the
//!                         containment plumbing under fire
//!   decode_sched_spill/s<S>/p<P>  the evict fleet with a graceful
//!                         drain() + adopt_spill() restart inserted
//!                         mid-decode — every session spills host-side
//!                         and restores lazily via the checksummed
//!                         copy-back rung
//!   decode_sched_spill_replay/s<S>/p<P>  the same, with SpillCorrupt
//!                         armed at 1/1 so every restore demotes to the
//!                         token-by-token replay-log fallback — the
//!                         ratio prices losing the fast rung

use std::sync::Arc;

use lutmax::attention::{
    spans_for, AttnMask, AttnScratch, AttnShape, ComposedAttention, DecodeAttention, DecodeBatch,
    FusedAttention, QuantTensor, SweepOrder, DECODE_AFFINE,
};
use lutmax::benchkit::{flush_json, Bench, Suite};
use lutmax::coordinator::{DecodePipeline, Payload, Reply};
use lutmax::kv::{HeadGroups, KvConfig, KvPool, KvSeq};
use lutmax::lut::Precision;
use lutmax::obs::TraceClock;
use lutmax::runtime::Tensor;
use lutmax::softmax::{engine, IntRow, Mode, ParSoftmax, Scratch, SoftmaxEngine};
use lutmax::testkit::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let n = 128usize;
    let rows = 256usize;
    let x = rng.normal_vec(rows * n, 2.0);
    let mut out = vec![0.0f32; x.len()];
    let mut scratch = Scratch::new();

    let mut suite = Suite::new("softmax SW models (256 rows x 128, fused run_with)");
    for mode in [
        Mode::Exact,
        Mode::PriorartEq2Plus,
        Mode::Rexp,
        Mode::Lut2d,
        Mode::Aggressive,
    ] {
        let e = engine(mode, Precision::Uint8, None);
        let r = Bench::new(format!("uint8/{}", mode.name()))
            .items(x.len())
            .run(|| e.run_with(&x, n, &mut out, &mut scratch));
        suite.add(r);
    }
    suite.ratio("uint8/rexp", "uint8/exact");
    suite.ratio("uint8/lut2d", "uint8/exact");

    // i8 ingestion: the same logical rows, already quantized — pass 1 is
    // pure integer (i8 max scan + clamp/fixed-point address), no float
    // subtract/cast per element. The acceptance bar: i8/<mode> beats
    // uint8/<mode>.
    let mut suite = Suite::new("i8 integer pass-1 ingestion (256 rows x 128, quantized)");
    let (xq, affine) = lutmax::quant::quantize(&x);
    let irow = IntRow::from_affine(&affine);
    for mode in [Mode::Rexp, Mode::Lut2d] {
        let e = engine(mode, Precision::Uint8, None);
        suite.add(
            Bench::new(format!("i8/{}", mode.name()))
                .items(xq.len())
                .run(|| e.run_i8_with(&xq, n, irow, &mut out, &mut scratch)),
        );
        // the f32 pass-1 on the same rows, re-timed in this suite so the
        // speedup line is computed from like-for-like samples
        suite.add(
            Bench::new(format!("i8_ref/{}", mode.name()))
                .items(x.len())
                .run(|| e.run_with(&x, n, &mut out, &mut scratch)),
        );
        suite.ratio(&format!("i8/{}", mode.name()), &format!("i8_ref/{}", mode.name()));
    }

    let mut suite = Suite::new("softmax SW models across precisions (rexp)");
    for p in lutmax::lut::ALL_PRECISIONS {
        let e = engine(Mode::Rexp, p, None);
        suite.add(
            Bench::new(format!("rexp/{}", p.name()))
                .items(x.len())
                .run(|| e.run_with(&x, n, &mut out, &mut scratch)),
        );
    }

    let mut suite = Suite::new("row-length scaling (uint8 lut2d)");
    for n in [16usize, 64, 256, 1024] {
        let x = rng.normal_vec(64 * n, 2.0);
        let mut out = vec![0.0f32; x.len()];
        let e = engine(Mode::Lut2d, Precision::Uint8, None);
        suite.add(
            Bench::new(format!("lut2d/n={n}"))
                .items(x.len())
                .run(|| e.run_with(&x, n, &mut out, &mut scratch)),
        );
    }

    // row-parallel scaling: the same 256x128 batch sharded across workers
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let title = format!("row-parallel softmax (256 rows x 128, {cores} cores available)");
    let mut suite = Suite::new(&title);
    // dedup so 2- or 4-core machines don't emit duplicate trajectory labels
    let mut worker_counts = vec![2usize, 4, cores];
    worker_counts.sort_unstable();
    worker_counts.dedup();
    for mode in [Mode::Rexp, Mode::Lut2d] {
        for &w in &worker_counts {
            let p = ParSoftmax::with_workers(Arc::from(engine(mode, Precision::Uint8, None)), w);
            suite.add(
                Bench::new(format!("par/{}/w{w}", mode.name()))
                    .items(x.len())
                    .run(|| p.run_with(&x, n, &mut out, &mut scratch)),
            );
        }
        if let (Some(&lo), Some(&hi)) = (worker_counts.first(), worker_counts.last()) {
            if lo != hi {
                suite.ratio(
                    &format!("par/{}/w{hi}", mode.name()),
                    &format!("par/{}/w{lo}", mode.name()),
                );
            }
        }
    }

    // fused integer attention vs the unfused compose (uint8 rexp).
    // items = score elements (B·H·L·S), the softmax-work measure; the
    // acceptance bar is attn/* >= 1.3x attn_unfused/* element throughput.
    let mut suite = Suite::new("fused integer attention vs unfused compose (uint8 rexp)");
    for (h, l) in [(4usize, 64usize), (8, 128)] {
        let shape = AttnShape::square(1, h, l, 64);
        let (qt, kt, vt) = lutmax::workload::attn_qkv(&mut rng, &shape, 1.0);
        let q = QuantTensor::quantize(qt.as_f32().unwrap());
        let k = QuantTensor::quantize(kt.as_f32().unwrap());
        let v = QuantTensor::quantize(vt.as_f32().unwrap());
        let mask = AttnMask::Dense;
        let mut aout = vec![0.0f32; shape.q_len()];
        let fused = FusedAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let mut ascratch = AttnScratch::new();
        suite.add(
            Bench::new(format!("attn/h{h}/L{l}"))
                .items(shape.score_len())
                .run(|| fused.run(&q, &k, &v, &shape, &mask, &mut aout, &mut ascratch)),
        );
        // same alpha table as the fused kernel so the compose does the
        // same logical work
        let composed = ComposedAttention::new(engine(
            Mode::Rexp,
            Precision::Uint8,
            Some(lutmax::attention::ATTN_ALPHA_LEN),
        ));
        suite.add(
            Bench::new(format!("attn_unfused/h{h}/L{l}"))
                .items(shape.score_len())
                .run(|| composed.run_quant(&q, &k, &v, &shape, &mask, &mut aout)),
        );
        suite.ratio(&format!("attn/h{h}/L{l}"), &format!("attn_unfused/h{h}/L{l}"));
    }

    // streaming decode: L single-token steps through DecodeAttention over
    // the paged i8 KV cache. items = score elements Σ_t H·t — the same
    // work measure as attn/*, so element throughput is comparable. The
    // h8/g8 vs h8/g2 pair is the MHA-vs-GQA story: identical MAC work,
    // 1/4 the stored K/V traffic. decode/* keeps the head-major sweep as
    // the stable baseline; decode_groupmajor/* runs the identical fleet
    // through the group-major product path, so that ratio is the pure
    // read-amplification saving (expect ≥ 1x, growing as H/G grows).
    let mut suite = Suite::new("streaming decode over paged KV (uint8 rexp, page 16)");
    let mut decode_case = |label: String, h: usize, g: usize, l: usize, order: SweepOrder| {
        let d = 64usize;
        let a = DECODE_AFFINE;
        let mut kv = KvPool::new(KvConfig {
            pages: 2 * l.div_ceil(16),
            page_size: 16,
            kv_heads: g,
            d_head: d,
        });
        let groups = HeadGroups::new(h, g).unwrap();
        let dec = DecodeAttention::with_order(Mode::Rexp, Precision::Uint8, None, order).unwrap();
        let mut step_rng = Rng::new(77);
        let qs: Vec<Vec<i8>> = (0..l)
            .map(|_| (0..h * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let ks: Vec<Vec<i8>> = (0..l)
            .map(|_| (0..g * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let vs: Vec<Vec<i8>> = (0..l)
            .map(|_| (0..g * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let mut out = vec![0.0f32; h * d];
        let mut scr = AttnScratch::new();
        suite.add(Bench::new(label).items(h * l * (l + 1) / 2).run(|| {
            let mut seq = KvSeq::new(groups, a, a);
            for t in 0..l {
                dec.step(&mut kv, &mut seq, &qs[t], a, &ks[t], &vs[t], &mut out, &mut scr)
                    .expect("bench arena sized for one sequence");
            }
            kv.close(seq);
        }));
    };
    decode_case("decode/h4/g4/L64".into(), 4, 4, 64, SweepOrder::HeadMajor);
    decode_case("decode/h8/g8/L128".into(), 8, 8, 128, SweepOrder::HeadMajor);
    decode_case("decode/h8/g2/L128".into(), 8, 2, 128, SweepOrder::HeadMajor);
    // the GQA side again under its stable semantic label (see header)
    decode_case("decode_gqa_vs_mha".into(), 8, 2, 128, SweepOrder::HeadMajor);
    // the same fleet, group-major: the delta is pure read amplification
    decode_case("decode_groupmajor/h4/g4/L64".into(), 4, 4, 64, SweepOrder::GroupMajor);
    decode_case("decode_groupmajor/h8/g8/L128".into(), 8, 8, 128, SweepOrder::GroupMajor);
    decode_case("decode_groupmajor/h8/g2/L128".into(), 8, 2, 128, SweepOrder::GroupMajor);
    suite.ratio("decode/h8/g2/L128", "decode/h8/g8/L128");
    suite.ratio("decode_gqa_vs_mha", "decode/h8/g8/L128");
    suite.ratio("decode_groupmajor/h8/g2/L128", "decode/h8/g2/L128");
    suite.ratio("decode_groupmajor/h8/g8/L128", "decode/h8/g8/L128");

    // long-context prefix-split decode: the same streaming shape at
    // L512 through step_split, spans chosen per step by the serving
    // policy (spans_for, split_min_tokens 128 — up to 4 spans at full
    // length). g1 is the motivating MQA case: a bare group-major step
    // has ONE sweep unit, so the prefix split is its only fan-out axis;
    // g8 prices the merge overhead where group fan-out already exists.
    let mut split_case = |label: String, h: usize, g: usize, l: usize| {
        let d = 64usize;
        let a = DECODE_AFFINE;
        let mut kv = KvPool::new(KvConfig {
            pages: 2 * l.div_ceil(16),
            page_size: 16,
            kv_heads: g,
            d_head: d,
        });
        let groups = HeadGroups::new(h, g).unwrap();
        let dec = DecodeAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let mut step_rng = Rng::new(80);
        let qs: Vec<Vec<i8>> = (0..l)
            .map(|_| (0..h * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let ks: Vec<Vec<i8>> = (0..l)
            .map(|_| (0..g * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let vs: Vec<Vec<i8>> = (0..l)
            .map(|_| (0..g * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let mut out = vec![0.0f32; h * d];
        let mut scr = AttnScratch::new();
        suite.add(Bench::new(label).items(h * l * (l + 1) / 2).run(|| {
            let mut seq = KvSeq::new(groups, a, a);
            for t in 0..l {
                let spans = spans_for(t + 1, 16, 128);
                dec.step_split(&mut kv, &mut seq, &qs[t], a, &ks[t], &vs[t], spans, &mut out, &mut scr)
                    .expect("bench arena sized for one sequence");
            }
            kv.close(seq);
        }));
    };
    split_case("decode_split/h8/g1/L512".into(), 8, 1, 512);
    split_case("decode_split/h8/g8/L512".into(), 8, 8, 512);
    suite.ratio("decode_split/h8/g1/L512", "decode_split/h8/g8/L512");

    // batched decode rounds: S concurrent sessions stream L tokens; every
    // round is ONE DecodeBatch scatter wave of S×G group tasks over the
    // worker pool (decode_batch/*) vs S per-session step_par scatters
    // (decode_batch_serial/*) — identical MAC work, identical outputs,
    // the delta is pool wakes + task accounting. items = total score
    // elements S·Σ_t H·t, comparable with decode/*.
    let mut suite = Suite::new("batched decode rounds (uint8 rexp, page 16, d 64)");
    let mut batch_case = |label: String, s: usize, h: usize, g: usize, l: usize, batched: bool| {
        let d = 64usize;
        let a = DECODE_AFFINE;
        let mut kv = KvPool::new(KvConfig {
            pages: s * l.div_ceil(16) + 2,
            page_size: 16,
            kv_heads: g,
            d_head: d,
        });
        let groups = HeadGroups::new(h, g).unwrap();
        let dec = DecodeAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let wave = DecodeBatch::new(&dec);
        let pool = ParSoftmax::with_policy(
            Arc::from(engine(Mode::Rexp, Precision::Uint8, None)),
            4,
            2,
        );
        let mut step_rng = Rng::new(78);
        let qs: Vec<Vec<i8>> = (0..s * l)
            .map(|_| (0..h * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let ks: Vec<Vec<i8>> = (0..s * l)
            .map(|_| (0..g * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let vs: Vec<Vec<i8>> = (0..s * l)
            .map(|_| (0..g * d).map(|_| step_rng.int(-64, 64) as i8).collect())
            .collect();
        let mut outs = vec![vec![0.0f32; h * d]; s];
        let mut scr = AttnScratch::new();
        suite.add(Bench::new(label).items(s * h * l * (l + 1) / 2).run(|| {
            let mut seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
            for t in 0..l {
                if batched {
                    let mut tasks: Vec<lutmax::attention::DecodeStepTask<'_>> = seqs
                        .iter_mut()
                        .zip(outs.iter_mut())
                        .enumerate()
                        .map(|(i, (seq, out))| lutmax::attention::DecodeStepTask {
                            seq,
                            q: &qs[i * l + t],
                            q_affine: a,
                            k_row: &ks[i * l + t],
                            v_row: &vs[i * l + t],
                            out,
                        })
                        .collect();
                    for r in wave.step_wave(&mut kv, &mut tasks, &pool, &mut scr) {
                        r.expect("bench arena sized for the fleet");
                    }
                } else {
                    for (i, (seq, out)) in seqs.iter_mut().zip(outs.iter_mut()).enumerate() {
                        dec.step_par(
                            &mut kv,
                            seq,
                            &qs[i * l + t],
                            a,
                            &ks[i * l + t],
                            &vs[i * l + t],
                            &pool,
                            out,
                            &mut scr,
                        )
                        .expect("bench arena sized for the fleet");
                    }
                }
            }
            for seq in seqs {
                kv.close(seq);
            }
        }));
    };
    batch_case("decode_batch/s4/h8/L64".into(), 4, 8, 2, 64, true);
    batch_case("decode_batch_serial/s4/h8/L64".into(), 4, 8, 2, 64, false);
    batch_case("decode_batch/s16/h8/L64".into(), 16, 8, 2, 64, true);
    batch_case("decode_batch_serial/s16/h8/L64".into(), 16, 8, 2, 64, false);
    suite.ratio("decode_batch/s4/h8/L64", "decode_batch_serial/s4/h8/L64");
    suite.ratio("decode_batch/s16/h8/L64", "decode_batch_serial/s16/h8/L64");

    // continuous-batching serving rounds: full session lifecycles (open,
    // 2-token prefill, L step rounds, close) through the scheduler. The
    // mixed-vs-barrier pair isolates round assembly (one wave per round
    // vs one run_batch per payload); the evict case overcommits the
    // arena so the measured rounds also carry eviction/restore churn.
    // items = total score elements S·Σ_t H·t with T = L + 2, comparable
    // with decode_batch/*.
    let mut suite = Suite::new("continuous-batching decode scheduler (uint8 rexp, g2, d 64)");
    let mut sched_case = |label: String, s: usize, pages: usize, l: usize, barrier: bool| {
        let (h, g, d) = (8usize, 2usize, 64usize);
        let p = DecodePipeline::load(&format!("decode:rexp:uint8:g{g}:p{pages}"), 4).unwrap();
        let mut step_rng = Rng::new(79);
        let pre: Vec<(Tensor, Tensor, Tensor)> = (0..s)
            .map(|_| lutmax::workload::decode_prefill_chunk(&mut step_rng, 2, h, g, d, 1.0))
            .collect();
        let qkv: Vec<(Tensor, Tensor, Tensor)> = (0..s * l)
            .map(|_| lutmax::workload::decode_qkv_step(&mut step_rng, h, g, d, 1.0))
            .collect();
        let total_t = l + 2;
        suite.add(Bench::new(label).items(s * h * total_t * (total_t + 1) / 2).run(|| {
            let opens: Vec<Payload> = (0..s).map(|_| Payload::DecodeOpen).collect();
            let refs: Vec<&Payload> = opens.iter().collect();
            let ids: Vec<u64> = p
                .run_batch(&refs)
                .into_iter()
                .map(|r| match r {
                    Reply::Session(id) => id,
                    other => panic!("open failed: {other:?}"),
                })
                .collect();
            let pres: Vec<Payload> = ids
                .iter()
                .zip(&pre)
                .map(|(&id, (q, k, v))| Payload::DecodePrefill {
                    session: id,
                    q: q.clone(),
                    k: k.clone(),
                    v: v.clone(),
                })
                .collect();
            let refs: Vec<&Payload> = pres.iter().collect();
            for r in p.run_batch(&refs) {
                assert!(matches!(r, Reply::Prefill(_)), "prefill failed: {r:?}");
            }
            for t in 0..l {
                let round: Vec<Payload> = ids
                    .iter()
                    .enumerate()
                    .map(|(i, &id)| {
                        let (q, k, v) = &qkv[i * l + t];
                        Payload::DecodeStep {
                            session: id,
                            q: q.clone(),
                            k: k.clone(),
                            v: v.clone(),
                        }
                    })
                    .collect();
                if barrier {
                    for pl in &round {
                        let r = p.run_batch(&[pl]).remove(0);
                        assert!(matches!(r, Reply::Token(_)), "step failed: {r:?}");
                    }
                } else {
                    let refs: Vec<&Payload> = round.iter().collect();
                    for r in p.run_batch(&refs) {
                        assert!(matches!(r, Reply::Token(_)), "step failed: {r:?}");
                    }
                }
            }
            let closes: Vec<Payload> = ids.iter().map(|&id| Payload::DecodeClose(id)).collect();
            let refs: Vec<&Payload> = closes.iter().collect();
            for r in p.run_batch(&refs) {
                assert!(matches!(r, Reply::Closed { .. }), "close failed: {r:?}");
            }
        }));
    };
    // 8 sessions x 18 tokens = 144 resident tokens on 512 slots: rounds
    // coalesce, nothing evicts
    sched_case("decode_sched/s8/p32/mixed".into(), 8, 32, 16, false);
    sched_case("decode_sched_barrier/s8/p32/mixed".into(), 8, 32, 16, true);
    // 16 sessions x 18 tokens = 288 resident tokens on 128 slots: every
    // round churns evictions and restores, no step may fail
    sched_case("decode_sched/s16/p8/evict".into(), 16, 8, 16, false);
    suite.ratio("decode_sched/s8/p32/mixed", "decode_sched_barrier/s8/p32/mixed");
    suite.ratio("decode_sched/s16/p8/evict", "decode_sched/s8/p32/mixed");

    // the same fleets under a live fault schedule: the route's `:fS`
    // suffix arms spurious KV alloc failures, contained worker panics
    // and slowdowns, and injected deadline sheds. Steps and prefills
    // may legally come back typed-degraded (Error/Shed/Exhausted) —
    // what may NOT happen is a lost or hung reply, a poisoned pool, or
    // a leaked page; opens and closes never fault
    lutmax::faults::silence_injected_panics();
    let mut fault_case = |label: String, s: usize, pages: usize, l: usize, seed: u64| {
        let (h, g, d) = (8usize, 2usize, 64usize);
        let p =
            DecodePipeline::load(&format!("decode:rexp:uint8:g{g}:p{pages}:f{seed}"), 4).unwrap();
        let mut step_rng = Rng::new(83);
        let pre: Vec<(Tensor, Tensor, Tensor)> = (0..s)
            .map(|_| lutmax::workload::decode_prefill_chunk(&mut step_rng, 2, h, g, d, 1.0))
            .collect();
        let qkv: Vec<(Tensor, Tensor, Tensor)> = (0..s * l)
            .map(|_| lutmax::workload::decode_qkv_step(&mut step_rng, h, g, d, 1.0))
            .collect();
        let total_t = l + 2;
        let degraded_ok = |r: &Reply| {
            matches!(r, Reply::Error(_) | Reply::Shed { .. } | Reply::Exhausted { .. })
        };
        suite.add(Bench::new(label).items(s * h * total_t * (total_t + 1) / 2).run(|| {
            let opens: Vec<Payload> = (0..s).map(|_| Payload::DecodeOpen).collect();
            let refs: Vec<&Payload> = opens.iter().collect();
            let ids: Vec<u64> = p
                .run_batch(&refs)
                .into_iter()
                .map(|r| match r {
                    Reply::Session(id) => id,
                    other => panic!("open failed: {other:?}"),
                })
                .collect();
            let pres: Vec<Payload> = ids
                .iter()
                .zip(&pre)
                .map(|(&id, (q, k, v))| Payload::DecodePrefill {
                    session: id,
                    q: q.clone(),
                    k: k.clone(),
                    v: v.clone(),
                })
                .collect();
            let refs: Vec<&Payload> = pres.iter().collect();
            for r in p.run_batch(&refs) {
                assert!(
                    matches!(r, Reply::Prefill(_)) || degraded_ok(&r),
                    "prefill lost: {r:?}"
                );
            }
            for t in 0..l {
                let round: Vec<Payload> = ids
                    .iter()
                    .enumerate()
                    .map(|(i, &id)| {
                        let (q, k, v) = &qkv[i * l + t];
                        Payload::DecodeStep {
                            session: id,
                            q: q.clone(),
                            k: k.clone(),
                            v: v.clone(),
                        }
                    })
                    .collect();
                let refs: Vec<&Payload> = round.iter().collect();
                for r in p.run_batch(&refs) {
                    assert!(
                        matches!(r, Reply::Token(_)) || degraded_ok(&r),
                        "step lost: {r:?}"
                    );
                }
            }
            let closes: Vec<Payload> = ids.iter().map(|&id| Payload::DecodeClose(id)).collect();
            let refs: Vec<&Payload> = closes.iter().collect();
            for r in p.run_batch(&refs) {
                assert!(matches!(r, Reply::Closed { .. }), "close failed: {r:?}");
            }
        }));
    };
    fault_case("decode_sched_fault/s8/p32/f7".into(), 8, 32, 16, 7);
    fault_case("decode_sched_fault/s16/p8/f7".into(), 16, 8, 16, 7);
    suite.ratio("decode_sched_fault/s8/p32/f7", "decode_sched/s8/p32/mixed");
    suite.ratio("decode_sched_fault/s16/p8/f7", "decode_sched/s16/p8/evict");

    // the spill rungs under drain/restart: the evict fleet again, with a
    // full graceful drain + re-adopt inserted mid-decode every iteration
    // — every session's pages go host-side and come back lazily as
    // rounds demand them. The plain label restores through the
    // checksummed verbatim copy-back rung; the _replay twin arms
    // FaultSite::SpillCorrupt at denominator 1 so EVERY restore demotes
    // to the token-by-token replay-log fallback. The ratio between the
    // two is the price of losing the fast rung (the asymmetry
    // hwsim::simulate_decode_spill models: per-page costs vs per-token
    // costs), and replies stay bit-identical Tokens on both — the ladder
    // is a latency story, never a correctness one
    let mut spill_case = |label: String, s: usize, pages: usize, l: usize, replay: bool| {
        use lutmax::faults::{FaultPlan, FaultSite};
        let (h, g, d) = (8usize, 2usize, 64usize);
        let p = DecodePipeline::load(&format!("decode:rexp:uint8:g{g}:p{pages}"), 4).unwrap();
        if replay {
            p.set_fault_plan(FaultPlan::none().with_seed(97).with(FaultSite::SpillCorrupt, 1));
        }
        let mut step_rng = Rng::new(89);
        let pre: Vec<(Tensor, Tensor, Tensor)> = (0..s)
            .map(|_| lutmax::workload::decode_prefill_chunk(&mut step_rng, 2, h, g, d, 1.0))
            .collect();
        let qkv: Vec<(Tensor, Tensor, Tensor)> = (0..s * l)
            .map(|_| lutmax::workload::decode_qkv_step(&mut step_rng, h, g, d, 1.0))
            .collect();
        let total_t = l + 2;
        suite.add(Bench::new(label).items(s * h * total_t * (total_t + 1) / 2).run(|| {
            let opens: Vec<Payload> = (0..s).map(|_| Payload::DecodeOpen).collect();
            let refs: Vec<&Payload> = opens.iter().collect();
            let ids: Vec<u64> = p
                .run_batch(&refs)
                .into_iter()
                .map(|r| match r {
                    Reply::Session(id) => id,
                    other => panic!("open failed: {other:?}"),
                })
                .collect();
            let pres: Vec<Payload> = ids
                .iter()
                .zip(&pre)
                .map(|(&id, (q, k, v))| Payload::DecodePrefill {
                    session: id,
                    q: q.clone(),
                    k: k.clone(),
                    v: v.clone(),
                })
                .collect();
            let refs: Vec<&Payload> = pres.iter().collect();
            for r in p.run_batch(&refs) {
                assert!(matches!(r, Reply::Prefill(_)), "prefill failed: {r:?}");
            }
            for t in 0..l {
                if t == l / 2 {
                    let report = p.drain();
                    p.adopt_spill(report);
                }
                let round: Vec<Payload> = ids
                    .iter()
                    .enumerate()
                    .map(|(i, &id)| {
                        let (q, k, v) = &qkv[i * l + t];
                        Payload::DecodeStep {
                            session: id,
                            q: q.clone(),
                            k: k.clone(),
                            v: v.clone(),
                        }
                    })
                    .collect();
                let refs: Vec<&Payload> = round.iter().collect();
                for r in p.run_batch(&refs) {
                    assert!(matches!(r, Reply::Token(_)), "step failed: {r:?}");
                }
            }
            let closes: Vec<Payload> = ids.iter().map(|&id| Payload::DecodeClose(id)).collect();
            let refs: Vec<&Payload> = closes.iter().collect();
            for r in p.run_batch(&refs) {
                assert!(matches!(r, Reply::Closed { .. }), "close failed: {r:?}");
            }
        }));
    };
    spill_case("decode_sched_spill/s16/p8".into(), 16, 8, 16, false);
    spill_case("decode_sched_spill_replay/s16/p8".into(), 16, 8, 16, true);
    suite.ratio("decode_sched_spill_replay/s16/p8", "decode_sched_spill/s16/p8");
    suite.ratio("decode_sched_spill/s16/p8", "decode_sched/s16/p8/evict");

    // the observability bound: the s8/p32 mixed fleet re-run with a
    // Wall-clock trace sink and per-stage timing armed. The ratio
    // against the untraced case IS the tracing overhead — the smoke
    // gate keeps it small (≤ ~1.05x). `reset_trace` at the top of each
    // iteration bounds the sink's memory without disarming it.
    let mut traced_case = |label: String, s: usize, pages: usize, l: usize| {
        let (h, g, d) = (8usize, 2usize, 64usize);
        let p = DecodePipeline::load(&format!("decode:rexp:uint8:g{g}:p{pages}"), 4).unwrap();
        p.set_trace(TraceClock::Wall);
        p.set_stage_timing(true);
        let mut step_rng = Rng::new(79);
        let pre: Vec<(Tensor, Tensor, Tensor)> = (0..s)
            .map(|_| lutmax::workload::decode_prefill_chunk(&mut step_rng, 2, h, g, d, 1.0))
            .collect();
        let qkv: Vec<(Tensor, Tensor, Tensor)> = (0..s * l)
            .map(|_| lutmax::workload::decode_qkv_step(&mut step_rng, h, g, d, 1.0))
            .collect();
        let total_t = l + 2;
        suite.add(Bench::new(label).items(s * h * total_t * (total_t + 1) / 2).run(|| {
            p.reset_trace();
            let opens: Vec<Payload> = (0..s).map(|_| Payload::DecodeOpen).collect();
            let refs: Vec<&Payload> = opens.iter().collect();
            let ids: Vec<u64> = p
                .run_batch(&refs)
                .into_iter()
                .map(|r| match r {
                    Reply::Session(id) => id,
                    other => panic!("open failed: {other:?}"),
                })
                .collect();
            let pres: Vec<Payload> = ids
                .iter()
                .zip(&pre)
                .map(|(&id, (q, k, v))| Payload::DecodePrefill {
                    session: id,
                    q: q.clone(),
                    k: k.clone(),
                    v: v.clone(),
                })
                .collect();
            let refs: Vec<&Payload> = pres.iter().collect();
            for r in p.run_batch(&refs) {
                assert!(matches!(r, Reply::Prefill(_)), "prefill failed: {r:?}");
            }
            for t in 0..l {
                let round: Vec<Payload> = ids
                    .iter()
                    .enumerate()
                    .map(|(i, &id)| {
                        let (q, k, v) = &qkv[i * l + t];
                        Payload::DecodeStep {
                            session: id,
                            q: q.clone(),
                            k: k.clone(),
                            v: v.clone(),
                        }
                    })
                    .collect();
                let refs: Vec<&Payload> = round.iter().collect();
                for r in p.run_batch(&refs) {
                    assert!(matches!(r, Reply::Token(_)), "step failed: {r:?}");
                }
            }
            let closes: Vec<Payload> = ids.iter().map(|&id| Payload::DecodeClose(id)).collect();
            let refs: Vec<&Payload> = closes.iter().collect();
            for r in p.run_batch(&refs) {
                assert!(matches!(r, Reply::Closed { .. }), "close failed: {r:?}");
            }
        }));
    };
    traced_case("decode_sched_traced/s8/p32".into(), 8, 32, 16);
    suite.ratio("decode_sched_traced/s8/p32", "decode_sched/s8/p32/mixed");

    // Single-source canonical label gate: `scripts/bench_labels.txt` is
    // the ONE list of labels this binary must emit — `bench_smoke.sh`
    // greps the same file against the JSON trajectory. Checking it here
    // too means a label can neither be dropped from the bench nor added
    // without being listed, and the failure happens at `cargo bench`
    // time, before any trajectory file is compared.
    let recorded = lutmax::benchkit::recorded_names();
    for label in include_str!("../scripts/bench_labels.txt")
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
    {
        assert!(
            recorded.iter().any(|n| n == label),
            "canonical bench label {label:?} (scripts/bench_labels.txt) was not recorded this run"
        );
    }

    if let Some(path) = flush_json().expect("write BENCH_JSON") {
        println!("\n[bench] wrote {}", path.display());
    }
}
