//! Softmax software-model benchmarks: the paper's datapaths vs baselines
//! on the rust hot path (per-element throughput, Table-1-adjacent).

use lutmax::benchkit::{Bench, Suite};
use lutmax::lut::Precision;
use lutmax::softmax::{engine, Mode};
use lutmax::testkit::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let n = 128usize;
    let rows = 256usize;
    let x = rng.normal_vec(rows * n, 2.0);
    let mut out = vec![0.0f32; x.len()];

    let mut suite = Suite::new("softmax SW models (256 rows x 128)");
    for mode in [
        Mode::Exact,
        Mode::PriorartEq2Plus,
        Mode::Rexp,
        Mode::Lut2d,
        Mode::Aggressive,
    ] {
        let e = engine(mode, Precision::Uint8, None);
        let r = Bench::new(format!("uint8/{}", mode.name()))
            .items(x.len())
            .run(|| e.run(&x, n, &mut out));
        suite.add(r);
    }
    suite.ratio("uint8/rexp", "uint8/exact");
    suite.ratio("uint8/lut2d", "uint8/exact");

    let mut suite = Suite::new("softmax SW models across precisions (rexp)");
    for p in lutmax::lut::ALL_PRECISIONS {
        let e = engine(Mode::Rexp, p, None);
        suite.add(
            Bench::new(format!("rexp/{}", p.name()))
                .items(x.len())
                .run(|| e.run(&x, n, &mut out)),
        );
    }

    let mut suite = Suite::new("row-length scaling (uint8 lut2d)");
    for n in [16usize, 64, 256, 1024] {
        let x = rng.normal_vec(64 * n, 2.0);
        let mut out = vec![0.0f32; x.len()];
        let e = engine(Mode::Lut2d, Precision::Uint8, None);
        suite.add(
            Bench::new(format!("lut2d/n={n}"))
                .items(x.len())
                .run(|| e.run(&x, n, &mut out)),
        );
    }
}
