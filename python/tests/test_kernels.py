"""Pallas kernel vs pure-jnp oracle: the CORE L1 correctness signal.

Equality contract: the integer pipelines must agree bit-exactly; the final
float division may differ by 1 ULP between interpret-mode pallas and plain
jnp, so comparisons are made on `round(out * qmax)` (the integer stage).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import luts, ref
from compile.kernels.attention import attention_pallas, attention_ref
from compile.kernels.softmax_exact import softmax_exact_pallas
from compile.kernels.softmax_lut2d import softmax_lut2d_pallas
from compile.kernels.softmax_rexp import softmax_rexp_pallas

RNG = np.random.default_rng(7)


def rand(shape, scale=3.0):
    return jnp.asarray(RNG.normal(0.0, scale, shape).astype(np.float32))


def assert_int_identical(out, want, qmax):
    a = np.rint(np.asarray(out, np.float64) * qmax)
    b = np.rint(np.asarray(want, np.float64) * qmax)
    np.testing.assert_array_equal(a, b)


class TestExactKernel:
    def test_matches_ref(self):
        x = rand((37, 50))
        np.testing.assert_allclose(
            softmax_exact_pallas(x), ref.softmax_exact(x), atol=1e-6
        )

    def test_multi_block_grid(self):
        # rows > block_rows exercises the row-tiled grid path
        x = rand((300, 32))
        np.testing.assert_allclose(
            softmax_exact_pallas(x, block_rows=64), ref.softmax_exact(x), atol=1e-6
        )

    def test_3d_input(self):
        x = rand((4, 9, 21))
        np.testing.assert_allclose(
            softmax_exact_pallas(x), ref.softmax_exact(x), atol=1e-6
        )

    def test_single_row(self):
        x = rand((1, 5))
        np.testing.assert_allclose(
            softmax_exact_pallas(x), ref.softmax_exact(x), atol=1e-6
        )


@pytest.mark.parametrize("prec", list(luts.PRECISIONS))
class TestRexpKernel:
    def test_matches_ref(self, prec):
        p = luts.precision(prec)
        x = rand((37, 50))
        assert_int_identical(
            softmax_rexp_pallas(x, prec), ref.softmax_rexp(x, prec), p.qmax
        )

    def test_multi_block(self, prec):
        p = luts.precision(prec)
        x = rand((260, 24))
        assert_int_identical(
            softmax_rexp_pallas(x, prec, block_rows=32),
            ref.softmax_rexp(x, prec),
            p.qmax,
        )


@pytest.mark.parametrize("prec", list(luts.PRECISIONS))
class TestLut2dKernel:
    def test_matches_ref(self, prec):
        p = luts.precision(prec)
        x = rand((37, 50))
        assert_int_identical(
            softmax_lut2d_pallas(x, prec), ref.softmax_lut2d(x, prec), p.qmax
        )

    def test_multi_block(self, prec):
        p = luts.precision(prec)
        x = rand((260, 24))
        assert_int_identical(
            softmax_lut2d_pallas(x, prec, block_rows=32),
            ref.softmax_lut2d(x, prec),
            p.qmax,
        )


class TestAttentionKernel:
    @pytest.mark.parametrize("mode", ref.SOFTMAX_MODES)
    def test_all_modes_match_ref(self, mode):
        q, k, v = (rand((2, 4, 16, 8), scale=1.0) for _ in range(3))
        out = attention_pallas(q, k, v, mode, "uint8")
        want = attention_ref(q, k, v, mode, "uint8")
        np.testing.assert_allclose(out, want, atol=1e-5)

    def test_cross_attention_shapes(self):
        q = rand((3, 2, 10, 8), scale=1.0)
        k = rand((3, 2, 17, 8), scale=1.0)
        v = rand((3, 2, 17, 8), scale=1.0)
        out = attention_pallas(q, k, v, "rexp", "uint8")
        assert out.shape == (3, 2, 10, 8)
        np.testing.assert_allclose(
            out, attention_ref(q, k, v, "rexp", "uint8"), atol=1e-5
        )

    def test_unknown_mode_raises(self):
        q = rand((1, 1, 4, 4))
        with pytest.raises(ValueError):
            attention_pallas(q, q, q, "bogus", "uint8")


class TestHypothesisKernelSweep:
    """Randomized shape/precision sweep of kernel==oracle (the task brief's
    L1 hypothesis requirement)."""

    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.integers(1, 70),
        n=st.integers(2, 80),
        scale=st.floats(0.2, 6.0),
        seed=st.integers(0, 2**31 - 1),
        prec=st.sampled_from(list(luts.PRECISIONS)),
        block=st.sampled_from([8, 32, 128]),
    )
    def test_rexp_kernel_equals_oracle(self, rows, n, scale, seed, prec, block):
        p = luts.precision(prec)
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, scale, (rows, n)).astype(np.float32))
        assert_int_identical(
            softmax_rexp_pallas(x, prec, block_rows=block),
            ref.softmax_rexp(x, prec),
            p.qmax,
        )

    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.integers(1, 70),
        n=st.integers(2, 80),
        scale=st.floats(0.2, 6.0),
        seed=st.integers(0, 2**31 - 1),
        prec=st.sampled_from(list(luts.PRECISIONS)),
        block=st.sampled_from([8, 32, 128]),
    )
    def test_lut2d_kernel_equals_oracle(self, rows, n, scale, seed, prec, block):
        p = luts.precision(prec)
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, scale, (rows, n)).astype(np.float32))
        assert_int_identical(
            softmax_lut2d_pallas(x, prec, block_rows=block),
            ref.softmax_lut2d(x, prec),
            p.qmax,
        )

    @settings(max_examples=10, deadline=None)
    @given(
        heads=st.integers(1, 6),
        L=st.integers(2, 24),
        dh=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
        mode=st.sampled_from(["exact", "rexp", "lut2d"]),
    )
    def test_attention_equals_oracle(self, heads, L, dh, seed, mode):
        r = np.random.default_rng(seed)
        q, k, v = (
            jnp.asarray(r.normal(0, 1, (heads, L, dh)).astype(np.float32))
            for _ in range(3)
        )
        np.testing.assert_allclose(
            attention_pallas(q, k, v, mode, "uint8"),
            attention_ref(q, k, v, mode, "uint8"),
            atol=1e-5,
        )
