"""Property and behaviour tests of the pure-jnp softmax oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import luts, ref

RNG = np.random.default_rng(1234)


def rand(shape, scale=3.0):
    return jnp.asarray(RNG.normal(0.0, scale, shape).astype(np.float32))


class TestExact:
    def test_matches_jax_nn(self):
        x = rand((17, 33))
        np.testing.assert_allclose(
            ref.softmax_exact(x), jax.nn.softmax(x, axis=-1), rtol=1e-6
        )

    def test_rows_sum_to_one(self):
        x = rand((8, 64))
        np.testing.assert_allclose(
            jnp.sum(ref.softmax_exact(x), -1), 1.0, rtol=1e-6
        )

    def test_translation_invariance(self):
        x = rand((4, 16))
        np.testing.assert_allclose(
            ref.softmax_exact(x), ref.softmax_exact(x + 100.0), rtol=1e-5
        )

    def test_large_magnitudes_stable(self):
        x = jnp.asarray([[1e4, 1e4 - 1.0, 0.0]], jnp.float32)
        out = ref.softmax_exact(x)
        assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("prec", list(luts.PRECISIONS))
@pytest.mark.parametrize("mode", ["rexp", "lut2d"])
class TestLutApprox:
    def test_output_in_unit_interval(self, mode, prec):
        out = ref.softmax_by_mode(rand((16, 40)), mode, prec)
        assert bool(jnp.all(out >= 0.0)) and bool(jnp.all(out <= 1.0))

    def test_output_is_quantized_grid(self, mode, prec):
        # every output must be an integer multiple of 1/qmax
        p = luts.precision(prec)
        out = ref.softmax_by_mode(rand((8, 24)), mode, prec)
        grid = out * p.qmax
        np.testing.assert_allclose(grid, jnp.round(grid), atol=1e-3)

    def test_argmax_preserved(self, mode, prec):
        # the winning logit keeps the (weakly) largest probability
        x = rand((32, 20))
        out = ref.softmax_by_mode(x, mode, prec)
        win = jnp.argmax(x, -1)
        rowmax = jnp.max(out, -1)
        np.testing.assert_allclose(
            out[jnp.arange(x.shape[0]), win], rowmax, atol=1e-6
        )

    def test_order_preserved(self, mode, prec):
        # monotone: larger logits never get smaller probabilities
        x = jnp.sort(rand((16, 12)), axis=-1)
        out = ref.softmax_by_mode(x, mode, prec)
        assert bool(jnp.all(jnp.diff(out, axis=-1) >= -1e-6))

    def test_translation_invariance(self, mode, prec):
        # max-normalization makes the LUT methods exactly shift-invariant
        x = rand((8, 16))
        a = ref.softmax_by_mode(x, mode, prec)
        b = ref.softmax_by_mode(x + 57.25, mode, prec)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestAccuracyOrdering:
    """The paper's headline: accuracy improves with precision, and the
    proposed methods at uint8 are far closer to exact than prior arts."""

    def setup_method(self):
        # attention-like scores: moderate spread, many rows
        self.x = rand((256, 64), scale=2.0)
        self.exact = ref.softmax_exact(self.x)

    def _mae(self, mode, prec):
        out = ref.softmax_by_mode(self.x, mode, prec)
        return float(jnp.mean(jnp.abs(out - self.exact)))

    @pytest.mark.parametrize("mode", ["rexp", "lut2d"])
    def test_error_decreases_with_precision(self, mode):
        errs = [self._mae(mode, p) for p in ("uint2", "uint4", "uint8")]
        assert errs[2] <= errs[1] <= errs[0]

    def test_uint8_rexp_reasonable(self):
        assert self._mae("rexp", "uint8") < 0.02

    def test_aggressive_unnormalized(self):
        # Fig. 5 mechanism: aggressive rows do not sum to ~1
        out = ref.softmax_aggressive(self.x, "uint8")
        sums = jnp.sum(out, -1)
        assert float(jnp.max(jnp.abs(sums - 1.0))) > 0.5

    def test_priorart_eq2plus_better_than_eq2(self):
        big = self.x + 20.0  # un-normalized inputs hurt Eq.(2)
        e1 = float(
            jnp.mean(jnp.abs(ref.softmax_priorart_eq2(big, "uint4") - ref.softmax_exact(big)))
        )
        e2 = float(
            jnp.mean(jnp.abs(ref.softmax_priorart_eq2plus(big, "uint4") - ref.softmax_exact(big)))
        )
        assert e2 <= e1

    def test_dispatch_unknown_mode(self):
        with pytest.raises(ValueError, match="unknown softmax mode"):
            ref.softmax_by_mode(self.x, "nope")


class TestHypothesisSweeps:
    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(1, 33),
        n=st.integers(2, 65),
        scale=st.floats(0.1, 8.0),
        seed=st.integers(0, 2**31 - 1),
        mode=st.sampled_from(["rexp", "lut2d"]),
        prec=st.sampled_from(list(luts.PRECISIONS)),
    )
    def test_bounded_and_finite(self, rows, n, scale, seed, mode, prec):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, scale, (rows, n)).astype(np.float32))
        out = ref.softmax_by_mode(x, mode, prec)
        assert out.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(out)))
        assert bool(jnp.all(out >= 0.0)) and bool(jnp.all(out <= 1.0))

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(2, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_rexp_rowsum_near_one_uint8(self, n, seed):
        # PDF normalization (Eq.(5)-(6)) keeps row sums near 1 — that is the
        # paper's improvement over the raw reciprocal of [29]. The >>w
        # truncation of the alpha index bounds the row-sum overshoot by
        # ~1/S; with S >= 1 we can guarantee a loose global bound.
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, 2.0, (16, n)).astype(np.float32))
        sums = jnp.sum(ref.softmax_rexp(x, "uint8"), -1)
        assert bool(jnp.all(sums <= 2.05))
        assert bool(jnp.all(sums >= 0.4))
