"""Model-zoo tests: shapes, masking, softmax-mode plumbing, learnability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.models import bert, common, detr, nmt


class TestCommon:
    def test_layernorm_normalizes(self):
        p = common.layernorm_init(16)
        x = jnp.asarray(np.random.default_rng(0).normal(3, 5, (4, 16)).astype(np.float32))
        y = common.layernorm(p, x)
        np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, atol=1e-2)

    def test_positions_shape_and_range(self):
        pos = common.sinusoidal_positions(10, 8)
        assert pos.shape == (10, 8)
        assert float(jnp.max(jnp.abs(pos))) <= 1.0

    def test_split_merge_heads_roundtrip(self):
        x = jnp.arange(2 * 5 * 8, dtype=jnp.float32).reshape(2, 5, 8)
        np.testing.assert_array_equal(
            np.asarray(common.merge_heads(common.split_heads(x, 4))), np.asarray(x)
        )

    def test_causal_mask_blocks_future(self):
        m = common.causal_mask(4)[0, 0]
        assert float(m[0, 1]) < -1e8
        assert float(m[3, 0]) == 0.0

    def test_padding_mask_blocks_pad_keys(self):
        toks = jnp.asarray([[5, 6, 0, 0]], jnp.int32)
        m = common.padding_mask(toks)[0, 0, 0]
        assert float(m[0]) == 0.0 and float(m[2]) < -1e8

    def test_mha_mode_changes_output(self):
        key = jax.random.PRNGKey(0)
        p = common.mha_init(key, 16)
        x = jax.random.normal(key, (2, 6, 16))
        exact = common.mha(p, x, x, 4, softmax_mode="exact")
        approx = common.mha(p, x, x, 4, softmax_mode="rexp", prec="uint2")
        assert not np.allclose(np.asarray(exact), np.asarray(approx))

    def test_adam_reduces_quadratic(self):
        params = {"x": jnp.asarray([5.0, -3.0])}
        opt = common.adam_init(params)
        loss = lambda p: jnp.sum(p["x"] ** 2)  # noqa: E731
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, opt = common.adam_update(params, g, opt, lr=0.1)
        assert float(loss(params)) < 0.1

    def test_checkpoint_roundtrip(self, tmp_path):
        key = jax.random.PRNGKey(1)
        p = {"a": common.dense_init(key, 4, 4), "b": {"c": jnp.ones((3,))}}
        path = str(tmp_path / "ck.npz")
        common.save_params(path, p)
        q = common.load_params(path)
        np.testing.assert_array_equal(np.asarray(q["a"]["w"]), np.asarray(p["a"]["w"]))
        np.testing.assert_array_equal(np.asarray(q["b"]["c"]), np.asarray(p["b"]["c"]))


class TestNmt:
    CFG = nmt.NmtModelConfig(d_model=32, d_ff=64, heads=2, layers=1)

    def test_shapes(self):
        params = nmt.init_params(jax.random.PRNGKey(0), self.CFG)
        src, tgt = data.nmt_batch(data.NmtConfig(), 4, seed=0)
        mem = nmt.encode(params, jnp.asarray(src), self.CFG)
        assert mem.shape == (4, self.CFG.max_src, self.CFG.d_model)
        logits = nmt.decode_logits(params, mem, jnp.asarray(src), jnp.asarray(tgt[:, :-1]), self.CFG)
        assert logits.shape == (4, tgt.shape[1] - 1, self.CFG.vocab)

    def test_loss_decreases_quickly(self):
        dcfg = data.NmtConfig()
        params = nmt.init_params(jax.random.PRNGKey(0), self.CFG)
        opt = common.adam_init(params)
        src, tgt = data.nmt_batch(dcfg, 32, seed=0)
        src, tgt = jnp.asarray(src), jnp.asarray(tgt)

        @jax.jit
        def step(params, opt):
            loss, g = jax.value_and_grad(nmt.loss_fn)(params, src, tgt, self.CFG)
            params, opt = common.adam_update(params, g, opt, lr=3e-3)
            return params, opt, loss

        first = None
        for i in range(60):
            params, opt, loss = step(params, opt)
            first = first if first is not None else float(loss)
        assert float(loss) < first * 0.8, (first, float(loss))

    def test_greedy_decode_terminates(self):
        params = nmt.init_params(jax.random.PRNGKey(0), self.CFG)
        src, _ = data.nmt_batch(data.NmtConfig(), 2, seed=0)
        out = nmt.greedy_decode(params, jnp.asarray(src), self.CFG)
        assert out.shape == (2, self.CFG.max_tgt)
        assert (np.asarray(out[:, 0]) == data.BOS).all()

    def test_softmax_mode_affects_inference_not_shapes(self):
        params = nmt.init_params(jax.random.PRNGKey(0), self.CFG)
        src, _ = data.nmt_batch(data.NmtConfig(), 2, seed=0)
        m1 = nmt.encode(params, jnp.asarray(src), self.CFG, "exact")
        m2 = nmt.encode(params, jnp.asarray(src), self.CFG, "rexp", "uint4")
        assert m1.shape == m2.shape
        assert not np.allclose(np.asarray(m1), np.asarray(m2), atol=1e-4)


class TestBert:
    CFG = bert.BertModelConfig(d_model=32, d_ff=64, heads=2, layers=1)

    def test_forward_shape(self):
        params = bert.init_params(jax.random.PRNGKey(0), self.CFG)
        toks, _ = data.sentiment_batch(data.SentimentConfig(), 4, seed=0)
        logits = bert.forward(params, jnp.asarray(toks), self.CFG)
        assert logits.shape == (4, 2)

    def test_learns_sentiment_quickly(self):
        params = bert.init_params(jax.random.PRNGKey(0), self.CFG)
        opt = common.adam_init(params)

        @jax.jit
        def step(params, opt, toks, labels):
            loss, g = jax.value_and_grad(bert.loss_fn)(params, toks, labels, self.CFG)
            params, opt = common.adam_update(params, g, opt, lr=3e-3)
            return params, opt, loss

        for i in range(150):
            toks, labels = data.sentiment_batch(data.SentimentConfig(), 64, seed=i)
            params, opt, loss = step(params, opt, jnp.asarray(toks), jnp.asarray(labels))
        toks, labels = data.sentiment_batch(data.SentimentConfig(), 256, seed=999)
        acc = bert.accuracy(params, jnp.asarray(toks), jnp.asarray(labels), self.CFG)
        assert acc > 0.62, acc


class TestDetr:
    CFG = detr.DetrModelConfig(d_model=32, d_ff=64, heads=2, enc_layers=1, dec_layers=1)

    def test_patchify_shapes(self):
        imgs, _ = data.scene_batch(data.SceneConfig(), 2, seed=0)
        x = detr.patchify(jnp.asarray(imgs), self.CFG)
        assert x.shape == (2, self.CFG.tokens, self.CFG.patch_dim)

    def test_dc5_quadruples_tokens(self):
        dc5 = detr.dc5_variant(self.CFG)
        assert dc5.tokens == 4 * self.CFG.tokens

    def test_forward_shapes(self):
        params = detr.init_params(jax.random.PRNGKey(0), self.CFG)
        imgs, _ = data.scene_batch(data.SceneConfig(), 2, seed=0)
        cls, box = detr.forward(params, jnp.asarray(imgs), self.CFG)
        assert cls.shape == (2, self.CFG.num_queries, self.CFG.num_classes + 1)
        assert box.shape == (2, self.CFG.num_queries, 4)
        assert float(box.min()) >= 0.0 and float(box.max()) <= 1.0

    def test_match_assigns_each_gt_once(self):
        params = detr.init_params(jax.random.PRNGKey(0), self.CFG)
        imgs, gts = data.scene_batch(data.SceneConfig(), 3, seed=1)
        cls, box = detr.forward(params, jnp.asarray(imgs), self.CFG)
        for (qi, gi), g in zip(detr.match(cls, box, gts), gts):
            assert len(set(qi)) == len(qi)
            assert sorted(gi) == list(range(len(g)))

    def test_loss_finite_and_decreases(self):
        params = detr.init_params(jax.random.PRNGKey(0), self.CFG)
        opt = common.adam_init(params)
        imgs, gts = data.scene_batch(data.SceneConfig(), 8, seed=0)
        imgs = jnp.asarray(imgs)
        first = None
        for _ in range(25):
            loss, g = jax.value_and_grad(detr.loss_fn)(params, imgs, gts, self.CFG)
            params, opt = common.adam_update(params, g, opt, lr=1e-3)
            first = first if first is not None else float(loss)
        assert np.isfinite(first)
        assert float(loss) < first


class TestStatsHook:
    def test_fig4_stats_collect_sums(self):
        cfg = TestDetr.CFG
        params = detr.init_params(jax.random.PRNGKey(0), cfg)
        imgs, _ = data.scene_batch(data.SceneConfig(), 2, seed=0)
        stats: list = []
        detr.forward(params, jnp.asarray(imgs), cfg, stats=stats)
        # enc 1 + dec (self + cross) = 3 attention tensors
        assert len(stats) == 3
        for s in stats:
            assert float(jnp.min(s)) >= 1.0  # sum e^{x-max} >= 1 always


@pytest.mark.parametrize("mode", ["exact", "rexp", "lut2d"])
def test_pallas_softmax_flag_consistency(mode):
    """USE_PALLAS_SOFTMAX=True must not change model numerics (kernel ==
    oracle) — the property the AOT path relies on."""
    cfg = TestBert.CFG
    params = bert.init_params(jax.random.PRNGKey(3), cfg)
    toks, _ = data.sentiment_batch(data.SentimentConfig(), 4, seed=0)
    toks = jnp.asarray(toks)
    try:
        common.USE_PALLAS_SOFTMAX = False
        ref_out = bert.forward(params, toks, cfg, mode, "uint8")
        common.USE_PALLAS_SOFTMAX = True
        pallas_out = bert.forward(params, toks, cfg, mode, "uint8")
    finally:
        common.USE_PALLAS_SOFTMAX = False
    np.testing.assert_allclose(
        np.asarray(ref_out), np.asarray(pallas_out), atol=2e-4
    )
