"""Tests of the PTQ-D dynamic quantization simulation."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant
from compile.models import common

RNG = np.random.default_rng(55)


class TestAffine:
    def test_roundtrip_error_half_step(self):
        w = jnp.asarray(RNG.normal(0, 1, (32, 16)).astype(np.float32))
        q, scale, zp = quant.quantize_weight(w)
        deq = (q.astype(jnp.float32) - zp) * scale
        assert float(jnp.max(jnp.abs(deq - w))) <= float(scale) * 0.5 + 1e-6

    def test_int8_range(self):
        w = jnp.asarray(RNG.normal(0, 10, (64,)).astype(np.float32))
        q, _, _ = quant.quantize_weight(w)
        assert int(q.min()) >= quant.QMIN and int(q.max()) <= quant.QMAX

    @settings(max_examples=20, deadline=None)
    @given(scale=st.floats(0.01, 50.0), seed=st.integers(0, 2**31 - 1))
    def test_fake_quant_bounded_error(self, scale, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, scale, (64,)).astype(np.float32))
        fq = quant.fake_quant_array(x)
        step = (float(x.max()) - min(float(x.min()), 0.0)) / 255.0
        assert float(jnp.max(jnp.abs(fq - x))) <= max(step, 1e-6) * 0.51 + 1e-6


class TestQuantizeParams:
    def _params(self):
        key = jax.random.PRNGKey(0)
        return {
            "embed": common.embedding_init(key, 16, 8),
            "layer": common.dense_init(key, 8, 8),
            "ln": common.layernorm_init(8),
        }

    def test_only_dense_kernels_touched(self):
        p = self._params()
        q = quant.quantize_params(p)
        # embeddings and layernorm untouched
        np.testing.assert_array_equal(q["embed"], p["embed"])
        np.testing.assert_array_equal(q["ln"]["g"], p["ln"]["g"])
        np.testing.assert_array_equal(q["layer"]["b"], p["layer"]["b"])
        # dense kernel is changed but close
        assert not np.array_equal(q["layer"]["w"], p["layer"]["w"])
        assert float(jnp.max(jnp.abs(q["layer"]["w"] - p["layer"]["w"]))) < 0.05

    def test_size_accounting_table4(self):
        p = self._params()
        fp = quant.model_size_bytes(p, quantized=False)
        pq = quant.model_size_bytes(p, quantized=True)
        n_w = int(p["layer"]["w"].size)
        # quantized: dense kernel 1 B/elem + 8 B params; everything else 4 B
        assert fp - pq == n_w * 3 - 8
        assert pq < fp

    def test_qdense_close_to_dense(self):
        p = common.dense_init(jax.random.PRNGKey(1), 12, 6)
        x = jnp.asarray(RNG.normal(0, 1, (4, 12)).astype(np.float32))
        y = x @ p["w"] + p["b"]
        yq = quant.qdense(p, x)
        assert float(jnp.max(jnp.abs(y - yq))) < 0.15


class TestQuantizedGraphPath:
    def test_dense_quantized_flag(self):
        p = common.dense_init(jax.random.PRNGKey(2), 8, 8)
        pq = {"w": quant.fake_quant_array(p["w"]), "b": p["b"]}
        x = jnp.asarray(RNG.normal(0, 1, (3, 8)).astype(np.float32))
        y_fp = common.dense(p, x, quantized=False)
        y_q = common.dense(pq, x, quantized=True)
        # same function approximately, exactly quantized weights+activations
        assert float(jnp.max(jnp.abs(y_fp - y_q))) < 0.2
