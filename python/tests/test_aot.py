"""AOT pipeline tests: HLO lowering, the no-divider op census, variant
registry consistency, and manifest structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, quant
from compile.kernels.softmax_lut2d import make_lut2d_callable
from compile.kernels.softmax_rexp import make_rexp_callable
from compile.models import common, nmt


def hlo_of(fn, *specs, keep_unused=False):
    lowered = jax.jit(fn, keep_unused=keep_unused).lower(*specs)
    return aot.to_hlo_text(lowered)


class TestHloCensus:
    """The paper's headline HW property, asserted on the lowered HLO."""

    def test_rexp_kernel_has_no_divide(self):
        fn, specs = make_rexp_callable(64, 32, "uint8")
        text = hlo_of(fn, *specs)
        assert " divide(" not in text, "REXP artifact contains a divide op"

    def test_lut2d_kernel_has_no_divide(self):
        fn, specs = make_lut2d_callable(64, 32, "uint8")
        text = hlo_of(fn, *specs)
        assert " divide(" not in text

    def test_lut2d_kernel_no_float_multiply_on_probs(self):
        # the 2D-LUT path's only f32 multiply is the final dequant-by-
        # constant; there is no data-dependent product
        fn, specs = make_lut2d_callable(64, 32, "uint8")
        text = hlo_of(fn, *specs)
        assert text.count("multiply(") <= 6, text.count("multiply(")

    def test_exact_softmax_does_divide(self):
        from compile.kernels.softmax_exact import softmax_exact_pallas

        spec = jax.ShapeDtypeStruct((64, 32), jnp.float32)
        text = hlo_of(lambda x: (softmax_exact_pallas(x),), spec)
        assert " divide(" in text, "exact baseline should contain the divide"


class TestVariantRegistry:
    def test_variant_counts(self):
        vs = model.all_variants()
        names = [v.name for v in vs]
        assert len(names) == len(set(names)), "duplicate variant names"
        nmt_v = [v for v in vs if v.model.startswith("nmt")]
        cls_v = [v for v in vs if v.model in ("sst2", "mrpc")]
        det_v = [v for v in vs if v.model.startswith("detr")]
        assert len(nmt_v) == 20 and len(cls_v) == 20 and len(det_v) == 24

    def test_variant_name_roundtrip(self):
        v = model.Variant("detr", "ptqd", "rexp", "uint8:a512")
        assert v.name == "detr__ptqd__rexp__uint8-a512"
        assert v.quantized

    def test_artifact_graphs_kinds(self):
        assert set(model.artifact_graphs(model.Variant("nmt14", "fp32", "exact", "fp32"))) == {
            "enc",
            "dec",
        }
        assert set(model.artifact_graphs(model.Variant("sst2", "fp32", "exact", "fp32"))) == {
            "cls"
        }
        assert set(model.artifact_graphs(model.Variant("detr", "fp32", "exact", "fp32"))) == {
            "det"
        }


class TestLoweringRoundtrip:
    def test_small_model_lowering_parses(self):
        # lower a tiny nmt encoder and sanity-check the HLO text shape
        cfg = nmt.NmtModelConfig(d_model=16, d_ff=32, heads=2, layers=1)
        params = nmt.init_params(jax.random.PRNGKey(0), cfg)

        def fn(params, src):
            return (nmt.encode(params, src, cfg),)

        spec = jax.ShapeDtypeStruct((2, cfg.max_src), jnp.int32)
        text = hlo_of(fn, params, spec, keep_unused=True)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_keep_unused_retains_all_params(self):
        # the rust runtime feeds the FULL weight bundle to every artifact:
        # parameter count must equal leaves + inputs even for the encoder,
        # which does not touch decoder weights
        cfg = nmt.NmtModelConfig(d_model=16, d_ff=32, heads=2, layers=1)
        params = nmt.init_params(jax.random.PRNGKey(0), cfg)
        n_leaves = len(jax.tree_util.tree_leaves(params))

        def fn(params, src):
            return (nmt.encode(params, src, cfg),)

        spec = jax.ShapeDtypeStruct((2, cfg.max_src), jnp.int32)
        text = hlo_of(fn, params, spec, keep_unused=True)
        import re

        entry = text[text.index("ENTRY") :]
        ids = set(re.findall(r"parameter\((\d+)\)", entry.split("\n}")[0]))
        assert len(ids) == n_leaves + 1, (len(ids), n_leaves)

    def test_param_leaf_order_matches_tree_flatten(self):
        cfg = nmt.NmtModelConfig(d_model=16, d_ff=32, heads=2, layers=1)
        params = nmt.init_params(jax.random.PRNGKey(0), cfg)
        names, arrays = aot.param_leaves(params)
        leaves = jax.tree_util.tree_leaves(params)
        assert len(names) == len(leaves)
        for a, b in zip(arrays, leaves):
            np.testing.assert_array_equal(a, np.asarray(b))

    def test_quantized_variant_graph_lowers(self):
        cfg = model.NMT_CFG
        params = nmt.init_params(jax.random.PRNGKey(0), cfg)
        pq = quant.quantize_params(params)
        v = model.Variant("nmt14", "ptqd", "lut2d", "uint4")
        fn, specs = model.nmt_encode_fn(v)
        tables = tuple(
            jax.ShapeDtypeStruct(t.shape, jnp.int32)
            for t in model.variant_tables(v)
        )
        text = hlo_of(fn, pq, tables, *specs, keep_unused=True)
        assert text.startswith("HloModule")
        # the remaining divides in a PTQ-D model graph belong to the
        # activation fake-quant (x / scale), NOT the softmax unit; the
        # kernel-level census above proves the softmax path divider-free


class TestGoldenDump:
    def test_golden_softmax_covers_all_modes(self, tmp_path):
        aot.dump_softmax_golden(str(tmp_path))
        from compile import tensorio

        b = tensorio.read_bundle(str(tmp_path / "golden_softmax.ltb"))
        assert "x" in b and "exact" in b
        for prec in ("int16", "uint8", "uint4", "uint2"):
            for mode in ("rexp", "lut2d", "aggressive"):
                assert f"{mode}/{prec}" in b


@pytest.mark.parametrize("quantized", [False, True])
def test_pallas_lowering_of_model_variant_matches_jnp(quantized):
    """Tiny end-to-end: pallas-lowered model output == jnp model output."""
    cfg = nmt.NmtModelConfig(d_model=16, d_ff=32, heads=2, layers=1)
    params = nmt.init_params(jax.random.PRNGKey(0), cfg)
    if quantized:
        params = quant.quantize_params(params)
    src = jnp.asarray(
        np.random.default_rng(0).integers(4, 60, (2, cfg.max_src)).astype(np.int32)
    )
    try:
        common.USE_PALLAS_SOFTMAX = True
        mem_pallas = nmt.encode(params, src, cfg, "rexp", "uint8", quantized)
    finally:
        common.USE_PALLAS_SOFTMAX = False
    mem_jnp = nmt.encode(params, src, cfg, "rexp", "uint8", quantized)
    np.testing.assert_allclose(
        np.asarray(mem_pallas), np.asarray(mem_jnp), atol=2e-4
    )
