"""Unit tests for the LUT constructors (paper Eq.(4), (7), (8)-(10))."""

import math

import numpy as np
import pytest

from compile.kernels import luts

ALL_PRECS = list(luts.PRECISIONS)


class TestPrecision:
    def test_qmax(self):
        assert luts.precision("int16").qmax == 32767
        assert luts.precision("uint8").qmax == 255
        assert luts.precision("uint4").qmax == 15
        assert luts.precision("uint2").qmax == 3

    def test_xq_matches_eq4(self):
        # x_q = ceil(ln(2^w - 1)) — Eq.(4)
        for name in ALL_PRECS:
            p = luts.precision(name)
            assert p.x_q == math.ceil(math.log(p.qmax))

    def test_unknown_precision_raises(self):
        with pytest.raises(ValueError, match="unknown precision"):
            luts.precision("uint7")


class TestRecipE:
    @pytest.mark.parametrize("name", ALL_PRECS)
    def test_values_match_eq4(self, name):
        p = luts.precision(name)
        t = luts.lut_recip_e(p)
        for i, v in enumerate(t):
            assert v == math.floor(p.qmax / math.exp(i))

    def test_shapes_match_paper_tables(self):
        # Table 5: int16 -> 1x13, uint8 -> 1x8; Table 8: uint4 -> 1x5.
        assert luts.lut_recip_e(luts.precision("int16")).shape == (13,)
        assert luts.lut_recip_e(luts.precision("uint8")).shape == (8,)
        assert luts.lut_recip_e(luts.precision("uint4")).shape == (5,)

    @pytest.mark.parametrize("name", ALL_PRECS)
    def test_monotone_nonincreasing_ends_at_zero(self, name):
        t = luts.lut_recip_e(luts.precision(name))
        assert (np.diff(t) <= 0).all()
        assert t[0] == luts.precision(name).qmax
        assert t[-1] == 0  # out-of-range distances decay to zero weight


class TestAlpha:
    @pytest.mark.parametrize("name", ALL_PRECS)
    def test_values_match_eq7(self, name):
        p = luts.precision(name)
        t = luts.lut_alpha(p, 32)
        assert t[0] == p.qmax
        for j in range(1, 32):
            assert t[j] == math.floor(p.qmax / j)

    def test_custom_length(self):
        p = luts.precision("uint8")
        for n in (16, 256, 320, 512):
            assert luts.lut_alpha(p, n).shape == (n,)

    def test_zero_length_rejected(self):
        with pytest.raises(ValueError):
            luts.lut_alpha(luts.precision("uint8"), 0)

    @pytest.mark.parametrize("name", ALL_PRECS)
    def test_monotone(self, name):
        t = luts.lut_alpha(luts.precision(name), 64)
        assert (np.diff(t) <= 0).all()


class TestExpTable:
    @pytest.mark.parametrize("name", ALL_PRECS)
    def test_shape_matches_table8(self, name):
        p = luts.precision(name)
        expected = {"int16": 101, "uint8": 101, "uint4": 48, "uint2": 12}
        assert luts.lut_exp(p).shape == (expected[name],)

    def test_values(self):
        p = luts.precision("uint8")
        t = luts.lut_exp(p)
        assert t[0] == p.qmax
        for k in (1, 10, 50, 100):
            assert t[k] == round(p.qmax * math.exp(-k * 0.1))

    @pytest.mark.parametrize("name", ALL_PRECS)
    def test_monotone(self, name):
        assert (np.diff(luts.lut_exp(luts.precision(name))) <= 0).all()


class TestSigmaTable:
    @pytest.mark.parametrize("name", ALL_PRECS)
    def test_shape_matches_table8(self, name):
        p = luts.precision(name)
        expected = {"int16": 60, "uint8": 60, "uint4": 29, "uint2": 8}
        assert luts.lut_sigma(p).shape == (11, expected[name])

    def test_values_match_eq8(self):
        p = luts.precision("uint8")
        t = luts.lut_sigma(p)
        for i in range(11):
            for j in range(1, t.shape[1] + 1):
                want = min(p.qmax, math.floor(p.qmax * (i * 0.1) / j))
                assert t[i, j - 1] == want

    def test_row_zero_is_zero(self):
        # numerator e^x ~ 0 -> sigma = 0 regardless of the denominator
        for name in ALL_PRECS:
            assert (luts.lut_sigma(luts.precision(name))[0] == 0).all()

    def test_rows_monotone_nondecreasing(self):
        t = luts.lut_sigma(luts.precision("int16"))
        assert (np.diff(t, axis=0) >= 0).all()  # larger numerator, larger sigma

    def test_cols_monotone_nonincreasing(self):
        t = luts.lut_sigma(luts.precision("int16"))
        assert (np.diff(t, axis=1) <= 0).all()  # larger denominator, smaller sigma


class TestByteAccounting:
    def test_nlp_sizes_match_table8(self):
        # (precision, 2D-LUT total, REXP total) rows of Table 8. The uint2
        # REXP entry differs by one table entry (paper trims LUT_{1/e} to
        # 1x3 where Eq.(4)'s i=0..x_q+1 yields 1x4); we keep the formula.
        expect_2d = {"int16": 1522, "uint8": 761, "uint4": 367, "uint2": 100}
        expect_rexp = {"int16": 58, "uint8": 24, "uint4": 21, "uint2": 11}
        for name in ALL_PRECS:
            p = luts.precision(name)
            assert luts.lut2d_tables(p).total_bytes == expect_2d[name]
            assert luts.rexp_tables(p).total_bytes == expect_rexp[name]

    def test_detr_sizes_match_table5(self):
        # Table 5: LUT_{1/e} + LUT_alpha of 256/320/512 entries.
        for alpha_len, want16, want8 in ((256, 538, 264), (320, 666, 328), (512, 1050, 520)):
            assert luts.rexp_tables("int16", alpha_len).total_bytes == want16
            assert luts.rexp_tables("uint8", alpha_len).total_bytes == want8

    def test_paper_headline_700_bytes(self):
        # §Abstract: "about 700 Bytes" for the uint8 2D-LUT method.
        assert luts.lut2d_tables("uint8").total_bytes == 761  # ~700 B
