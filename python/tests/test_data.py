"""Tests of the synthetic dataset generators (the paper-dataset analogs)."""

import numpy as np
import pytest

from compile import data


class TestNmt:
    def test_determinism(self):
        cfg = data.NmtConfig(corpus_seed=14)
        a = data.nmt_batch(cfg, 8, seed=3)
        b = data.nmt_batch(cfg, 8, seed=3)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_corpora_differ(self):
        a = data.nmt_batch(data.NmtConfig(corpus_seed=14), 8, seed=3)
        b = data.nmt_batch(data.NmtConfig(corpus_seed=17), 8, seed=3)
        assert not np.array_equal(a[1], b[1])

    def test_target_is_reversed_remap(self):
        cfg = data.NmtConfig(corpus_seed=14)
        src, tgt = data.nmt_batch(cfg, 4, seed=0)
        for b in range(4):
            content = [t for t in src[b] if t >= data.FIRST_TOKEN]
            want = [cfg.permutation[t] for t in reversed(content)]
            got = list(tgt[b][1 : 1 + len(content)])
            assert got == want
            assert tgt[b][0] == data.BOS
            assert tgt[b][1 + len(content)] == data.EOS

    def test_permutation_is_bijection_on_content(self):
        cfg = data.NmtConfig(corpus_seed=14)
        p = cfg.permutation
        content = p[data.FIRST_TOKEN:]
        assert sorted(content) == list(range(data.FIRST_TOKEN, cfg.vocab))

    def test_shapes_and_padding(self):
        cfg = data.NmtConfig()
        src, tgt = data.nmt_batch(cfg, 16, seed=1)
        assert src.shape == (16, cfg.max_len)
        assert tgt.shape == (16, cfg.max_len + 1)
        assert (src >= 0).all() and (src < cfg.vocab).all()


class TestSentiment:
    def test_labels_balanced_enough(self):
        toks, labels = data.sentiment_batch(data.SentimentConfig(), 512, seed=5)
        rate = labels.mean()
        assert 0.25 < rate < 0.75, rate

    def test_negation_flips_polarity(self):
        # construct check: a "not" immediately before a positive token
        # counts negative in the generator's scoring (verified indirectly:
        # generator is deterministic, so fixed seeds keep coverage of both
        # label classes with the not-token present)
        cfg = data.SentimentConfig()
        toks, labels = data.sentiment_batch(cfg, 256, seed=9)
        has_not = (toks == cfg.not_token).any(axis=1)
        assert has_not.any()
        assert labels[has_not].std() > 0  # both classes appear under negation

    def test_token_range(self):
        cfg = data.SentimentConfig()
        toks, _ = data.sentiment_batch(cfg, 64, seed=2)
        assert toks.max() < cfg.vocab
        assert toks.min() >= 0


class TestMrpc:
    def test_imbalance_matches_config(self):
        cfg = data.MrpcConfig()
        _, labels = data.mrpc_batch(cfg, 2000, seed=11)
        rate = labels.mean()
        assert abs(rate - cfg.pos_rate) < 0.05, rate

    def test_paraphrase_map_is_involution(self):
        cfg = data.MrpcConfig()
        m = cfg.paraphrase_map
        content = np.arange(data.FIRST_TOKEN, cfg.vocab)
        np.testing.assert_array_equal(m[m[content]], content)

    def test_row_structure(self):
        cfg = data.MrpcConfig()
        toks, _ = data.mrpc_batch(cfg, 8, seed=0)
        for row in toks:
            assert row[0] == data.BOS
            assert data.SEP in row
            assert data.EOS in row


class TestScenes:
    def test_image_range_and_gt(self):
        cfg = data.SceneConfig()
        imgs, gts = data.scene_batch(cfg, 8, seed=4)
        assert imgs.shape == (8, 32, 32, 3)
        assert imgs.min() >= 0.0 and imgs.max() <= 1.0
        for g in gts:
            assert 1 <= len(g) <= cfg.max_objects
            assert (g[:, 0] < cfg.num_classes).all()
            assert (g[:, 1:] >= 0).all() and (g[:, 1:] <= 1).all()

    def test_objects_are_visible(self):
        # rendered rectangles must move pixel stats away from background
        cfg = data.SceneConfig()
        imgs, gts = data.scene_batch(cfg, 4, seed=7)
        for b, g in enumerate(gts):
            cls, cx, cy, w, h = g[0]
            x0 = int((cx - w / 2) * 32)
            y0 = int((cy - h / 2) * 32)
            patch = imgs[b, y0 : y0 + max(int(h * 32), 1), x0 : x0 + max(int(w * 32), 1)]
            pal = cfg.palette[int(cls)]
            assert np.abs(patch.mean(axis=(0, 1)) - pal).mean() < 0.25


class TestRoundTripWithRust:
    """Tensorio self-consistency (the rust side re-checks the same file)."""

    def test_bundle_roundtrip(self, tmp_path):
        from compile import tensorio

        path = str(tmp_path / "t.ltb")
        t = {
            "a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b/c": np.array([-1, 5], dtype=np.int32),
        }
        tensorio.write_bundle(path, t)
        back = tensorio.read_bundle(path)
        np.testing.assert_array_equal(back["a"], t["a"])
        np.testing.assert_array_equal(back["b/c"], t["b/c"])

    def test_rejects_unknown_dtype(self, tmp_path):
        from compile import tensorio

        with pytest.raises(TypeError):
            tensorio.write_bundle(
                str(tmp_path / "bad.ltb"), {"x": np.array(["a"], dtype=object)}
            )
