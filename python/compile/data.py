"""Synthetic task generators standing in for the paper's datasets.

Substitution rationale (DESIGN.md §2): the paper measures how LUT softmax
approximation inside attention degrades task metrics. What matters is (a)
the graph shape (softmax deep inside encoder/decoder stacks), (b) the
metric (BLEU / accuracy / F1 / AP), and (c) the distribution of attention
score rows (which sets sum(e^x), Fig. 4). These generators produce
learnable attention-dependent tasks with the same metric structure:

* NMT  (WMT14/WMT17 analog) — token-remap + reversal transduction; two
  "corpora" differ by seed, vocabulary permutation and length profile.
* SST-2 analog — keyword-polarity sentiment with negation tokens.
* MRPC analog — sentence-pair equivalence, imbalanced 68/32 like MRPC.
* COCO analog — synthetic scenes of colored rectangles for set-prediction
  detection (DETR-lite); the +DC5 analog doubles token resolution.

Everything is deterministic given a seed. Token conventions:
PAD=0, BOS=1, EOS=2, SEP=3, content tokens start at 4.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PAD, BOS, EOS, SEP = 0, 1, 2, 3
FIRST_TOKEN = 4

__all__ = [
    "PAD",
    "BOS",
    "EOS",
    "SEP",
    "FIRST_TOKEN",
    "NmtConfig",
    "nmt_batch",
    "nmt_reference",
    "SentimentConfig",
    "sentiment_batch",
    "MrpcConfig",
    "mrpc_batch",
    "SceneConfig",
    "scene_batch",
]


# ---------------------------------------------------------------------------
# NMT: token-remap + reversal "translation"


@dataclass(frozen=True)
class NmtConfig:
    vocab: int = 64
    max_len: int = 20          # source length incl. EOS
    min_content: int = 4
    max_content: int = 14
    corpus_seed: int = 14      # 14 -> "WMT14" analog, 17 -> "WMT17" analog

    @property
    def permutation(self) -> np.ndarray:
        """Fixed vocabulary remap of this corpus (identity on specials)."""
        r = np.random.default_rng(1000 + self.corpus_seed)
        content = np.arange(FIRST_TOKEN, self.vocab)
        perm = r.permutation(content)
        table = np.arange(self.vocab)
        table[FIRST_TOKEN:] = perm
        return table


def nmt_reference(cfg: NmtConfig, src_row: np.ndarray) -> np.ndarray:
    """Ground-truth translation of one padded source row: reverse the
    content tokens and remap each through the corpus permutation."""
    content = [t for t in src_row if t >= FIRST_TOKEN]
    out = [int(cfg.permutation[t]) for t in reversed(content)]
    return np.array(out, dtype=np.int32)


def nmt_batch(
    cfg: NmtConfig, batch: int, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (src, tgt) of shapes (batch, max_len), (batch, max_len+1).

    src:  content tokens then EOS, PAD-right.
    tgt:  BOS + translated tokens + EOS, PAD-right (teacher-forcing ready).
    """
    r = np.random.default_rng(seed * 7919 + cfg.corpus_seed)
    src = np.full((batch, cfg.max_len), PAD, np.int32)
    tgt = np.full((batch, cfg.max_len + 1), PAD, np.int32)
    for b in range(batch):
        n = int(r.integers(cfg.min_content, cfg.max_content + 1))
        toks = r.integers(FIRST_TOKEN, cfg.vocab, n).astype(np.int32)
        src[b, :n] = toks
        src[b, n] = EOS
        ref = nmt_reference(cfg, src[b])
        tgt[b, 0] = BOS
        tgt[b, 1 : 1 + n] = ref
        tgt[b, 1 + n] = EOS
    return src, tgt


# ---------------------------------------------------------------------------
# SST-2 analog: keyword sentiment with negation


@dataclass(frozen=True)
class SentimentConfig:
    vocab: int = 64
    max_len: int = 24
    min_content: int = 6
    max_content: int = 20
    n_polar: int = 8           # tokens [4, 4+n) positive, [4+n, 4+2n) negative
    seed_base: int = 20

    @property
    def pos_range(self) -> tuple[int, int]:
        return (FIRST_TOKEN, FIRST_TOKEN + self.n_polar)

    @property
    def neg_range(self) -> tuple[int, int]:
        return (FIRST_TOKEN + self.n_polar, FIRST_TOKEN + 2 * self.n_polar)

    @property
    def not_token(self) -> int:
        return FIRST_TOKEN + 2 * self.n_polar  # the negation word


def sentiment_batch(
    cfg: SentimentConfig, batch: int, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (tokens, labels). Label = sign of negation-adjusted polarity.

    A `not_token` immediately before a polar token flips its polarity —
    classification requires attending to local context, not just a bag of
    words.
    """
    r = np.random.default_rng(seed * 104729 + cfg.seed_base)
    toks = np.full((batch, cfg.max_len), PAD, np.int32)
    labels = np.zeros((batch,), np.int32)
    p0, p1 = cfg.pos_range
    n0, n1 = cfg.neg_range
    neutral0 = cfg.not_token + 1
    for b in range(batch):
        n = int(r.integers(cfg.min_content, cfg.max_content + 1))
        row = [BOS]
        score = 0
        while len(row) < n:
            kind = r.random()
            negate = r.random() < 0.25
            if negate and len(row) < n - 1:
                row.append(cfg.not_token)
            if kind < 0.35:
                row.append(int(r.integers(p0, p1)))
                score += -1 if negate else 1
            elif kind < 0.7:
                row.append(int(r.integers(n0, n1)))
                score += 1 if negate else -1
            else:
                if negate:
                    score = score  # dangling "not" before a neutral word
                row.append(int(r.integers(neutral0, cfg.vocab)))
        if score == 0:
            # force a decisive token to keep labels well-defined
            row[-1] = int(r.integers(p0, p1))
            score = 1
        toks[b, : len(row)] = row
        labels[b] = 1 if score > 0 else 0
    return toks, labels


# ---------------------------------------------------------------------------
# MRPC analog: sentence-pair semantic equivalence (imbalanced 68/32)


@dataclass(frozen=True)
class MrpcConfig:
    vocab: int = 64
    sent_len: int = 9          # content tokens per sentence
    max_len: int = 24          # BOS s1 SEP s2 EOS padded
    pos_rate: float = 0.68     # MRPC's class imbalance
    seed_base: int = 30

    @property
    def paraphrase_map(self) -> np.ndarray:
        """Token-level "paraphrase" synonym map (an involution on content)."""
        r = np.random.default_rng(2000 + self.seed_base)
        content = np.arange(FIRST_TOKEN, self.vocab)
        shuffled = r.permutation(content)
        table = np.arange(self.vocab)
        half = len(shuffled) // 2
        a, b = shuffled[:half], shuffled[half : 2 * half]
        table[a], table[b] = b, a  # swap pairs -> involution
        return table


def mrpc_batch(
    cfg: MrpcConfig, batch: int, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (tokens, labels). Positive pairs: s2 is the synonym-mapped s1
    (possibly with one token swap); negatives: independent s2 or a corrupted
    paraphrase with several replaced tokens."""
    r = np.random.default_rng(seed * 15485863 + cfg.seed_base)
    toks = np.full((batch, cfg.max_len), PAD, np.int32)
    labels = np.zeros((batch,), np.int32)
    pmap = cfg.paraphrase_map
    for b in range(batch):
        s1 = r.integers(FIRST_TOKEN, cfg.vocab, cfg.sent_len).astype(np.int32)
        positive = r.random() < cfg.pos_rate
        if positive:
            s2 = pmap[s1].astype(np.int32)
            if r.random() < 0.5:  # harmless local swap keeps it non-trivial
                i = int(r.integers(0, cfg.sent_len - 1))
                s2[[i, i + 1]] = s2[[i + 1, i]]
        else:
            s2 = pmap[s1].astype(np.int32)
            k = int(r.integers(3, cfg.sent_len))  # corrupt >= 3 tokens
            idx = r.choice(cfg.sent_len, k, replace=False)
            s2[idx] = r.integers(FIRST_TOKEN, cfg.vocab, k)
        row = np.concatenate(([BOS], s1, [SEP], s2, [EOS])).astype(np.int32)
        toks[b, : len(row)] = row
        labels[b] = int(positive)
    return toks, labels


# ---------------------------------------------------------------------------
# COCO analog: synthetic rectangle scenes for DETR-lite


@dataclass(frozen=True)
class SceneConfig:
    image_size: int = 32
    channels: int = 3
    max_objects: int = 4
    num_classes: int = 3
    noise: float = 0.08
    seed_base: int = 40

    #: class -> RGB fill color (distinct, learnable)
    @property
    def palette(self) -> np.ndarray:
        return np.array(
            [[0.9, 0.15, 0.1], [0.1, 0.85, 0.2], [0.15, 0.2, 0.95]], np.float32
        )


def scene_batch(
    cfg: SceneConfig, batch: int, seed: int
) -> tuple[np.ndarray, list[np.ndarray]]:
    """Returns (images, gts).

    images: (batch, H, W, C) float32 in [0, 1].
    gts:    per image, array (n_obj, 5) of [class, cx, cy, w, h] normalized.
    """
    r = np.random.default_rng(seed * 32452843 + cfg.seed_base)
    H = W = cfg.image_size
    imgs = r.normal(0.5, cfg.noise, (batch, H, W, cfg.channels)).astype(np.float32)
    gts: list[np.ndarray] = []
    pal = cfg.palette
    for b in range(batch):
        n = int(r.integers(1, cfg.max_objects + 1))
        rows = np.zeros((n, 5), np.float32)
        for o in range(n):
            cls = int(r.integers(0, cfg.num_classes))
            w = int(r.integers(max(3, H // 8), H // 2))
            h = int(r.integers(max(3, H // 8), H // 2))
            x0 = int(r.integers(0, W - w))
            y0 = int(r.integers(0, H - h))
            imgs[b, y0 : y0 + h, x0 : x0 + w] = pal[cls] + r.normal(
                0, cfg.noise / 2, (h, w, cfg.channels)
            )
            rows[o] = [
                cls,
                (x0 + w / 2) / W,
                (y0 + h / 2) / H,
                w / W,
                h / H,
            ]
        gts.append(rows)
    np.clip(imgs, 0.0, 1.0, out=imgs)
    return imgs, gts
