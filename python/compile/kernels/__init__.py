"""L1: Pallas kernels + LUT builders + pure-jnp oracles.

Public surface:
  luts            — LUT constructors (Eq.(4), (7), (8)-(10)) and Precision
  ref             — pure-jnp oracles / shared integer pipelines
  softmax_exact   — exact-softmax Pallas kernel (fp baseline)
  softmax_rexp    — REXP Pallas kernel (Algorithm 1)
  softmax_lut2d   — 2D-LUT Pallas kernel (Algorithm 2)
  attention       — fused SDPA with pluggable softmax approximation
"""

from . import attention, luts, ref, softmax_exact, softmax_lut2d, softmax_rexp

__all__ = [
    "attention",
    "luts",
    "ref",
    "softmax_exact",
    "softmax_lut2d",
    "softmax_rexp",
]
