"""Pallas kernel: 2D-LUT softmax approximation (paper §4.2, Algorithm 2).

The quantized path contains **no divide and no multiply**: numerator and
denominator MSBs index a precomputed (11 x cols) quotient table (Fig. 1).
On TPU the whole table (<= 1.5 KB) is VMEM-resident; index math is a cast,
a shift and two clamps on the VPU.

Kernel body delegates to :func:`ref.lut2d_pipeline` — bit-identical to the
oracle by construction. Tables are operands for runtime reconfigurability.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import luts, ref
from .softmax_exact import DEFAULT_BLOCK_ROWS, _pad_rows

__all__ = ["softmax_lut2d_pallas", "lut2d_with_tables", "make_lut2d_callable"]


def _lut2d_kernel(x_ref, exp_ref, row_ref, sigma_ref, o_ref, *, w: int, qmax: int):
    x = x_ref[...]
    exp_t = exp_ref[...]
    row_t = row_ref[...]
    sigma_t = sigma_ref[...]
    o_ref[...] = ref.lut2d_pipeline(x, exp_t, row_t, sigma_t, w, qmax)


def _call(x2d, exp_t, row_t, sigma_t, w, qmax, bm):
    n = x2d.shape[1]
    kern = functools.partial(_lut2d_kernel, w=w, qmax=qmax)
    return pl.pallas_call(
        kern,
        grid=(x2d.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec(exp_t.shape, lambda i: (0,)),
            pl.BlockSpec(row_t.shape, lambda i: (0,)),
            pl.BlockSpec(sigma_t.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.float32),
        interpret=True,
    )(x2d, exp_t, row_t, sigma_t)


@functools.partial(jax.jit, static_argnames=("prec", "sigma_cols", "block_rows"))
def softmax_lut2d_pallas(
    x: jnp.ndarray,
    prec: str = "uint8",
    sigma_cols: int | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jnp.ndarray:
    """2D-LUT softmax over the last axis of `x` (builds tables internally)."""
    p = luts.precision(prec)
    t = luts.lut2d_tables(p, sigma_cols)
    exp_t = jnp.asarray(t.exp, dtype=jnp.int32)
    row_t = jnp.asarray(t.row, dtype=jnp.int32)
    sigma_t = jnp.asarray(t.sigma, dtype=jnp.int32)

    shape = x.shape
    x2d = x.reshape(-1, shape[-1]).astype(jnp.float32)
    bm = min(block_rows, x2d.shape[0])
    x2d, rows = _pad_rows(x2d, bm)
    out = _call(x2d, exp_t, row_t, sigma_t, p.w, p.qmax, bm)
    return out[:rows].reshape(shape)


def lut2d_with_tables(
    x: jnp.ndarray,
    exp_t: jnp.ndarray,
    row_t: jnp.ndarray,
    sigma_t: jnp.ndarray,
    prec: str = "uint8",
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jnp.ndarray:
    """2D-LUT softmax with caller-supplied (traced) tables — see
    rexp_with_tables for why model graphs must use operand tables."""
    p = luts.precision(prec)
    shape = x.shape
    x2d = x.reshape(-1, shape[-1]).astype(jnp.float32)
    bm = min(block_rows, x2d.shape[0])
    x2d, rows = _pad_rows(x2d, bm)
    out = _call(x2d, exp_t, row_t, sigma_t, p.w, p.qmax, bm)
    return out[:rows].reshape(shape)


def make_lut2d_callable(rows: int, n: int, prec: str = "uint8"):
    """AOT entry point mirroring :func:`make_rexp_callable`."""
    p = luts.precision(prec)
    t = luts.lut2d_tables(p)
    bm = min(DEFAULT_BLOCK_ROWS, rows)

    def fn(x, exp_t, row_t, sigma_t):
        x2d, r = _pad_rows(x.reshape(-1, n).astype(jnp.float32), bm)
        out = _call(x2d, exp_t, row_t, sigma_t, p.w, p.qmax, bm)
        return (out[:r].reshape(rows, n),)

    specs = (
        jax.ShapeDtypeStruct((rows, n), jnp.float32),
        jax.ShapeDtypeStruct(t.exp.shape, jnp.int32),
        jax.ShapeDtypeStruct(t.row.shape, jnp.int32),
        jax.ShapeDtypeStruct(t.sigma.shape, jnp.int32),
    )
    return fn, specs
