"""Pure-jnp oracles for every softmax variant — the pytest ground truth.

Each function operates row-wise over the last axis and returns float32
probabilities. The integer pipelines here define the *bit-exact* semantics
that the Pallas kernels (softmax_rexp.py / softmax_lut2d.py) and the rust
software models (rust/src/softmax/) must reproduce entry for entry.

Shared integer contract (see luts.py for the table contents):

REXP (Algorithm 1 of the paper):
  1. ``d = max(x) - x``                      (float, >= 0)
  2. ``idx = clamp(int(d), 0, len(recip)-1)``  — truncation == MSB indexing
  3. ``e_int = LUT_recip[idx]``              (0..qmax)
  4. ``s = sum(e_int)``                      (int32)
  5. ``j = s >> w``                          — integer part of sum(sigma*)
  6. ``a_int = 0 if j >= len(alpha) else LUT_alpha[j]``
  7. ``sig_int = (e_int * a_int) >> w``
  8. ``sigma = sig_int / qmax``

2D-LUT (Algorithm 2):
  1. ``d = max(x) - x``
  2. ``k = clamp(int(d / 0.1), 0, len(exp)-1)``
  3. ``e_int = LUT_exp[k]``
  4. ``s = sum(e_int)``
  5. ``row = clamp((e_int*10 + qmax//2) // qmax, 0, 10)`` — rounding divide
  6. ``col = clamp(s >> w, 1, cols)``        (saturating both ends)
  7. ``sig_int = LUT_sigma[row, col-1]``
  8. ``sigma = sig_int / qmax``
"""

from __future__ import annotations

import jax.numpy as jnp

from . import luts

__all__ = [
    "softmax_exact",
    "softmax_rexp",
    "softmax_lut2d",
    "softmax_priorart_eq2",
    "softmax_priorart_eq2plus",
    "softmax_aggressive",
    "SOFTMAX_MODES",
    "softmax_by_mode",
]


def softmax_exact(x: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable exact softmax (Eq.(2) with max subtraction)."""
    x = x.astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _rexp_e_int(x: jnp.ndarray, recip: jnp.ndarray) -> jnp.ndarray:
    """Steps 1-3 of the REXP pipeline: integer reciprocal-exponentiation."""
    x = x.astype(jnp.float32)
    d = jnp.max(x, axis=-1, keepdims=True) - x
    idx = jnp.clip(d.astype(jnp.int32), 0, recip.shape[0] - 1)
    return jnp.take(recip, idx)


def rexp_pipeline(
    x: jnp.ndarray, recip: jnp.ndarray, alpha: jnp.ndarray, w: int, qmax: int
) -> jnp.ndarray:
    """Table-parameterized REXP integer pipeline (shared with the Pallas
    kernel body so the two are bit-identical by construction)."""
    e_int = _rexp_e_int(x, recip)
    s = jnp.sum(e_int, axis=-1, keepdims=True)
    j = s >> w
    a_int = jnp.where(
        j >= alpha.shape[0],
        0,
        jnp.take(alpha, jnp.clip(j, 0, alpha.shape[0] - 1)),
    )
    sig_int = (e_int * a_int) >> w
    # multiply-by-reciprocal: the lowered HLO of the LUT paths must contain
    # no divide op (the paper's "no divider" claim; asserted by test_aot's
    # HLO op census)
    return sig_int.astype(jnp.float32) * (1.0 / qmax)


def lut2d_pipeline(
    x: jnp.ndarray,
    exp_t: jnp.ndarray,
    row_t: jnp.ndarray,
    sigma_t: jnp.ndarray,
    w: int,
    qmax: int,
) -> jnp.ndarray:
    """Table-parameterized 2D-LUT integer pipeline (shared with the Pallas
    kernel body). The sigma row index is a pure LUT read (`row_t`, see
    luts.lut_row) so the lowered datapath contains NO divide — asserted by
    test_aot's HLO census."""
    cols = sigma_t.shape[1]
    x = x.astype(jnp.float32)
    d = jnp.max(x, axis=-1, keepdims=True) - x
    k = jnp.clip((d * (1.0 / luts.EXP_STEP)).astype(jnp.int32), 0, exp_t.shape[0] - 1)
    e_int = jnp.take(exp_t, k)
    s = jnp.sum(e_int, axis=-1, keepdims=True)
    row = jnp.take(row_t, k)
    col = jnp.clip(s >> w, 1, cols)
    sig_int = sigma_t[row, jnp.broadcast_to(col, row.shape) - 1]
    return sig_int.astype(jnp.float32) * (1.0 / qmax)


def aggressive_pipeline(
    x: jnp.ndarray, recip: jnp.ndarray, qmax: int
) -> jnp.ndarray:
    """Table-parameterized aggressive (unnormalized) pipeline of [29]."""
    return _rexp_e_int(x, recip).astype(jnp.float32) * (1.0 / qmax)


def softmax_rexp(
    x: jnp.ndarray,
    prec: luts.Precision | str = "uint8",
    alpha_len: int | None = None,
) -> jnp.ndarray:
    """REXP approximation (paper §4.1, Algorithm 1), float-in/float-out."""
    p = luts.precision(prec) if isinstance(prec, str) else prec
    t = luts.rexp_tables(p, alpha_len)
    recip = jnp.asarray(t.recip_e, dtype=jnp.int32)
    alpha = jnp.asarray(t.alpha, dtype=jnp.int32)
    return rexp_pipeline(x, recip, alpha, p.w, p.qmax)


def softmax_lut2d(
    x: jnp.ndarray,
    prec: luts.Precision | str = "uint8",
    sigma_cols: int | None = None,
) -> jnp.ndarray:
    """2D-LUT approximation (paper §4.2, Algorithm 2), float-in/float-out."""
    p = luts.precision(prec) if isinstance(prec, str) else prec
    t = luts.lut2d_tables(p, sigma_cols)
    exp_t = jnp.asarray(t.exp, dtype=jnp.int32)
    row_t = jnp.asarray(t.row, dtype=jnp.int32)
    sigma_t = jnp.asarray(t.sigma, dtype=jnp.int32)
    return lut2d_pipeline(x, exp_t, row_t, sigma_t, p.w, p.qmax)


def _round_to_precision(y: jnp.ndarray, qmax: int) -> jnp.ndarray:
    """The paper's HW-mimic quantization: ``round(y * prec) / prec``."""
    return jnp.round(y * qmax) / qmax


def softmax_priorart_eq2(
    x: jnp.ndarray, prec: luts.Precision | str = "uint8"
) -> jnp.ndarray:
    """Prior art Eq.(11) (= Eq.(2) of [32]): exp(x - ln(sum e^x)), no
    max-normalization, outer exp quantized to `prec` bits (Appendix A.1.2)."""
    p = luts.precision(prec) if isinstance(prec, str) else prec
    x = x.astype(jnp.float32)
    y = jnp.exp(x - jnp.log(jnp.sum(jnp.exp(x), axis=-1, keepdims=True)))
    return _round_to_precision(y, p.qmax)


def softmax_priorart_eq2plus(
    x: jnp.ndarray, prec: luts.Precision | str = "uint8"
) -> jnp.ndarray:
    """Prior art Eq.(12) (= Eq.(2)+ of [32]): Eq.(11) with max-normalization."""
    p = luts.precision(prec) if isinstance(prec, str) else prec
    x = x.astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    xm = x - m
    y = jnp.exp(xm - jnp.log(jnp.sum(jnp.exp(xm), axis=-1, keepdims=True)))
    return _round_to_precision(y, p.qmax)


def softmax_aggressive(
    x: jnp.ndarray, prec: luts.Precision | str = "uint8"
) -> jnp.ndarray:
    """Aggressive reciprocal-exponentiation of [29] (Eq.(3)): the raw
    UNNORMALIZED sigma* read from LUT_{1/e}. Collapses attention models
    (paper Fig. 5) because rows no longer sum to ~1."""
    p = luts.precision(prec) if isinstance(prec, str) else prec
    recip = jnp.asarray(luts.lut_recip_e(p), dtype=jnp.int32)
    return aggressive_pipeline(x, recip, p.qmax)


#: mode name -> (fn(x, prec) -> probs). Shared vocabulary across L1/L2/L3.
SOFTMAX_MODES = (
    "exact",
    "rexp",
    "lut2d",
    "priorart_eq2",
    "priorart_eq2plus",
    "aggressive",
)


def softmax_by_mode(
    x: jnp.ndarray, mode: str, prec: luts.Precision | str = "uint8", **kw
) -> jnp.ndarray:
    """Dispatch a softmax variant by mode name (the L2 models' entry point).

    `prec` accepts spec strings like ``"uint8:a512"`` (see luts.parse_spec)
    to override the REXP alpha-table length per the paper's DETR cases.
    """
    if isinstance(prec, str):
        p, alpha_len = luts.parse_spec(prec)
    else:
        p, alpha_len = prec, None
    if mode == "exact":
        return softmax_exact(x)
    if mode == "rexp":
        kw.setdefault("alpha_len", alpha_len)
        return softmax_rexp(x, p, **kw)
    if mode == "lut2d":
        return softmax_lut2d(x, p, **kw)
    if mode == "priorart_eq2":
        return softmax_priorart_eq2(x, prec)
    if mode == "priorart_eq2plus":
        return softmax_priorart_eq2plus(x, prec)
    if mode == "aggressive":
        return softmax_aggressive(x, prec)
    raise ValueError(f"unknown softmax mode {mode!r}; expected {SOFTMAX_MODES}")
