"""Pallas kernel: fused scaled-dot-product attention with pluggable softmax.

One grid program per (batch * heads) slice: scores = q k^T / sqrt(d_h) are
formed in VMEM, the selected softmax variant (exact / REXP / 2D-LUT /
prior arts) is applied row-wise, and the probs @ v product is written back.
The approximation bodies are the same traced pipelines as ref.py, so fused
attention is bit-consistent with the standalone kernels.

TPU mapping: q/k/v tiles stream HBM->VMEM per head; the two matmuls hit the
MXU (bf16-able), the LUT softmax stays on the VPU with the tables resident.
Fusing removes the HBM round-trip of the (L, L) probability matrix — the
paper's motivating data-movement cost (§2) — which is what
`exp -- perf` quantifies as bytes moved per attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import luts, ref

__all__ = ["attention_pallas", "attention_ref", "make_attention_callable"]


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mode: str = "exact",
    prec: str = "uint8",
) -> jnp.ndarray:
    """Pure-jnp oracle: softmax(q k^T / sqrt(d_h)) v with the chosen mode.

    Shapes: q (..., L, d), k (..., S, d), v (..., S, d) -> (..., L, d).
    """
    d = q.shape[-1]
    scores = jnp.einsum("...ld,...sd->...ls", q, k) / jnp.sqrt(
        jnp.asarray(d, jnp.float32)
    )
    probs = ref.softmax_by_mode(scores, mode, prec)
    return jnp.einsum("...ls,...sd->...ld", probs, v)


def _mode_tables(mode: str, prec: str) -> list[jnp.ndarray]:
    """Runtime table operands required by a softmax mode.

    Tables are kernel *operands* (not baked constants) so the same compiled
    attention executable accepts reconfigured LUTs from L3.
    """
    p = luts.precision(prec)
    if mode == "rexp":
        t = luts.rexp_tables(p)
        return [
            jnp.asarray(t.recip_e, jnp.int32),
            jnp.asarray(t.alpha, jnp.int32),
        ]
    if mode == "lut2d":
        t = luts.lut2d_tables(p)
        return [
            jnp.asarray(t.exp, jnp.int32),
            jnp.asarray(t.row, jnp.int32),
            jnp.asarray(t.sigma, jnp.int32),
        ]
    if mode == "aggressive":
        return [jnp.asarray(luts.lut_recip_e(p), jnp.int32)]
    return []


def _apply_mode(scores, tables, mode: str, prec: str):
    p = luts.precision(prec)
    if mode == "exact":
        return ref.softmax_exact(scores)
    if mode == "rexp":
        return ref.rexp_pipeline(scores, tables[0], tables[1], p.w, p.qmax)
    if mode == "lut2d":
        return ref.lut2d_pipeline(scores, tables[0], tables[1], tables[2], p.w, p.qmax)
    if mode == "aggressive":
        return ref.aggressive_pipeline(scores, tables[0], p.qmax)
    if mode == "priorart_eq2":
        return ref.softmax_priorart_eq2(scores, p)
    if mode == "priorart_eq2plus":
        return ref.softmax_priorart_eq2plus(scores, p)
    raise ValueError(f"unknown mode {mode!r}")


def _attn_kernel(*refs, mode: str, prec: str):
    q_ref, k_ref, v_ref = refs[:3]
    o_ref = refs[-1]
    tables = [t[...] for t in refs[3:-1]]
    q = q_ref[...][0].astype(jnp.float32)  # (L, d)
    k = k_ref[...][0].astype(jnp.float32)  # (S, d)
    v = v_ref[...][0].astype(jnp.float32)  # (S, d)
    d = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    probs = _apply_mode(scores, tables, mode, prec)
    o_ref[...] = (probs @ v)[None]


@functools.partial(jax.jit, static_argnames=("mode", "prec"))
def attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mode: str = "exact",
    prec: str = "uint8",
    tables: tuple = (),
) -> jnp.ndarray:
    """Fused attention; leading axes are flattened into the grid.

    `tables` overrides the built-in LUT contents with traced operands (the
    AOT path — see rexp_with_tables for why).
    """
    if mode not in ref.SOFTMAX_MODES:
        raise ValueError(f"unknown mode {mode!r}")
    tables = list(tables) if tables else _mode_tables(mode, prec)

    lead = q.shape[:-2]
    L, dh = q.shape[-2:]
    S = k.shape[-2]
    q3 = q.reshape(-1, L, dh).astype(jnp.float32)
    k3 = k.reshape(-1, S, dh).astype(jnp.float32)
    v3 = v.reshape(-1, S, dh).astype(jnp.float32)
    heads = q3.shape[0]

    kern = functools.partial(_attn_kernel, mode=mode, prec=prec)
    table_specs = [
        pl.BlockSpec(t.shape, (lambda i: (0,)) if t.ndim == 1 else (lambda i: (0, 0)))
        for t in tables
    ]
    out = pl.pallas_call(
        kern,
        grid=(heads,),
        in_specs=[
            pl.BlockSpec((1, L, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S, dh), lambda i: (i, 0, 0)),
            *table_specs,
        ],
        out_specs=pl.BlockSpec((1, L, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, L, dh), jnp.float32),
        interpret=True,
    )(q3, k3, v3, *tables)
    return out.reshape(*lead, L, dh)


def make_attention_callable(
    heads: int, L: int, dh: int, mode: str = "exact", prec: str = "uint8"
):
    """AOT entry point: fixed-shape fused attention for aot.py to lower.
    LUT tables are trailing runtime operands (reconfigurable, and baked s32
    constants miscompile under xla_extension 0.5.1)."""

    def fn(q, k, v, *tables):
        return (attention_pallas(q, k, v, mode=mode, prec=prec, tables=tables),)

    spec = jax.ShapeDtypeStruct((heads, L, dh), jnp.float32)
    table_specs = tuple(
        jax.ShapeDtypeStruct(t.shape, jnp.int32) for t in _mode_tables(mode, prec)
    )
    return fn, (spec, spec, spec, *table_specs)
