"""Pallas kernel: REXP softmax approximation (paper §4.1, Algorithm 1).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's datapath
is LUT-ROM + adder-tree + one integer multiplier, no divider. On TPU the
two tables (<= ~1 KB) are kernel-resident constants pinned in VMEM/SMEM for
the whole grid; the "MSB wiring" index of Fig. 1 becomes an int cast + clamp
on the VPU; and the row tiles stream HBM->VMEM via BlockSpec. No MXU is
involved — the unit is matmul-free, mirroring the paper's claim.

The kernel body delegates to :func:`ref.rexp_pipeline`, so the executed
integer semantics are bit-identical to the pure-jnp oracle by construction;
the pytest suite additionally asserts it on random tensors.

Tables are passed as *operands*, not baked constants, to preserve the
paper's "LUT can be reconfigured on demand" property: the same compiled
executable serves any alpha-table length of the same shape (L3 swaps tables
at dispatch time).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import luts, ref
from .softmax_exact import DEFAULT_BLOCK_ROWS, _pad_rows

__all__ = ["softmax_rexp_pallas", "rexp_with_tables", "make_rexp_callable"]


def _rexp_kernel(x_ref, recip_ref, alpha_ref, o_ref, *, w: int, qmax: int):
    x = x_ref[...]
    recip = recip_ref[...]
    alpha = alpha_ref[...]
    o_ref[...] = ref.rexp_pipeline(x, recip, alpha, w, qmax)


def _call(x2d, recip, alpha, w, qmax, bm):
    n = x2d.shape[1]
    kern = functools.partial(_rexp_kernel, w=w, qmax=qmax)
    return pl.pallas_call(
        kern,
        grid=(x2d.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec(recip.shape, lambda i: (0,)),
            pl.BlockSpec(alpha.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.float32),
        interpret=True,
    )(x2d, recip, alpha)


@functools.partial(jax.jit, static_argnames=("prec", "alpha_len", "block_rows"))
def softmax_rexp_pallas(
    x: jnp.ndarray,
    prec: str = "uint8",
    alpha_len: int | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jnp.ndarray:
    """REXP softmax over the last axis of `x` (builds tables internally)."""
    p = luts.precision(prec)
    t = luts.rexp_tables(p, alpha_len)
    recip = jnp.asarray(t.recip_e, dtype=jnp.int32)
    alpha = jnp.asarray(t.alpha, dtype=jnp.int32)

    shape = x.shape
    x2d = x.reshape(-1, shape[-1]).astype(jnp.float32)
    bm = min(block_rows, x2d.shape[0])
    x2d, rows = _pad_rows(x2d, bm)
    out = _call(x2d, recip, alpha, p.w, p.qmax, bm)
    return out[:rows].reshape(shape)


def rexp_with_tables(
    x: jnp.ndarray,
    recip: jnp.ndarray,
    alpha: jnp.ndarray,
    prec: str = "uint8",
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jnp.ndarray:
    """REXP softmax with caller-supplied (traced) tables — used by the L2
    model graphs so tables lower to runtime OPERANDS. (Baked s32 constants
    miscompile under xla_extension 0.5.1; operands round-trip bit-exactly,
    and operand tables are the paper's reconfigurability story anyway.)"""
    p = luts.precision(prec)
    shape = x.shape
    x2d = x.reshape(-1, shape[-1]).astype(jnp.float32)
    bm = min(block_rows, x2d.shape[0])
    x2d, rows = _pad_rows(x2d, bm)
    out = _call(x2d, recip, alpha, p.w, p.qmax, bm)
    return out[:rows].reshape(shape)


def make_rexp_callable(rows: int, n: int, prec: str = "uint8"):
    """AOT entry point: a (x, recip, alpha) -> sigma function of fixed shape
    for `aot.py` to lower; tables stay runtime operands so L3 can swap them."""
    p = luts.precision(prec)
    t = luts.rexp_tables(p)
    bm = min(DEFAULT_BLOCK_ROWS, rows)

    def fn(x, recip, alpha):
        x2d, r = _pad_rows(x.reshape(-1, n).astype(jnp.float32), bm)
        out = _call(x2d, recip, alpha, p.w, p.qmax, bm)
        return (out[:r].reshape(rows, n),)

    specs = (
        jax.ShapeDtypeStruct((rows, n), jnp.float32),
        jax.ShapeDtypeStruct(t.recip_e.shape, jnp.int32),
        jax.ShapeDtypeStruct(t.alpha.shape, jnp.int32),
    )
    return fn, specs
