"""LookUp Table constructors for the REXP and 2D-LUT softmax approximations.

Implements Eq.(4), Eq.(7) (REXP, paper §4.1) and Eq.(8)-(10) (2D LUT, §4.2)
of Vasyltsov & Chang, "Efficient Softmax Approximation for Deep Neural
Networks with Attention Mechanism" (2021).

These builders are the single source of truth for LUT *content* on the
python side.  The rust side (`rust/src/lut/`) re-implements them
bit-identically; `aot.py` dumps golden JSON files of every table so the rust
test-suite can assert equality entry by entry.

Integer semantics (shared contract with rust — keep in sync!):

* A precision `w` stores values scaled by ``qmax = 2**w - 1``.
* ``LUT_recip_e[i] = floor(qmax / e**i)`` for ``i = 0..x_q+1`` with
  ``x_q = ceil(ln(qmax))``  (Eq.(4)).  Out-of-range distance indices clamp
  to the last entry (whose value is 0 by construction for every w).
* ``LUT_alpha[j] = qmax if j == 0 else floor(qmax / j)`` for
  ``j = 0..alpha_len-1``; indices ``>= alpha_len`` read as **0** — the
  paper's ``LUT_alpha[x_s] = 0`` clipping, which is exactly what degrades
  DETR+DC5 (right-tailed sum distribution, Fig. 4).
* ``LUT_exp[k] = round(qmax * e**(-k*0.1))`` for ``k = 0..exp_len-1`` — the
  1-D e^x table of the 2D-LUT method, step 0.1 over the per-precision
  useful range (sizes match Table 8: 101/101/48/12 entries).
* ``LUT_sigma[i][j-1] = min(qmax, floor(qmax * (i*0.1) / j))`` for
  ``i = 0..10``, ``j = 1..sigma_cols``  (Eq.(8)); row index saturates at 10,
  column index saturates at ``sigma_cols`` (values beyond ``max(sum e^x)``
  underestimate alpha, again the Fig. 4 mechanism).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

__all__ = [
    "Precision",
    "PRECISIONS",
    "precision",
    "parse_spec",
    "lut_recip_e",
    "lut_alpha",
    "lut_exp",
    "lut_row",
    "lut_sigma",
    "RexpTables",
    "Lut2dTables",
    "rexp_tables",
    "lut2d_tables",
    "lut_bytes",
]


@dataclass(frozen=True)
class Precision:
    """A quantization precision: `w` value bits, scale `qmax = 2**w - 1`."""

    name: str
    #: number of value bits (the paper's "bits per entry")
    w: int
    #: default LUT_alpha length for NLP workloads (Table 8)
    alpha_len: int
    #: LUT_exp length for the 2D-LUT method (Table 8)
    exp_len: int
    #: number of columns of LUT_sigma == assumed max(sum e^x) (Table 8)
    sigma_cols: int

    @property
    def qmax(self) -> int:
        return (1 << self.w) - 1

    @property
    def x_q(self) -> int:
        """Efficient quantization boundary ``ceil(ln(2**w - 1))`` (Eq.(4))."""
        return math.ceil(math.log(self.qmax))


# Table 8 of the paper fixes the per-precision table shapes for the NLP
# experiments; Table 5 overrides alpha_len for the DETR cases (256/320/512).
PRECISIONS: dict[str, Precision] = {
    "int16": Precision("int16", 15, alpha_len=16, exp_len=101, sigma_cols=60),
    "uint8": Precision("uint8", 8, alpha_len=16, exp_len=101, sigma_cols=60),
    "uint4": Precision("uint4", 4, alpha_len=16, exp_len=48, sigma_cols=29),
    "uint2": Precision("uint2", 2, alpha_len=7, exp_len=12, sigma_cols=8),
}

#: step of the 1-D LUT_exp index in x units (paper: scale_ex = 0.1)
EXP_STEP = 0.1
#: row quantization of LUT_sigma (paper: scale_ex = 0.1 -> rows 0..10)
SIGMA_ROWS = 11
#: column quantization of LUT_sigma (paper: scale_sigma = 1.0)
SIGMA_COL_SCALE = 1.0


def precision(name: str) -> Precision:
    if ":" in name:
        name = name.split(":", 1)[0]
    try:
        return PRECISIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown precision {name!r}; expected one of {sorted(PRECISIONS)}"
        ) from None


def parse_spec(spec: str) -> tuple[Precision, int | None]:
    """Parse a precision spec string: ``"uint8"`` or ``"uint8:a512"``.

    The ``:aN`` suffix overrides the LUT_alpha length (the paper's DETR
    cases 1-3 use 256/320/512-entry alpha tables, Table 5) and is threaded
    through the model stack as part of the precision string.
    """
    if ":" not in spec:
        return precision(spec), None
    base, suffix = spec.split(":", 1)
    if not suffix.startswith("a"):
        raise ValueError(f"bad precision spec {spec!r}; expected '<prec>:aN'")
    return precision(base), int(suffix[1:])


def lut_recip_e(prec: Precision) -> np.ndarray:
    """Eq.(4): ``LUT_{1/e}[i] = floor((1/e**i) * qmax)``, i = 0..x_q+1.

    Length is ``x_q + 2`` which reproduces the paper's Table 5/8 sizes:
    int16 -> 1x13, uint8 -> 1x8, uint4 -> 1x5, uint2 -> 1x3.
    """
    i = np.arange(prec.x_q + 2, dtype=np.float64)
    return np.floor(prec.qmax * np.exp(-i)).astype(np.int32)


def lut_alpha(prec: Precision, length: int | None = None) -> np.ndarray:
    """Eq.(7): ``LUT_alpha[j] = floor(qmax / j)`` with ``LUT_alpha[0] = qmax``.

    `length` is the paper's ``x_s`` (reads at index >= length return 0;
    callers implement that clipping — the table itself has `length` entries).
    """
    n = prec.alpha_len if length is None else length
    if n < 1:
        raise ValueError(f"LUT_alpha length must be >= 1, got {n}")
    j = np.arange(n, dtype=np.float64)
    with np.errstate(divide="ignore"):
        vals = np.floor(prec.qmax / np.maximum(j, 1.0))
    vals[0] = prec.qmax
    return vals.astype(np.int32)


def lut_exp(prec: Precision, length: int | None = None) -> np.ndarray:
    """1-D e^x table of the 2D-LUT method: ``round(qmax * e**(-k*0.1))``.

    Index k quantizes the max-normalized input ``d = max(x) - x`` with step
    0.1; lengths per precision follow Table 8 (101/101/48/12).
    """
    n = prec.exp_len if length is None else length
    k = np.arange(n, dtype=np.float64)
    return np.rint(prec.qmax * np.exp(-k * EXP_STEP)).astype(np.int32)


def lut_row(prec: Precision, length: int | None = None) -> np.ndarray:
    """Row-index decode table of the 2D-LUT method.

    The paper (§4.2) notes the first index of LUT_sigma "can be calculated
    not from e^x but directly from input x" — this table does exactly
    that: ``LUT_row[k]`` maps the distance index k (the LUT_exp address)
    straight to the sigma-table row ``clamp(round(e^{-k*0.1} * 10), 0, 10)``
    computed in the integer domain from LUT_exp. In hardware it is an
    address-decode ROM folded into LUT_exp's output wiring (no arithmetic,
    and in particular no divider, on the datapath).
    """
    e = lut_exp(prec, length)
    q = prec.qmax
    return np.clip((e * 10 + q // 2) // q, 0, SIGMA_ROWS - 1).astype(np.int32)


def lut_sigma(prec: Precision, cols: int | None = None) -> np.ndarray:
    """Eq.(8)-(10): the 2-D quotient table ``LUT_sigma[i][j-1]``.

    Shape ``(11, cols)``; entry value ``min(qmax, floor(qmax * 0.1*i / j))``
    where row i quantizes the numerator e^x in steps of 0.1 and column j-1
    quantizes the denominator sum(e^x) in steps of 1.0.
    """
    c = prec.sigma_cols if cols is None else cols
    i = np.arange(SIGMA_ROWS, dtype=np.float64)[:, None] * EXP_STEP
    j = (np.arange(c, dtype=np.float64)[None, :] + 1.0) * SIGMA_COL_SCALE
    vals = np.floor(prec.qmax * i / j)
    return np.minimum(vals, prec.qmax).astype(np.int32)


@dataclass(frozen=True)
class RexpTables:
    """The two 1-D tables of the REXP method (§4.1)."""

    prec: Precision
    recip_e: np.ndarray
    alpha: np.ndarray

    @property
    def total_bytes(self) -> int:
        return lut_bytes(self.prec, len(self.recip_e)) + lut_bytes(
            self.prec, len(self.alpha)
        )


@dataclass(frozen=True)
class Lut2dTables:
    """The 1-D exp table + 2-D quotient table of the 2D-LUT method (§4.2).

    `row` is the index-decode ROM (see :func:`lut_row`); it is wiring
    folded into LUT_exp in hardware and therefore not counted in
    `total_bytes` (which reproduces the paper's Table 8 accounting).
    """

    prec: Precision
    exp: np.ndarray
    row: np.ndarray
    sigma: np.ndarray

    @property
    def total_bytes(self) -> int:
        return lut_bytes(self.prec, len(self.exp)) + lut_bytes(
            self.prec, int(self.sigma.size)
        )


def rexp_tables(prec: Precision | str, alpha_len: int | None = None) -> RexpTables:
    p = precision(prec) if isinstance(prec, str) else prec
    return RexpTables(p, lut_recip_e(p), lut_alpha(p, alpha_len))


def lut2d_tables(prec: Precision | str, sigma_cols: int | None = None) -> Lut2dTables:
    p = precision(prec) if isinstance(prec, str) else prec
    return Lut2dTables(p, lut_exp(p), lut_row(p), lut_sigma(p, sigma_cols))


def lut_bytes(prec: Precision, entries: int) -> int:
    """Storage estimate used by Tables 5 and 8: ``ceil(w/8)`` bytes/entry.

    The paper stores each entry in whole bytes (no sub-byte packing):
    15-bit int16 entries take 2 B, and uint8/uint4/uint2 take 1 B. This
    reproduces every total of Table 5 (e.g. int16 case 1: (13+256)*2 = 538)
    and Table 8 (e.g. uint4 2D-LUT: 48 + 11*29 = 367).
    """
    return entries * math.ceil(prec.w / 8)
