"""Pallas kernel: numerically-stable exact softmax (the fp reference datapath).

Row-tiled: the grid walks blocks of rows; each program computes a stable
softmax over the full last axis of its tile. This is the baseline the LUT
kernels are compared against both for accuracy and for HBM<->VMEM traffic
(DESIGN.md §Perf).

All kernels in this package use ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret-mode lowers to plain HLO
that the rust runtime can compile and run (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["softmax_exact_pallas", "DEFAULT_BLOCK_ROWS"]

#: default row-tile; small enough that tile + exp temporaries fit VMEM for
#: any n <= 4096 at fp32 (2 * 128 * 4096 * 4 B = 4 MiB of a 16 MiB VMEM).
DEFAULT_BLOCK_ROWS = 128


def _exact_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def _pad_rows(x2d: jnp.ndarray, bm: int) -> tuple[jnp.ndarray, int]:
    rows = x2d.shape[0]
    pad = (-rows) % bm
    if pad:
        x2d = jnp.concatenate([x2d, jnp.zeros((pad, x2d.shape[1]), x2d.dtype)])
    return x2d, rows


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax_exact_pallas(
    x: jnp.ndarray, block_rows: int = DEFAULT_BLOCK_ROWS
) -> jnp.ndarray:
    """Exact softmax over the last axis of `x` via a row-tiled Pallas kernel."""
    shape = x.shape
    n = shape[-1]
    x2d = x.reshape(-1, n).astype(jnp.float32)
    bm = min(block_rows, x2d.shape[0])
    x2d, rows = _pad_rows(x2d, bm)

    out = pl.pallas_call(
        _exact_kernel,
        grid=(x2d.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.float32),
        interpret=True,
    )(x2d)
    return out[:rows].reshape(shape)
