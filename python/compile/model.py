"""L2 variant registry: every (model, weights, softmax mode, precision)
inference graph the AOT pipeline lowers, and the python-side entry points
for building them.

A *variant* is identified by a string ``<model>__<weights>__<mode>__<spec>``:

    model   nmt14 | nmt17 | sst2 | mrpc | detr | detr_dc5
    weights fp32 | ptqd            (ptqd = dynamic int8 PTQ, Appendix A.3)
    mode    exact | rexp | lut2d | priorart_eq2 | priorart_eq2plus | aggressive
    spec    fp32 | int16 | uint8 | uint4 | uint2, optionally ':aN' for the
            REXP alpha-table length (the paper's DETR cases 1..3)

NMT variants lower to TWO artifacts (encode + decode step) because the
rust coordinator owns the autoregressive loop. Model weights are runtime
*operands* (not baked constants): artifacts stay small and the same
weight bundle feeds every softmax variant of a model.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp

import numpy as np

from . import data
from .kernels import luts
from .models import bert, common, detr, nmt

#: evaluation batch sizes baked into artifact shapes (the coordinator's
#: dynamic batcher packs requests up to this size)
NMT_BATCH = 8
CLS_BATCH = 8
DETR_BATCH = 4

NMT_DATA = {14: data.NmtConfig(corpus_seed=14), 17: data.NmtConfig(corpus_seed=17)}
NMT_CFG = nmt.NmtModelConfig()
BERT_CFG = bert.BertModelConfig()
DETR_CFG = detr.DetrModelConfig()
DETR_DC5_CFG = detr.dc5_variant(DETR_CFG)


@dataclass(frozen=True)
class Variant:
    model: str          # nmt14 / nmt17 / sst2 / mrpc / detr / detr_dc5
    weights: str        # fp32 / ptqd
    mode: str           # softmax mode
    spec: str           # precision spec ("fp32" only with mode == exact)

    @property
    def name(self) -> str:
        spec = self.spec.replace(":", "-")
        return f"{self.model}__{self.weights}__{self.mode}__{spec}"

    @property
    def quantized(self) -> bool:
        return self.weights == "ptqd"

    @property
    def ckpt(self) -> str:
        return self.model  # one checkpoint per model name


def _nmt_variants(model: str) -> list[Variant]:
    out = [Variant(model, "fp32", "exact", "fp32"), Variant(model, "ptqd", "exact", "fp32")]
    for mode in ("rexp", "lut2d"):
        for spec in ("int16", "uint8", "uint4", "uint2"):
            out.append(Variant(model, "ptqd", mode, spec))
    return out


def _cls_variants(model: str) -> list[Variant]:
    return _nmt_variants(model)  # same grid (Table 2)


def _detr_variants(model: str) -> list[Variant]:
    out = [Variant(model, "fp32", "exact", "fp32"), Variant(model, "ptqd", "exact", "fp32")]
    # Tables 6/7 + Fig 2: PTQ-D x {int16, uint8} x alpha cases 256/320/512
    for spec_base in ("int16", "uint8"):
        for alpha in (256, 320, 512):
            out.append(Variant(model, "ptqd", "rexp", f"{spec_base}:a{alpha}"))
    # Tables 1/3: prior arts at fp32 weights, uint8 outer rounding
    out.append(Variant(model, "fp32", "priorart_eq2", "uint8"))
    out.append(Variant(model, "fp32", "priorart_eq2plus", "uint8"))
    # REXP row of Table 1 at fp32 weights (prior-art comparison conditions)
    out.append(Variant(model, "fp32", "rexp", "uint8:a256"))
    # Fig 5: aggressive approximation collapse
    out.append(Variant(model, "fp32", "aggressive", "uint8"))
    return out


def all_variants() -> list[Variant]:
    vs: list[Variant] = []
    for m in ("nmt14", "nmt17"):
        vs += _nmt_variants(m)
    for m in ("sst2", "mrpc"):
        vs += _cls_variants(m)
    for m in ("detr", "detr_dc5"):
        vs += _detr_variants(m)
    return vs


def _mode_spec(v: Variant) -> tuple[str, str]:
    """(softmax_mode, prec-spec) as consumed by the model stack."""
    return v.mode, (v.spec if v.spec != "fp32" else "uint8")


def variant_tables(v: Variant) -> list[np.ndarray]:
    """LUT contents a variant's artifact takes as runtime operands.

    The rust runtime rebuilds EXACTLY these from its own lut substrate
    (mode + spec are in the manifest) and feeds them on every execution —
    tables never live inside the compiled artifact.
    """
    mode, spec = _mode_spec(v)
    p, alpha_len = luts.parse_spec(spec)
    if mode == "rexp":
        t = luts.rexp_tables(p, alpha_len)
        return [t.recip_e, t.alpha]
    if mode == "lut2d":
        t = luts.lut2d_tables(p)
        return [t.exp, t.row, t.sigma]
    if mode == "aggressive":
        return [luts.lut_recip_e(p)]
    return []


class _tables_ctx:
    """Scoped install of traced table operands into the model stack."""

    def __init__(self, tables):
        self.tables = list(tables)

    def __enter__(self):
        common.RUNTIME_TABLES = self.tables if self.tables else None

    def __exit__(self, *exc):
        common.RUNTIME_TABLES = None


# ---------------------------------------------------------------------------
# graph builders: fn(params, *inputs) -> tuple of outputs


def nmt_encode_fn(v: Variant):
    mode, spec = _mode_spec(v)

    def fn(params, tables, src):
        with _tables_ctx(tables):
            return (nmt.encode(params, src, NMT_CFG, mode, spec, v.quantized),)

    args = (jax.ShapeDtypeStruct((NMT_BATCH, NMT_CFG.max_src), jnp.int32),)
    return fn, args


def nmt_decode_fn(v: Variant):
    mode, spec = _mode_spec(v)

    def fn(params, tables, memory, src, tgt):
        with _tables_ctx(tables):
            return (
                nmt.decode_logits(
                    params, memory, src, tgt, NMT_CFG, mode, spec, v.quantized
                ),
            )

    args = (
        jax.ShapeDtypeStruct((NMT_BATCH, NMT_CFG.max_src, NMT_CFG.d_model), jnp.float32),
        jax.ShapeDtypeStruct((NMT_BATCH, NMT_CFG.max_src), jnp.int32),
        jax.ShapeDtypeStruct((NMT_BATCH, NMT_CFG.max_tgt), jnp.int32),
    )
    return fn, args


def cls_fn(v: Variant):
    mode, spec = _mode_spec(v)

    def fn(params, tables, tokens):
        with _tables_ctx(tables):
            return (bert.forward(params, tokens, BERT_CFG, mode, spec, v.quantized),)

    args = (jax.ShapeDtypeStruct((CLS_BATCH, BERT_CFG.max_len), jnp.int32),)
    return fn, args


def detr_fn(v: Variant):
    mode, spec = _mode_spec(v)
    cfg = DETR_DC5_CFG if v.model == "detr_dc5" else DETR_CFG

    def fn(params, tables, images):
        with _tables_ctx(tables):
            cls_logits, boxes = detr.forward(
                params, images, cfg, mode, spec, v.quantized
            )
        return (cls_logits, boxes)

    s = cfg.image_size
    args = (jax.ShapeDtypeStruct((DETR_BATCH, s, s, cfg.channels), jnp.float32),)
    return fn, args


def artifact_graphs(v: Variant) -> dict[str, tuple]:
    """Variant -> {artifact suffix: (fn, example_args)}."""
    if v.model.startswith("nmt"):
        return {"enc": nmt_encode_fn(v), "dec": nmt_decode_fn(v)}
    if v.model in ("sst2", "mrpc"):
        return {"cls": cls_fn(v)}
    return {"det": detr_fn(v)}


def load_ckpt(out_dir: str, model: str) -> common.Params:
    return common.load_params(os.path.join(out_dir, "ckpt", f"{model}.npz"))
