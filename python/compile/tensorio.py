"""LTB1: a minimal tensor-bundle binary format shared with rust.

Datasets, golden LUTs and checkpoint-derived constants cross the
python -> rust boundary through these bundles (serde/npz are unavailable
offline; this format is ~40 lines on each side and fully specified here).

Layout (little-endian):
    magic   4 bytes  b"LTB1"
    count   u32
    then per tensor:
        name_len u16, name utf8 bytes
        dtype    u8   (0 = f32, 1 = i32)
        ndim     u8
        dims     ndim x u32
        data     product(dims) elements, LE
Rust reader: rust/src/runtime/tensorio.rs (kept in sync by the golden-file
integration test `integration_runtime::ltb_roundtrip`).
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"LTB1"
_DTYPES = {0: np.float32, 1: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_bundle(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.int64:
                arr = arr.astype(np.int32)
            if arr.dtype == np.float64:
                arr = arr.astype(np.float32)
            code = _CODES.get(arr.dtype)
            if code is None:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read_bundle(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            dt = np.dtype(_DTYPES[code]).newbyteorder("<")
            n = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(f.read(n * dt.itemsize), dtype=dt).reshape(dims)
            out[name] = arr.astype(_DTYPES[code])
    return out
