"""AOT compile path: train -> dump datasets/LUTs/weights -> lower HLO text.

Runs ONCE at build time (`make artifacts`); python never touches the
request path. Interchange formats:

  *.hlo.txt      HLO **text** (not serialized HloModuleProto: jax >= 0.5
                 emits 64-bit instruction ids that xla_extension 0.5.1
                 rejects; the text parser reassigns ids — see
                 /opt/xla-example/README.md)
  *.ltb          LTB1 tensor bundles (tensorio.py <-> rust/src/runtime/tensorio.rs)
  manifest.json  the artifact index the rust runtime loads everything from

Usage: cd python && python -m compile.aot --out ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, quant, tensorio, train
from .kernels import luts, ref
from .kernels.softmax_lut2d import make_lut2d_callable
from .kernels.softmax_rexp import make_rexp_callable
from .models import common, detr

#: standalone softmax artifact shape (quickstart + runtime tests + the
#: softmax-microservice example)
SM_ROWS, SM_COLS = 256, 64

EVAL_NMT, EVAL_CLS, EVAL_DETR = 200, 400, 100


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# LUT golden files


def dump_luts(out: str) -> dict:
    """Every LUT for every precision (+ the DETR alpha cases) in one bundle;
    rust/src/lut asserts bit-identical regeneration (golden-file test)."""
    tensors: dict[str, np.ndarray] = {}
    meta: dict = {"precisions": {}, "alpha_cases": [256, 320, 512]}
    for name, p in luts.PRECISIONS.items():
        rt = luts.rexp_tables(p)
        lt = luts.lut2d_tables(p)
        tensors[f"{name}/recip_e"] = rt.recip_e
        tensors[f"{name}/alpha"] = rt.alpha
        tensors[f"{name}/exp"] = lt.exp
        tensors[f"{name}/row"] = lt.row
        tensors[f"{name}/sigma"] = lt.sigma
        for alen in meta["alpha_cases"]:
            tensors[f"{name}/alpha_{alen}"] = luts.lut_alpha(p, alen)
        meta["precisions"][name] = {
            "w": p.w,
            "qmax": p.qmax,
            "x_q": p.x_q,
            "rexp_bytes": rt.total_bytes,
            "lut2d_bytes": lt.total_bytes,
        }
    tensorio.write_bundle(os.path.join(out, "luts.ltb"), tensors)
    return meta


def dump_model_golden(out: str) -> None:
    """Golden model outputs (first 8 eval rows) for representative variants;
    the rust runtime must reproduce them through the artifacts — closes the
    whole python->HLO->PJRT->rust loop numerically."""
    from . import tensorio as tio

    toks = tio.read_bundle(os.path.join(out, "eval_sst2.ltb"))["tokens"][:8]
    tensors: dict[str, np.ndarray] = {"tokens": toks}
    base = model.load_ckpt(out, "sst2")
    for v in (
        model.Variant("sst2", "fp32", "exact", "fp32"),
        model.Variant("sst2", "ptqd", "exact", "fp32"),
        model.Variant("sst2", "ptqd", "rexp", "uint8"),
        model.Variant("sst2", "ptqd", "lut2d", "uint8"),
        model.Variant("sst2", "ptqd", "rexp", "uint2"),
    ):
        params = quant.quantize_params(base) if v.quantized else base
        tables = tuple(jnp.asarray(t) for t in model.variant_tables(v))
        fn, _ = model.cls_fn(v)
        common.USE_PALLAS_SOFTMAX = True
        (logits,) = fn(params, tables, jnp.asarray(toks))
        common.USE_PALLAS_SOFTMAX = False
        tensors[f"logits/{v.name}"] = np.asarray(logits)
    tensorio.write_bundle(os.path.join(out, "golden_models.ltb"), tensors)
    print("[aot] golden_models.ltb written")


def dump_softmax_golden(out: str) -> None:
    """Golden input/output vectors for the rust software softmax models
    (rust/src/softmax must reproduce the integer stage bit-exactly)."""
    rng = np.random.default_rng(4242)
    x = rng.normal(0.0, 3.0, (64, 32)).astype(np.float32)
    tensors: dict[str, np.ndarray] = {"x": x}
    xj = jnp.asarray(x)
    for name, p in luts.PRECISIONS.items():
        for mode in ("rexp", "lut2d", "aggressive"):
            y = np.asarray(ref.softmax_by_mode(xj, mode, name))
            tensors[f"{mode}/{name}"] = np.rint(y * p.qmax).astype(np.int32)
    tensors["exact"] = np.asarray(ref.softmax_exact(xj))
    tensorio.write_bundle(os.path.join(out, "golden_softmax.ltb"), tensors)


# ---------------------------------------------------------------------------
# evaluation datasets (shared with rust through LTB bundles)


def dump_datasets(out: str) -> dict:
    meta = {}
    # NMT: src + teacher tgt (BLEU references derive from tgt rows)
    for seed in (14, 17):
        cfg = model.NMT_DATA[seed]
        src, tgt = data.nmt_batch(cfg, EVAL_NMT, seed=99_000 + seed)
        tensorio.write_bundle(
            os.path.join(out, f"eval_nmt{seed}.ltb"), {"src": src, "tgt": tgt}
        )
        meta[f"nmt{seed}"] = {"samples": EVAL_NMT}
    # classification
    toks, labels = data.sentiment_batch(data.SentimentConfig(), EVAL_CLS, seed=99_100)
    tensorio.write_bundle(
        os.path.join(out, "eval_sst2.ltb"), {"tokens": toks, "labels": labels}
    )
    meta["sst2"] = {"samples": EVAL_CLS}
    toks, labels = data.mrpc_batch(data.MrpcConfig(), EVAL_CLS, seed=99_200)
    tensorio.write_bundle(
        os.path.join(out, "eval_mrpc.ltb"), {"tokens": toks, "labels": labels}
    )
    meta["mrpc"] = {"samples": EVAL_CLS}
    # detection: images + flattened gt rows [img_idx, class, cx, cy, w, h]
    scfg = data.SceneConfig()
    imgs, gts = data.scene_batch(scfg, EVAL_DETR, seed=99_300)
    rows = np.concatenate(
        [
            np.concatenate([np.full((len(g), 1), i, np.float32), g], axis=1)
            for i, g in enumerate(gts)
        ]
    ).astype(np.float32)
    tensorio.write_bundle(
        os.path.join(out, "eval_detr.ltb"), {"images": imgs, "gt": rows}
    )
    meta["detr"] = {"samples": EVAL_DETR}
    return meta


# ---------------------------------------------------------------------------
# weights bundles (params as runtime operands)


def param_leaves(params) -> tuple[list[str], list[np.ndarray]]:
    """Leaf names + arrays in EXACTLY the order jax.jit flattens the pytree
    (tree_flatten_with_path), i.e. the HLO parameter order."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    names, arrays = [], []
    for path, leaf in leaves:
        names.append("".join(str(k.key) + "/" for k in path).rstrip("/"))
        arrays.append(np.asarray(leaf))
    return names, arrays


def dump_weights(out: str) -> dict:
    meta = {}
    for m in ("nmt14", "nmt17", "sst2", "mrpc", "detr", "detr_dc5"):
        params = model.load_ckpt(out, m)
        for weights in ("fp32", "ptqd"):
            p = quant.quantize_params(params) if weights == "ptqd" else params
            names, arrays = param_leaves(p)
            tensorio.write_bundle(
                os.path.join(out, f"weights_{m}_{weights}.ltb"),
                {f"{i:03d}:{n}": a for i, (n, a) in enumerate(zip(names, arrays))},
            )
        meta[m] = {
            "param_order": names,
            "fp32_bytes": quant.model_size_bytes(params, False),
            "ptqd_bytes": quant.model_size_bytes(params, True),
        }
    return meta


# ---------------------------------------------------------------------------
# HLO lowering


def lower_variant(out: str, v: model.Variant, params) -> list[dict]:
    """Lower every graph of a variant; returns manifest entries."""
    entries = []
    tables = model.variant_tables(v)
    table_specs = tuple(
        jax.ShapeDtypeStruct(t.shape, jnp.int32) for t in tables
    )
    for suffix, (fn, args) in model.artifact_graphs(v).items():
        name = f"{v.name}__{suffix}"
        path = os.path.join(out, f"{name}.hlo.txt")
        t0 = time.time()
        # keep_unused=True: the rust runtime feeds the FULL weight bundle to
        # every artifact of a model; without it jax prunes params the graph
        # doesn't touch (e.g. decoder weights from the encoder artifact) and
        # the PJRT buffer count no longer matches. LUT tables are runtime
        # operands (constants miscompile under xla_extension 0.5.1 and
        # operands give L3 the paper's reconfigure-on-demand).
        lowered = jax.jit(fn, keep_unused=True).lower(params, table_specs, *args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "variant": v.name,
                "model": v.model,
                "weights": v.weights,
                "mode": v.mode,
                "spec": v.spec,
                "kind": suffix,
                "file": os.path.basename(path),
                "tables": len(tables),
                "inputs": [
                    {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
                ],
                "outputs": len(lowered.out_info),
                "lower_seconds": round(time.time() - t0, 2),
            }
        )
        print(f"[aot] {name}: {len(text) // 1024} KiB in {entries[-1]['lower_seconds']}s")
    return entries


def lower_softmax_kernels(out: str) -> list[dict]:
    """Standalone LUT-softmax artifacts with table operands (quickstart,
    integration tests, softmax microservice)."""
    entries = []
    for prec in ("uint8", "int16"):
        for mode, maker in (("rexp", make_rexp_callable), ("lut2d", make_lut2d_callable)):
            fn, specs = maker(SM_ROWS, SM_COLS, prec)
            name = f"softmax__{mode}__{prec}"
            path = os.path.join(out, f"{name}.hlo.txt")
            lowered = jax.jit(fn).lower(*specs)
            with open(path, "w") as f:
                f.write(to_hlo_text(lowered))
            entries.append(
                {
                    "name": name,
                    "kind": "softmax",
                    "mode": mode,
                    "spec": prec,
                    "file": os.path.basename(path),
                    "inputs": [
                        {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
                    ],
                }
            )
            print(f"[aot] {name} lowered")
    # fused attention artifacts (perf comparison: one kernel vs the
    # unfused scores -> softmax -> values path; DESIGN.md §Perf)
    from .kernels.attention import make_attention_callable

    AH, AL, AD = 8, 64, 16
    for mode in ("exact", "rexp"):
        fn, specs = make_attention_callable(AH, AL, AD, mode=mode, prec="uint8")
        name = f"attention__{mode}__uint8"
        path = os.path.join(out, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*specs)
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        entries.append(
            {
                "name": name,
                "kind": "attention",
                "mode": mode,
                "spec": "uint8",
                "file": os.path.basename(path),
                "inputs": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
                ],
            }
        )
        print(f"[aot] {name} lowered")

    # exact softmax baseline artifact
    from .kernels.softmax_exact import softmax_exact_pallas

    fn = lambda x: (softmax_exact_pallas(x),)  # noqa: E731
    spec = jax.ShapeDtypeStruct((SM_ROWS, SM_COLS), jnp.float32)
    path = os.path.join(out, "softmax__exact__fp32.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(jax.jit(fn).lower(spec)))
    entries.append(
        {
            "name": "softmax__exact__fp32",
            "kind": "softmax",
            "mode": "exact",
            "spec": "fp32",
            "file": os.path.basename(path),
            "inputs": [{"shape": [SM_ROWS, SM_COLS], "dtype": "float32"}],
        }
    )
    return entries


# ---------------------------------------------------------------------------
# Fig. 4 instrumentation: sum(e^x) distributions of DETR attention rows


def dump_fig4(out: str) -> dict:
    scfg = data.SceneConfig()
    result = {}
    for m, cfg in (("detr", model.DETR_CFG), ("detr_dc5", model.DETR_DC5_CFG)):
        params = model.load_ckpt(out, m)
        stats: list = []
        run = 0
        while len(stats) < 200:
            imgs, _ = data.scene_batch(scfg, model.DETR_BATCH, seed=50_000 + run)
            detr.forward(params, jnp.asarray(imgs), cfg, stats=stats)
            run += 1
        values = np.concatenate([np.asarray(s).ravel() for s in stats[:200]])
        counts, edges = np.histogram(values, bins=50, range=(0.0, 500.0))
        result[m] = {
            "tensors": 200,
            "values": int(values.size),
            "mean": float(values.mean()),
            "p99": float(np.percentile(values, 99)),
            "max": float(values.max()),
            "bin_edges": [float(e) for e in edges],
            "counts": [int(c) for c in counts],
        }
        print(f"[aot] fig4 {m}: mean sum(e^x) = {result[m]['mean']:.1f}")
    return result


# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="short training runs")
    ap.add_argument(
        "--jnp-softmax",
        action="store_true",
        help="lower models with ref-jnp softmax instead of the Pallas kernels",
    )
    args = ap.parse_args(argv)
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    t0 = time.time()
    # training differentiates the graph -> always ref-jnp softmax there;
    # the Pallas kernels are enabled only for the inference lowerings below.
    common.USE_PALLAS_SOFTMAX = False
    train.train_all(out, quick=args.quick)

    dump_softmax_golden(out)
    manifest: dict = {
        "created": time.strftime("%Y-%m-%d %H:%M:%S"),
        "luts": dump_luts(out),
        "datasets": dump_datasets(out),
        "weights": dump_weights(out),
        "batch": {
            "nmt": model.NMT_BATCH,
            "cls": model.CLS_BATCH,
            "detr": model.DETR_BATCH,
        },
        "nmt": {
            "max_src": model.NMT_CFG.max_src,
            "max_tgt": model.NMT_CFG.max_tgt,
            "vocab": model.NMT_CFG.vocab,
        },
        "softmax_shape": [SM_ROWS, SM_COLS],
        "artifacts": [],
    }

    common.USE_PALLAS_SOFTMAX = not args.jnp_softmax
    manifest["artifacts"] += lower_softmax_kernels(out)
    for v in model.all_variants():
        params = model.load_ckpt(out, v.ckpt)
        if v.quantized:
            params = quant.quantize_params(params)
        manifest["artifacts"] += lower_variant(out, v, params)

    common.USE_PALLAS_SOFTMAX = False  # fig4 only needs the jnp forward
    manifest["fig4"] = dump_fig4(out)
    dump_model_golden(out)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"[aot] DONE: {len(manifest['artifacts'])} artifacts in "
        f"{time.time() - t0:.0f}s -> {out}"
    )


if __name__ == "__main__":
    sys.exit(main())


_ = ref  # keep the oracle import: documents the L1 dependency of this module
