"""nmt_lite: transformer encoder-decoder (the paper's OpenNMT analog).

Architecture: Transformer-base scaled down for CPU training — shared token
embeddings, sinusoidal positions, N encoder + N decoder blocks, tied output
projection. The inference graph splits into `encode` and `decode_step`
artifacts so the **rust coordinator owns the autoregressive loop** (the
serving-runtime framing of DESIGN.md).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import data
from . import common


@dataclass(frozen=True)
class NmtModelConfig:
    vocab: int = 64
    d_model: int = 64
    d_ff: int = 128
    heads: int = 4
    layers: int = 2
    max_src: int = 20
    max_tgt: int = 21


def init_params(key, cfg: NmtModelConfig) -> common.Params:
    ks = jax.random.split(key, 2 * cfg.layers + 2)
    return {
        "embed": common.embedding_init(ks[0], cfg.vocab, cfg.d_model),
        "enc": {
            str(i): common.block_init(ks[1 + i], cfg.d_model, cfg.d_ff)
            for i in range(cfg.layers)
        },
        "dec": {
            str(i): common.block_init(
                ks[1 + cfg.layers + i], cfg.d_model, cfg.d_ff, cross=True
            )
            for i in range(cfg.layers)
        },
        "out": common.dense_init(ks[-1], cfg.d_model, cfg.vocab),
    }


def encode(
    params,
    src: jnp.ndarray,
    cfg: NmtModelConfig,
    softmax_mode: str = "exact",
    prec: str = "uint8",
    quantized: bool = False,
    stats: list | None = None,
) -> jnp.ndarray:
    """(batch, max_src) tokens -> (batch, max_src, d_model) memory."""
    mask = common.padding_mask(src)
    x = params["embed"][src] + common.sinusoidal_positions(src.shape[1], cfg.d_model)
    for i in range(cfg.layers):
        x = common.encoder_block(
            params["enc"][str(i)], x, cfg.heads, mask, softmax_mode, prec, quantized, stats
        )
    return x


def decode_logits(
    params,
    memory: jnp.ndarray,
    src: jnp.ndarray,
    tgt: jnp.ndarray,
    cfg: NmtModelConfig,
    softmax_mode: str = "exact",
    prec: str = "uint8",
    quantized: bool = False,
    stats: list | None = None,
) -> jnp.ndarray:
    """Teacher-forced decoder: (batch, T) prefix -> (batch, T, vocab) logits."""
    T = tgt.shape[1]
    self_mask = common.causal_mask(T) + common.padding_mask(tgt)
    cross_mask = common.padding_mask(src)
    x = params["embed"][tgt] + common.sinusoidal_positions(T, cfg.d_model)
    for i in range(cfg.layers):
        x = common.decoder_block(
            params["dec"][str(i)],
            x,
            memory,
            cfg.heads,
            self_mask,
            cross_mask,
            softmax_mode,
            prec,
            quantized,
            stats,
        )
    return common.dense(params["out"], x, quantized)


def loss_fn(params, src, tgt, cfg: NmtModelConfig) -> jnp.ndarray:
    """Cross-entropy over next-token prediction, PAD positions masked."""
    memory = encode(params, src, cfg)
    logits = decode_logits(params, memory, src, tgt[:, :-1], cfg)
    targets = tgt[:, 1:]
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, targets[..., None], -1)[..., 0]
    mask = (targets != data.PAD).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def greedy_decode(
    params,
    src: jnp.ndarray,
    cfg: NmtModelConfig,
    softmax_mode: str = "exact",
    prec: str = "uint8",
    quantized: bool = False,
) -> jnp.ndarray:
    """Python-side greedy decoding (build-time eval only; the serving path
    re-implements this loop in rust over the AOT artifacts)."""
    memory = encode(params, src, cfg, softmax_mode, prec, quantized)
    batch = src.shape[0]
    tgt = jnp.full((batch, cfg.max_tgt), data.PAD, jnp.int32)
    tgt = tgt.at[:, 0].set(data.BOS)
    done = jnp.zeros((batch,), bool)
    for t in range(1, cfg.max_tgt):
        logits = decode_logits(
            params, memory, src, tgt[:, :t], cfg, softmax_mode, prec, quantized
        )
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        nxt = jnp.where(done, data.PAD, nxt)
        tgt = tgt.at[:, t].set(nxt)
        done = done | (nxt == data.EOS)
        if bool(jnp.all(done)):
            break
    return tgt
