"""Shared transformer building blocks and a hand-rolled Adam.

flax/optax are unavailable offline, so parameters are plain nested dicts of
jnp arrays, initializers use jax.random, and Adam is implemented directly
on pytrees. Every attention call takes ``softmax_mode``/``prec`` so the
inference graph can swap the softmax approximation without retraining
(post-training substitution, paper §5).

Quantized inference (PTQ-D, Appendix A.3) is driven by the ``quantized``
flag: dense layers then run the dynamic int8 scheme from `compile.quant`.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .. import quant
from ..kernels import ref

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# initializers


def dense_init(key, d_in: int, d_out: int) -> Params:
    k1, _ = jax.random.split(key)
    scale = math.sqrt(2.0 / (d_in + d_out))
    return {
        "w": jax.random.normal(k1, (d_in, d_out), jnp.float32) * scale,
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def layernorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def embedding_init(key, vocab: int, d: int) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


def mha_init(key, d_model: int) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, d_model),
        "wk": dense_init(ks[1], d_model, d_model),
        "wv": dense_init(ks[2], d_model, d_model),
        "wo": dense_init(ks[3], d_model, d_model),
    }


def ffn_init(key, d_model: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {"up": dense_init(k1, d_model, d_ff), "down": dense_init(k2, d_ff, d_model)}


def block_init(key, d_model: int, d_ff: int, cross: bool = False) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "attn": mha_init(ks[0], d_model),
        "ffn": ffn_init(ks[1], d_model, d_ff),
        "ln1": layernorm_init(d_model),
        "ln2": layernorm_init(d_model),
    }
    if cross:
        p["xattn"] = mha_init(ks[2], d_model)
        p["ln3"] = layernorm_init(d_model)
    return p


# ---------------------------------------------------------------------------
# forward ops


def dense(p: Params, x: jnp.ndarray, quantized: bool = False) -> jnp.ndarray:
    """Dense layer; quantized=True adds the *dynamic activation* half of
    PTQ-D (weights are fake-quantized offline by quant.quantize_params, so
    quantized graphs must be fed quantized param pytrees)."""
    if quantized:
        x = quant.fake_quant_array(x)
    return x @ p["w"] + p["b"]


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def sinusoidal_positions(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def split_heads(x: jnp.ndarray, heads: int) -> jnp.ndarray:
    b, l, d = x.shape
    return x.reshape(b, l, heads, d // heads).transpose(0, 2, 1, 3)


def merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, l, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * dh)


def mha(
    p: Params,
    q_in: jnp.ndarray,
    kv_in: jnp.ndarray,
    heads: int,
    mask: jnp.ndarray | None = None,
    softmax_mode: str = "exact",
    prec: str = "uint8",
    quantized: bool = False,
    stats: list | None = None,
) -> jnp.ndarray:
    """Multi-head attention with pluggable softmax approximation.

    `mask` is additive (0 = keep, -inf-ish = drop), broadcastable to
    (batch, heads, Lq, Lk). When `stats` is a list, the per-row sum(e^x)
    values of the exact softmax are appended (Fig. 4 instrumentation).
    """
    d_model = q_in.shape[-1]
    dh = d_model // heads
    q = split_heads(dense(p["wq"], q_in, quantized), heads)
    k = split_heads(dense(p["wk"], kv_in, quantized), heads)
    v = split_heads(dense(p["wv"], kv_in, quantized), heads)

    scores = jnp.einsum("bhld,bhsd->bhls", q, k) * (1.0 / math.sqrt(dh))
    if mask is not None:
        scores = scores + mask
    if stats is not None:
        m = jnp.max(scores, -1, keepdims=True)
        stats.append(jnp.sum(jnp.exp(scores - m), -1))
    probs = _softmax(scores, softmax_mode, prec)
    out = merge_heads(jnp.einsum("bhls,bhsd->bhld", probs, v))
    return dense(p["wo"], out, quantized)


#: when True, mha routes exact/rexp/lut2d through the L1 *Pallas kernels*
#: so they lower into the model's HLO (the AOT contract); ref-jnp otherwise
#: (training / quick python eval — numerically identical, kernel==oracle is
#: asserted by tests/test_kernels.py).
USE_PALLAS_SOFTMAX = False

#: when set (by the AOT graph builders in model.py), LUT contents come from
#: these *traced* arrays so they lower to runtime HLO OPERANDS instead of
#: baked constants. Two reasons: (a) the paper's "LUT reconfigurable on
#: demand" property — L3 swaps tables without recompiling; (b) s32 constant
#: tables miscompile through the xla_extension 0.5.1 text round-trip,
#: operands execute bit-exactly (see DESIGN.md §Perf notes).
RUNTIME_TABLES: list | None = None


def _softmax(scores: jnp.ndarray, mode: str, prec: str) -> jnp.ndarray:
    from ..kernels import luts

    if RUNTIME_TABLES is not None and mode in ("rexp", "lut2d", "aggressive"):
        t = RUNTIME_TABLES
        p, _ = luts.parse_spec(prec)
        if mode == "rexp":
            from ..kernels.softmax_rexp import rexp_with_tables

            return rexp_with_tables(scores, t[0], t[1], p.name)
        if mode == "lut2d":
            from ..kernels.softmax_lut2d import lut2d_with_tables

            return lut2d_with_tables(scores, t[0], t[1], t[2], p.name)
        return ref.aggressive_pipeline(scores, t[0], p.qmax)
    if USE_PALLAS_SOFTMAX and mode in ("exact", "rexp", "lut2d"):
        from ..kernels.softmax_exact import softmax_exact_pallas
        from ..kernels.softmax_lut2d import softmax_lut2d_pallas
        from ..kernels.softmax_rexp import softmax_rexp_pallas

        if mode == "exact":
            return softmax_exact_pallas(scores)
        p, alpha_len = luts.parse_spec(prec)
        if mode == "rexp":
            return softmax_rexp_pallas(scores, p.name, alpha_len)
        return softmax_lut2d_pallas(scores, p.name)
    return ref.softmax_by_mode(scores, mode, prec)


def ffn(p: Params, x: jnp.ndarray, quantized: bool = False) -> jnp.ndarray:
    return dense(p["down"], jax.nn.relu(dense(p["up"], x, quantized)), quantized)


def encoder_block(
    p: Params,
    x: jnp.ndarray,
    heads: int,
    mask=None,
    softmax_mode="exact",
    prec="uint8",
    quantized=False,
    stats=None,
) -> jnp.ndarray:
    x = layernorm(
        p["ln1"],
        x + mha(p["attn"], x, x, heads, mask, softmax_mode, prec, quantized, stats),
    )
    return layernorm(p["ln2"], x + ffn(p["ffn"], x, quantized))


def decoder_block(
    p: Params,
    x: jnp.ndarray,
    memory: jnp.ndarray,
    heads: int,
    self_mask=None,
    cross_mask=None,
    softmax_mode="exact",
    prec="uint8",
    quantized=False,
    stats=None,
) -> jnp.ndarray:
    x = layernorm(
        p["ln1"],
        x + mha(p["attn"], x, x, heads, self_mask, softmax_mode, prec, quantized, stats),
    )
    x = layernorm(
        p["ln3"],
        x
        + mha(
            p["xattn"], x, memory, heads, cross_mask, softmax_mode, prec, quantized, stats
        ),
    )
    return layernorm(p["ln2"], x + ffn(p["ffn"], x, quantized))


def causal_mask(length: int) -> jnp.ndarray:
    m = jnp.tril(jnp.ones((length, length), jnp.float32))
    return jnp.where(m == 0, -1e9, 0.0)[None, None]


def padding_mask(tokens: jnp.ndarray, pad_id: int = 0) -> jnp.ndarray:
    """(batch, L) tokens -> additive mask (batch, 1, 1, L)."""
    return jnp.where(tokens == pad_id, -1e9, 0.0)[:, None, None, :]


# ---------------------------------------------------------------------------
# Adam on pytrees (optax is unavailable offline)


def adam_init(params: Params) -> Params:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(
    params: Params,
    grads: Params,
    state: Params,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[Params, Params]:
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# checkpoint (flat .npz; keys are /-joined paths)


def flatten(params: Params, prefix: str = "") -> dict[str, jnp.ndarray]:
    out = {}
    for k, v in params.items():
        key = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten(v, key))
        else:
            out[key] = v
    return out


def unflatten(flat: dict[str, jnp.ndarray]) -> Params:
    root: Params = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)
    return root


def save_params(path: str, params: Params) -> None:
    import numpy as np

    np.savez(path, **{k: np.asarray(v) for k, v in flatten(params).items()})


def load_params(path: str) -> Params:
    import numpy as np

    with np.load(path) as z:
        return unflatten({k: z[k] for k in z.files})
