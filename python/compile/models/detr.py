"""detr_lite: set-prediction object detector (the paper's DETR analog).

Patch-embedding "backbone" + transformer encoder + decoder with learned
object queries + class/box heads, trained with Hungarian matching exactly
like DETR. The `dc5` flag halves the patch size, *quadrupling* the encoder
token count — the analog of DETR's dilated-C5 backbone whose longer
self-attention rows shift the sum(e^x) distribution right (paper Fig. 4)
and stress LUT_alpha clipping.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from scipy.optimize import linear_sum_assignment

from . import common


@dataclass(frozen=True)
class DetrModelConfig:
    image_size: int = 32
    channels: int = 3
    patch: int = 4              # dc5 variant uses patch=2 -> 4x tokens
    d_model: int = 64
    d_ff: int = 128
    heads: int = 4
    enc_layers: int = 2
    dec_layers: int = 2
    num_queries: int = 8
    num_classes: int = 3        # + 1 implicit "no object" class

    @property
    def tokens(self) -> int:
        return (self.image_size // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels


def dc5_variant(cfg: DetrModelConfig) -> DetrModelConfig:
    """The +DC5 analog: finer patches -> 4x encoder tokens (2x per side)."""
    return DetrModelConfig(
        image_size=cfg.image_size,
        channels=cfg.channels,
        patch=cfg.patch // 2,
        d_model=cfg.d_model,
        d_ff=cfg.d_ff,
        heads=cfg.heads,
        enc_layers=cfg.enc_layers,
        dec_layers=cfg.dec_layers,
        num_queries=cfg.num_queries,
        num_classes=cfg.num_classes,
    )


def init_params(key, cfg: DetrModelConfig) -> common.Params:
    ks = jax.random.split(key, cfg.enc_layers + cfg.dec_layers + 5)
    return {
        "patch": common.dense_init(ks[0], cfg.patch_dim, cfg.d_model),
        "query": jax.random.normal(
            ks[1], (cfg.num_queries, cfg.d_model), jnp.float32
        )
        * 0.02,
        "enc": {
            str(i): common.block_init(ks[2 + i], cfg.d_model, cfg.d_ff)
            for i in range(cfg.enc_layers)
        },
        "dec": {
            str(i): common.block_init(
                ks[2 + cfg.enc_layers + i], cfg.d_model, cfg.d_ff, cross=True
            )
            for i in range(cfg.dec_layers)
        },
        "cls": common.dense_init(ks[-2], cfg.d_model, cfg.num_classes + 1),
        "box": common.dense_init(ks[-1], cfg.d_model, 4),
    }


def patchify(images: jnp.ndarray, cfg: DetrModelConfig) -> jnp.ndarray:
    """(b, H, W, C) -> (b, tokens, patch_dim)."""
    b, H, W, C = images.shape
    p = cfg.patch
    x = images.reshape(b, H // p, p, W // p, p, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, (H // p) * (W // p), p * p * C)


def forward(
    params,
    images: jnp.ndarray,
    cfg: DetrModelConfig,
    softmax_mode: str = "exact",
    prec: str = "uint8",
    quantized: bool = False,
    stats: list | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (class_logits (b, Q, C+1), boxes (b, Q, 4) in [0,1])."""
    x = common.dense(params["patch"], patchify(images, cfg), quantized)
    x = x + common.sinusoidal_positions(x.shape[1], cfg.d_model)
    for i in range(cfg.enc_layers):
        x = common.encoder_block(
            params["enc"][str(i)], x, cfg.heads, None, softmax_mode, prec, quantized, stats
        )
    q = jnp.broadcast_to(
        params["query"][None], (images.shape[0], cfg.num_queries, cfg.d_model)
    )
    for i in range(cfg.dec_layers):
        q = common.decoder_block(
            params["dec"][str(i)],
            q,
            x,
            cfg.heads,
            None,
            None,
            softmax_mode,
            prec,
            quantized,
            stats,
        )
    cls_logits = common.dense(params["cls"], q, quantized)
    boxes = jax.nn.sigmoid(common.dense(params["box"], q, quantized))
    return cls_logits, boxes


# ---------------------------------------------------------------------------
# Hungarian-matched set loss (DETR's bipartite matching, scipy assignment)


def _pairwise_cost(
    cls_logits: np.ndarray, boxes: np.ndarray, gt: np.ndarray
) -> np.ndarray:
    """Matching cost between Q predictions and n ground-truth objects:
    -p(class) + L1(box), DETR's matching cost without GIoU for simplicity."""
    probs = np.asarray(jax.nn.softmax(jnp.asarray(cls_logits), -1))
    classes = gt[:, 0].astype(int)
    cost_cls = -probs[:, classes]                       # (Q, n)
    l1 = np.abs(boxes[:, None, :] - gt[None, :, 1:5]).sum(-1)
    return cost_cls + l1


def match(cls_logits, boxes, gts) -> list[tuple[np.ndarray, np.ndarray]]:
    """Per-image Hungarian assignment (query indices, gt indices)."""
    out = []
    for b, gt in enumerate(gts):
        cost = _pairwise_cost(
            np.asarray(cls_logits[b]), np.asarray(boxes[b]), np.asarray(gt)
        )
        qi, gi = linear_sum_assignment(cost)
        out.append((qi, gi))
    return out


def build_targets(
    assignments: list[tuple[np.ndarray, np.ndarray]],
    gts: list[np.ndarray],
    batch: int,
    cfg: DetrModelConfig,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Hungarian assignments -> dense (target_cls, target_box, box_mask)."""
    target_cls = np.full((batch, cfg.num_queries), cfg.num_classes, np.int32)
    target_box = np.zeros((batch, cfg.num_queries, 4), np.float32)
    box_mask = np.zeros((batch, cfg.num_queries), np.float32)
    for i, (qi, gi) in enumerate(assignments):
        gt = np.asarray(gts[i])
        target_cls[i, qi] = gt[gi, 0].astype(np.int32)
        target_box[i, qi] = gt[gi, 1:5]
        box_mask[i, qi] = 1.0
    return target_cls, target_box, box_mask


def loss_from_targets(
    params,
    images,
    target_cls,
    target_box,
    box_mask,
    cfg: DetrModelConfig,
    no_obj_weight: float = 0.2,
):
    """Set loss given dense matched targets — fully jittable (the Hungarian
    assignment stays outside the gradient, as in the original DETR)."""
    cls_logits, boxes = forward(params, images, cfg)
    logp = jax.nn.log_softmax(cls_logits, -1)
    nll = -jnp.take_along_axis(logp, target_cls[..., None], -1)[..., 0]
    w = jnp.where(target_cls == cfg.num_classes, no_obj_weight, 1.0)
    cls_loss = jnp.sum(nll * w) / jnp.sum(w)

    l1 = jnp.sum(jnp.abs(boxes - target_box), -1)
    box_loss = jnp.sum(l1 * box_mask) / jnp.maximum(jnp.sum(box_mask), 1.0)
    return cls_loss + 2.0 * box_loss


def loss_fn(params, images, gts, cfg: DetrModelConfig, no_obj_weight=0.2):
    """Convenience single-call set loss (used by tests; train.py uses the
    split forward/match/loss_from_targets path to stay jit-cached)."""
    cls_logits, boxes = forward(params, images, cfg)
    assignments = match(
        jax.lax.stop_gradient(cls_logits), jax.lax.stop_gradient(boxes), gts
    )
    tc, tb, bm = build_targets(assignments, gts, images.shape[0], cfg)
    return loss_from_targets(
        params, images, jnp.asarray(tc), jnp.asarray(tb), jnp.asarray(bm), cfg,
        no_obj_weight,
    )
