"""L2: pure-JAX model zoo (no flax/optax in this environment).

Models are parameter-pytree functions; every attention goes through
`kernels.attention.attention_ref` semantics with a pluggable softmax mode,
so post-training softmax substitution (the paper's experiment) is a pure
config change on the inference graph.
"""

from . import bert, common, detr, nmt

__all__ = ["bert", "common", "detr", "nmt"]
