"""bert_lite: encoder-only classifier (the paper's BERT/GLUE analog).

Encoder stack + CLS-position head, used for both the SST-2-analog
(sentiment) and MRPC-analog (pair equivalence) tasks; the two tasks share
the architecture and differ only in trained weights, like fine-tuned BERT.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import data
from . import common


@dataclass(frozen=True)
class BertModelConfig:
    vocab: int = 64
    d_model: int = 64
    d_ff: int = 128
    heads: int = 4
    layers: int = 2
    max_len: int = 24
    num_classes: int = 2


def init_params(key, cfg: BertModelConfig) -> common.Params:
    ks = jax.random.split(key, cfg.layers + 3)
    return {
        "embed": common.embedding_init(ks[0], cfg.vocab, cfg.d_model),
        "enc": {
            str(i): common.block_init(ks[1 + i], cfg.d_model, cfg.d_ff)
            for i in range(cfg.layers)
        },
        "pool": common.dense_init(ks[-2], cfg.d_model, cfg.d_model),
        "head": common.dense_init(ks[-1], cfg.d_model, cfg.num_classes),
    }


def forward(
    params,
    tokens: jnp.ndarray,
    cfg: BertModelConfig,
    softmax_mode: str = "exact",
    prec: str = "uint8",
    quantized: bool = False,
    stats: list | None = None,
) -> jnp.ndarray:
    """(batch, max_len) tokens -> (batch, num_classes) logits."""
    mask = common.padding_mask(tokens)
    x = params["embed"][tokens] + common.sinusoidal_positions(
        tokens.shape[1], cfg.d_model
    )
    for i in range(cfg.layers):
        x = common.encoder_block(
            params["enc"][str(i)], x, cfg.heads, mask, softmax_mode, prec, quantized, stats
        )
    cls = jnp.tanh(common.dense(params["pool"], x[:, 0], quantized))
    return common.dense(params["head"], cls, quantized)


def loss_fn(params, tokens, labels, cfg: BertModelConfig) -> jnp.ndarray:
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))


def accuracy(params, tokens, labels, cfg: BertModelConfig, **kw) -> float:
    pred = jnp.argmax(forward(params, tokens, cfg, **kw), -1)
    return float(jnp.mean((pred == labels).astype(jnp.float32)))


_ = data  # re-exported conventions (PAD etc.) used by callers
