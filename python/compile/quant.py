"""PTQ-D: dynamic post-training quantization simulation (Appendix A.3).

Mirrors PyTorch's ``torch.quantization.quantize_dynamic`` defaults used by
the paper: linear-layer weights stored as per-tensor affine **qint8**, and
activations quantized dynamically per tensor at matmul time. The matmul is
computed in the integer domain and dequantized with the product of the two
scales, reproducing the accuracy characteristics (Table 4) without needing
actual int8 BLAS.

All other ops (layernorm, residuals, softmax inputs) stay fp32, exactly as
in the paper's PTQ-D setup — the LUT softmax approximation is then layered
on top of this quantized model.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "quantize_weight",
    "fake_quant_array",
    "quantize_params",
    "qdense",
    "model_size_bytes",
]

QMIN, QMAX = -128, 127


def _affine_params(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor affine (scale, zero_point) covering [min, max] — the
    torch.per_tensor_affine scheme."""
    lo = jnp.minimum(jnp.min(x), 0.0)
    hi = jnp.maximum(jnp.max(x), 0.0)
    scale = jnp.maximum((hi - lo) / (QMAX - QMIN), 1e-12)
    zp = jnp.clip(jnp.round(QMIN - lo / scale), QMIN, QMAX)
    return scale, zp


def quantize_weight(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """fp32 weight -> (int8 values, scale, zero_point)."""
    scale, zp = _affine_params(w)
    q = jnp.clip(jnp.round(w / scale) + zp, QMIN, QMAX).astype(jnp.int8)
    return q, scale, zp


def _dynamic_quant(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    scale, zp = _affine_params(x)
    q = jnp.clip(jnp.round(x / scale) + zp, QMIN, QMAX).astype(jnp.int8)
    return q, scale, zp


def qdense(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Dynamically-quantized dense layer: int8 x @ int8 w, fp32 dequant.

    Equivalent to torch dynamic quantization of nn.Linear: weights are
    quantized once (here: on the fly from the fp32 master copy — bit-wise
    the same result), activations per call.
    """
    wq, ws, wz = quantize_weight(p["w"])
    xq, xs, xz = _dynamic_quant(x)
    # integer matmul in int32, then affine dequant:
    #   y = xs*ws * (xq - xz) @ (wq - wz)
    acc = (xq.astype(jnp.int32) - xz.astype(jnp.int32)) @ (
        wq.astype(jnp.int32) - wz.astype(jnp.int32)
    )
    return acc.astype(jnp.float32) * (xs * ws) + p["b"]


def fake_quant_array(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize-dequantize round trip (int8 per-tensor affine).

    Used two ways: offline on weights (`quantize_params`) and *inside the
    lowered graph* on activations (`models.common.dense` with
    quantized=True) — together they reproduce torch dynamic quantization's
    numerics in a weights-as-operands graph.
    """
    scale, zp = _affine_params(x)
    q = jnp.clip(jnp.round(x / scale) + zp, QMIN, QMAX)
    return (q - zp) * scale


def quantize_params(params: dict) -> dict:
    """PTQ-D weight pass: fake-quantize every dense kernel ('w' leaf).

    Returns a new pytree; embeddings/layernorms stay fp32 (torch dynamic
    quantization only touches nn.Linear).
    """
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "w" and not isinstance(v, dict):
                    out[k] = fake_quant_array(v)
                else:
                    out[k] = walk(v)
            return out
        return node

    return walk(params)


def model_size_bytes(params: dict, quantized: bool) -> int:
    """Storage bytes of a parameter pytree for Table 4's size-ratio column.

    Dense kernels ('w' leaves) count 1 B/element when quantized (+ 8 B of
    scale/zero-point per tensor); everything else stays fp32 (4 B).
    """
    from .models import common

    total = 0
    for key, leaf in common.flatten(params).items():
        n = int(leaf.size)
        if quantized and key.endswith("/w"):
            total += n + 8
        else:
            total += 4 * n
    return total
