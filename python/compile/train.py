"""Build-time training of the three lite models on the synthetic tasks.

Runs as part of `make artifacts` (via aot.py). Each model trains for a few
hundred Adam steps on CPU (seconds-to-minutes at these sizes), logs its
loss curve to artifacts/train_log_<name>.json and saves a .npz checkpoint.
Training always uses exact fp32 softmax; LUT substitution is strictly
post-training, as in the paper.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .models import bert, common, detr, nmt

__all__ = ["train_nmt", "train_bert", "train_detr", "train_all", "CKPT_NAMES"]

CKPT_NAMES = ("nmt14", "nmt17", "sst2", "mrpc", "detr", "detr_dc5")


def _cosine_lr(base: float, step: int, total: int) -> float:
    """Cosine decay to 10% of the base rate (simple, optimizer-agnostic)."""
    import math

    frac = min(step / max(total, 1), 1.0)
    return base * (0.1 + 0.9 * 0.5 * (1.0 + math.cos(math.pi * frac)))


def _log(out_dir: str, name: str, losses: list[float], seconds: float) -> None:
    path = os.path.join(out_dir, f"train_log_{name}.json")
    with open(path, "w") as f:
        json.dump(
            {
                "model": name,
                "steps": len(losses),
                "seconds": round(seconds, 2),
                "loss": [round(float(x), 5) for x in losses],
            },
            f,
        )
    print(
        f"[train] {name}: {len(losses)} steps in {seconds:.1f}s "
        f"loss {losses[0]:.3f} -> {losses[-1]:.3f}"
    )


def train_nmt(
    out_dir: str, corpus_seed: int, steps: int = 400, batch: int = 48, seed: int = 0
) -> str:
    name = f"nmt{corpus_seed}"
    dcfg = data.NmtConfig(corpus_seed=corpus_seed)
    mcfg = nmt.NmtModelConfig(vocab=dcfg.vocab, max_src=dcfg.max_len, max_tgt=dcfg.max_len + 1)
    params = nmt.init_params(jax.random.PRNGKey(seed), mcfg)
    opt = common.adam_init(params)

    @jax.jit
    def step(params, opt, src, tgt, lr):
        loss, grads = jax.value_and_grad(nmt.loss_fn)(params, src, tgt, mcfg)
        params, opt = common.adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for i in range(steps):
        src, tgt = data.nmt_batch(dcfg, batch, seed=i)
        params, opt, loss = step(
            params, opt, jnp.asarray(src), jnp.asarray(tgt), _cosine_lr(2e-3, i, steps)
        )
        losses.append(float(loss))
    _log(out_dir, name, losses, time.time() - t0)
    path = os.path.join(out_dir, "ckpt", f"{name}.npz")
    common.save_params(path, params)
    return path


def train_bert(
    out_dir: str, task: str, steps: int = 400, batch: int = 64, seed: int = 0
) -> str:
    assert task in ("sst2", "mrpc")
    mcfg = bert.BertModelConfig()
    params = bert.init_params(jax.random.PRNGKey(seed + 1), mcfg)
    opt = common.adam_init(params)

    def make_batch(i: int):
        if task == "sst2":
            return data.sentiment_batch(data.SentimentConfig(), batch, seed=i)
        return data.mrpc_batch(data.MrpcConfig(), batch, seed=i)

    @jax.jit
    def step(params, opt, toks, labels, lr):
        loss, grads = jax.value_and_grad(bert.loss_fn)(params, toks, labels, mcfg)
        params, opt = common.adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for i in range(steps):
        toks, labels = make_batch(i)
        params, opt, loss = step(
            params, opt, jnp.asarray(toks), jnp.asarray(labels), _cosine_lr(2e-3, i, steps)
        )
        losses.append(float(loss))
    _log(out_dir, task, losses, time.time() - t0)
    path = os.path.join(out_dir, "ckpt", f"{task}.npz")
    common.save_params(path, params)
    return path


def train_detr(
    out_dir: str, dc5: bool, steps: int = 300, batch: int = 16, seed: int = 0
) -> str:
    name = "detr_dc5" if dc5 else "detr"
    scfg = data.SceneConfig()
    mcfg = detr.DetrModelConfig(image_size=scfg.image_size)
    if dc5:
        mcfg = detr.dc5_variant(mcfg)
    params = detr.init_params(jax.random.PRNGKey(seed + 2), mcfg)
    opt = common.adam_init(params)

    # Hungarian matching runs in numpy between two jitted stages so the
    # expensive forward/backward stays compile-cached across steps.
    fwd = jax.jit(lambda p, im: detr.forward(p, im, mcfg))

    @jax.jit
    def step(params, opt, imgs, tc, tb, bm, lr):
        loss, grads = jax.value_and_grad(detr.loss_from_targets)(
            params, imgs, tc, tb, bm, mcfg
        )
        params, opt = common.adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for i in range(steps):
        imgs, gts = data.scene_batch(scfg, batch, seed=i)
        imgs_j = jnp.asarray(imgs)
        cls_logits, boxes = fwd(params, imgs_j)
        assignments = detr.match(cls_logits, boxes, gts)
        tc, tb, bm = detr.build_targets(assignments, gts, batch, mcfg)
        params, opt, loss = step(
            params, opt, imgs_j, jnp.asarray(tc), jnp.asarray(tb), jnp.asarray(bm),
            _cosine_lr(1e-3, i, steps),
        )
        losses.append(float(loss))
    _log(out_dir, name, losses, time.time() - t0)
    path = os.path.join(out_dir, "ckpt", f"{name}.npz")
    common.save_params(path, params)
    return path


def train_all(out_dir: str, quick: bool = False) -> dict[str, str]:
    """Train every lite model (skipping ones whose checkpoint exists)."""
    os.makedirs(os.path.join(out_dir, "ckpt"), exist_ok=True)
    k = 0.25 if quick else 1.0
    paths = {}
    jobs = {
        "nmt14": lambda: train_nmt(out_dir, 14, steps=int(3000 * k)),
        "nmt17": lambda: train_nmt(out_dir, 17, steps=int(3000 * k)),
        "sst2": lambda: train_bert(out_dir, "sst2", steps=int(2500 * k)),
        "mrpc": lambda: train_bert(out_dir, "mrpc", steps=int(4000 * k)),
        "detr": lambda: train_detr(out_dir, dc5=False, steps=int(1500 * k)),
        "detr_dc5": lambda: train_detr(out_dir, dc5=True, steps=int(900 * k)),
    }
    for name, job in jobs.items():
        ckpt = os.path.join(out_dir, "ckpt", f"{name}.npz")
        if os.path.exists(ckpt):
            print(f"[train] {name}: checkpoint exists, skipping")
            paths[name] = ckpt
        else:
            paths[name] = job()
    return paths


_ = np  # imported for side-typing clarity in annotations
