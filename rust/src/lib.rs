//! # lutmax — LUT-based division-free softmax for attention DNNs
//!
//! Three-layer reproduction of Vasyltsov & Chang, *Efficient Softmax
//! Approximation for Deep Neural Networks with Attention Mechanism* (2021):
//!
//! * **L1** (build-time python): Pallas kernels for the REXP (§4.1) and
//!   2D-LUT (§4.2) approximations (`python/compile/kernels/`).
//! * **L2** (build-time python): JAX models (nmt/bert/detr lite) whose
//!   attention routes through the L1 kernels; AOT-lowered to HLO text.
//! * **L3** (this crate): the serving coordinator, PJRT runtime, LUT and
//!   quantization substrates, bit-exact software models of the paper's
//!   hardware datapath, a cycle/area/energy hardware simulator, and the
//!   experiment/benchmark harness that regenerates every table and figure
//!   of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binaries are self-contained.
//!
//! Module map (see DESIGN.md for the per-experiment index):
//!
//! | module        | role |
//! |---------------|------|
//! | [`lut`]       | LUT builders, bit-identical to `python/compile/kernels/luts.py` |
//! | [`quant`]     | integer quantization helpers (PTQ-D int8 affine) |
//! | [`softmax`]   | bit-exact SW models of the LUT datapaths + baselines (f32 and i8 ingestion) |
//! | [`attention`] | fused integer-native `QK^T → LUT softmax → ×V` kernel + streaming decode |
//! | [`kv`]        | paged integer KV cache (arena + free-list + grouped heads) |
//! | [`faults`]    | deterministic fault injection (seeded plans, replayable chaos) |
//! | [`obs`]       | trace spans, metrics registry, LUT range telemetry (zero-cost off) |
//! | [`hwsim`]     | cycle/area/energy simulator of softmax HW designs |
//! | [`runtime`]   | PJRT client: load + execute `artifacts/*.hlo.txt` |
//! | [`eval`]      | BLEU / accuracy / F1 / Hungarian-matched AP metrics |
//! | [`workload`]  | synthetic request & scene generators (load tests) |
//! | [`coordinator`] | request router, dynamic batcher, server loop |
//! | [`config`]    | hand-rolled JSON + CLI (serde/clap are offline-unavailable) |
//! | [`testkit`]   | seeded PRNG + property-test helpers (proptest substitute) |
//! | [`benchkit`]  | micro-benchmark harness (criterion substitute) |

pub mod attention;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod faults;
pub mod hwsim;
pub mod kv;
pub mod lut;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod softmax;
pub mod testkit;
pub mod workload;

/// Default artifacts directory, overridable with `LUTMAX_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("LUTMAX_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
