//! Streaming decode through the fused LUT-softmax attention kernel.
//!
//! Autoregressive decode is the workload where the paper's softmax sits
//! on the critical path of every generated token: one query row per step,
//! attending over the whole stored prefix. [`DecodeAttention`] drives the
//! *same* integer substrate as [`super::FusedAttention`]'s prefill sweep
//! — the identical score algebra (zero points hoisted out of the `i8`
//! dot, per-key byte sums read from page metadata), the identical
//! single-row LUT softmax ([`FusedAttention::sig_row`]), and the identical
//! `sig_int × V` integer MAC — so a T-step decode is **bit-identical** to
//! a length-T causal prefill (property-tested in
//! `rust/tests/integration_decode.rs`). An f32 probability row is never
//! materialized; K/V are gathered straight out of the paged `i8` arena
//! ([`crate::kv::KvPool`]).
//!
//! Grouped-query heads: the sequence's [`crate::kv::HeadGroups`] maps
//! each query head onto its stored K/V head, so one page block serves
//! `H/G` query heads.
//!
//! # Read-traffic contract (group-major sweep)
//!
//! Decode is memory-bound — the LUT softmax made the arithmetic cheap,
//! so the per-token cost is the sweep over the stored i8 prefix. The hot
//! path's unit of work is therefore one KV **group**, not one query
//! head ([`SweepOrder::GroupMajor`], the default): a group task walks
//! its pages exactly once per step ([`KvPool::page_blocks`]), computing
//! the score rows of all `H/G` query heads sharing the group against
//! each resident K block, runs the per-head LUT softmax
//! (`sig_row`, unchanged algebra, one row per head), then one V sweep
//! producing all `H/G` output rows per V read. **K/V are read once per
//! group per step** — read amplification is `G`-proportional, matching
//! the storage saving. The head-major order
//! ([`SweepOrder::HeadMajor`], which re-gathers the group's pages once
//! per *query* head, `H/G×` the traffic) is kept as the conformance
//! reference and bench baseline: the two sweeps are a pure reorder of
//! reads over identical integer expressions, **bit-identical** across
//! the whole conformance sweep (`integration_conformance.rs`).
//!
//! # Prefix-split partial softmax ([`DecodeAttention::step_split`])
//!
//! A bare group-major step has exactly G sweep units, so long-context
//! decode at small G (MQA: G = 1) is G-bounded. `step_split` splits the
//! paged prefix into page-aligned spans swept independently, each
//! producing integer-domain partials per query row — the span's score
//! maximum `m_p`, a histogram of LUT addresses taken against `m_p`
//! (the same `pass1` mapping the unsplit row runs), and per-address V
//! sums — merged by a LUT-exact reduction
//! ([`FusedAttention::merge_span_row`]): span `p`'s histogram shifts by
//! `Δ_p = map.index(m_global − m_p)`, the fixed-point image of
//! rescaling the span's partial sums by `sig(m_global − m_p)` through
//! the existing [`IntMap`] path, then one global normalizer and one
//! per-address `sig × ΣV` MAC finish the row.
//!
//! **Merge exactness**: when every `m_global − m_p` lands on a
//! LUT-index boundary (`IntMap::shift_is_exact` — always true for one
//! span, zero diffs, and unit maps), truncation distributes over the
//! address sum and saturation composes, so the shifted addresses equal
//! the unsplit ones element-for-element and the merged output is
//! **bit-identical** to the unsplit sweep. Otherwise every shifted
//! address sits at most one LUT index below the unsplit one and the
//! per-element output deviation is **provably bounded** by the computed
//! [`SplitReport::bound`] (adjacent-address × normalizer-interval
//! discrepancy — never an assumed epsilon). Conformance invariant 9
//! asserts both halves across the {mode, prec, G, page_size, sessions,
//! faults, spans} axes.
//!
//! Per step, the sweep units (G group tasks, or H head rows head-major)
//! either run inline (short prefixes — a pool wake costs more than the
//! work) or scatter over a [`ParSoftmax`] pool as one task batch
//! ([`DecodeAttention::step_par`], `==`-exact with the sequential
//! sweep).
//!
//! Prompt ingestion goes through [`DecodeAttention::prefill_chunk`]:
//! append a block of `T'` tokens, attend once — bit-identical to `T'`
//! single steps. Concurrent sessions' steps batch into ONE scatter wave
//! through [`super::DecodeBatch`] (`attention/batch.rs`).

use std::sync::Mutex;

use anyhow::Result;

use super::batch::WaveError;
use super::kernel::{wave_stays_inline, AttnScratch, FusedAttention, OutPtr};
use crate::kv::{KvError, KvPool, KvSeq};
use crate::lut::Precision;
use crate::quant::Affine;
use crate::softmax::{lock_unpoisoned, pass1_scores_mapped, IntMap, Mode, ParSoftmax, Scratch};

/// Ingress quantization of the decode serving route: a fixed dyadic
/// affine (2^-4 per step, range ±8) sized for normalized activations —
/// the paper's operating point, and the premise that makes the LUT
/// softmax hold up. Fixed (rather than fitted per step) so every page of
/// a session shares one affine — the per-page quantization contract of
/// [`crate::kv`] — and decode replies are deterministic functions of the
/// inputs.
pub const DECODE_AFFINE: Affine = Affine { scale: 0.0625, zero_point: 0 };

/// Everything a step's head sweep needs that is constant across heads:
/// the score-unit LUT map and the fused output dequant, mirroring
/// `FusedAttention::plan` expression for expression (bit-exactness with
/// prefill depends on it). Shared with the batched-wave layer
/// ([`super::DecodeBatch`]), which computes one plan per session.
#[derive(Clone, Copy)]
pub(super) struct StepPlan {
    map: IntMap,
    out_scale: f32,
    zq: i32,
    zk: i32,
    zv: i32,
}

/// Order in which a decode sweep walks the paged prefix. Outputs are
/// **bit-identical** across orders (a pure reorder of reads over the
/// same integer expressions — pinned by the conformance harness); the
/// difference is K/V read traffic per step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SweepOrder {
    /// One sweep unit per KV group: pages read **once per group per
    /// step**, each read serving all `H/G` query heads of the group.
    /// The product path.
    #[default]
    GroupMajor,
    /// One sweep unit per query head: every head re-gathers its group's
    /// pages, reading each K/V byte `H/G` times per step. Kept as the
    /// conformance reference and the `decode/*` bench baseline.
    HeadMajor,
}

/// What a prefix-split sweep ([`DecodeAttention::step_split`]) did: the
/// effective span count and the merge-exactness outcome (module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SplitReport {
    /// effective span count after clamping the request to
    /// `[1, resident pages]`
    pub spans: usize,
    /// every merged row's span maxima were LUT-index-aligned — the
    /// outputs are bit-identical to the unsplit sweep (always true when
    /// `spans == 1`)
    pub aligned: bool,
    /// when not `aligned`: a per-output-element bound on
    /// `|split − unsplit|`, the integer merge bound finalized through
    /// `out_scale` plus conservative f32-cast slack; `0.0` when aligned
    pub bound: f32,
}

/// Serving policy for the prefix-split sweep: how many spans a step over
/// a `valid`-token prefix splits into under the scheduler's
/// `split_min_tokens` knob. `0` disables splitting (the serving default
/// — replies are then unconditionally bit-identical to the unsplit
/// sweep); otherwise roughly one span per `split_min_tokens` tokens,
/// clamped to one span per resident page, so short prefixes stay
/// unsplit and long ones fan out.
pub fn spans_for(valid: usize, page_size: usize, split_min_tokens: usize) -> usize {
    if split_min_tokens == 0 {
        return 1;
    }
    let npages = valid.div_ceil(page_size).max(1);
    (valid / split_min_tokens).clamp(1, npages)
}

/// Page range of span `p` of `spans` near-even contiguous spans over
/// `npages` resident pages. With `1 ≤ spans ≤ npages` every span is
/// non-empty and the spans partition `0..npages` in order, so the page
/// walks of all spans concatenate to the unsplit walk
/// ([`KvPool::page_blocks_range`]).
pub(super) fn span_page_range(npages: usize, spans: usize, p: usize) -> std::ops::Range<usize> {
    debug_assert!(spans >= 1 && spans <= npages.max(1) && p < spans);
    p * npages / spans..(p + 1) * npages / spans
}

/// Per-step decode attention over a paged KV cache. Construct once per
/// (mode, precision, alpha) route; [`DecodeAttention::step`] /
/// [`DecodeAttention::step_par`] per generated token.
pub struct DecodeAttention {
    kernel: FusedAttention,
    order: SweepOrder,
    /// per-worker scratch instances for the scattered path, persisted
    /// across steps: decode runs once per generated token, so a fresh
    /// scratch per call would put heap allocation on exactly the per-step
    /// hot path the paged KV arena is built to avoid (shared with the
    /// batched-wave layer in `attention/batch.rs`)
    pub(super) spare: Mutex<Vec<AttnScratch>>,
}

impl DecodeAttention {
    /// Same mode/precision/alpha space as [`FusedAttention::new`] (LUT
    /// modes only). Sweeps group-major ([`SweepOrder::GroupMajor`]).
    pub fn new(mode: Mode, prec: Precision, alpha_len: Option<usize>) -> Result<Self> {
        Self::with_order(mode, prec, alpha_len, SweepOrder::default())
    }

    /// [`DecodeAttention::new`] with an explicit sweep order — the
    /// head-major order exists for the conformance differential and the
    /// `decode/*` (vs `decode_groupmajor/*`) bench baseline.
    pub fn with_order(
        mode: Mode,
        prec: Precision,
        alpha_len: Option<usize>,
        order: SweepOrder,
    ) -> Result<Self> {
        Ok(Self {
            kernel: FusedAttention::new(mode, prec, alpha_len)?,
            order,
            spare: Mutex::new(Vec::new()),
        })
    }

    /// The underlying fused kernel (mode/precision accessors).
    pub fn kernel(&self) -> &FusedAttention {
        &self.kernel
    }

    /// The sweep order this kernel walks the paged prefix in.
    pub fn order(&self) -> SweepOrder {
        self.order
    }

    pub(super) fn plan(&self, seq: &KvSeq, d_head: usize, q_affine: Affine) -> StepPlan {
        let step = (q_affine.scale as f64 * seq.k_affine().scale as f64
            / (d_head as f64).sqrt()) as f32;
        StepPlan {
            map: self.kernel.int_map(step),
            out_scale: seq.v_affine().scale * self.kernel.inv_qmax(),
            zq: q_affine.zero_point,
            zk: seq.k_affine().zero_point,
            zv: seq.v_affine().zero_point,
        }
    }

    /// One decode step, sequential over query heads: append the token's
    /// K/V rows (`G * d`, `[g][d]`, quantized with the sequence's
    /// affines), then attend `q` (`H * d`, `[h][d]`) over the whole
    /// stored prefix into `out` (`H * d` f32). On exhaustion nothing is
    /// appended and `out` is untouched — retry the same step after
    /// capacity frees up.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_row: &[i8],
        v_row: &[i8],
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), KvError> {
        kv.append(seq, k_row, v_row)?;
        let d = kv.config().d_head;
        let h = seq.groups().q_heads();
        check_step_shapes(q, out, h, d);
        let plan = self.plan(seq, d, q_affine);
        self.sweep_step(kv, seq, q, plan, out, scr);
        Ok(())
    }

    /// The sequential sweep of one already-appended step, in the
    /// kernel's [`SweepOrder`]. Shared by [`DecodeAttention::step`] and
    /// the inline arm of [`DecodeAttention::step_par`].
    fn sweep_step(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        q: &[i8],
        plan: StepPlan,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let d = kv.config().d_head;
        match self.order {
            SweepOrder::HeadMajor => {
                for (hh, oh) in out.chunks_exact_mut(d).enumerate() {
                    self.head_step(kv, seq, hh, &q[hh * d..(hh + 1) * d], plan, oh, scr);
                }
            }
            SweepOrder::GroupMajor => {
                let r = seq.groups().group_size();
                for (gi, og) in out.chunks_exact_mut(r * d).enumerate() {
                    self.group_step(kv, seq, gi, &q[gi * r * d..(gi * r + r) * d], plan, og, scr);
                }
            }
        }
    }

    /// [`DecodeAttention::step`] with the sweep units (G group tasks, or
    /// H head rows head-major) scattered across a [`ParSoftmax`] pool as
    /// one task batch (bit-identical — units are independent and write
    /// disjoint output blocks). Steps run inline on `scr` under the
    /// shared wave accounting (`wave_stays_inline`: whole-step MACs +
    /// MAC-weighted row equivalents, so a 2-group step with heavy heads
    /// still fans out) — the same whole-submission accounting the
    /// batched wave ([`super::DecodeBatch`]) and the scattered prefill
    /// use, so a 1-task wave and a bare `step_par` make the identical
    /// inline-vs-pool decision.
    ///
    /// **Parallelism trade**: a bare group-major step has exactly G
    /// sweep units (a single query row per head), so G = 1 (MQA) always
    /// runs a bare step inline. The finer unit is the *prefix*:
    /// [`Self::step_split`] splits it into page-aligned spans with a
    /// LUT-exact partial-softmax merge (module docs), which is how the
    /// serving layer restores fan-out on long-context small-G steps
    /// (`DecodeBatch` waves become S×G×spans tasks past the scheduler's
    /// split threshold). A latency-critical small-G deployment that
    /// wants per-head fan-out on bare steps can still pin
    /// [`SweepOrder::HeadMajor`].
    ///
    /// **Failure domains**: the append can fail with
    /// [`WaveError::Kv`] (nothing written, retryable); a sweep unit
    /// panicking — injected or genuine — is contained by the pool and
    /// surfaces as [`WaveError::Panicked`] (the append already landed:
    /// state advanced, output lost, do NOT replay the step).
    #[allow(clippy::too_many_arguments)]
    pub fn step_par(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_row: &[i8],
        v_row: &[i8],
        pool: &ParSoftmax,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), WaveError> {
        kv.append(seq, k_row, v_row).map_err(WaveError::Kv)?;
        let d = kv.config().d_head;
        let h = seq.groups().q_heads();
        check_step_shapes(q, out, h, d);
        let plan = self.plan(seq, d, q_affine);
        let step_macs = h * seq.len() * d;
        let r = seq.groups().group_size();
        let units = match self.order {
            SweepOrder::HeadMajor => h,
            SweepOrder::GroupMajor => seq.groups().kv_heads(),
        };
        let spare = &self.spare;
        // SAFETY (OutPtr contract): sweep tasks reconstruct disjoint
        // blocks of `out` only (one `d` block per head, or one
        // contiguous `H/G · d` block per group).
        let optr = OutPtr(out.as_mut_ptr());
        let kv_ref: &KvPool = kv;
        let seq_ref: &KvSeq = seq;
        let order = self.order;
        // the caller's scratch is lent to the spare stack for the wave,
        // so the inline arm keeps its amortized buffers
        lock_unpoisoned(spare).push(std::mem::take(scr));
        let run = |u: usize, _s: &mut Scratch| {
            let mut hs = lock_unpoisoned(spare).pop().unwrap_or_default();
            match order {
                SweepOrder::HeadMajor => {
                    let oh = unsafe { std::slice::from_raw_parts_mut(optr.0.add(u * d), d) };
                    self.head_step(kv_ref, seq_ref, u, &q[u * d..(u + 1) * d], plan, oh, &mut hs);
                }
                SweepOrder::GroupMajor => {
                    let og =
                        unsafe { std::slice::from_raw_parts_mut(optr.0.add(u * r * d), r * d) };
                    self.group_step(
                        kv_ref,
                        seq_ref,
                        u,
                        &q[u * r * d..(u * r + r) * d],
                        plan,
                        og,
                        &mut hs,
                    );
                }
            }
            lock_unpoisoned(spare).push(hs);
        };
        let mut pool_scratch = Scratch::new();
        let outcome = if wave_stays_inline(pool, units, h, step_macs) {
            pool.scatter_inline(units, &mut pool_scratch, &run)
        } else {
            pool.scatter(units, &mut pool_scratch, &run)
        };
        if let Some(hs) = lock_unpoisoned(spare).pop() {
            *scr = hs;
        }
        if outcome.is_ok() {
            Ok(())
        } else {
            Err(WaveError::Panicked)
        }
    }

    /// Append a block of `T'` tokens to the paged cache and attend ONCE
    /// through the fused kernel — chunked prefill. Layouts are step-major:
    /// `q`/`out` are `T' * H * d` (`[t][h][d]`), `k_rows`/`v_rows` are
    /// `T' * G * d` (`[t][g][d]`), so row `t` of the output is exactly
    /// what the `t`-th single [`DecodeAttention::step`] would have
    /// produced.
    ///
    /// **Bit-identical to `T'` single steps by construction**: the block
    /// append lands token-for-token the same bytes/sums/page-table growth
    /// as `T'` appends ([`KvPool::append_block`]), and each chunk row
    /// attends over its own causal prefix (`base + t + 1`) with the same
    /// integer expressions ([`Self::head_prefix`]) a single step uses —
    /// the pages it reads were all written before any of them is read, so
    /// deferring the attention sweep changes nothing. Property-tested in
    /// `integration_decode_batch.rs` and swept by the conformance harness.
    ///
    /// **Atomic on exhaustion**: capacity for the whole chunk is reserved
    /// up front; on [`KvError::Exhausted`] neither the cache nor `out` is
    /// touched and the same chunk can be retried after pages free up.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_chunk(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_rows: &[i8],
        v_rows: &[i8],
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), KvError> {
        let Some((t_chunk, base)) = prefill_ingest(kv, seq, q, k_rows, v_rows, out)? else {
            return Ok(());
        };
        let plan = self.plan(seq, kv.config().d_head, q_affine);
        self.sweep_prefill(kv, seq, q, plan, base, t_chunk, out, scr);
        Ok(())
    }

    /// The sequential sweep of an already-appended chunk, in the
    /// kernel's [`SweepOrder`]. Shared by
    /// [`DecodeAttention::prefill_chunk`] and the inline arm of
    /// [`DecodeAttention::prefill_chunk_par`] (the prefill mirror of
    /// [`Self::sweep_step`]).
    #[allow(clippy::too_many_arguments)]
    fn sweep_prefill(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        q: &[i8],
        plan: StepPlan,
        base: usize,
        t_chunk: usize,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        match self.order {
            // head-major reference order: one head streams the page
            // blocks for all T' of its query rows (each page read H/G
            // times per row set)
            SweepOrder::HeadMajor => {
                for hh in 0..seq.groups().q_heads() {
                    self.prefill_head_rows(kv, seq, hh, q, plan, base, t_chunk, out, scr);
                }
            }
            // group-major: one group sweeps its pages once per chunk row
            // for ALL its H/G heads
            SweepOrder::GroupMajor => {
                for gi in 0..seq.groups().kv_heads() {
                    self.prefill_group_rows(kv, seq, gi, q, plan, base, t_chunk, out, scr);
                }
            }
        }
    }

    /// [`DecodeAttention::prefill_chunk`] with the sweep units (G group
    /// sweeps, or H head sweeps head-major) scattered across a
    /// [`ParSoftmax`] pool (bit-identical — each task writes its own
    /// disjoint `(t, head)` output blocks). A prompt chunk is the most
    /// parallelizable payload the decode route serves (`T' × H`
    /// independent rows), so the serving pipeline routes prefills here;
    /// small chunks stay inline under the same wave accounting as step
    /// waves.
    ///
    /// **Failure domains**: as for [`Self::step_par`] — a failed ingest
    /// is [`WaveError::Kv`] (atomic, retryable); a panicking sweep unit
    /// is contained and surfaces as [`WaveError::Panicked`] (the chunk's
    /// tokens are already appended: state advanced, output lost, do NOT
    /// replay the chunk).
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_chunk_par(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_rows: &[i8],
        v_rows: &[i8],
        pool: &ParSoftmax,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), WaveError> {
        let ingest = prefill_ingest(kv, seq, q, k_rows, v_rows, out).map_err(WaveError::Kv)?;
        let Some((t_chunk, base)) = ingest else {
            return Ok(());
        };
        let (h, d) = (seq.groups().q_heads(), kv.config().d_head);
        let g = seq.groups().kv_heads();
        let plan = self.plan(seq, d, q_affine);
        // whole-chunk accounting: Σ_t h·(base+t+1)·d MACs over the wave,
        // t_chunk·h head rows
        let chunk_macs: usize = (0..t_chunk).map(|t| h * (base + t + 1) * d).sum();
        // group-major prefill scatters one task per (group, chunk row):
        // a chunk has T' independent row sweeps per group, so prefill
        // parallelism is G·T', NOT capped at G — each task still reads
        // its group's pages exactly once per row, the same traffic as
        // the sequential group-major sweep (single *steps* have one row
        // and are genuinely G-bounded; see `step_par`)
        let units = match self.order {
            SweepOrder::HeadMajor => h,
            SweepOrder::GroupMajor => g * t_chunk,
        };
        let spare = &self.spare;
        // SAFETY (OutPtr contract): sweep task `u` reconstructs only its
        // own disjoint `(t, head)` blocks of `out` — one `d` slice per
        // row head-major, one contiguous `H/G · d` slice for its single
        // (group, row) pair group-major; concurrent tasks never alias.
        let optr = OutPtr(out.as_mut_ptr());
        let kv_ref: &KvPool = kv;
        let seq_ref: &KvSeq = seq;
        let r = seq.groups().group_size();
        let order = self.order;
        // lend the caller's scratch to the spare stack for the wave
        lock_unpoisoned(spare).push(std::mem::take(scr));
        let run = |u: usize, _s: &mut Scratch| {
            let mut hs = lock_unpoisoned(spare).pop().unwrap_or_default();
            match order {
                SweepOrder::HeadMajor => {
                    for t in 0..t_chunk {
                        let qh = &q[(t * h + u) * d..(t * h + u + 1) * d];
                        let oh = unsafe {
                            std::slice::from_raw_parts_mut(optr.0.add((t * h + u) * d), d)
                        };
                        self.head_prefix(kv_ref, seq_ref, u, qh, plan, base + t + 1, oh, 0, &mut hs);
                    }
                }
                SweepOrder::GroupMajor => {
                    let (gi, t) = (u % g, u / g);
                    let qg = &q[(t * h + gi * r) * d..(t * h + gi * r + r) * d];
                    let og = unsafe {
                        std::slice::from_raw_parts_mut(optr.0.add((t * h + gi * r) * d), r * d)
                    };
                    self.group_prefix(kv_ref, seq_ref, gi, qg, plan, base + t + 1, og, 0, &mut hs);
                }
            }
            lock_unpoisoned(spare).push(hs);
        };
        let mut pool_scratch = Scratch::new();
        let outcome = if wave_stays_inline(pool, units, t_chunk * h, chunk_macs) {
            pool.scatter_inline(units, &mut pool_scratch, &run)
        } else {
            pool.scatter(units, &mut pool_scratch, &run)
        };
        if let Some(hs) = lock_unpoisoned(spare).pop() {
            *scr = hs;
        }
        if outcome.is_ok() {
            Ok(())
        } else {
            Err(WaveError::Panicked)
        }
    }

    /// One head's causal sweep over a freshly-appended chunk: rows
    /// `base..base+t_chunk`, each over its own prefix, writing the head's
    /// `(t, hh)` blocks of `out`.
    #[allow(clippy::too_many_arguments)]
    fn prefill_head_rows(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        hh: usize,
        q: &[i8],
        plan: StepPlan,
        base: usize,
        t_chunk: usize,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let (h, d) = (seq.groups().q_heads(), kv.config().d_head);
        for t in 0..t_chunk {
            let qh = &q[(t * h + hh) * d..(t * h + hh + 1) * d];
            self.head_prefix(kv, seq, hh, qh, plan, base + t + 1, out, (t * h + hh) * d, scr);
        }
    }

    /// One group's causal sweep over a freshly-appended chunk: for each
    /// row `base..base+t_chunk`, one group-major page sweep serves all
    /// `H/G` of the group's query heads, writing their `(t, head)`
    /// blocks of `out`.
    #[allow(clippy::too_many_arguments)]
    fn prefill_group_rows(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        gi: usize,
        q: &[i8],
        plan: StepPlan,
        base: usize,
        t_chunk: usize,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let (h, d) = (seq.groups().q_heads(), kv.config().d_head);
        let r = seq.groups().group_size();
        for t in 0..t_chunk {
            let qg = &q[(t * h + gi * r) * d..(t * h + gi * r + r) * d];
            self.group_prefix(kv, seq, gi, qg, plan, base + t + 1, out, (t * h + gi * r) * d, scr);
        }
    }

    /// One query head over the paged prefix — the decode mirror of the
    /// prefill kernel's per-row sweep, same integer expressions on the
    /// same values:
    ///
    ///   1. `q·K^T` as raw i8×i8 widening MACs per page block, zero
    ///      points hoisted (`Σk` read from the page's precomputed sums);
    ///   2./3. single-row integer LUT softmax (`sig_row`, shared);
    ///   4. `sig × V` gather across pages, i64 accumulators, one fused
    ///      dequant per output element.
    ///
    /// Attends over the whole stored prefix (`seq.len()`); the chunked
    /// prefill path uses [`Self::head_prefix`] directly with a shorter
    /// causal bound. `pub(super)` so the batched-wave layer
    /// (`attention/batch.rs`) drives the identical expressions.
    pub(super) fn head_step(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        h: usize,
        qh: &[i8],
        plan: StepPlan,
        oh: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let d = kv.config().d_head;
        let valid = seq.len();
        debug_assert_eq!(oh.len(), d);
        // route through the prefix sweep with a zero-offset output view
        self.head_prefix(kv, seq, h, qh, plan, valid, oh, 0, scr);
    }

    /// The shared per-head sweep over a causal prefix of `valid ≤
    /// seq.len()` tokens, writing `d_head` output elements at `out[off..]`.
    #[allow(clippy::too_many_arguments)]
    fn head_prefix(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        h: usize,
        qh: &[i8],
        plan: StepPlan,
        valid: usize,
        out: &mut [f32],
        off: usize,
        scr: &mut AttnScratch,
    ) {
        let cfg = kv.config();
        let (d, psize) = (cfg.d_head, cfg.page_size);
        let gi = seq.groups().group_of(h);
        debug_assert!(valid >= 1 && valid <= seq.len());
        scr.prepare_decode(valid, d, self.kernel.table().len());
        let qsum: i32 = qh.iter().map(|&v| v as i32).sum();
        let zqzk = d as i32 * plan.zq * plan.zk;
        // 1. integer q·K^T over the causal prefix (`valid` tokens; full
        // pages except the prefix tail — a chunked-prefill row stops
        // before the sequence's stored length)
        let mut j = 0usize;
        for (pi, &page) in seq.pages().iter().enumerate() {
            let in_page = valid.saturating_sub(pi * psize).min(psize);
            if in_page == 0 {
                break;
            }
            let kb = kv.page_k(page, gi);
            let ks = kv.page_ksum(page, gi);
            for t in 0..in_page {
                let kj = &kb[t * d..(t + 1) * d];
                let mut dot = 0i32;
                for (&a, &b) in qh.iter().zip(kj) {
                    dot += a as i32 * b as i32;
                }
                scr.scores[j] = dot - plan.zk * qsum - plan.zq * ks[t] + zqzk;
                j += 1;
            }
        }
        debug_assert_eq!(j, valid);
        // 2./3. single-row integer softmax -> sig_int
        let sig_sum = self.kernel.sig_row(valid, plan.map, scr);
        // 4. sig × V gather across pages (i32 products — sig ≤ qmax,
        // |v| ≤ 128 — accumulated in i64, as in the prefill kernel)
        scr.acc[..d].fill(0);
        let mut j = 0usize;
        for (pi, &page) in seq.pages().iter().enumerate() {
            let in_page = valid.saturating_sub(pi * psize).min(psize);
            if in_page == 0 {
                break;
            }
            let vb = kv.page_v(page, gi);
            for t in 0..in_page {
                let g = scr.sig[j];
                for (a, &v) in scr.acc[..d].iter_mut().zip(&vb[t * d..(t + 1) * d]) {
                    *a += (g * v as i32) as i64;
                }
                j += 1;
            }
        }
        let corr = plan.zv as i64 * sig_sum;
        for (o, &a) in out[off..off + d].iter_mut().zip(&scr.acc[..d]) {
            *o = (a - corr) as f32 * plan.out_scale;
        }
    }

    /// One KV group over the whole stored prefix: the group-major mirror
    /// of [`Self::head_step`], taking the group's `H/G` query rows
    /// (`qg`, `[r][d]` — the contiguous head block of group `gi`) and
    /// writing their `H/G · d` output block. `pub(super)` so the
    /// batched-wave layer (`attention/batch.rs`) drives the identical
    /// expressions.
    pub(super) fn group_step(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        gi: usize,
        qg: &[i8],
        plan: StepPlan,
        og: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let d = kv.config().d_head;
        let valid = seq.len();
        debug_assert_eq!(og.len(), seq.groups().group_size() * d);
        self.group_prefix(kv, seq, gi, qg, plan, valid, og, 0, scr);
    }

    /// The group-major sweep over a causal prefix of `valid ≤ seq.len()`
    /// tokens: each of the group's resident K blocks is read ONCE and
    /// dotted against all `H/G` query heads (score rows parked side by
    /// side in scratch, row `r` at offset `r · valid`), each head then
    /// runs the unchanged per-row LUT softmax ([`FusedAttention::
    /// sig_row_at`]), and one V sweep accumulates all `H/G` output rows
    /// per V read. Every integer expression is the one
    /// [`Self::head_prefix`] evaluates on the same values, in the same
    /// per-head order — the two sweeps differ only in *when* each page
    /// is read, so outputs are **bit-identical** (pinned by the
    /// conformance harness's group-vs-head axis). Pages are walked via
    /// [`KvPool::page_blocks`], which yields K, V, byte sums and affines
    /// in one page-table lookup.
    #[allow(clippy::too_many_arguments)]
    fn group_prefix(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        gi: usize,
        qg: &[i8],
        plan: StepPlan,
        valid: usize,
        out: &mut [f32],
        off: usize,
        scr: &mut AttnScratch,
    ) {
        let d = kv.config().d_head;
        let r = seq.groups().group_size();
        debug_assert_eq!(qg.len(), r * d);
        debug_assert!(valid >= 1 && valid <= seq.len());
        scr.prepare_decode_group(r, valid, d, self.kernel.table().len());
        for (rr, qh) in qg.chunks_exact(d).enumerate() {
            scr.qsum[rr] = qh.iter().map(|&v| v as i32).sum();
        }
        let zqzk = d as i32 * plan.zq * plan.zk;
        // 1. integer q·K^T, group-major: each resident K row is read
        // once and dotted against every query head of the group (same
        // score expression as `head_prefix`, reordered reads)
        let mut j = 0usize;
        for blk in kv.page_blocks(seq, gi, valid) {
            for t in 0..blk.len {
                let kj = &blk.k[t * d..(t + 1) * d];
                for (rr, qh) in qg.chunks_exact(d).enumerate() {
                    let mut dot = 0i32;
                    for (&a, &b) in qh.iter().zip(kj) {
                        dot += a as i32 * b as i32;
                    }
                    scr.scores[rr * valid + j] =
                        dot - plan.zk * scr.qsum[rr] - plan.zq * blk.ksum[t] + zqzk;
                }
                j += 1;
            }
        }
        debug_assert_eq!(j, valid);
        // 2./3. per-head single-row integer softmax -> sig_int rows
        for rr in 0..r {
            let s = self.kernel.sig_row_at(valid, plan.map, scr, rr * valid);
            scr.sig_sum[rr] = s;
        }
        // 4. one sig × V sweep: each resident V row is read once and
        // accumulated into every head's output accumulator (i32
        // products, i64 accumulation — as in `head_prefix`)
        scr.acc[..r * d].fill(0);
        let mut j = 0usize;
        for blk in kv.page_blocks(seq, gi, valid) {
            for t in 0..blk.len {
                let vrow = &blk.v[t * d..(t + 1) * d];
                for rr in 0..r {
                    let g = scr.sig[rr * valid + j];
                    for (a, &v) in scr.acc[rr * d..(rr + 1) * d].iter_mut().zip(vrow) {
                        *a += (g * v as i32) as i64;
                    }
                }
                j += 1;
            }
        }
        for rr in 0..r {
            let corr = plan.zv as i64 * scr.sig_sum[rr];
            for (o, &a) in out[off + rr * d..off + (rr + 1) * d]
                .iter_mut()
                .zip(&scr.acc[rr * d..(rr + 1) * d])
            {
                *o = (a - corr) as f32 * plan.out_scale;
            }
        }
    }

    /// One decode step through the prefix-split sweep (module docs):
    /// append the token, then sweep each group's prefix as `spans`
    /// page-aligned spans and merge the span partials per query row.
    /// Always group-major — the split is defined on the group sweep,
    /// which the conformance harness already pins bit-identical to the
    /// head-major reference.
    ///
    /// `spans` is a request, clamped to `[1, resident pages]` (pass
    /// `usize::MAX` for one span per page). `spans = 1` *is* the unsplit
    /// sweep. For `spans > 1` the output is bit-identical to
    /// [`DecodeAttention::step`] whenever [`SplitReport::aligned`], and
    /// within [`SplitReport::bound`] of it otherwise — conformance
    /// invariant 9 asserts both. On exhaustion nothing is appended and
    /// `out` is untouched.
    #[allow(clippy::too_many_arguments)]
    pub fn step_split(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_row: &[i8],
        v_row: &[i8],
        spans: usize,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<SplitReport, KvError> {
        kv.append(seq, k_row, v_row)?;
        let d = kv.config().d_head;
        let h = seq.groups().q_heads();
        check_step_shapes(q, out, h, d);
        let plan = self.plan(seq, d, q_affine);
        let valid = seq.len();
        let npages = valid.div_ceil(kv.config().page_size);
        let spans = spans.clamp(1, npages.max(1));
        let r = seq.groups().group_size();
        let mut report = SplitReport { spans, aligned: true, bound: 0.0 };
        for gi in 0..seq.groups().kv_heads() {
            let qg = &q[gi * r * d..(gi * r + r) * d];
            let og = &mut out[gi * r * d..(gi * r + r) * d];
            self.group_prefix_split(kv, seq, gi, qg, plan, valid, spans, og, 0, scr, &mut report);
        }
        Ok(report)
    }

    /// One group's prefix-split sweep: every span's partials
    /// ([`Self::group_prefix_span`]), then the per-row merge + dequant
    /// ([`Self::merge_group_row`]) into the group's output block. The
    /// serial mirror of the wave layer's S×G×spans scatter.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn group_prefix_split(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        gi: usize,
        qg: &[i8],
        plan: StepPlan,
        valid: usize,
        spans: usize,
        out: &mut [f32],
        off: usize,
        scr: &mut AttnScratch,
        report: &mut SplitReport,
    ) {
        let d = kv.config().d_head;
        let r = seq.groups().group_size();
        let t_len = self.kernel.table().len();
        scr.prepare_decode_split(r, valid, d, t_len, spans);
        // the span partials move out of the scratch for the sweep so the
        // span tasks can keep borrowing its score/address rows
        let mut span_m = std::mem::take(&mut scr.span_m);
        let mut span_cnt = std::mem::take(&mut scr.span_cnt);
        let mut span_vs = std::mem::take(&mut scr.span_vs);
        let npages = valid.div_ceil(kv.config().page_size);
        for p in 0..spans {
            self.group_prefix_span(
                kv,
                seq,
                gi,
                qg,
                plan,
                valid,
                span_page_range(npages, spans, p),
                &mut span_m[p * r..(p + 1) * r],
                &mut span_cnt[p * r * t_len..(p + 1) * r * t_len],
                &mut span_vs[p * r * t_len * d..(p + 1) * r * t_len * d],
                scr,
            );
        }
        for rr in 0..r {
            let (aligned, bound) = self.merge_group_row(
                plan,
                d,
                valid,
                spans,
                r,
                &span_m[rr..],
                &span_cnt[rr * t_len..],
                &span_vs[rr * t_len * d..],
                &mut out[off + rr * d..off + (rr + 1) * d],
                scr,
            );
            if !aligned {
                report.aligned = false;
                report.bound = report.bound.max(bound);
            }
        }
        scr.span_m = span_m;
        scr.span_cnt = span_cnt;
        scr.span_vs = span_vs;
    }

    /// One span of one group's prefix: the page-range sweep producing
    /// the span's integer partials — per query row, the span's score
    /// maximum `m_p`, the histogram of LUT addresses taken against `m_p`
    /// (the row's `pass1` mapping, local maximum), and per-address V
    /// sums. The score/V expressions are exactly
    /// [`Self::group_prefix`]'s, restricted to the span's pages
    /// (`pages`, from [`span_page_range`]) via
    /// [`KvPool::page_blocks_range`]. The partial slices are THIS span's
    /// own contiguous block: `span_m` holds `rows` maxima (row `rr` at
    /// `rr`), `span_cnt` the `rows × table_len` histograms, `span_vs`
    /// the `rows × table_len × d` V sums — so the wave layer's
    /// S×G×spans tasks each write one disjoint region. `pub(super)` so
    /// those tasks drive the identical expressions.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn group_prefix_span(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        gi: usize,
        qg: &[i8],
        plan: StepPlan,
        valid: usize,
        pages: std::ops::Range<usize>,
        span_m: &mut [i32],
        span_cnt: &mut [i32],
        span_vs: &mut [i64],
        scr: &mut AttnScratch,
    ) {
        let cfg = kv.config();
        let (d, psize) = (cfg.d_head, cfg.page_size);
        let r = seq.groups().group_size();
        let t_len = self.kernel.table().len();
        debug_assert_eq!(qg.len(), r * d);
        let lo_tok = (pages.start * psize).min(valid);
        let hi_tok = (pages.end * psize).min(valid);
        let n = hi_tok - lo_tok;
        debug_assert!(n >= 1, "span planning never yields an empty span");
        scr.prepare_decode_group(r, n, d, t_len);
        for (rr, qh) in qg.chunks_exact(d).enumerate() {
            scr.qsum[rr] = qh.iter().map(|&v| v as i32).sum();
        }
        let zqzk = d as i32 * plan.zq * plan.zk;
        // 1. span-local q·K^T rows (row rr at rr·n) — the unsplit score
        // expression over the span's page blocks
        let mut j = 0usize;
        for blk in kv.page_blocks_range(seq, gi, valid, pages.clone()) {
            for t in 0..blk.len {
                let kj = &blk.k[t * d..(t + 1) * d];
                for (rr, qh) in qg.chunks_exact(d).enumerate() {
                    let mut dot = 0i32;
                    for (&a, &b) in qh.iter().zip(kj) {
                        dot += a as i32 * b as i32;
                    }
                    scr.scores[rr * n + j] =
                        dot - plan.zk * scr.qsum[rr] - plan.zq * blk.ksum[t] + zqzk;
                }
                j += 1;
            }
        }
        debug_assert_eq!(j, n);
        // 2. per-row local max + LUT addresses against it, folded into
        // the span's address histogram
        let table = self.kernel.table();
        for rr in 0..r {
            let row = &scr.scores[rr * n..(rr + 1) * n];
            let m_p = row.iter().copied().max().unwrap_or(0);
            pass1_scores_mapped(row, m_p, plan.map, table, &mut scr.idx[rr * n..(rr + 1) * n]);
            span_m[rr] = m_p;
            let cnt = &mut span_cnt[rr * t_len..(rr + 1) * t_len];
            cnt.fill(0);
            for &k in &scr.idx[rr * n..(rr + 1) * n] {
                cnt[k as usize] += 1;
            }
        }
        // 3. per-address V sums: one V sweep serves all rows (i64 — the
        // per-address regroup of the sig×V MAC is exact there)
        span_vs[..r * t_len * d].fill(0);
        let mut j = 0usize;
        for blk in kv.page_blocks_range(seq, gi, valid, pages) {
            for t in 0..blk.len {
                let vrow = &blk.v[t * d..(t + 1) * d];
                for rr in 0..r {
                    let k = scr.idx[rr * n + j] as usize;
                    let vs = &mut span_vs[(rr * t_len + k) * d..][..d];
                    for (a, &v) in vs.iter_mut().zip(vrow) {
                        *a += v as i64;
                    }
                }
                j += 1;
            }
        }
    }

    /// Merge ONE query row's span partials and write its dequantized
    /// output elements — shared by the serial split sweep and the
    /// batched wave's merge phase. The partial slices are the span-major
    /// buffers offset to the row (`&span_m[rr..]`, `&span_cnt[rr·T..]`,
    /// `&span_vs[rr·T·d..]`); `rows` is the span-to-span stride. Returns
    /// `(aligned, f32 bound)`; the bound is `0.0` when aligned. The
    /// caller's scratch must have been prepared via
    /// [`AttnScratch::prepare_decode_split`].
    #[allow(clippy::too_many_arguments)]
    pub(super) fn merge_group_row(
        &self,
        plan: StepPlan,
        d: usize,
        valid: usize,
        spans: usize,
        rows: usize,
        m_spans: &[i32],
        cnts: &[i32],
        vsums: &[i64],
        out_row: &mut [f32],
        scr: &mut AttnScratch,
    ) -> (bool, f32) {
        let t_len = self.kernel.table().len();
        let merge = self.kernel.merge_span_row(
            plan.map,
            plan.zv,
            d,
            spans,
            rows,
            m_spans,
            cnts,
            vsums,
            &mut scr.merge_cnt[..t_len],
            &mut scr.merge_vs[..t_len * d],
            &mut scr.sig_tab[..t_len],
            &mut scr.acc[..d],
        );
        let corr = plan.zv as i64 * merge.sig_sum;
        for (o, &a) in out_row.iter_mut().zip(&scr.acc[..d]) {
            *o = (a - corr) as f32 * plan.out_scale;
        }
        if merge.aligned {
            (true, 0.0)
        } else {
            (false, self.f32_bound(merge.err_bound_int, valid, plan))
        }
    }

    /// Conservative f32-domain finalization of an integer merge bound:
    /// `|o_split − o_unsplit| ≤ |out_scale| · E` plus slack for the two
    /// `as f32` casts and the product rounding, each within
    /// `2^-24 · |value|` of exact, with
    /// `|acc − z_v·Σsig| ≤ L · qmax · (128 + |z_v|)`.
    fn f32_bound(&self, err_int: i64, valid: usize, plan: StepPlan) -> f32 {
        let os = plan.out_scale.abs() as f64;
        let qmax = self.kernel.precision().qmax() as f64;
        let amax = valid as f64 * qmax * (128.0 + plan.zv.unsigned_abs() as f64);
        (os * err_int as f64 + os * amax * 4.0 * 2f64.powi(-24)) as f32
    }
}

pub(super) fn check_step_shapes(q: &[i8], out: &[f32], h: usize, d: usize) {
    assert_eq!(q.len(), h * d, "q step must be q_heads * d_head");
    assert_eq!(out.len(), h * d, "out must be q_heads * d_head");
}

/// Shared prefill prelude: shape checks + the atomic block append.
/// Returns `None` for an empty chunk (a no-op), otherwise
/// `(t_chunk, base)` where `base` is the prefix length before the chunk.
fn prefill_ingest(
    kv: &mut KvPool,
    seq: &mut KvSeq,
    q: &[i8],
    k_rows: &[i8],
    v_rows: &[i8],
    out: &[f32],
) -> Result<Option<(usize, usize)>, KvError> {
    let d = kv.config().d_head;
    let (h, g) = (seq.groups().q_heads(), seq.groups().kv_heads());
    let gd = g * d;
    assert_eq!(k_rows.len() % gd, 0, "k chunk must be T' * kv_heads * d_head");
    assert_eq!(k_rows.len(), v_rows.len(), "k/v chunks must match");
    let t_chunk = k_rows.len() / gd;
    assert_eq!(q.len(), t_chunk * h * d, "q chunk must be T' * q_heads * d_head");
    assert_eq!(out.len(), t_chunk * h * d, "out must be T' * q_heads * d_head");
    if t_chunk == 0 {
        return Ok(None);
    }
    let base = seq.len();
    kv.append_block(seq, k_rows, v_rows)?;
    Ok(Some((t_chunk, base)))
}

/// A parsed `"decode:..."` route spec (see [`parse_decode_route`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeRoute {
    pub mode: Mode,
    pub prec: Precision,
    /// `aN`: REXP LUT_alpha length override
    pub alpha_len: Option<usize>,
    /// `gG`: stored-head count the route accepts (absent: MHA)
    pub kv_heads: Option<usize>,
    /// `pP`: KV arena pages override (absent: the serving default) — lets
    /// deployments size the arena to their traffic, and lets tests drive
    /// the route to `KvError::Exhausted` cheaply
    pub pages: Option<usize>,
    /// `fS`: install [`crate::faults::FaultPlan::seeded`]`(S)` on the
    /// route's pipeline — makes chaos scenarios wire-reachable (the
    /// `lutmax serve` fault smoke, the `decode_sched_fault/*` benches)
    pub fault_seed: Option<u64>,
}

/// Why a `"decode:..."` route spec failed to parse — typed so the
/// serving layer rejects bad routes with a reason on the wire instead of
/// panicking or silently defaulting (the failure-semantics table in
/// `coordinator::request`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouteError {
    /// not a `decode:` spec at all
    Scheme,
    /// mode segment missing, unknown, or not a LUT mode (only the LUT
    /// modes have the integer decode datapath)
    Mode(String),
    /// precision segment missing or unknown
    Precision(String),
    /// a suffix segment that isn't `aN` / `gG` / `pP` / `fS`, including
    /// an empty segment
    Segment(String),
    /// the same suffix key given twice
    Duplicate(char),
    /// a suffix value that isn't a number (e.g. `:fXYZ`)
    Value(char, String),
    /// a suffix value that must be positive was zero (`:g0`, `:p0`)
    Zero(char),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Scheme => write!(f, "route spec must start with \"decode:\""),
            RouteError::Mode(m) => write!(f, "unknown or non-LUT decode mode {m:?}"),
            RouteError::Precision(p) => write!(f, "unknown decode precision {p:?}"),
            RouteError::Segment(s) => {
                write!(f, "unknown route segment {s:?} (want aN/gG/pP/fS)")
            }
            RouteError::Duplicate(c) => write!(f, "duplicate route segment key '{c}'"),
            RouteError::Value(c, v) => {
                write!(f, "route segment '{c}' has a non-numeric value {v:?}")
            }
            RouteError::Zero(c) => write!(f, "route segment '{c}' must be positive"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Parse a decode route spec `"decode:<mode>:<prec>[:aN][:gG][:pP][:fS]"`
/// (e.g. `"decode:rexp:uint8"`, `"decode:lut2d:int16:a512:g2:p256"`).
/// `gG` fixes the stored-head count the route accepts (absent: MHA, every
/// query head stores K/V); `pP` sizes the KV arena in pages; `fS` installs
/// the seeded fault plan (chaos scenarios). Anything else — non-LUT
/// modes, malformed/duplicate/zero segments — is a typed [`RouteError`],
/// never a panic and never a silent default.
pub fn parse_decode_route(spec: &str) -> Result<DecodeRoute, RouteError> {
    let rest = spec.strip_prefix("decode:").ok_or(RouteError::Scheme)?;
    let mut parts = rest.split(':');
    let mode_s = parts.next().unwrap_or("");
    let mode = Mode::parse(mode_s).ok_or_else(|| RouteError::Mode(mode_s.into()))?;
    if !matches!(mode, Mode::Rexp | Mode::Lut2d) {
        return Err(RouteError::Mode(mode_s.into()));
    }
    let prec_s = parts.next().unwrap_or("");
    let prec = Precision::parse(prec_s).ok_or_else(|| RouteError::Precision(prec_s.into()))?;
    let (mut alpha, mut kv_heads, mut pages, mut fault_seed) = (None, None, None, None);
    for seg in parts {
        let key = match seg.chars().next() {
            Some(k) if matches!(k, 'a' | 'g' | 'p' | 'f') => k,
            _ => return Err(RouteError::Segment(seg.into())),
        };
        let val = &seg[1..];
        let dup = match key {
            'a' => alpha.is_some(),
            'g' => kv_heads.is_some(),
            'p' => pages.is_some(),
            _ => fault_seed.is_some(),
        };
        if dup {
            return Err(RouteError::Duplicate(key));
        }
        if key == 'f' {
            fault_seed = Some(val.parse().map_err(|_| RouteError::Value(key, val.into()))?);
            continue;
        }
        let v: usize = val.parse().map_err(|_| RouteError::Value(key, val.into()))?;
        match key {
            'a' => alpha = Some(v),
            'g' | 'p' if v == 0 => return Err(RouteError::Zero(key)),
            'g' => kv_heads = Some(v),
            _ => pages = Some(v),
        }
    }
    Ok(DecodeRoute { mode, prec, alpha_len: alpha, kv_heads, pages, fault_seed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{HeadGroups, KvConfig};
    use crate::testkit::Rng;

    #[test]
    fn decode_route_parsing() {
        let r = parse_decode_route("decode:rexp:uint8").unwrap();
        assert_eq!(
            r,
            DecodeRoute {
                mode: Mode::Rexp,
                prec: Precision::Uint8,
                alpha_len: None,
                kv_heads: None,
                pages: None,
                fault_seed: None,
            }
        );
        let r = parse_decode_route("decode:lut2d:int16:a512:g2:p256").unwrap();
        assert_eq!(
            r,
            DecodeRoute {
                mode: Mode::Lut2d,
                prec: Precision::Int16,
                alpha_len: Some(512),
                kv_heads: Some(2),
                pages: Some(256),
                fault_seed: None,
            }
        );
        let r = parse_decode_route("decode:rexp:uint8:g4").unwrap();
        assert_eq!((r.alpha_len, r.kv_heads, r.pages), (None, Some(4), None));
        let r = parse_decode_route("decode:rexp:uint8:g2:p64:f7").unwrap();
        assert_eq!((r.kv_heads, r.pages, r.fault_seed), (Some(2), Some(64), Some(7)));
        // seed 0 is a valid (distinct) schedule, not "disabled"
        assert_eq!(parse_decode_route("decode:rexp:uint8:f0").unwrap().fault_seed, Some(0));
        // malformed specs are TYPED errors, never panics or defaults
        assert_eq!(
            parse_decode_route("decode:exact:uint8"),
            Err(RouteError::Mode("exact".into())),
            "non-LUT mode"
        );
        assert_eq!(parse_decode_route("attn:rexp:uint8"), Err(RouteError::Scheme));
        assert_eq!(parse_decode_route("decode:rexp"), Err(RouteError::Precision("".into())));
        assert_eq!(parse_decode_route("decode:rexp:uint8:g0"), Err(RouteError::Zero('g')));
        assert_eq!(parse_decode_route("decode:rexp:uint8:p0"), Err(RouteError::Zero('p')));
        assert_eq!(
            parse_decode_route("decode:rexp:uint8:x3"),
            Err(RouteError::Segment("x3".into()))
        );
        assert_eq!(parse_decode_route("decode:rexp:uint8:"), Err(RouteError::Segment("".into())));
        assert_eq!(parse_decode_route("decode:rexp:uint8:g2:g4"), Err(RouteError::Duplicate('g')));
        assert_eq!(parse_decode_route("decode:rexp:uint8:p8:p9"), Err(RouteError::Duplicate('p')));
        assert_eq!(parse_decode_route("decode:rexp:uint8:f1:f2"), Err(RouteError::Duplicate('f')));
        assert_eq!(
            parse_decode_route("decode:rexp:uint8:fx"),
            Err(RouteError::Value('f', "x".into()))
        );
    }

    #[test]
    fn decode_rows_are_probability_mixes_of_v() {
        // V = constant 1.0 rows => every output element must be ~1.0
        // (softmax rows mix to 1 within LUT quantization), pages crossed
        let (h, g, d, ps) = (4usize, 2usize, 8usize, 4usize);
        let mut kv = KvPool::new(KvConfig { pages: 16, page_size: ps, kv_heads: g, d_head: d });
        let a = DECODE_AFFINE;
        let mut seq = KvSeq::new(HeadGroups::new(h, g).unwrap(), a, a);
        let dec = DecodeAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let mut rng = Rng::new(4);
        let mut scr = AttnScratch::new();
        let one = a.quantize(1.0);
        let vrow = vec![one; g * d];
        for _ in 0..11 {
            let krow: Vec<i8> = (0..g * d).map(|_| a.quantize(rng.normal() as f32)).collect();
            let qrow: Vec<i8> = (0..h * d).map(|_| a.quantize(rng.normal() as f32)).collect();
            let mut out = vec![0.0f32; h * d];
            dec.step(&mut kv, &mut seq, &qrow, a, &krow, &vrow, &mut out, &mut scr).unwrap();
            // same bound the prefill kernel's row-sum test uses: LUT
            // normalizer quantization, not exact unity
            for (i, &o) in out.iter().enumerate() {
                assert!(o > 0.5 && o < 1.5, "elem {i} = {o} after {} tokens", seq.len());
            }
        }
        assert_eq!(seq.pages().len(), 3);
    }

    #[test]
    fn group_major_and_head_major_sweeps_are_bit_identical() {
        // the tentpole invariant, at unit scale (the conformance harness
        // sweeps it): a pure reorder of page reads — steps and chunks
        // produce == outputs in both orders, pages crossed mid-prefix
        let (h, g, d, ps) = (4usize, 2usize, 8usize, 4usize);
        let a = DECODE_AFFINE;
        let groups = HeadGroups::new(h, g).unwrap();
        let cfg = KvConfig { pages: 16, page_size: ps, kv_heads: g, d_head: d };
        for mode in [Mode::Rexp, Mode::Lut2d] {
            let grp = DecodeAttention::new(mode, Precision::Uint8, None).unwrap();
            assert_eq!(grp.order(), SweepOrder::GroupMajor);
            let hed =
                DecodeAttention::with_order(mode, Precision::Uint8, None, SweepOrder::HeadMajor)
                    .unwrap();
            let (mut kv_g, mut kv_h) = (KvPool::new(cfg), KvPool::new(cfg));
            let mut sg = KvSeq::new(groups, a, a);
            let mut sh = KvSeq::new(groups, a, a);
            let mut rng = Rng::new(21);
            let mut scr = AttnScratch::new();
            for t in 0..9 {
                let qrow: Vec<i8> = (0..h * d).map(|_| rng.int(-128, 127) as i8).collect();
                let krow: Vec<i8> = (0..g * d).map(|_| rng.int(-128, 127) as i8).collect();
                let vrow: Vec<i8> = (0..g * d).map(|_| rng.int(-128, 127) as i8).collect();
                let mut og = vec![0.0f32; h * d];
                let mut oh = vec![0.0f32; h * d];
                grp.step(&mut kv_g, &mut sg, &qrow, a, &krow, &vrow, &mut og, &mut scr).unwrap();
                hed.step(&mut kv_h, &mut sh, &qrow, a, &krow, &vrow, &mut oh, &mut scr).unwrap();
                assert_eq!(og, oh, "{mode:?} step {t}");
            }
            // a chunk on top of the step prefix, both orders
            let tc = 6usize;
            let qc: Vec<i8> = (0..tc * h * d).map(|_| rng.int(-128, 127) as i8).collect();
            let kc: Vec<i8> = (0..tc * g * d).map(|_| rng.int(-128, 127) as i8).collect();
            let vc: Vec<i8> = (0..tc * g * d).map(|_| rng.int(-128, 127) as i8).collect();
            let mut og = vec![0.0f32; tc * h * d];
            let mut oh = vec![0.0f32; tc * h * d];
            grp.prefill_chunk(&mut kv_g, &mut sg, &qc, a, &kc, &vc, &mut og, &mut scr).unwrap();
            hed.prefill_chunk(&mut kv_h, &mut sh, &qc, a, &kc, &vc, &mut oh, &mut scr).unwrap();
            assert_eq!(og, oh, "{mode:?} chunk");
            kv_g.close(sg);
            kv_h.close(sh);
        }
    }

    #[test]
    fn split_step_matches_unsplit_when_aligned_and_is_within_bound_otherwise() {
        // the tentpole invariant at unit scale (conformance invariant 9
        // sweeps it): spans ∈ {1, 2, per-page} against the unsplit
        // group-major sweep, both modes, pages crossed mid-prefix
        let (h, g, d, ps) = (4usize, 2usize, 8usize, 4usize);
        let a = DECODE_AFFINE;
        let groups = HeadGroups::new(h, g).unwrap();
        let cfg = KvConfig { pages: 16, page_size: ps, kv_heads: g, d_head: d };
        for mode in [Mode::Rexp, Mode::Lut2d] {
            for spans_req in [1usize, 2, usize::MAX] {
                let dec = DecodeAttention::new(mode, Precision::Uint8, None).unwrap();
                let (mut kv_u, mut kv_s) = (KvPool::new(cfg), KvPool::new(cfg));
                let mut su = KvSeq::new(groups, a, a);
                let mut ss = KvSeq::new(groups, a, a);
                let mut rng = Rng::new(33);
                let mut scr = AttnScratch::new();
                for t in 0..13 {
                    let qrow: Vec<i8> = (0..h * d).map(|_| rng.int(-128, 127) as i8).collect();
                    let krow: Vec<i8> = (0..g * d).map(|_| rng.int(-128, 127) as i8).collect();
                    let vrow: Vec<i8> = (0..g * d).map(|_| rng.int(-128, 127) as i8).collect();
                    let mut ou = vec![0.0f32; h * d];
                    let mut os = vec![0.0f32; h * d];
                    dec.step(&mut kv_u, &mut su, &qrow, a, &krow, &vrow, &mut ou, &mut scr)
                        .unwrap();
                    let rep = dec
                        .step_split(
                            &mut kv_s, &mut ss, &qrow, a, &krow, &vrow, spans_req, &mut os,
                            &mut scr,
                        )
                        .unwrap();
                    let npages = su.len().div_ceil(ps).max(1);
                    assert!(rep.spans >= 1 && rep.spans <= npages, "span clamp");
                    if spans_req == 1 {
                        assert_eq!((rep.spans, rep.aligned, rep.bound), (1, true, 0.0));
                    }
                    if rep.aligned {
                        assert_eq!(ou, os, "{mode:?} spans {spans_req} step {t} must be bit-identical");
                    } else {
                        assert!(rep.bound > 0.0);
                        for (i, (&u, &s)) in ou.iter().zip(&os).enumerate() {
                            assert!(
                                (u - s).abs() <= rep.bound,
                                "{mode:?} spans {spans_req} step {t} elem {i}: |{u} - {s}| > {}",
                                rep.bound
                            );
                        }
                    }
                }
                assert_eq!(kv_u.close(su), kv_s.close(ss), "split path frees the same pages");
            }
        }
    }

    #[test]
    fn spans_for_policy_respects_threshold_and_page_count() {
        // knob off -> never split
        assert_eq!(spans_for(10_000, 16, 0), 1);
        // below threshold -> unsplit
        assert_eq!(spans_for(100, 16, 128), 1);
        // one span per threshold's worth of tokens...
        assert_eq!(spans_for(512, 16, 128), 4);
        // ...clamped to one span per resident page
        assert_eq!(spans_for(512, 256, 128), 2);
        assert_eq!(spans_for(0, 16, 128), 1);
    }

    #[test]
    fn prefill_chunk_matches_single_steps_and_is_atomic() {
        let (h, g, d, ps) = (2usize, 1usize, 4usize, 2usize);
        let a = DECODE_AFFINE;
        let groups = HeadGroups::new(h, g).unwrap();
        let cfg = KvConfig { pages: 4, page_size: ps, kv_heads: g, d_head: d };
        let (mut kv_a, mut kv_b) = (KvPool::new(cfg), KvPool::new(cfg));
        let mut sa = KvSeq::new(groups, a, a);
        let mut sb = KvSeq::new(groups, a, a);
        let dec = DecodeAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let mut rng = Rng::new(9);
        let mut scr = AttnScratch::new();
        let t = 5usize;
        let q: Vec<i8> = (0..t * h * d).map(|_| rng.int(-64, 64) as i8).collect();
        let ks: Vec<i8> = (0..t * g * d).map(|_| rng.int(-64, 64) as i8).collect();
        let vs: Vec<i8> = (0..t * g * d).map(|_| rng.int(-64, 64) as i8).collect();
        let mut chunk_out = vec![0.0f32; t * h * d];
        dec.prefill_chunk(&mut kv_a, &mut sa, &q, a, &ks, &vs, &mut chunk_out, &mut scr)
            .unwrap();
        for tt in 0..t {
            let mut got = vec![0.0f32; h * d];
            dec.step(
                &mut kv_b,
                &mut sb,
                &q[tt * h * d..(tt + 1) * h * d],
                a,
                &ks[tt * g * d..(tt + 1) * g * d],
                &vs[tt * g * d..(tt + 1) * g * d],
                &mut got,
                &mut scr,
            )
            .unwrap();
            assert_eq!(&chunk_out[tt * h * d..(tt + 1) * h * d], &got[..], "step {tt}");
        }
        // empty chunk: a no-op
        dec.prefill_chunk(&mut kv_a, &mut sa, &[], a, &[], &[], &mut [], &mut scr).unwrap();
        assert_eq!(sa.len(), t);
        // exhaustion is atomic: 5 of 8 tokens stored (3 pages held, 1
        // free); a 4-token chunk needs 2 more pages -> nothing changes
        let mut out = vec![7.0f32; 4 * h * d];
        let err = dec.prefill_chunk(
            &mut kv_a,
            &mut sa,
            &q[..4 * h * d],
            a,
            &ks[..4 * g * d],
            &vs[..4 * g * d],
            &mut out,
            &mut scr,
        );
        assert_eq!(err, Err(KvError::Exhausted { pages: 4, free_pages: 1 }));
        assert_eq!(sa.len(), t, "failed chunk must not land partially");
        assert!(out.iter().all(|&o| o == 7.0), "failed chunk must not write output");
        kv_a.close(sa);
        kv_b.close(sb);
        assert_eq!(kv_a.free_pages(), 4);
    }

    #[test]
    fn exhausted_step_leaves_output_untouched() {
        let (h, g, d) = (2usize, 1usize, 4usize);
        let mut kv = KvPool::new(KvConfig { pages: 1, page_size: 2, kv_heads: g, d_head: d });
        let a = DECODE_AFFINE;
        let mut seq = KvSeq::new(HeadGroups::new(h, g).unwrap(), a, a);
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let mut scr = AttnScratch::new();
        let row = vec![3i8; g * d];
        let q = vec![1i8; h * d];
        let mut out = vec![7.0f32; h * d];
        for _ in 0..2 {
            dec.step(&mut kv, &mut seq, &q, a, &row, &row, &mut out, &mut scr).unwrap();
        }
        out.fill(7.0);
        let err = dec.step(&mut kv, &mut seq, &q, a, &row, &row, &mut out, &mut scr);
        assert_eq!(err, Err(KvError::Exhausted { pages: 1, free_pages: 0 }));
        assert!(out.iter().all(|&o| o == 7.0), "failed step must not write output");
        assert_eq!(seq.len(), 2);
    }
}
