//! Streaming decode through the fused LUT-softmax attention kernel.
//!
//! Autoregressive decode is the workload where the paper's softmax sits
//! on the critical path of every generated token: one query row per step,
//! attending over the whole stored prefix. [`DecodeAttention`] drives the
//! *same* integer substrate as [`super::FusedAttention`]'s prefill sweep
//! — the identical score algebra (zero points hoisted out of the `i8`
//! dot, per-key byte sums read from page metadata), the identical
//! single-row LUT softmax ([`FusedAttention::sig_row`]), and the identical
//! `sig_int × V` integer MAC — so a T-step decode is **bit-identical** to
//! a length-T causal prefill (property-tested in
//! `rust/tests/integration_decode.rs`). An f32 probability row is never
//! materialized; K/V are gathered straight out of the paged `i8` arena
//! ([`crate::kv::KvPool`]).
//!
//! Grouped-query heads: the sequence's [`crate::kv::HeadGroups`] maps
//! each query head onto its stored K/V head, so one page block serves
//! `H/G` query heads. Per step, all `H` query-head rows either run inline
//! (short prefixes — a pool wake costs more than the row) or scatter over
//! a [`ParSoftmax`] pool as one task batch ([`DecodeAttention::step_par`],
//! `==`-exact with the sequential sweep).

use std::sync::Mutex;

use anyhow::Result;

use super::kernel::{AttnScratch, FusedAttention, MIN_HEAD_MACS};
use crate::kv::{KvError, KvPool, KvSeq};
use crate::lut::Precision;
use crate::quant::Affine;
use crate::softmax::{IntMap, Mode, ParSoftmax, Scratch};

/// Ingress quantization of the decode serving route: a fixed dyadic
/// affine (2^-4 per step, range ±8) sized for normalized activations —
/// the paper's operating point, and the premise that makes the LUT
/// softmax hold up. Fixed (rather than fitted per step) so every page of
/// a session shares one affine — the per-page quantization contract of
/// [`crate::kv`] — and decode replies are deterministic functions of the
/// inputs.
pub const DECODE_AFFINE: Affine = Affine { scale: 0.0625, zero_point: 0 };

/// Everything a step's head sweep needs that is constant across heads:
/// the score-unit LUT map and the fused output dequant, mirroring
/// `FusedAttention::plan` expression for expression (bit-exactness with
/// prefill depends on it).
#[derive(Clone, Copy)]
struct StepPlan {
    map: IntMap,
    out_scale: f32,
    zq: i32,
    zk: i32,
    zv: i32,
}

/// Per-step decode attention over a paged KV cache. Construct once per
/// (mode, precision, alpha) route; [`DecodeAttention::step`] /
/// [`DecodeAttention::step_par`] per generated token.
pub struct DecodeAttention {
    kernel: FusedAttention,
    /// per-worker scratch instances for the scattered path, persisted
    /// across steps: decode runs once per generated token, so a fresh
    /// scratch per call would put heap allocation on exactly the per-step
    /// hot path the paged KV arena is built to avoid
    spare: Mutex<Vec<AttnScratch>>,
}

impl DecodeAttention {
    /// Same mode/precision/alpha space as [`FusedAttention::new`] (LUT
    /// modes only).
    pub fn new(mode: Mode, prec: Precision, alpha_len: Option<usize>) -> Result<Self> {
        Ok(Self {
            kernel: FusedAttention::new(mode, prec, alpha_len)?,
            spare: Mutex::new(Vec::new()),
        })
    }

    /// The underlying fused kernel (mode/precision accessors).
    pub fn kernel(&self) -> &FusedAttention {
        &self.kernel
    }

    fn plan(&self, seq: &KvSeq, d_head: usize, q_affine: Affine) -> StepPlan {
        let step = (q_affine.scale as f64 * seq.k_affine().scale as f64
            / (d_head as f64).sqrt()) as f32;
        StepPlan {
            map: self.kernel.int_map(step),
            out_scale: seq.v_affine().scale * self.kernel.inv_qmax(),
            zq: q_affine.zero_point,
            zk: seq.k_affine().zero_point,
            zv: seq.v_affine().zero_point,
        }
    }

    /// One decode step, sequential over query heads: append the token's
    /// K/V rows (`G * d`, `[g][d]`, quantized with the sequence's
    /// affines), then attend `q` (`H * d`, `[h][d]`) over the whole
    /// stored prefix into `out` (`H * d` f32). On exhaustion nothing is
    /// appended and `out` is untouched — retry the same step after
    /// capacity frees up.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_row: &[i8],
        v_row: &[i8],
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), KvError> {
        kv.append(seq, k_row, v_row)?;
        let d = kv.config().d_head;
        let h = seq.groups().q_heads();
        check_step_shapes(q, out, h, d);
        let plan = self.plan(seq, d, q_affine);
        for (hh, oh) in out.chunks_exact_mut(d).enumerate() {
            self.head_step(kv, seq, hh, &q[hh * d..(hh + 1) * d], plan, oh, scr);
        }
        Ok(())
    }

    /// [`DecodeAttention::step`] with the `H` query-head rows scattered
    /// across a [`ParSoftmax`] pool as one task batch (bit-identical —
    /// heads are independent and write disjoint `d`-sized output blocks).
    /// Steps run inline on `scr` when the per-head work is under
    /// [`MIN_HEAD_MACS`] (short prefixes) **or** there are fewer head
    /// rows than the pool's
    /// [`min_rows_per_shard`](ParSoftmax::min_rows_per_shard) — the same
    /// row-threshold policy the pool applies to softmax batches, which is
    /// how a decode route tunes its inline-vs-pool trade-off
    /// (`ParSoftmax::with_policy`).
    #[allow(clippy::too_many_arguments)]
    pub fn step_par(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_row: &[i8],
        v_row: &[i8],
        pool: &ParSoftmax,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), KvError> {
        kv.append(seq, k_row, v_row)?;
        let d = kv.config().d_head;
        let h = seq.groups().q_heads();
        check_step_shapes(q, out, h, d);
        let plan = self.plan(seq, d, q_affine);
        let head_macs = seq.len() * d;
        if h < 2 || h < pool.min_rows_per_shard() || head_macs < MIN_HEAD_MACS {
            for (hh, oh) in out.chunks_exact_mut(d).enumerate() {
                self.head_step(kv, seq, hh, &q[hh * d..(hh + 1) * d], plan, oh, scr);
            }
            return Ok(());
        }
        let spare = &self.spare;
        struct OutPtr(*mut f32);
        // SAFETY: head tasks write disjoint `d`-sized blocks of `out`,
        // and `scatter` blocks until every task has finished.
        unsafe impl Send for OutPtr {}
        unsafe impl Sync for OutPtr {}
        let optr = OutPtr(out.as_mut_ptr());
        let kv_ref: &KvPool = kv;
        let seq_ref: &KvSeq = seq;
        let mut pool_scratch = Scratch::new();
        pool.scatter(h, &mut pool_scratch, &|hh, _s| {
            let mut scr = spare.lock().unwrap().pop().unwrap_or_default();
            let oh = unsafe { std::slice::from_raw_parts_mut(optr.0.add(hh * d), d) };
            self.head_step(kv_ref, seq_ref, hh, &q[hh * d..(hh + 1) * d], plan, oh, &mut scr);
            spare.lock().unwrap().push(scr);
        });
        Ok(())
    }

    /// One query head over the paged prefix — the decode mirror of the
    /// prefill kernel's per-row sweep, same integer expressions on the
    /// same values:
    ///
    ///   1. `q·K^T` as raw i8×i8 widening MACs per page block, zero
    ///      points hoisted (`Σk` read from the page's precomputed sums);
    ///   2./3. single-row integer LUT softmax (`sig_row`, shared);
    ///   4. `sig × V` gather across pages, i64 accumulators, one fused
    ///      dequant per output element.
    fn head_step(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        h: usize,
        qh: &[i8],
        plan: StepPlan,
        oh: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let cfg = kv.config();
        let (d, psize) = (cfg.d_head, cfg.page_size);
        let gi = seq.groups().group_of(h);
        let valid = seq.len();
        scr.prepare_decode(valid, d, self.kernel.table().len());
        let qsum: i32 = qh.iter().map(|&v| v as i32).sum();
        let zqzk = d as i32 * plan.zq * plan.zk;
        // 1. integer q·K^T over the paged prefix
        let mut j = 0usize;
        for (pi, &page) in seq.pages().iter().enumerate() {
            let in_page = seq.tokens_in_page(psize, pi);
            let kb = kv.page_k(page, gi);
            let ks = kv.page_ksum(page, gi);
            for t in 0..in_page {
                let kj = &kb[t * d..(t + 1) * d];
                let mut dot = 0i32;
                for (&a, &b) in qh.iter().zip(kj) {
                    dot += a as i32 * b as i32;
                }
                scr.scores[j] = dot - plan.zk * qsum - plan.zq * ks[t] + zqzk;
                j += 1;
            }
        }
        debug_assert_eq!(j, valid);
        // 2./3. single-row integer softmax -> sig_int
        let sig_sum = self.kernel.sig_row(valid, plan.map, scr);
        // 4. sig × V gather across pages (i32 products — sig ≤ qmax,
        // |v| ≤ 128 — accumulated in i64, as in the prefill kernel)
        scr.acc[..d].fill(0);
        let mut j = 0usize;
        for (pi, &page) in seq.pages().iter().enumerate() {
            let in_page = seq.tokens_in_page(psize, pi);
            let vb = kv.page_v(page, gi);
            for t in 0..in_page {
                let g = scr.sig[j];
                for (a, &v) in scr.acc[..d].iter_mut().zip(&vb[t * d..(t + 1) * d]) {
                    *a += (g * v as i32) as i64;
                }
                j += 1;
            }
        }
        let corr = plan.zv as i64 * sig_sum;
        for (o, &a) in oh.iter_mut().zip(&scr.acc[..d]) {
            *o = (a - corr) as f32 * plan.out_scale;
        }
    }
}

fn check_step_shapes(q: &[i8], out: &[f32], h: usize, d: usize) {
    assert_eq!(q.len(), h * d, "q step must be q_heads * d_head");
    assert_eq!(out.len(), h * d, "out must be q_heads * d_head");
}

/// Parse a decode route spec `"decode:<mode>:<prec>[:aN][:gG]"` (e.g.
/// `"decode:rexp:uint8"`, `"decode:lut2d:int16:a512:g2"`) into
/// `(mode, precision, alpha_len, kv_heads)`. `gG` fixes the stored-head
/// count the route accepts (absent: MHA, every query head stores K/V).
/// Returns `None` for anything else, including non-LUT modes.
pub fn parse_decode_route(
    spec: &str,
) -> Option<(Mode, Precision, Option<usize>, Option<usize>)> {
    let rest = spec.strip_prefix("decode:")?;
    let mut parts = rest.split(':');
    let mode = Mode::parse(parts.next()?)?;
    if !matches!(mode, Mode::Rexp | Mode::Lut2d) {
        return None;
    }
    let prec = Precision::parse(parts.next()?)?;
    let (mut alpha, mut kv_heads) = (None, None);
    for seg in parts {
        if let Some(a) = seg.strip_prefix('a') {
            if alpha.is_some() {
                return None;
            }
            alpha = Some(a.parse().ok()?);
        } else if let Some(g) = seg.strip_prefix('g') {
            if kv_heads.is_some() {
                return None;
            }
            let g: usize = g.parse().ok()?;
            if g == 0 {
                return None;
            }
            kv_heads = Some(g);
        } else {
            return None;
        }
    }
    Some((mode, prec, alpha, kv_heads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{HeadGroups, KvConfig};
    use crate::testkit::Rng;

    #[test]
    fn decode_route_parsing() {
        let (m, p, a, g) = parse_decode_route("decode:rexp:uint8").unwrap();
        assert_eq!((m, p, a, g), (Mode::Rexp, Precision::Uint8, None, None));
        let (m, p, a, g) = parse_decode_route("decode:lut2d:int16:a512:g2").unwrap();
        assert_eq!((m, p, a, g), (Mode::Lut2d, Precision::Int16, Some(512), Some(2)));
        let (_, _, a, g) = parse_decode_route("decode:rexp:uint8:g4").unwrap();
        assert_eq!((a, g), (None, Some(4)));
        assert!(parse_decode_route("decode:exact:uint8").is_none(), "non-LUT mode");
        assert!(parse_decode_route("attn:rexp:uint8").is_none());
        assert!(parse_decode_route("decode:rexp").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:g0").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:x3").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:g2:g4").is_none());
    }

    #[test]
    fn decode_rows_are_probability_mixes_of_v() {
        // V = constant 1.0 rows => every output element must be ~1.0
        // (softmax rows mix to 1 within LUT quantization), pages crossed
        let (h, g, d, ps) = (4usize, 2usize, 8usize, 4usize);
        let mut kv = KvPool::new(KvConfig { pages: 16, page_size: ps, kv_heads: g, d_head: d });
        let a = DECODE_AFFINE;
        let mut seq = KvSeq::new(HeadGroups::new(h, g).unwrap(), a, a);
        let dec = DecodeAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let mut rng = Rng::new(4);
        let mut scr = AttnScratch::new();
        let one = a.quantize(1.0);
        let vrow = vec![one; g * d];
        for _ in 0..11 {
            let krow: Vec<i8> = (0..g * d).map(|_| a.quantize(rng.normal() as f32)).collect();
            let qrow: Vec<i8> = (0..h * d).map(|_| a.quantize(rng.normal() as f32)).collect();
            let mut out = vec![0.0f32; h * d];
            dec.step(&mut kv, &mut seq, &qrow, a, &krow, &vrow, &mut out, &mut scr).unwrap();
            // same bound the prefill kernel's row-sum test uses: LUT
            // normalizer quantization, not exact unity
            for (i, &o) in out.iter().enumerate() {
                assert!(o > 0.5 && o < 1.5, "elem {i} = {o} after {} tokens", seq.len());
            }
        }
        assert_eq!(seq.pages().len(), 3);
    }

    #[test]
    fn exhausted_step_leaves_output_untouched() {
        let (h, g, d) = (2usize, 1usize, 4usize);
        let mut kv = KvPool::new(KvConfig { pages: 1, page_size: 2, kv_heads: g, d_head: d });
        let a = DECODE_AFFINE;
        let mut seq = KvSeq::new(HeadGroups::new(h, g).unwrap(), a, a);
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let mut scr = AttnScratch::new();
        let row = vec![3i8; g * d];
        let q = vec![1i8; h * d];
        let mut out = vec![7.0f32; h * d];
        for _ in 0..2 {
            dec.step(&mut kv, &mut seq, &q, a, &row, &row, &mut out, &mut scr).unwrap();
        }
        out.fill(7.0);
        let err = dec.step(&mut kv, &mut seq, &q, a, &row, &row, &mut out, &mut scr);
        assert_eq!(err, Err(KvError::Exhausted { pages: 1 }));
        assert!(out.iter().all(|&o| o == 7.0), "failed step must not write output");
        assert_eq!(seq.len(), 2);
    }
}
