//! Streaming decode through the fused LUT-softmax attention kernel.
//!
//! Autoregressive decode is the workload where the paper's softmax sits
//! on the critical path of every generated token: one query row per step,
//! attending over the whole stored prefix. [`DecodeAttention`] drives the
//! *same* integer substrate as [`super::FusedAttention`]'s prefill sweep
//! — the identical score algebra (zero points hoisted out of the `i8`
//! dot, per-key byte sums read from page metadata), the identical
//! single-row LUT softmax ([`FusedAttention::sig_row`]), and the identical
//! `sig_int × V` integer MAC — so a T-step decode is **bit-identical** to
//! a length-T causal prefill (property-tested in
//! `rust/tests/integration_decode.rs`). An f32 probability row is never
//! materialized; K/V are gathered straight out of the paged `i8` arena
//! ([`crate::kv::KvPool`]).
//!
//! Grouped-query heads: the sequence's [`crate::kv::HeadGroups`] maps
//! each query head onto its stored K/V head, so one page block serves
//! `H/G` query heads.
//!
//! # Read-traffic contract (group-major sweep)
//!
//! Decode is memory-bound — the LUT softmax made the arithmetic cheap,
//! so the per-token cost is the sweep over the stored i8 prefix. The hot
//! path's unit of work is therefore one KV **group**, not one query
//! head ([`SweepOrder::GroupMajor`], the default): a group task walks
//! its pages exactly once per step ([`KvPool::page_blocks`]), computing
//! the score rows of all `H/G` query heads sharing the group against
//! each resident K block, runs the per-head LUT softmax
//! (`sig_row`, unchanged algebra, one row per head), then one V sweep
//! producing all `H/G` output rows per V read. **K/V are read once per
//! group per step** — read amplification is `G`-proportional, matching
//! the storage saving. The head-major order
//! ([`SweepOrder::HeadMajor`], which re-gathers the group's pages once
//! per *query* head, `H/G×` the traffic) is kept as the conformance
//! reference and bench baseline: the two sweeps are a pure reorder of
//! reads over identical integer expressions, **bit-identical** across
//! the whole conformance sweep (`integration_conformance.rs`).
//!
//! Per step, the sweep units (G group tasks, or H head rows head-major)
//! either run inline (short prefixes — a pool wake costs more than the
//! work) or scatter over a [`ParSoftmax`] pool as one task batch
//! ([`DecodeAttention::step_par`], `==`-exact with the sequential
//! sweep).
//!
//! Prompt ingestion goes through [`DecodeAttention::prefill_chunk`]:
//! append a block of `T'` tokens, attend once — bit-identical to `T'`
//! single steps. Concurrent sessions' steps batch into ONE scatter wave
//! through [`super::DecodeBatch`] (`attention/batch.rs`).

use std::sync::Mutex;

use anyhow::Result;

use super::batch::WaveError;
use super::kernel::{wave_stays_inline, AttnScratch, FusedAttention, OutPtr};
use crate::kv::{KvError, KvPool, KvSeq};
use crate::lut::Precision;
use crate::quant::Affine;
use crate::softmax::{lock_unpoisoned, IntMap, Mode, ParSoftmax, Scratch};

/// Ingress quantization of the decode serving route: a fixed dyadic
/// affine (2^-4 per step, range ±8) sized for normalized activations —
/// the paper's operating point, and the premise that makes the LUT
/// softmax hold up. Fixed (rather than fitted per step) so every page of
/// a session shares one affine — the per-page quantization contract of
/// [`crate::kv`] — and decode replies are deterministic functions of the
/// inputs.
pub const DECODE_AFFINE: Affine = Affine { scale: 0.0625, zero_point: 0 };

/// Everything a step's head sweep needs that is constant across heads:
/// the score-unit LUT map and the fused output dequant, mirroring
/// `FusedAttention::plan` expression for expression (bit-exactness with
/// prefill depends on it). Shared with the batched-wave layer
/// ([`super::DecodeBatch`]), which computes one plan per session.
#[derive(Clone, Copy)]
pub(super) struct StepPlan {
    map: IntMap,
    out_scale: f32,
    zq: i32,
    zk: i32,
    zv: i32,
}

/// Order in which a decode sweep walks the paged prefix. Outputs are
/// **bit-identical** across orders (a pure reorder of reads over the
/// same integer expressions — pinned by the conformance harness); the
/// difference is K/V read traffic per step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SweepOrder {
    /// One sweep unit per KV group: pages read **once per group per
    /// step**, each read serving all `H/G` query heads of the group.
    /// The product path.
    #[default]
    GroupMajor,
    /// One sweep unit per query head: every head re-gathers its group's
    /// pages, reading each K/V byte `H/G` times per step. Kept as the
    /// conformance reference and the `decode/*` bench baseline.
    HeadMajor,
}

/// Per-step decode attention over a paged KV cache. Construct once per
/// (mode, precision, alpha) route; [`DecodeAttention::step`] /
/// [`DecodeAttention::step_par`] per generated token.
pub struct DecodeAttention {
    kernel: FusedAttention,
    order: SweepOrder,
    /// per-worker scratch instances for the scattered path, persisted
    /// across steps: decode runs once per generated token, so a fresh
    /// scratch per call would put heap allocation on exactly the per-step
    /// hot path the paged KV arena is built to avoid (shared with the
    /// batched-wave layer in `attention/batch.rs`)
    pub(super) spare: Mutex<Vec<AttnScratch>>,
}

impl DecodeAttention {
    /// Same mode/precision/alpha space as [`FusedAttention::new`] (LUT
    /// modes only). Sweeps group-major ([`SweepOrder::GroupMajor`]).
    pub fn new(mode: Mode, prec: Precision, alpha_len: Option<usize>) -> Result<Self> {
        Self::with_order(mode, prec, alpha_len, SweepOrder::default())
    }

    /// [`DecodeAttention::new`] with an explicit sweep order — the
    /// head-major order exists for the conformance differential and the
    /// `decode/*` (vs `decode_groupmajor/*`) bench baseline.
    pub fn with_order(
        mode: Mode,
        prec: Precision,
        alpha_len: Option<usize>,
        order: SweepOrder,
    ) -> Result<Self> {
        Ok(Self {
            kernel: FusedAttention::new(mode, prec, alpha_len)?,
            order,
            spare: Mutex::new(Vec::new()),
        })
    }

    /// The underlying fused kernel (mode/precision accessors).
    pub fn kernel(&self) -> &FusedAttention {
        &self.kernel
    }

    /// The sweep order this kernel walks the paged prefix in.
    pub fn order(&self) -> SweepOrder {
        self.order
    }

    pub(super) fn plan(&self, seq: &KvSeq, d_head: usize, q_affine: Affine) -> StepPlan {
        let step = (q_affine.scale as f64 * seq.k_affine().scale as f64
            / (d_head as f64).sqrt()) as f32;
        StepPlan {
            map: self.kernel.int_map(step),
            out_scale: seq.v_affine().scale * self.kernel.inv_qmax(),
            zq: q_affine.zero_point,
            zk: seq.k_affine().zero_point,
            zv: seq.v_affine().zero_point,
        }
    }

    /// One decode step, sequential over query heads: append the token's
    /// K/V rows (`G * d`, `[g][d]`, quantized with the sequence's
    /// affines), then attend `q` (`H * d`, `[h][d]`) over the whole
    /// stored prefix into `out` (`H * d` f32). On exhaustion nothing is
    /// appended and `out` is untouched — retry the same step after
    /// capacity frees up.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_row: &[i8],
        v_row: &[i8],
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), KvError> {
        kv.append(seq, k_row, v_row)?;
        let d = kv.config().d_head;
        let h = seq.groups().q_heads();
        check_step_shapes(q, out, h, d);
        let plan = self.plan(seq, d, q_affine);
        self.sweep_step(kv, seq, q, plan, out, scr);
        Ok(())
    }

    /// The sequential sweep of one already-appended step, in the
    /// kernel's [`SweepOrder`]. Shared by [`DecodeAttention::step`] and
    /// the inline arm of [`DecodeAttention::step_par`].
    fn sweep_step(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        q: &[i8],
        plan: StepPlan,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let d = kv.config().d_head;
        match self.order {
            SweepOrder::HeadMajor => {
                for (hh, oh) in out.chunks_exact_mut(d).enumerate() {
                    self.head_step(kv, seq, hh, &q[hh * d..(hh + 1) * d], plan, oh, scr);
                }
            }
            SweepOrder::GroupMajor => {
                let r = seq.groups().group_size();
                for (gi, og) in out.chunks_exact_mut(r * d).enumerate() {
                    self.group_step(kv, seq, gi, &q[gi * r * d..(gi * r + r) * d], plan, og, scr);
                }
            }
        }
    }

    /// [`DecodeAttention::step`] with the sweep units (G group tasks, or
    /// H head rows head-major) scattered across a [`ParSoftmax`] pool as
    /// one task batch (bit-identical — units are independent and write
    /// disjoint output blocks). Steps run inline on `scr` under the
    /// shared wave accounting (`wave_stays_inline`: whole-step MACs +
    /// MAC-weighted row equivalents, so a 2-group step with heavy heads
    /// still fans out) — the same whole-submission accounting the
    /// batched wave ([`super::DecodeBatch`]) and the scattered prefill
    /// use, so a 1-task wave and a bare `step_par` make the identical
    /// inline-vs-pool decision.
    ///
    /// **Parallelism trade**: a bare group-major step has exactly G
    /// sweep units (a single query row per head, so there is nothing
    /// finer to split without splitting the *prefix* — a partial-softmax
    /// reduction this kernel doesn't do; ROADMAP open item). G = 1 (MQA)
    /// therefore always runs a bare step inline. That is the deliberate
    /// bandwidth-for-parallelism trade of the group-major sweep: serving
    /// restores concurrency across sessions (`DecodeBatch` waves are
    /// S×G tasks) and across prompt rows (`prefill_chunk_par` scatters
    /// G·T' tasks); a latency-critical small-G deployment that wants
    /// per-head fan-out on bare steps can pin
    /// [`SweepOrder::HeadMajor`].
    ///
    /// **Failure domains**: the append can fail with
    /// [`WaveError::Kv`] (nothing written, retryable); a sweep unit
    /// panicking — injected or genuine — is contained by the pool and
    /// surfaces as [`WaveError::Panicked`] (the append already landed:
    /// state advanced, output lost, do NOT replay the step).
    #[allow(clippy::too_many_arguments)]
    pub fn step_par(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_row: &[i8],
        v_row: &[i8],
        pool: &ParSoftmax,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), WaveError> {
        kv.append(seq, k_row, v_row).map_err(WaveError::Kv)?;
        let d = kv.config().d_head;
        let h = seq.groups().q_heads();
        check_step_shapes(q, out, h, d);
        let plan = self.plan(seq, d, q_affine);
        let step_macs = h * seq.len() * d;
        let r = seq.groups().group_size();
        let units = match self.order {
            SweepOrder::HeadMajor => h,
            SweepOrder::GroupMajor => seq.groups().kv_heads(),
        };
        let spare = &self.spare;
        // SAFETY (OutPtr contract): sweep tasks reconstruct disjoint
        // blocks of `out` only (one `d` block per head, or one
        // contiguous `H/G · d` block per group).
        let optr = OutPtr(out.as_mut_ptr());
        let kv_ref: &KvPool = kv;
        let seq_ref: &KvSeq = seq;
        let order = self.order;
        // the caller's scratch is lent to the spare stack for the wave,
        // so the inline arm keeps its amortized buffers
        lock_unpoisoned(spare).push(std::mem::take(scr));
        let run = |u: usize, _s: &mut Scratch| {
            let mut hs = lock_unpoisoned(spare).pop().unwrap_or_default();
            match order {
                SweepOrder::HeadMajor => {
                    let oh = unsafe { std::slice::from_raw_parts_mut(optr.0.add(u * d), d) };
                    self.head_step(kv_ref, seq_ref, u, &q[u * d..(u + 1) * d], plan, oh, &mut hs);
                }
                SweepOrder::GroupMajor => {
                    let og =
                        unsafe { std::slice::from_raw_parts_mut(optr.0.add(u * r * d), r * d) };
                    self.group_step(
                        kv_ref,
                        seq_ref,
                        u,
                        &q[u * r * d..(u * r + r) * d],
                        plan,
                        og,
                        &mut hs,
                    );
                }
            }
            lock_unpoisoned(spare).push(hs);
        };
        let mut pool_scratch = Scratch::new();
        let outcome = if wave_stays_inline(pool, units, h, step_macs) {
            pool.scatter_inline(units, &mut pool_scratch, &run)
        } else {
            pool.scatter(units, &mut pool_scratch, &run)
        };
        if let Some(hs) = lock_unpoisoned(spare).pop() {
            *scr = hs;
        }
        if outcome.is_ok() {
            Ok(())
        } else {
            Err(WaveError::Panicked)
        }
    }

    /// Append a block of `T'` tokens to the paged cache and attend ONCE
    /// through the fused kernel — chunked prefill. Layouts are step-major:
    /// `q`/`out` are `T' * H * d` (`[t][h][d]`), `k_rows`/`v_rows` are
    /// `T' * G * d` (`[t][g][d]`), so row `t` of the output is exactly
    /// what the `t`-th single [`DecodeAttention::step`] would have
    /// produced.
    ///
    /// **Bit-identical to `T'` single steps by construction**: the block
    /// append lands token-for-token the same bytes/sums/page-table growth
    /// as `T'` appends ([`KvPool::append_block`]), and each chunk row
    /// attends over its own causal prefix (`base + t + 1`) with the same
    /// integer expressions ([`Self::head_prefix`]) a single step uses —
    /// the pages it reads were all written before any of them is read, so
    /// deferring the attention sweep changes nothing. Property-tested in
    /// `integration_decode_batch.rs` and swept by the conformance harness.
    ///
    /// **Atomic on exhaustion**: capacity for the whole chunk is reserved
    /// up front; on [`KvError::Exhausted`] neither the cache nor `out` is
    /// touched and the same chunk can be retried after pages free up.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_chunk(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_rows: &[i8],
        v_rows: &[i8],
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), KvError> {
        let Some((t_chunk, base)) = prefill_ingest(kv, seq, q, k_rows, v_rows, out)? else {
            return Ok(());
        };
        let plan = self.plan(seq, kv.config().d_head, q_affine);
        self.sweep_prefill(kv, seq, q, plan, base, t_chunk, out, scr);
        Ok(())
    }

    /// The sequential sweep of an already-appended chunk, in the
    /// kernel's [`SweepOrder`]. Shared by
    /// [`DecodeAttention::prefill_chunk`] and the inline arm of
    /// [`DecodeAttention::prefill_chunk_par`] (the prefill mirror of
    /// [`Self::sweep_step`]).
    #[allow(clippy::too_many_arguments)]
    fn sweep_prefill(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        q: &[i8],
        plan: StepPlan,
        base: usize,
        t_chunk: usize,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        match self.order {
            // head-major reference order: one head streams the page
            // blocks for all T' of its query rows (each page read H/G
            // times per row set)
            SweepOrder::HeadMajor => {
                for hh in 0..seq.groups().q_heads() {
                    self.prefill_head_rows(kv, seq, hh, q, plan, base, t_chunk, out, scr);
                }
            }
            // group-major: one group sweeps its pages once per chunk row
            // for ALL its H/G heads
            SweepOrder::GroupMajor => {
                for gi in 0..seq.groups().kv_heads() {
                    self.prefill_group_rows(kv, seq, gi, q, plan, base, t_chunk, out, scr);
                }
            }
        }
    }

    /// [`DecodeAttention::prefill_chunk`] with the sweep units (G group
    /// sweeps, or H head sweeps head-major) scattered across a
    /// [`ParSoftmax`] pool (bit-identical — each task writes its own
    /// disjoint `(t, head)` output blocks). A prompt chunk is the most
    /// parallelizable payload the decode route serves (`T' × H`
    /// independent rows), so the serving pipeline routes prefills here;
    /// small chunks stay inline under the same wave accounting as step
    /// waves.
    ///
    /// **Failure domains**: as for [`Self::step_par`] — a failed ingest
    /// is [`WaveError::Kv`] (atomic, retryable); a panicking sweep unit
    /// is contained and surfaces as [`WaveError::Panicked`] (the chunk's
    /// tokens are already appended: state advanced, output lost, do NOT
    /// replay the chunk).
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_chunk_par(
        &self,
        kv: &mut KvPool,
        seq: &mut KvSeq,
        q: &[i8],
        q_affine: Affine,
        k_rows: &[i8],
        v_rows: &[i8],
        pool: &ParSoftmax,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) -> Result<(), WaveError> {
        let ingest = prefill_ingest(kv, seq, q, k_rows, v_rows, out).map_err(WaveError::Kv)?;
        let Some((t_chunk, base)) = ingest else {
            return Ok(());
        };
        let (h, d) = (seq.groups().q_heads(), kv.config().d_head);
        let g = seq.groups().kv_heads();
        let plan = self.plan(seq, d, q_affine);
        // whole-chunk accounting: Σ_t h·(base+t+1)·d MACs over the wave,
        // t_chunk·h head rows
        let chunk_macs: usize = (0..t_chunk).map(|t| h * (base + t + 1) * d).sum();
        // group-major prefill scatters one task per (group, chunk row):
        // a chunk has T' independent row sweeps per group, so prefill
        // parallelism is G·T', NOT capped at G — each task still reads
        // its group's pages exactly once per row, the same traffic as
        // the sequential group-major sweep (single *steps* have one row
        // and are genuinely G-bounded; see `step_par`)
        let units = match self.order {
            SweepOrder::HeadMajor => h,
            SweepOrder::GroupMajor => g * t_chunk,
        };
        let spare = &self.spare;
        // SAFETY (OutPtr contract): sweep task `u` reconstructs only its
        // own disjoint `(t, head)` blocks of `out` — one `d` slice per
        // row head-major, one contiguous `H/G · d` slice for its single
        // (group, row) pair group-major; concurrent tasks never alias.
        let optr = OutPtr(out.as_mut_ptr());
        let kv_ref: &KvPool = kv;
        let seq_ref: &KvSeq = seq;
        let r = seq.groups().group_size();
        let order = self.order;
        // lend the caller's scratch to the spare stack for the wave
        lock_unpoisoned(spare).push(std::mem::take(scr));
        let run = |u: usize, _s: &mut Scratch| {
            let mut hs = lock_unpoisoned(spare).pop().unwrap_or_default();
            match order {
                SweepOrder::HeadMajor => {
                    for t in 0..t_chunk {
                        let qh = &q[(t * h + u) * d..(t * h + u + 1) * d];
                        let oh = unsafe {
                            std::slice::from_raw_parts_mut(optr.0.add((t * h + u) * d), d)
                        };
                        self.head_prefix(kv_ref, seq_ref, u, qh, plan, base + t + 1, oh, 0, &mut hs);
                    }
                }
                SweepOrder::GroupMajor => {
                    let (gi, t) = (u % g, u / g);
                    let qg = &q[(t * h + gi * r) * d..(t * h + gi * r + r) * d];
                    let og = unsafe {
                        std::slice::from_raw_parts_mut(optr.0.add((t * h + gi * r) * d), r * d)
                    };
                    self.group_prefix(kv_ref, seq_ref, gi, qg, plan, base + t + 1, og, 0, &mut hs);
                }
            }
            lock_unpoisoned(spare).push(hs);
        };
        let mut pool_scratch = Scratch::new();
        let outcome = if wave_stays_inline(pool, units, t_chunk * h, chunk_macs) {
            pool.scatter_inline(units, &mut pool_scratch, &run)
        } else {
            pool.scatter(units, &mut pool_scratch, &run)
        };
        if let Some(hs) = lock_unpoisoned(spare).pop() {
            *scr = hs;
        }
        if outcome.is_ok() {
            Ok(())
        } else {
            Err(WaveError::Panicked)
        }
    }

    /// One head's causal sweep over a freshly-appended chunk: rows
    /// `base..base+t_chunk`, each over its own prefix, writing the head's
    /// `(t, hh)` blocks of `out`.
    #[allow(clippy::too_many_arguments)]
    fn prefill_head_rows(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        hh: usize,
        q: &[i8],
        plan: StepPlan,
        base: usize,
        t_chunk: usize,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let (h, d) = (seq.groups().q_heads(), kv.config().d_head);
        for t in 0..t_chunk {
            let qh = &q[(t * h + hh) * d..(t * h + hh + 1) * d];
            self.head_prefix(kv, seq, hh, qh, plan, base + t + 1, out, (t * h + hh) * d, scr);
        }
    }

    /// One group's causal sweep over a freshly-appended chunk: for each
    /// row `base..base+t_chunk`, one group-major page sweep serves all
    /// `H/G` of the group's query heads, writing their `(t, head)`
    /// blocks of `out`.
    #[allow(clippy::too_many_arguments)]
    fn prefill_group_rows(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        gi: usize,
        q: &[i8],
        plan: StepPlan,
        base: usize,
        t_chunk: usize,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let (h, d) = (seq.groups().q_heads(), kv.config().d_head);
        let r = seq.groups().group_size();
        for t in 0..t_chunk {
            let qg = &q[(t * h + gi * r) * d..(t * h + gi * r + r) * d];
            self.group_prefix(kv, seq, gi, qg, plan, base + t + 1, out, (t * h + gi * r) * d, scr);
        }
    }

    /// One query head over the paged prefix — the decode mirror of the
    /// prefill kernel's per-row sweep, same integer expressions on the
    /// same values:
    ///
    ///   1. `q·K^T` as raw i8×i8 widening MACs per page block, zero
    ///      points hoisted (`Σk` read from the page's precomputed sums);
    ///   2./3. single-row integer LUT softmax (`sig_row`, shared);
    ///   4. `sig × V` gather across pages, i64 accumulators, one fused
    ///      dequant per output element.
    ///
    /// Attends over the whole stored prefix (`seq.len()`); the chunked
    /// prefill path uses [`Self::head_prefix`] directly with a shorter
    /// causal bound. `pub(super)` so the batched-wave layer
    /// (`attention/batch.rs`) drives the identical expressions.
    pub(super) fn head_step(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        h: usize,
        qh: &[i8],
        plan: StepPlan,
        oh: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let d = kv.config().d_head;
        let valid = seq.len();
        debug_assert_eq!(oh.len(), d);
        // route through the prefix sweep with a zero-offset output view
        self.head_prefix(kv, seq, h, qh, plan, valid, oh, 0, scr);
    }

    /// The shared per-head sweep over a causal prefix of `valid ≤
    /// seq.len()` tokens, writing `d_head` output elements at `out[off..]`.
    #[allow(clippy::too_many_arguments)]
    fn head_prefix(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        h: usize,
        qh: &[i8],
        plan: StepPlan,
        valid: usize,
        out: &mut [f32],
        off: usize,
        scr: &mut AttnScratch,
    ) {
        let cfg = kv.config();
        let (d, psize) = (cfg.d_head, cfg.page_size);
        let gi = seq.groups().group_of(h);
        debug_assert!(valid >= 1 && valid <= seq.len());
        scr.prepare_decode(valid, d, self.kernel.table().len());
        let qsum: i32 = qh.iter().map(|&v| v as i32).sum();
        let zqzk = d as i32 * plan.zq * plan.zk;
        // 1. integer q·K^T over the causal prefix (`valid` tokens; full
        // pages except the prefix tail — a chunked-prefill row stops
        // before the sequence's stored length)
        let mut j = 0usize;
        for (pi, &page) in seq.pages().iter().enumerate() {
            let in_page = valid.saturating_sub(pi * psize).min(psize);
            if in_page == 0 {
                break;
            }
            let kb = kv.page_k(page, gi);
            let ks = kv.page_ksum(page, gi);
            for t in 0..in_page {
                let kj = &kb[t * d..(t + 1) * d];
                let mut dot = 0i32;
                for (&a, &b) in qh.iter().zip(kj) {
                    dot += a as i32 * b as i32;
                }
                scr.scores[j] = dot - plan.zk * qsum - plan.zq * ks[t] + zqzk;
                j += 1;
            }
        }
        debug_assert_eq!(j, valid);
        // 2./3. single-row integer softmax -> sig_int
        let sig_sum = self.kernel.sig_row(valid, plan.map, scr);
        // 4. sig × V gather across pages (i32 products — sig ≤ qmax,
        // |v| ≤ 128 — accumulated in i64, as in the prefill kernel)
        scr.acc[..d].fill(0);
        let mut j = 0usize;
        for (pi, &page) in seq.pages().iter().enumerate() {
            let in_page = valid.saturating_sub(pi * psize).min(psize);
            if in_page == 0 {
                break;
            }
            let vb = kv.page_v(page, gi);
            for t in 0..in_page {
                let g = scr.sig[j];
                for (a, &v) in scr.acc[..d].iter_mut().zip(&vb[t * d..(t + 1) * d]) {
                    *a += (g * v as i32) as i64;
                }
                j += 1;
            }
        }
        let corr = plan.zv as i64 * sig_sum;
        for (o, &a) in out[off..off + d].iter_mut().zip(&scr.acc[..d]) {
            *o = (a - corr) as f32 * plan.out_scale;
        }
    }

    /// One KV group over the whole stored prefix: the group-major mirror
    /// of [`Self::head_step`], taking the group's `H/G` query rows
    /// (`qg`, `[r][d]` — the contiguous head block of group `gi`) and
    /// writing their `H/G · d` output block. `pub(super)` so the
    /// batched-wave layer (`attention/batch.rs`) drives the identical
    /// expressions.
    pub(super) fn group_step(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        gi: usize,
        qg: &[i8],
        plan: StepPlan,
        og: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let d = kv.config().d_head;
        let valid = seq.len();
        debug_assert_eq!(og.len(), seq.groups().group_size() * d);
        self.group_prefix(kv, seq, gi, qg, plan, valid, og, 0, scr);
    }

    /// The group-major sweep over a causal prefix of `valid ≤ seq.len()`
    /// tokens: each of the group's resident K blocks is read ONCE and
    /// dotted against all `H/G` query heads (score rows parked side by
    /// side in scratch, row `r` at offset `r · valid`), each head then
    /// runs the unchanged per-row LUT softmax ([`FusedAttention::
    /// sig_row_at`]), and one V sweep accumulates all `H/G` output rows
    /// per V read. Every integer expression is the one
    /// [`Self::head_prefix`] evaluates on the same values, in the same
    /// per-head order — the two sweeps differ only in *when* each page
    /// is read, so outputs are **bit-identical** (pinned by the
    /// conformance harness's group-vs-head axis). Pages are walked via
    /// [`KvPool::page_blocks`], which yields K, V, byte sums and affines
    /// in one page-table lookup.
    #[allow(clippy::too_many_arguments)]
    fn group_prefix(
        &self,
        kv: &KvPool,
        seq: &KvSeq,
        gi: usize,
        qg: &[i8],
        plan: StepPlan,
        valid: usize,
        out: &mut [f32],
        off: usize,
        scr: &mut AttnScratch,
    ) {
        let d = kv.config().d_head;
        let r = seq.groups().group_size();
        debug_assert_eq!(qg.len(), r * d);
        debug_assert!(valid >= 1 && valid <= seq.len());
        scr.prepare_decode_group(r, valid, d, self.kernel.table().len());
        for (rr, qh) in qg.chunks_exact(d).enumerate() {
            scr.qsum[rr] = qh.iter().map(|&v| v as i32).sum();
        }
        let zqzk = d as i32 * plan.zq * plan.zk;
        // 1. integer q·K^T, group-major: each resident K row is read
        // once and dotted against every query head of the group (same
        // score expression as `head_prefix`, reordered reads)
        let mut j = 0usize;
        for blk in kv.page_blocks(seq, gi, valid) {
            for t in 0..blk.len {
                let kj = &blk.k[t * d..(t + 1) * d];
                for (rr, qh) in qg.chunks_exact(d).enumerate() {
                    let mut dot = 0i32;
                    for (&a, &b) in qh.iter().zip(kj) {
                        dot += a as i32 * b as i32;
                    }
                    scr.scores[rr * valid + j] =
                        dot - plan.zk * scr.qsum[rr] - plan.zq * blk.ksum[t] + zqzk;
                }
                j += 1;
            }
        }
        debug_assert_eq!(j, valid);
        // 2./3. per-head single-row integer softmax -> sig_int rows
        for rr in 0..r {
            let s = self.kernel.sig_row_at(valid, plan.map, scr, rr * valid);
            scr.sig_sum[rr] = s;
        }
        // 4. one sig × V sweep: each resident V row is read once and
        // accumulated into every head's output accumulator (i32
        // products, i64 accumulation — as in `head_prefix`)
        scr.acc[..r * d].fill(0);
        let mut j = 0usize;
        for blk in kv.page_blocks(seq, gi, valid) {
            for t in 0..blk.len {
                let vrow = &blk.v[t * d..(t + 1) * d];
                for rr in 0..r {
                    let g = scr.sig[rr * valid + j];
                    for (a, &v) in scr.acc[rr * d..(rr + 1) * d].iter_mut().zip(vrow) {
                        *a += (g * v as i32) as i64;
                    }
                }
                j += 1;
            }
        }
        for rr in 0..r {
            let corr = plan.zv as i64 * scr.sig_sum[rr];
            for (o, &a) in out[off + rr * d..off + (rr + 1) * d]
                .iter_mut()
                .zip(&scr.acc[rr * d..(rr + 1) * d])
            {
                *o = (a - corr) as f32 * plan.out_scale;
            }
        }
    }
}

pub(super) fn check_step_shapes(q: &[i8], out: &[f32], h: usize, d: usize) {
    assert_eq!(q.len(), h * d, "q step must be q_heads * d_head");
    assert_eq!(out.len(), h * d, "out must be q_heads * d_head");
}

/// Shared prefill prelude: shape checks + the atomic block append.
/// Returns `None` for an empty chunk (a no-op), otherwise
/// `(t_chunk, base)` where `base` is the prefix length before the chunk.
fn prefill_ingest(
    kv: &mut KvPool,
    seq: &mut KvSeq,
    q: &[i8],
    k_rows: &[i8],
    v_rows: &[i8],
    out: &[f32],
) -> Result<Option<(usize, usize)>, KvError> {
    let d = kv.config().d_head;
    let (h, g) = (seq.groups().q_heads(), seq.groups().kv_heads());
    let gd = g * d;
    assert_eq!(k_rows.len() % gd, 0, "k chunk must be T' * kv_heads * d_head");
    assert_eq!(k_rows.len(), v_rows.len(), "k/v chunks must match");
    let t_chunk = k_rows.len() / gd;
    assert_eq!(q.len(), t_chunk * h * d, "q chunk must be T' * q_heads * d_head");
    assert_eq!(out.len(), t_chunk * h * d, "out must be T' * q_heads * d_head");
    if t_chunk == 0 {
        return Ok(None);
    }
    let base = seq.len();
    kv.append_block(seq, k_rows, v_rows)?;
    Ok(Some((t_chunk, base)))
}

/// A parsed `"decode:..."` route spec (see [`parse_decode_route`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeRoute {
    pub mode: Mode,
    pub prec: Precision,
    /// `aN`: REXP LUT_alpha length override
    pub alpha_len: Option<usize>,
    /// `gG`: stored-head count the route accepts (absent: MHA)
    pub kv_heads: Option<usize>,
    /// `pP`: KV arena pages override (absent: the serving default) — lets
    /// deployments size the arena to their traffic, and lets tests drive
    /// the route to `KvError::Exhausted` cheaply
    pub pages: Option<usize>,
    /// `fS`: install [`crate::faults::FaultPlan::seeded`]`(S)` on the
    /// route's pipeline — makes chaos scenarios wire-reachable (the
    /// `lutmax serve` fault smoke, the `decode_sched_fault/*` benches)
    pub fault_seed: Option<u64>,
}

/// Parse a decode route spec `"decode:<mode>:<prec>[:aN][:gG][:pP][:fS]"`
/// (e.g. `"decode:rexp:uint8"`, `"decode:lut2d:int16:a512:g2:p256"`).
/// `gG` fixes the stored-head count the route accepts (absent: MHA, every
/// query head stores K/V); `pP` sizes the KV arena in pages; `fS` installs
/// the seeded fault plan (chaos scenarios). Returns `None` for anything
/// else, including non-LUT modes.
pub fn parse_decode_route(spec: &str) -> Option<DecodeRoute> {
    let rest = spec.strip_prefix("decode:")?;
    let mut parts = rest.split(':');
    let mode = Mode::parse(parts.next()?)?;
    if !matches!(mode, Mode::Rexp | Mode::Lut2d) {
        return None;
    }
    let prec = Precision::parse(parts.next()?)?;
    let (mut alpha, mut kv_heads, mut pages, mut fault_seed) = (None, None, None, None);
    for seg in parts {
        if let Some(a) = seg.strip_prefix('a') {
            if alpha.is_some() {
                return None;
            }
            alpha = Some(a.parse().ok()?);
        } else if let Some(g) = seg.strip_prefix('g') {
            if kv_heads.is_some() {
                return None;
            }
            let g: usize = g.parse().ok()?;
            if g == 0 {
                return None;
            }
            kv_heads = Some(g);
        } else if let Some(p) = seg.strip_prefix('p') {
            if pages.is_some() {
                return None;
            }
            let p: usize = p.parse().ok()?;
            if p == 0 {
                return None;
            }
            pages = Some(p);
        } else if let Some(f) = seg.strip_prefix('f') {
            if fault_seed.is_some() {
                return None;
            }
            fault_seed = Some(f.parse().ok()?);
        } else {
            return None;
        }
    }
    Some(DecodeRoute { mode, prec, alpha_len: alpha, kv_heads, pages, fault_seed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{HeadGroups, KvConfig};
    use crate::testkit::Rng;

    #[test]
    fn decode_route_parsing() {
        let r = parse_decode_route("decode:rexp:uint8").unwrap();
        assert_eq!(
            r,
            DecodeRoute {
                mode: Mode::Rexp,
                prec: Precision::Uint8,
                alpha_len: None,
                kv_heads: None,
                pages: None,
                fault_seed: None,
            }
        );
        let r = parse_decode_route("decode:lut2d:int16:a512:g2:p256").unwrap();
        assert_eq!(
            r,
            DecodeRoute {
                mode: Mode::Lut2d,
                prec: Precision::Int16,
                alpha_len: Some(512),
                kv_heads: Some(2),
                pages: Some(256),
                fault_seed: None,
            }
        );
        let r = parse_decode_route("decode:rexp:uint8:g4").unwrap();
        assert_eq!((r.alpha_len, r.kv_heads, r.pages), (None, Some(4), None));
        let r = parse_decode_route("decode:rexp:uint8:g2:p64:f7").unwrap();
        assert_eq!((r.kv_heads, r.pages, r.fault_seed), (Some(2), Some(64), Some(7)));
        // seed 0 is a valid (distinct) schedule, not "disabled"
        assert_eq!(parse_decode_route("decode:rexp:uint8:f0").unwrap().fault_seed, Some(0));
        assert!(parse_decode_route("decode:exact:uint8").is_none(), "non-LUT mode");
        assert!(parse_decode_route("attn:rexp:uint8").is_none());
        assert!(parse_decode_route("decode:rexp").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:g0").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:p0").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:x3").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:g2:g4").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:p8:p9").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:f1:f2").is_none());
        assert!(parse_decode_route("decode:rexp:uint8:fx").is_none());
    }

    #[test]
    fn decode_rows_are_probability_mixes_of_v() {
        // V = constant 1.0 rows => every output element must be ~1.0
        // (softmax rows mix to 1 within LUT quantization), pages crossed
        let (h, g, d, ps) = (4usize, 2usize, 8usize, 4usize);
        let mut kv = KvPool::new(KvConfig { pages: 16, page_size: ps, kv_heads: g, d_head: d });
        let a = DECODE_AFFINE;
        let mut seq = KvSeq::new(HeadGroups::new(h, g).unwrap(), a, a);
        let dec = DecodeAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let mut rng = Rng::new(4);
        let mut scr = AttnScratch::new();
        let one = a.quantize(1.0);
        let vrow = vec![one; g * d];
        for _ in 0..11 {
            let krow: Vec<i8> = (0..g * d).map(|_| a.quantize(rng.normal() as f32)).collect();
            let qrow: Vec<i8> = (0..h * d).map(|_| a.quantize(rng.normal() as f32)).collect();
            let mut out = vec![0.0f32; h * d];
            dec.step(&mut kv, &mut seq, &qrow, a, &krow, &vrow, &mut out, &mut scr).unwrap();
            // same bound the prefill kernel's row-sum test uses: LUT
            // normalizer quantization, not exact unity
            for (i, &o) in out.iter().enumerate() {
                assert!(o > 0.5 && o < 1.5, "elem {i} = {o} after {} tokens", seq.len());
            }
        }
        assert_eq!(seq.pages().len(), 3);
    }

    #[test]
    fn group_major_and_head_major_sweeps_are_bit_identical() {
        // the tentpole invariant, at unit scale (the conformance harness
        // sweeps it): a pure reorder of page reads — steps and chunks
        // produce == outputs in both orders, pages crossed mid-prefix
        let (h, g, d, ps) = (4usize, 2usize, 8usize, 4usize);
        let a = DECODE_AFFINE;
        let groups = HeadGroups::new(h, g).unwrap();
        let cfg = KvConfig { pages: 16, page_size: ps, kv_heads: g, d_head: d };
        for mode in [Mode::Rexp, Mode::Lut2d] {
            let grp = DecodeAttention::new(mode, Precision::Uint8, None).unwrap();
            assert_eq!(grp.order(), SweepOrder::GroupMajor);
            let hed =
                DecodeAttention::with_order(mode, Precision::Uint8, None, SweepOrder::HeadMajor)
                    .unwrap();
            let (mut kv_g, mut kv_h) = (KvPool::new(cfg), KvPool::new(cfg));
            let mut sg = KvSeq::new(groups, a, a);
            let mut sh = KvSeq::new(groups, a, a);
            let mut rng = Rng::new(21);
            let mut scr = AttnScratch::new();
            for t in 0..9 {
                let qrow: Vec<i8> = (0..h * d).map(|_| rng.int(-128, 127) as i8).collect();
                let krow: Vec<i8> = (0..g * d).map(|_| rng.int(-128, 127) as i8).collect();
                let vrow: Vec<i8> = (0..g * d).map(|_| rng.int(-128, 127) as i8).collect();
                let mut og = vec![0.0f32; h * d];
                let mut oh = vec![0.0f32; h * d];
                grp.step(&mut kv_g, &mut sg, &qrow, a, &krow, &vrow, &mut og, &mut scr).unwrap();
                hed.step(&mut kv_h, &mut sh, &qrow, a, &krow, &vrow, &mut oh, &mut scr).unwrap();
                assert_eq!(og, oh, "{mode:?} step {t}");
            }
            // a chunk on top of the step prefix, both orders
            let tc = 6usize;
            let qc: Vec<i8> = (0..tc * h * d).map(|_| rng.int(-128, 127) as i8).collect();
            let kc: Vec<i8> = (0..tc * g * d).map(|_| rng.int(-128, 127) as i8).collect();
            let vc: Vec<i8> = (0..tc * g * d).map(|_| rng.int(-128, 127) as i8).collect();
            let mut og = vec![0.0f32; tc * h * d];
            let mut oh = vec![0.0f32; tc * h * d];
            grp.prefill_chunk(&mut kv_g, &mut sg, &qc, a, &kc, &vc, &mut og, &mut scr).unwrap();
            hed.prefill_chunk(&mut kv_h, &mut sh, &qc, a, &kc, &vc, &mut oh, &mut scr).unwrap();
            assert_eq!(og, oh, "{mode:?} chunk");
            kv_g.close(sg);
            kv_h.close(sh);
        }
    }

    #[test]
    fn prefill_chunk_matches_single_steps_and_is_atomic() {
        let (h, g, d, ps) = (2usize, 1usize, 4usize, 2usize);
        let a = DECODE_AFFINE;
        let groups = HeadGroups::new(h, g).unwrap();
        let cfg = KvConfig { pages: 4, page_size: ps, kv_heads: g, d_head: d };
        let (mut kv_a, mut kv_b) = (KvPool::new(cfg), KvPool::new(cfg));
        let mut sa = KvSeq::new(groups, a, a);
        let mut sb = KvSeq::new(groups, a, a);
        let dec = DecodeAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let mut rng = Rng::new(9);
        let mut scr = AttnScratch::new();
        let t = 5usize;
        let q: Vec<i8> = (0..t * h * d).map(|_| rng.int(-64, 64) as i8).collect();
        let ks: Vec<i8> = (0..t * g * d).map(|_| rng.int(-64, 64) as i8).collect();
        let vs: Vec<i8> = (0..t * g * d).map(|_| rng.int(-64, 64) as i8).collect();
        let mut chunk_out = vec![0.0f32; t * h * d];
        dec.prefill_chunk(&mut kv_a, &mut sa, &q, a, &ks, &vs, &mut chunk_out, &mut scr)
            .unwrap();
        for tt in 0..t {
            let mut got = vec![0.0f32; h * d];
            dec.step(
                &mut kv_b,
                &mut sb,
                &q[tt * h * d..(tt + 1) * h * d],
                a,
                &ks[tt * g * d..(tt + 1) * g * d],
                &vs[tt * g * d..(tt + 1) * g * d],
                &mut got,
                &mut scr,
            )
            .unwrap();
            assert_eq!(&chunk_out[tt * h * d..(tt + 1) * h * d], &got[..], "step {tt}");
        }
        // empty chunk: a no-op
        dec.prefill_chunk(&mut kv_a, &mut sa, &[], a, &[], &[], &mut [], &mut scr).unwrap();
        assert_eq!(sa.len(), t);
        // exhaustion is atomic: 5 of 8 tokens stored (3 pages held, 1
        // free); a 4-token chunk needs 2 more pages -> nothing changes
        let mut out = vec![7.0f32; 4 * h * d];
        let err = dec.prefill_chunk(
            &mut kv_a,
            &mut sa,
            &q[..4 * h * d],
            a,
            &ks[..4 * g * d],
            &vs[..4 * g * d],
            &mut out,
            &mut scr,
        );
        assert_eq!(err, Err(KvError::Exhausted { pages: 4, free_pages: 1 }));
        assert_eq!(sa.len(), t, "failed chunk must not land partially");
        assert!(out.iter().all(|&o| o == 7.0), "failed chunk must not write output");
        kv_a.close(sa);
        kv_b.close(sb);
        assert_eq!(kv_a.free_pages(), 4);
    }

    #[test]
    fn exhausted_step_leaves_output_untouched() {
        let (h, g, d) = (2usize, 1usize, 4usize);
        let mut kv = KvPool::new(KvConfig { pages: 1, page_size: 2, kv_heads: g, d_head: d });
        let a = DECODE_AFFINE;
        let mut seq = KvSeq::new(HeadGroups::new(h, g).unwrap(), a, a);
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let mut scr = AttnScratch::new();
        let row = vec![3i8; g * d];
        let q = vec![1i8; h * d];
        let mut out = vec![7.0f32; h * d];
        for _ in 0..2 {
            dec.step(&mut kv, &mut seq, &q, a, &row, &row, &mut out, &mut scr).unwrap();
        }
        out.fill(7.0);
        let err = dec.step(&mut kv, &mut seq, &q, a, &row, &row, &mut out, &mut scr);
        assert_eq!(err, Err(KvError::Exhausted { pages: 1, free_pages: 0 }));
        assert!(out.iter().all(|&o| o == 7.0), "failed step must not write output");
        assert_eq!(seq.len(), 2);
    }
}
