//! Batched decode: many concurrent sessions' steps in ONE head-scatter
//! wave.
//!
//! PR 3's serving loop scattered each decode step's `H` head rows across
//! the [`ParSoftmax`] pool per request — `S` concurrent sessions paid `S`
//! pool wakes per serving round, and short-prefix steps never reached the
//! pool at all because `H` alone sits under the row threshold.
//! [`DecodeBatch`] collects the per-step work of a whole round into one
//! wave of independent sweep tasks — `S × G` *group* tasks under the
//! group-major order (each sweeping its KV pages once for all `H/G`
//! query heads of the group, the PR 5 read-amplification fix), or
//! `S × H` head-row tasks under the head-major reference — and submits
//! it as a single [`ParSoftmax::scatter`], so the wake (and the
//! page-gather setup) is amortized across every session in the round —
//! the batch-shaped datapath A³/SOLE assume, mirrored in hwsim by
//! [`crate::hwsim::simulate_decode_batched`].
//!
//! # The anchor property (ordering + bit-reproducibility)
//!
//! A batched round over `S` sessions is **`==`-bit-identical** to `S`
//! serial [`DecodeAttention::step`] calls in ANY interleaving order.
//! That holds by construction, not by luck:
//!
//! * **phase 1 (serial)**: every task's K/V row is appended to its own
//!   sequence ([`KvPool::append`]) in wave order. Appends touch only
//!   pages owned by their sequence, so sessions cannot observe each
//!   other's appends — only the *page-id assignment* depends on order,
//!   and no output ever reads a page id.
//! * **phase 2 (parallel)**: each sweep task is a pure function of
//!   its own sequence's pages and the step plan (the same `group_step` /
//!   `head_step` expressions a serial step runs), and writes a disjoint
//!   output block (one group's contiguous `H/G · d_head`, or one head's
//!   `d_head`). Scatter order is therefore unobservable.
//!
//! Exhaustion is per-task: a session whose append hits
//! [`KvError::Exhausted`] fails alone (its output untouched, its
//! sequence unchanged, the step retryable after a close frees pages)
//! while the rest of the wave proceeds — property-tested in
//! `integration_decode_batch.rs`. Waves also support **mid-round
//! admission of freed capacity**: [`DecodeBatch::step_wave_with`] takes
//! an exhaustion hook that may reclaim pages (the continuous-batching
//! scheduler evicts the youngest idle session there) and have the failed
//! append retried in place, so one starved task no longer forfeits its
//! round when capacity could be made available. The hook runs between
//! phase-1 appends — never during the parallel sweep — so the
//! bit-reproducibility argument below is unchanged.
//!
//! # Wave accounting
//!
//! The inline-vs-scatter decision counts the WHOLE wave's head rows
//! (`S × H`) and MACs — counting per session would keep row-rich waves
//! inline (the PR 4 fix, regression-tested in `integration_par.rs`) —
//! and since group tasks are `H/G×` heavier than head rows, the pool's
//! row threshold is asked with MAC-weighted row equivalents
//! (`wave_stays_inline`, shared with `step_par` / `prefill_chunk_par`),
//! never the raw task count.
//!
//! # Prefix-split waves (S × G × spans)
//!
//! With a split threshold configured
//! ([`DecodeBatch::with_split_min_tokens`]; the scheduler's
//! `split_min_tokens` knob), a group-major task whose prefix has reached
//! the threshold fans out as one sweep unit per page-aligned prefix
//! *span* ([`DecodeAttention::step_split`]'s wave form): each span unit
//! computes the span's integer partials into its own disjoint block of
//! the wave's partial buffers, and a serial merge phase after the
//! scatter folds each group row's spans
//! (`DecodeAttention::merge_group_row`) and writes the output. The
//! merge runs on the caller's thread — never concurrently with span
//! units — so the bit-reproducibility argument above is unchanged, and
//! a wave's output is bit-identical to the same sessions' serial
//! [`DecodeAttention::step_split`] calls (and to unsplit steps whenever
//! the span maxima are LUT-index-aligned; see the decode module docs).
//! Long-context G = 1 (MQA) steps — previously a single sweep unit —
//! thus regain worker fan-out.

use std::fmt;

use super::decode::{check_step_shapes, span_page_range, spans_for, StepPlan, SweepOrder};
use super::kernel::{wave_stays_inline, AttnScratch, OutPtr};
use super::DecodeAttention;
use crate::kv::{KvError, KvPool, KvSeq};
use crate::quant::Affine;
use crate::softmax::{lock_unpoisoned, ParSoftmax, Scratch};

/// Per-task failure of a batched decode wave. The two variants have
/// opposite retry semantics, and serving layers must honor the split:
///
/// * [`WaveError::Kv`] — the phase-1 append failed (typed backpressure or
///   an injected allocation fault). The task's sequence and output are
///   **untouched**; the same step is retryable after capacity frees up.
/// * [`WaveError::Panicked`] — a phase-2 sweep unit of this task panicked
///   (contained by the pool; siblings unaffected). The session's K/V row
///   was **already appended** in phase 1, so state has advanced and only
///   the output is lost: the step must NOT be replayed (that would
///   double-append). Fail the step with a typed reply; the session stays
///   live and its next step is well-defined.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WaveError {
    /// KV allocation failure in the serial append phase (retryable)
    Kv(KvError),
    /// a sweep task panicked in the parallel phase (not retryable:
    /// the append already landed)
    Panicked,
}

impl From<KvError> for WaveError {
    fn from(e: KvError) -> Self {
        WaveError::Kv(e)
    }
}

impl fmt::Display for WaveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WaveError::Kv(e) => write!(f, "{e}"),
            WaveError::Panicked => write!(f, "decode sweep task panicked (step output lost)"),
        }
    }
}

impl std::error::Error for WaveError {}

/// Measured traffic of one executed wave, for the serving layer's
/// metrics registry — the MEASURED counterpart of the hwsim charge names
/// (`kv_bytes_read`, wave setup): both publish under the same
/// [`crate::obs::names`] series so simulated and observed traffic are
/// directly comparable. Counting rides the existing phase-2 accounting
/// loop; it adds no KV reads of its own.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WaveStats {
    /// Σ query-head rows over the surviving tasks (`S × H`)
    pub rows: usize,
    /// Σ sweep MACs over the surviving tasks (`Σ h · seq_len · d`)
    pub macs: usize,
    /// sweep units submitted (group tasks, or head rows head-major)
    pub units: usize,
    /// whether the wave ran inline (under `wave_stays_inline`) instead
    /// of as a pool scatter
    pub inline: bool,
    /// K+V bytes the sweep reads: Σ over surviving tasks of
    /// `seq_len · kv_heads · d_head · 2` (each page once per group —
    /// the group-major contract; a split group's spans partition its
    /// pages, so splitting never re-reads)
    pub kv_bytes: u64,
    /// prefix-span sweep units submitted (0 when no task split) —
    /// published as `wave_span_units_total`
    pub span_units: usize,
    /// tasks that ran the prefix-split sweep this wave — published as
    /// `wave_split_tasks_total`
    pub split_tasks: usize,
}

/// One session's contribution to a batched decode round: the same inputs
/// a single [`DecodeAttention::step`] takes, borrowed so the wave can
/// prove (via `&mut`) that sequences and outputs are pairwise disjoint.
pub struct DecodeStepTask<'a> {
    pub seq: &'a mut KvSeq,
    /// `H * d_head` quantized query rows, `[h][d]`
    pub q: &'a [i8],
    pub q_affine: Affine,
    /// `G * d_head` new-token K rows, `[g][d]`
    pub k_row: &'a [i8],
    /// `G * d_head` new-token V rows, `[g][d]`
    pub v_row: &'a [i8],
    /// `H * d_head` f32 output, `[h][d]` — untouched on a failed append
    pub out: &'a mut [f32],
}

/// The batched decode scheduler's kernel layer: one wave of `S × G`
/// group tasks (or `S × H` head rows under the head-major reference
/// order) per serving round over a shared [`DecodeAttention`]. See the
/// module docs for the ordering / bit-reproducibility contract.
pub struct DecodeBatch<'d> {
    dec: &'d DecodeAttention,
    /// prefix-split threshold: group-major tasks with at least this many
    /// resident tokens fan out as span units (`0` = splitting off, the
    /// default — waves are then unconditionally bit-identical to serial
    /// `step` calls)
    split_min_tokens: usize,
}

/// One sweep unit of a batched round: a KV group (group-major) or a
/// single query head (head-major) of one session's step.
struct SweepTask<'b> {
    seq: &'b KvSeq,
    /// the unit's query rows: a group's contiguous `H/G · d_head` block,
    /// or one head's `d_head` slice
    q: &'b [i8],
    plan: StepPlan,
    /// group index (group-major) or query-head index (head-major)
    /// within the session
    unit: usize,
    /// elements of the unit's output block at `out`
    out_len: usize,
    out: OutPtr,
}

/// `Send`/`Sync` shims for the disjoint span-partial blocks the split
/// wave fans across the pool — same contract as [`OutPtr`]: each unit
/// reconstructs only its own block, and the scatter blocks until every
/// unit finished.
struct I32Ptr(*mut i32);
unsafe impl Send for I32Ptr {}
unsafe impl Sync for I32Ptr {}
struct I64Ptr(*mut i64);
unsafe impl Send for I64Ptr {}
unsafe impl Sync for I64Ptr {}

/// One prefix-span unit of a split group task: computes the span's
/// integer partials ([`DecodeAttention::group_prefix_span`]) into its
/// own contiguous block of the wave's partial buffers; the post-scatter
/// merge phase folds them.
struct SpanTask<'b> {
    seq: &'b KvSeq,
    /// the group's contiguous `H/G · d_head` query block
    q: &'b [i8],
    plan: StepPlan,
    gi: usize,
    /// this span's page range within the sequence's resident pages
    pages: std::ops::Range<usize>,
    rows: usize,
    /// base pointers of this span's partial blocks (`rows`,
    /// `rows · T`, `rows · T · d` elements)
    m: I32Ptr,
    cnt: I32Ptr,
    vs: I64Ptr,
}

/// A phase-2 wave unit: a whole-group/head sweep or one prefix span.
enum WaveUnit<'b> {
    Sweep(SweepTask<'b>),
    Span(SpanTask<'b>),
}

/// Deferred merge of one split group: runs serially after the scatter,
/// folding each of the group's `rows` query rows across its spans and
/// writing the group's output block.
struct MergeJob {
    /// owning task — skipped if the task failed (append or panic)
    ti: usize,
    plan: StepPlan,
    spans: usize,
    rows: usize,
    valid: usize,
    /// element offsets of the group's span-major partial region
    m_off: usize,
    cnt_off: usize,
    vs_off: usize,
    out: OutPtr,
}

impl<'d> DecodeBatch<'d> {
    /// Wrap an existing per-route kernel (shares its scratch pool).
    /// Prefix splitting starts off; see
    /// [`Self::with_split_min_tokens`].
    pub fn new(dec: &'d DecodeAttention) -> Self {
        Self { dec, split_min_tokens: 0 }
    }

    /// Enable the prefix-split sweep for group-major tasks whose prefix
    /// has at least `n` resident tokens (`0` disables, the default).
    /// Span counts follow [`spans_for`]. Head-major waves (the
    /// conformance reference order) never split.
    pub fn with_split_min_tokens(mut self, n: usize) -> Self {
        self.split_min_tokens = n;
        self
    }

    /// The configured prefix-split threshold (`0` = off).
    pub fn split_min_tokens(&self) -> usize {
        self.split_min_tokens
    }

    /// The wrapped per-step kernel.
    pub fn decode(&self) -> &DecodeAttention {
        self.dec
    }

    /// One batched decode round: append every task's token (phase 1,
    /// serial, per-task exhaustion), then attend all surviving tasks'
    /// sweep units (`S × G` group tasks, or `S × H` head rows
    /// head-major) in ONE [`ParSoftmax::scatter`] wave (phase 2) — or
    /// inline when the whole wave sits under the shared accounting
    /// (`wave_stays_inline`: total MACs + MAC-weighted row equivalents).
    /// Returns one result per task, in task order; failed tasks'
    /// sequences and outputs are untouched.
    pub fn step_wave(
        &self,
        kv: &mut KvPool,
        tasks: &mut [DecodeStepTask<'_>],
        pool: &ParSoftmax,
        scr: &mut AttnScratch,
    ) -> Vec<Result<(), WaveError>> {
        self.step_wave_with(kv, tasks, pool, scr, |_, _| false)
    }

    /// [`Self::step_wave`] with an exhaustion hook: when task `i`'s
    /// phase-1 append fails with [`KvError::Exhausted`], the hook is
    /// called with the pool and the task index and may free capacity
    /// (e.g. evict an idle session's pages back to the free list). If it
    /// returns `true` the append is retried; it may be called repeatedly
    /// for the same task until the append lands or it returns `false`
    /// (the task then fails exactly as under [`Self::step_wave`]). The
    /// hook must not touch any sequence borrowed by the wave's tasks —
    /// the `&mut` borrows here already guarantee it cannot.
    pub fn step_wave_with(
        &self,
        kv: &mut KvPool,
        tasks: &mut [DecodeStepTask<'_>],
        pool: &ParSoftmax,
        scr: &mut AttnScratch,
        on_exhausted: impl FnMut(&mut KvPool, usize) -> bool,
    ) -> Vec<Result<(), WaveError>> {
        self.step_wave_with_stats(kv, tasks, pool, scr, on_exhausted).0
    }

    /// [`Self::step_wave_with`] plus the wave's measured traffic
    /// ([`WaveStats`]) — what the serving layer feeds the metrics
    /// registry. Results are identical to `step_wave_with`'s.
    pub fn step_wave_with_stats(
        &self,
        kv: &mut KvPool,
        tasks: &mut [DecodeStepTask<'_>],
        pool: &ParSoftmax,
        scr: &mut AttnScratch,
        mut on_exhausted: impl FnMut(&mut KvPool, usize) -> bool,
    ) -> (Vec<Result<(), WaveError>>, WaveStats) {
        // phase 1: serial appends, task order (page-id assignment is the
        // only order-dependent effect, and nothing downstream reads it)
        let mut results: Vec<Result<(), WaveError>> = tasks
            .iter_mut()
            .enumerate()
            .map(|(i, t)| loop {
                match kv.append(t.seq, t.k_row, t.v_row) {
                    Ok(()) => break Ok(()),
                    Err(e) => {
                        if !on_exhausted(kv, i) {
                            break Err(WaveError::Kv(e));
                        }
                    }
                }
            })
            .collect();

        // phase 2: flatten the surviving tasks into wave units (whole
        // group/head sweeps, or per-span partial sweeps for split
        // tasks), remembering each unit's owning task so a contained
        // panic can be mapped back to exactly one session
        let kv_ref: &KvPool = kv;
        let d = kv_ref.config().d_head;
        let psize = kv_ref.config().page_size;
        let t_len = self.dec.kernel().table().len();
        let order = self.dec.order();

        // pass A: size the span-partial buffers (split tasks only, so
        // the common unsplit wave allocates nothing). Span counts here
        // and in pass B come from the same `spans_for` call, so the
        // offsets agree.
        let (mut m_total, mut cnt_total, mut vs_total) = (0usize, 0usize, 0usize);
        if matches!(order, SweepOrder::GroupMajor) && self.split_min_tokens > 0 {
            for (t, res) in tasks.iter().zip(&results) {
                if res.is_err() {
                    continue;
                }
                let spans = spans_for(t.seq.len(), psize, self.split_min_tokens);
                if spans < 2 {
                    continue;
                }
                let r = t.seq.groups().group_size();
                let g = t.seq.groups().kv_heads();
                m_total += g * spans * r;
                cnt_total += g * spans * r * t_len;
                vs_total += g * spans * r * t_len * d;
            }
        }
        let mut m_buf = vec![0i32; m_total];
        let mut cnt_buf = vec![0i32; cnt_total];
        let mut vs_buf = vec![0i64; vs_total];
        let (m_base, cnt_base, vs_base) =
            (m_buf.as_mut_ptr(), cnt_buf.as_mut_ptr(), vs_buf.as_mut_ptr());

        // pass B: build the units, merge jobs, and traffic accounting
        let mut units: Vec<WaveUnit<'_>> = Vec::new();
        let mut owners: Vec<usize> = Vec::new();
        let mut merges: Vec<MergeJob> = Vec::new();
        let (mut m_off, mut cnt_off, mut vs_off) = (0usize, 0usize, 0usize);
        let mut wave_rows = 0usize;
        let mut wave_macs = 0usize;
        let mut kv_bytes = 0u64;
        let mut span_units = 0usize;
        let mut split_tasks = 0usize;
        for (ti, (t, res)) in tasks.iter_mut().zip(&results).enumerate() {
            if res.is_err() {
                continue;
            }
            let h = t.seq.groups().q_heads();
            check_step_shapes(t.q, t.out, h, d);
            let plan = self.dec.plan(t.seq, d, t.q_affine);
            wave_rows += h;
            wave_macs += h * t.seq.len() * d;
            // a split group's spans partition its pages, so splitting
            // never re-reads: the round's KV traffic is span-count
            // independent
            kv_bytes += (t.seq.len() * t.seq.groups().kv_heads() * d * 2) as u64;
            let seq: &KvSeq = t.seq;
            let optr = t.out.as_mut_ptr();
            match order {
                SweepOrder::HeadMajor => {
                    for hh in 0..h {
                        units.push(WaveUnit::Sweep(SweepTask {
                            seq,
                            q: &t.q[hh * d..(hh + 1) * d],
                            plan,
                            unit: hh,
                            out_len: d,
                            // SAFETY: within `out`'s `h * d` allocation
                            // (shape checked above); disjoint per head
                            out: OutPtr(unsafe { optr.add(hh * d) }),
                        }));
                        owners.push(ti);
                    }
                }
                SweepOrder::GroupMajor => {
                    let r = seq.groups().group_size();
                    let valid = seq.len();
                    let spans = if self.split_min_tokens > 0 {
                        spans_for(valid, psize, self.split_min_tokens)
                    } else {
                        1
                    };
                    let npages = valid.div_ceil(psize).max(1);
                    for gi in 0..seq.groups().kv_heads() {
                        let q = &t.q[gi * r * d..(gi * r + r) * d];
                        // SAFETY: within `out`'s `h * d` allocation
                        // (shape checked above); disjoint per group
                        let optr_g = unsafe { optr.add(gi * r * d) };
                        if spans < 2 {
                            units.push(WaveUnit::Sweep(SweepTask {
                                seq,
                                q,
                                plan,
                                unit: gi,
                                out_len: r * d,
                                out: OutPtr(optr_g),
                            }));
                            owners.push(ti);
                            continue;
                        }
                        merges.push(MergeJob {
                            ti,
                            plan,
                            spans,
                            rows: r,
                            valid,
                            m_off,
                            cnt_off,
                            vs_off,
                            out: OutPtr(optr_g),
                        });
                        for p in 0..spans {
                            units.push(WaveUnit::Span(SpanTask {
                                seq,
                                q,
                                plan,
                                gi,
                                pages: span_page_range(npages, spans, p),
                                rows: r,
                                // SAFETY: pass A sized the buffers from
                                // the same spans_for/geometry walk, so
                                // span p's block `[off + p·sz, off +
                                // (p+1)·sz)` is in-bounds and disjoint
                                // from every other span unit's
                                m: I32Ptr(unsafe { m_base.add(m_off + p * r) }),
                                cnt: I32Ptr(unsafe { cnt_base.add(cnt_off + p * r * t_len) }),
                                vs: I64Ptr(unsafe { vs_base.add(vs_off + p * r * t_len * d) }),
                            }));
                            owners.push(ti);
                        }
                        m_off += spans * r;
                        cnt_off += spans * r * t_len;
                        vs_off += spans * r * t_len * d;
                        span_units += spans;
                    }
                    if spans >= 2 {
                        split_tasks += 1;
                    }
                }
            }
        }
        debug_assert!(m_off == m_total && cnt_off == cnt_total && vs_off == vs_total);

        // wave accounting: the WHOLE round's head rows and MACs decide
        // the inline-vs-scatter trade (never per session — the PR 4 fix
        // — and never the raw group-task count, which undercounts by
        // H/G per task)
        let run_unit = |ut: &WaveUnit<'_>, us: &mut AttnScratch| match ut {
            WaveUnit::Sweep(st) => {
                let ob = unsafe { std::slice::from_raw_parts_mut(st.out.0, st.out_len) };
                match order {
                    SweepOrder::HeadMajor => {
                        self.dec.head_step(kv_ref, st.seq, st.unit, st.q, st.plan, ob, us)
                    }
                    SweepOrder::GroupMajor => {
                        self.dec.group_step(kv_ref, st.seq, st.unit, st.q, st.plan, ob, us)
                    }
                }
            }
            WaveUnit::Span(sp) => {
                let r = sp.rows;
                // SAFETY: this unit's own disjoint partial block (see
                // the pass-B pointer construction); the scatter joins
                // before the merge phase reads any of it
                let (m, cnt, vs) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(sp.m.0, r),
                        std::slice::from_raw_parts_mut(sp.cnt.0, r * t_len),
                        std::slice::from_raw_parts_mut(sp.vs.0, r * t_len * d),
                    )
                };
                self.dec.group_prefix_span(
                    kv_ref,
                    sp.seq,
                    sp.gi,
                    sp.q,
                    sp.plan,
                    sp.seq.len(),
                    sp.pages.clone(),
                    m,
                    cnt,
                    vs,
                    us,
                );
            }
        };
        // both arms run units under the pool's containment (and fault
        // schedule): a panicking unit is contained, mapped below to its
        // owning task, and must never poison the pool or the spare stack.
        // The caller's scratch is lent to the spare stack for the wave,
        // so the inline arm keeps its amortized buffers.
        let spare = &self.dec.spare;
        lock_unpoisoned(spare).push(std::mem::take(scr));
        let run = |i: usize, _s: &mut Scratch| {
            let mut hs = lock_unpoisoned(spare).pop().unwrap_or_default();
            run_unit(&units[i], &mut hs);
            lock_unpoisoned(spare).push(hs);
        };
        let mut pool_scratch = Scratch::new();
        let inline = wave_stays_inline(pool, units.len(), wave_rows, wave_macs);
        let outcome = if inline {
            pool.scatter_inline(units.len(), &mut pool_scratch, &run)
        } else {
            pool.scatter(units.len(), &mut pool_scratch, &run)
        };
        if let Some(hs) = lock_unpoisoned(spare).pop() {
            *scr = hs;
        }
        let stats = WaveStats {
            rows: wave_rows,
            macs: wave_macs,
            units: units.len(),
            inline,
            kv_bytes,
            span_units,
            split_tasks,
        };
        for &u in outcome.panicked() {
            // the owner's phase-1 append already landed: state advanced,
            // output lost — exactly one typed failure per panicked task
            // (a task's first panicked unit wins; repeats are idempotent)
            results[owners[u]] = Err(WaveError::Panicked);
        }
        // phase 3: serial merge of each split group's span partials —
        // the identical fold the serial `step_split` path runs, so the
        // wave output stays bit-identical to per-session split steps.
        // Groups owned by a failed task are skipped: their output stays
        // untouched, matching the sweep arm's panic contract (a panicked
        // span leaves its partial block zeroed, which must never be
        // folded into a row the caller will read).
        for job in &merges {
            if results[job.ti].is_err() {
                continue;
            }
            scr.prepare_decode_split(job.rows, 1, d, t_len, job.spans);
            for rr in 0..job.rows {
                // SAFETY: row rr of this group's output block; every
                // span unit of the group has joined, and failed owners
                // were skipped above
                let ob = unsafe { std::slice::from_raw_parts_mut(job.out.0.add(rr * d), d) };
                self.dec.merge_group_row(
                    job.plan,
                    d,
                    job.valid,
                    job.spans,
                    job.rows,
                    &m_buf[job.m_off + rr..],
                    &cnt_buf[job.cnt_off + rr * t_len..],
                    &vs_buf[job.vs_off + rr * t_len * d..],
                    ob,
                    scr,
                );
            }
        }
        (results, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::DECODE_AFFINE;
    use crate::kv::{HeadGroups, KvConfig};
    use crate::lut::Precision;
    use crate::softmax::{engine_parallel, Mode};
    use crate::testkit::Rng;

    #[test]
    fn one_wave_matches_serial_steps_bitwise() {
        let (s, h, g, d) = (3usize, 2usize, 1usize, 8usize);
        let a = DECODE_AFFINE;
        let cfg = KvConfig { pages: 16, page_size: 4, kv_heads: g, d_head: d };
        let (mut kv_w, mut kv_s) = (KvPool::new(cfg), KvPool::new(cfg));
        let groups = HeadGroups::new(h, g).unwrap();
        let mut wave_seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
        let mut ser_seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let batch = DecodeBatch::new(&dec);
        let pool = engine_parallel(Mode::Lut2d, Precision::Uint8, None, Some(3));
        let mut rng = Rng::new(14);
        let mut scr = AttnScratch::new();
        for round in 0..7 {
            let qs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..h * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let ks: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let vs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let mut wave_out = vec![vec![0.0f32; h * d]; s];
            let mut tasks: Vec<DecodeStepTask<'_>> = wave_seqs
                .iter_mut()
                .zip(wave_out.iter_mut())
                .enumerate()
                .map(|(i, (seq, out))| DecodeStepTask {
                    seq,
                    q: &qs[i],
                    q_affine: a,
                    k_row: &ks[i],
                    v_row: &vs[i],
                    out,
                })
                .collect();
            let res = batch.step_wave(&mut kv_w, &mut tasks, &pool, &mut scr);
            assert!(res.iter().all(|r| r.is_ok()));
            drop(tasks);
            // serial replay in REVERSE session order: interleaving must
            // not matter
            for i in (0..s).rev() {
                let mut want = vec![0.0f32; h * d];
                dec.step(&mut kv_s, &mut ser_seqs[i], &qs[i], a, &ks[i], &vs[i], &mut want, &mut scr)
                    .unwrap();
                assert_eq!(wave_out[i], want, "round {round} session {i}");
            }
        }
        for seq in wave_seqs {
            kv_w.close(seq);
        }
        assert_eq!(kv_w.free_pages(), 16);
        for seq in ser_seqs {
            kv_s.close(seq);
        }
    }

    #[test]
    fn split_wave_matches_serial_split_steps_bitwise() {
        let (s, h, g, d) = (3usize, 4usize, 2usize, 8usize);
        let a = DECODE_AFFINE;
        let cfg = KvConfig { pages: 32, page_size: 4, kv_heads: g, d_head: d };
        let (mut kv_w, mut kv_s) = (KvPool::new(cfg), KvPool::new(cfg));
        let groups = HeadGroups::new(h, g).unwrap();
        let mut wave_seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
        let mut ser_seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
        let dec = DecodeAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let min = 4usize;
        let batch = DecodeBatch::new(&dec).with_split_min_tokens(min);
        assert_eq!(batch.split_min_tokens(), min);
        let pool = engine_parallel(Mode::Rexp, Precision::Uint8, None, Some(3));
        let mut rng = Rng::new(77);
        let mut scr = AttnScratch::new();
        // session 0 starts with a longer prefix so waves mix split and
        // unsplit tasks (same prefix in both pools)
        for _ in 0..6 {
            let kr: Vec<i8> = (0..g * d).map(|_| rng.int(-96, 96) as i8).collect();
            let vr: Vec<i8> = (0..g * d).map(|_| rng.int(-96, 96) as i8).collect();
            kv_w.append(&mut wave_seqs[0], &kr, &vr).unwrap();
            kv_s.append(&mut ser_seqs[0], &kr, &vr).unwrap();
        }
        let mut saw_mixed = false;
        for round in 0..12 {
            let qs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..h * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let ks: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let vs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            // the span plan the wave will use, from the post-append
            // lengths (serial pool is pre-append here)
            let expect: Vec<usize> =
                ser_seqs.iter().map(|q| spans_for(q.len() + 1, cfg.page_size, min)).collect();
            let mut wave_out = vec![vec![0.0f32; h * d]; s];
            let mut tasks: Vec<DecodeStepTask<'_>> = wave_seqs
                .iter_mut()
                .zip(wave_out.iter_mut())
                .enumerate()
                .map(|(i, (seq, out))| DecodeStepTask {
                    seq,
                    q: &qs[i],
                    q_affine: a,
                    k_row: &ks[i],
                    v_row: &vs[i],
                    out,
                })
                .collect();
            let (res, stats) =
                batch.step_wave_with_stats(&mut kv_w, &mut tasks, &pool, &mut scr, |_, _| false);
            assert!(res.iter().all(|r| r.is_ok()));
            drop(tasks);
            let split = expect.iter().filter(|&&sp| sp >= 2).count();
            assert_eq!(stats.split_tasks, split, "round {round}");
            assert_eq!(
                stats.span_units,
                expect.iter().map(|&sp| if sp >= 2 { g * sp } else { 0 }).sum::<usize>(),
                "round {round}"
            );
            assert_eq!(stats.units, (s - split) * g + stats.span_units, "round {round}");
            saw_mixed |= split > 0 && split < s;
            // serial replay in REVERSE session order with the same span
            // plan: interleaving must not matter, and the wave's
            // compute-partials-then-merge is the identical fold
            for i in (0..s).rev() {
                let mut want = vec![0.0f32; h * d];
                let rep = dec
                    .step_split(
                        &mut kv_s,
                        &mut ser_seqs[i],
                        &qs[i],
                        a,
                        &ks[i],
                        &vs[i],
                        expect[i],
                        &mut want,
                        &mut scr,
                    )
                    .unwrap();
                assert_eq!(rep.spans, expect[i], "round {round} session {i}");
                assert_eq!(
                    wave_out[i], want,
                    "round {round} session {i} (aligned = {})",
                    rep.aligned
                );
            }
        }
        assert!(saw_mixed, "rounds must mix split and unsplit tasks");
        for seq in wave_seqs {
            kv_w.close(seq);
        }
        assert_eq!(kv_w.free_pages(), 32);
        for seq in ser_seqs {
            kv_s.close(seq);
        }
    }

    #[test]
    fn wave_stats_measure_rows_macs_and_kv_traffic() {
        let (s, h, g, d) = (2usize, 2usize, 1usize, 8usize);
        let a = DECODE_AFFINE;
        let cfg = KvConfig { pages: 16, page_size: 4, kv_heads: g, d_head: d };
        let mut kv = KvPool::new(cfg);
        let groups = HeadGroups::new(h, g).unwrap();
        let mut seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let batch = DecodeBatch::new(&dec);
        let pool = engine_parallel(Mode::Lut2d, Precision::Uint8, None, Some(2));
        let mut rng = Rng::new(33);
        let mut scr = AttnScratch::new();
        for round in 0..3usize {
            let qs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..h * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let ks: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let vs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let mut outs = vec![vec![0.0f32; h * d]; s];
            let mut tasks: Vec<DecodeStepTask<'_>> = seqs
                .iter_mut()
                .zip(outs.iter_mut())
                .enumerate()
                .map(|(i, (seq, out))| DecodeStepTask {
                    seq,
                    q: &qs[i],
                    q_affine: a,
                    k_row: &ks[i],
                    v_row: &vs[i],
                    out,
                })
                .collect();
            let (res, stats) =
                batch.step_wave_with_stats(&mut kv, &mut tasks, &pool, &mut scr, |_, _| false);
            assert!(res.iter().all(|r| r.is_ok()));
            drop(tasks);
            // tokens resident after this round's appends
            let len = round + 1;
            assert_eq!(stats.rows, s * h, "round {round}");
            assert_eq!(stats.macs, s * h * len * d, "round {round}");
            assert_eq!(stats.kv_bytes, (s * len * g * d * 2) as u64, "round {round}");
            assert!(stats.units > 0);
        }
        for seq in seqs {
            kv.close(seq);
        }
    }

    #[test]
    fn exhaustion_hook_reclaims_pages_and_retries_in_place() {
        let (h, g, d) = (2usize, 1usize, 8usize);
        let a = DECODE_AFFINE;
        let cfg = KvConfig { pages: 2, page_size: 4, kv_heads: g, d_head: d };
        let mut kv = KvPool::new(cfg);
        let groups = HeadGroups::new(h, g).unwrap();
        let mut rng = Rng::new(21);
        let mut row = |n: usize| -> Vec<i8> { (0..n).map(|_| rng.int(-96, 96) as i8).collect() };
        // a victim session fills the whole arena
        let mut victim = KvSeq::new(groups, a, a);
        for _ in 0..8 {
            let r = row(g * d);
            kv.append(&mut victim, &r, &r).unwrap();
        }
        assert_eq!(kv.free_pages(), 0);
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let batch = DecodeBatch::new(&dec);
        let pool = engine_parallel(Mode::Lut2d, Precision::Uint8, None, Some(2));
        let mut scr = AttnScratch::new();
        let mut seq = KvSeq::new(groups, a, a);
        let (q, k, v) = (row(h * d), row(g * d), row(g * d));
        let mut out = vec![0.0f32; h * d];
        let mut tasks = vec![DecodeStepTask {
            seq: &mut seq,
            q: &q,
            q_affine: a,
            k_row: &k,
            v_row: &v,
            out: &mut out,
        }];
        // without a hook the task starves as before...
        let res = batch.step_wave(&mut kv, &mut tasks, &pool, &mut scr);
        assert_eq!(res, vec![Err(WaveError::Kv(KvError::Exhausted { pages: 2, free_pages: 0 }))]);
        // ...with a hook that evicts the victim, the same wave lands
        let mut victim = Some(victim);
        let mut evictions = 0usize;
        let res = batch.step_wave_with(&mut kv, &mut tasks, &pool, &mut scr, |kvp, i| {
            assert_eq!(i, 0);
            match victim.take() {
                Some(s) => {
                    kvp.close(s);
                    evictions += 1;
                    true
                }
                None => false,
            }
        });
        assert_eq!(res, vec![Ok(())]);
        assert_eq!(evictions, 1);
        drop(tasks);
        // the retried step is bit-identical to a serial step on a fresh
        // arena — eviction only moved page ids, which nothing reads
        let mut kv_s = KvPool::new(cfg);
        let mut seq_s = KvSeq::new(groups, a, a);
        let mut want = vec![0.0f32; h * d];
        dec.step(&mut kv_s, &mut seq_s, &q, a, &k, &v, &mut want, &mut scr).unwrap();
        assert_eq!(out, want);
        kv.close(seq);
        kv_s.close(seq_s);
    }
}
