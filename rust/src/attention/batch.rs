//! Batched decode: many concurrent sessions' steps in ONE head-scatter
//! wave.
//!
//! PR 3's serving loop scattered each decode step's `H` head rows across
//! the [`ParSoftmax`] pool per request — `S` concurrent sessions paid `S`
//! pool wakes per serving round, and short-prefix steps never reached the
//! pool at all because `H` alone sits under the row threshold.
//! [`DecodeBatch`] collects the per-step work of a whole round into one
//! wave of independent sweep tasks — `S × G` *group* tasks under the
//! group-major order (each sweeping its KV pages once for all `H/G`
//! query heads of the group, the PR 5 read-amplification fix), or
//! `S × H` head-row tasks under the head-major reference — and submits
//! it as a single [`ParSoftmax::scatter`], so the wake (and the
//! page-gather setup) is amortized across every session in the round —
//! the batch-shaped datapath A³/SOLE assume, mirrored in hwsim by
//! [`crate::hwsim::simulate_decode_batched`].
//!
//! # The anchor property (ordering + bit-reproducibility)
//!
//! A batched round over `S` sessions is **`==`-bit-identical** to `S`
//! serial [`DecodeAttention::step`] calls in ANY interleaving order.
//! That holds by construction, not by luck:
//!
//! * **phase 1 (serial)**: every task's K/V row is appended to its own
//!   sequence ([`KvPool::append`]) in wave order. Appends touch only
//!   pages owned by their sequence, so sessions cannot observe each
//!   other's appends — only the *page-id assignment* depends on order,
//!   and no output ever reads a page id.
//! * **phase 2 (parallel)**: each sweep task is a pure function of
//!   its own sequence's pages and the step plan (the same `group_step` /
//!   `head_step` expressions a serial step runs), and writes a disjoint
//!   output block (one group's contiguous `H/G · d_head`, or one head's
//!   `d_head`). Scatter order is therefore unobservable.
//!
//! Exhaustion is per-task: a session whose append hits
//! [`KvError::Exhausted`] fails alone (its output untouched, its
//! sequence unchanged, the step retryable after a close frees pages)
//! while the rest of the wave proceeds — property-tested in
//! `integration_decode_batch.rs`. Waves also support **mid-round
//! admission of freed capacity**: [`DecodeBatch::step_wave_with`] takes
//! an exhaustion hook that may reclaim pages (the continuous-batching
//! scheduler evicts the youngest idle session there) and have the failed
//! append retried in place, so one starved task no longer forfeits its
//! round when capacity could be made available. The hook runs between
//! phase-1 appends — never during the parallel sweep — so the
//! bit-reproducibility argument below is unchanged.
//!
//! # Wave accounting
//!
//! The inline-vs-scatter decision counts the WHOLE wave's head rows
//! (`S × H`) and MACs — counting per session would keep row-rich waves
//! inline (the PR 4 fix, regression-tested in `integration_par.rs`) —
//! and since group tasks are `H/G×` heavier than head rows, the pool's
//! row threshold is asked with MAC-weighted row equivalents
//! (`wave_stays_inline`, shared with `step_par` / `prefill_chunk_par`),
//! never the raw task count.

use std::fmt;

use super::decode::{check_step_shapes, StepPlan, SweepOrder};
use super::kernel::{wave_stays_inline, AttnScratch, OutPtr};
use super::DecodeAttention;
use crate::kv::{KvError, KvPool, KvSeq};
use crate::quant::Affine;
use crate::softmax::{lock_unpoisoned, ParSoftmax, Scratch};

/// Per-task failure of a batched decode wave. The two variants have
/// opposite retry semantics, and serving layers must honor the split:
///
/// * [`WaveError::Kv`] — the phase-1 append failed (typed backpressure or
///   an injected allocation fault). The task's sequence and output are
///   **untouched**; the same step is retryable after capacity frees up.
/// * [`WaveError::Panicked`] — a phase-2 sweep unit of this task panicked
///   (contained by the pool; siblings unaffected). The session's K/V row
///   was **already appended** in phase 1, so state has advanced and only
///   the output is lost: the step must NOT be replayed (that would
///   double-append). Fail the step with a typed reply; the session stays
///   live and its next step is well-defined.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WaveError {
    /// KV allocation failure in the serial append phase (retryable)
    Kv(KvError),
    /// a sweep task panicked in the parallel phase (not retryable:
    /// the append already landed)
    Panicked,
}

impl From<KvError> for WaveError {
    fn from(e: KvError) -> Self {
        WaveError::Kv(e)
    }
}

impl fmt::Display for WaveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WaveError::Kv(e) => write!(f, "{e}"),
            WaveError::Panicked => write!(f, "decode sweep task panicked (step output lost)"),
        }
    }
}

impl std::error::Error for WaveError {}

/// Measured traffic of one executed wave, for the serving layer's
/// metrics registry — the MEASURED counterpart of the hwsim charge names
/// (`kv_bytes_read`, wave setup): both publish under the same
/// [`crate::obs::names`] series so simulated and observed traffic are
/// directly comparable. Counting rides the existing phase-2 accounting
/// loop; it adds no KV reads of its own.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WaveStats {
    /// Σ query-head rows over the surviving tasks (`S × H`)
    pub rows: usize,
    /// Σ sweep MACs over the surviving tasks (`Σ h · seq_len · d`)
    pub macs: usize,
    /// sweep units submitted (group tasks, or head rows head-major)
    pub units: usize,
    /// whether the wave ran inline (under `wave_stays_inline`) instead
    /// of as a pool scatter
    pub inline: bool,
    /// K+V bytes the sweep reads: Σ over surviving tasks of
    /// `seq_len · kv_heads · d_head · 2` (each page once per group —
    /// the group-major contract)
    pub kv_bytes: u64,
}

/// One session's contribution to a batched decode round: the same inputs
/// a single [`DecodeAttention::step`] takes, borrowed so the wave can
/// prove (via `&mut`) that sequences and outputs are pairwise disjoint.
pub struct DecodeStepTask<'a> {
    pub seq: &'a mut KvSeq,
    /// `H * d_head` quantized query rows, `[h][d]`
    pub q: &'a [i8],
    pub q_affine: Affine,
    /// `G * d_head` new-token K rows, `[g][d]`
    pub k_row: &'a [i8],
    /// `G * d_head` new-token V rows, `[g][d]`
    pub v_row: &'a [i8],
    /// `H * d_head` f32 output, `[h][d]` — untouched on a failed append
    pub out: &'a mut [f32],
}

/// The batched decode scheduler's kernel layer: one wave of `S × G`
/// group tasks (or `S × H` head rows under the head-major reference
/// order) per serving round over a shared [`DecodeAttention`]. See the
/// module docs for the ordering / bit-reproducibility contract.
pub struct DecodeBatch<'d> {
    dec: &'d DecodeAttention,
}

/// One sweep unit of a batched round: a KV group (group-major) or a
/// single query head (head-major) of one session's step.
struct SweepTask<'b> {
    seq: &'b KvSeq,
    /// the unit's query rows: a group's contiguous `H/G · d_head` block,
    /// or one head's `d_head` slice
    q: &'b [i8],
    plan: StepPlan,
    /// group index (group-major) or query-head index (head-major)
    /// within the session
    unit: usize,
    /// elements of the unit's output block at `out`
    out_len: usize,
    out: OutPtr,
}

impl<'d> DecodeBatch<'d> {
    /// Wrap an existing per-route kernel (shares its scratch pool).
    pub fn new(dec: &'d DecodeAttention) -> Self {
        Self { dec }
    }

    /// The wrapped per-step kernel.
    pub fn decode(&self) -> &DecodeAttention {
        self.dec
    }

    /// One batched decode round: append every task's token (phase 1,
    /// serial, per-task exhaustion), then attend all surviving tasks'
    /// sweep units (`S × G` group tasks, or `S × H` head rows
    /// head-major) in ONE [`ParSoftmax::scatter`] wave (phase 2) — or
    /// inline when the whole wave sits under the shared accounting
    /// (`wave_stays_inline`: total MACs + MAC-weighted row equivalents).
    /// Returns one result per task, in task order; failed tasks'
    /// sequences and outputs are untouched.
    pub fn step_wave(
        &self,
        kv: &mut KvPool,
        tasks: &mut [DecodeStepTask<'_>],
        pool: &ParSoftmax,
        scr: &mut AttnScratch,
    ) -> Vec<Result<(), WaveError>> {
        self.step_wave_with(kv, tasks, pool, scr, |_, _| false)
    }

    /// [`Self::step_wave`] with an exhaustion hook: when task `i`'s
    /// phase-1 append fails with [`KvError::Exhausted`], the hook is
    /// called with the pool and the task index and may free capacity
    /// (e.g. evict an idle session's pages back to the free list). If it
    /// returns `true` the append is retried; it may be called repeatedly
    /// for the same task until the append lands or it returns `false`
    /// (the task then fails exactly as under [`Self::step_wave`]). The
    /// hook must not touch any sequence borrowed by the wave's tasks —
    /// the `&mut` borrows here already guarantee it cannot.
    pub fn step_wave_with(
        &self,
        kv: &mut KvPool,
        tasks: &mut [DecodeStepTask<'_>],
        pool: &ParSoftmax,
        scr: &mut AttnScratch,
        on_exhausted: impl FnMut(&mut KvPool, usize) -> bool,
    ) -> Vec<Result<(), WaveError>> {
        self.step_wave_with_stats(kv, tasks, pool, scr, on_exhausted).0
    }

    /// [`Self::step_wave_with`] plus the wave's measured traffic
    /// ([`WaveStats`]) — what the serving layer feeds the metrics
    /// registry. Results are identical to `step_wave_with`'s.
    pub fn step_wave_with_stats(
        &self,
        kv: &mut KvPool,
        tasks: &mut [DecodeStepTask<'_>],
        pool: &ParSoftmax,
        scr: &mut AttnScratch,
        mut on_exhausted: impl FnMut(&mut KvPool, usize) -> bool,
    ) -> (Vec<Result<(), WaveError>>, WaveStats) {
        // phase 1: serial appends, task order (page-id assignment is the
        // only order-dependent effect, and nothing downstream reads it)
        let mut results: Vec<Result<(), WaveError>> = tasks
            .iter_mut()
            .enumerate()
            .map(|(i, t)| loop {
                match kv.append(t.seq, t.k_row, t.v_row) {
                    Ok(()) => break Ok(()),
                    Err(e) => {
                        if !on_exhausted(kv, i) {
                            break Err(WaveError::Kv(e));
                        }
                    }
                }
            })
            .collect();

        // phase 2: flatten the surviving tasks into sweep units,
        // remembering each unit's owning task so a contained panic can be
        // mapped back to exactly one session
        let kv_ref: &KvPool = kv;
        let d = kv_ref.config().d_head;
        let order = self.dec.order();
        let mut units: Vec<SweepTask<'_>> = Vec::new();
        let mut owners: Vec<usize> = Vec::new();
        let mut wave_rows = 0usize;
        let mut wave_macs = 0usize;
        let mut kv_bytes = 0u64;
        for (ti, (t, res)) in tasks.iter_mut().zip(&results).enumerate() {
            if res.is_err() {
                continue;
            }
            let h = t.seq.groups().q_heads();
            check_step_shapes(t.q, t.out, h, d);
            let plan = self.dec.plan(t.seq, d, t.q_affine);
            wave_rows += h;
            wave_macs += h * t.seq.len() * d;
            kv_bytes += (t.seq.len() * t.seq.groups().kv_heads() * d * 2) as u64;
            let seq: &KvSeq = t.seq;
            let optr = t.out.as_mut_ptr();
            match order {
                SweepOrder::HeadMajor => {
                    for hh in 0..h {
                        units.push(SweepTask {
                            seq,
                            q: &t.q[hh * d..(hh + 1) * d],
                            plan,
                            unit: hh,
                            out_len: d,
                            // SAFETY: within `out`'s `h * d` allocation
                            // (shape checked above); disjoint per head
                            out: OutPtr(unsafe { optr.add(hh * d) }),
                        });
                        owners.push(ti);
                    }
                }
                SweepOrder::GroupMajor => {
                    let r = seq.groups().group_size();
                    for gi in 0..seq.groups().kv_heads() {
                        units.push(SweepTask {
                            seq,
                            q: &t.q[gi * r * d..(gi * r + r) * d],
                            plan,
                            unit: gi,
                            out_len: r * d,
                            // SAFETY: within `out`'s `h * d` allocation
                            // (shape checked above); disjoint per group
                            out: OutPtr(unsafe { optr.add(gi * r * d) }),
                        });
                        owners.push(ti);
                    }
                }
            }
        }

        // wave accounting: the WHOLE round's head rows and MACs decide
        // the inline-vs-scatter trade (never per session — the PR 4 fix
        // — and never the raw group-task count, which undercounts by
        // H/G per task)
        let run_unit = |ut: &SweepTask<'_>, us: &mut AttnScratch| {
            let ob = unsafe { std::slice::from_raw_parts_mut(ut.out.0, ut.out_len) };
            match order {
                SweepOrder::HeadMajor => {
                    self.dec.head_step(kv_ref, ut.seq, ut.unit, ut.q, ut.plan, ob, us)
                }
                SweepOrder::GroupMajor => {
                    self.dec.group_step(kv_ref, ut.seq, ut.unit, ut.q, ut.plan, ob, us)
                }
            }
        };
        // both arms run units under the pool's containment (and fault
        // schedule): a panicking unit is contained, mapped below to its
        // owning task, and must never poison the pool or the spare stack.
        // The caller's scratch is lent to the spare stack for the wave,
        // so the inline arm keeps its amortized buffers.
        let spare = &self.dec.spare;
        lock_unpoisoned(spare).push(std::mem::take(scr));
        let run = |i: usize, _s: &mut Scratch| {
            let mut hs = lock_unpoisoned(spare).pop().unwrap_or_default();
            run_unit(&units[i], &mut hs);
            lock_unpoisoned(spare).push(hs);
        };
        let mut pool_scratch = Scratch::new();
        let inline = wave_stays_inline(pool, units.len(), wave_rows, wave_macs);
        let outcome = if inline {
            pool.scatter_inline(units.len(), &mut pool_scratch, &run)
        } else {
            pool.scatter(units.len(), &mut pool_scratch, &run)
        };
        if let Some(hs) = lock_unpoisoned(spare).pop() {
            *scr = hs;
        }
        let stats = WaveStats {
            rows: wave_rows,
            macs: wave_macs,
            units: units.len(),
            inline,
            kv_bytes,
        };
        for &u in outcome.panicked() {
            // the owner's phase-1 append already landed: state advanced,
            // output lost — exactly one typed failure per panicked task
            // (a task's first panicked unit wins; repeats are idempotent)
            results[owners[u]] = Err(WaveError::Panicked);
        }
        (results, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::DECODE_AFFINE;
    use crate::kv::{HeadGroups, KvConfig};
    use crate::lut::Precision;
    use crate::softmax::{engine_parallel, Mode};
    use crate::testkit::Rng;

    #[test]
    fn one_wave_matches_serial_steps_bitwise() {
        let (s, h, g, d) = (3usize, 2usize, 1usize, 8usize);
        let a = DECODE_AFFINE;
        let cfg = KvConfig { pages: 16, page_size: 4, kv_heads: g, d_head: d };
        let (mut kv_w, mut kv_s) = (KvPool::new(cfg), KvPool::new(cfg));
        let groups = HeadGroups::new(h, g).unwrap();
        let mut wave_seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
        let mut ser_seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let batch = DecodeBatch::new(&dec);
        let pool = engine_parallel(Mode::Lut2d, Precision::Uint8, None, Some(3));
        let mut rng = Rng::new(14);
        let mut scr = AttnScratch::new();
        for round in 0..7 {
            let qs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..h * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let ks: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let vs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let mut wave_out = vec![vec![0.0f32; h * d]; s];
            let mut tasks: Vec<DecodeStepTask<'_>> = wave_seqs
                .iter_mut()
                .zip(wave_out.iter_mut())
                .enumerate()
                .map(|(i, (seq, out))| DecodeStepTask {
                    seq,
                    q: &qs[i],
                    q_affine: a,
                    k_row: &ks[i],
                    v_row: &vs[i],
                    out,
                })
                .collect();
            let res = batch.step_wave(&mut kv_w, &mut tasks, &pool, &mut scr);
            assert!(res.iter().all(|r| r.is_ok()));
            drop(tasks);
            // serial replay in REVERSE session order: interleaving must
            // not matter
            for i in (0..s).rev() {
                let mut want = vec![0.0f32; h * d];
                dec.step(&mut kv_s, &mut ser_seqs[i], &qs[i], a, &ks[i], &vs[i], &mut want, &mut scr)
                    .unwrap();
                assert_eq!(wave_out[i], want, "round {round} session {i}");
            }
        }
        for seq in wave_seqs {
            kv_w.close(seq);
        }
        assert_eq!(kv_w.free_pages(), 16);
        for seq in ser_seqs {
            kv_s.close(seq);
        }
    }

    #[test]
    fn wave_stats_measure_rows_macs_and_kv_traffic() {
        let (s, h, g, d) = (2usize, 2usize, 1usize, 8usize);
        let a = DECODE_AFFINE;
        let cfg = KvConfig { pages: 16, page_size: 4, kv_heads: g, d_head: d };
        let mut kv = KvPool::new(cfg);
        let groups = HeadGroups::new(h, g).unwrap();
        let mut seqs: Vec<KvSeq> = (0..s).map(|_| KvSeq::new(groups, a, a)).collect();
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let batch = DecodeBatch::new(&dec);
        let pool = engine_parallel(Mode::Lut2d, Precision::Uint8, None, Some(2));
        let mut rng = Rng::new(33);
        let mut scr = AttnScratch::new();
        for round in 0..3usize {
            let qs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..h * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let ks: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let vs: Vec<Vec<i8>> = (0..s)
                .map(|_| (0..g * d).map(|_| rng.int(-96, 96) as i8).collect())
                .collect();
            let mut outs = vec![vec![0.0f32; h * d]; s];
            let mut tasks: Vec<DecodeStepTask<'_>> = seqs
                .iter_mut()
                .zip(outs.iter_mut())
                .enumerate()
                .map(|(i, (seq, out))| DecodeStepTask {
                    seq,
                    q: &qs[i],
                    q_affine: a,
                    k_row: &ks[i],
                    v_row: &vs[i],
                    out,
                })
                .collect();
            let (res, stats) =
                batch.step_wave_with_stats(&mut kv, &mut tasks, &pool, &mut scr, |_, _| false);
            assert!(res.iter().all(|r| r.is_ok()));
            drop(tasks);
            // tokens resident after this round's appends
            let len = round + 1;
            assert_eq!(stats.rows, s * h, "round {round}");
            assert_eq!(stats.macs, s * h * len * d, "round {round}");
            assert_eq!(stats.kv_bytes, (s * len * g * d * 2) as u64, "round {round}");
            assert!(stats.units > 0);
        }
        for seq in seqs {
            kv.close(seq);
        }
    }

    #[test]
    fn exhaustion_hook_reclaims_pages_and_retries_in_place() {
        let (h, g, d) = (2usize, 1usize, 8usize);
        let a = DECODE_AFFINE;
        let cfg = KvConfig { pages: 2, page_size: 4, kv_heads: g, d_head: d };
        let mut kv = KvPool::new(cfg);
        let groups = HeadGroups::new(h, g).unwrap();
        let mut rng = Rng::new(21);
        let mut row = |n: usize| -> Vec<i8> { (0..n).map(|_| rng.int(-96, 96) as i8).collect() };
        // a victim session fills the whole arena
        let mut victim = KvSeq::new(groups, a, a);
        for _ in 0..8 {
            let r = row(g * d);
            kv.append(&mut victim, &r, &r).unwrap();
        }
        assert_eq!(kv.free_pages(), 0);
        let dec = DecodeAttention::new(Mode::Lut2d, Precision::Uint8, None).unwrap();
        let batch = DecodeBatch::new(&dec);
        let pool = engine_parallel(Mode::Lut2d, Precision::Uint8, None, Some(2));
        let mut scr = AttnScratch::new();
        let mut seq = KvSeq::new(groups, a, a);
        let (q, k, v) = (row(h * d), row(g * d), row(g * d));
        let mut out = vec![0.0f32; h * d];
        let mut tasks = vec![DecodeStepTask {
            seq: &mut seq,
            q: &q,
            q_affine: a,
            k_row: &k,
            v_row: &v,
            out: &mut out,
        }];
        // without a hook the task starves as before...
        let res = batch.step_wave(&mut kv, &mut tasks, &pool, &mut scr);
        assert_eq!(res, vec![Err(WaveError::Kv(KvError::Exhausted { pages: 2, free_pages: 0 }))]);
        // ...with a hook that evicts the victim, the same wave lands
        let mut victim = Some(victim);
        let mut evictions = 0usize;
        let res = batch.step_wave_with(&mut kv, &mut tasks, &pool, &mut scr, |kvp, i| {
            assert_eq!(i, 0);
            match victim.take() {
                Some(s) => {
                    kvp.close(s);
                    evictions += 1;
                    true
                }
                None => false,
            }
        });
        assert_eq!(res, vec![Ok(())]);
        assert_eq!(evictions, 1);
        drop(tasks);
        // the retried step is bit-identical to a serial step on a fresh
        // arena — eviction only moved page ids, which nothing reads
        let mut kv_s = KvPool::new(cfg);
        let mut seq_s = KvSeq::new(groups, a, a);
        let mut want = vec![0.0f32; h * d];
        dec.step(&mut kv_s, &mut seq_s, &q, a, &k, &v, &mut want, &mut scr).unwrap();
        assert_eq!(out, want);
        kv.close(seq);
        kv_s.close(seq_s);
    }
}
