//! The fused integer attention kernel and its unfused f32 compose.
//!
//! Per head, per query row (single sweep, no f32 probability matrix):
//!
//!   1. `scores[j] = Σ_d q[i][d]·k[j][d]` raw i8×i8 widening MACs; the
//!      affine zero points are hoisted algebraically:
//!      `(q-z_q)·(k-z_k) = q·k - z_k·Σq - z_q·Σk + d_h·z_q·z_k`, with
//!      `Σk[j]` computed once per head and `Σq[i]` once per row — the
//!      inner loop is a pure dot product.
//!   2. integer row max, then the shared softmax pass 1
//!      ([`pass1_scores_mapped`]): LUT address per element via one
//!      fixed-point multiply, integer row sum, addresses parked.
//!   3. the per-row normalizer (REXP `LUT_alpha` read / 2D-LUT column
//!      select) and `sig_int` per element — hoisted through a per-row
//!      integer mirror of the (tiny) table when the row is long enough,
//!      exactly like the engines' fused pass 2.
//!   4. `out[i][d] = (Σ_j sig[j]·v_raw[j][d] − z_v·Σsig) · s_v/qmax`:
//!      an i32-multiply/i64-accumulate MAC over the widened V block, one
//!      fused dequant per output element.
//!
//! Masked (causal/PAD) positions are excluded by loop bound and
//! contribute exactly-zero probability. [`ComposedAttention`] is the
//! unfused baseline: explicit dequant passes, a materialized f32 score
//! matrix, a full softmax pass, then a separate f32 `probs @ V` — the
//! compose the `attn/*` vs `attn_unfused/*` bench labels compare.

use std::sync::Mutex;

use anyhow::{bail, Result};

use super::{AttnMask, AttnShape, QuantTensor, ATTN_ALPHA_LEN};
use crate::lut::Precision;
use crate::quant;
use crate::softmax::{
    lock_unpoisoned, pass1_scores_mapped, IntMap, Mode, ParSoftmax, Scratch, SoftmaxEngine,
    SoftmaxLut2d, SoftmaxRexp,
};

/// Don't scatter below this many MACs of work per pool submission: a
/// pool wake + per-task synchronization costs more than computing that
/// much inline — the same tiny-batch policy [`ParSoftmax`] applies to
/// softmax row shards. ~4k MACs is a few µs of integer work, on the
/// order of one task round-trip. The prefill kernel's `run_par` counts
/// it per head (`len_q·len_k·d_head` — a head is its submission unit);
/// the decode paths (`step_par`, `DecodeBatch::step_wave`,
/// `prefill_chunk_par`) count the WHOLE submitted wave via
/// [`wave_stays_inline`], so one wake is charged once per wave however
/// the rows are grouped.
pub(super) const MIN_HEAD_MACS: usize = 4096;

/// Inline-vs-scatter decision for a decode sweep wave of `tasks` scatter
/// units carrying `rows` query-head rows and `macs` total integer MACs —
/// shared by `step_par`, `prefill_chunk_par` and `DecodeBatch::step_wave`.
///
/// Since the group-major restructure a scatter unit is one KV *group*,
/// which is `H/G×` heavier than a head row, so the raw task count
/// undercounts the wave: a 2-group step with heavy heads (long prefix ×
/// deep `d_head`) would sit under the pool's row threshold forever if we
/// asked with `tasks`. Instead the pool's threshold is asked with the
/// wave's head-row count or its MAC load in [`MIN_HEAD_MACS`]-sized row
/// equivalents, whichever is larger (regression-tested in
/// `integration_par.rs::group_task_accounting_weighs_heavy_groups`);
/// waves under [`MIN_HEAD_MACS`] of total work never wake the pool.
pub(super) fn wave_stays_inline(pool: &ParSoftmax, tasks: usize, rows: usize, macs: usize) -> bool {
    if tasks < 2 || macs < MIN_HEAD_MACS {
        return true;
    }
    pool.scatter_stays_inline(rows.max(macs / MIN_HEAD_MACS))
}

/// `Send`/`Sync` shim for the disjoint output-block pointers the
/// head-scatter paths fan across the worker pool.
///
/// SAFETY contract, shared by every user ([`FusedAttention::run_par`],
/// `DecodeAttention::step_par` / `prefill_chunk_par`,
/// `DecodeBatch::step_wave`): a task reconstructs only pairwise-disjoint
/// blocks from this pointer, and [`ParSoftmax::scatter`] blocks until
/// every task has finished, so no access outlives the buffer and no two
/// tasks alias.
pub(super) struct OutPtr(pub(super) *mut f32);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Result of merging one group row's span partials
/// ([`FusedAttention::merge_span_row`]).
pub(super) struct SpanRowMerge {
    /// merged `Σ sig` (the zero-point correction term)
    pub(super) sig_sum: i64,
    /// every span's `m − m_span` landed on a LUT-index boundary — the
    /// merge is bit-identical to the unsplit sweep
    pub(super) aligned: bool,
    /// bound on `|merged − unsplit|` of any output element's integer
    /// value `acc[·] − z_v·Σsig`; `0` when `aligned`
    pub(super) err_bound_int: i64,
}

/// Reusable per-thread workspace of the fused kernel (score row, LUT
/// addresses, sig row, widened V/K-sum blocks, output accumulators).
#[derive(Debug, Default)]
pub struct AttnScratch {
    pub(super) scores: Vec<i32>,
    pub(super) idx: Vec<i32>,
    pub(super) sig: Vec<i32>,
    pub(super) sig_tab: Vec<i32>,
    v32: Vec<i32>,
    ksum: Vec<i32>,
    pub(super) acc: Vec<i64>,
    /// per-query-head Σq of a group-major decode task (`H/G` entries)
    pub(super) qsum: Vec<i32>,
    /// per-query-head Σ sig of a group-major decode task (`H/G` entries)
    pub(super) sig_sum: Vec<i64>,
    /// split-sweep span partials (see `DecodeAttention::step_split`):
    /// per-(span, row) local maxima, LUT-address histograms, and
    /// per-address V sums — span-major (span `p` owns the contiguous
    /// `p * rows ..` block, row `rr` at offset `rr` within), so each
    /// parallel span task writes one contiguous disjoint region; the
    /// merge reads across spans with a `rows`-sized stride.
    pub(super) span_m: Vec<i32>,
    pub(super) span_cnt: Vec<i32>,
    pub(super) span_vs: Vec<i64>,
    /// merge-side fold buffers (one row at a time: `T` / `T · d_head`)
    pub(super) merge_cnt: Vec<i32>,
    pub(super) merge_vs: Vec<i64>,
}

impl AttnScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Decode-path prepare: one query row over a `len`-token prefix. No
    /// widened V / K-sum blocks — those live in the KV pages.
    pub(super) fn prepare_decode(&mut self, len: usize, d_head: usize, table_len: usize) {
        grow_i32(&mut self.scores, len);
        grow_i32(&mut self.idx, len);
        grow_i32(&mut self.sig, len);
        grow_i32(&mut self.sig_tab, table_len);
        if self.acc.len() < d_head {
            self.acc.resize(d_head, 0);
        }
    }

    /// Group-major decode prepare: `rows = H/G` score/sig rows of `len`
    /// scores each (row `r` lives at offset `r * len`), `rows` output
    /// accumulators, and the per-head Σq / Σsig slots — one group task
    /// carries every query head sharing its stored K/V head.
    pub(super) fn prepare_decode_group(
        &mut self,
        rows: usize,
        len: usize,
        d_head: usize,
        table_len: usize,
    ) {
        grow_i32(&mut self.scores, rows * len);
        grow_i32(&mut self.idx, rows * len);
        grow_i32(&mut self.sig, rows * len);
        grow_i32(&mut self.sig_tab, table_len);
        grow_i32(&mut self.qsum, rows);
        if self.sig_sum.len() < rows {
            self.sig_sum.resize(rows, 0);
        }
        if self.acc.len() < rows * d_head {
            self.acc.resize(rows * d_head, 0);
        }
    }

    /// Split-sweep prepare on top of [`Self::prepare_decode_group`]: room
    /// for `spans` per-span partials of a `rows`-row group (histograms
    /// over `table_len` LUT addresses, per-address `d_head`-deep V sums)
    /// plus the one-row merge fold buffers.
    pub(super) fn prepare_decode_split(
        &mut self,
        rows: usize,
        len: usize,
        d_head: usize,
        table_len: usize,
        spans: usize,
    ) {
        self.prepare_decode_group(rows, len, d_head, table_len);
        grow_i32(&mut self.span_m, spans * rows);
        grow_i32(&mut self.span_cnt, spans * rows * table_len);
        if self.span_vs.len() < spans * rows * table_len * d_head {
            self.span_vs.resize(spans * rows * table_len * d_head, 0);
        }
        grow_i32(&mut self.merge_cnt, table_len);
        if self.merge_vs.len() < table_len * d_head {
            self.merge_vs.resize(table_len * d_head, 0);
        }
    }

    fn prepare(&mut self, len_k: usize, d_head: usize, table_len: usize) {
        grow_i32(&mut self.scores, len_k);
        grow_i32(&mut self.idx, len_k);
        grow_i32(&mut self.sig, len_k);
        grow_i32(&mut self.sig_tab, table_len);
        grow_i32(&mut self.v32, len_k * d_head);
        grow_i32(&mut self.ksum, len_k);
        if self.acc.len() < d_head {
            self.acc.resize(d_head, 0);
        }
    }
}

fn grow_i32(v: &mut Vec<i32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0);
    }
}

enum IntSoftmax {
    Rexp(SoftmaxRexp),
    Lut2d(SoftmaxLut2d),
}

/// Fused integer-native attention over one of the paper's LUT softmax
/// datapaths. Construct once per (mode, precision, alpha) route; `run` /
/// `run_par` per problem.
pub struct FusedAttention {
    mode: Mode,
    softmax: IntSoftmax,
    prec: Precision,
    inv_qmax: f32,
}

impl FusedAttention {
    /// `alpha_len = None` uses [`ATTN_ALPHA_LEN`] (attention rows are
    /// long; the NLP default saturates — see the module docs). Only the
    /// LUT modes have an integer datapath; anything else is an error.
    pub fn new(mode: Mode, prec: Precision, alpha_len: Option<usize>) -> Result<Self> {
        let softmax = match mode {
            Mode::Rexp => {
                IntSoftmax::Rexp(SoftmaxRexp::new(prec, Some(alpha_len.unwrap_or(ATTN_ALPHA_LEN))))
            }
            Mode::Lut2d => IntSoftmax::Lut2d(SoftmaxLut2d::new(prec)),
            other => bail!("fused attention needs a LUT softmax mode, got {:?}", other),
        };
        Ok(Self { mode, softmax, prec, inv_qmax: 1.0 / prec.qmax() as f32 })
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    pub(super) fn inv_qmax(&self) -> f32 {
        self.inv_qmax
    }

    pub(super) fn table(&self) -> &[i32] {
        match &self.softmax {
            IntSoftmax::Rexp(e) => &e.tables().recip_e,
            IntSoftmax::Lut2d(e) => &e.tables().exp,
        }
    }

    /// The diff→address map for integer scores whose unit is `step` logit
    /// units (for QK^T accumulators, `step = s_q·s_k/√d_h`).
    pub(super) fn int_map(&self, step: f32) -> IntMap {
        match &self.softmax {
            IntSoftmax::Rexp(e) => e.int_map(step),
            IntSoftmax::Lut2d(e) => e.int_map(step),
        }
    }

    /// Integer softmax over `scr.scores[..n]` (pass 1 + normalizer +
    /// sig), writing `scr.sig[..n]`; returns `Σ sig` for the zero-point
    /// correction. Shared with the decode path, which fills the score row
    /// from paged K blocks instead of a contiguous head.
    pub(super) fn sig_row(&self, n: usize, map: IntMap, scr: &mut AttnScratch) -> i64 {
        self.sig_row_at(n, map, scr, 0)
    }

    /// [`Self::sig_row`] over the score row stored at
    /// `scr.scores[off..off + n]` (`sig` written at the same offset) —
    /// identical expressions, offset rows. The group-major decode sweep
    /// parks one score row per query head of a group in the same scratch
    /// (row `r` at `off = r * n`) so the K and V page sweeps run once per
    /// group; each head's softmax is still this exact per-row chain.
    pub(super) fn sig_row_at(&self, n: usize, map: IntMap, scr: &mut AttnScratch, off: usize) -> i64 {
        let table = self.table();
        let m = scr.scores[off..off + n].iter().copied().max().unwrap_or(0);
        let s =
            pass1_scores_mapped(&scr.scores[off..off + n], m, map, table, &mut scr.idx[off..off + n]);
        // per-row integer mirror of the sig chain (hoisted for long rows,
        // exactly like the engines' fused pass 2)
        let hoist = n >= table.len();
        match &self.softmax {
            IntSoftmax::Rexp(e) => {
                let w = e.tables().prec.w();
                let a = e.alpha_for(s);
                let recip = &e.tables().recip_e;
                if hoist {
                    for (t, &ev) in scr.sig_tab.iter_mut().zip(recip.iter()) {
                        *t = (ev * a) >> w;
                    }
                    for (g, &k) in scr.sig[off..off + n].iter_mut().zip(&scr.idx[off..off + n]) {
                        *g = scr.sig_tab[k as usize];
                    }
                } else {
                    for (g, &k) in scr.sig[off..off + n].iter_mut().zip(&scr.idx[off..off + n]) {
                        *g = (recip[k as usize] * a) >> w;
                    }
                }
            }
            IntSoftmax::Lut2d(e) => {
                let col = e.col_for(s);
                let t = e.tables();
                if hoist {
                    for (slot, &r) in scr.sig_tab.iter_mut().zip(t.row.iter()) {
                        *slot = t.sigma_at(r as usize, col);
                    }
                    for (g, &k) in scr.sig[off..off + n].iter_mut().zip(&scr.idx[off..off + n]) {
                        *g = scr.sig_tab[k as usize];
                    }
                } else {
                    for (g, &k) in scr.sig[off..off + n].iter_mut().zip(&scr.idx[off..off + n]) {
                        *g = t.sigma_at(t.row[k as usize] as usize, col);
                    }
                }
            }
        }
        scr.sig[off..off + n].iter().map(|&v| v as i64).sum()
    }

    /// Per-table-entry sig values for a row whose pass-1 sum is `s` —
    /// exactly the hoisted branch of [`Self::sig_row_at`], exposed for
    /// the split-sweep merge (which always works per LUT address).
    fn fill_sig_tab(&self, s: i32, sig_tab: &mut [i32]) {
        match &self.softmax {
            IntSoftmax::Rexp(e) => {
                let w = e.tables().prec.w();
                let a = e.alpha_for(s);
                for (t, &ev) in sig_tab.iter_mut().zip(e.tables().recip_e.iter()) {
                    *t = (ev * a) >> w;
                }
            }
            IntSoftmax::Lut2d(e) => {
                let col = e.col_for(s);
                let t = e.tables();
                for (slot, &r) in sig_tab.iter_mut().zip(t.row.iter()) {
                    *slot = t.sigma_at(r as usize, col);
                }
            }
        }
    }

    /// Merge the per-span partials of ONE group row into the row's
    /// `(Σsig, acc)` pair — the LUT-exact reduction of the prefix-split
    /// sweep.
    ///
    /// Each span `p` contributes its local max `m_p`, a histogram
    /// `cnt_p[k]` of LUT addresses taken against `m_p`, and per-address
    /// V sums `vs_p[k][·]`. Partials are span-major over a `rows`-row
    /// group (span `p`'s block starts at `p · rows` entries /
    /// `p · rows · T` histogram slots / `p · rows · T · d` V-sum lanes);
    /// the caller offsets the slices to its row, and this fold strides
    /// across spans. The fold rescales span `p` by
    /// `sig(m − m_p)` in the LUT-index domain: every bucket shifts by
    /// `Δ_p = map.index(m − m_p)` (saturating at the top address), which
    /// is the fixed-point image of multiplying the span's partial sums by
    /// `sig(m_global − m_span)`. When every `m − m_p` lands on an index
    /// boundary ([`IntMap::shift_is_exact`]) the shifted addresses equal
    /// the unsplit addresses element-for-element — truncation distributes
    /// over a sum with a zero fractional part, and saturation composes —
    /// so the merged row sum, normalizer, `Σsig` and `acc` are
    /// **bit-identical** to [`Self::sig_row_at`] plus the unsplit sig×V
    /// MAC (integer addition is associative; per-address regrouping of
    /// `Σ_j sig_j·v_j` into `Σ_k sig[k]·Σ_{j∈k} v_j` is exact in i64).
    ///
    /// Otherwise each shifted address is at most ONE below the true
    /// address (`trunc(a)+trunc(b) ∈ {trunc(a+b)−1, trunc(a+b)}`), the
    /// tables are non-increasing, and the returned
    /// [`SpanRowMerge::err_bound_int`] bounds the absolute error of any
    /// output element's integer value `acc[·] − z_v·Σsig` — computed
    /// from the adjacent-address / normalizer-interval discrepancy, never
    /// assumed. Row sums are taken in i64 and wrapped to i32 so the
    /// aligned case reproduces pass 1's release-mode arithmetic exactly.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn merge_span_row(
        &self,
        map: IntMap,
        zv: i32,
        d: usize,
        spans: usize,
        rows: usize,
        m_spans: &[i32],
        cnts: &[i32],
        vsums: &[i64],
        merged_cnt: &mut [i32],
        merged_vs: &mut [i64],
        sig_tab: &mut [i32],
        acc: &mut [i64],
    ) -> SpanRowMerge {
        let t_len = self.table().len();
        let last = map.last();
        debug_assert_eq!(merged_cnt.len(), t_len);
        debug_assert!(merged_vs.len() >= t_len * d);
        debug_assert!(spans >= 1 && rows >= 1);
        debug_assert!(m_spans.len() > (spans - 1) * rows);
        merged_cnt.fill(0);
        merged_vs[..t_len * d].fill(0);
        let m = (0..spans).map(|p| m_spans[p * rows]).max().unwrap_or(0);
        // fold every span's histogram, shifted by Δ_p = index(m − m_p)
        let mut aligned = true;
        for p in 0..spans {
            let dm = m - m_spans[p * rows];
            let dl = map.index(dm);
            if !map.shift_is_exact(dm) {
                aligned = false;
            }
            let c = &cnts[p * rows * t_len..][..t_len];
            let vs = &vsums[p * rows * t_len * d..][..t_len * d];
            for (k, &ck) in c.iter().enumerate() {
                if ck == 0 {
                    continue;
                }
                let kk = (k as i32 + dl).min(last) as usize;
                merged_cnt[kk] += ck;
                for (o, &v) in merged_vs[kk * d..kk * d + d].iter_mut().zip(&vs[k * d..k * d + d]) {
                    *o += v;
                }
            }
        }
        // merged pass-1 sum (i64 fold, wrapped to i32 like the serial +=)
        let table = self.table();
        let s64: i64 = merged_cnt
            .iter()
            .zip(table)
            .map(|(&c, &t)| c as i64 * t as i64)
            .sum();
        let s = s64 as i32;
        self.fill_sig_tab(s, sig_tab);
        acc[..d].fill(0);
        let mut sig_sum = 0i64;
        for (k, &ck) in merged_cnt.iter().enumerate() {
            if ck == 0 {
                continue;
            }
            let g = sig_tab[k] as i64;
            sig_sum += g * ck as i64;
            for (a, &v) in acc[..d].iter_mut().zip(&merged_vs[k * d..k * d + d]) {
                *a += g * v;
            }
        }
        let err_bound_int = if aligned {
            0
        } else {
            self.span_err_bound(map, zv, s, merged_cnt, table)
        };
        SpanRowMerge { sig_sum, aligned, err_bound_int }
    }

    /// Conservative integer error bound of a non-aligned merge (see
    /// [`Self::merge_span_row`]): the true row sum lies in
    /// `[s − U, s]` where `U = Σ_k cnt[k]·(T[k] − T[k+1])` (each true
    /// address is `k` or `k+1`, tables non-increasing), every element's
    /// true sig lies between the extremes of the sig chain over the
    /// adjacent-address × normalizer-interval candidates, and
    /// `|v − z_v| ≤ 128 + |z_v|`.
    fn span_err_bound(&self, map: IntMap, zv: i32, s: i32, cnt: &[i32], table: &[i32]) -> i64 {
        let t_len = table.len();
        let last = map.last() as usize;
        // U: how far the merged sum can overshoot the true sum
        let mut u: i64 = 0;
        for (k, &ck) in cnt.iter().enumerate() {
            if ck == 0 {
                continue;
            }
            let next = table[(k + 1).min(last)];
            u += ck as i64 * (table[k] - next) as i64;
        }
        let s_lo = ((s as i64 - u).max(0)).min(s as i64) as i32;
        // per-address sig extremes across {k, k+1} × the normalizer range
        let mut disc_sum: i64 = 0;
        match &self.softmax {
            IntSoftmax::Rexp(e) => {
                let t = e.tables();
                let w = t.prec.w();
                let (j_lo, j_hi) = ((s_lo >> w).max(0) as usize, (s >> w).max(0) as usize);
                let (j_lo, j_hi) = (j_lo.min(t.alpha.len()), j_hi.min(t.alpha.len()));
                let (mut a_min, mut a_max) = (i32::MAX, i32::MIN);
                for j in j_lo..=j_hi {
                    let a = if j >= t.alpha.len() { 0 } else { t.alpha[j] };
                    a_min = a_min.min(a);
                    a_max = a_max.max(a);
                }
                for (k, &ck) in cnt.iter().enumerate() {
                    if ck == 0 {
                        continue;
                    }
                    let (e0, e1) = (t.recip_e[k], t.recip_e[(k + 1).min(last)]);
                    let hi = (e0.max(e1) * a_max) >> w;
                    let lo = (e0.min(e1) * a_min) >> w;
                    disc_sum += ck as i64 * (hi - lo) as i64;
                }
            }
            IntSoftmax::Lut2d(e) => {
                let t = e.tables();
                let (c_lo, c_hi) = (e.col_for(s_lo), e.col_for(s));
                for (k, &ck) in cnt.iter().enumerate() {
                    if ck == 0 {
                        continue;
                    }
                    let (mut lo, mut hi) = (i32::MAX, i32::MIN);
                    for kv in [k, (k + 1).min(last)] {
                        let r = t.row[kv] as usize;
                        for col in c_lo.min(c_hi)..=c_lo.max(c_hi) {
                            let sg = t.sigma_at(r, col);
                            lo = lo.min(sg);
                            hi = hi.max(sg);
                        }
                    }
                    disc_sum += ck as i64 * (hi - lo) as i64;
                }
            }
        }
        (128 + zv.unsigned_abs() as i64) * disc_sum
    }

    /// One head: `q_h (L,d)`, `k_h/v_h (S,d)` raw i8 blocks → `o_h (L,d)`.
    #[allow(clippy::too_many_arguments)]
    fn head(
        &self,
        qh: &[i8],
        kh: &[i8],
        vh: &[i8],
        zq: i32,
        zk: i32,
        zv: i32,
        b: usize,
        shape: &AttnShape,
        mask: &AttnMask,
        map: IntMap,
        out_scale: f32,
        oh: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        let (s, dh) = (shape.len_k, shape.d_head);
        scr.prepare(s, dh, self.table().len());
        // per-head hoists: widened V block + per-key-row byte sums
        for (w32, &v) in scr.v32[..s * dh].iter_mut().zip(vh) {
            *w32 = v as i32;
        }
        for (ks, krow) in scr.ksum[..s].iter_mut().zip(kh.chunks_exact(dh)) {
            *ks = krow.iter().map(|&v| v as i32).sum();
        }
        let zqzk = dh as i32 * zq * zk;
        for (i, orow) in oh.chunks_exact_mut(dh).enumerate() {
            let valid = mask.valid_len(b, i, s);
            if valid == 0 {
                orow.fill(0.0);
                continue;
            }
            let qi = &qh[i * dh..(i + 1) * dh];
            let qsum: i32 = qi.iter().map(|&v| v as i32).sum();
            // 1. integer QK^T row (zero points hoisted out of the dot)
            for (j, sc) in scr.scores[..valid].iter_mut().enumerate() {
                let kj = &kh[j * dh..(j + 1) * dh];
                let mut dot = 0i32;
                for (&a, &bb) in qi.iter().zip(kj) {
                    dot += a as i32 * bb as i32;
                }
                *sc = dot - zk * qsum - zq * scr.ksum[j] + zqzk;
            }
            // 2./3. integer softmax → sig_int
            let sig_sum = self.sig_row(valid, map, scr);
            // 4. sig × V MAC (i32 products — sig ≤ qmax ≤ 32767, |v| ≤ 128
            // — accumulated in i64 so any row length is safe)
            scr.acc[..dh].fill(0);
            for (j, vrow) in scr.v32[..valid * dh].chunks_exact(dh).enumerate() {
                let g = scr.sig[j];
                for (a, &v) in scr.acc[..dh].iter_mut().zip(vrow) {
                    *a += (g * v) as i64;
                }
            }
            let corr = zv as i64 * sig_sum;
            for (o, &a) in orow.iter_mut().zip(&scr.acc[..dh]) {
                *o = (a - corr) as f32 * out_scale;
            }
        }
    }

    /// Score-unit step and derived map/scale for a (q, k, v) triple.
    fn plan(&self, q: &QuantTensor, k: &QuantTensor, v: &QuantTensor, shape: &AttnShape) -> (IntMap, f32) {
        let step =
            (q.affine.scale as f64 * k.affine.scale as f64 / (shape.d_head as f64).sqrt()) as f32;
        (self.int_map(step), v.affine.scale * self.inv_qmax)
    }

    /// Fused attention, sequential over heads. `out` is `(B,H,L,d)`
    /// row-major like `q`.
    pub fn run(
        &self,
        q: &QuantTensor,
        k: &QuantTensor,
        v: &QuantTensor,
        shape: &AttnShape,
        mask: &AttnMask,
        out: &mut [f32],
        scr: &mut AttnScratch,
    ) {
        check_shapes(q, k, v, shape, mask, out);
        let (map, out_scale) = self.plan(q, k, v, shape);
        let (ql, kl, ol) = (shape.len_q * shape.d_head, shape.len_k * shape.d_head, shape.len_q * shape.d_head);
        for h in 0..shape.heads_total() {
            let b = h / shape.heads;
            self.head(
                &q.data[h * ql..(h + 1) * ql],
                &k.data[h * kl..(h + 1) * kl],
                &v.data[h * kl..(h + 1) * kl],
                q.affine.zero_point,
                k.affine.zero_point,
                v.affine.zero_point,
                b,
                shape,
                mask,
                map,
                out_scale,
                &mut out[h * ol..(h + 1) * ol],
                scr,
            );
        }
    }

    /// Fused attention with the B×H head-blocks scattered across a
    /// [`ParSoftmax`] worker pool (bit-identical to [`FusedAttention::run`]
    /// — heads are independent and write disjoint output blocks).
    /// Problems with fewer than two heads or under [`MIN_HEAD_MACS`] of
    /// work per head run inline: tiny requests must not pay a pool wake.
    pub fn run_par(
        &self,
        q: &QuantTensor,
        k: &QuantTensor,
        v: &QuantTensor,
        shape: &AttnShape,
        mask: &AttnMask,
        pool: &ParSoftmax,
        out: &mut [f32],
    ) {
        let head_macs = shape.len_q * shape.len_k * shape.d_head;
        if shape.heads_total() < 2 || head_macs < MIN_HEAD_MACS {
            let mut scr = AttnScratch::new();
            return self.run(q, k, v, shape, mask, out, &mut scr);
        }
        check_shapes(q, k, v, shape, mask, out);
        let (map, out_scale) = self.plan(q, k, v, shape);
        let (ql, kl, ol) = (shape.len_q * shape.d_head, shape.len_k * shape.d_head, shape.len_q * shape.d_head);
        // per-worker AttnScratch instances, reused across head tasks
        let spare: Mutex<Vec<AttnScratch>> = Mutex::new(Vec::new());
        // SAFETY (OutPtr contract): head tasks reconstruct disjoint
        // `ol`-sized blocks of `out` only.
        let optr = OutPtr(out.as_mut_ptr());
        let mut pool_scratch = Scratch::new();
        let outcome = pool.scatter(shape.heads_total(), &mut pool_scratch, &|h, _s| {
            let mut scr = lock_unpoisoned(&spare).pop().unwrap_or_default();
            let b = h / shape.heads;
            let oh = unsafe { std::slice::from_raw_parts_mut(optr.0.add(h * ol), ol) };
            self.head(
                &q.data[h * ql..(h + 1) * ql],
                &k.data[h * kl..(h + 1) * kl],
                &v.data[h * kl..(h + 1) * kl],
                q.affine.zero_point,
                k.affine.zero_point,
                v.affine.zero_point,
                b,
                shape,
                mask,
                map,
                out_scale,
                oh,
                &mut scr,
            );
            lock_unpoisoned(&spare).push(scr);
        });
        // the fused prefill path has no per-session failure domain (one
        // caller, one output tensor) and no fault plan targets it — a
        // contained head panic is re-raised in the submitter, after the
        // whole wave has completed (no hang, pool unpoisoned)
        assert!(
            outcome.is_ok(),
            "fused attention head task panicked (heads {:?})",
            outcome.panicked()
        );
    }

    /// Verification view: the integer-softmax attention map of head block
    /// `bh` (`0..heads_total`) as f32 probabilities, `(L, S)` row-major.
    /// Masked positions are exactly `0.0`. Not a hot path (allocates).
    pub fn probs_head(
        &self,
        q: &QuantTensor,
        k: &QuantTensor,
        shape: &AttnShape,
        mask: &AttnMask,
        bh: usize,
    ) -> Vec<f32> {
        let (l, s, dh) = (shape.len_q, shape.len_k, shape.d_head);
        let step =
            (q.affine.scale as f64 * k.affine.scale as f64 / (dh as f64).sqrt()) as f32;
        let map = self.int_map(step);
        let (zq, zk) = (q.affine.zero_point, k.affine.zero_point);
        let qh = &q.data[bh * l * dh..(bh + 1) * l * dh];
        let kh = &k.data[bh * s * dh..(bh + 1) * s * dh];
        let b = bh / shape.heads;
        let mut scr = AttnScratch::new();
        scr.prepare(s, dh, self.table().len());
        let mut probs = vec![0.0f32; l * s];
        for i in 0..l {
            let valid = mask.valid_len(b, i, s);
            if valid == 0 {
                continue;
            }
            let qi = &qh[i * dh..(i + 1) * dh];
            for (j, sc) in scr.scores[..valid].iter_mut().enumerate() {
                let kj = &kh[j * dh..(j + 1) * dh];
                let mut dot = 0i32;
                for (&a, &bb) in qi.iter().zip(kj) {
                    dot += (a as i32 - zq) * (bb as i32 - zk);
                }
                *sc = dot;
            }
            self.sig_row(valid, map, &mut scr);
            for (p, &g) in probs[i * s..i * s + valid].iter_mut().zip(&scr.sig[..valid]) {
                *p = g as f32 * self.inv_qmax;
            }
        }
        probs
    }
}

fn check_shapes(
    q: &QuantTensor,
    k: &QuantTensor,
    v: &QuantTensor,
    shape: &AttnShape,
    mask: &AttnMask,
    out: &[f32],
) {
    assert_eq!(q.data.len(), shape.q_len(), "q shape mismatch");
    assert_eq!(k.data.len(), shape.kv_len(), "k shape mismatch");
    assert_eq!(v.data.len(), shape.kv_len(), "v shape mismatch");
    assert_eq!(out.len(), shape.q_len(), "out shape mismatch");
    if let AttnMask::Padding(lens) = mask {
        assert_eq!(lens.len(), shape.batch, "one pad length per batch");
    }
}

/// The unfused compose the fused kernel is measured against, and the f32
/// reference for accuracy tests: explicit dequantize passes, a
/// materialized f32 score matrix per head, a full softmax pass over it,
/// then a separate f32 `probs @ V`. Intermediates are allocated per call
/// on purpose — the materialization traffic is what "unfused" means.
pub struct ComposedAttention {
    engine: Box<dyn SoftmaxEngine>,
}

impl ComposedAttention {
    pub fn new(engine: Box<dyn SoftmaxEngine>) -> Self {
        Self { engine }
    }

    /// f32 compose (also the accuracy reference when `engine` is
    /// `SoftmaxExact`). Layouts as in [`FusedAttention::run`].
    pub fn run_f32(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        shape: &AttnShape,
        mask: &AttnMask,
        out: &mut [f32],
    ) {
        let (l, s, dh) = (shape.len_q, shape.len_k, shape.d_head);
        assert_eq!(q.len(), shape.q_len());
        assert_eq!(k.len(), shape.kv_len());
        assert_eq!(v.len(), shape.kv_len());
        assert_eq!(out.len(), shape.q_len());
        let inv_sqrt = 1.0 / (dh as f32).sqrt();
        let mut scores = vec![0.0f32; l * s];
        let mut probs = vec![0.0f32; s];
        let mut scratch = Scratch::new();
        for h in 0..shape.heads_total() {
            let b = h / shape.heads;
            let qh = &q[h * l * dh..(h + 1) * l * dh];
            let kh = &k[h * s * dh..(h + 1) * s * dh];
            let vh = &v[h * s * dh..(h + 1) * s * dh];
            let oh = &mut out[h * l * dh..(h + 1) * l * dh];
            // pass A: materialize the score matrix
            for i in 0..l {
                let qi = &qh[i * dh..(i + 1) * dh];
                for (j, sc) in scores[i * s..(i + 1) * s].iter_mut().enumerate() {
                    let kj = &kh[j * dh..(j + 1) * dh];
                    let mut dot = 0.0f32;
                    for (&a, &bb) in qi.iter().zip(kj) {
                        dot += a * bb;
                    }
                    *sc = dot * inv_sqrt;
                }
            }
            // pass B: softmax over each valid prefix; pass C: probs @ V
            for (i, orow) in oh.chunks_exact_mut(dh).enumerate() {
                let valid = mask.valid_len(b, i, s);
                if valid == 0 {
                    orow.fill(0.0);
                    continue;
                }
                self.engine.run_with(
                    &scores[i * s..i * s + valid],
                    valid,
                    &mut probs[..valid],
                    &mut scratch,
                );
                orow.fill(0.0);
                for (j, vrow) in vh[..valid * dh].chunks_exact(dh).enumerate() {
                    let p = probs[j];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += p * vv;
                    }
                }
            }
        }
    }

    /// The integer-ingress compose: dequantize Q/K/V into fresh f32
    /// buffers (the round-trip the fused path eliminates), then
    /// [`ComposedAttention::run_f32`].
    pub fn run_quant(
        &self,
        q: &QuantTensor,
        k: &QuantTensor,
        v: &QuantTensor,
        shape: &AttnShape,
        mask: &AttnMask,
        out: &mut [f32],
    ) {
        let mut qf = vec![0.0f32; q.data.len()];
        let mut kf = vec![0.0f32; k.data.len()];
        let mut vf = vec![0.0f32; v.data.len()];
        quant::dequantize_into(&q.data, q.affine, &mut qf);
        quant::dequantize_into(&k.data, k.affine, &mut kf);
        quant::dequantize_into(&v.data, v.affine, &mut vf);
        self.run_f32(&qf, &kf, &vf, shape, mask, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::SoftmaxExact;
    use crate::testkit::Rng;

    fn qkv(rng: &mut Rng, shape: &AttnShape) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            rng.normal_vec(shape.q_len(), 1.0),
            rng.normal_vec(shape.kv_len(), 1.0),
            rng.normal_vec(shape.kv_len(), 1.0),
        )
    }

    #[test]
    fn fused_rows_resemble_probability_mixes() {
        // dense probs rows ~ sum to 1 (within LUT quantization), masked
        // entries are exactly zero
        let shape = AttnShape::square(1, 2, 32, 16);
        let mut rng = Rng::new(1);
        let (qf, kf, _vf) = qkv(&mut rng, &shape);
        let q = QuantTensor::quantize(&qf);
        let k = QuantTensor::quantize(&kf);
        let fused = FusedAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let probs = fused.probs_head(&q, &k, &shape, &AttnMask::Dense, 1);
        for row in probs.chunks_exact(shape.len_k) {
            let s: f32 = row.iter().sum();
            assert!(s > 0.5 && s < 1.5, "row sum {s}");
        }
        let causal = fused.probs_head(&q, &k, &shape, &AttnMask::Causal, 0);
        for i in 0..shape.len_q {
            for j in i + 1..shape.len_k {
                assert_eq!(causal[i * shape.len_k + j], 0.0, "({i},{j}) must be masked");
            }
        }
    }

    #[test]
    fn par_heads_match_sequential_exactly() {
        let shape = AttnShape::square(2, 3, 24, 8);
        let mut rng = Rng::new(2);
        let (qf, kf, vf) = qkv(&mut rng, &shape);
        let (q, k, v) = (
            QuantTensor::quantize(&qf),
            QuantTensor::quantize(&kf),
            QuantTensor::quantize(&vf),
        );
        for mode in [Mode::Rexp, Mode::Lut2d] {
            let fused = FusedAttention::new(mode, Precision::Uint8, None).unwrap();
            let mut seq = vec![0.0f32; shape.q_len()];
            let mut par = vec![0.0f32; shape.q_len()];
            let mut scr = AttnScratch::new();
            let mask = AttnMask::Padding(vec![20, 0]);
            fused.run(&q, &k, &v, &shape, &mask, &mut seq, &mut scr);
            let pool = crate::softmax::engine_parallel(mode, Precision::Uint8, None, Some(3));
            fused.run_par(&q, &k, &v, &shape, &mask, &pool, &mut par);
            assert_eq!(seq, par, "{mode:?}");
            // batch 1 is fully padded: all-zero output rows
            let half = shape.q_len() / 2;
            assert!(seq[half..].iter().all(|&o| o == 0.0));
            assert!(seq[..half].iter().any(|&o| o != 0.0));
        }
    }

    #[test]
    fn tiny_heads_run_inline_instead_of_waking_the_pool() {
        // B=1,H=2,L=8,d=16 -> 1024 MACs/head, far below MIN_HEAD_MACS:
        // run_par must compute inline (and stay == with run)
        let shape = AttnShape::square(1, 2, 8, 16);
        let mut rng = Rng::new(3);
        let (qf, kf, vf) = qkv(&mut rng, &shape);
        let (q, k, v) = (
            QuantTensor::quantize(&qf),
            QuantTensor::quantize(&kf),
            QuantTensor::quantize(&vf),
        );
        let fused = FusedAttention::new(Mode::Rexp, Precision::Uint8, None).unwrap();
        let pool = crate::softmax::engine_parallel(Mode::Rexp, Precision::Uint8, None, Some(4));
        let mut seq = vec![0.0f32; shape.q_len()];
        let mut par = vec![0.0f32; shape.q_len()];
        let mut scr = AttnScratch::new();
        fused.run(&q, &k, &v, &shape, &AttnMask::Dense, &mut seq, &mut scr);
        fused.run_par(&q, &k, &v, &shape, &AttnMask::Dense, &pool, &mut par);
        assert_eq!(seq, par);
        assert_eq!(pool.parallel_batches(), 0, "tiny heads must not fan out");
    }

    #[test]
    fn non_lut_modes_are_rejected() {
        assert!(FusedAttention::new(Mode::Exact, Precision::Uint8, None).is_err());
        assert!(FusedAttention::new(Mode::Aggressive, Precision::Uint8, None).is_err());
    }

    #[test]
    fn composed_exact_is_a_softmax_mixture() {
        // composed with SoftmaxExact: a one-hot V recovers the probs row
        let shape = AttnShape::square(1, 1, 4, 4);
        let mut rng = Rng::new(4);
        let (qf, kf, _) = qkv(&mut rng, &shape);
        let mut vf = vec![0.0f32; shape.kv_len()];
        for j in 0..shape.len_k {
            vf[j * shape.d_head + j] = 1.0; // V = identity
        }
        let mut out = vec![0.0f32; shape.q_len()];
        ComposedAttention::new(Box::new(SoftmaxExact)).run_f32(
            &qf,
            &kf,
            &vf,
            &shape,
            &AttnMask::Dense,
            &mut out,
        );
        for row in out.chunks_exact(shape.d_head) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row sum {s}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }
}
