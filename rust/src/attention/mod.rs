//! Integer-native attention: fused `QK^T (i8) → LUT softmax → ×V`.
//!
//! The paper's premise is that attention inputs are normalized and
//! quantized, so the softmax approximation stays cheap *inside* an
//! integer attention block (the A³ / SOLE architecture). This module is
//! that block on the rust side:
//!
//! * Q/K/V are per-tensor-affine `i8` ([`QuantTensor`], params from
//!   [`crate::quant::Affine`]);
//! * QK^T accumulates in `i32` (the zero points are hoisted out of the
//!   inner dot product algebraically — the MAC loop is a raw `i8×i8`
//!   widening dot);
//! * the softmax stage is the integer pass-1 substrate of
//!   [`crate::softmax`] (fixed-point diff→LUT-address map, integer row
//!   sum, integer normalizer) producing `sig_int ∈ [0, qmax]` — an f32
//!   probability matrix is **never materialized**;
//! * `probs × V` is another integer MAC (`sig_int × i8`, i64
//!   accumulators so every precision is safe), with one fused
//!   `(acc − z_v·Σsig) · s_v/qmax` dequant per output element.
//!
//! Masks ([`AttnMask`]) are prefix-shaped (causal and PAD are both "a
//! valid key prefix per row"), so masking is a **loop bound**, not a
//! per-element branch: masked positions cost nothing and their
//! probability is exactly 0.
//!
//! [`kernel::FusedAttention`] is the fused kernel (sequential and
//! pool-scattered via [`crate::softmax::ParSoftmax::scatter`], one task
//! per B×H head); [`kernel::ComposedAttention`] is the unfused
//! dequantize → f32 QK^T → softmax → ×V compose it is benchmarked
//! against (`attn/*` vs `attn_unfused/*` in `softmax_bench`). The
//! serving route `"attn:<mode>:<prec[:aN]>"` (see
//! [`crate::coordinator`]) is parsed by [`parse_route`].
//!
//! [`decode::DecodeAttention`] is the streaming-decode entry point: one
//! query row per generated token over a paged integer KV cache
//! ([`crate::kv`]), bit-identical to a causal prefill through this same
//! kernel; its serving route `"decode:<mode>:<prec>[:aN][:gG][:pP][:fS]"`
//! is parsed by [`parse_decode_route`]. The decode hot path sweeps the
//! cache **group-major** ([`decode::SweepOrder`]): one sweep unit per
//! stored K/V group, reading each page once per group per step for all
//! `H/G` query heads sharing it — bit-identical to the head-major
//! reference order, which re-reads pages once per query head.
//! [`DecodeAttention::prefill_chunk`] ingests whole prompt blocks
//! (append `T'` tokens, attend once — bit-identical to `T'` single
//! steps), and [`batch::DecodeBatch`] collects many concurrent
//! sessions' steps into ONE group-scatter wave per serving round (the
//! coordinator's `DecodeStepBatch` round).

mod batch;
mod decode;
mod kernel;

pub use batch::{DecodeBatch, DecodeStepTask, WaveError, WaveStats};
pub use decode::{
    parse_decode_route, spans_for, DecodeAttention, DecodeRoute, RouteError, SplitReport,
    SweepOrder, DECODE_AFFINE,
};
pub use kernel::{AttnScratch, ComposedAttention, FusedAttention};

use crate::lut::Precision;
use crate::quant::{self, Affine};
use crate::softmax::Mode;

/// Default LUT_alpha length for attention workloads: rows are long (a
/// whole key prefix), so the REXP row sum needs the paper's DETR-style
/// 256-entry table rather than the 16-entry NLP default (Table 5). Rows
/// with `sum >> w` beyond the table still saturate to zero probability —
/// the Fig. 4 property; size via the route's `:aN` suffix per workload.
pub const ATTN_ALPHA_LEN: usize = 256;

/// Shape of one attention problem: `q` is `(batch, heads, len_q, d_head)`
/// and `k`/`v` are `(batch, heads, len_k, d_head)`, all row-major.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnShape {
    pub batch: usize,
    pub heads: usize,
    pub len_q: usize,
    pub len_k: usize,
    pub d_head: usize,
}

impl AttnShape {
    /// Square self-attention shape (len_q == len_k).
    pub fn square(batch: usize, heads: usize, len: usize, d_head: usize) -> Self {
        Self { batch, heads, len_q: len, len_k: len, d_head }
    }

    pub fn heads_total(&self) -> usize {
        self.batch * self.heads
    }

    /// elements of q (and of the output)
    pub fn q_len(&self) -> usize {
        self.heads_total() * self.len_q * self.d_head
    }

    /// elements of k / v
    pub fn kv_len(&self) -> usize {
        self.heads_total() * self.len_k * self.d_head
    }

    /// score elements per full (unmasked) problem — the work measure the
    /// benches report element throughput against
    pub fn score_len(&self) -> usize {
        self.heads_total() * self.len_q * self.len_k
    }
}

/// Attention masks. Both of the paper's workload masks are prefix-shaped,
/// which is why the kernel can mask with a loop bound: row `i` of batch
/// `b` attends to key positions `0..valid_len(b, i)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AttnMask {
    /// every query attends to every key
    Dense,
    /// query `i` attends to keys `0..=i` (requires len_q == len_k to be
    /// meaningful; longer prefixes clamp to len_k)
    Causal,
    /// per-batch valid key prefix lengths (`lens.len() == batch`); a 0
    /// entry means a fully-padded batch element (output rows are zeroed)
    Padding(Vec<usize>),
}

impl AttnMask {
    /// Valid key prefix for query row `i` of batch `b`.
    #[inline]
    pub fn valid_len(&self, b: usize, i: usize, len_k: usize) -> usize {
        match self {
            AttnMask::Dense => len_k,
            AttnMask::Causal => (i + 1).min(len_k),
            AttnMask::Padding(lens) => lens[b].min(len_k),
        }
    }
}

/// A per-tensor-affine quantized activation tensor: the ingress format of
/// the fused kernel.
#[derive(Clone, Debug)]
pub struct QuantTensor {
    pub data: Vec<i8>,
    pub affine: Affine,
}

impl QuantTensor {
    /// Fit an affine to `x` and quantize (the serving path's per-request
    /// ingress).
    pub fn quantize(x: &[f32]) -> Self {
        let (data, affine) = quant::quantize(x);
        Self { data, affine }
    }

    /// Quantize with fixed params (tests construct dyadic scales to pin
    /// bit-exactness against the f32 datapath).
    pub fn quantize_with(x: &[f32], affine: Affine) -> Self {
        let mut data = vec![0i8; x.len()];
        quant::quantize_into(x, affine, &mut data);
        Self { data, affine }
    }
}

/// Parse an attention route spec `"attn:<mode>:<prec[:aN]>"` (e.g.
/// `"attn:rexp:uint8"`, `"attn:rexp:uint8:a512"`) into
/// `(mode, precision, alpha_len)`. Returns `None` for anything else,
/// including non-LUT modes — the fused kernel is integer-native only.
pub fn parse_route(spec: &str) -> Option<(Mode, Precision, Option<usize>)> {
    let rest = spec.strip_prefix("attn:")?;
    let (mode_s, prec_s) = rest.split_once(':')?;
    let mode = Mode::parse(mode_s)?;
    if !matches!(mode, Mode::Rexp | Mode::Lut2d) {
        return None;
    }
    let (prec, alpha_len) = Precision::parse_spec(prec_s)?;
    Some((mode, prec, alpha_len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_valid_lens() {
        assert_eq!(AttnMask::Dense.valid_len(0, 3, 16), 16);
        assert_eq!(AttnMask::Causal.valid_len(0, 0, 16), 1);
        assert_eq!(AttnMask::Causal.valid_len(1, 5, 16), 6);
        assert_eq!(AttnMask::Causal.valid_len(0, 40, 16), 16);
        let pad = AttnMask::Padding(vec![7, 0]);
        assert_eq!(pad.valid_len(0, 9, 16), 7);
        assert_eq!(pad.valid_len(1, 0, 16), 0);
    }

    #[test]
    fn shape_accounting() {
        let s = AttnShape::square(2, 4, 64, 32);
        assert_eq!(s.heads_total(), 8);
        assert_eq!(s.q_len(), 2 * 4 * 64 * 32);
        assert_eq!(s.kv_len(), s.q_len());
        assert_eq!(s.score_len(), 2 * 4 * 64 * 64);
    }

    #[test]
    fn route_parsing() {
        let (m, p, a) = parse_route("attn:rexp:uint8").unwrap();
        assert_eq!((m, p, a), (Mode::Rexp, Precision::Uint8, None));
        let (m, p, a) = parse_route("attn:lut2d:int16:a512").unwrap();
        assert_eq!((m, p, a), (Mode::Lut2d, Precision::Int16, Some(512)));
        assert!(parse_route("attn:exact:uint8").is_none(), "non-LUT mode");
        assert!(parse_route("cpu:rexp:uint8").is_none());
        assert!(parse_route("attn:rexp").is_none());
        assert!(parse_route("attn:rexp:float64").is_none());
    }

    #[test]
    fn quantize_roundtrip_through_quant_tensor() {
        let x = [0.5f32, -1.0, 0.0, 2.0];
        let t = QuantTensor::quantize(&x);
        for (&q, &v) in t.data.iter().zip(&x) {
            assert!((t.affine.dequantize(q) - v).abs() <= t.affine.scale);
        }
        let fixed = QuantTensor::quantize_with(&x, Affine { scale: 0.0625, zero_point: 0 });
        assert_eq!(fixed.data[0], 8); // 0.5 / 0.0625
    }
}
