//! Deterministic fault injection for the serving stack.
//!
//! Production failure modes — a panicking worker task, a spurious
//! allocation failure, a scheduler round blowing its deadline — are rare
//! and timing-dependent in the wild, which makes the *containment*
//! machinery (panic isolation in `softmax/par.rs`, eviction/retry in the
//! scheduler, typed `Reply::Error`/`Reply::Shed` surfaces) the least
//! tested code in the stack. A [`FaultPlan`] turns those events into a
//! deterministic, replayable schedule so the chaos suites
//! (`integration_decode_batch.rs`, conformance invariant 8) can assert
//! the failure-domain contract exactly: every injected fault surfaces as
//! one typed reply, non-faulted sessions replay bit-identically, nothing
//! hangs, and the KV free list round-trips.
//!
//! # Determinism under parallelism
//!
//! The plan is **stateless per query**: [`FaultPlan::should_fault`] is a
//! pure hash of `(seed, site, index)` — no internal RNG stream, no
//! `std::time`. Each injection site supplies its own monotone index (the
//! KV pool counts allocation attempts, the scatter layer counts tasks,
//! the scheduler counts rounds), so the fault schedule depends only on
//! the sequence of *logical* events, never on thread interleaving or
//! wall-clock — the same run replays the same faults.
//!
//! # Zero cost when disabled
//!
//! [`FaultPlan::none`] has every site rate at 0; `should_fault` then
//! returns `false` after a single integer compare, and the hot paths
//! guard on [`FaultPlan::is_none`] before doing any per-event counting.
//!
//! # Wiring
//!
//! - [`crate::kv::KvPool::set_fault_plan`] — spurious
//!   `KvError::Exhausted` on page allocation ([`FaultSite::KvAlloc`]).
//! - [`crate::softmax::ParSoftmax::set_fault_plan`] — injected task
//!   panic / injected slow task in the worker loop
//!   ([`FaultSite::WorkerPanic`] / [`FaultSite::WorkerSlow`]); panics
//!   are contained by the pool and surface as a scatter-level outcome.
//! - the decode scheduler reads the pipeline's plan per round
//!   ([`FaultSite::SchedDeadline`]) and sheds the oldest waiting
//!   request as if its deadline overran.
//! - the spill restore path draws [`FaultSite::SpillCorrupt`] per
//!   restore attempt: a hit simulates a corrupted host copy, forcing
//!   the checksum-mismatch fallback onto the replay log (see
//!   `docs/RELIABILITY.md`).
//! - the `"decode:..."` route accepts an `fSEED` segment, so a fault
//!   plan is installable over the wire (`lutmax serve` smoke, benches).

/// Marker substring of every injected panic's payload. The
/// [`silence_injected_panics`] hook (and log scrapers) match on it;
/// genuine panics never contain it.
pub const INJECTED_PANIC: &str = "injected fault";

/// An injection site. Each site draws from its own hash domain, so
/// enabling one site never perturbs another's schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// spurious [`crate::kv::KvError::Exhausted`] on a page allocation
    /// (single-token append or block reserve), despite free pages
    KvAlloc,
    /// a `ParSoftmax` scatter task panics before running
    WorkerPanic,
    /// a `ParSoftmax` scatter task is delayed (yields) before running —
    /// perturbs completion order, must never perturb bytes
    WorkerSlow,
    /// a scheduler round overruns its deadline: the oldest waiting
    /// request is shed with a typed `Reply::Shed`
    SchedDeadline,
    /// a spilled session's host copy fails its checksum on restore, so
    /// the copy-back path must fall back to the replay log
    SpillCorrupt,
}

/// A seeded, replayable fault schedule. `Copy` and a few words, so
/// layers store it by value; [`FaultPlan::none`] (the default) is free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    /// per-site denominators: site fires on ~1/denom of its events;
    /// 0 disables the site
    kv_alloc: u32,
    worker_panic: u32,
    worker_slow: u32,
    sched_deadline: u32,
    spill_corrupt: u32,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::none()
    }
}

impl FaultPlan {
    /// The disabled plan: every site off, every query a single compare.
    pub const fn none() -> Self {
        Self {
            seed: 0,
            kv_alloc: 0,
            worker_panic: 0,
            worker_slow: 0,
            sched_deadline: 0,
            spill_corrupt: 0,
        }
    }

    /// A chaos-soak default: every site enabled at a moderate rate.
    /// Same seed ⇒ same schedule, across processes. (Corrupt spills are
    /// safe to arm here: the fallback ladder replays the host log, which
    /// is still bit-identical — the site tests the ladder, not the bits.)
    pub const fn seeded(seed: u64) -> Self {
        Self {
            seed,
            kv_alloc: 13,
            worker_panic: 11,
            worker_slow: 5,
            sched_deadline: 9,
            spill_corrupt: 7,
        }
    }

    /// Builder: set one site's denominator (fires on ~1/`denom` of the
    /// site's events; 0 disables it).
    pub const fn with(mut self, site: FaultSite, denom: u32) -> Self {
        match site {
            FaultSite::KvAlloc => self.kv_alloc = denom,
            FaultSite::WorkerPanic => self.worker_panic = denom,
            FaultSite::WorkerSlow => self.worker_slow = denom,
            FaultSite::SchedDeadline => self.sched_deadline = denom,
            FaultSite::SpillCorrupt => self.spill_corrupt = denom,
        }
        self
    }

    /// Builder: replace the seed, keeping the site rates.
    pub const fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// `true` when no site can ever fire.
    pub fn is_none(&self) -> bool {
        self.kv_alloc == 0
            && self.worker_panic == 0
            && self.worker_slow == 0
            && self.sched_deadline == 0
            && self.spill_corrupt == 0
    }

    /// Does `site`'s `index`-th event fault? Pure in `(seed, site,
    /// index)` — thread-interleaving-independent, replayable.
    #[inline]
    pub fn should_fault(&self, site: FaultSite, index: u64) -> bool {
        let denom = match site {
            FaultSite::KvAlloc => self.kv_alloc,
            FaultSite::WorkerPanic => self.worker_panic,
            FaultSite::WorkerSlow => self.worker_slow,
            FaultSite::SchedDeadline => self.sched_deadline,
            FaultSite::SpillCorrupt => self.spill_corrupt,
        };
        if denom == 0 {
            return false;
        }
        let tag = match site {
            FaultSite::KvAlloc => 0x4B56_414C_4C4F_4331,
            FaultSite::WorkerPanic => 0x5041_4E49_4331_0001,
            FaultSite::WorkerSlow => 0x534C_4F57_0000_0002,
            FaultSite::SchedDeadline => 0x4445_4144_4C4E_0003,
            FaultSite::SpillCorrupt => 0x5350_4C43_5250_0005,
        };
        mix64(self.seed ^ tag ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15)) % denom as u64 == 0
    }
}

/// splitmix64 finalizer — the avalanche behind [`FaultPlan::should_fault`].
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Install (once, process-wide) a panic hook that suppresses the default
/// "thread panicked" printout for **injected** panics only — payloads
/// containing [`INJECTED_PANIC`] — and delegates everything else to the
/// previously-installed hook. Chaos suites and the serve/bench fault
/// scenarios call this so a seeded plan doesn't spray thousands of
/// expected backtraces; genuine panics still report normally.
pub fn silence_injected_panics() {
    use std::sync::Once;
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains(INJECTED_PANIC))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<String>()
                        .map(|s| s.contains(INJECTED_PANIC))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_free_and_never_fires() {
        let p = FaultPlan::none();
        assert!(p.is_none());
        assert_eq!(p, FaultPlan::default());
        for site in [
            FaultSite::KvAlloc,
            FaultSite::WorkerPanic,
            FaultSite::WorkerSlow,
            FaultSite::SchedDeadline,
            FaultSite::SpillCorrupt,
        ] {
            for i in 0..1000 {
                assert!(!p.should_fault(site, i));
            }
        }
    }

    #[test]
    fn schedules_are_replayable_and_seed_sensitive() {
        let a = FaultPlan::seeded(42);
        let b = FaultPlan::seeded(42);
        let c = FaultPlan::seeded(43);
        assert!(!a.is_none());
        let trace = |p: &FaultPlan| -> Vec<bool> {
            (0..512).map(|i| p.should_fault(FaultSite::WorkerPanic, i)).collect()
        };
        assert_eq!(trace(&a), trace(&b), "same seed, same schedule");
        assert_ne!(trace(&a), trace(&c), "seeds must diverge");
        // the schedule is a pure function of the index: querying out of
        // order or repeatedly changes nothing
        for i in [7u64, 3, 7, 511, 0, 7] {
            assert_eq!(
                a.should_fault(FaultSite::WorkerPanic, i),
                b.should_fault(FaultSite::WorkerPanic, i)
            );
        }
    }

    #[test]
    fn sites_draw_from_independent_domains() {
        let p = FaultPlan::seeded(7).with(FaultSite::WorkerSlow, 11);
        let panics: Vec<u64> =
            (0..2000).filter(|&i| p.should_fault(FaultSite::WorkerPanic, i)).collect();
        let slows: Vec<u64> =
            (0..2000).filter(|&i| p.should_fault(FaultSite::WorkerSlow, i)).collect();
        assert!(!panics.is_empty() && !slows.is_empty());
        assert_ne!(panics, slows, "sites must not share a schedule");
        // disabling one site leaves the other's schedule untouched
        let q = p.with(FaultSite::WorkerPanic, 0);
        let slows_q: Vec<u64> =
            (0..2000).filter(|&i| q.should_fault(FaultSite::WorkerSlow, i)).collect();
        assert_eq!(slows, slows_q);
        assert!(!(0..2000).any(|i| q.should_fault(FaultSite::WorkerPanic, i)));
    }

    #[test]
    fn rates_land_near_their_denominators() {
        let p = FaultPlan::seeded(1);
        let n = 20_000u64;
        let hits = (0..n).filter(|&i| p.should_fault(FaultSite::KvAlloc, i)).count();
        let expect = n as f64 / 13.0;
        assert!(
            (hits as f64) > expect * 0.7 && (hits as f64) < expect * 1.3,
            "kv_alloc fired {hits} of {n} (expected ≈ {expect:.0})"
        );
    }

    #[test]
    fn builder_toggles_sites() {
        let p = FaultPlan::none().with_seed(5).with(FaultSite::WorkerPanic, 1);
        assert!(!p.is_none());
        // denominator 1: every event faults
        assert!((0..64).all(|i| p.should_fault(FaultSite::WorkerPanic, i)));
        assert!((0..64).all(|i| !p.should_fault(FaultSite::KvAlloc, i)));
    }
}
