//! `artifacts/manifest.json` — the index the runtime loads everything from.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::config::Json;

/// One lowered HLO artifact (a model graph or a standalone softmax kernel).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// enc / dec / cls / det / softmax
    pub kind: String,
    pub model: Option<String>,
    pub weights: Option<String>,
    pub mode: String,
    pub spec: String,
    pub file: String,
    /// number of LUT-table operands the artifact takes between the weights
    /// and the data inputs (rebuilt by the rust lut substrate at load)
    pub tables: usize,
    /// non-weight, non-table input signature (shape, dtype) in call order
    pub inputs: Vec<(Vec<usize>, String)>,
}

/// Parsed manifest + typed views of the fields the runtime needs.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    /// model name -> ordered param leaf names (the HLO parameter order)
    pub param_order: BTreeMap<String, Vec<String>>,
    /// model name -> (fp32_bytes, ptqd_bytes) for Table 4
    pub model_bytes: BTreeMap<String, (usize, usize)>,
    pub nmt_max_src: usize,
    pub nmt_max_tgt: usize,
    pub nmt_vocab: usize,
    pub batch_nmt: usize,
    pub batch_cls: usize,
    pub batch_detr: usize,
    pub raw: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let raw = Json::parse_file(&dir.join("manifest.json"))?;
        let mut artifacts = BTreeMap::new();
        for a in raw
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts not an array"))?
        {
            let name = a.req("name")?.as_str().unwrap_or_default().to_string();
            let inputs = a
                .req("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|i| {
                    let dims = i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|s| s.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default();
                    let dt = i
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string();
                    (dims, dt)
                })
                .collect();
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name,
                    kind: a.req("kind")?.as_str().unwrap_or_default().into(),
                    model: a.get("model").and_then(Json::as_str).map(Into::into),
                    weights: a.get("weights").and_then(Json::as_str).map(Into::into),
                    mode: a.get("mode").and_then(Json::as_str).unwrap_or("").into(),
                    spec: a.get("spec").and_then(Json::as_str).unwrap_or("").into(),
                    file: a.req("file")?.as_str().unwrap_or_default().into(),
                    tables: a.get("tables").and_then(Json::as_usize).unwrap_or(0),
                    inputs,
                },
            );
        }

        let mut param_order = BTreeMap::new();
        let mut model_bytes = BTreeMap::new();
        if let Some(w) = raw.get("weights").and_then(Json::as_obj) {
            for (model, meta) in w {
                let order = meta
                    .get("param_order")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(Json::as_str)
                            .map(String::from)
                            .collect()
                    })
                    .unwrap_or_default();
                param_order.insert(model.clone(), order);
                let fp = meta
                    .get("fp32_bytes")
                    .and_then(Json::as_usize)
                    .unwrap_or(0);
                let pq = meta
                    .get("ptqd_bytes")
                    .and_then(Json::as_usize)
                    .unwrap_or(0);
                model_bytes.insert(model.clone(), (fp, pq));
            }
        }

        let g = |a: &str, b: &str, d: usize| -> usize {
            raw.get(a)
                .and_then(|v| v.get(b))
                .and_then(Json::as_usize)
                .unwrap_or(d)
        };
        Ok(Self {
            dir: dir.to_path_buf(),
            artifacts,
            param_order,
            model_bytes,
            nmt_max_src: g("nmt", "max_src", 20),
            nmt_max_tgt: g("nmt", "max_tgt", 21),
            nmt_vocab: g("nmt", "vocab", 64),
            batch_nmt: g("batch", "nmt", 8),
            batch_cls: g("batch", "cls", 8),
            batch_detr: g("batch", "detr", 4),
            raw,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// All artifacts of a given model (e.g. "nmt14"), sorted by name.
    pub fn model_artifacts(&self, model: &str) -> Vec<&ArtifactMeta> {
        self.artifacts
            .values()
            .filter(|a| a.model.as_deref() == Some(model))
            .collect()
    }

    /// Variant name prefix -> artifact of the given kind, e.g.
    /// (`"nmt14__ptqd__rexp__uint8"`, `"dec"`).
    pub fn variant_artifact(&self, variant: &str, kind: &str) -> Result<&ArtifactMeta> {
        self.artifact(&format!("{variant}__{kind}"))
    }
}
