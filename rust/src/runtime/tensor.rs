//! Host-side tensor type crossing the L3 <-> PJRT boundary.

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: TensorData::I32(data) }
    }

    pub fn zeros_f32(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Self::f32(dims, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self.data {
            TensorData::F32(_) => "float32",
            TensorData::I32(_) => "int32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is int32, expected float32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is float32, expected int32")),
        }
    }

    /// Row `i` of a 2-D tensor, as a slice.
    pub fn row_f32(&self, i: usize) -> Result<&[f32]> {
        let n = *self.dims.last().ok_or_else(|| anyhow!("0-d tensor"))?;
        let v = self.as_f32()?;
        Ok(&v[i * n..(i + 1) * n])
    }

    pub fn row_i32(&self, i: usize) -> Result<&[i32]> {
        let n = *self.dims.last().ok_or_else(|| anyhow!("0-d tensor"))?;
        let v = self.as_i32()?;
        Ok(&v[i * n..(i + 1) * n])
    }

    /// Check dims match, for validating artifact input signatures.
    pub fn expect_dims(&self, dims: &[usize]) -> Result<()> {
        if self.dims != dims {
            bail!("shape mismatch: got {:?}, expected {:?}", self.dims, dims);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_and_rows() {
        let t = Tensor::f32(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.row_f32(1).unwrap(), &[3., 4., 5.]);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn expect_dims() {
        let t = Tensor::i32(vec![4], vec![1, 2, 3, 4]);
        assert!(t.expect_dims(&[4]).is_ok());
        assert!(t.expect_dims(&[2, 2]).is_err());
    }
}
