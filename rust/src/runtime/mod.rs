//! PJRT runtime: artifact manifest, LTB tensor bundles, and the engine
//! that compiles `artifacts/*.hlo.txt` once and executes them from the
//! request path.

mod executor;
mod manifest;
mod tensor;
pub mod tensorio;

pub use executor::{mode_tables, Engine, ModelRunner};
pub use manifest::{ArtifactMeta, Manifest};
pub use tensor::{Tensor, TensorData};
