//! LTB1 tensor bundles — rust side of `python/compile/tensorio.py`.
//!
//! Layout (little-endian): magic `LTB1`, u32 count, then per tensor:
//! u16 name_len + name, u8 dtype (0=f32, 1=i32), u8 ndim, ndim x u32 dims,
//! raw LE data.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::tensor::{Tensor, TensorData};

const MAGIC: &[u8; 4] = b"LTB1";

pub fn read_bundle(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path).with_context(|| path.display().to_string())?;
    read_bundle_bytes(&bytes).with_context(|| path.display().to_string())
}

pub fn read_bundle_bytes(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut r = bytes;
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad LTB magic {magic:?}");
    }
    let count = read_u32(&mut r)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name_len = read_u16(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let n: usize = dims.iter().product();
        let data = match dtype {
            0 => {
                let mut buf = vec![0u8; n * 4];
                r.read_exact(&mut buf)?;
                TensorData::F32(
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            1 => {
                let mut buf = vec![0u8; n * 4];
                r.read_exact(&mut buf)?;
                TensorData::I32(
                    buf.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            d => bail!("tensor {name}: unknown dtype code {d}"),
        };
        out.insert(name, Tensor { dims, data });
    }
    Ok(out)
}

pub fn write_bundle(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        out.extend_from_slice(nb);
        let code: u8 = match t.data {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
        };
        out.push(code);
        out.push(t.dims.len() as u8);
        for &d in &t.dims {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    let mut f = std::fs::File::create(path).with_context(|| path.display().to_string())?;
    f.write_all(&out)?;
    Ok(())
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).map_err(|_| anyhow!("truncated LTB"))?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut &[u8]) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b).map_err(|_| anyhow!("truncated LTB"))?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::f32(vec![2, 3], vec![1.5; 6]));
        m.insert("b/c".into(), Tensor::i32(vec![4], vec![-1, 0, 7, 42]));
        let dir = std::env::temp_dir().join("lutmax_ltb_test.ltb");
        write_bundle(&dir, &m).unwrap();
        let back = read_bundle(&dir).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["a"].dims, vec![2, 3]);
        assert_eq!(back["a"].as_f32().unwrap(), &[1.5; 6][..]);
        assert_eq!(back["b/c"].as_i32().unwrap(), &[-1, 0, 7, 42][..]);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_bundle_bytes(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut m = BTreeMap::new();
        m.insert("t".into(), Tensor::f32(vec![8], vec![0.0; 8]));
        let p = std::env::temp_dir().join("lutmax_ltb_trunc.ltb");
        write_bundle(&p, &m).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.truncate(bytes.len() - 5);
        assert!(read_bundle_bytes(&bytes).is_err());
        std::fs::remove_file(p).ok();
    }
}
