//! PJRT executor: load HLO text artifacts, compile once, execute many.
//!
//! HLO *text* is the interchange format (jax >= 0.5 emits HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids — /opt/xla-example/README.md).
//!
//! PJRT handles in the `xla` crate are `Rc`-based and thread-confined, so
//! an [`Engine`] lives on ONE thread; the coordinator talks to it through
//! channels (see `coordinator::server`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::{Tensor, TensorData};
use super::tensorio;

/// A compiled artifact plus device-resident weight + LUT-table buffers.
pub struct ModelRunner {
    exe: Rc<xla::PjRtLoadedExecutable>,
    weights: Rc<Vec<xla::PjRtBuffer>>,
    /// LUT operands rebuilt from the lut substrate per (mode, spec) — the
    /// paper's reconfigure-on-demand: swap tables without recompiling
    tables: Vec<xla::PjRtBuffer>,
    pub meta: ArtifactMeta,
}

/// LUT operands a (mode, spec) pair requires, in artifact order — mirrors
/// `python/compile/model.py::variant_tables`.
pub fn mode_tables(mode: &str, spec: &str) -> Result<Vec<Tensor>> {
    use crate::lut::{lut2d_tables, lut_recip_e, rexp_tables, Precision, SIGMA_ROWS};

    let parse = || {
        Precision::parse_spec(spec)
            .ok_or_else(|| anyhow!("bad precision spec {spec:?}"))
    };
    Ok(match mode {
        "rexp" => {
            let (p, alpha_len) = parse()?;
            let t = rexp_tables(p, alpha_len);
            vec![
                Tensor::i32(vec![t.recip_e.len()], t.recip_e),
                Tensor::i32(vec![t.alpha.len()], t.alpha),
            ]
        }
        "lut2d" => {
            let (p, _) = parse()?;
            let t = lut2d_tables(p, None);
            vec![
                Tensor::i32(vec![t.exp.len()], t.exp),
                Tensor::i32(vec![t.row.len()], t.row),
                Tensor::i32(vec![SIGMA_ROWS, t.cols], t.sigma),
            ]
        }
        "aggressive" => {
            let (p, _) = parse()?;
            let r = lut_recip_e(p);
            vec![Tensor::i32(vec![r.len()], r)]
        }
        _ => vec![],
    })
}

/// Thread-confined PJRT engine with executable + weight caches.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    weights: RefCell<HashMap<String, Rc<Vec<xla::PjRtBuffer>>>>,
    /// executions performed (metrics)
    pub exec_count: RefCell<u64>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)
            .with_context(|| "loading manifest (run `make artifacts` first?)")?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Self {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    /// Compile (or fetch cached) an artifact by manifest name.
    pub fn compile(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.artifact(name)?.clone();
        let path = self.manifest.hlo_path(&meta);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).map_err(wrap)?);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Device-resident weight buffers for `(model, weights)`, loaded from
    /// `weights_<model>_<weights>.ltb` in manifest param order and cached.
    pub fn weight_buffers(
        &self,
        model: &str,
        weights: &str,
    ) -> Result<Rc<Vec<xla::PjRtBuffer>>> {
        let key = format!("{model}_{weights}");
        if let Some(w) = self.weights.borrow().get(&key) {
            return Ok(w.clone());
        }
        let path = self.manifest.dir.join(format!("weights_{key}.ltb"));
        let bundle = tensorio::read_bundle(&path)?;
        // bundle keys are "NNN:leaf/path" — numeric prefix fixes the order
        let mut entries: Vec<_> = bundle.into_iter().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let mut bufs = Vec::with_capacity(entries.len());
        for (_, t) in entries {
            bufs.push(self.host_to_device(&t)?);
        }
        let rc = Rc::new(bufs);
        self.weights.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    pub fn host_to_device(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let b = match &t.data {
            TensorData::F32(v) => self
                .client
                .buffer_from_host_buffer(v, &t.dims, None)
                .map_err(wrap)?,
            TensorData::I32(v) => self
                .client
                .buffer_from_host_buffer(v, &t.dims, None)
                .map_err(wrap)?,
        };
        Ok(b)
    }

    /// Build a [`ModelRunner`] for a model-variant artifact.
    pub fn model_runner(&self, artifact_name: &str) -> Result<ModelRunner> {
        let meta = self.manifest.artifact(artifact_name)?.clone();
        let model = meta
            .model
            .clone()
            .ok_or_else(|| anyhow!("{artifact_name} is not a model artifact"))?;
        let weights = meta
            .weights
            .clone()
            .ok_or_else(|| anyhow!("{artifact_name} has no weights field"))?;
        let table_tensors = mode_tables(&meta.mode, &meta.spec)?;
        if table_tensors.len() != meta.tables {
            bail!(
                "{artifact_name}: manifest declares {} table operands, lut \
                 substrate built {}",
                meta.tables,
                table_tensors.len()
            );
        }
        let tables = table_tensors
            .iter()
            .map(|t| self.host_to_device(t))
            .collect::<Result<_>>()?;
        Ok(ModelRunner {
            exe: self.compile(artifact_name)?,
            weights: self.weight_buffers(&model, &weights)?,
            tables,
            meta,
        })
    }

    /// Execute a *standalone* artifact (no weights), literals in/out.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.compile(name)?;
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| self.host_to_device(t))
            .collect::<Result<_>>()?;
        self.run_exe(&exe, &bufs)
    }

    pub(crate) fn run_exe<T: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[T],
    ) -> Result<Vec<Tensor>> {
        *self.exec_count.borrow_mut() += 1;
        let outputs = exe.execute_b(args).map_err(wrap)?;
        let first = outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no output from executable"))?;
        let mut tensors = Vec::new();
        for buf in first {
            let lit = buf.to_literal_sync().map_err(wrap)?;
            // lowering uses return_tuple=True -> a single tuple output
            for t in untuple(lit)? {
                tensors.push(t);
            }
        }
        Ok(tensors)
    }

    /// Execute a model runner with some inputs already device-resident
    /// (§Perf: the NMT decode loop keeps memory/src on device across the
    /// up-to-20 step executions instead of re-uploading per step).
    /// `inputs[i] = None` means "use `device_inputs` next in order".
    pub fn run_model_mixed(
        &self,
        runner: &ModelRunner,
        inputs: &[Option<&Tensor>],
        device_inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        if inputs.len() != runner.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                runner.meta.name,
                runner.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut host_bufs = Vec::new();
        for t in inputs.iter().flatten() {
            host_bufs.push(self.host_to_device(t)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = runner.weights.iter().collect();
        args.extend(runner.tables.iter());
        let mut hi = 0;
        let mut di = 0;
        for t in inputs {
            match t {
                Some(_) => {
                    args.push(&host_bufs[hi]);
                    hi += 1;
                }
                None => {
                    args.push(
                        device_inputs
                            .get(di)
                            .copied()
                            .ok_or_else(|| anyhow!("missing device input {di}"))?,
                    );
                    di += 1;
                }
            }
        }
        *self.exec_count.borrow_mut() += 1;
        let outputs = runner.exe.execute_b(&args).map_err(wrap)?;
        let first = outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no output"))?;
        let mut tensors = Vec::new();
        for buf in first {
            let lit = buf.to_literal_sync().map_err(wrap)?;
            for t in untuple(lit)? {
                tensors.push(t);
            }
        }
        Ok(tensors)
    }

    /// Execute a model runner: weights are prepended to the inputs.
    pub fn run_model(&self, runner: &ModelRunner, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        // signature check against the manifest
        if inputs.len() != runner.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                runner.meta.name,
                runner.meta.inputs.len(),
                inputs.len()
            );
        }
        for (t, (dims, _)) in inputs.iter().zip(&runner.meta.inputs) {
            t.expect_dims(dims)
                .with_context(|| runner.meta.name.clone())?;
        }
        let mut args: Vec<&xla::PjRtBuffer> = runner.weights.iter().collect();
        args.extend(runner.tables.iter());
        let input_bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| self.host_to_device(t))
            .collect::<Result<_>>()?;
        args.extend(input_bufs.iter());
        // execute_b takes Borrow<PjRtBuffer>; &PjRtBuffer works
        *self.exec_count.borrow_mut() += 1;
        let outputs = runner.exe.execute_b(&args).map_err(wrap)?;
        let first = outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no output"))?;
        let mut tensors = Vec::new();
        for buf in first {
            let lit = buf.to_literal_sync().map_err(wrap)?;
            for t in untuple(lit)? {
                tensors.push(t);
            }
        }
        Ok(tensors)
    }
}

/// Convert a (possibly tuple) literal into host tensors.
fn untuple(lit: xla::Literal) -> Result<Vec<Tensor>> {
    let shape = lit.shape().map_err(wrap)?;
    match shape {
        xla::Shape::Tuple(_) => {
            let mut lit = lit;
            let parts = lit.decompose_tuple().map_err(wrap)?;
            let mut out = Vec::new();
            for p in parts {
                out.extend(untuple(p)?);
            }
            Ok(out)
        }
        xla::Shape::Array(a) => {
            let dims: Vec<usize> = a.dims().iter().map(|&d| d as usize).collect();
            match a.ty() {
                xla::ElementType::F32 => {
                    Ok(vec![Tensor::f32(dims, lit.to_vec::<f32>().map_err(wrap)?)])
                }
                xla::ElementType::S32 => {
                    Ok(vec![Tensor::i32(dims, lit.to_vec::<i32>().map_err(wrap)?)])
                }
                t => bail!("unsupported output element type {t:?}"),
            }
        }
        s => bail!("unsupported output shape {s:?}"),
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}
