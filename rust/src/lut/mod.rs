//! LUT substrate: precisions and table builders, bit-identical to
//! `python/compile/kernels/luts.py` (asserted against the golden bundle
//! `artifacts/luts.ltb` by `tests/integration_lut.rs`).

mod precision;
mod tables;

pub use precision::{Precision, ALL_PRECISIONS};
pub use tables::{
    lut2d_tables, lut_alpha, lut_bytes, lut_exp, lut_recip_e, lut_row,
    lut_sigma, rexp_tables, Lut2dTables, RexpTables, EXP_STEP, SIGMA_ROWS,
};
