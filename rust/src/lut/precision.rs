//! Quantization precisions (the paper's int16 / uint8 / uint4 / uint2).

/// A quantization precision: `w` value bits, scale `qmax = 2^w - 1`.
///
/// The per-precision table shapes follow Table 8 of the paper (`alpha_len`
/// is the NLP default; the DETR experiments override it with the 256/320/
/// 512-entry cases of Table 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    Int16,
    Uint8,
    Uint4,
    Uint2,
}

pub const ALL_PRECISIONS: [Precision; 4] = [
    Precision::Int16,
    Precision::Uint8,
    Precision::Uint4,
    Precision::Uint2,
];

impl Precision {
    /// Parse `"uint8"` (also accepts spec strings `"uint8:a512"`, ignoring
    /// the alpha suffix — callers that need it use [`Precision::parse_spec`]).
    pub fn parse(name: &str) -> Option<Self> {
        let base = name.split(':').next().unwrap_or(name);
        Some(match base {
            "int16" => Self::Int16,
            "uint8" => Self::Uint8,
            "uint4" => Self::Uint4,
            "uint2" => Self::Uint2,
            _ => return None,
        })
    }

    /// Parse a full spec string `"uint8:a512"` -> (precision, alpha_len).
    pub fn parse_spec(spec: &str) -> Option<(Self, Option<usize>)> {
        let mut it = spec.splitn(2, ':');
        let p = Self::parse(it.next()?)?;
        match it.next() {
            None => Some((p, None)),
            Some(s) => {
                let n = s.strip_prefix('a')?.parse().ok()?;
                Some((p, Some(n)))
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Int16 => "int16",
            Self::Uint8 => "uint8",
            Self::Uint4 => "uint4",
            Self::Uint2 => "uint2",
        }
    }

    /// value bits (the paper's "bits per entry")
    pub fn w(self) -> u32 {
        match self {
            Self::Int16 => 15,
            Self::Uint8 => 8,
            Self::Uint4 => 4,
            Self::Uint2 => 2,
        }
    }

    /// full-scale value `2^w - 1`
    pub fn qmax(self) -> i32 {
        (1i32 << self.w()) - 1
    }

    /// Eq.(4)'s efficient quantization boundary `ceil(ln(qmax))`.
    pub fn x_q(self) -> usize {
        (self.qmax() as f64).ln().ceil() as usize
    }

    /// default LUT_alpha length for NLP workloads (Table 8)
    pub fn alpha_len(self) -> usize {
        match self {
            Self::Uint2 => 7,
            _ => 16,
        }
    }

    /// LUT_exp length for the 2D-LUT method (Table 8)
    pub fn exp_len(self) -> usize {
        match self {
            Self::Int16 | Self::Uint8 => 101,
            Self::Uint4 => 48,
            Self::Uint2 => 12,
        }
    }

    /// columns of LUT_sigma == assumed max(sum e^x) (Table 8)
    pub fn sigma_cols(self) -> usize {
        match self {
            Self::Int16 | Self::Uint8 => 60,
            Self::Uint4 => 29,
            Self::Uint2 => 8,
        }
    }

    /// whole bytes per stored entry (paper Tables 5/8 accounting)
    pub fn bytes_per_entry(self) -> usize {
        (self.w() as usize).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(Precision::Int16.qmax(), 32767);
        assert_eq!(Precision::Uint8.qmax(), 255);
        assert_eq!(Precision::Uint4.qmax(), 15);
        assert_eq!(Precision::Uint2.qmax(), 3);
    }

    #[test]
    fn x_q_matches_eq4() {
        assert_eq!(Precision::Int16.x_q(), 11);
        assert_eq!(Precision::Uint8.x_q(), 6);
        assert_eq!(Precision::Uint4.x_q(), 3);
        assert_eq!(Precision::Uint2.x_q(), 2);
    }

    #[test]
    fn parse_roundtrip() {
        for p in ALL_PRECISIONS {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("float64"), None);
    }

    #[test]
    fn parse_spec_alpha() {
        assert_eq!(
            Precision::parse_spec("uint8:a512"),
            Some((Precision::Uint8, Some(512)))
        );
        assert_eq!(Precision::parse_spec("int16"), Some((Precision::Int16, None)));
        assert_eq!(Precision::parse_spec("uint8:b12"), None);
    }

    #[test]
    fn bytes_per_entry() {
        assert_eq!(Precision::Int16.bytes_per_entry(), 2);
        assert_eq!(Precision::Uint8.bytes_per_entry(), 1);
        assert_eq!(Precision::Uint2.bytes_per_entry(), 1);
    }
}
