//! Table builders — Eq.(4), Eq.(7), Eq.(8)-(10) of the paper.
//!
//! Contents must stay bit-identical to `python/compile/kernels/luts.py`
//! (same f64 expression order); `tests/integration_lut.rs` asserts every
//! entry against the python-emitted golden bundle.

use super::Precision;

/// step of the 1-D LUT_exp index in x units (paper: scale_ex = 0.1)
pub const EXP_STEP: f64 = 0.1;
/// rows of LUT_sigma (numerator e^x quantized in steps of 0.1 over (0, 1])
pub const SIGMA_ROWS: usize = 11;

/// Eq.(4): `LUT_{1/e}[i] = floor(qmax / e^i)` for `i = 0..=x_q+1`.
pub fn lut_recip_e(p: Precision) -> Vec<i32> {
    let qmax = p.qmax() as f64;
    (0..p.x_q() + 2)
        .map(|i| (qmax * (-(i as f64)).exp()).floor() as i32)
        .collect()
}

/// Eq.(7): `LUT_alpha[j] = floor(qmax / j)`, with `LUT_alpha[0] = qmax`.
/// Reads at index >= len are defined as 0 (the paper's `LUT_alpha[x_s]=0`).
pub fn lut_alpha(p: Precision, len: usize) -> Vec<i32> {
    assert!(len >= 1, "LUT_alpha length must be >= 1");
    let qmax = p.qmax() as f64;
    (0..len)
        .map(|j| {
            if j == 0 {
                p.qmax()
            } else {
                (qmax / j as f64).floor() as i32
            }
        })
        .collect()
}

/// 1-D e^x table of the 2D-LUT method: `round(qmax * e^(-k*0.1))`.
pub fn lut_exp(p: Precision) -> Vec<i32> {
    let qmax = p.qmax() as f64;
    (0..p.exp_len())
        .map(|k| (qmax * (-(k as f64) * EXP_STEP).exp()).round() as i32)
        .collect()
}

/// Row-index decode table of the 2D-LUT method: maps the LUT_exp address
/// k straight to the sigma row (the paper's "first index computed directly
/// from input x" variant — address-decode wiring, no datapath arithmetic).
/// Built in the integer domain from LUT_exp so python and rust agree
/// bit-exactly: `clamp((LUT_exp[k]*10 + qmax/2) / qmax, 0, 10)`.
pub fn lut_row(p: Precision) -> Vec<i32> {
    let q = p.qmax();
    lut_exp(p)
        .into_iter()
        .map(|e| ((e * 10 + q / 2) / q).clamp(0, SIGMA_ROWS as i32 - 1))
        .collect()
}

/// Eq.(8)-(10): the (11 x cols) quotient table, row-major.
/// Entry `[i][j-1] = min(qmax, floor(qmax * (i*0.1) / j))`.
pub fn lut_sigma(p: Precision, cols: usize) -> Vec<i32> {
    let qmax = p.qmax() as f64;
    let mut out = Vec::with_capacity(SIGMA_ROWS * cols);
    for i in 0..SIGMA_ROWS {
        // mirror python's evaluation order: qmax * (i*0.1) / j
        let num = qmax * (i as f64 * EXP_STEP);
        for j in 1..=cols {
            let v = (num / j as f64).floor();
            out.push(v.min(qmax) as i32);
        }
    }
    out
}

/// Storage bytes (Tables 5/8): whole bytes per entry, no sub-byte packing.
pub fn lut_bytes(p: Precision, entries: usize) -> usize {
    entries * p.bytes_per_entry()
}

/// The two 1-D tables of the REXP method (§4.1).
#[derive(Clone, Debug)]
pub struct RexpTables {
    pub prec: Precision,
    pub recip_e: Vec<i32>,
    pub alpha: Vec<i32>,
}

impl RexpTables {
    pub fn total_bytes(&self) -> usize {
        lut_bytes(self.prec, self.recip_e.len() + self.alpha.len())
    }
}

/// Build REXP tables; `alpha_len = None` uses the NLP default (Table 8).
pub fn rexp_tables(p: Precision, alpha_len: Option<usize>) -> RexpTables {
    RexpTables {
        prec: p,
        recip_e: lut_recip_e(p),
        alpha: lut_alpha(p, alpha_len.unwrap_or(p.alpha_len())),
    }
}

/// The exp + quotient tables of the 2D-LUT method (§4.2).
#[derive(Clone, Debug)]
pub struct Lut2dTables {
    pub prec: Precision,
    pub exp: Vec<i32>,
    /// row-index decode ROM (see [`lut_row`]; wiring, not counted in bytes)
    pub row: Vec<i32>,
    /// row-major (SIGMA_ROWS x cols)
    pub sigma: Vec<i32>,
    pub cols: usize,
}

impl Lut2dTables {
    pub fn total_bytes(&self) -> usize {
        lut_bytes(self.prec, self.exp.len() + self.sigma.len())
    }

    #[inline]
    pub fn sigma_at(&self, row: usize, col1: usize) -> i32 {
        // col1 is the paper's 1-based denominator index j
        self.sigma[row * self.cols + (col1 - 1)]
    }
}

pub fn lut2d_tables(p: Precision, sigma_cols: Option<usize>) -> Lut2dTables {
    let cols = sigma_cols.unwrap_or(p.sigma_cols());
    Lut2dTables {
        prec: p,
        exp: lut_exp(p),
        row: lut_row(p),
        sigma: lut_sigma(p, cols),
        cols,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::ALL_PRECISIONS;

    #[test]
    fn recip_shapes_match_paper() {
        assert_eq!(lut_recip_e(Precision::Int16).len(), 13);
        assert_eq!(lut_recip_e(Precision::Uint8).len(), 8);
        assert_eq!(lut_recip_e(Precision::Uint4).len(), 5);
    }

    #[test]
    fn recip_monotone_first_full_last_zero() {
        for p in ALL_PRECISIONS {
            let t = lut_recip_e(p);
            assert_eq!(t[0], p.qmax());
            assert_eq!(*t.last().unwrap(), 0);
            assert!(t.windows(2).all(|w| w[0] >= w[1]));
        }
    }

    #[test]
    fn alpha_values_eq7() {
        let t = lut_alpha(Precision::Uint8, 16);
        assert_eq!(t[0], 255);
        assert_eq!(t[1], 255);
        assert_eq!(t[2], 127);
        assert_eq!(t[5], 51);
        assert_eq!(t[15], 17);
    }

    #[test]
    fn sigma_shape_and_bounds() {
        for p in ALL_PRECISIONS {
            let t = lut2d_tables(p, None);
            assert_eq!(t.sigma.len(), SIGMA_ROWS * p.sigma_cols());
            assert!(t.sigma.iter().all(|&v| v >= 0 && v <= p.qmax()));
            // row 0: zero numerator -> zero everywhere
            assert!(t.sigma[..t.cols].iter().all(|&v| v == 0));
        }
    }

    #[test]
    fn byte_totals_match_table5_and_8() {
        assert_eq!(rexp_tables(Precision::Int16, Some(256)).total_bytes(), 538);
        assert_eq!(rexp_tables(Precision::Int16, Some(320)).total_bytes(), 666);
        assert_eq!(rexp_tables(Precision::Int16, Some(512)).total_bytes(), 1050);
        assert_eq!(rexp_tables(Precision::Uint8, Some(256)).total_bytes(), 264);
        assert_eq!(rexp_tables(Precision::Uint8, Some(512)).total_bytes(), 520);
        assert_eq!(lut2d_tables(Precision::Int16, None).total_bytes(), 1522);
        assert_eq!(lut2d_tables(Precision::Uint8, None).total_bytes(), 761);
        assert_eq!(lut2d_tables(Precision::Uint4, None).total_bytes(), 367);
        assert_eq!(lut2d_tables(Precision::Uint2, None).total_bytes(), 100);
        assert_eq!(rexp_tables(Precision::Int16, None).total_bytes(), 58);
        assert_eq!(rexp_tables(Precision::Uint8, None).total_bytes(), 24);
        assert_eq!(rexp_tables(Precision::Uint4, None).total_bytes(), 21);
    }

    #[test]
    fn sigma_at_indexing() {
        let t = lut2d_tables(Precision::Uint8, None);
        // i=10 (e^x = 1.0), j=1 -> floor(255 * 1.0 / 1) = 255
        assert_eq!(t.sigma_at(10, 1), 255);
        // i=5 (0.5), j=2 -> floor(255 * 0.5 / 2) = 63
        assert_eq!(t.sigma_at(5, 2), 63);
    }
}
