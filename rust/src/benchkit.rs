//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Benches are plain binaries (`harness = false`) that call [`bench`] /
//! [`Suite`]: warmup, then timed batches until both a minimum wall-time
//! and iteration count are reached; reports mean / p50 / p99 per op and
//! throughput. Set `BENCH_FAST=1` to shrink budgets (CI smoke).
//!
//! Set `BENCH_JSON=<path>` and call [`flush_json`] at the end of a bench
//! binary to dump every recorded result as a JSON array of
//! `{name, mean_ns, p50_ns, p99_ns, items_per_sec}` — the repo's perf
//! trajectory files (`BENCH_*.json`, refreshed by `make bench-smoke`).

use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

fn registry() -> &'static Mutex<Vec<BenchResult>> {
    static REGISTRY: OnceLock<Mutex<Vec<BenchResult>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Write all results recorded so far to `$BENCH_JSON` (no-op when the
/// variable is unset). Returns the path written, if any.
pub fn flush_json() -> std::io::Result<Option<std::path::PathBuf>> {
    let Some(path) = std::env::var_os("BENCH_JSON") else {
        return Ok(None);
    };
    let path = std::path::PathBuf::from(path);
    let results = registry().lock().unwrap();
    let arr = crate::config::Json::Arr(
        results
            .iter()
            .map(|r| {
                crate::jobj![
                    ("name", r.name.as_str()),
                    ("mean_ns", r.mean_ns),
                    ("p50_ns", r.p50_ns),
                    ("p99_ns", r.p99_ns),
                    ("items_per_sec", r.items_per_sec()),
                ]
            })
            .collect(),
    );
    std::fs::write(&path, arr.to_string_pretty())?;
    Ok(Some(path))
}

/// Names of every result recorded so far this process. The canonical-
/// label gate in `softmax_bench` checks the single-source list
/// (`scripts/bench_labels.txt`, the same file `bench_smoke.sh` greps
/// against the JSON trajectory) against this at run time, so a label can
/// neither be dropped from the bench nor added without being listed.
pub fn recorded_names() -> Vec<String> {
    registry().lock().unwrap().iter().map(|r| r.name.clone()).collect()
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// items per op (for throughput lines), settable via `Bench::items`
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn ops_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }

    pub fn items_per_sec(&self) -> f64 {
        self.ops_per_sec() * self.items_per_iter
    }

    pub fn report(&self) {
        let unit = |ns: f64| -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} us", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.2} s", ns / 1e9)
            }
        };
        if self.items_per_iter > 1.0 {
            println!(
                "{:<44} {:>10}/op  p50 {:>9}  p99 {:>9}  {:>12.0} elem/s",
                self.name,
                unit(self.mean_ns),
                unit(self.p50_ns),
                unit(self.p99_ns),
                self.items_per_sec()
            );
        } else {
            println!(
                "{:<44} {:>10}/op  p50 {:>9}  p99 {:>9}  {:>10.0} op/s",
                self.name,
                unit(self.mean_ns),
                unit(self.p50_ns),
                unit(self.p99_ns),
                self.ops_per_sec()
            );
        }
    }
}

pub struct Bench {
    name: String,
    min_time: Duration,
    min_iters: u64,
    items: f64,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        let fast = std::env::var("BENCH_FAST").is_ok();
        Self {
            name: name.into(),
            min_time: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(700)
            },
            min_iters: if fast { 5 } else { 20 },
            items: 1.0,
        }
    }

    /// items processed per iteration (for element-throughput reporting)
    pub fn items(mut self, n: usize) -> Self {
        self.items = n as f64;
        self
    }

    pub fn min_time_ms(mut self, ms: u64) -> Self {
        self.min_time = Duration::from_millis(ms);
        self
    }

    pub fn run<F: FnMut()>(self, mut f: F) -> BenchResult {
        // warmup
        let warm_until = Instant::now() + self.min_time / 5;
        while Instant::now() < warm_until {
            f();
        }
        let mut samples: Vec<u64> = Vec::with_capacity(1024);
        let start = Instant::now();
        while start.elapsed() < self.min_time || (samples.len() as u64) < self.min_iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as u64);
        }
        samples.sort_unstable();
        let iters = samples.len() as u64;
        let mean = samples.iter().sum::<u64>() as f64 / iters as f64;
        let pct = |p: f64| samples[((iters as f64 * p) as usize).min(samples.len() - 1)] as f64;
        let res = BenchResult {
            name: self.name,
            iters,
            mean_ns: mean,
            p50_ns: pct(0.50),
            p99_ns: pct(0.99),
            items_per_iter: self.items,
        };
        res.report();
        registry().lock().unwrap().push(res.clone());
        res
    }
}

/// Convenience: run and report one benchmark.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    Bench::new(name).run(f)
}

/// A named group printed with a header (mirrors criterion's groups).
pub struct Suite {
    pub results: Vec<BenchResult>,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        Self { results: Vec::new() }
    }

    pub fn add(&mut self, r: BenchResult) -> &mut Self {
        self.results.push(r);
        self
    }

    /// Ratio line between two recorded results (speedup reporting).
    pub fn ratio(&self, a: &str, b: &str) {
        let f = |n: &str| self.results.iter().find(|r| r.name == n);
        if let (Some(x), Some(y)) = (f(a), f(b)) {
            println!("    {a} vs {b}: {:.2}x", y.mean_ns / x.mean_ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        std::env::set_var("BENCH_FAST", "1");
        let r = Bench::new("noop").min_time_ms(10).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn items_throughput() {
        std::env::set_var("BENCH_FAST", "1");
        let r = Bench::new("items").min_time_ms(5).items(100).run(|| {
            std::hint::black_box([0u8; 100]);
        });
        assert!(r.items_per_sec() >= r.ops_per_sec());
    }

    #[test]
    fn results_land_in_the_registry() {
        std::env::set_var("BENCH_FAST", "1");
        Bench::new("registry_probe_xyz").min_time_ms(5).run(|| {
            std::hint::black_box(2 + 2);
        });
        let reg = registry().lock().unwrap();
        assert!(reg.iter().any(|r| r.name == "registry_probe_xyz"));
    }

    #[test]
    fn flush_json_without_env_is_noop() {
        // BENCH_JSON is deliberately not set in the test environment
        if std::env::var_os("BENCH_JSON").is_none() {
            assert!(flush_json().unwrap().is_none());
        }
    }
}
