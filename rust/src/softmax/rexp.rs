//! REXP softmax (paper §4.1, Algorithm 1) — bit-exact integer pipeline.
//!
//! Datapath per row (matches `kernels/ref.py::rexp_pipeline`):
//!   1. `d = max(x) - x`                        (f32)
//!   2. `idx = clamp(trunc(d), 0, len-1)`       — the MSB index
//!   3. `e = LUT_{1/e}[idx]`
//!   4. `s = sum(e)`;  `j = s >> w`
//!   5. `a = if j >= alpha_len { 0 } else { LUT_alpha[j] }`
//!   6. `sig = (e * a) >> w`;  `out = sig * (1/qmax)`
//!
//! No divide anywhere; one integer multiply per element (step 6).
//!
//! §Perf: the fused [`SoftmaxEngine::run_with`] does steps 2–4 in pass 1
//! (parking the LUT *addresses* in the caller's [`Scratch`]), then — since
//! `LUT_{1/e}` has at most `x_q + 2` (≤ 13) entries — steps 5–6 are hoisted
//! into a per-row f32-mirrored dequant of the whole table
//! (`deq[k] = ((LUT_{1/e}[k]·α) >> w) · 1/qmax`), so pass 2 is a single
//! branchless gather per element. Rows shorter than the table skip the
//! hoist and fuse dequant directly: one int-mul + shift + fmul. Both paths
//! evaluate the exact integer expressions of the old three-pass loop on
//! the same inputs, so outputs are bit-identical; the third full pass and
//! the `thread_local!` scratch it needed are gone.

use super::{
    debug_check_shape, i8_row_max, pass1_i8_mapped, pass1_i8_unit, row_max, IntMap, IntRow,
    Scratch, SoftmaxEngine,
};
use crate::lut::{rexp_tables, Precision, RexpTables};

pub struct SoftmaxRexp {
    tables: RexpTables,
    w: u32,
    inv_qmax: f32,
}

impl SoftmaxRexp {
    pub fn new(prec: Precision, alpha_len: Option<usize>) -> Self {
        Self::with_tables(rexp_tables(prec, alpha_len))
    }

    /// Reconfigure-on-demand entry point (the paper's LUT swap property).
    pub fn with_tables(tables: RexpTables) -> Self {
        let w = tables.prec.w();
        let inv_qmax = 1.0 / tables.prec.qmax() as f32;
        Self { tables, w, inv_qmax }
    }

    pub fn tables(&self) -> &RexpTables {
        &self.tables
    }

    /// Integer-stage output (`sig_int`), useful for bit-exactness tests and
    /// as the value a fixed-point consumer would read before dequant.
    pub fn run_int(&self, x: &[f32], n: usize, out: &mut [i32]) {
        let recip = &self.tables.recip_e;
        let alpha = &self.tables.alpha;
        let last = (recip.len() - 1) as i32;
        for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = row_max(row);
            let mut s: i32 = 0;
            for (o, &v) in orow.iter_mut().zip(row) {
                let idx = ((m - v) as i32).clamp(0, last);
                let e = recip[idx as usize];
                *o = e;
                s += e;
            }
            let j = (s >> self.w) as usize;
            let a = if j >= alpha.len() { 0 } else { alpha[j] };
            for o in orow.iter_mut() {
                *o = (*o * a) >> self.w;
            }
        }
    }

    /// The [`IntMap`] of the i8 path: `row.scale` LUT-index units (logit
    /// units) per quantization step.
    pub(crate) fn int_map(&self, step: f32) -> IntMap {
        IntMap::new(step, (self.tables.recip_e.len() - 1) as i32)
    }

    /// LUT-alpha read for an integer row sum (0 beyond the table — the
    /// paper's `LUT_alpha[x_s] = 0` convention).
    #[inline]
    pub(crate) fn alpha_for(&self, s: i32) -> i32 {
        let j = (s >> self.w) as usize;
        let alpha = &self.tables.alpha;
        if j >= alpha.len() {
            // rare branch: the telemetry guard load never sits on the
            // in-table path
            if crate::obs::range::enabled() {
                crate::obs::range::note_pass2_clamp();
            }
            0
        } else {
            alpha[j]
        }
    }

    /// Integer-stage output of the i8 fast path (`sig_int`) — mirrors
    /// [`SoftmaxRexp::run_int`] with integer ingestion; used by the
    /// bit-exactness tests and by fixed-point consumers.
    pub fn run_i8_int(&self, x: &[i8], n: usize, row: IntRow, out: &mut [i32]) {
        let recip = &self.tables.recip_e;
        let map = self.int_map(row.scale);
        for (rowq, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = i8_row_max(rowq) as i32;
            let mut s: i32 = 0;
            for (o, &v) in orow.iter_mut().zip(rowq) {
                let e = recip[map.index(m - v as i32) as usize];
                *o = e;
                s += e;
            }
            let a = self.alpha_for(s);
            for o in orow.iter_mut() {
                *o = (*o * a) >> self.w;
            }
        }
    }
}

impl SoftmaxEngine for SoftmaxRexp {
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        let recip = &self.tables.recip_e;
        let alpha = &self.tables.alpha;
        let last = (recip.len() - 1) as i32;
        let hoist = n >= recip.len();
        let (idx, deq) = scratch.borrow2(n, recip.len());
        for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = row_max(row);
            let mut s: i32 = 0;
            for (slot, &v) in idx.iter_mut().zip(row) {
                let k = ((m - v) as i32).clamp(0, last);
                s += recip[k as usize];
                *slot = k;
            }
            let j = (s >> self.w) as usize;
            let a = if j >= alpha.len() { 0 } else { alpha[j] };
            if hoist {
                // f32-mirrored table: dequant once per ENTRY, gather per elem
                for (d, &e) in deq.iter_mut().zip(recip.iter()) {
                    *d = ((e * a) >> self.w) as f32 * self.inv_qmax;
                }
                for (o, &k) in orow.iter_mut().zip(idx.iter()) {
                    *o = deq[k as usize];
                }
            } else {
                for (o, &k) in orow.iter_mut().zip(idx.iter()) {
                    *o = ((recip[k as usize] * a) >> self.w) as f32 * self.inv_qmax;
                }
            }
        }
    }

    /// i8 fast path: pass 1 is pure integer — an `i8` max scan, then the
    /// branchless `chunks_exact(8)` address/gather blocks of
    /// [`pass1_i8_unit`] (aligned case, `idx = clamp(m_q - v_q, 0, last)`)
    /// or [`pass1_i8_mapped`] (one fixed-point multiply). Pass 2 is the
    /// same fused dequant as the f32 path, so output ==
    /// `run_i8_int * 1/qmax` bit-exactly.
    fn run_i8_with(&self, x: &[i8], n: usize, row: IntRow, out: &mut [f32], scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        let recip = &self.tables.recip_e;
        let map = self.int_map(row.scale);
        let unit = map.is_unit();
        let hoist = n >= recip.len();
        let (idx, deq) = scratch.borrow2(n, recip.len());
        for (rowq, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = i8_row_max(rowq) as i32;
            let s = if unit {
                pass1_i8_unit(rowq, m, map.last(), recip, idx)
            } else {
                pass1_i8_mapped(rowq, m, map, recip, idx)
            };
            let a = self.alpha_for(s);
            if hoist {
                for (d, &e) in deq.iter_mut().zip(recip.iter()) {
                    *d = ((e * a) >> self.w) as f32 * self.inv_qmax;
                }
                for (o, &k) in orow.iter_mut().zip(idx.iter()) {
                    *o = deq[k as usize];
                }
            } else {
                for (o, &k) in orow.iter_mut().zip(idx.iter()) {
                    *o = ((recip[k as usize] * a) >> self.w) as f32 * self.inv_qmax;
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "rexp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::{SoftmaxEngine, SoftmaxExact};
    use crate::testkit;

    #[test]
    fn uniform_row_uint8() {
        // two equal logits: d = 0 for both, e = qmax; s = 2*qmax;
        // j = (2*255) >> 8 = 1 -> alpha = 255; sig = (255*255)>>8 = 254.
        let e = SoftmaxRexp::new(Precision::Uint8, None);
        let mut out = [0i32; 2];
        e.run_int(&[1.0, 1.0], 2, &mut out);
        assert_eq!(out, [254, 254]);
    }

    #[test]
    fn output_bounded_unit_interval() {
        testkit::check("rexp bounded", 30, |rng| {
            let n = rng.usize(2, 64);
            let rows = rng.usize(1, 8);
            let x = rng.normal_vec(rows * n, 3.0);
            let e = SoftmaxRexp::new(Precision::Uint8, None);
            for v in e.apply(&x, n) {
                assert!((0.0..=1.0).contains(&v), "{v}");
            }
        });
    }

    #[test]
    fn argmax_preserved() {
        testkit::check("rexp argmax", 30, |rng| {
            let n = rng.usize(3, 32);
            let x = rng.normal_vec(n, 2.0);
            let e = SoftmaxRexp::new(Precision::Int16, None);
            let out = e.apply(&x, n);
            let win = x
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            let max = out.iter().copied().fold(0.0f32, f32::max);
            assert_eq!(out[win], max);
        });
    }

    #[test]
    fn shift_invariant_exactly() {
        testkit::check("rexp shift", 20, |rng| {
            let n = rng.usize(2, 24);
            let x = rng.normal_vec(n, 2.0);
            let shifted: Vec<f32> = x.iter().map(|v| v + 37.5).collect();
            let e = SoftmaxRexp::new(Precision::Uint8, None);
            assert_eq!(e.apply(&x, n), e.apply(&shifted, n));
        });
    }

    #[test]
    fn close_to_exact_at_uint8() {
        let mut rng = testkit::Rng::new(9);
        let n = 48;
        let x = rng.normal_vec(256 * n, 2.0);
        let approx = SoftmaxRexp::new(Precision::Uint8, None).apply(&x, n);
        let exact = SoftmaxExact.apply(&x, n);
        let mae: f32 = approx
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / approx.len() as f32;
        assert!(mae < 0.02, "mae {mae}");
    }

    #[test]
    fn alpha_overflow_clips_to_zero() {
        // enough identical logits that s >> w exceeds the alpha table:
        // uint8 NLP alpha has 16 entries; 17 equal logits -> j = 16 -> 0.
        let e = SoftmaxRexp::new(Precision::Uint8, None);
        let x = vec![0.0f32; 17];
        let out = e.apply(&x, 17);
        assert!(out.iter().all(|&v| v == 0.0), "{out:?}");
        // the DETR-case 256-entry table handles the same row fine
        let e = SoftmaxRexp::new(Precision::Uint8, Some(256));
        let out = e.apply(&x, 17);
        assert!(out.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn i8_fast_path_matches_its_integer_stage() {
        // hoisted and direct pass-2 variants, unit and non-unit maps: the
        // f32 output of run_i8_with must equal run_i8_int * 1/qmax exactly
        testkit::check("rexp i8 fused dequant", 25, |rng| {
            let prec = *rng.choice(&crate::lut::ALL_PRECISIONS);
            let e = SoftmaxRexp::new(prec, None);
            let table_len = e.tables().recip_e.len();
            let n = rng.usize(1, 2 * table_len);
            let rows = rng.usize(1, 6);
            let row = IntRow::new(*rng.choice(&[1.0f32, 0.5, 0.37, 2.0]), rng.int(-20, 20) as i32);
            let x: Vec<i8> = (0..rows * n).map(|_| rng.int(-128, 127) as i8).collect();
            let mut ints = vec![0i32; x.len()];
            e.run_i8_int(&x, n, row, &mut ints);
            let inv = 1.0 / prec.qmax() as f32;
            let want: Vec<f32> = ints.iter().map(|&v| v as f32 * inv).collect();
            assert_eq!(e.apply_i8(&x, n, row), want);
        });
    }

    #[test]
    fn i8_unit_map_indexes_by_raw_quant_diff() {
        // the aligned case: two quant steps below the max land on LUT
        // entry 2 regardless of zero point
        let e = SoftmaxRexp::new(Precision::Uint8, None);
        let recip = &e.tables().recip_e;
        let mut out = [0i32; 3];
        e.run_i8_int(&[5, 3, 4], 3, IntRow::unit(), &mut out);
        let s = recip[0] + recip[2] + recip[1];
        let a = e.alpha_for(s);
        assert_eq!(out[1], (recip[2] * a) >> 8);
    }

    #[test]
    fn fused_gather_matches_integer_stage() {
        // the hoisted (n >= table) and direct (n < table) dequant paths must
        // both equal sig_int * 1/qmax exactly
        testkit::check("rexp fused dequant", 25, |rng| {
            let prec = *rng.choice(&crate::lut::ALL_PRECISIONS);
            let e = SoftmaxRexp::new(prec, None);
            let table_len = e.tables().recip_e.len();
            // straddle the hoist threshold
            let n = rng.usize(1, 2 * table_len);
            let rows = rng.usize(1, 6);
            let x = rng.normal_vec(rows * n, 2.5);
            let mut ints = vec![0i32; x.len()];
            e.run_int(&x, n, &mut ints);
            let inv = 1.0 / prec.qmax() as f32;
            let want: Vec<f32> = ints.iter().map(|&v| v as f32 * inv).collect();
            assert_eq!(e.apply(&x, n), want);
        });
    }
}
