//! Bit-exact software models of the softmax datapaths — fused, batch-aware,
//! row-parallel.
//!
//! The integer stages reproduce, entry for entry, the Pallas kernels and
//! jnp oracles on the python side (asserted against
//! `artifacts/golden_softmax.ltb`). They serve three roles:
//!
//! 1. the request-path hot loop of the standalone softmax service
//!    (including the CPU fallback behind `coordinator::SoftmaxPipeline`),
//! 2. the functional layer under the cycle-accurate [`crate::hwsim`],
//! 3. the rust-side baseline for the criterion-style benches.
//!
//! # Kernel architecture (fused two-pass)
//!
//! Every LUT engine streams each row in exactly **two** passes:
//!
//! * **pass 1** scans the row once: computes the LUT *address* per element
//!   (a branchless clamp), accumulates the integer sum, and parks the
//!   addresses in a caller-provided [`Scratch`] (no thread-local state,
//!   no allocation on the hot path).
//! * **between passes** the per-row normalizer (REXP's `LUT_alpha` read,
//!   2D-LUT's column select) is resolved once, and — when the row is at
//!   least as long as the table — the whole dequantized output table is
//!   hoisted into an f32 mirror (`Scratch::deq`): one `int-mul + shift +
//!   int→f32 + fmul` per *table entry* instead of per element.
//! * **pass 2** is then a single branchless gather per element
//!   (`out[i] = deq[idx[i]]`), or for short rows the direct fused
//!   `((e·α) >> w) as f32 * 1/qmax` — one int-mul+shift+fmul. Either way
//!   the old third full pass over the data (and its thread-local i32
//!   scratch) is gone, and results are bit-identical by construction:
//!   the same integer expressions are evaluated on the same inputs.
//!
//! # Batching and parallelism
//!
//! [`SoftmaxEngine::run_with`] is the scratch-carrying batched entry
//! point: callers that run many batches (services, benches, the worker
//! pool) hold one [`Scratch`] per thread and amortize allocation
//! explicitly. [`SoftmaxEngine::run`] remains the convenience wrapper
//! that brings its own scratch. Rows are independent, so [`ParSoftmax`]
//! (see [`par`]) shards row-blocks of a batch across a persistent worker
//! pool and stays `==`-exact with the wrapped engine.

mod exact;
mod lut2d;
mod par;
mod priorart;
mod rexp;

pub use exact::SoftmaxExact;
pub use lut2d::SoftmaxLut2d;
pub use par::ParSoftmax;
pub use priorart::{SoftmaxAggressive, SoftmaxEq2, SoftmaxEq2Plus};
pub use rexp::SoftmaxRexp;

use crate::lut::Precision;

/// Shared vocabulary with the python side (`kernels.ref.SOFTMAX_MODES`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    Exact,
    Rexp,
    Lut2d,
    PriorartEq2,
    PriorartEq2Plus,
    Aggressive,
}

impl Mode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "exact" => Self::Exact,
            "rexp" => Self::Rexp,
            "lut2d" => Self::Lut2d,
            "priorart_eq2" => Self::PriorartEq2,
            "priorart_eq2plus" => Self::PriorartEq2Plus,
            "aggressive" => Self::Aggressive,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::Rexp => "rexp",
            Self::Lut2d => "lut2d",
            Self::PriorartEq2 => "priorart_eq2",
            Self::PriorartEq2Plus => "priorart_eq2plus",
            Self::Aggressive => "aggressive",
        }
    }
}

/// Reusable per-thread kernel workspace: LUT addresses for one row and the
/// per-row dequantized f32 mirror of the active table. Engines only grow
/// the buffers; a single `Scratch` serves any engine/shape sequence.
#[derive(Debug, Default)]
pub struct Scratch {
    idx: Vec<i32>,
    deq: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutable views of the two buffers, grown to at least the requested
    /// lengths. Split borrow so pass 1 (addresses) and the dequant mirror
    /// can be filled in the same row iteration.
    pub(crate) fn borrow2(&mut self, idx_len: usize, deq_len: usize) -> (&mut [i32], &mut [f32]) {
        if self.idx.len() < idx_len {
            self.idx.resize(idx_len, 0);
        }
        if self.deq.len() < deq_len {
            self.deq.resize(deq_len, 0.0);
        }
        (&mut self.idx[..idx_len], &mut self.deq[..deq_len])
    }
}

/// A row-wise softmax engine: fills `out` with probabilities for each
/// length-`n` row of `x` (row-major, `x.len() == rows * n`).
///
/// `n == 0` is a caller bug: it is rejected by `debug_assert!` at every
/// engine boundary (an empty *batch*, `x.len() == 0`, is fine and a
/// no-op). See [`row_max`] for why the guard exists.
pub trait SoftmaxEngine: Send + Sync {
    /// Scratch-carrying batched entry point — the hot path. Callers that
    /// run repeatedly should hold one [`Scratch`] per thread; the engines
    /// never allocate when the scratch has warmed up.
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch);

    /// Convenience single-shot wrapper (brings its own scratch).
    fn run(&self, x: &[f32], n: usize, out: &mut [f32]) {
        let mut scratch = Scratch::new();
        self.run_with(x, n, out, &mut scratch);
    }

    fn name(&self) -> &'static str;

    /// convenience: allocate and return the result
    fn apply(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0; x.len()];
        self.run(x, n, &mut out);
        out
    }
}

/// Build an engine by (mode, precision, alpha override) — the L3 dispatch
/// used by the coordinator and the experiment harness.
pub fn engine(
    mode: Mode,
    prec: Precision,
    alpha_len: Option<usize>,
) -> Box<dyn SoftmaxEngine> {
    match mode {
        Mode::Exact => Box::new(SoftmaxExact),
        Mode::Rexp => Box::new(SoftmaxRexp::new(prec, alpha_len)),
        Mode::Lut2d => Box::new(SoftmaxLut2d::new(prec)),
        Mode::PriorartEq2 => Box::new(SoftmaxEq2::new(prec)),
        Mode::PriorartEq2Plus => Box::new(SoftmaxEq2Plus::new(prec)),
        Mode::Aggressive => Box::new(SoftmaxAggressive::new(prec)),
    }
}

/// Build a row-parallel engine: the sequential engine for `(mode, prec,
/// alpha_len)` wrapped in a [`ParSoftmax`] worker pool. `workers = None`
/// uses the machine's available parallelism.
pub fn engine_parallel(
    mode: Mode,
    prec: Precision,
    alpha_len: Option<usize>,
    workers: Option<usize>,
) -> ParSoftmax {
    let inner: std::sync::Arc<dyn SoftmaxEngine> =
        std::sync::Arc::from(engine(mode, prec, alpha_len));
    match workers {
        Some(w) => ParSoftmax::with_workers(inner, w),
        None => ParSoftmax::new(inner),
    }
}

/// max of a row (f32, NaN-free inputs assumed — attention scores).
///
/// An empty row yields `0.0`, never `NEG_INFINITY`: the fused kernels cast
/// `max - x` to an integer LUT address, and `NEG_INFINITY as i32` would be
/// a garbage clamp index. Engines additionally `debug_assert!(n > 0)` at
/// the trait boundary so the case cannot arise silently.
#[inline]
pub(crate) fn row_max(row: &[f32]) -> f32 {
    if row.is_empty() {
        return 0.0;
    }
    row.iter().copied().fold(f32::NEG_INFINITY, f32::max)
}

/// Trait-boundary shape guard shared by every engine (debug builds).
#[inline]
pub(crate) fn debug_check_shape(x: &[f32], n: usize, out: &[f32]) {
    debug_assert!(n > 0, "softmax row length n must be > 0");
    debug_assert_eq!(x.len() % n, 0, "x.len() must be a multiple of n");
    debug_assert_eq!(x.len(), out.len(), "out length must match x");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [
            Mode::Exact,
            Mode::Rexp,
            Mode::Lut2d,
            Mode::PriorartEq2,
            Mode::PriorartEq2Plus,
            Mode::Aggressive,
        ] {
            assert_eq!(Mode::parse(m.name()), Some(m));
        }
        assert_eq!(Mode::parse("softmax9000"), None);
    }

    #[test]
    fn engine_dispatch_names() {
        let e = engine(Mode::Rexp, Precision::Uint8, None);
        assert_eq!(e.name(), "rexp");
        let e = engine(Mode::Exact, Precision::Uint8, None);
        assert_eq!(e.name(), "exact");
    }

    #[test]
    fn row_max_empty_row_is_finite() {
        assert_eq!(row_max(&[]), 0.0);
        assert_eq!(row_max(&[-3.0, 1.5]), 1.5);
        // the clamp index an empty row would produce must be sane
        assert_eq!((row_max(&[]) as i32).clamp(0, 7), 0);
    }

    #[test]
    fn empty_batch_is_a_noop_for_all_engines() {
        for m in [
            Mode::Exact,
            Mode::Rexp,
            Mode::Lut2d,
            Mode::PriorartEq2,
            Mode::PriorartEq2Plus,
            Mode::Aggressive,
        ] {
            let e = engine(m, Precision::Uint8, None);
            let out = e.apply(&[], 4);
            assert!(out.is_empty(), "{m:?}");
        }
    }

    #[test]
    #[should_panic(expected = "row length n must be > 0")]
    #[cfg(debug_assertions)]
    fn zero_n_is_rejected_at_the_trait_boundary() {
        let e = engine(Mode::Rexp, Precision::Uint8, None);
        let mut out = [0.0f32; 2];
        e.run(&[1.0, 2.0], 0, &mut out);
    }

    #[test]
    fn scratch_reuse_across_engines_and_shapes() {
        let mut s = Scratch::new();
        let rexp = SoftmaxRexp::new(Precision::Uint8, None);
        let l2d = SoftmaxLut2d::new(Precision::Int16);
        let x1 = [0.5, -1.0, 2.0, 0.0, 0.0, 1.0];
        let x2 = [1.0, 1.0];
        let mut got = [0.0f32; 6];
        rexp.run_with(&x1, 3, &mut got, &mut s);
        assert_eq!(got.to_vec(), rexp.apply(&x1, 3));
        let mut got2 = [0.0f32; 2];
        l2d.run_with(&x2, 2, &mut got2, &mut s);
        assert_eq!(got2.to_vec(), l2d.apply(&x2, 2));
        // back to the first engine with a different row length
        let mut got3 = [0.0f32; 6];
        rexp.run_with(&x1, 2, &mut got3, &mut s);
        assert_eq!(got3.to_vec(), rexp.apply(&x1, 2));
    }
}
