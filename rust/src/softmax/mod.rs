//! Bit-exact software models of the softmax datapaths — fused, batch-aware,
//! row-parallel.
//!
//! The integer stages reproduce, entry for entry, the Pallas kernels and
//! jnp oracles on the python side (asserted against
//! `artifacts/golden_softmax.ltb`). They serve three roles:
//!
//! 1. the request-path hot loop of the standalone softmax service
//!    (including the CPU fallback behind `coordinator::SoftmaxPipeline`),
//! 2. the functional layer under the cycle-accurate [`crate::hwsim`],
//! 3. the rust-side baseline for the criterion-style benches.
//!
//! # Kernel architecture (fused two-pass)
//!
//! Every LUT engine streams each row in exactly **two** passes:
//!
//! * **pass 1** scans the row once: computes the LUT *address* per element
//!   (a branchless clamp), accumulates the integer sum, and parks the
//!   addresses in a caller-provided [`Scratch`] (no thread-local state,
//!   no allocation on the hot path).
//! * **between passes** the per-row normalizer (REXP's `LUT_alpha` read,
//!   2D-LUT's column select) is resolved once, and — when the row is at
//!   least as long as the table — the whole dequantized output table is
//!   hoisted into an f32 mirror (`Scratch::deq`): one `int-mul + shift +
//!   int→f32 + fmul` per *table entry* instead of per element.
//! * **pass 2** is then a single branchless gather per element
//!   (`out[i] = deq[idx[i]]`), or for short rows the direct fused
//!   `((e·α) >> w) as f32 * 1/qmax` — one int-mul+shift+fmul. Either way
//!   the old third full pass over the data (and its thread-local i32
//!   scratch) is gone, and results are bit-identical by construction:
//!   the same integer expressions are evaluated on the same inputs.
//!
//! # Batching and parallelism
//!
//! [`SoftmaxEngine::run_with`] is the scratch-carrying batched entry
//! point: callers that run many batches (services, benches, the worker
//! pool) hold one [`Scratch`] per thread and amortize allocation
//! explicitly. [`SoftmaxEngine::run`] remains the convenience wrapper
//! that brings its own scratch. Rows are independent, so [`ParSoftmax`]
//! (see [`par`]) shards row-blocks of a batch across a persistent worker
//! pool and stays `==`-exact with the wrapped engine.
//!
//! # Integer pass 1 (i8 ingestion)
//!
//! The paper's whole premise is that attention inputs arrive *already
//! quantized*; feeding the engines f32 rows therefore pays a float
//! subtract + cast per element that the hardware never would. The
//! [`SoftmaxEngine::run_i8_with`] entry point ingests raw `i8` rows
//! described by an [`IntRow`] adapter (per-tensor affine, from
//! [`crate::quant::Affine`]): the LUT engines override it with a pass 1
//! that is **pure integer** — the row max is an `i8` scan, and the LUT
//! address is `idx = clamp(m_q - v_q, 0, last)` when one quantization
//! step equals one LUT-index unit (the aligned case), or one fixed-point
//! `(d * mult) >> shift` multiply otherwise (see [`IntMap`]). The inner
//! loops are branchless `chunks_exact(8)` blocks (sub/min + table gather,
//! no float math, no data-dependent branches) that LLVM autovectorizes;
//! the `i8/<mode>` vs `uint8/<mode>` labels in `softmax_bench` track the
//! resulting pass-1 delta. Pass 2 is the same fused f32-mirrored dequant
//! gather as the f32 path, so `run_i8_with` output equals
//! `run_i8_int * 1/qmax` bit-exactly, and equals the f32 datapath on
//! dequantized inputs whenever the affine scale is exactly representable
//! (dyadic scales — asserted in `integration_attention.rs`).
//!
//! The same integer substrate (diff → fixed-point map → LUT gather →
//! integer normalizer) backs the fused attention kernel in
//! [`crate::attention`], which keeps QK^T scores in `i32` and never
//! materializes an f32 probability matrix.

mod exact;
mod lut2d;
mod par;
mod priorart;
mod rexp;

pub use exact::SoftmaxExact;
pub use lut2d::SoftmaxLut2d;
pub use par::{ParSoftmax, ScatterOutcome, DEFAULT_MIN_ROWS_PER_SHARD};
pub(crate) use par::lock_unpoisoned;
pub use priorart::{SoftmaxAggressive, SoftmaxEq2, SoftmaxEq2Plus};
pub use rexp::SoftmaxRexp;

use crate::lut::Precision;
use crate::quant::Affine;

/// Shared vocabulary with the python side (`kernels.ref.SOFTMAX_MODES`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    Exact,
    Rexp,
    Lut2d,
    PriorartEq2,
    PriorartEq2Plus,
    Aggressive,
}

impl Mode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "exact" => Self::Exact,
            "rexp" => Self::Rexp,
            "lut2d" => Self::Lut2d,
            "priorart_eq2" => Self::PriorartEq2,
            "priorart_eq2plus" => Self::PriorartEq2Plus,
            "aggressive" => Self::Aggressive,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::Rexp => "rexp",
            Self::Lut2d => "lut2d",
            Self::PriorartEq2 => "priorart_eq2",
            Self::PriorartEq2Plus => "priorart_eq2plus",
            Self::Aggressive => "aggressive",
        }
    }
}

/// Quantized-row ingestion descriptor for the i8 fast path: how raw `i8`
/// scores map into an engine's LUT-index domain.
///
/// One quantization step spans `scale` LUT-index units (for REXP the
/// index unit is one logit unit; the 2D-LUT engine folds its 0.1-per-bin
/// step internally). `zero_point` is only consulted by engines without an
/// integer datapath (the default trait path dequantizes element-wise);
/// the LUT engines consume row *diffs* `d = m_q - v_q`, which cancel it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IntRow {
    pub scale: f32,
    pub zero_point: i32,
}

impl IntRow {
    pub fn new(scale: f32, zero_point: i32) -> Self {
        Self { scale, zero_point }
    }

    /// The adapter for a [`crate::quant`] per-tensor affine tensor.
    pub fn from_affine(a: &Affine) -> Self {
        Self { scale: a.scale, zero_point: a.zero_point }
    }

    /// The paper's aligned case: one quantization step == one LUT-index
    /// unit, so the REXP address is literally `clamp(m_q - v_q, 0, last)`.
    pub fn unit() -> Self {
        Self { scale: 1.0, zero_point: 0 }
    }

    #[inline]
    pub(crate) fn dequantize(&self, q: i8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }
}

/// Fixed-point LUT-address map of the integer pass 1:
/// `index(d) = min((d * mult) >> shift, last)` for an integer diff
/// `d >= 0`, where `step` is LUT-index units per integer diff unit.
///
/// The shift is chosen per map so `mult = round(step * 2^shift)` lands
/// in `[2^30, 2^31]`: **constant relative precision (~2^-30) for any
/// step**, rather than a fixed absolute grid. This matters for the
/// attention path, whose steps are tiny (`s_q·s_k/√d_h` ~ 1e-4..1e-6) —
/// a fixed 16-bit shift would round such multipliers to 0 or 1 and
/// collapse every score diff to the same address. Dyadic steps are
/// represented exactly (`mult` a power of two times the odd part), so
/// the map reproduces the f32 datapath's `trunc(d_f32 * step)`
/// bit-for-bit on dequantized inputs. `mult ≤ 2^31` and `d ≤ 2^31`, so
/// the widened product never overflows `i64`; steps too large for the
/// clamped multiplier saturate every nonzero diff to `last`, exactly as
/// the true map would.
#[derive(Clone, Copy, Debug)]
pub(crate) struct IntMap {
    mult: i64,
    shift: u32,
    last: i64,
}

impl IntMap {
    /// `step`: LUT-index units per integer diff unit; `last`: top address.
    pub(crate) fn new(step: f32, last: i32) -> Self {
        let step = step as f64;
        let (mult, shift) = if !(step > 0.0) || !step.is_finite() {
            (0i64, 0u32)
        } else {
            // step = m·2^e with m ∈ [1, 2): shift = 30 − e puts
            // round(step·2^shift) in [2^30, 2^31]
            let e = step.log2().floor() as i32;
            let shift = (30 - e).clamp(0, 62) as u32;
            let mult = (step * (2f64).powi(shift as i32)).round();
            ((mult.min((1u64 << 31) as f64)) as i64, shift)
        };
        Self { mult, shift, last: last as i64 }
    }

    /// One quant step == one LUT address: the multiply disappears and
    /// pass 1 is the paper's `clamp(m_q - v_q, 0, last)` wiring.
    #[inline]
    pub(crate) fn is_unit(&self) -> bool {
        self.mult == 1i64 << self.shift
    }

    #[inline(always)]
    pub(crate) fn index(&self, d: i32) -> i32 {
        debug_assert!(d >= 0, "diff from the row max must be non-negative");
        (((d as i64 * self.mult) >> self.shift).min(self.last)) as i32
    }

    #[inline]
    pub(crate) fn last(&self) -> i32 {
        self.last as i32
    }

    /// Would diff `d` saturate the top LUT address? Telemetry only —
    /// the hot path's [`Self::index`] `min` never branches on this.
    #[inline]
    pub(crate) fn clamps(&self, d: i32) -> bool {
        (d as i64 * self.mult) >> self.shift > self.last
    }

    /// Does diff `d` land exactly on a LUT-index boundary — i.e. is
    /// `d * mult` a multiple of `2^shift`, so the truncation in
    /// [`Self::index`] drops nothing?
    ///
    /// This is the split-merge alignment predicate: when a span's
    /// max-to-global-max diff `m − m_span` is index-aligned,
    /// `index(a + d) == index(a) + index(d)` for every in-span diff `a`
    /// (truncation distributes over a sum with a zero fractional part),
    /// so shifting a span's LUT-address histogram by `index(d)` reproduces
    /// the unsplit addresses bit-for-bit. `d == 0` and unit maps are
    /// always aligned.
    #[inline]
    pub(crate) fn shift_is_exact(&self, d: i32) -> bool {
        debug_assert!(d >= 0, "alignment is asked of non-negative diffs");
        (d as i64 * self.mult) & ((1i64 << self.shift) - 1) == 0
    }
}

/// Sampled LUT range telemetry (see [`crate::obs::range`]): when the
/// sampling gate admits a call, the row's diffs are re-scanned AFTER the
/// fused pass — the branchless hot loops above stay bit-for-bit
/// untouched, telemetry on or off.
#[cold]
fn record_pass1_range(
    diffs: impl Iterator<Item = i32>,
    saturates: impl Fn(i32) -> bool,
    denom: i32,
) {
    let (mut clamped, mut lo, mut hi) = (0u64, i64::MAX, i64::MIN);
    for d in diffs {
        if saturates(d) {
            clamped += 1;
        }
        lo = lo.min(d as i64);
        hi = hi.max(d as i64);
    }
    if lo <= hi {
        crate::obs::range::record_pass1(clamped, lo, hi, denom as i64);
    }
}

/// Integer pass 1 over an i8 row, aligned (unit-map) variant: LUT address
/// is `min(m - v, last)` (diffs are non-negative, so the lower clamp is
/// free). Parks addresses in `idx` and returns the integer row sum.
///
/// §Perf: branchless `chunks_exact(8)` blocks — the address block is pure
/// widen/sub/min (autovectorizes; the `i8/<mode>` bench labels track the
/// delta vs the f32 pass 1), the gather block feeds the scalar sum.
#[inline]
pub(crate) fn pass1_i8_unit(row: &[i8], m: i32, last: i32, table: &[i32], idx: &mut [i32]) -> i32 {
    let mut s = 0i32;
    for (i8b, r8) in idx.chunks_exact_mut(8).zip(row.chunks_exact(8)) {
        for k in 0..8 {
            i8b[k] = (m - r8[k] as i32).min(last);
        }
        for k in 0..8 {
            s += table[i8b[k] as usize];
        }
    }
    let rem = row.len() - row.len() % 8;
    for (slot, &v) in idx[rem..].iter_mut().zip(&row[rem..]) {
        let k = (m - v as i32).min(last);
        *slot = k;
        s += table[k as usize];
    }
    if crate::obs::range::sample_gate() {
        record_pass1_range(row.iter().map(|&v| m - v as i32), |d| d > last, s);
    }
    s
}

/// Integer pass 1 over an i8 row, general fixed-point variant: one
/// widening multiply + shift + min per element (see [`IntMap`]).
#[inline]
pub(crate) fn pass1_i8_mapped(row: &[i8], m: i32, map: IntMap, table: &[i32], idx: &mut [i32]) -> i32 {
    let mut s = 0i32;
    for (i8b, r8) in idx.chunks_exact_mut(8).zip(row.chunks_exact(8)) {
        for k in 0..8 {
            i8b[k] = map.index(m - r8[k] as i32);
        }
        for k in 0..8 {
            s += table[i8b[k] as usize];
        }
    }
    let rem = row.len() - row.len() % 8;
    for (slot, &v) in idx[rem..].iter_mut().zip(&row[rem..]) {
        let k = map.index(m - v as i32);
        *slot = k;
        s += table[k as usize];
    }
    if crate::obs::range::sample_gate() {
        record_pass1_range(row.iter().map(|&v| m - v as i32), |d| map.clamps(d), s);
    }
    s
}

/// Integer pass 1 over a row of i32 scores (the attention path's QK^T
/// accumulators) — same structure as [`pass1_i8_mapped`], wider input.
#[inline]
pub(crate) fn pass1_scores_mapped(row: &[i32], m: i32, map: IntMap, table: &[i32], idx: &mut [i32]) -> i32 {
    let mut s = 0i32;
    for (i8b, r8) in idx.chunks_exact_mut(8).zip(row.chunks_exact(8)) {
        for k in 0..8 {
            i8b[k] = map.index(m - r8[k]);
        }
        for k in 0..8 {
            s += table[i8b[k] as usize];
        }
    }
    let rem = row.len() - row.len() % 8;
    for (slot, &v) in idx[rem..].iter_mut().zip(&row[rem..]) {
        let k = map.index(m - v);
        *slot = k;
        s += table[k as usize];
    }
    if crate::obs::range::sample_gate() {
        record_pass1_range(row.iter().copied().map(|v| m - v), |d| map.clamps(d), s);
    }
    s
}

/// Row max of an i8 row (empty row -> `i8::MIN`, which callers never see:
/// the trait boundary rejects `n == 0` and empty batches return early).
#[inline]
pub(crate) fn i8_row_max(row: &[i8]) -> i8 {
    row.iter().copied().fold(i8::MIN, i8::max)
}

/// Reusable per-thread kernel workspace: LUT addresses for one row, the
/// per-row dequantized f32 mirror of the active table, and a spill buffer
/// for the default (dequantizing) i8 path. Engines only grow the buffers;
/// a single `Scratch` serves any engine/shape sequence.
#[derive(Debug, Default)]
pub struct Scratch {
    idx: Vec<i32>,
    deq: Vec<f32>,
    fbuf: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutable views of the two buffers, grown to at least the requested
    /// lengths. Split borrow so pass 1 (addresses) and the dequant mirror
    /// can be filled in the same row iteration.
    pub(crate) fn borrow2(&mut self, idx_len: usize, deq_len: usize) -> (&mut [i32], &mut [f32]) {
        if self.idx.len() < idx_len {
            self.idx.resize(idx_len, 0);
        }
        if self.deq.len() < deq_len {
            self.deq.resize(deq_len, 0.0);
        }
        (&mut self.idx[..idx_len], &mut self.deq[..deq_len])
    }

    /// Move the spill buffer out (grown to `len`) so the default i8 path
    /// can dequantize into it and still hand the scratch to `run_with`;
    /// return it with [`Scratch::put_fbuf`] to keep the amortization.
    pub(crate) fn take_fbuf(&mut self, len: usize) -> Vec<f32> {
        let mut b = std::mem::take(&mut self.fbuf);
        if b.len() < len {
            b.resize(len, 0.0);
        }
        b
    }

    pub(crate) fn put_fbuf(&mut self, buf: Vec<f32>) {
        self.fbuf = buf;
    }
}

/// A row-wise softmax engine: fills `out` with probabilities for each
/// length-`n` row of `x` (row-major, `x.len() == rows * n`).
///
/// `n == 0` is a caller bug: it is rejected by `debug_assert!` at every
/// engine boundary (an empty *batch*, `x.len() == 0`, is fine and a
/// no-op). See [`row_max`] for why the guard exists.
pub trait SoftmaxEngine: Send + Sync {
    /// Scratch-carrying batched entry point — the hot path. Callers that
    /// run repeatedly should hold one [`Scratch`] per thread; the engines
    /// never allocate when the scratch has warmed up.
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch);

    /// Convenience single-shot wrapper (brings its own scratch).
    fn run(&self, x: &[f32], n: usize, out: &mut [f32]) {
        let mut scratch = Scratch::new();
        self.run_with(x, n, out, &mut scratch);
    }

    /// i8 fast path: softmax over raw quantized rows described by an
    /// [`IntRow`] adapter. The default implementation dequantizes into the
    /// scratch spill buffer and runs the f32 datapath (reference
    /// semantics, and the fallback for engines without an integer pass);
    /// the LUT engines override it with a pure-integer pass 1 — see the
    /// module docs ("Integer pass 1").
    fn run_i8_with(&self, x: &[i8], n: usize, row: IntRow, out: &mut [f32], scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        let mut buf = scratch.take_fbuf(x.len());
        for (b, &q) in buf.iter_mut().zip(x) {
            *b = row.dequantize(q);
        }
        self.run_with(&buf[..x.len()], n, out, scratch);
        scratch.put_fbuf(buf);
    }

    /// Convenience single-shot i8 wrapper (brings its own scratch).
    fn run_i8(&self, x: &[i8], n: usize, row: IntRow, out: &mut [f32]) {
        let mut scratch = Scratch::new();
        self.run_i8_with(x, n, row, out, &mut scratch);
    }

    fn name(&self) -> &'static str;

    /// convenience: allocate and return the result
    fn apply(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0; x.len()];
        self.run(x, n, &mut out);
        out
    }

    /// convenience: allocate and return the i8-path result
    fn apply_i8(&self, x: &[i8], n: usize, row: IntRow) -> Vec<f32> {
        let mut out = vec![0.0; x.len()];
        self.run_i8(x, n, row, &mut out);
        out
    }
}

/// Build an engine by (mode, precision, alpha override) — the L3 dispatch
/// used by the coordinator and the experiment harness.
pub fn engine(
    mode: Mode,
    prec: Precision,
    alpha_len: Option<usize>,
) -> Box<dyn SoftmaxEngine> {
    match mode {
        Mode::Exact => Box::new(SoftmaxExact),
        Mode::Rexp => Box::new(SoftmaxRexp::new(prec, alpha_len)),
        Mode::Lut2d => Box::new(SoftmaxLut2d::new(prec)),
        Mode::PriorartEq2 => Box::new(SoftmaxEq2::new(prec)),
        Mode::PriorartEq2Plus => Box::new(SoftmaxEq2Plus::new(prec)),
        Mode::Aggressive => Box::new(SoftmaxAggressive::new(prec)),
    }
}

/// Build a row-parallel engine: the sequential engine for `(mode, prec,
/// alpha_len)` wrapped in a [`ParSoftmax`] worker pool. `workers = None`
/// uses the machine's available parallelism.
pub fn engine_parallel(
    mode: Mode,
    prec: Precision,
    alpha_len: Option<usize>,
    workers: Option<usize>,
) -> ParSoftmax {
    let inner: std::sync::Arc<dyn SoftmaxEngine> =
        std::sync::Arc::from(engine(mode, prec, alpha_len));
    match workers {
        Some(w) => ParSoftmax::with_workers(inner, w),
        None => ParSoftmax::new(inner),
    }
}

/// max of a row (f32, NaN-free inputs assumed — attention scores).
///
/// An empty row yields `0.0`, never `NEG_INFINITY`: the fused kernels cast
/// `max - x` to an integer LUT address, and `NEG_INFINITY as i32` would be
/// a garbage clamp index. Engines additionally `debug_assert!(n > 0)` at
/// the trait boundary so the case cannot arise silently.
#[inline]
pub(crate) fn row_max(row: &[f32]) -> f32 {
    if row.is_empty() {
        return 0.0;
    }
    row.iter().copied().fold(f32::NEG_INFINITY, f32::max)
}

/// Trait-boundary shape guard shared by every engine (debug builds);
/// generic over the input element so the f32 and i8 paths share it.
#[inline]
pub(crate) fn debug_check_shape<T>(x: &[T], n: usize, out: &[f32]) {
    debug_assert!(n > 0, "softmax row length n must be > 0");
    debug_assert_eq!(x.len() % n, 0, "x.len() must be a multiple of n");
    debug_assert_eq!(x.len(), out.len(), "out length must match x");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [
            Mode::Exact,
            Mode::Rexp,
            Mode::Lut2d,
            Mode::PriorartEq2,
            Mode::PriorartEq2Plus,
            Mode::Aggressive,
        ] {
            assert_eq!(Mode::parse(m.name()), Some(m));
        }
        assert_eq!(Mode::parse("softmax9000"), None);
    }

    #[test]
    fn engine_dispatch_names() {
        let e = engine(Mode::Rexp, Precision::Uint8, None);
        assert_eq!(e.name(), "rexp");
        let e = engine(Mode::Exact, Precision::Uint8, None);
        assert_eq!(e.name(), "exact");
    }

    #[test]
    fn row_max_empty_row_is_finite() {
        assert_eq!(row_max(&[]), 0.0);
        assert_eq!(row_max(&[-3.0, 1.5]), 1.5);
        // the clamp index an empty row would produce must be sane
        assert_eq!((row_max(&[]) as i32).clamp(0, 7), 0);
    }

    #[test]
    fn empty_batch_is_a_noop_for_all_engines() {
        for m in [
            Mode::Exact,
            Mode::Rexp,
            Mode::Lut2d,
            Mode::PriorartEq2,
            Mode::PriorartEq2Plus,
            Mode::Aggressive,
        ] {
            let e = engine(m, Precision::Uint8, None);
            let out = e.apply(&[], 4);
            assert!(out.is_empty(), "{m:?}");
        }
    }

    #[test]
    #[should_panic(expected = "row length n must be > 0")]
    #[cfg(debug_assertions)]
    fn zero_n_is_rejected_at_the_trait_boundary() {
        let e = engine(Mode::Rexp, Precision::Uint8, None);
        let mut out = [0.0f32; 2];
        e.run(&[1.0, 2.0], 0, &mut out);
    }

    #[test]
    fn int_map_unit_is_identity_up_to_last() {
        let m = IntMap::new(1.0, 7);
        assert!(m.is_unit());
        for d in 0..32 {
            assert_eq!(m.index(d), d.min(7));
        }
        assert_eq!(m.last(), 7);
    }

    #[test]
    fn int_map_clamps_flags_exactly_the_saturating_diffs() {
        let m = IntMap::new(1.0, 7);
        for d in 0..32 {
            assert_eq!(m.clamps(d), d > 7, "unit map d={d}");
        }
        let m = IntMap::new(0.5, 4);
        assert!(!m.clamps(8), "raw index 4 == last is not a clamp");
        assert!(!m.clamps(9), "trunc(4.5) == last is not a clamp");
        assert!(m.clamps(10), "raw index 5 saturates");
    }

    #[test]
    fn int_map_matches_f32_trunc_for_dyadic_steps() {
        for &step in &[1.0f32, 0.5, 0.25, 0.125, 2.0, 10.0, 0.625] {
            let m = IntMap::new(step, 100);
            for d in 0..512 {
                let want = ((d as f32 * step) as i32).min(100);
                assert_eq!(m.index(d), want, "step {step} d {d}");
            }
        }
    }

    #[test]
    fn int_map_shift_is_exact_marks_boundary_diffs() {
        // unit map: every diff is on a boundary
        let m = IntMap::new(1.0, 7);
        for d in 0..32 {
            assert!(m.shift_is_exact(d), "unit map d={d}");
        }
        // dyadic half-step: even diffs aligned, odd diffs not
        let m = IntMap::new(0.5, 100);
        for d in 0..64 {
            assert_eq!(m.shift_is_exact(d), d % 2 == 0, "step 0.5 d={d}");
        }
        // aligned diffs really do distribute through index()
        for d in (0..64).filter(|d| d % 2 == 0) {
            for a in 0..64 {
                assert_eq!(m.index(a + d), m.index(a) + m.index(d), "a={a} d={d}");
            }
        }
        // non-dyadic step: only d = 0 is guaranteed aligned
        let m = IntMap::new(0.37, 100);
        assert!(m.shift_is_exact(0));
    }

    #[test]
    fn int_map_huge_step_saturates_without_overflow() {
        let m = IntMap::new(f32::MAX, 12);
        assert_eq!(m.index(0), 0);
        assert_eq!(m.index(1), 12);
        assert_eq!(m.index(i32::MAX), 12);
    }

    #[test]
    fn int_map_keeps_precision_for_tiny_attention_steps() {
        // regression: the attention path's step is s_q·s_k/√d_h ~ 1e-4..1e-6;
        // a fixed 16-bit shift would round the multiplier to 0 or 1 and
        // collapse every diff to one address. The per-map shift must keep
        // the map faithful to trunc(d · step) across the whole diff range.
        // Dyadic tiny step: exact.
        let step = 2.0f32.powi(-20);
        let m = IntMap::new(step, 100);
        for d in [0i32, 1, 1 << 19, (1 << 20) - 1, 1 << 20, 3 << 20, 200 << 20] {
            assert_eq!(m.index(d), (d >> 20).min(100), "d={d}");
        }
        // Non-dyadic tiny step: within one index unit of the real map over
        // score-diff magnitudes the QK^T accumulators actually produce.
        let step = 3.8e-6f32;
        let m = IntMap::new(step, 100);
        for d in [0i32, 100_000, 263_158, 1_000_000, 5_000_000] {
            let want = ((d as f64 * step as f64) as i32).min(100);
            assert!((m.index(d) - want).abs() <= 1, "d={d}: {} vs {want}", m.index(d));
        }
        assert_eq!(m.index(5_000_000 * 40), 100, "saturation still clamps");
    }

    #[test]
    fn pass1_helpers_agree_and_cover_tails() {
        // unit and mapped i8 variants, and the i32-score variant, must all
        // produce the same addresses/sum at step 1.0 (tail lengths 0..7)
        let table: Vec<i32> = (0..9).map(|i| 100 - 10 * i).collect();
        let last = (table.len() - 1) as i32;
        let map = IntMap::new(1.0, last);
        for n in 1..20usize {
            let row: Vec<i8> = (0..n).map(|i| ((i * 7) % 23) as i8 - 11).collect();
            let m = i8_row_max(&row) as i32;
            let wide: Vec<i32> = row.iter().map(|&v| v as i32).collect();
            let (mut ia, mut ib, mut ic) = (vec![0; n], vec![0; n], vec![0; n]);
            let sa = pass1_i8_unit(&row, m, last, &table, &mut ia);
            let sb = pass1_i8_mapped(&row, m, map, &table, &mut ib);
            let sc = pass1_scores_mapped(&wide, m, map, &table, &mut ic);
            assert_eq!((sa, &ia), (sb, &ib), "n={n}");
            assert_eq!((sb, &ib), (sc, &ic), "n={n}");
            for (&k, &v) in ia.iter().zip(&row) {
                assert_eq!(k, (m - v as i32).min(last));
            }
        }
    }

    #[test]
    fn default_i8_path_is_dequant_plus_f32_run() {
        // engines without an integer pass (Exact) route i8 input through
        // the scratch spill buffer and the f32 datapath
        let e = SoftmaxExact;
        let row = IntRow::new(0.25, -3);
        let x: Vec<i8> = vec![-8, 0, 5, 120, -128, 4];
        let deq: Vec<f32> = x.iter().map(|&q| row.dequantize(q)).collect();
        assert_eq!(e.apply_i8(&x, 3, row), e.apply(&deq, 3));
        // scratch reuse across the two paths stays clean
        let mut s = Scratch::new();
        let mut a = vec![0.0; x.len()];
        let mut b = vec![0.0; x.len()];
        e.run_i8_with(&x, 2, row, &mut a, &mut s);
        e.run_with(&deq, 2, &mut b, &mut s);
        assert_eq!(a, b);
    }

    #[test]
    fn scratch_reuse_across_engines_and_shapes() {
        let mut s = Scratch::new();
        let rexp = SoftmaxRexp::new(Precision::Uint8, None);
        let l2d = SoftmaxLut2d::new(Precision::Int16);
        let x1 = [0.5, -1.0, 2.0, 0.0, 0.0, 1.0];
        let x2 = [1.0, 1.0];
        let mut got = [0.0f32; 6];
        rexp.run_with(&x1, 3, &mut got, &mut s);
        assert_eq!(got.to_vec(), rexp.apply(&x1, 3));
        let mut got2 = [0.0f32; 2];
        l2d.run_with(&x2, 2, &mut got2, &mut s);
        assert_eq!(got2.to_vec(), l2d.apply(&x2, 2));
        // back to the first engine with a different row length
        let mut got3 = [0.0f32; 6];
        rexp.run_with(&x1, 2, &mut got3, &mut s);
        assert_eq!(got3.to_vec(), rexp.apply(&x1, 2));
    }
}
