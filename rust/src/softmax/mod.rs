//! Bit-exact software models of the softmax datapaths.
//!
//! These are the "HW functional model" of the paper's Algorithms 1 and 2:
//! the integer stages reproduce, entry for entry, the Pallas kernels and
//! jnp oracles on the python side (asserted against
//! `artifacts/golden_softmax.ltb`). They serve three roles:
//!
//! 1. the request-path hot loop of the standalone softmax service,
//! 2. the functional layer under the cycle-accurate [`crate::hwsim`],
//! 3. the rust-side baseline for the criterion-style benches.

mod exact;
mod lut2d;
mod priorart;
mod rexp;

pub use exact::SoftmaxExact;
pub use lut2d::SoftmaxLut2d;
pub use priorart::{SoftmaxAggressive, SoftmaxEq2, SoftmaxEq2Plus};
pub use rexp::SoftmaxRexp;

use crate::lut::Precision;

/// Shared vocabulary with the python side (`kernels.ref.SOFTMAX_MODES`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    Exact,
    Rexp,
    Lut2d,
    PriorartEq2,
    PriorartEq2Plus,
    Aggressive,
}

impl Mode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "exact" => Self::Exact,
            "rexp" => Self::Rexp,
            "lut2d" => Self::Lut2d,
            "priorart_eq2" => Self::PriorartEq2,
            "priorart_eq2plus" => Self::PriorartEq2Plus,
            "aggressive" => Self::Aggressive,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::Rexp => "rexp",
            Self::Lut2d => "lut2d",
            Self::PriorartEq2 => "priorart_eq2",
            Self::PriorartEq2Plus => "priorart_eq2plus",
            Self::Aggressive => "aggressive",
        }
    }
}

/// A row-wise softmax engine: `run` fills `out` with probabilities for each
/// length-`n` row of `x` (row-major, `x.len() == rows * n`).
pub trait SoftmaxEngine: Send + Sync {
    fn run(&self, x: &[f32], n: usize, out: &mut [f32]);

    fn name(&self) -> &'static str;

    /// convenience: allocate and return the result
    fn apply(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0; x.len()];
        self.run(x, n, &mut out);
        out
    }
}

/// Build an engine by (mode, precision, alpha override) — the L3 dispatch
/// used by the coordinator and the experiment harness.
pub fn engine(
    mode: Mode,
    prec: Precision,
    alpha_len: Option<usize>,
) -> Box<dyn SoftmaxEngine> {
    match mode {
        Mode::Exact => Box::new(SoftmaxExact),
        Mode::Rexp => Box::new(SoftmaxRexp::new(prec, alpha_len)),
        Mode::Lut2d => Box::new(SoftmaxLut2d::new(prec)),
        Mode::PriorartEq2 => Box::new(SoftmaxEq2::new(prec)),
        Mode::PriorartEq2Plus => Box::new(SoftmaxEq2Plus::new(prec)),
        Mode::Aggressive => Box::new(SoftmaxAggressive::new(prec)),
    }
}

/// max of a row (f32, NaN-free inputs assumed — attention scores)
#[inline]
pub(crate) fn row_max(row: &[f32]) -> f32 {
    row.iter().copied().fold(f32::NEG_INFINITY, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [
            Mode::Exact,
            Mode::Rexp,
            Mode::Lut2d,
            Mode::PriorartEq2,
            Mode::PriorartEq2Plus,
            Mode::Aggressive,
        ] {
            assert_eq!(Mode::parse(m.name()), Some(m));
        }
        assert_eq!(Mode::parse("softmax9000"), None);
    }

    #[test]
    fn engine_dispatch_names() {
        let e = engine(Mode::Rexp, Precision::Uint8, None);
        assert_eq!(e.name(), "rexp");
        let e = engine(Mode::Exact, Precision::Uint8, None);
        assert_eq!(e.name(), "exact");
    }
}
