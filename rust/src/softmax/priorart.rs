//! Prior-art softmax baselines (paper Appendix A.1).
//!
//! * [`SoftmaxEq2`]      — Eq.(11): `exp(x - ln(sum e^x))`, no max-norm,
//!   outer exp rounded to the target precision ([32]'s Eq.(2)).
//! * [`SoftmaxEq2Plus`]  — Eq.(12): same with max-normalization.
//! * [`SoftmaxAggressive`] — [29]'s raw reciprocal exponentiation
//!   (Eq.(3)): UNNORMALIZED, collapses attention models (paper Fig. 5).
//!
//! These are float-transcendental models (the accuracy experiments run
//! them through the PJRT artifacts; these rust twins exist for hwsim and
//! the benches, where 1-ULP libm differences are irrelevant).
//! `SoftmaxAggressive` has no per-row normalizer at all, so its dequant is
//! hoisted once at construction: an f32-mirrored `LUT_{1/e}`
//! (`recip[i] as f32 * 1/qmax`, the same expression the old per-element
//! loop evaluated) makes its whole run one clamp + gather per element.

use super::{debug_check_shape, row_max, Scratch, SoftmaxEngine};
use crate::lut::{lut_recip_e, Precision};

fn round_to_precision(v: f32, qmax: f32) -> f32 {
    (v * qmax).round() / qmax
}

pub struct SoftmaxEq2 {
    qmax: f32,
}

impl SoftmaxEq2 {
    pub fn new(prec: Precision) -> Self {
        Self { qmax: prec.qmax() as f32 }
    }
}

impl SoftmaxEngine for SoftmaxEq2 {
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], _scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let sum: f32 = row.iter().map(|v| v.exp()).sum();
            let ln_sum = sum.ln();
            for (o, &v) in orow.iter_mut().zip(row) {
                *o = round_to_precision((v - ln_sum).exp(), self.qmax);
            }
        }
    }

    fn name(&self) -> &'static str {
        "priorart_eq2"
    }
}

pub struct SoftmaxEq2Plus {
    qmax: f32,
}

impl SoftmaxEq2Plus {
    pub fn new(prec: Precision) -> Self {
        Self { qmax: prec.qmax() as f32 }
    }
}

impl SoftmaxEngine for SoftmaxEq2Plus {
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], _scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = row_max(row);
            let sum: f32 = row.iter().map(|v| (v - m).exp()).sum();
            let ln_sum = sum.ln();
            for (o, &v) in orow.iter_mut().zip(row) {
                *o = round_to_precision((v - m - ln_sum).exp(), self.qmax);
            }
        }
    }

    fn name(&self) -> &'static str {
        "priorart_eq2plus"
    }
}

pub struct SoftmaxAggressive {
    /// f32-mirrored `LUT_{1/e}`, premultiplied by 1/qmax at construction
    recip_f32: Vec<f32>,
}

impl SoftmaxAggressive {
    pub fn new(prec: Precision) -> Self {
        let inv_qmax = 1.0 / prec.qmax() as f32;
        Self {
            recip_f32: lut_recip_e(prec)
                .into_iter()
                .map(|e| e as f32 * inv_qmax)
                .collect(),
        }
    }
}

impl SoftmaxEngine for SoftmaxAggressive {
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], _scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        let last = (self.recip_f32.len() - 1) as i32;
        for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = row_max(row);
            for (o, &v) in orow.iter_mut().zip(row) {
                let idx = ((m - v) as i32).clamp(0, last);
                *o = self.recip_f32[idx as usize];
            }
        }
    }

    fn name(&self) -> &'static str {
        "aggressive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::{SoftmaxEngine, SoftmaxExact};
    use crate::testkit;

    #[test]
    fn eq2plus_close_to_exact_before_rounding() {
        let mut rng = testkit::Rng::new(3);
        let x = rng.normal_vec(64, 2.0);
        let a = SoftmaxEq2Plus::new(Precision::Int16).apply(&x, 16);
        let e = SoftmaxExact.apply(&x, 16);
        for (u, v) in a.iter().zip(&e) {
            assert!((u - v).abs() < 1e-3);
        }
    }

    #[test]
    fn eq2_degrades_on_shifted_inputs() {
        // without max-normalization, large-magnitude inputs overflow exp
        let x: Vec<f32> = vec![90.0, 91.0, 92.0, 89.0];
        let out = SoftmaxEq2::new(Precision::Uint8).apply(&x, 4);
        let exact = SoftmaxExact.apply(&x, 4);
        let err: f32 = out
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        // exp(90+) is inf in f32 -> ln(inf) = inf -> exp(-inf) = 0 rows
        assert!(err > 0.1 || out.iter().any(|v| !v.is_finite() || *v == 0.0));
    }

    #[test]
    fn aggressive_rows_do_not_normalize() {
        let mut rng = testkit::Rng::new(4);
        let x = rng.normal_vec(64, 2.0);
        let out = SoftmaxAggressive::new(Precision::Uint8).apply(&x, 16);
        let worst = out
            .chunks(16)
            .map(|r| (r.iter().sum::<f32>() - 1.0).abs())
            .fold(0.0, f32::max);
        assert!(worst > 0.5, "rows unexpectedly normalized: {worst}");
    }

    #[test]
    fn quantization_grid_respected() {
        let x = vec![0.3, -1.0, 0.7, 2.2];
        for v in SoftmaxEq2Plus::new(Precision::Uint4).apply(&x, 4) {
            let g = v * 15.0;
            assert!((g - g.round()).abs() < 1e-4);
        }
    }

    #[test]
    fn aggressive_mirror_matches_integer_dequant() {
        // the premultiplied f32 table must equal per-element
        // `recip[i] as f32 * 1/qmax` bit for bit
        for prec in crate::lut::ALL_PRECISIONS {
            let e = SoftmaxAggressive::new(prec);
            let inv = 1.0 / prec.qmax() as f32;
            for (i, &r) in lut_recip_e(prec).iter().enumerate() {
                assert_eq!(e.recip_f32[i], r as f32 * inv);
            }
        }
    }
}
