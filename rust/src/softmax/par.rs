//! Row-parallel softmax: a persistent worker pool (std threads + a
//! Mutex/Condvar job queue, no external deps) that shards row-blocks of a
//! batch across cores.
//!
//! Rows of a softmax batch are independent, so [`ParSoftmax`] is bit-exact
//! with the engine it wraps *by construction*: each worker runs the
//! wrapped engine's own `run_with` over a contiguous block of whole rows,
//! writing into a disjoint slice of the caller's output buffer. Workers
//! hold a private [`Scratch`] each, so the per-row LUT-address and dequant
//! buffers are allocated once per thread for the lifetime of the pool —
//! the explicit-amortization story of `run_with`, multiplied across cores.
//!
//! Small batches fall back to the wrapped engine inline (fan-out costs
//! more than it saves below a few thousand elements — or below a handful
//! of rows, however wide), which keeps single requests at sequential
//! latency while saturated batches scale.
//!
//! The pool is deliberately task-generic underneath: besides f32 and i8
//! softmax row-blocks, [`ParSoftmax::scatter`] fans arbitrary indexed
//! closures (the fused attention kernel's B×H head-blocks) across the
//! same workers.
//!
//! # Failure domains
//!
//! A panicking task must never take the pool (or its submitter) with it.
//! Every task body runs under `catch_unwind` — tasks are unwind-safe by
//! construction, touching only disjoint raw slices / scatter indices —
//! and the worker **always** signals `done` with an `Ok`/`Panicked`
//! status, so a submitter can never hang on a dropped sender. The queue
//! mutex is taken with explicit poison recovery everywhere (the queue
//! state is a plain job list, valid under any interleaving), so one
//! contained panic never wedges subsequent waves. Scatter submitters get
//! the per-index verdict back as a [`ScatterOutcome`], which the decode
//! wave layer (`attention/batch.rs`) maps to the owning session — one
//! bad head task fails one session's step, siblings stay bit-identical.
//! Deterministic panic/delay injection for the chaos suites comes from a
//! [`FaultPlan`] ([`ParSoftmax::set_fault_plan`]); the disabled plan is
//! a single branch per scatter.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use super::{debug_check_shape, IntRow, Scratch, SoftmaxEngine};
use crate::faults::{FaultPlan, FaultSite, INJECTED_PANIC};

/// Lock a mutex, recovering the guard from a poisoned lock. The pool's
/// shared state (a job queue / a spare-scratch stack / a fault plan) is
/// structurally valid under any interleaving — poisoning carries no
/// information here, and propagating it would wedge every later wave on
/// one contained panic.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Don't bother fanning out below this many elements per shard.
const MIN_ELEMS_PER_SHARD: usize = 2048;

/// Default minimum rows per shard: waking the pool to hand a worker one
/// or two rows costs more than computing them (the tiny-batch latency
/// regression guarded by `integration_par.rs`). Tunable per pool via
/// [`ParSoftmax::with_policy`] — the decode serving path runs few-row
/// single-step batches and wants a lower inline threshold.
pub const DEFAULT_MIN_ROWS_PER_SHARD: usize = 4;

/// What a worker runs: a sharded softmax row-block (f32 or i8 ingestion)
/// or one index of a [`ParSoftmax::scatter`] fan-out.
enum Task {
    Softmax {
        x: *const f32,
        out: *mut f32,
        len: usize,
        n: usize,
        engine: Arc<dyn SoftmaxEngine>,
    },
    SoftmaxI8 {
        x: *const i8,
        out: *mut f32,
        len: usize,
        n: usize,
        row: IntRow,
        engine: Arc<dyn SoftmaxEngine>,
    },
    Scatter {
        /// type-erased `&F where F: Fn(usize, &mut Scratch) + Sync`
        ctx: *const (),
        /// monomorphized trampoline reconstituting `&F` from `ctx`
        call: unsafe fn(*const (), usize, &mut Scratch),
        index: usize,
    },
}

/// Injected behavior for one job, decided at submit time from the pool's
/// [`FaultPlan`] (scatter tasks only — softmax row shards are internal
/// work with no per-session failure domain to isolate).
#[derive(Clone, Copy, PartialEq, Eq)]
enum InjectedFault {
    None,
    /// panic before running the task (contained by the worker)
    Panic,
    /// yield repeatedly before running — perturbs completion order,
    /// must never perturb bytes
    Slow,
}

/// Per-job completion status, always sent on `done` — the submitter
/// never blocks on a dropped sender.
#[derive(Clone, Copy, PartialEq, Eq)]
struct JobDone {
    /// the submitter-assigned tag (scatter index / shard number)
    tag: usize,
    ok: bool,
}

/// One unit of pool work. The submitting thread blocks until every job of
/// the batch has signalled `done`, so the pointers outlive the job; `out`
/// blocks (and scatter indices) are disjoint between jobs of one batch.
struct Job {
    task: Task,
    tag: usize,
    fault: InjectedFault,
    done: mpsc::Sender<JobDone>,
}

// SAFETY: `x`/`out`/`ctx` stay valid and unaliased for the job's lifetime
// (the submitter blocks on `done` before returning, and hands each job a
// disjoint block/index); `engine` is `Send + Sync` by the trait bound,
// scatter closures are `Sync` by `scatter`'s bound; `done` is a `Send`
// sender.
unsafe impl Send for Job {}

struct Shared {
    queue: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lutmax-softmax-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn softmax worker")
            })
            .collect();
        Self { shared, handles }
    }

    fn workers(&self) -> usize {
        self.handles.len()
    }

    fn submit(&self, job: Job) {
        // poison recovery: a contained task panic must never wedge the
        // next submitter (the queue state is valid under any unwind)
        let mut q = lock_unpoisoned(&self.shared.queue);
        q.jobs.push_back(job);
        drop(q);
        self.shared.ready.notify_one();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        lock_unpoisoned(&self.shared.queue).shutdown = true;
        self.shared.ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run one task body, containing any panic. Tasks are unwind-safe by
/// construction: each touches only its own disjoint output block /
/// scatter index plus shared `Sync` state, so no observer can see a
/// half-updated invariant after an unwind.
fn run_task(task: &Task, fault: InjectedFault, scratch: &mut Scratch) -> bool {
    catch_unwind(AssertUnwindSafe(|| {
        match fault {
            InjectedFault::None => {}
            InjectedFault::Panic => panic!("{INJECTED_PANIC}: worker task panic"),
            InjectedFault::Slow => {
                for _ in 0..64 {
                    std::thread::yield_now();
                }
            }
        }
        // SAFETY: see `unsafe impl Send for Job` — the submitter keeps the
        // buffers alive and the blocks disjoint until `done` is signalled.
        match *task {
            Task::Softmax { x, out, len, n, ref engine } => {
                let x = unsafe { std::slice::from_raw_parts(x, len) };
                let out = unsafe { std::slice::from_raw_parts_mut(out, len) };
                engine.run_with(x, n, out, scratch);
            }
            Task::SoftmaxI8 { x, out, len, n, row, ref engine } => {
                let x = unsafe { std::slice::from_raw_parts(x, len) };
                let out = unsafe { std::slice::from_raw_parts_mut(out, len) };
                engine.run_i8_with(x, n, row, out, scratch);
            }
            Task::Scatter { ctx, call, index } => unsafe { call(ctx, index, scratch) },
        }
    }))
    .is_ok()
}

fn worker_loop(shared: &Shared) {
    let mut scratch = Scratch::new();
    loop {
        let job = {
            let mut q = lock_unpoisoned(&shared.queue);
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = match shared.ready.wait(q) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
        };
        let ok = run_task(&job.task, job.fault, &mut scratch);
        // ALWAYS signal, panic or not — a submitter blocked on `done`
        // must never hang because a task died (send failure just means
        // the submitter is gone; nothing to do)
        let _ = job.done.send(JobDone { tag: job.tag, ok });
    }
}

/// Per-index verdict of one [`ParSoftmax::scatter`] wave. The wave as a
/// whole always completes — panicked indices simply never wrote their
/// output block — and the submitter decides what each failure means (the
/// decode wave layer fails the owning session's step; the fused-attention
/// path re-raises).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ScatterOutcome {
    panicked: Vec<usize>,
}

impl ScatterOutcome {
    /// `true` when every index ran to completion.
    pub fn is_ok(&self) -> bool {
        self.panicked.is_empty()
    }

    /// Indices whose task panicked (ascending). Their output blocks are
    /// untouched or partially written — the submitter must not read them.
    pub fn panicked(&self) -> &[usize] {
        &self.panicked
    }
}

/// [`SoftmaxEngine`] adapter that shards row-blocks across a persistent
/// worker pool. Output is `==` to the wrapped engine's for every input.
pub struct ParSoftmax {
    inner: Arc<dyn SoftmaxEngine>,
    pool: WorkerPool,
    /// inline-vs-pool threshold: a shard must carry at least this many
    /// whole rows to be worth a pool wake
    min_rows_per_shard: usize,
    /// batches dispatched to the pool (vs. run inline) — test/bench probe
    parallel_batches: AtomicUsize,
    /// injected-fault schedule for scatter tasks ([`FaultPlan::none`]
    /// outside the chaos suites — one `is_none` branch per scatter)
    faults: Mutex<FaultPlan>,
    /// monotone scatter-task counter — the fault plan's per-task index,
    /// so a schedule replays as long as waves are submitted in the same
    /// order (reset by [`ParSoftmax::set_fault_plan`])
    fault_seq: AtomicU64,
}

impl ParSoftmax {
    /// Wrap `inner` with one worker per available core.
    pub fn new(inner: Arc<dyn SoftmaxEngine>) -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::with_workers(inner, workers)
    }

    /// Wrap `inner` with an explicit worker count (min 1) and the default
    /// inline-vs-pool row threshold.
    pub fn with_workers(inner: Arc<dyn SoftmaxEngine>, workers: usize) -> Self {
        Self::with_policy(inner, workers, DEFAULT_MIN_ROWS_PER_SHARD)
    }

    /// Wrap `inner` with an explicit worker count and minimum rows per
    /// shard (both clamped to >= 1). Lower thresholds let few-row batches
    /// (e.g. single decode steps) reach the pool; higher ones keep more
    /// traffic inline.
    pub fn with_policy(
        inner: Arc<dyn SoftmaxEngine>,
        workers: usize,
        min_rows_per_shard: usize,
    ) -> Self {
        Self {
            inner,
            pool: WorkerPool::new(workers.max(1)),
            min_rows_per_shard: min_rows_per_shard.max(1),
            parallel_batches: AtomicUsize::new(0),
            faults: Mutex::new(FaultPlan::none()),
            fault_seq: AtomicU64::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Install a fault schedule for scatter tasks (and reset the task
    /// counter, so the schedule replays from its start). Pass
    /// [`FaultPlan::none`] to disable injection.
    pub fn set_fault_plan(&self, plan: FaultPlan) {
        *lock_unpoisoned(&self.faults) = plan;
        self.fault_seq.store(0, Ordering::SeqCst);
    }

    /// The currently-installed fault schedule.
    pub fn fault_plan(&self) -> FaultPlan {
        *lock_unpoisoned(&self.faults)
    }

    /// The pool's inline-vs-pool row threshold.
    pub fn min_rows_per_shard(&self) -> usize {
        self.min_rows_per_shard
    }

    /// Inline-vs-scatter decision for a [`ParSoftmax::scatter`] wave of
    /// `rows` independent row tasks: `true` means the wave is too small to
    /// be worth a pool wake and the submitter should compute inline.
    ///
    /// Callers MUST ask with the **whole wave's** row count. A batched
    /// decode round over S sessions of H query heads each is ONE wave of
    /// S×H rows — asking per session (with H) double-counts the pool wake
    /// S times and keeps row-rich waves inline; that accounting bug is
    /// regression-tested in `integration_par.rs`
    /// (`wave_accounting_counts_the_whole_waves_rows`). When scatter
    /// tasks are UNEQUAL — the group-major decode sweep submits one task
    /// per KV group, each carrying H/G head-rows of work — `rows` must
    /// be the wave's row *equivalents* (head rows, or the MAC load in
    /// row-sized units), never the raw task count: 2 heavy group tasks
    /// are worth far more than 2 rows (see `attention`'s
    /// `wave_stays_inline`, regression-tested in
    /// `integration_par.rs::group_task_accounting_weighs_heavy_groups`).
    /// The threshold is the same `min_rows_per_shard` policy the pool
    /// applies to softmax batches, so one [`ParSoftmax::with_policy`]
    /// knob tunes both.
    pub fn scatter_stays_inline(&self, rows: usize) -> bool {
        self.pool.workers() <= 1 || rows < 2 || rows < self.min_rows_per_shard
    }

    /// The wrapped sequential engine.
    pub fn inner(&self) -> &dyn SoftmaxEngine {
        &*self.inner
    }

    /// How many `run_with` calls actually fanned out to the pool.
    pub fn parallel_batches(&self) -> usize {
        self.parallel_batches.load(Ordering::Relaxed)
    }

    /// Rows per shard for a (rows, n) batch; 0 means "run inline". A
    /// shard must carry both enough elements AND enough whole rows to be
    /// worth a pool wake — a 3-row batch stays inline no matter how wide.
    fn shard_rows(&self, rows: usize, n: usize) -> usize {
        let workers = self.pool.workers();
        if workers <= 1 || rows < 2 {
            return 0;
        }
        let by_work = (rows * n) / MIN_ELEMS_PER_SHARD;
        let by_rows = rows / self.min_rows_per_shard;
        let shards = workers.min(by_work).min(by_rows);
        if shards < 2 {
            return 0;
        }
        rows.div_ceil(shards)
    }

    /// Injected fault (if any) for each task of a `count`-task scatter,
    /// drawn from the installed plan against the monotone task counter.
    /// `None` (the overwhelmingly common case) costs one lock + one
    /// branch per wave.
    fn wave_faults(&self, count: usize) -> Option<Vec<InjectedFault>> {
        let plan = *lock_unpoisoned(&self.faults);
        if plan.is_none() {
            return None;
        }
        let base = self.fault_seq.fetch_add(count as u64, Ordering::SeqCst);
        Some(
            (0..count as u64)
                .map(|i| {
                    if plan.should_fault(FaultSite::WorkerPanic, base + i) {
                        InjectedFault::Panic
                    } else if plan.should_fault(FaultSite::WorkerSlow, base + i) {
                        InjectedFault::Slow
                    } else {
                        InjectedFault::None
                    }
                })
                .collect(),
        )
    }

    /// Fan `f(index, worker scratch)` over `0..count` on the pool,
    /// blocking until every index has run; `count < 2` (or a 1-worker
    /// pool) runs inline on the caller's scratch. Used by the fused
    /// attention kernel to batch B×H head-blocks through the same pool
    /// that shards softmax rows.
    ///
    /// Contract: `f` runs concurrently from worker threads, so everything
    /// it writes must be disjoint per index (the `Sync` bound covers the
    /// reads).
    ///
    /// The wave always completes: a panicking task (injected or genuine)
    /// is contained — inline or on a worker — and reported in the
    /// returned [`ScatterOutcome`] instead of unwinding into the
    /// submitter or poisoning the pool. Non-panicked indices are
    /// unaffected (disjoint outputs), so their results are bit-identical
    /// to a fault-free run.
    #[must_use = "panicked indices' output blocks are unwritten — check the outcome"]
    pub fn scatter<F>(&self, count: usize, scratch: &mut Scratch, f: &F) -> ScatterOutcome
    where
        F: Fn(usize, &mut Scratch) + Sync,
    {
        if self.pool.workers() <= 1 || count < 2 {
            return self.scatter_inline(count, scratch, f);
        }
        let mut outcome = ScatterOutcome::default();
        let faults = self.wave_faults(count);
        let fault_of =
            |i: usize| faults.as_ref().map_or(InjectedFault::None, |fs| fs[i]);
        self.parallel_batches.fetch_add(1, Ordering::Relaxed);
        unsafe fn trampoline<F: Fn(usize, &mut Scratch) + Sync>(
            ctx: *const (),
            index: usize,
            scratch: &mut Scratch,
        ) {
            // SAFETY: `ctx` is the `&F` the submitter holds alive until
            // every `done` signal has been received.
            let f = unsafe { &*(ctx as *const F) };
            f(index, scratch);
        }
        let ctx = f as *const F as *const ();
        let (done_tx, done_rx) = mpsc::channel::<JobDone>();
        for index in 0..count {
            self.pool.submit(Job {
                task: Task::Scatter { ctx, call: trampoline::<F>, index },
                tag: index,
                fault: fault_of(index),
                done: done_tx.clone(),
            });
        }
        drop(done_tx);
        for _ in 0..count {
            // cannot hang: workers signal done for every job, panic or
            // not (recv errors only if the pool lost every worker, which
            // containment rules out)
            let d = done_rx
                .recv()
                .expect("softmax worker pool: worker died mid-scatter");
            if !d.ok {
                outcome.panicked.push(d.tag);
            }
        }
        outcome.panicked.sort_unstable();
        outcome
    }

    /// Run a `count`-index wave inline on the caller's thread — for
    /// submitters whose own accounting decided fan-out isn't worth a pool
    /// wake (the decode wave layer's `wave_stays_inline`) — with the SAME
    /// fault injection and panic containment as a pooled
    /// [`Self::scatter`] wave, so the failure-domain contract (and an
    /// installed fault schedule's task indexing) does not depend on the
    /// inline-vs-pool decision.
    #[must_use = "panicked indices' output blocks are unwritten — check the outcome"]
    pub fn scatter_inline<F>(&self, count: usize, scratch: &mut Scratch, f: &F) -> ScatterOutcome
    where
        F: Fn(usize, &mut Scratch) + Sync,
    {
        let mut outcome = ScatterOutcome::default();
        if count == 0 {
            return outcome;
        }
        let faults = self.wave_faults(count);
        let fault_of =
            |i: usize| faults.as_ref().map_or(InjectedFault::None, |fs| fs[i]);
        for i in 0..count {
            let ok = match fault_of(i) {
                // injected inline panic: same containment verdict as a
                // worker would report, without the unwind
                InjectedFault::Panic => false,
                _ => catch_unwind(AssertUnwindSafe(|| f(i, scratch))).is_ok(),
            };
            if !ok {
                outcome.panicked.push(i);
            }
        }
        outcome
    }
}

impl SoftmaxEngine for ParSoftmax {
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        let rows = x.len() / n;
        let block = self.shard_rows(rows, n);
        if block == 0 {
            return self.inner.run_with(x, n, out, scratch);
        }
        self.parallel_batches.fetch_add(1, Ordering::Relaxed);
        let chunk = block * n;
        let (done_tx, done_rx) = mpsc::channel::<JobDone>();
        let mut sent = 0usize;
        for (xc, oc) in x.chunks(chunk).zip(out.chunks_mut(chunk)) {
            self.pool.submit(Job {
                task: Task::Softmax {
                    x: xc.as_ptr(),
                    out: oc.as_mut_ptr(),
                    len: xc.len(),
                    n,
                    engine: self.inner.clone(),
                },
                tag: sent,
                fault: InjectedFault::None,
                done: done_tx.clone(),
            });
            sent += 1;
        }
        drop(done_tx);
        let mut panicked = false;
        for _ in 0..sent {
            // cannot hang: workers always signal, panic or not; by the
            // time all `sent` signals arrive every job has terminated, so
            // unwinding below cannot race the buffers
            let d = done_rx
                .recv()
                .expect("softmax worker pool: worker died mid-batch");
            panicked |= !d.ok;
        }
        // a softmax shard has no per-session failure domain (the batch is
        // one caller's buffer) — re-raise in the submitter after draining
        assert!(!panicked, "softmax worker shard panicked");
    }

    /// i8 batches shard exactly like f32 batches (same inline policy),
    /// each worker running the wrapped engine's integer fast path.
    fn run_i8_with(&self, x: &[i8], n: usize, row: IntRow, out: &mut [f32], scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        let rows = x.len() / n;
        let block = self.shard_rows(rows, n);
        if block == 0 {
            return self.inner.run_i8_with(x, n, row, out, scratch);
        }
        self.parallel_batches.fetch_add(1, Ordering::Relaxed);
        let chunk = block * n;
        let (done_tx, done_rx) = mpsc::channel::<JobDone>();
        let mut sent = 0usize;
        for (xc, oc) in x.chunks(chunk).zip(out.chunks_mut(chunk)) {
            self.pool.submit(Job {
                task: Task::SoftmaxI8 {
                    x: xc.as_ptr(),
                    out: oc.as_mut_ptr(),
                    len: xc.len(),
                    n,
                    row,
                    engine: self.inner.clone(),
                },
                tag: sent,
                fault: InjectedFault::None,
                done: done_tx.clone(),
            });
            sent += 1;
        }
        drop(done_tx);
        let mut panicked = false;
        for _ in 0..sent {
            let d = done_rx
                .recv()
                .expect("softmax worker pool: worker died mid-batch");
            panicked |= !d.ok;
        }
        assert!(!panicked, "softmax worker shard panicked");
    }

    fn name(&self) -> &'static str {
        "par"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::Precision;
    use crate::softmax::{engine, Mode};
    use crate::testkit::Rng;

    fn par(mode: Mode, prec: Precision, workers: usize) -> ParSoftmax {
        ParSoftmax::with_workers(Arc::from(engine(mode, prec, None)), workers)
    }

    #[test]
    fn shards_cover_all_rows_exactly() {
        let mut rng = Rng::new(7);
        let n = 64;
        let rows = 129; // not a multiple of any worker count
        let x = rng.normal_vec(rows * n, 2.0);
        let p = par(Mode::Rexp, Precision::Uint8, 4);
        let seq = engine(Mode::Rexp, Precision::Uint8, None);
        assert_eq!(p.apply(&x, n), seq.apply(&x, n));
        assert_eq!(p.parallel_batches(), 1);
    }

    #[test]
    fn tiny_batches_run_inline() {
        let mut rng = Rng::new(8);
        let x = rng.normal_vec(2 * 8, 1.0);
        let p = par(Mode::Lut2d, Precision::Uint8, 4);
        let seq = engine(Mode::Lut2d, Precision::Uint8, None);
        assert_eq!(p.apply(&x, 8), seq.apply(&x, 8));
        assert_eq!(p.parallel_batches(), 0, "16 elements must not fan out");
    }

    #[test]
    fn pool_survives_many_batches() {
        let mut rng = Rng::new(9);
        let p = par(Mode::Rexp, Precision::Int16, 3);
        let seq = engine(Mode::Rexp, Precision::Int16, None);
        for _ in 0..20 {
            let n = rng.usize(1, 96);
            let rows = rng.usize(1, 200);
            let x = rng.normal_vec(rows * n, 2.0);
            assert_eq!(p.apply(&x, n), seq.apply(&x, n));
        }
    }

    #[test]
    fn single_worker_pool_is_sequential() {
        let mut rng = Rng::new(10);
        let x = rng.normal_vec(64 * 64, 2.0);
        let p = par(Mode::Exact, Precision::Uint8, 1);
        let seq = engine(Mode::Exact, Precision::Uint8, None);
        assert_eq!(p.apply(&x, 64), seq.apply(&x, 64));
        assert_eq!(p.parallel_batches(), 0);
    }

    #[test]
    fn few_rows_stay_inline_however_wide() {
        // 3 rows x 4096 elements clears the element threshold but not the
        // row threshold: tiny batches must not pay a pool wake
        let mut rng = Rng::new(11);
        let n = 4096;
        let x = rng.normal_vec(3 * n, 2.0);
        let p = par(Mode::Rexp, Precision::Uint8, 4);
        let seq = engine(Mode::Rexp, Precision::Uint8, None);
        assert_eq!(p.apply(&x, n), seq.apply(&x, n));
        assert_eq!(p.parallel_batches(), 0, "3 rows must run inline");
    }

    #[test]
    fn policy_threshold_tunes_inline_vs_pool() {
        // default policy keeps a 3-row batch inline however wide; a
        // min_rows_per_shard of 1 lets the same batch fan out, == exact
        let mut rng = Rng::new(13);
        let n = 4096;
        let x = rng.normal_vec(3 * n, 2.0);
        let seq = engine(Mode::Rexp, Precision::Uint8, None);
        let dflt = par(Mode::Rexp, Precision::Uint8, 4);
        assert_eq!(dflt.min_rows_per_shard(), DEFAULT_MIN_ROWS_PER_SHARD);
        assert_eq!(dflt.apply(&x, n), seq.apply(&x, n));
        assert_eq!(dflt.parallel_batches(), 0, "default policy stays inline");
        let eager =
            ParSoftmax::with_policy(Arc::from(engine(Mode::Rexp, Precision::Uint8, None)), 4, 1);
        assert_eq!(eager.min_rows_per_shard(), 1);
        assert_eq!(eager.apply(&x, n), seq.apply(&x, n));
        assert_eq!(eager.parallel_batches(), 1, "threshold 1 must fan 3 rows out");
        // 0 clamps to 1
        let clamped =
            ParSoftmax::with_policy(Arc::from(engine(Mode::Rexp, Precision::Uint8, None)), 2, 0);
        assert_eq!(clamped.min_rows_per_shard(), 1);
    }

    #[test]
    fn i8_batches_shard_like_f32_batches() {
        let mut rng = Rng::new(12);
        let n = 128;
        let row = crate::softmax::IntRow::new(0.5, 3);
        let x: Vec<i8> = (0..256 * n).map(|_| rng.int(-128, 127) as i8).collect();
        let p = par(Mode::Lut2d, Precision::Uint8, 4);
        let seq = engine(Mode::Lut2d, Precision::Uint8, None);
        assert_eq!(p.apply_i8(&x, n, row), seq.apply_i8(&x, n, row));
        assert_eq!(p.parallel_batches(), 1, "32k i8 elements must fan out");
        // and a tiny i8 batch stays inline
        assert_eq!(p.apply_i8(&x[..2 * n], n, row), seq.apply_i8(&x[..2 * n], n, row));
        assert_eq!(p.parallel_batches(), 1);
    }

    #[test]
    fn scatter_runs_every_index_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let p = par(Mode::Rexp, Precision::Uint8, 4);
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        let mut scratch = Scratch::new();
        let out = p.scatter(hits.len(), &mut scratch, &|i, _s| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(out.is_ok());
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        // single-index scatter runs inline on the caller's scratch
        let one = AtomicUsize::new(0);
        let out = p.scatter(1, &mut scratch, &|i, _s| {
            assert_eq!(i, 0);
            one.fetch_add(1, Ordering::SeqCst);
        });
        assert!(out.is_ok());
        assert_eq!(one.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scatter_contains_genuine_task_panics() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        crate::faults::silence_injected_panics();
        let p = par(Mode::Rexp, Precision::Uint8, 4);
        let hits: Vec<AtomicUsize> = (0..24).map(|_| AtomicUsize::new(0)).collect();
        let mut scratch = Scratch::new();
        let out = p.scatter(hits.len(), &mut scratch, &|i, _s| {
            if i == 5 || i == 17 {
                panic!("{}: test task bomb", crate::faults::INJECTED_PANIC);
            }
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(out.panicked(), &[5, 17]);
        assert!(!out.is_ok());
        for (i, h) in hits.iter().enumerate() {
            let want = usize::from(i != 5 && i != 17);
            assert_eq!(h.load(Ordering::SeqCst), want, "index {i}");
        }
        // the pool must stay fully usable: no poisoned mutex, no dead
        // workers — the next wave and the next softmax batch both succeed
        let out = p.scatter(hits.len(), &mut scratch, &|i, _s| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(out.is_ok());
        let mut rng = Rng::new(21);
        let x = rng.normal_vec(64 * 64, 2.0);
        let seq = engine(Mode::Rexp, Precision::Uint8, None);
        assert_eq!(p.apply(&x, 64), seq.apply(&x, 64));
    }

    #[test]
    fn scatter_inline_arm_contains_panics_too() {
        crate::faults::silence_injected_panics();
        // single-worker pool: everything runs inline in the submitter
        let p = par(Mode::Rexp, Precision::Uint8, 1);
        let mut scratch = Scratch::new();
        let out = p.scatter(3, &mut scratch, &|i, _s| {
            if i == 1 {
                panic!("{}: inline bomb", crate::faults::INJECTED_PANIC);
            }
        });
        assert_eq!(out.panicked(), &[1]);
        let out = p.scatter(3, &mut scratch, &|_i, _s| {});
        assert!(out.is_ok());
    }

    #[test]
    fn injected_panic_plan_is_replayable_and_resettable() {
        use crate::faults::{FaultPlan, FaultSite};
        use std::sync::atomic::{AtomicUsize, Ordering};
        crate::faults::silence_injected_panics();
        let p = par(Mode::Rexp, Precision::Uint8, 4);
        let plan = FaultPlan::none().with_seed(77).with(FaultSite::WorkerPanic, 4);
        p.set_fault_plan(plan);
        assert_eq!(p.fault_plan(), plan);
        let run_wave = |p: &ParSoftmax| -> Vec<usize> {
            let hits: Vec<AtomicUsize> = (0..32).map(|_| AtomicUsize::new(0)).collect();
            let mut scratch = Scratch::new();
            let out = p.scatter(hits.len(), &mut scratch, &|i, _s| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            // injected panics skip the task body: panicked ⟺ not run
            for (i, h) in hits.iter().enumerate() {
                let ran = h.load(Ordering::SeqCst) == 1;
                assert_eq!(ran, !out.panicked().contains(&i), "index {i}");
            }
            out.panicked().to_vec()
        };
        let first = run_wave(&p);
        assert!(!first.is_empty(), "denominator 4 over 32 tasks must fire");
        let second = run_wave(&p);
        // the task counter advanced, so the second wave draws a different
        // slice of the schedule; resetting the plan replays from index 0
        p.set_fault_plan(plan);
        let replay = run_wave(&p);
        assert_eq!(first, replay, "set_fault_plan must reset the schedule");
        drop(second);
        // disabling the plan stops injection entirely
        p.set_fault_plan(FaultPlan::none());
        let mut scratch = Scratch::new();
        assert!(p.scatter(32, &mut scratch, &|_i, _s| {}).is_ok());
    }

    #[test]
    fn injected_slow_tasks_never_perturb_bytes() {
        use crate::faults::{FaultPlan, FaultSite};
        let mut rng = Rng::new(31);
        let p = par(Mode::Lut2d, Precision::Uint8, 4);
        let seq = engine(Mode::Lut2d, Precision::Uint8, None);
        p.set_fault_plan(FaultPlan::none().with_seed(9).with(FaultSite::WorkerSlow, 2));
        let n = 64;
        let x = rng.normal_vec(64 * n, 2.0);
        // scatter a per-row wave under heavy slow-injection: completion
        // order shuffles, bytes must not
        let mut out = vec![0.0f32; x.len()];
        let mut scratch = Scratch::new();
        let cell = std::sync::Mutex::new(&mut out);
        let outcome = p.scatter(64, &mut scratch, &|i, s| {
            let mut rowbuf = vec![0.0f32; n];
            seq.run_with(&x[i * n..(i + 1) * n], n, &mut rowbuf, s);
            cell.lock().unwrap()[i * n..(i + 1) * n].copy_from_slice(&rowbuf);
        });
        assert!(outcome.is_ok(), "slow faults must not fail tasks");
        drop(cell);
        assert_eq!(out, seq.apply(&x, n));
    }
}
