//! Numerically-stable exact softmax — the fp32 reference datapath
//! (requires the divider the paper's designs eliminate).

use super::{debug_check_shape, row_max, Scratch, SoftmaxEngine};

pub struct SoftmaxExact;

impl SoftmaxEngine for SoftmaxExact {
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], _scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = row_max(row);
            let mut sum = 0.0f32;
            for (o, &v) in orow.iter_mut().zip(row) {
                let e = (v - m).exp();
                *o = e;
                sum += e;
            }
            let inv = 1.0 / sum;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    }

    fn name(&self) -> &'static str {
        "exact"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_sum_to_one() {
        let x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let out = SoftmaxExact.apply(&x, 3);
        for row in out.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "sum {s}");
        }
    }

    #[test]
    fn shift_invariant() {
        let x = vec![0.5, -0.25, 2.0, 1.5];
        let shifted: Vec<f32> = x.iter().map(|v| v + 1000.0).collect();
        let a = SoftmaxExact.apply(&x, 4);
        let b = SoftmaxExact.apply(&shifted, 4);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-6);
        }
    }

    #[test]
    fn known_values() {
        let out = SoftmaxExact.apply(&[0.0, 0.0], 2);
        assert!((out[0] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn extreme_logits_stable() {
        let out = SoftmaxExact.apply(&[1.0e4, 1.0e4 - 1.0, 0.0], 3);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
