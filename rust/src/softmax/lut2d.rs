//! 2D-LUT softmax (paper §4.2, Algorithm 2) — bit-exact integer pipeline.
//!
//! Per row (matches `kernels/ref.py::lut2d_pipeline`):
//!   1. `d = max(x) - x`                          (f32)
//!   2. `k = clamp(trunc(d * 10), 0, len-1)`      — LUT_exp index
//!   3. `e = LUT_exp[k]`;  `s = sum(e)`
//!   4. `row = clamp((e*10 + qmax/2) / qmax, 0, 10)`   (rounding divide by
//!      the constant qmax — a multiply+shift in HW)
//!   5. `col = clamp(s >> w, 1, cols)`
//!   6. `out = LUT_sigma[row][col-1] * (1/qmax)`
//!
//! No divider and no data-dependent multiplier: the quantized path is
//! wiring + adds (the paper's headline HW property).
//!
//! §Perf: the fused [`SoftmaxEngine::run_with`] keeps the table ADDRESS
//! `k` from pass 1 in the caller's [`Scratch`], resolves the column once
//! per row, and — when the row is at least as long as `LUT_exp` — hoists
//! the `row-decode → sigma → dequant` chain into a per-row f32 table
//! (`deq[k] = sigma[row[k]][col-1] · 1/qmax`), making pass 2 one
//! branchless gather per element. Short rows gather the chain directly.
//! Both paths are bit-identical to the old three-pass loop; the third
//! f32 pass and its `thread_local!` scratch are gone.

use super::{
    debug_check_shape, i8_row_max, pass1_i8_mapped, pass1_i8_unit, row_max, IntMap, IntRow,
    Scratch, SoftmaxEngine,
};
use crate::lut::{lut2d_tables, Lut2dTables, Precision, EXP_STEP};

pub struct SoftmaxLut2d {
    tables: Lut2dTables,
    w: u32,
    inv_qmax: f32,
}

impl SoftmaxLut2d {
    pub fn new(prec: Precision) -> Self {
        Self::with_tables(lut2d_tables(prec, None))
    }

    pub fn with_tables(tables: Lut2dTables) -> Self {
        let w = tables.prec.w();
        let qmax = tables.prec.qmax();
        Self { tables, w, inv_qmax: 1.0 / qmax as f32 }
    }

    pub fn tables(&self) -> &Lut2dTables {
        &self.tables
    }

    pub fn run_int(&self, x: &[f32], n: usize, out: &mut [i32]) {
        let exp_t = &self.tables.exp;
        let row_t = &self.tables.row;
        let last = (exp_t.len() - 1) as i32;
        let cols = self.tables.cols as i32;
        for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = row_max(row);
            let mut s: i32 = 0;
            for (o, &v) in orow.iter_mut().zip(row) {
                // mirror jnp: (d * 10.0f32) truncated toward zero; keep the
                // table ADDRESS k so phase 2 is a pure row_t read
                let k = (((m - v) * 10.0) as i32).clamp(0, last);
                s += exp_t[k as usize];
                *o = k;
            }
            let col = (s >> self.w).clamp(1, cols) as usize;
            for o in orow.iter_mut() {
                let r = row_t[*o as usize] as usize;
                *o = self.tables.sigma_at(r, col);
            }
        }
    }

    /// The [`IntMap`] of the i8 path: one quantization step spans
    /// `step / EXP_STEP` LUT_exp bins (the engine owns its 0.1 bin width,
    /// so callers hand over plain logit units — a step of exactly 0.1 is
    /// the aligned `idx = clamp(m_q - v_q, 0, last)` case here).
    pub(crate) fn int_map(&self, step: f32) -> IntMap {
        // 1/EXP_STEP is exactly 10.0 in f64; multiplying (not dividing)
        // keeps dyadic steps bit-exact with the f32 datapath's `d * 10.0`
        IntMap::new(step * (1.0 / EXP_STEP) as f32, (self.tables.exp.len() - 1) as i32)
    }

    /// LUT_sigma column select for an integer row sum.
    #[inline]
    pub(crate) fn col_for(&self, s: i32) -> usize {
        (s >> self.w).clamp(1, self.tables.cols as i32) as usize
    }

    /// Integer-stage output of the i8 fast path — mirrors
    /// [`SoftmaxLut2d::run_int`] with integer ingestion.
    pub fn run_i8_int(&self, x: &[i8], n: usize, row: IntRow, out: &mut [i32]) {
        let exp_t = &self.tables.exp;
        let row_t = &self.tables.row;
        let map = self.int_map(row.scale);
        for (rowq, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = i8_row_max(rowq) as i32;
            let mut s: i32 = 0;
            for (o, &v) in orow.iter_mut().zip(rowq) {
                let k = map.index(m - v as i32);
                s += exp_t[k as usize];
                *o = k;
            }
            let col = self.col_for(s);
            for o in orow.iter_mut() {
                let r = row_t[*o as usize] as usize;
                *o = self.tables.sigma_at(r, col);
            }
        }
    }
}

impl SoftmaxEngine for SoftmaxLut2d {
    fn run_with(&self, x: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        let exp_t = &self.tables.exp;
        let row_t = &self.tables.row;
        let last = (exp_t.len() - 1) as i32;
        let cols = self.tables.cols as i32;
        let hoist = n >= exp_t.len();
        let (idx, deq) = scratch.borrow2(n, exp_t.len());
        for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = row_max(row);
            let mut s: i32 = 0;
            for (slot, &v) in idx.iter_mut().zip(row) {
                let k = (((m - v) * 10.0) as i32).clamp(0, last);
                s += exp_t[k as usize];
                *slot = k;
            }
            let col = (s >> self.w).clamp(1, cols) as usize;
            if hoist {
                // f32-mirrored row of LUT_sigma for this column: resolve the
                // row-decode + sigma read + dequant once per table ENTRY
                for (d, &r) in deq.iter_mut().zip(row_t.iter()) {
                    *d = self.tables.sigma_at(r as usize, col) as f32 * self.inv_qmax;
                }
                for (o, &k) in orow.iter_mut().zip(idx.iter()) {
                    *o = deq[k as usize];
                }
            } else {
                for (o, &k) in orow.iter_mut().zip(idx.iter()) {
                    let r = row_t[k as usize] as usize;
                    *o = self.tables.sigma_at(r, col) as f32 * self.inv_qmax;
                }
            }
        }
    }

    /// i8 fast path: integer max scan + the branchless `chunks_exact(8)`
    /// pass-1 blocks (see the module docs of [`crate::softmax`]); pass 2
    /// is the same fused `row-decode → sigma → dequant` chain as the f32
    /// path, so output == `run_i8_int * 1/qmax` bit-exactly.
    fn run_i8_with(&self, x: &[i8], n: usize, row: IntRow, out: &mut [f32], scratch: &mut Scratch) {
        debug_check_shape(x, n, out);
        if x.is_empty() {
            return;
        }
        let exp_t = &self.tables.exp;
        let row_t = &self.tables.row;
        let map = self.int_map(row.scale);
        let unit = map.is_unit();
        let hoist = n >= exp_t.len();
        let (idx, deq) = scratch.borrow2(n, exp_t.len());
        for (rowq, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            let m = i8_row_max(rowq) as i32;
            let s = if unit {
                pass1_i8_unit(rowq, m, map.last(), exp_t, idx)
            } else {
                pass1_i8_mapped(rowq, m, map, exp_t, idx)
            };
            let col = self.col_for(s);
            if hoist {
                for (d, &r) in deq.iter_mut().zip(row_t.iter()) {
                    *d = self.tables.sigma_at(r as usize, col) as f32 * self.inv_qmax;
                }
                for (o, &k) in orow.iter_mut().zip(idx.iter()) {
                    *o = deq[k as usize];
                }
            } else {
                for (o, &k) in orow.iter_mut().zip(idx.iter()) {
                    let r = row_t[k as usize] as usize;
                    *o = self.tables.sigma_at(r, col) as f32 * self.inv_qmax;
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "lut2d"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::{SoftmaxEngine, SoftmaxExact};
    use crate::testkit;

    #[test]
    fn bounded_and_quantized() {
        testkit::check("lut2d bounded", 30, |rng| {
            let n = rng.usize(2, 48);
            let x = rng.normal_vec(n * 4, 3.0);
            let e = SoftmaxLut2d::new(Precision::Uint8);
            for v in e.apply(&x, n) {
                assert!((0.0..=1.0).contains(&v));
                let grid = v * 255.0;
                assert!((grid - grid.round()).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn winner_row_reads_high_sigma() {
        // a dominant logit with a small sum lands in the top-right of the
        // table: row 10, col 1 -> sigma = qmax
        let e = SoftmaxLut2d::new(Precision::Uint8);
        let out = e.apply(&[10.0, -10.0, -10.0], 3);
        assert!((out[0] - 1.0).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn close_to_exact_at_int16() {
        let mut rng = testkit::Rng::new(11);
        let n = 32;
        let x = rng.normal_vec(128 * n, 1.5);
        let approx = SoftmaxLut2d::new(Precision::Int16).apply(&x, n);
        let exact = SoftmaxExact.apply(&x, n);
        let mae: f32 = approx
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / approx.len() as f32;
        assert!(mae < 0.05, "mae {mae}");
    }

    #[test]
    fn col_saturates_for_long_rows() {
        // sum(e^x) beyond the table's max column must saturate, not panic
        // (the Fig. 4 mechanism for DETR+DC5)
        let e = SoftmaxLut2d::new(Precision::Uint8);
        let x = vec![0.0f32; 100]; // sum e^x = 100 > 60 cols
        let out = e.apply(&x, 100);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn shift_invariant_exactly() {
        let mut rng = testkit::Rng::new(5);
        let x = rng.normal_vec(24, 2.0);
        let shifted: Vec<f32> = x.iter().map(|v| v + 12.0).collect();
        let e = SoftmaxLut2d::new(Precision::Int16);
        assert_eq!(e.apply(&x, 24), e.apply(&shifted, 24));
    }

    #[test]
    fn i8_fast_path_matches_its_integer_stage() {
        // unit (scale = EXP_STEP) and general maps, hoisted and direct
        // pass 2: run_i8_with must equal run_i8_int * 1/qmax exactly
        testkit::check("lut2d i8 fused dequant", 20, |rng| {
            let prec = *rng.choice(&crate::lut::ALL_PRECISIONS);
            let e = SoftmaxLut2d::new(prec);
            let table_len = e.tables().exp.len();
            let n = rng.usize(1, table_len + table_len / 2 + 2);
            let rows = rng.usize(1, 4);
            let row = IntRow::new(*rng.choice(&[0.1f32, 0.05, 0.13, 1.0]), rng.int(-9, 9) as i32);
            let x: Vec<i8> = (0..rows * n).map(|_| rng.int(-128, 127) as i8).collect();
            let mut ints = vec![0i32; x.len()];
            e.run_i8_int(&x, n, row, &mut ints);
            let inv = 1.0 / prec.qmax() as f32;
            let want: Vec<f32> = ints.iter().map(|&v| v as f32 * inv).collect();
            assert_eq!(e.apply_i8(&x, n, row), want);
        });
    }

    #[test]
    fn i8_aligned_step_is_one_bin_per_quant_unit() {
        // scale exactly EXP_STEP: the fixed-point map degenerates to the
        // clamp(m_q - v_q) wiring, one LUT_exp bin per quantization step
        let e = SoftmaxLut2d::new(Precision::Uint8);
        assert!(e.int_map(0.1).is_unit());
        assert!(!e.int_map(0.2).is_unit());
    }

    #[test]
    fn fused_gather_matches_integer_stage() {
        // hoisted (n >= LUT_exp len) and direct paths must both equal
        // sig_int * 1/qmax exactly
        testkit::check("lut2d fused dequant", 20, |rng| {
            let prec = *rng.choice(&crate::lut::ALL_PRECISIONS);
            let e = SoftmaxLut2d::new(prec);
            let table_len = e.tables().exp.len();
            let n = rng.usize(1, table_len + table_len / 2 + 2);
            let rows = rng.usize(1, 4);
            let x = rng.normal_vec(rows * n, 2.0);
            let mut ints = vec![0i32; x.len()];
            e.run_int(&x, n, &mut ints);
            let inv = 1.0 / prec.qmax() as f32;
            let want: Vec<f32> = ints.iter().map(|&v| v as f32 * inv).collect();
            assert_eq!(e.apply(&x, n), want);
        });
    }
}
