//! Workload generators for load tests and benches.
//!
//! Correctness datasets cross from python via LTB bundles (no mirror
//! drift); these generators only produce *shapes and arrival processes*
//! for serving experiments: random token sequences, scenes and Poisson
//! arrivals matching the evaluation distributions.

use crate::attention::{AttnMask, AttnShape};
use crate::runtime::Tensor;
use crate::testkit::Rng;

/// Token conventions shared with `python/compile/data.py`.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const FIRST_TOKEN: i32 = 4;

/// A padded random source sentence (content tokens + EOS, PAD right).
pub fn random_src_row(rng: &mut Rng, max_len: usize, vocab: i32) -> Vec<i32> {
    let n = rng.usize(4, (max_len - 1).min(14));
    let mut row = vec![PAD; max_len];
    for slot in row.iter_mut().take(n) {
        *slot = rng.int(FIRST_TOKEN as i64, vocab as i64 - 1) as i32;
    }
    row[n] = EOS;
    row
}

/// A batch of random source rows as an i32 tensor (batch, max_len).
pub fn random_src_batch(rng: &mut Rng, batch: usize, max_len: usize, vocab: i32) -> Tensor {
    let mut data = Vec::with_capacity(batch * max_len);
    for _ in 0..batch {
        data.extend(random_src_row(rng, max_len, vocab));
    }
    Tensor::i32(vec![batch, max_len], data)
}

/// A random classification row (BOS + content, PAD right).
pub fn random_cls_row(rng: &mut Rng, max_len: usize, vocab: i32) -> Vec<i32> {
    let n = rng.usize(6, max_len - 2);
    let mut row = vec![PAD; max_len];
    row[0] = BOS;
    for slot in row.iter_mut().take(n + 1).skip(1) {
        *slot = rng.int(FIRST_TOKEN as i64, vocab as i64 - 1) as i32;
    }
    row
}

/// A random scene image tensor (H, W, C) with a colored rectangle.
pub fn random_image(rng: &mut Rng, size: usize, channels: usize) -> Tensor {
    let mut data = vec![0.5f32; size * size * channels];
    for v in data.iter_mut() {
        *v += rng.normal() as f32 * 0.08;
    }
    let w = rng.usize(size / 8, size / 2);
    let h = rng.usize(size / 8, size / 2);
    let x0 = rng.usize(0, size - w);
    let y0 = rng.usize(0, size - h);
    let cls = rng.usize(0, 2);
    let palette = [[0.9f32, 0.15, 0.1], [0.1, 0.85, 0.2], [0.15, 0.2, 0.95]];
    for y in y0..y0 + h {
        for x in x0..x0 + w {
            for c in 0..channels.min(3) {
                data[(y * size + x) * channels + c] = palette[cls][c];
            }
        }
    }
    Tensor::f32(vec![size, size, channels], data)
}

/// Q/K/V activation tensors for an attention workload: `(B,H,L,d)` /
/// `(B,H,S,d)` f32, entries ~ N(0, scale) — normalized attention inputs,
/// the paper's operating point. Shared by the `attn/*` benches, the
/// `"attn:<mode>:<prec>"` route's load tests and the hwsim experiments.
pub fn attn_qkv(rng: &mut Rng, shape: &AttnShape, scale: f32) -> (Tensor, Tensor, Tensor) {
    let qdims = vec![shape.batch, shape.heads, shape.len_q, shape.d_head];
    let kdims = vec![shape.batch, shape.heads, shape.len_k, shape.d_head];
    (
        Tensor::f32(qdims, rng.normal_vec(shape.q_len(), scale)),
        Tensor::f32(kdims.clone(), rng.normal_vec(shape.kv_len(), scale)),
        Tensor::f32(kdims, rng.normal_vec(shape.kv_len(), scale)),
    )
}

/// A `B×H×L×S` attention score tensor flattened to `(B·H·L, S)` rows —
/// softmax-shaped load for benches and the standalone softmax routes.
/// Entries ~ N(0, 1), the distribution of `q·k/√d` under unit q/k.
pub fn attn_scores(rng: &mut Rng, shape: &AttnShape) -> Tensor {
    let rows = shape.heads_total() * shape.len_q;
    Tensor::f32(vec![rows, shape.len_k], rng.normal_vec(rows * shape.len_k, 1.0))
}

/// One streaming-decode step's activations: f32 q `(q_heads, d_head)` and
/// new-token k/v rows `(kv_heads, d_head)`, entries ~ N(0, scale) —
/// normalized decode inputs, the paper's operating point. Shared by the
/// `decode/*` benches, the `"decode:<mode>:<prec>[:gG]"` route's load
/// tests and the hwsim decode experiments.
pub fn decode_qkv_step(
    rng: &mut Rng,
    q_heads: usize,
    kv_heads: usize,
    d_head: usize,
    scale: f32,
) -> (Tensor, Tensor, Tensor) {
    (
        Tensor::f32(vec![q_heads, d_head], rng.normal_vec(q_heads * d_head, scale)),
        Tensor::f32(vec![kv_heads, d_head], rng.normal_vec(kv_heads * d_head, scale)),
        Tensor::f32(vec![kv_heads, d_head], rng.normal_vec(kv_heads * d_head, scale)),
    )
}

/// A chunked-prefill block of `tokens` steps: f32 q `(T', q_heads,
/// d_head)` and k/v `(T', kv_heads, d_head)`, entries ~ N(0, scale) —
/// the prompt-ingest payload of the decode route
/// (`Payload::DecodePrefill`), shaped so row `t` is exactly one
/// [`decode_qkv_step`]'s worth of activations.
pub fn decode_prefill_chunk(
    rng: &mut Rng,
    tokens: usize,
    q_heads: usize,
    kv_heads: usize,
    d_head: usize,
    scale: f32,
) -> (Tensor, Tensor, Tensor) {
    (
        Tensor::f32(
            vec![tokens, q_heads, d_head],
            rng.normal_vec(tokens * q_heads * d_head, scale),
        ),
        Tensor::f32(
            vec![tokens, kv_heads, d_head],
            rng.normal_vec(tokens * kv_heads * d_head, scale),
        ),
        Tensor::f32(
            vec![tokens, kv_heads, d_head],
            rng.normal_vec(tokens * kv_heads * d_head, scale),
        ),
    )
}

/// A multi-sequence decode trace: per-session generation lengths in
/// `[min_steps, max_steps]` — the shape of a serving run where sessions
/// open, stream that many steps, and close.
pub fn decode_session_lens(
    rng: &mut Rng,
    sessions: usize,
    min_steps: usize,
    max_steps: usize,
) -> Vec<usize> {
    (0..sessions).map(|_| rng.usize(min_steps, max_steps)).collect()
}

/// Random per-batch valid key prefix lengths in `[1, len_k]` (PAD masks).
pub fn attn_pad_lens(rng: &mut Rng, batch: usize, len_k: usize) -> Vec<usize> {
    (0..batch).map(|_| rng.usize(1, len_k)).collect()
}

/// A random mask of any of the three kinds, PAD lengths included.
pub fn attn_mask(rng: &mut Rng, shape: &AttnShape) -> AttnMask {
    match rng.usize(0, 2) {
        0 => AttnMask::Dense,
        1 => AttnMask::Causal,
        _ => AttnMask::Padding(attn_pad_lens(rng, shape.batch, shape.len_k)),
    }
}

/// Poisson inter-arrival gaps (in microseconds) for open-loop load tests.
pub fn poisson_arrivals_us(rng: &mut Rng, count: usize, rate_per_sec: f64) -> Vec<u64> {
    let mean_us = 1e6 / rate_per_sec;
    (0..count)
        .map(|_| {
            let u = rng.f64().max(1e-12);
            (-u.ln() * mean_us) as u64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn src_rows_are_well_formed() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let row = random_src_row(&mut rng, 20, 64);
            assert_eq!(row.len(), 20);
            let eos = row.iter().position(|&t| t == EOS).expect("has EOS");
            assert!(row[..eos].iter().all(|&t| t >= FIRST_TOKEN));
            assert!(row[eos + 1..].iter().all(|&t| t == PAD));
        }
    }

    #[test]
    fn batch_tensor_shape() {
        let mut rng = Rng::new(2);
        let t = random_src_batch(&mut rng, 8, 20, 64);
        assert_eq!(t.dims, vec![8, 20]);
    }

    #[test]
    fn image_values_bounded() {
        let mut rng = Rng::new(3);
        let t = random_image(&mut rng, 32, 3);
        assert_eq!(t.dims, vec![32, 32, 3]);
        let v = t.as_f32().unwrap();
        assert!(v.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn attn_generators_are_well_shaped() {
        let mut rng = Rng::new(9);
        let shape = AttnShape { batch: 2, heads: 3, len_q: 8, len_k: 12, d_head: 4 };
        let (q, k, v) = attn_qkv(&mut rng, &shape, 1.0);
        assert_eq!(q.dims, vec![2, 3, 8, 4]);
        assert_eq!(k.dims, vec![2, 3, 12, 4]);
        assert_eq!(v.dims, vec![2, 3, 12, 4]);
        assert_eq!(q.len(), shape.q_len());
        let scores = attn_scores(&mut rng, &shape);
        assert_eq!(scores.dims, vec![2 * 3 * 8, 12]);
        let lens = attn_pad_lens(&mut rng, 50, 12);
        assert!(lens.iter().all(|&l| (1..=12).contains(&l)));
        for _ in 0..20 {
            match attn_mask(&mut rng, &shape) {
                AttnMask::Padding(lens) => assert_eq!(lens.len(), 2),
                AttnMask::Dense | AttnMask::Causal => {}
            }
        }
    }

    #[test]
    fn decode_generators_are_well_shaped() {
        let mut rng = Rng::new(10);
        let (q, k, v) = decode_qkv_step(&mut rng, 8, 2, 64, 1.0);
        assert_eq!(q.dims, vec![8, 64]);
        assert_eq!(k.dims, vec![2, 64]);
        assert_eq!(v.dims, vec![2, 64]);
        assert!(q.as_f32().unwrap().iter().all(|x| x.is_finite()));
        let lens = decode_session_lens(&mut rng, 40, 3, 17);
        assert_eq!(lens.len(), 40);
        assert!(lens.iter().all(|&l| (3..=17).contains(&l)));
        let (q, k, v) = decode_prefill_chunk(&mut rng, 5, 8, 2, 64, 1.0);
        assert_eq!(q.dims, vec![5, 8, 64]);
        assert_eq!(k.dims, vec![5, 2, 64]);
        assert_eq!(v.dims, vec![5, 2, 64]);
        assert!(q.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn poisson_mean_roughly_matches_rate() {
        let mut rng = Rng::new(4);
        let gaps = poisson_arrivals_us(&mut rng, 5000, 1000.0); // 1k rps
        let mean = gaps.iter().sum::<u64>() as f64 / gaps.len() as f64;
        assert!((mean - 1000.0).abs() < 100.0, "mean {mean}");
    }
}
