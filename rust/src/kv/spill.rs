//! Evict-to-host KV spill: verbatim page copies with a checksum, so an
//! evicted session restores by **bit-exact copy-back** instead of replay.
//!
//! The paper's normalization premise (arXiv 2111.10770) is what makes
//! this trivial: the whole datapath lives in small integer LUT domains,
//! so a page's entire state is its `i8` K/V blocks, the per-page
//! [`Affine`] pair and the per-token K byte sums — plain bytes with no
//! device-resident derivation. A [`SpillStore`] therefore holds evicted
//! sessions' pages *verbatim* off-arena; restore pops fresh pages off the
//! free list and copies the blocks back, reproducing the arena state
//! bit-for-bit in O(pages) copies instead of the O(tokens) replay
//! recompute the PR 6 eviction path paid.
//!
//! # The fallback ladder
//!
//! Host copies can rot (and the chaos plan's
//! [`crate::faults::FaultSite::SpillCorrupt`] site simulates exactly
//! that), so every spilled session carries two independent encodings:
//!
//! 1. **pages** — the verbatim `[g][t][d]` blocks + byte sums + affines,
//!    guarded by a per-session FNV checksum over every byte;
//! 2. **replay rows** — the `[t][g][d]` row log the PR 6 restore used,
//!    replayable through [`KvPool::append_block`].
//!
//! Restore tries the checksummed copy-back first; a checksum mismatch
//! (or an injected `SpillCorrupt` hit) demotes to the replay log, which
//! rebuilds the same bytes token by token. Only when *both* encodings
//! are unusable does the session die — with a typed `Reply::Error` at
//! the serving layer, never a panic. `docs/RELIABILITY.md` documents the
//! ladder end to end.

use std::collections::HashMap;

use crate::quant::Affine;

use super::{HeadGroups, KvError, KvPool, KvSeq};

/// One spilled page, verbatim: the full K/V blocks (`[g][t][d]`
/// row-major, `page_elems` each), the byte-sum block (`[g][t]`,
/// `sum_elems`), the page's recorded affine pair, and the count of valid
/// tokens (full pages except the tail).
#[derive(Clone, Debug)]
pub struct SpilledPage {
    pub k: Vec<i8>,
    pub v: Vec<i8>,
    pub ksum: Vec<i32>,
    pub k_affine: Affine,
    pub v_affine: Affine,
    pub len: usize,
}

/// Everything one evicted session needs to come back bit-identical:
/// the verbatim pages (checksummed), plus the independent replay-row
/// fallback.
#[derive(Clone, Debug)]
pub struct SpilledSession {
    groups: HeadGroups,
    k_affine: Affine,
    v_affine: Affine,
    tokens: usize,
    pages: Vec<SpilledPage>,
    /// FNV-1a over every page byte + geometry (see [`Self::checksum_now`])
    checksum: u64,
    /// replay fallback: `[t][g][d]` K rows, exactly the PR 6 eviction log
    replay_k: Vec<i8>,
    /// replay fallback: `[t][g][d]` V rows
    replay_v: Vec<i8>,
}

impl SpilledSession {
    pub fn groups(&self) -> HeadGroups {
        self.groups
    }

    /// tokens resident when the session was spilled
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// pages a copy-back restore must allocate
    pub fn page_count(&self) -> usize {
        self.pages.len()
    }

    /// Recompute the checksum over the *current* page bytes. Equal to the
    /// stored checksum unless the host copy rotted since the spill.
    fn checksum_now(&self) -> u64 {
        // FNV-1a 64 over geometry, affines, then every page's bytes
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut step = |x: u64| {
            h = (h ^ x).wrapping_mul(0x100_0000_01B3);
        };
        step(self.tokens as u64);
        step(self.groups.q_heads() as u64);
        step(self.groups.kv_heads() as u64);
        step(self.k_affine.scale.to_bits() as u64);
        step(self.k_affine.zero_point as u64);
        step(self.v_affine.scale.to_bits() as u64);
        step(self.v_affine.zero_point as u64);
        for p in &self.pages {
            step(p.len as u64);
            step(p.k_affine.scale.to_bits() as u64);
            step(p.k_affine.zero_point as u64);
            step(p.v_affine.scale.to_bits() as u64);
            step(p.v_affine.zero_point as u64);
            for &b in p.k.iter().chain(&p.v) {
                step(b as u8 as u64);
            }
            for &s in &p.ksum {
                step(s as u32 as u64);
            }
        }
        h
    }

    /// `true` while the page copies still match their spill-time checksum.
    pub fn intact(&self) -> bool {
        self.checksum == self.checksum_now()
    }

    /// The replay-log fallback: `[t][g][d]` K and V rows, or `None` if
    /// the log has been wiped (the both-encodings-dead terminal case).
    pub fn replay_rows(&self) -> Option<(&[i8], &[i8])> {
        let gd = self.groups.kv_heads() * self.replay_row_width();
        let want = self.tokens * gd;
        if gd == 0 || self.replay_k.len() != want || self.replay_v.len() != want {
            return None;
        }
        Some((&self.replay_k, &self.replay_v))
    }

    /// `d_head` as implied by the replay log (0 when the log is empty).
    fn replay_row_width(&self) -> usize {
        if self.tokens == 0 {
            return 0;
        }
        self.replay_k.len() / (self.tokens * self.groups.kv_heads())
    }
}

/// Host-side store of spilled sessions, keyed by session id. One store
/// serves a whole [`KvPool`]; it owns no arena pages — everything in it
/// is plain host memory, cheap to move across a drain/restart boundary.
#[derive(Clone, Debug, Default)]
pub struct SpillStore {
    sessions: HashMap<u64, SpilledSession>,
    /// sessions drained while still open-but-unbound (no pages yet);
    /// re-adopted as `Unbound` on restart
    open: Vec<u64>,
}

impl SpillStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// spilled sessions currently held (excluding drained open-unbound)
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty() && self.open.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    pub fn session(&self, id: u64) -> Option<&SpilledSession> {
        self.sessions.get(&id)
    }

    /// Session ids in ascending order — deterministic iteration for
    /// drain reports and restart adoption.
    pub fn ids_sorted(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.sessions.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// total pages held across all spilled sessions
    pub fn pages_held(&self) -> usize {
        self.sessions.values().map(|s| s.pages.len()).sum()
    }

    /// total tokens held across all spilled sessions
    pub fn tokens_held(&self) -> usize {
        self.sessions.values().map(|s| s.tokens).sum()
    }

    /// Record a drained open-but-unbound session (no pages to move).
    pub fn note_open(&mut self, id: u64) {
        self.open.push(id);
    }

    /// Drained open-but-unbound session ids, ascending.
    pub fn open_sessions(&self) -> Vec<u64> {
        let mut ids = self.open.clone();
        ids.sort_unstable();
        ids
    }

    /// Move `seq`'s pages off-arena verbatim (and build the replay-row
    /// fallback), then return the pages to the pool's free list. Returns
    /// the number of pages moved. The inverse of
    /// [`Self::restore_copy_back`], bit-exact both ways.
    pub fn spill(&mut self, pool: &mut KvPool, id: u64, seq: KvSeq) -> usize {
        let cfg = *pool.config();
        let (g, d, ps) = (cfg.kv_heads, cfg.d_head, cfg.page_size);
        let tokens = seq.len();
        let mut pages = Vec::with_capacity(seq.pages().len());
        let mut replay_k = vec![0i8; tokens * g * d];
        let mut replay_v = vec![0i8; tokens * g * d];
        for (pi, &p) in seq.pages().iter().enumerate() {
            let in_page = seq.tokens_in_page(ps, pi);
            let base = p as usize * cfg.page_elems();
            let sbase = p as usize * cfg.sum_elems();
            let (k_affine, v_affine) = pool.page_affines(p);
            pages.push(SpilledPage {
                k: pool.k[base..base + cfg.page_elems()].to_vec(),
                v: pool.v[base..base + cfg.page_elems()].to_vec(),
                ksum: pool.ksum[sbase..sbase + cfg.sum_elems()].to_vec(),
                k_affine,
                v_affine,
                len: in_page,
            });
            // transpose the page's [g][t][d] blocks into [t][g][d] rows
            for gi in 0..g {
                let kb = pool.page_k(p, gi);
                let vb = pool.page_v(p, gi);
                for t in 0..in_page {
                    let row = ((pi * ps + t) * g + gi) * d;
                    replay_k[row..row + d].copy_from_slice(&kb[t * d..(t + 1) * d]);
                    replay_v[row..row + d].copy_from_slice(&vb[t * d..(t + 1) * d]);
                }
            }
        }
        let mut rec = SpilledSession {
            groups: *seq.groups(),
            k_affine: seq.k_affine(),
            v_affine: seq.v_affine(),
            tokens,
            pages,
            checksum: 0,
            replay_k,
            replay_v,
        };
        rec.checksum = rec.checksum_now();
        let moved = pool.close(seq);
        self.sessions.insert(id, rec);
        moved
    }

    /// Bit-exact copy-back: allocate the session's pages off the free
    /// list and write the spilled blocks verbatim. **Atomic** like
    /// [`KvPool::append_block`]: capacity (and one injected-fault draw)
    /// is checked up front; on `Err(Exhausted)` the store entry and the
    /// arena are untouched, so the caller can evict and retry. `None`
    /// means no record for `id` — the caller's bug surface, typed, not a
    /// panic. Callers should consult [`SpilledSession::intact`] first:
    /// copy-back of a rotted record would resurrect corrupt bytes.
    pub fn restore_copy_back(
        &mut self,
        pool: &mut KvPool,
        id: u64,
    ) -> Option<Result<KvSeq, KvError>> {
        let rec = self.sessions.remove(&id)?;
        let needed = rec.pages.len();
        if (needed > 0 && pool.alloc_faulted()) || needed > pool.free.len() {
            let err =
                KvError::Exhausted { pages: pool.cfg.pages, free_pages: pool.free.len() };
            self.sessions.insert(id, rec);
            return Some(Err(err));
        }
        let mut seq = KvSeq::new(rec.groups, rec.k_affine, rec.v_affine);
        for sp in &rec.pages {
            let p = pool.free.pop().expect("capacity reserved above") as usize;
            let base = p * pool.cfg.page_elems();
            pool.k[base..base + pool.cfg.page_elems()].copy_from_slice(&sp.k);
            pool.v[base..base + pool.cfg.page_elems()].copy_from_slice(&sp.v);
            let sbase = p * pool.cfg.sum_elems();
            pool.ksum[sbase..sbase + pool.cfg.sum_elems()].copy_from_slice(&sp.ksum);
            pool.k_aff[p] = sp.k_affine;
            pool.v_aff[p] = sp.v_affine;
            seq.pages.push(p as u32);
        }
        seq.len = rec.tokens;
        Some(Ok(seq))
    }

    /// Drop (and return) a session's spill record — after a successful
    /// replay-fallback restore, or when the session dies.
    pub fn remove(&mut self, id: u64) -> Option<SpilledSession> {
        self.sessions.remove(&id)
    }

    /// Chaos hook: rot the host copy. Flips one byte of the first page's
    /// K block (so [`SpilledSession::intact`] fails), and with
    /// `wipe_replay` also destroys the replay log — the terminal
    /// both-encodings-dead case. Returns `false` if `id` has no record
    /// (or no pages to rot).
    pub fn corrupt(&mut self, id: u64, wipe_replay: bool) -> bool {
        let Some(rec) = self.sessions.get_mut(&id) else {
            return false;
        };
        let Some(first) = rec.pages.first_mut() else {
            return false;
        };
        let Some(b) = first.k.first_mut() else {
            return false;
        };
        *b = b.wrapping_add(1);
        if wipe_replay {
            rec.replay_k.clear();
            rec.replay_v.clear();
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{FaultPlan, FaultSite};
    use crate::kv::KvConfig;
    use crate::testkit::Rng;

    fn pool4() -> KvPool {
        KvPool::new(KvConfig { pages: 4, page_size: 4, kv_heads: 2, d_head: 8 })
    }

    fn seq_with(pool: &mut KvPool, rng: &mut Rng, tokens: usize) -> KvSeq {
        let mut seq = KvSeq::new(
            HeadGroups::new(4, 2).unwrap(),
            Affine { scale: 0.5, zero_point: 3 },
            Affine { scale: 0.25, zero_point: -2 },
        );
        let n = pool.config().kv_heads * pool.config().d_head;
        for _ in 0..tokens {
            let k: Vec<i8> = (0..n).map(|_| rng.int(-128, 127) as i8).collect();
            let v: Vec<i8> = (0..n).map(|_| rng.int(-128, 127) as i8).collect();
            pool.append(&mut seq, &k, &v).unwrap();
        }
        seq
    }

    fn snapshot(pool: &KvPool, seq: &KvSeq) -> Vec<Vec<i8>> {
        let mut out = Vec::new();
        for gi in 0..pool.config().kv_heads {
            for b in pool.page_blocks(seq, gi, seq.len()) {
                out.push(b.k.to_vec());
                out.push(b.v.to_vec());
                out.push(b.ksum.iter().flat_map(|s| s.to_le_bytes().map(|x| x as i8)).collect());
            }
        }
        out
    }

    #[test]
    fn spill_and_copy_back_roundtrip_bit_exactly() {
        let mut rng = Rng::new(31);
        let mut pool = pool4();
        let seq = seq_with(&mut pool, &mut rng, 10); // 3 pages
        let before = snapshot(&pool, &seq);
        let (ka, va) = (seq.k_affine(), seq.v_affine());

        let mut store = SpillStore::new();
        assert_eq!(store.spill(&mut pool, 7, seq), 3);
        assert_eq!(pool.free_pages(), 4, "spill returns the pages");
        assert_eq!(store.pages_held(), 3);
        assert_eq!(store.tokens_held(), 10);
        assert!(store.session(7).unwrap().intact());

        // interleave another session so copy-back lands on different ids
        let other = seq_with(&mut pool, &mut rng, 5);

        let seq = store.restore_copy_back(&mut pool, 7).unwrap().unwrap();
        assert!(!store.contains(7), "successful restore consumes the record");
        assert_eq!(seq.len(), 10);
        assert_eq!((seq.k_affine(), seq.v_affine()), (ka, va));
        assert_eq!(snapshot(&pool, &seq), before, "copy-back must be bit-exact");
        assert_eq!(pool.close(seq), 3);
        assert_eq!(pool.close(other), 2);
        assert_eq!(pool.free_pages(), 4, "free list round-trips");
    }

    #[test]
    fn copy_back_is_atomic_under_exhaustion_and_faults() {
        let mut rng = Rng::new(5);
        let mut pool = pool4();
        let seq = seq_with(&mut pool, &mut rng, 9); // 3 pages
        let mut store = SpillStore::new();
        store.spill(&mut pool, 1, seq);
        // occupy 2 pages: only 2 free, restore needs 3
        let hog = seq_with(&mut pool, &mut rng, 8);
        let err = store.restore_copy_back(&mut pool, 1).unwrap().unwrap_err();
        assert_eq!(err, KvError::Exhausted { pages: 4, free_pages: 2 });
        assert!(store.contains(1), "failed restore leaves the record");
        assert_eq!(pool.free_pages(), 2, "and the arena untouched");
        // injected allocation fault: same typed error, free pages remain
        pool.close(hog);
        pool.set_fault_plan(FaultPlan::none().with_seed(3).with(FaultSite::KvAlloc, 1));
        let err = store.restore_copy_back(&mut pool, 1).unwrap().unwrap_err();
        assert_eq!(err, KvError::Exhausted { pages: 4, free_pages: 4 });
        pool.set_fault_plan(FaultPlan::none());
        let seq = store.restore_copy_back(&mut pool, 1).unwrap().unwrap();
        assert_eq!(seq.len(), 9);
        assert_eq!(pool.close(seq), 3);
        // unknown id is None, not a panic
        assert!(store.restore_copy_back(&mut pool, 99).is_none());
    }

    #[test]
    fn corruption_trips_the_checksum_but_replay_rows_survive() {
        let mut rng = Rng::new(13);
        let mut pool = pool4();
        let seq = seq_with(&mut pool, &mut rng, 6);
        let mut store = SpillStore::new();
        store.spill(&mut pool, 3, seq);
        assert!(store.session(3).unwrap().intact());
        let (rk, rv) = {
            let rec = store.session(3).unwrap();
            let (rk, rv) = rec.replay_rows().unwrap();
            (rk.to_vec(), rv.to_vec())
        };
        assert_eq!(rk.len(), 6 * 2 * 8);

        assert!(store.corrupt(3, false));
        let rec = store.session(3).unwrap();
        assert!(!rec.intact(), "flipped byte must trip the checksum");
        let (rk2, rv2) = rec.replay_rows().unwrap();
        assert_eq!((rk2, rv2), (&rk[..], &rv[..]), "replay log is independent");

        // replaying the log rebuilds the same bytes the spill held
        let mut seq = KvSeq::new(rec.groups(), rec.k_affine, rec.v_affine);
        pool.append_block(&mut seq, &rk, &rv).unwrap();
        assert_eq!(seq.len(), 6);
        let mut fresh = SpillStore::new();
        fresh.spill(&mut pool, 9, seq);
        // both encodings agree once the corrupt byte is accounted for:
        // the replay-rebuilt record differs from the rotted one only in
        // that byte, so its replay rows match the original log exactly
        let rec9 = fresh.session(9).unwrap();
        assert!(rec9.intact());
        assert_eq!(rec9.replay_rows().unwrap().0, &rk[..]);

        // wiping the replay log is the terminal case
        assert!(store.corrupt(3, true));
        assert!(store.session(3).unwrap().replay_rows().is_none());
        assert!(store.remove(3).is_some());
        assert!(store.is_empty());
    }

    #[test]
    fn open_sessions_ride_the_store_across_a_drain() {
        let mut store = SpillStore::new();
        assert!(store.is_empty());
        store.note_open(12);
        store.note_open(4);
        assert!(!store.is_empty());
        assert_eq!(store.open_sessions(), vec![4, 12]);
        assert_eq!(store.len(), 0, "open-unbound sessions hold no pages");
        assert_eq!(store.ids_sorted(), Vec::<u64>::new());
    }
}
