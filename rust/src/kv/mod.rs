//! Paged integer-native KV cache — the storage substrate of streaming
//! decode.
//!
//! Autoregressive decode re-reads the whole key/value prefix every
//! generated token, so the cache layout *is* the decode memory system.
//! This module keeps the A³/SOLE premise end to end: K and V live as
//! quantized `i8` in fixed-size pages carved out of one shared arena, and
//! the fused decode kernel ([`crate::attention::DecodeAttention`]) reads
//! them without ever materializing f32 tensors.
//!
//! # Page layout
//!
//! A [`KvPool`] is built from a [`KvConfig`] `{pages, page_size, kv_heads,
//! d_head}`. One page holds `page_size` token slots for **all** `kv_heads`
//! stored heads of one sequence, group-major:
//!
//! ```text
//! page p (K arena, same shape in the V arena):
//!   [g = 0][t = 0..page_size][d = 0..d_head]
//!   [g = 1][t = 0..page_size][d = 0..d_head]
//!   ...
//! ```
//!
//! so each group's rows are contiguous within a page — the per-step
//! `q·K^T` sweep for query heads of group `g` streams one dense
//! `page_size × d_head` block per page. Alongside the `i8` data every
//! page stores the per-token **K byte sums** (`Σ_d k[g][t][d]`, an `i32`
//! per `(g, t)` slot), precomputed once at append time: the fused kernel
//! hoists the affine zero points out of the dot product
//! (`(q−z_q)·(k−z_k) = q·k − z_k·Σq − z_q·Σk + d·z_q·z_k`), and decode
//! re-reads `Σk` for the whole prefix every step, so recomputing it would
//! cost `d_head` adds per key per step.
//!
//! Pages are recycled through a free-list: allocation is a `Vec::pop`,
//! release is an extend — no per-step heap allocation, and thousands of
//! concurrent sequences (one [`KvSeq`] page table each) share one arena.
//! Exhaustion is **typed backpressure** ([`KvError::Exhausted`]), never a
//! panic: serving layers surface it as a retryable error while other
//! sessions keep streaming.
//!
//! # Per-page quantization contract
//!
//! Every page records the [`Affine`] pair (K and V) of the rows stored in
//! it, copied from the owning [`KvSeq`] at page-allocation time. The
//! current contract is **sequence-uniform**: a sequence's affines are
//! fixed at [`KvSeq::new`] and every page of that sequence carries the
//! same pair (debug-asserted on append), which is what keeps a T-step
//! decode bit-identical to a length-T prefill through one per-tensor
//! affine. The per-page slot exists so a later PR can requantize cold
//! pages (or admit per-block scales) without changing the arena layout —
//! readers must already consult [`KvPool::page_affines`] per page.
//!
//! # Grouped-query heads
//!
//! [`HeadGroups`] maps `q_heads` query heads onto `kv_heads ≤ q_heads`
//! stored K/V heads (`G = kv_heads`: `G == q_heads` is vanilla MHA,
//! `G == 1` is multi-query attention). K/V rows are stored **once per
//! group**; all `q_heads / kv_heads` query heads of a group read the same
//! page block, which divides decode's dominant memory traffic by the
//! group size.
//!
//! # Read-traffic contract
//!
//! Storage once per group is only half of GQA's saving — the other half
//! is *reads*. The decode kernel sweeps a sequence's pages
//! **group-major**: per decode step (and per chunked-prefill row), each
//! group's resident K/V bytes are read exactly **once per group per
//! step**, serving all `q_heads / kv_heads` query heads of the group out
//! of the one sweep. [`KvPool::page_blocks`] is the gather API behind
//! it: each page-table walk yields a page's K block, V block,
//! precomputed byte sums and affine pair in ONE lookup, so a sweep
//! phase never makes separate `page_k` / `page_ksum` / `page_affines`
//! calls per page (the kernel walks twice per sweep — a K/score phase
//! and a V phase, with the softmax between — but each phase touches
//! each page once, and the *bytes* it pulls are per group, not per
//! head). The affine pair rides along because the per-page quantization
//! contract above requires readers to consult it per page — today's
//! sequence-uniform affines make that a formality, but a cold-page
//! requantization PR will not change the gather API. Decode's K/V read
//! traffic is therefore proportional to `G`, not `H` (mirrored by
//! `hwsim::simulate_decode`'s `kv_bytes_read` accounting).

pub mod spill;

use std::fmt;

use anyhow::{bail, Result};

use crate::faults::{FaultPlan, FaultSite};
use crate::quant::Affine;

/// Geometry of a paged KV arena, fixed at [`KvPool::new`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvConfig {
    /// total pages in the arena
    pub pages: usize,
    /// token slots per page
    pub page_size: usize,
    /// stored K/V heads per token (the `G` of grouped-query attention)
    pub kv_heads: usize,
    /// head depth
    pub d_head: usize,
}

impl KvConfig {
    /// `i8` elements of one page's K (or V) block (`[g][t][d]` row-major).
    pub fn page_elems(&self) -> usize {
        self.kv_heads * self.page_size * self.d_head
    }

    /// `i32` K-byte-sum slots per page (`[g][t]`).
    fn sum_elems(&self) -> usize {
        self.kv_heads * self.page_size
    }

    /// tokens storable per sequence-free arena
    pub fn capacity_tokens(&self) -> usize {
        self.pages * self.page_size
    }
}

/// Typed KV allocation failure. Exhaustion is expected under load — it is
/// the serving layer's backpressure signal, not a bug — so it must reach
/// callers as an `Err`, never a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvError {
    /// the arena cannot cover the requested allocation; retry after
    /// sessions close (or after the scheduler evicts one)
    Exhausted {
        /// total pages in the arena
        pages: usize,
        /// pages on the free list at failure time — 0 for a single-token
        /// append, possibly > 0 for a block append that needed more
        free_pages: usize,
    },
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvError::Exhausted { pages, free_pages } => {
                write!(f, "kv pool exhausted ({free_pages} of {pages} pages free)")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Query-head → stored-head grouping: `q_heads` query heads share
/// `kv_heads` K/V heads in contiguous blocks of `q_heads / kv_heads`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeadGroups {
    q_heads: usize,
    kv_heads: usize,
}

impl HeadGroups {
    /// `kv_heads` must divide `q_heads` (both ≥ 1).
    pub fn new(q_heads: usize, kv_heads: usize) -> Result<Self> {
        if q_heads == 0 || kv_heads == 0 {
            bail!("head counts must be >= 1, got H={q_heads} G={kv_heads}");
        }
        if kv_heads > q_heads || q_heads % kv_heads != 0 {
            bail!("kv heads ({kv_heads}) must evenly divide query heads ({q_heads})");
        }
        Ok(Self { q_heads, kv_heads })
    }

    /// Vanilla multi-head attention: every query head stores its own K/V.
    pub fn mha(heads: usize) -> Self {
        Self::new(heads, heads).expect("heads >= 1")
    }

    pub fn q_heads(&self) -> usize {
        self.q_heads
    }

    pub fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    /// query heads per stored head
    pub fn group_size(&self) -> usize {
        self.q_heads / self.kv_heads
    }

    /// stored head serving query head `h`
    #[inline]
    pub fn group_of(&self, h: usize) -> usize {
        debug_assert!(h < self.q_heads);
        h / self.group_size()
    }
}

/// Per-sequence cache state: the page table plus the sequence's fixed
/// quantization params (see the module docs, "Per-page quantization
/// contract"). Cheap to create per session; pages are allocated lazily on
/// append and returned via [`KvPool::close`]. Deliberately NOT `Clone`:
/// a sequence is the unique owner of its page-table entries, and a copy
/// would let [`KvPool::close`] free the same pages twice (aliasing live
/// sequences onto recycled pages).
#[derive(Debug)]
pub struct KvSeq {
    groups: HeadGroups,
    k_affine: Affine,
    v_affine: Affine,
    pages: Vec<u32>,
    len: usize,
}

impl KvSeq {
    pub fn new(groups: HeadGroups, k_affine: Affine, v_affine: Affine) -> Self {
        Self { groups, k_affine, v_affine, pages: Vec::new(), len: 0 }
    }

    /// tokens stored so far (the decode prefix length)
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn groups(&self) -> &HeadGroups {
        &self.groups
    }

    pub fn k_affine(&self) -> Affine {
        self.k_affine
    }

    pub fn v_affine(&self) -> Affine {
        self.v_affine
    }

    /// page table, in token order
    pub fn pages(&self) -> &[u32] {
        &self.pages
    }

    /// tokens resident in page-table entry `pi` (full pages except the
    /// tail)
    #[inline]
    pub fn tokens_in_page(&self, page_size: usize, pi: usize) -> usize {
        (self.len - pi * page_size).min(page_size)
    }
}

/// The shared paged arena: quantized K/V pages + per-token K byte sums +
/// a free-list allocator. One pool serves every concurrent sequence of a
/// decode route; all per-sequence state lives in [`KvSeq`].
pub struct KvPool {
    cfg: KvConfig,
    k: Vec<i8>,
    v: Vec<i8>,
    ksum: Vec<i32>,
    k_aff: Vec<Affine>,
    v_aff: Vec<Affine>,
    /// free page ids, popped from the back (so fresh pools allocate in
    /// ascending id order — handy in tests, irrelevant to correctness)
    free: Vec<u32>,
    /// injected-fault schedule ([`FaultPlan::none`] outside chaos tests:
    /// one branch per allocation attempt)
    faults: FaultPlan,
    /// monotone allocation-attempt counter — the fault plan's per-attempt
    /// index, so a spurious failure is transient: the retry is a new
    /// attempt with a fresh draw
    alloc_seq: u64,
}

impl KvPool {
    pub fn new(cfg: KvConfig) -> Self {
        assert!(
            cfg.pages > 0 && cfg.page_size > 0 && cfg.kv_heads > 0 && cfg.d_head > 0,
            "kv config dimensions must be >= 1, got {cfg:?}"
        );
        assert!(cfg.pages <= u32::MAX as usize, "page ids are u32");
        let zero = Affine { scale: 1.0, zero_point: 0 };
        Self {
            k: vec![0; cfg.pages * cfg.page_elems()],
            v: vec![0; cfg.pages * cfg.page_elems()],
            ksum: vec![0; cfg.pages * cfg.sum_elems()],
            k_aff: vec![zero; cfg.pages],
            v_aff: vec![zero; cfg.pages],
            free: (0..cfg.pages as u32).rev().collect(),
            faults: FaultPlan::none(),
            alloc_seq: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &KvConfig {
        &self.cfg
    }

    /// Install a fault schedule for page allocation (and reset the
    /// attempt counter, so the schedule replays from its start). Pass
    /// [`FaultPlan::none`] to disable injection.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.faults = plan;
        self.alloc_seq = 0;
    }

    /// The currently-installed fault schedule.
    pub fn fault_plan(&self) -> FaultPlan {
        self.faults
    }

    /// Should this allocation attempt spuriously fail? Counts the attempt
    /// (so retries draw fresh) and consults the plan. One branch when the
    /// plan is disabled.
    fn alloc_faulted(&mut self) -> bool {
        if self.faults.is_none() {
            return false;
        }
        let i = self.alloc_seq;
        self.alloc_seq += 1;
        self.faults.should_fault(FaultSite::KvAlloc, i)
    }

    /// pages currently on the free list
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// pages currently owned by live sequences (`pages - free_pages`) —
    /// the KV-pool occupancy gauge; with Σ resident tokens it also gives
    /// the internal-fragmentation gauge (allocated-but-unfilled token
    /// slots in tail pages): `used_pages * page_size - resident_tokens`.
    pub fn used_pages(&self) -> usize {
        self.cfg.pages - self.free.len()
    }

    /// Append one token's K/V rows (`kv_heads * d_head` each, `[g][d]`
    /// row-major) to `seq`, allocating a page when the tail page is full.
    /// On [`KvError::Exhausted`] the sequence is left untouched, so the
    /// caller can retry the same step after capacity frees up.
    pub fn append(&mut self, seq: &mut KvSeq, k_row: &[i8], v_row: &[i8]) -> Result<(), KvError> {
        let (g, d, psize) = (self.cfg.kv_heads, self.cfg.d_head, self.cfg.page_size);
        assert_eq!(
            seq.groups.kv_heads(),
            g,
            "sequence stores {} kv heads but the pool is laid out for {g}",
            seq.groups.kv_heads()
        );
        assert_eq!(k_row.len(), g * d, "k row must be kv_heads * d_head");
        assert_eq!(v_row.len(), g * d, "v row must be kv_heads * d_head");
        let slot = seq.len % psize;
        if slot == 0 {
            // injected spurious failure: same typed error as the real
            // thing, but `free_pages` may be > 0 — callers must treat
            // Exhausted as retryable, not as proof the arena is full
            if self.alloc_faulted() {
                return Err(KvError::Exhausted {
                    pages: self.cfg.pages,
                    free_pages: self.free.len(),
                });
            }
            let Some(p) = self.free.pop() else {
                return Err(KvError::Exhausted { pages: self.cfg.pages, free_pages: 0 });
            };
            self.k_aff[p as usize] = seq.k_affine;
            self.v_aff[p as usize] = seq.v_affine;
            seq.pages.push(p);
        }
        let p = *seq.pages.last().expect("tail page exists") as usize;
        // per-page quantization contract: sequence-uniform affines
        debug_assert_eq!(self.k_aff[p], seq.k_affine);
        debug_assert_eq!(self.v_aff[p], seq.v_affine);
        let base = p * self.cfg.page_elems();
        let sbase = p * self.cfg.sum_elems();
        for gi in 0..g {
            let row = &k_row[gi * d..(gi + 1) * d];
            let off = base + (gi * psize + slot) * d;
            self.k[off..off + d].copy_from_slice(row);
            self.v[off..off + d].copy_from_slice(&v_row[gi * d..(gi + 1) * d]);
            self.ksum[sbase + gi * psize + slot] = row.iter().map(|&x| x as i32).sum();
        }
        seq.len += 1;
        Ok(())
    }

    /// Pages a further `extra_tokens` appends to `seq` would have to
    /// allocate — the admission check of the block/batched append paths.
    pub fn pages_needed(&self, seq: &KvSeq, extra_tokens: usize) -> usize {
        (seq.len + extra_tokens).div_ceil(self.cfg.page_size) - seq.pages.len()
    }

    /// Pages ONE more decode step on `seq` would allocate (0 while the
    /// tail page has a free slot, 1 at a page boundary) — the scheduler's
    /// admission probe, so exhaustion is predicted at admit time instead
    /// of discovered mid-wave. Compare against [`Self::free_pages`].
    pub fn pages_needed_for_step(&self, seq: &KvSeq) -> usize {
        self.pages_needed(seq, 1)
    }

    /// Append a whole block of tokens (`tokens * kv_heads * d_head` each
    /// for K and V, `[t][g][d]` row-major) to `seq` — the chunked-prefill
    /// ingest path. **Atomic**: capacity for the entire block is checked
    /// up front, and on [`KvError::Exhausted`] the sequence (and the
    /// arena) is left exactly as it was, so the caller can retry the same
    /// chunk after capacity frees up. Token-for-token identical to
    /// `tokens` single [`KvPool::append`] calls (same slots, same sums,
    /// same page-table growth).
    pub fn append_block(
        &mut self,
        seq: &mut KvSeq,
        k_rows: &[i8],
        v_rows: &[i8],
    ) -> Result<(), KvError> {
        let gd = self.cfg.kv_heads * self.cfg.d_head;
        assert_eq!(k_rows.len() % gd, 0, "k block must be tokens * kv_heads * d_head");
        assert_eq!(k_rows.len(), v_rows.len(), "k/v blocks must match");
        let tokens = k_rows.len() / gd;
        let needed = self.pages_needed(seq, tokens);
        // one injected draw covers the whole block reserve (atomic: the
        // block either lands entirely or not at all), counted whether or
        // not the real check would pass
        if (needed > 0 && self.alloc_faulted()) || needed > self.free.len() {
            return Err(KvError::Exhausted {
                pages: self.cfg.pages,
                free_pages: self.free.len(),
            });
        }
        // the per-page draws inside `append` must not double-fault the
        // reserved block: disable the plan across the inner appends
        let plan = self.faults;
        self.faults = FaultPlan::none();
        for (kr, vr) in k_rows.chunks_exact(gd).zip(v_rows.chunks_exact(gd)) {
            self.append(seq, kr, vr).expect("block capacity reserved above");
        }
        self.faults = plan;
        Ok(())
    }

    /// Return a sequence's pages to the free list; the `KvSeq` is
    /// consumed (it is the unique owner of those page-table entries).
    /// Returns the number of pages freed.
    pub fn close(&mut self, seq: KvSeq) -> usize {
        let n = seq.pages.len();
        debug_assert!(
            seq.pages.iter().all(|p| (*p as usize) < self.cfg.pages),
            "sequence closed against a pool that does not own its pages"
        );
        debug_assert!(
            seq.pages.iter().all(|p| !self.free.contains(p)),
            "page freed twice — a sequence's pages must be uniquely owned"
        );
        self.free.extend(seq.pages);
        n
    }

    /// Group `gi`'s K block of page `page`: `page_size * d_head` i8,
    /// token-major.
    #[inline]
    pub fn page_k(&self, page: u32, gi: usize) -> &[i8] {
        let off = page as usize * self.cfg.page_elems() + gi * self.cfg.page_size * self.cfg.d_head;
        &self.k[off..off + self.cfg.page_size * self.cfg.d_head]
    }

    /// Group `gi`'s V block of page `page` (same shape as [`Self::page_k`]).
    #[inline]
    pub fn page_v(&self, page: u32, gi: usize) -> &[i8] {
        let off = page as usize * self.cfg.page_elems() + gi * self.cfg.page_size * self.cfg.d_head;
        &self.v[off..off + self.cfg.page_size * self.cfg.d_head]
    }

    /// Group `gi`'s per-token K byte sums of page `page` (`page_size` i32).
    #[inline]
    pub fn page_ksum(&self, page: u32, gi: usize) -> &[i32] {
        let off = page as usize * self.cfg.sum_elems() + gi * self.cfg.page_size;
        &self.ksum[off..off + self.cfg.page_size]
    }

    /// The (K, V) affine pair recorded for `page`.
    #[inline]
    pub fn page_affines(&self, page: u32) -> (Affine, Affine) {
        (self.k_aff[page as usize], self.v_aff[page as usize])
    }

    /// Walk `seq`'s page table once for group `gi` over the first `valid`
    /// tokens, yielding each resident page's K block, V block, K byte
    /// sums and affine pair in ONE lookup ([`PageBlock`]) — the gather
    /// API of the group-major decode sweep (see the module docs,
    /// "Read-traffic contract"). Slices are truncated to the tokens of
    /// the prefix resident in the page (full pages except the tail), and
    /// iteration stops at the first empty page, so `Σ len == valid`.
    pub fn page_blocks<'a>(
        &'a self,
        seq: &'a KvSeq,
        gi: usize,
        valid: usize,
    ) -> impl Iterator<Item = PageBlock<'a>> + 'a {
        self.page_blocks_range(seq, gi, valid, 0..seq.pages().len())
    }

    /// [`Self::page_blocks`] restricted to the page-index span
    /// `pages.start .. pages.end` of the sequence's page table — the
    /// gather unit of the prefix-split decode sweep: each span worker
    /// walks only its page-aligned slice of the prefix, and the spans of
    /// a partition yield exactly the blocks of the unsplit walk
    /// (`Σ_span Σ len == valid`). Out-of-table indices are clipped, so a
    /// span planned past the resident prefix is an empty iteration, not
    /// a panic.
    pub fn page_blocks_range<'a>(
        &'a self,
        seq: &'a KvSeq,
        gi: usize,
        valid: usize,
        pages: std::ops::Range<usize>,
    ) -> impl Iterator<Item = PageBlock<'a>> + 'a {
        debug_assert!(gi < self.cfg.kv_heads);
        debug_assert!(valid <= seq.len());
        let (d, psize) = (self.cfg.d_head, self.cfg.page_size);
        let hi = pages.end.min(seq.pages().len());
        let lo = pages.start.min(hi);
        seq.pages()[lo..hi]
            .iter()
            .enumerate()
            .map(move |(i, &page)| {
                let pi = lo + i;
                let len = valid.saturating_sub(pi * psize).min(psize);
                let off = page as usize * self.cfg.page_elems() + gi * psize * d;
                let soff = page as usize * self.cfg.sum_elems() + gi * psize;
                let (k_affine, v_affine) = self.page_affines(page);
                PageBlock {
                    k: &self.k[off..off + len * d],
                    v: &self.v[off..off + len * d],
                    ksum: &self.ksum[soff..soff + len],
                    k_affine,
                    v_affine,
                    len,
                }
            })
            .take_while(|b| b.len > 0)
    }
}

/// One resident page's view for one group, as yielded by
/// [`KvPool::page_blocks`]: everything a sweep needs from the page in a
/// single page-table lookup.
pub struct PageBlock<'a> {
    /// the group's K rows resident in this page (`len * d_head` i8,
    /// token-major)
    pub k: &'a [i8],
    /// the group's V rows (same shape as `k`)
    pub v: &'a [i8],
    /// per-token K byte sums (`len` i32) — the zero-point hoist's `Σk`
    pub ksum: &'a [i32],
    /// the page's recorded K affine
    pub k_affine: Affine,
    /// the page's recorded V affine
    pub v_affine: Affine,
    /// tokens of the swept prefix resident in this page
    pub len: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn pool4() -> KvPool {
        KvPool::new(KvConfig { pages: 4, page_size: 4, kv_heads: 2, d_head: 8 })
    }

    fn seq_for(pool: &KvPool) -> KvSeq {
        KvSeq::new(
            HeadGroups::new(4, pool.config().kv_heads).unwrap(),
            Affine { scale: 0.5, zero_point: 3 },
            Affine { scale: 0.25, zero_point: -2 },
        )
    }

    fn rand_row(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.int(-128, 127) as i8).collect()
    }

    #[test]
    fn used_pages_tracks_allocation_and_close() {
        let mut pool = pool4();
        assert_eq!(pool.used_pages(), 0);
        let mut rng = Rng::new(5);
        let mut seq = seq_for(&pool);
        let n = pool.config().kv_heads * pool.config().d_head;
        // page_size 4: five appends span two pages
        for _ in 0..5 {
            let (k, v) = (rand_row(&mut rng, n), rand_row(&mut rng, n));
            pool.append(&mut seq, &k, &v).unwrap();
        }
        assert_eq!(pool.used_pages(), 2);
        assert_eq!(pool.used_pages() + pool.free_pages(), pool.config().pages);
        // fragmentation gauge: 2 pages hold 8 token slots, 5 resident
        assert_eq!(pool.used_pages() * pool.config().page_size - seq.len(), 3);
        pool.close(seq);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn head_groups_validate_and_map() {
        let g = HeadGroups::new(8, 2).unwrap();
        assert_eq!(g.group_size(), 4);
        assert_eq!(g.group_of(0), 0);
        assert_eq!(g.group_of(3), 0);
        assert_eq!(g.group_of(4), 1);
        assert_eq!(g.group_of(7), 1);
        let mha = HeadGroups::mha(3);
        assert_eq!((mha.q_heads(), mha.kv_heads(), mha.group_size()), (3, 3, 1));
        assert_eq!(mha.group_of(2), 2);
        assert!(HeadGroups::new(8, 3).is_err(), "3 does not divide 8");
        assert!(HeadGroups::new(2, 4).is_err(), "more kv heads than query heads");
        assert!(HeadGroups::new(0, 1).is_err());
        assert!(HeadGroups::new(1, 0).is_err());
    }

    #[test]
    fn append_roundtrips_rows_sums_and_affines() {
        let mut rng = Rng::new(1);
        let mut pool = pool4();
        let mut seq = seq_for(&pool);
        let (g, d, ps) = (2usize, 8usize, 4usize);
        let mut krows: Vec<Vec<i8>> = Vec::new();
        let mut vrows: Vec<Vec<i8>> = Vec::new();
        for _ in 0..10 {
            // 10 tokens: 3 pages (4 + 4 + 2)
            let kr = rand_row(&mut rng, g * d);
            let vr = rand_row(&mut rng, g * d);
            pool.append(&mut seq, &kr, &vr).unwrap();
            krows.push(kr);
            vrows.push(vr);
        }
        assert_eq!(seq.len(), 10);
        assert_eq!(seq.pages().len(), 3);
        assert_eq!(pool.free_pages(), 1);
        for (pi, &p) in seq.pages().iter().enumerate() {
            let in_page = seq.tokens_in_page(ps, pi);
            assert_eq!(in_page, if pi == 2 { 2 } else { 4 });
            assert_eq!(pool.page_affines(p), (seq.k_affine(), seq.v_affine()));
            for gi in 0..g {
                let kb = pool.page_k(p, gi);
                let vb = pool.page_v(p, gi);
                let ks = pool.page_ksum(p, gi);
                for t in 0..in_page {
                    let tok = pi * ps + t;
                    assert_eq!(&kb[t * d..(t + 1) * d], &krows[tok][gi * d..(gi + 1) * d]);
                    assert_eq!(&vb[t * d..(t + 1) * d], &vrows[tok][gi * d..(gi + 1) * d]);
                    let want: i32 =
                        krows[tok][gi * d..(gi + 1) * d].iter().map(|&x| x as i32).sum();
                    assert_eq!(ks[t], want, "ksum token {tok} group {gi}");
                }
            }
        }
    }

    #[test]
    fn page_blocks_walk_resident_pages_once_and_match_the_raw_accessors() {
        let mut rng = Rng::new(9);
        let mut pool = pool4();
        let mut seq = seq_for(&pool);
        let (g, d, ps) = (2usize, 8usize, 4usize);
        for _ in 0..10 {
            let kr = rand_row(&mut rng, g * d);
            let vr = rand_row(&mut rng, g * d);
            pool.append(&mut seq, &kr, &vr).unwrap();
        }
        for gi in 0..g {
            // every causal prefix, including mid-page and tail-page bounds
            for valid in 1..=seq.len() {
                let blocks: Vec<_> = pool.page_blocks(&seq, gi, valid).collect();
                assert_eq!(blocks.len(), valid.div_ceil(ps), "valid={valid}");
                let total: usize = blocks.iter().map(|b| b.len).sum();
                assert_eq!(total, valid, "blocks must cover the prefix exactly");
                for (pi, b) in blocks.iter().enumerate() {
                    let page = seq.pages()[pi];
                    assert_eq!(b.k, &pool.page_k(page, gi)[..b.len * d]);
                    assert_eq!(b.v, &pool.page_v(page, gi)[..b.len * d]);
                    assert_eq!(b.ksum, &pool.page_ksum(page, gi)[..b.len]);
                    assert_eq!((b.k_affine, b.v_affine), pool.page_affines(page));
                }
            }
        }
        pool.close(seq);
    }

    #[test]
    fn page_blocks_range_spans_partition_the_unsplit_walk() {
        let mut rng = Rng::new(21);
        let mut pool = pool4();
        let mut seq = seq_for(&pool);
        let (g, ps) = (2usize, 4usize);
        for _ in 0..13 {
            let kr = rand_row(&mut rng, g * 8);
            let vr = rand_row(&mut rng, g * 8);
            pool.append(&mut seq, &kr, &vr).unwrap();
        }
        for gi in 0..g {
            for valid in 1..=seq.len() {
                let full: Vec<_> = pool.page_blocks(&seq, gi, valid).collect();
                let npages = valid.div_ceil(ps);
                // any split point: the two spans yield exactly the full walk
                for cut in 0..=npages {
                    let a: Vec<_> = pool.page_blocks_range(&seq, gi, valid, 0..cut).collect();
                    let b: Vec<_> =
                        pool.page_blocks_range(&seq, gi, valid, cut..seq.pages().len()).collect();
                    assert_eq!(a.len() + b.len(), full.len(), "valid={valid} cut={cut}");
                    for (x, y) in a.iter().chain(b.iter()).zip(&full) {
                        assert_eq!(x.k, y.k);
                        assert_eq!(x.v, y.v);
                        assert_eq!(x.ksum, y.ksum);
                        assert_eq!(x.len, y.len);
                    }
                }
                // a span past the resident prefix is empty, not a panic
                assert_eq!(pool.page_blocks_range(&seq, gi, valid, npages..npages + 4).count(), 0);
            }
        }
        pool.close(seq);
    }

    #[test]
    fn exhaustion_is_typed_and_leaves_the_sequence_untouched() {
        let mut rng = Rng::new(2);
        let mut pool = pool4(); // 4 pages x 4 tokens = 16 tokens capacity
        let mut a = seq_for(&pool);
        let row = rand_row(&mut rng, 16);
        for _ in 0..16 {
            pool.append(&mut a, &row, &row).unwrap();
        }
        assert_eq!(pool.free_pages(), 0);
        // a 17th token needs a 5th page: typed backpressure
        let err = pool.append(&mut a, &row, &row).unwrap_err();
        assert_eq!(err, KvError::Exhausted { pages: 4, free_pages: 0 });
        assert!(err.to_string().contains("exhausted"), "{err}");
        assert_eq!(a.len(), 16, "failed append must not advance the sequence");
        // a second sequence cannot even start
        let mut b = seq_for(&pool);
        assert!(pool.append(&mut b, &row, &row).is_err());
        assert_eq!(b.len(), 0);
        assert!(b.pages().is_empty());
        // closing reclaims, and the blocked appends then succeed
        assert_eq!(pool.close(a), 4);
        assert_eq!(pool.free_pages(), 4);
        pool.append(&mut b, &row, &row).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(pool.close(b), 1);
        assert_eq!(pool.free_pages(), 4, "free list round-trips to initial");
    }

    #[test]
    fn append_block_is_atomic_and_token_identical_to_single_appends() {
        let mut rng = Rng::new(7);
        let (g, d) = (2usize, 8usize);
        let mut pool_a = pool4();
        let mut pool_b = pool4();
        let mut a = seq_for(&pool_a);
        let mut b = seq_for(&pool_b);
        // 10 tokens in chunks of [3, 1, 6] vs 10 single appends
        let kblock = rand_row(&mut rng, 10 * g * d);
        let vblock = rand_row(&mut rng, 10 * g * d);
        let mut off = 0usize;
        for chunk in [3usize, 1, 6] {
            let n = chunk * g * d;
            pool_a
                .append_block(&mut a, &kblock[off..off + n], &vblock[off..off + n])
                .unwrap();
            off += n;
        }
        for t in 0..10 {
            let n = g * d;
            pool_b
                .append(&mut b, &kblock[t * n..(t + 1) * n], &vblock[t * n..(t + 1) * n])
                .unwrap();
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.pages().len(), b.pages().len());
        for (pi, (&pa, &pb)) in a.pages().iter().zip(b.pages()).enumerate() {
            for gi in 0..g {
                assert_eq!(pool_a.page_k(pa, gi), pool_b.page_k(pb, gi), "page {pi}");
                assert_eq!(pool_a.page_v(pa, gi), pool_b.page_v(pb, gi), "page {pi}");
                assert_eq!(pool_a.page_ksum(pa, gi), pool_b.page_ksum(pb, gi), "page {pi}");
            }
        }
        // atomicity: 10 tokens hold 3 pages of 4; a 8-token block needs 2
        // more pages but only 1 is free -> nothing changes
        assert_eq!(pool_a.pages_needed(&a, 8), 2);
        let err = pool_a.append_block(&mut a, &kblock[..8 * g * d], &vblock[..8 * g * d]);
        assert_eq!(err, Err(KvError::Exhausted { pages: 4, free_pages: 1 }));
        assert_eq!(a.len(), 10, "failed block must not land partially");
        assert_eq!(pool_a.free_pages(), 1);
        // a block that fits the tail slots + last page still lands
        pool_a.append_block(&mut a, &kblock[..6 * g * d], &vblock[..6 * g * d]).unwrap();
        assert_eq!(a.len(), 16);
        assert_eq!(pool_a.free_pages(), 0);
        assert_eq!(pool_a.close(a), 4);
    }

    #[test]
    fn many_sequences_share_the_arena_without_leaks() {
        let mut rng = Rng::new(3);
        let mut pool = KvPool::new(KvConfig { pages: 32, page_size: 2, kv_heads: 1, d_head: 4 });
        for _ in 0..50 {
            let mut live: Vec<KvSeq> = Vec::new();
            for _ in 0..rng.usize(1, 6) {
                let mut s = KvSeq::new(
                    HeadGroups::new(2, 1).unwrap(),
                    Affine { scale: 1.0, zero_point: 0 },
                    Affine { scale: 1.0, zero_point: 0 },
                );
                for _ in 0..rng.usize(0, 9) {
                    let row = rand_row(&mut rng, 4);
                    if pool.append(&mut s, &row, &row).is_err() {
                        break; // arena full: fine, keep what landed
                    }
                }
                live.push(s);
            }
            let allocated: usize = live.iter().map(|s| s.pages().len()).sum();
            assert_eq!(pool.free_pages(), 32 - allocated);
            for s in live {
                pool.close(s);
            }
            assert_eq!(pool.free_pages(), 32, "all pages reclaimed each round");
        }
    }

    #[test]
    fn admission_probes_track_the_free_list_exactly() {
        let mut rng = Rng::new(11);
        let mut pool = pool4(); // 4 pages x 4 tokens
        let mut seq = seq_for(&pool);
        let row = rand_row(&mut rng, 16);
        // empty sequence: the first step must allocate a page
        assert_eq!(pool.pages_needed_for_step(&seq), 1);
        assert_eq!(pool.free_pages(), 4);
        for t in 0..16 {
            // the probe predicts exactly when append will take a page:
            // 1 at every page boundary (t % 4 == 0), else 0
            let want = usize::from(t % 4 == 0);
            assert_eq!(pool.pages_needed_for_step(&seq), want, "token {t}");
            let free_before = pool.free_pages();
            pool.append(&mut seq, &row, &row).unwrap();
            assert_eq!(pool.free_pages(), free_before - want, "token {t}");
        }
        // arena full, tail page full: the probe predicts the exhaustion
        assert_eq!(pool.pages_needed_for_step(&seq), 1);
        assert_eq!(pool.free_pages(), 0);
        assert!(pool.pages_needed_for_step(&seq) > pool.free_pages());
        let err = pool.append(&mut seq, &row, &row).unwrap_err();
        assert_eq!(err, KvError::Exhausted { pages: 4, free_pages: 0 });
        // multi-token probe agrees with the single-step one at +1
        assert_eq!(pool.pages_needed(&seq, 1), pool.pages_needed_for_step(&seq));
        assert_eq!(pool.close(seq), 4);
    }

    #[test]
    fn injected_alloc_faults_are_transient_and_leave_state_clean() {
        use crate::faults::{FaultPlan, FaultSite};
        let mut rng = Rng::new(17);
        let mut pool = pool4();
        let mut seq = seq_for(&pool);
        let row = rand_row(&mut rng, 16);
        // denominator 1: every allocation attempt faults...
        pool.set_fault_plan(FaultPlan::none().with_seed(3).with(FaultSite::KvAlloc, 1));
        let err = pool.append(&mut seq, &row, &row).unwrap_err();
        // ...spuriously: free pages remain, and the sequence is untouched
        assert_eq!(err, KvError::Exhausted { pages: 4, free_pages: 4 });
        assert_eq!(seq.len(), 0);
        assert_eq!(pool.free_pages(), 4);
        let err = pool.append_block(&mut seq, &row, &row).unwrap_err();
        assert_eq!(err, KvError::Exhausted { pages: 4, free_pages: 4 });
        assert_eq!(seq.len(), 0);
        // disabling restores clean behavior; free list round-trips
        pool.set_fault_plan(FaultPlan::none());
        pool.append(&mut seq, &row, &row).unwrap();
        assert_eq!(pool.close(seq), 1);
        assert_eq!(pool.free_pages(), 4);

        // a moderate rate means retries eventually land (fresh draw per
        // attempt): page_size 1 makes every append an allocation attempt
        let mut pool =
            KvPool::new(KvConfig { pages: 64, page_size: 1, kv_heads: 1, d_head: 4 });
        pool.set_fault_plan(FaultPlan::none().with_seed(3).with(FaultSite::KvAlloc, 3));
        let mut seq = KvSeq::new(
            HeadGroups::new(1, 1).unwrap(),
            Affine { scale: 1.0, zero_point: 0 },
            Affine { scale: 1.0, zero_point: 0 },
        );
        let row = rand_row(&mut rng, 4);
        let mut faulted = 0usize;
        while seq.len() < 32 {
            match pool.append(&mut seq, &row, &row) {
                Ok(()) => {}
                Err(KvError::Exhausted { free_pages, .. }) => {
                    assert!(free_pages > 0, "only spurious failures expected here");
                    faulted += 1;
                    assert!(faulted < 1000, "retries must eventually land");
                }
            }
        }
        assert!(faulted > 0, "denominator 3 over ~48 attempts must fire");
        assert_eq!(pool.free_pages(), 32);
        assert_eq!(pool.close(seq), 32);
        assert_eq!(pool.free_pages(), 64, "free list round-trips under faults");
    }

    #[test]
    fn capacity_accounting() {
        let cfg = KvConfig { pages: 8, page_size: 16, kv_heads: 2, d_head: 32 };
        assert_eq!(cfg.page_elems(), 2 * 16 * 32);
        assert_eq!(cfg.capacity_tokens(), 128);
        let pool = KvPool::new(cfg);
        assert_eq!(pool.free_pages(), 8);
        assert_eq!(pool.config().d_head, 32);
    }
}
