//! `exp` — the experiment harness that regenerates every table and figure
//! of the paper (see DESIGN.md §4 for the experiment index).
//!
//! Usage: exp <table1|table2|table3|table4|table5|table6|table7|table8|
//!             fig2|fig3|fig4|fig5|hw|perf|all> [--artifacts DIR]

use anyhow::{anyhow, Result};
use lutmax::config::Args;

mod experiments {
    include!("exp/common.rs");
    include!("exp/detr.rs");
    include!("exp/hw.rs");
    include!("exp/nlp.rs");
    include!("exp/perf.rs");
    include!("exp/tables.rs");
}

fn main() -> Result<()> {
    let args = Args::from_env(&["artifacts", "samples", "lanes", "n"])?;
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    let dir = args
        .opt("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(lutmax::artifacts_dir);
    match cmd {
        "table1" => experiments::table1(&dir, &args),
        "table2" => experiments::table2(&dir, &args),
        "table3" => experiments::table3(&dir, &args),
        "table4" => experiments::table4(&dir, &args),
        "table5" => experiments::table5(),
        "table6" => experiments::table6(&dir, &args, "ap"),
        "table7" => experiments::table6(&dir, &args, "ar"),
        "table8" => experiments::table8(),
        "fig2" => experiments::fig2(&dir, &args),
        "fig3" => experiments::fig3(&dir, &args),
        "fig4" => experiments::fig4(&dir),
        "fig5" => experiments::fig5(&dir, &args),
        "hw" => experiments::hw(&args),
        "perf" => experiments::perf(&dir, &args),
        "eval" => experiments::eval_one(&dir, &args),
        "all" => {
            experiments::table5()?;
            experiments::table8()?;
            experiments::hw(&args)?;
            experiments::table4(&dir, &args)?;
            experiments::fig4(&dir)?;
            experiments::table2(&dir, &args)?;
            experiments::fig3(&dir, &args)?;
            experiments::table6(&dir, &args, "ap")?;
            experiments::table6(&dir, &args, "ar")?;
            experiments::fig2(&dir, &args)?;
            experiments::table1(&dir, &args)?;
            experiments::table3(&dir, &args)?;
            experiments::fig5(&dir, &args)?;
            Ok(())
        }
        _ => Err(anyhow!(
            "usage: exp <table1..table8|fig2..fig5|hw|perf|all> [--artifacts DIR]"
        )),
    }
}
