// DETR experiments: Tables 1/3/6/7, Figures 2/4/5.

const DETR_MODELS: [&str; 2] = ["detr", "detr_dc5"];
const ALPHA_CASES: [usize; 3] = [256, 320, 512];

fn detr_label(m: &str) -> &'static str {
    if m == "detr_dc5" {
        "DETR+DC5"
    } else {
        "DETR"
    }
}

/// Tables 6/7 grid: (model, column) -> DetEval.
fn detr_grid(
    engine: &Engine,
    dir: &Path,
    limit: usize,
) -> Result<BTreeMap<(String, String), eval::DetEval>> {
    let mut out = BTreeMap::new();
    for model in DETR_MODELS {
        let e = |variant: &str| eval_det_variant(engine, dir, variant, limit);
        out.insert(
            (model.into(), "FP32".into()),
            e(&format!("{model}__fp32__exact__fp32"))?,
        );
        out.insert(
            (model.into(), "PTQ-D".into()),
            e(&format!("{model}__ptqd__exact__fp32"))?,
        );
        for prec in ["int16", "uint8"] {
            for (case, alpha) in ALPHA_CASES.iter().enumerate() {
                let v = e(&format!("{model}__ptqd__rexp__{prec}-a{alpha}"))?;
                let label = format!("{} case{}", prec.to_uppercase(), case + 1);
                println!("  [{model}/{label}] AP={:.3} AR={:.3}", v.ap, v.ar);
                out.insert((model.into(), label), v);
            }
        }
    }
    Ok(out)
}

/// Tables 6 (AP) / 7 (AR): full DETR grid.
pub fn table6(dir: &Path, args: &Args, which: &str) -> Result<()> {
    let limit = args.opt_usize("samples", 100)?;
    let engine = Engine::new(dir)?;
    let title = if which == "ap" { "Table 6 (AP)" } else { "Table 7 (AR)" };
    println!("\n== {title}: DETR validation across LUT cases ==");
    let grid = detr_grid(&engine, dir, limit)?;

    let cols = [
        "FP32", "PTQ-D", "INT16 case1", "INT16 case2", "INT16 case3", "UINT8 case1",
        "UINT8 case2", "UINT8 case3",
    ];
    println!(
        "{:<10} {:>7} {:>7} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "model", cols[0], cols[1], cols[2], cols[3], cols[4], cols[5], cols[6], cols[7]
    );
    let mut report = Vec::new();
    for model in DETR_MODELS {
        let vals: Vec<f64> = cols
            .iter()
            .map(|c| {
                let ev = &grid[&(model.to_string(), c.to_string())];
                if which == "ap" {
                    ev.ap
                } else {
                    ev.ar
                }
            })
            .collect();
        println!(
            "{:<10} {:>7.3} {:>7.3} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>12.3}",
            detr_label(model),
            vals[0],
            vals[1],
            vals[2],
            vals[3],
            vals[4],
            vals[5],
            vals[6],
            vals[7]
        );
        report.push(jobj![
            ("model", model),
            ("metric", which),
            ("columns", cols.iter().map(|c| c.to_string()).collect::<Vec<_>>()),
            ("values", vals.clone()),
        ]);
    }
    println!("paper shape: plain DETR ~flat across cases; +DC5 degrades, recovers case1->case3");
    write_report(dir, &format!("table6_{which}"), &Json::Arr(report))
}

/// Figure 2: averaged accuracy drop (percentage points of AP/AR) of the
/// approximated PTQ-D models vs the FP32 models.
pub fn fig2(dir: &Path, args: &Args) -> Result<()> {
    let limit = args.opt_usize("samples", 100)?;
    let engine = Engine::new(dir)?;
    println!("\n== Figure 2: DETR accuracy drop vs FP32 ==");
    let grid = detr_grid(&engine, dir, limit)?;
    let mut report = Vec::new();
    for metric in ["ap", "ar"] {
        println!("-- {} drop (pp) --", metric.to_uppercase());
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
            "model", "int16 c1", "int16 c2", "int16 c3", "uint8 c1", "uint8 c2", "uint8 c3"
        );
        for model in DETR_MODELS {
            let base = &grid[&(model.to_string(), "FP32".to_string())];
            let base_v = if metric == "ap" { base.ap } else { base.ar };
            let mut drops = Vec::new();
            for prec in ["INT16", "UINT8"] {
                for case in 1..=3 {
                    let ev = &grid[&(model.to_string(), format!("{prec} case{case}"))];
                    let v = if metric == "ap" { ev.ap } else { ev.ar };
                    drops.push((base_v - v) * 100.0);
                }
            }
            println!(
                "{:<10} {:>12.2} {:>12.2} {:>12.2} {:>12.2} {:>12.2} {:>12.2}",
                detr_label(model), drops[0], drops[1], drops[2], drops[3], drops[4], drops[5]
            );
            report.push(jobj![
                ("model", model),
                ("metric", metric),
                ("drops_pp", drops.clone()),
            ]);
        }
    }
    println!("paper shape: no-DC5 drops < 1pp everywhere; +DC5 drops shrink as LUT_alpha grows");
    write_report(dir, "fig2", &Json::Arr(report))
}

/// Tables 1 & 3: prior-art accuracy drop vs the proposed REXP method.
pub fn table1(dir: &Path, args: &Args) -> Result<()> {
    let limit = args.opt_usize("samples", 100)?;
    let engine = Engine::new(dir)?;
    println!("\n== Table 1: averaged accuracy drop by method over DETR models (AP pp) ==");
    println!(
        "{:<22} {:>10} {:>14}",
        "method", "DETR", "DETR+DC5"
    );
    let mut report = Vec::new();
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, mode_spec) in [
        ("Eq.(2) in [32]", "fp32__priorart_eq2__uint8"),
        ("Eq.(2)+ in [32]", "fp32__priorart_eq2plus__uint8"),
        ("Section 4.1 (REXP)", "fp32__rexp__uint8-a256"),
    ] {
        let mut drops = Vec::new();
        for model in DETR_MODELS {
            let base = eval_det_variant(&engine, dir, &format!("{model}__fp32__exact__fp32"), limit)?;
            let v = eval_det_variant(&engine, dir, &format!("{model}__{mode_spec}"), limit)?;
            // paper's Table 1 averages the AP-family drop; we average the
            // same six metrics (AP, AP50, AP75 + AR trio as available)
            let drop = ((base.ap - v.ap) + (base.ap50 - v.ap50) + (base.ap75 - v.ap75)) / 3.0
                * 100.0;
            drops.push(drop);
        }
        println!("{:<22} {:>10.2} {:>14.2}", label, drops[0], drops[1]);
        rows.push((label.to_string(), drops.clone()));
        report.push(jobj![("method", label), ("drops_pp", drops.clone())]);
    }
    // the paper's key claim: REXP's drop is several times smaller
    if let (Some(eq2), Some(rexp)) = (rows.first(), rows.last()) {
        if rexp.1[0] > 0.0 {
            println!(
                "improvement vs Eq.(2): {:.1}x (DETR), {:.1}x (DETR+DC5)",
                eq2.1[0] / rexp.1[0].max(0.01),
                eq2.1[1] / rexp.1[1].max(0.01)
            );
        }
    }
    println!("paper: Eq.(2) 7.2/19.3; Eq.(2)+ 2.5/12.9; REXP 0.33/2.92 (x4-x20 better)");
    write_report(dir, "table1", &Json::Arr(report))
}

/// Table 3: prior-art per-metric AP breakdown.
pub fn table3(dir: &Path, args: &Args) -> Result<()> {
    let limit = args.opt_usize("samples", 100)?;
    let engine = Engine::new(dir)?;
    println!("\n== Table 3: prior-art validation over DETR models (per-metric) ==");
    println!(
        "{:<10} {:<7} {:>9} {:>9} {:>9} {:>10} {:>10}",
        "model", "metric", "fp32", "Eq.(2)", "Eq.(2)+", "drop2 pp", "drop2+ pp"
    );
    let mut report = Vec::new();
    for model in DETR_MODELS {
        let base = eval_det_variant(&engine, dir, &format!("{model}__fp32__exact__fp32"), limit)?;
        let eq2 = eval_det_variant(&engine, dir, &format!("{model}__fp32__priorart_eq2__uint8"), limit)?;
        let eq2p =
            eval_det_variant(&engine, dir, &format!("{model}__fp32__priorart_eq2plus__uint8"), limit)?;
        for (metric, b, a, ap) in [
            ("AP", base.ap, eq2.ap, eq2p.ap),
            ("AP_50", base.ap50, eq2.ap50, eq2p.ap50),
            ("AP_75", base.ap75, eq2.ap75, eq2p.ap75),
        ] {
            println!(
                "{:<10} {:<7} {:>9.3} {:>9.3} {:>9.3} {:>10.1} {:>10.1}",
                detr_label(model),
                metric,
                b,
                a,
                ap,
                (b - a) * 100.0,
                (b - ap) * 100.0
            );
            report.push(jobj![
                ("model", model),
                ("metric", metric),
                ("fp32", b),
                ("eq2", a),
                ("eq2plus", ap),
            ]);
        }
    }
    println!("paper shape: Eq.(2)+ always better than Eq.(2); both far worse than REXP");
    write_report(dir, "table3", &Json::Arr(report))
}

/// Figure 4: histogram of sum(e^x) for DETR vs DETR+DC5 (computed at
/// artifact-build time from the real attention tensors; printed here).
pub fn fig4(dir: &Path) -> Result<()> {
    let manifest = Json::parse_file(&dir.join("manifest.json"))?;
    let f4 = manifest.req("fig4")?;
    println!("\n== Figure 4: distribution of sum(e^x) in DETR attention ==");
    for model in DETR_MODELS {
        let m = f4.req(model)?;
        let mean = m.req("mean")?.as_f64().unwrap_or(0.0);
        let p99 = m.req("p99")?.as_f64().unwrap_or(0.0);
        let counts: Vec<f64> = m
            .req("counts")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let total: f64 = counts.iter().sum();
        println!(
            "{:<10} mean={:>7.1}  p99={:>7.1}  (histogram over (0,500), 50 bins)",
            detr_label(model),
            mean,
            p99
        );
        // coarse ASCII histogram, 25 buckets of 2 bins each
        let maxc = counts.iter().cloned().fold(1.0, f64::max);
        for chunk in 0..25 {
            let c = counts[chunk * 2] + counts.get(chunk * 2 + 1).unwrap_or(&0.0);
            let bar = "#".repeat(((c / (2.0 * maxc)) * 60.0) as usize);
            if c > total * 0.001 {
                println!("  [{:>3}-{:>3}) {:>8.0} {}", chunk * 20, (chunk + 1) * 20, c, bar);
            }
        }
    }
    println!("paper shape: +DC5 is right-tailed with more high-magnitude sums");
    Ok(())
}

/// Figure 5: aggressive approximation collapses DETR to ~zero AP.
pub fn fig5(dir: &Path, args: &Args) -> Result<()> {
    let limit = args.opt_usize("samples", 100)?;
    let engine = Engine::new(dir)?;
    println!("\n== Figure 5: aggressive approximation collapse ==");
    for model in DETR_MODELS {
        let base = eval_det_variant(&engine, dir, &format!("{model}__fp32__exact__fp32"), limit)?;
        let agg =
            eval_det_variant(&engine, dir, &format!("{model}__fp32__aggressive__uint8"), limit)?;
        println!(
            "{:<10} exact AP={:.3}  aggressive[29] AP={:.3}  AR={:.3}",
            detr_label(model),
            base.ap,
            agg.ap,
            agg.ar
        );
    }
    println!("paper: aggressive methods give 0.000 everywhere (model collapse)");
    Ok(())
}
