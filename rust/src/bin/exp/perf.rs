// Perf probe: request-path timings used by EXPERIMENTS.md §Perf.

pub fn perf(dir: &Path, args: &Args) -> Result<()> {
    use std::time::Instant;

    let iters = args.opt_usize("samples", 50)?;
    let engine = Engine::new(dir)?;
    println!("\n== Perf probe (request path) ==");

    // 1. standalone softmax artifact latency (compile once, execute many)
    let shape = {
        let meta = engine.manifest.artifact("softmax__rexp__uint8")?;
        meta.inputs[0].0.clone()
    };
    let (rows, cols) = (shape[0], shape[1]);
    let mut rng = lutmax::testkit::Rng::new(3);
    let x = Tensor::f32(vec![rows, cols], rng.normal_vec(rows * cols, 2.0));
    let t = lut::rexp_tables(Precision::Uint8, None);
    let recip = Tensor::i32(vec![t.recip_e.len()], t.recip_e.clone());
    let alpha = Tensor::i32(vec![t.alpha.len()], t.alpha.clone());
    engine.execute("softmax__rexp__uint8", &[x.clone(), recip.clone(), alpha.clone()])?;
    let t0 = Instant::now();
    for _ in 0..iters {
        engine.execute("softmax__rexp__uint8", &[x.clone(), recip.clone(), alpha.clone()])?;
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "softmax__rexp__uint8 ({rows}x{cols}): {:.3} ms/exec  ({:.1} M elem/s)",
        per * 1e3,
        rows as f64 * cols as f64 / per / 1e6
    );

    // 2. rust SW-model softmax throughput (the paper's datapath, no PJRT)
    let xs = rng.normal_vec(rows * cols, 2.0);
    let eng = lutmax::softmax::engine(
        lutmax::softmax::Mode::Rexp,
        Precision::Uint8,
        None,
    );
    let mut out = vec![0.0f32; xs.len()];
    let t0 = Instant::now();
    let reps = iters * 20;
    for _ in 0..reps {
        eng.run(&xs, cols, &mut out);
    }
    let per_sw = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "rexp SW model        ({rows}x{cols}): {:.3} ms/run   ({:.1} M elem/s)",
        per_sw * 1e3,
        xs.len() as f64 / per_sw / 1e6
    );

    // 3. fused attention artifacts: exact vs REXP softmax inside the kernel
    for mode in ["exact", "rexp"] {
        let name = format!("attention__{mode}__uint8");
        if engine.manifest.artifact(&name).is_err() {
            continue;
        }
        let meta = engine.manifest.artifact(&name)?;
        let dims = meta.inputs[0].0.clone();
        let n: usize = dims.iter().product();
        let mut args = vec![
            Tensor::f32(dims.clone(), rng.normal_vec(n, 1.0)),
            Tensor::f32(dims.clone(), rng.normal_vec(n, 1.0)),
            Tensor::f32(dims.clone(), rng.normal_vec(n, 1.0)),
        ];
        args.extend(lutmax::runtime::mode_tables(mode, "uint8")?);
        engine.execute(&name, &args)?;
        let t0 = Instant::now();
        for _ in 0..iters {
            engine.execute(&name, &args)?;
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{name} ({dims:?}):  {:.3} ms/exec",
            per * 1e3
        );
    }

    // 4. model-step latency (nmt decode step — the serving inner loop)
    let pipe = NmtPipeline::load(&engine, "nmt14__ptqd__rexp__uint8")?;
    let mut srcs = Vec::new();
    for _ in 0..pipe.batch {
        srcs.push(lutmax::workload::random_src_row(&mut rng, pipe.max_src, 64));
    }
    let t0 = Instant::now();
    let n_translate = (iters / 10).max(2);
    for _ in 0..n_translate {
        pipe.translate(&engine, &srcs)?;
    }
    let per_tr = t0.elapsed().as_secs_f64() / n_translate as f64;
    println!(
        "nmt translate batch={}: {:.1} ms/batch ({:.1} ms/seq, incl. up to {} decode steps)",
        pipe.batch,
        per_tr * 1e3,
        per_tr * 1e3 / pipe.batch as f64,
        pipe.max_tgt - 1
    );
    println!("pjrt executions so far: {}", engine.exec_count.borrow());
    Ok(())
}
