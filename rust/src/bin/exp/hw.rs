// HW experiment: the paper's architectural claims on the cycle/area/energy
// simulator (no divider, tiny LUTs, minimal overhead).

pub fn hw(args: &Args) -> Result<()> {
    let n = args.opt_usize("n", 128)?;
    let lanes = args.opt_usize("lanes", 4)?;
    println!("\n== HW: softmax unit designs on the cycle/area/energy model ==");
    println!("row length n={n}, lanes={lanes}, 1024 rows\n");
    println!(
        "{:<20} {:>6} {:>11} {:>10} {:>9} {:>9} {:>5} {:>5}",
        "design", "prec", "cycles/elem", "energy/el", "area", "LUT B", "div", "mul"
    );
    for prec in [Precision::Uint8, Precision::Int16] {
        for d in hwsim::all_designs(prec) {
            let r = hwsim::simulate(
                &d,
                hwsim::SimConfig { n, rows: 1024, lanes },
            );
            println!(
                "{:<20} {:>6} {:>11.2} {:>10.2} {:>9.1} {:>9} {:>5} {:>5}",
                r.design,
                prec.name(),
                r.cycles_per_elem(),
                r.energy_per_elem(),
                r.area,
                r.lut_bytes,
                if r.has_divider { "yes" } else { "-" },
                if r.has_multiplier { "yes" } else { "-" }
            );
        }
        println!();
    }
    // headline ratios at uint8
    let div = hwsim::simulate(
        &hwsim::Design::new(hwsim::DesignKind::ExactDivider, Precision::Uint8),
        hwsim::SimConfig { n, rows: 1024, lanes },
    );
    let l2d = hwsim::simulate(
        &hwsim::Design::new(hwsim::DesignKind::Lut2d, Precision::Uint8),
        hwsim::SimConfig { n, rows: 1024, lanes },
    );
    let rexp = hwsim::simulate(
        &hwsim::Design::new(hwsim::DesignKind::Rexp, Precision::Uint8),
        hwsim::SimConfig { n, rows: 1024, lanes },
    );
    println!(
        "speedup vs exact divider: rexp {:.2}x, 2d-lut {:.2}x; area {:.1}% / {:.1}%",
        div.cycles as f64 / rexp.cycles as f64,
        div.cycles as f64 / l2d.cycles as f64,
        100.0 * rexp.area / div.area,
        100.0 * l2d.area / div.area,
    );
    println!("paper claims: no divider; 2D-LUT needs no multiplier; LUTs ~700 B (uint8 2D)");
    Ok(())
}
