// Shared imports + helpers for the experiment harness (all exp/*.rs files
// are `include!`d into one module; `use` statements live here only).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};
use lutmax::config::{Args, Json};
use lutmax::jobj;
use lutmax::coordinator::{ClsPipeline, DetPipeline, NmtPipeline};
use lutmax::eval::{self, DetectionBox, GroundTruth};
use lutmax::hwsim;
use lutmax::lut::{self, Precision};
use lutmax::runtime::{tensorio, Engine, Tensor};
use lutmax::softmax::SoftmaxEngine as _;
use lutmax::workload::{BOS, EOS, PAD};

/// Write an experiment report JSON under artifacts/results/.
pub fn write_report(dir: &Path, name: &str, json: &Json) -> Result<()> {
    let results = dir.join("results");
    std::fs::create_dir_all(&results)?;
    let path = results.join(format!("{name}.json"));
    std::fs::write(&path, json.to_string_pretty())?;
    println!("[report] {}", path.display());
    Ok(())
}

/// Strip a teacher-forcing target row to the BLEU reference tokens.
pub fn reference_tokens(row: &[i32]) -> Vec<i32> {
    row.iter()
        .copied()
        .skip_while(|&t| t == BOS)
        .take_while(|&t| t != EOS && t != PAD)
        .collect()
}

/// Load the NMT eval bundle -> (src rows, reference rows).
pub fn load_nmt_eval(
    dir: &Path,
    corpus: &str,
    limit: usize,
) -> Result<(Vec<Vec<i32>>, Vec<Vec<i32>>)> {
    let b = tensorio::read_bundle(&dir.join(format!("eval_{corpus}.ltb")))?;
    let src = b.get("src").ok_or_else(|| anyhow!("bundle missing src"))?;
    let tgt = b.get("tgt").ok_or_else(|| anyhow!("bundle missing tgt"))?;
    let n = src.dims[0].min(limit);
    let mut srcs = Vec::with_capacity(n);
    let mut refs = Vec::with_capacity(n);
    for i in 0..n {
        srcs.push(src.row_i32(i)?.to_vec());
        refs.push(reference_tokens(tgt.row_i32(i)?));
    }
    Ok((srcs, refs))
}

/// Load a classification eval bundle -> (token rows, labels).
pub fn load_cls_eval(dir: &Path, task: &str, limit: usize) -> Result<(Vec<Vec<i32>>, Vec<i32>)> {
    let b = tensorio::read_bundle(&dir.join(format!("eval_{task}.ltb")))?;
    let toks = b.get("tokens").ok_or_else(|| anyhow!("missing tokens"))?;
    let labels = b.get("labels").ok_or_else(|| anyhow!("missing labels"))?;
    let n = toks.dims[0].min(limit);
    let rows = (0..n).map(|i| toks.row_i32(i).map(|r| r.to_vec())).collect::<Result<_>>()?;
    Ok((rows, labels.as_i32()?[..n].to_vec()))
}

/// Load the detection eval bundle -> (images, ground truth).
pub fn load_det_eval(
    dir: &Path,
    limit: usize,
) -> Result<(Vec<Tensor>, Vec<GroundTruth>)> {
    let b = tensorio::read_bundle(&dir.join("eval_detr.ltb"))?;
    let images = b.get("images").ok_or_else(|| anyhow!("missing images"))?;
    let gt = b.get("gt").ok_or_else(|| anyhow!("missing gt"))?;
    let n = images.dims[0].min(limit);
    let pix: usize = images.dims[1..].iter().product();
    let data = images.as_f32()?;
    let imgs: Vec<Tensor> = (0..n)
        .map(|i| {
            Tensor::f32(
                images.dims[1..].to_vec(),
                data[i * pix..(i + 1) * pix].to_vec(),
            )
        })
        .collect();
    let mut gts = Vec::new();
    let gv = gt.as_f32()?;
    for row in gv.chunks_exact(6) {
        let image = row[0] as usize;
        if image >= n {
            continue;
        }
        gts.push(GroundTruth {
            image,
            class: row[1] as usize,
            cx: row[2] as f64,
            cy: row[3] as f64,
            w: row[4] as f64,
            h: row[5] as f64,
        });
    }
    Ok((imgs, gts))
}

/// BLEU of one NMT variant over the eval corpus.
pub fn eval_nmt_variant(
    engine: &Engine,
    dir: &Path,
    corpus: &str,
    variant: &str,
    limit: usize,
) -> Result<f64> {
    let (srcs, refs) = load_nmt_eval(dir, corpus, limit)?;
    let pipe = NmtPipeline::load(engine, variant)
        .with_context(|| format!("loading {variant}"))?;
    let hyps = pipe.translate(engine, &srcs)?;
    let pairs: Vec<(Vec<i32>, Vec<i32>)> = hyps.into_iter().zip(refs).collect();
    Ok(eval::bleu_corpus(&pairs))
}

/// Accuracy (task=sst2) or F1 (task=mrpc) of a classifier variant, percent.
pub fn eval_cls_variant(
    engine: &Engine,
    dir: &Path,
    task: &str,
    variant: &str,
    limit: usize,
) -> Result<f64> {
    let (rows, labels) = load_cls_eval(dir, task, limit)?;
    let pipe = ClsPipeline::load(engine, variant)?;
    let preds = pipe.classify(engine, &rows)?;
    Ok(if task == "mrpc" {
        eval::f1_binary(&preds, &labels)
    } else {
        eval::accuracy(&preds, &labels)
    })
}

/// `exp eval <variant>`: evaluate a single model variant (debug utility).
pub fn eval_one(dir: &Path, args: &Args) -> Result<()> {
    let variant = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: exp eval <variant> [--samples N]"))?
        .clone();
    let limit = args.opt_usize("samples", 200)?;
    let engine = Engine::new(dir)?;
    let model = variant.split("__").next().unwrap_or("");
    let metric = match model {
        "nmt14" | "nmt17" => {
            ("BLEU", eval_nmt_variant(&engine, dir, model, &variant, limit)?)
        }
        "sst2" => ("acc%", eval_cls_variant(&engine, dir, "sst2", &variant, limit)?),
        "mrpc" => ("F1%", eval_cls_variant(&engine, dir, "mrpc", &variant, limit)?),
        "detr" | "detr_dc5" => {
            let e = eval_det_variant(&engine, dir, &variant, limit)?;
            ("AP%", e.ap * 100.0)
        }
        m => return Err(anyhow!("unknown model prefix {m:?}")),
    };
    println!("{variant}: {} = {:.2}", metric.0, metric.1);
    Ok(())
}

/// Detection AP/AR of a detr variant.
pub fn eval_det_variant(
    engine: &Engine,
    dir: &Path,
    variant: &str,
    limit: usize,
) -> Result<eval::DetEval> {
    let (imgs, gts) = load_det_eval(dir, limit)?;
    let pipe = DetPipeline::load(engine, variant)?;
    let dets: Vec<DetectionBox> = pipe.detect(engine, &imgs, 0)?;
    Ok(eval::average_precision(&dets, &gts, pipe.num_classes))
}
