// Tables 5 and 8: LUT storage accounting (pure LUT substrate, no PJRT).

/// Table 5: LUT sizes for the DETR experiments (REXP cases 1-3).
pub fn table5() -> Result<()> {
    println!("\n== Table 5: LUTs size used for DETR experiments ==");
    println!(
        "{:<10} {:>5} | {:>16} {:>7} | {:>16} {:>7} | {:>16} {:>7}",
        "precision", "bits", "case1 LUTs", "bytes", "case2 LUTs", "bytes", "case3 LUTs", "bytes"
    );
    for p in [Precision::Int16, Precision::Uint8] {
        let mut cells = Vec::new();
        for alpha in [256usize, 320, 512] {
            let t = lut::rexp_tables(p, Some(alpha));
            cells.push((
                format!("1x{} + 1x{}", t.recip_e.len(), t.alpha.len()),
                t.total_bytes(),
            ));
        }
        println!(
            "{:<10} {:>5} | {:>16} {:>7} | {:>16} {:>7} | {:>16} {:>7}",
            p.name(),
            p.w(),
            cells[0].0,
            cells[0].1,
            cells[1].0,
            cells[1].1,
            cells[2].0,
            cells[2].1
        );
    }
    println!("paper (int16): 538 / 666 / 1050 B; (uint8): 264 / 328 / 520 B");
    Ok(())
}

/// Table 8: LUT sizes for the NLP experiments.
pub fn table8() -> Result<()> {
    println!("\n== Table 8: LUTs size used for NLP experiments ==");
    println!(
        "{:<10} {:>5} | {:>18} {:>7} | {:>14} {:>7}",
        "precision", "bits", "2D-LUT tables", "bytes", "REXP tables", "bytes"
    );
    for p in lut::ALL_PRECISIONS {
        let l = lut::lut2d_tables(p, None);
        let r = lut::rexp_tables(p, None);
        println!(
            "{:<10} {:>5} | {:>18} {:>7} | {:>14} {:>7}",
            p.name(),
            p.w(),
            format!("1x{} + 11x{}", l.exp.len(), l.cols),
            l.total_bytes(),
            format!("1x{} + 1x{}", r.recip_e.len(), r.alpha.len()),
            r.total_bytes()
        );
    }
    println!("paper 2D-LUT: 1522 / 761 / 367 / 100 B; REXP: 58 / 24 / 21 / 10 B");
    println!("(uint2 REXP differs by one LUT_1/e entry: Eq.(4) yields 1x4, the paper trims to 1x3)");
    Ok(())
}

/// Table 4: PTQ-D model sizes and quantization accuracy drop.
pub fn table4(dir: &Path, args: &Args) -> Result<()> {
    let limit = args.opt_usize("samples", 400)?;
    let engine = Engine::new(dir)?;
    println!("\n== Table 4: properties of dynamically quantized PTQ-D models ==");
    println!(
        "{:<12} {:>10} {:>10} {:>7} | {:>9} {:>9} {:>7}",
        "model", "fp32 MB", "ptqd MB", "ratio%", "fp32 acc", "ptqd acc", "drop"
    );
    let mut rows = Vec::new();
    let models: Vec<String> = engine.manifest.model_bytes.keys().cloned().collect();
    for model in models {
        let (fp, pq) = engine.manifest.model_bytes[&model];
        let (metric_fp, metric_pq): (f64, f64) = match model.as_str() {
            m @ ("nmt14" | "nmt17") => (
                eval_nmt_variant(&engine, dir, m, &format!("{m}__fp32__exact__fp32"), limit)?,
                eval_nmt_variant(&engine, dir, m, &format!("{m}__ptqd__exact__fp32"), limit)?,
            ),
            m @ ("sst2" | "mrpc") => (
                eval_cls_variant(&engine, dir, m, &format!("{m}__fp32__exact__fp32"), limit)?,
                eval_cls_variant(&engine, dir, m, &format!("{m}__ptqd__exact__fp32"), limit)?,
            ),
            m => {
                let a = eval_det_variant(&engine, dir, &format!("{m}__fp32__exact__fp32"), limit)?;
                let b = eval_det_variant(&engine, dir, &format!("{m}__ptqd__exact__fp32"), limit)?;
                (a.ap * 100.0, b.ap * 100.0)
            }
        };
        let ratio = 100.0 * pq as f64 / fp as f64;
        let drop = metric_fp - metric_pq;
        println!(
            "{:<12} {:>10.2} {:>10.2} {:>7.0} | {:>9.2} {:>9.2} {:>7.2}",
            model,
            fp as f64 / 1e6,
            pq as f64 / 1e6,
            ratio,
            metric_fp,
            metric_pq,
            drop
        );
        rows.push(jobj![
            ("model", model.as_str()),
            ("fp32_bytes", fp),
            ("ptqd_bytes", pq),
            ("ratio_pct", ratio),
            ("metric_fp32", metric_fp),
            ("metric_ptqd", metric_pq),
            ("drop", drop),
        ]);
    }
    println!("paper: size ratios 41-84%, accuracy drop 0.0-0.66%");
    write_report(dir, "table4", &Json::Arr(rows))
}
