// Table 2 + Figure 3: NLP accuracy across precisions and methods.

const NLP_PRECS: [&str; 4] = ["int16", "uint8", "uint4", "uint2"];

/// Evaluate the full NLP grid. Returns
/// (model, method, precision-label) -> metric.
fn nlp_grid(
    engine: &Engine,
    dir: &Path,
    limit: usize,
) -> Result<BTreeMap<(String, String, String), f64>> {
    let mut out = BTreeMap::new();
    for model in ["nmt14", "nmt17", "sst2", "mrpc"] {
        let eval = |variant: &str| -> Result<f64> {
            if model.starts_with("nmt") {
                eval_nmt_variant(engine, dir, model, variant, limit)
            } else {
                eval_cls_variant(engine, dir, model, variant, limit)
            }
        };
        let fp32 = eval(&format!("{model}__fp32__exact__fp32"))?;
        let ptqd = eval(&format!("{model}__ptqd__exact__fp32"))?;
        for method in ["2dlut", "rexp"] {
            out.insert((model.into(), method.into(), "FP32".into()), fp32);
            out.insert((model.into(), method.into(), "PTQ-D".into()), ptqd);
            let mode = if method == "2dlut" { "lut2d" } else { "rexp" };
            for prec in NLP_PRECS {
                let v = eval(&format!("{model}__ptqd__{mode}__{prec}"))?;
                out.insert((model.into(), method.into(), prec.to_uppercase()), v);
                println!("  [{model}/{method}/{prec}] = {v:.2}");
            }
        }
    }
    Ok(out)
}

/// Table 2: experimental validation over the NLP models and datasets.
pub fn table2(dir: &Path, args: &Args) -> Result<()> {
    let limit = args.opt_usize("samples", 200)?;
    let engine = Engine::new(dir)?;
    println!("\n== Table 2: NLP validation (Transformer BLEU, BERT acc/F1) ==");
    let grid = nlp_grid(&engine, dir, limit)?;

    let rows = ["FP32", "PTQ-D", "INT16", "UINT8", "UINT4", "UINT2"];
    println!(
        "{:<7} | {:>9} {:>9} {:>9} {:>9} | {:>9} {:>9} {:>9} {:>9}",
        "prec",
        "2D nmt14",
        "2D nmt17",
        "RX nmt14",
        "RX nmt17",
        "2D sst2",
        "2D mrpc",
        "RX sst2",
        "RX mrpc"
    );
    let mut report = Vec::new();
    for r in rows {
        let g = |model: &str, method: &str| -> f64 {
            grid.get(&(model.into(), method.into(), r.into()))
                .copied()
                .unwrap_or(f64::NAN)
        };
        println!(
            "{:<7} | {:>9.2} {:>9.2} {:>9.2} {:>9.2} | {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            r,
            g("nmt14", "2dlut"),
            g("nmt17", "2dlut"),
            g("nmt14", "rexp"),
            g("nmt17", "rexp"),
            g("sst2", "2dlut"),
            g("mrpc", "2dlut"),
            g("sst2", "rexp"),
            g("mrpc", "rexp"),
        );
        report.push(jobj![
            ("precision", r),
            ("nmt14_2dlut", g("nmt14", "2dlut")),
            ("nmt17_2dlut", g("nmt17", "2dlut")),
            ("nmt14_rexp", g("nmt14", "rexp")),
            ("nmt17_rexp", g("nmt17", "rexp")),
            ("sst2_2dlut", g("sst2", "2dlut")),
            ("mrpc_2dlut", g("mrpc", "2dlut")),
            ("sst2_rexp", g("sst2", "rexp")),
            ("mrpc_rexp", g("mrpc", "rexp")),
        ]);
    }
    println!("paper shape: <1% drop down to uint8; uint2 degrades (esp. MRPC F1 via 2D LUT)");
    write_report(dir, "table2", &Json::Arr(report))
}

/// Figure 3: accuracy DROP of approximated models vs FP32 (left) and vs
/// plain PTQ-D (right).
pub fn fig3(dir: &Path, args: &Args) -> Result<()> {
    let limit = args.opt_usize("samples", 200)?;
    let engine = Engine::new(dir)?;
    println!("\n== Figure 3: NLP accuracy drop curves ==");
    let grid = nlp_grid(&engine, dir, limit)?;

    let mut report = Vec::new();
    for vs in ["FP32", "PTQ-D"] {
        println!("-- drop vs {vs} (percentage points) --");
        println!(
            "{:<7} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "prec",
            "2D nmt14",
            "2D nmt17",
            "RX nmt14",
            "RX nmt17",
            "2D sst2",
            "2D mrpc",
            "RX sst2",
            "RX mrpc"
        );
        for prec in ["INT16", "UINT8", "UINT4", "UINT2"] {
            let mut vals = Vec::new();
            for (model, method) in [
                ("nmt14", "2dlut"),
                ("nmt17", "2dlut"),
                ("nmt14", "rexp"),
                ("nmt17", "rexp"),
                ("sst2", "2dlut"),
                ("mrpc", "2dlut"),
                ("sst2", "rexp"),
                ("mrpc", "rexp"),
            ] {
                let base = grid[&(model.into(), method.into(), vs.into())];
                let v = grid[&(model.into(), method.into(), prec.into())];
                vals.push(base - v);
            }
            println!(
                "{:<7} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
                prec, vals[0], vals[1], vals[2], vals[3], vals[4], vals[5], vals[6], vals[7]
            );
            report.push(jobj![
                ("vs", vs),
                ("precision", prec),
                ("drops", vals.clone()),
            ]);
        }
    }
    println!("paper shape: drops < 1 down to uint8; drop vs PTQ-D sometimes negative (recovery)");
    write_report(dir, "fig3", &Json::Arr(report))
}
