//! `runhlo` — debug utility: execute any HLO-text file via PJRT with
//! inputs from an LTB bundle, writing outputs to another bundle.
//!
//! Usage: runhlo <file.hlo.txt> <inputs.ltb> <outputs.ltb>
//!
//! Inputs are fed in key order (name the tensors 000, 001, ... in the
//! bundle). Used to bisect python-vs-rust numerical mismatches down to a
//! single lowered computation without manifest plumbing.

use anyhow::{anyhow, Context, Result};
use lutmax::runtime::{tensorio, Tensor, TensorData};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let [hlo, inputs, outputs] = match args.as_slice() {
        [a, b, c] => [a, b, c],
        _ => return Err(anyhow!("usage: runhlo <file.hlo.txt> <in.ltb> <out.ltb>")),
    };
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e}"))?;
    let proto = xla::HloModuleProto::from_text_file(hlo).map_err(|e| anyhow!("{e}"))?;
    let exe = client
        .compile(&xla::XlaComputation::from_proto(&proto))
        .map_err(|e| anyhow!("{e}"))?;

    let bundle = tensorio::read_bundle(std::path::Path::new(inputs))?;
    let mut bufs = Vec::new();
    for (name, t) in &bundle {
        let buf = match &t.data {
            TensorData::F32(v) => client.buffer_from_host_buffer(v, &t.dims, None),
            TensorData::I32(v) => client.buffer_from_host_buffer(v, &t.dims, None),
        }
        .map_err(|e| anyhow!("{name}: {e}"))?;
        bufs.push(buf);
    }
    let result = exe.execute_b(&bufs).map_err(|e| anyhow!("{e}"))?;
    let mut out = std::collections::BTreeMap::new();
    for (i, buf) in result
        .into_iter()
        .next()
        .context("no output")?
        .into_iter()
        .enumerate()
    {
        let mut lit = buf.to_literal_sync().map_err(|e| anyhow!("{e}"))?;
        let parts = match lit.shape().map_err(|e| anyhow!("{e}"))? {
            xla::Shape::Tuple(_) => lit.decompose_tuple().map_err(|e| anyhow!("{e}"))?,
            _ => vec![lit],
        };
        for (j, p) in parts.into_iter().enumerate() {
            let shape = p.array_shape().map_err(|e| anyhow!("{e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let t = match shape.ty() {
                xla::ElementType::F32 => {
                    Tensor::f32(dims, p.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?)
                }
                xla::ElementType::S32 => {
                    Tensor::i32(dims, p.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?)
                }
                ty => return Err(anyhow!("unsupported output type {ty:?}")),
            };
            out.insert(format!("out{i}_{j}"), t);
        }
    }
    tensorio::write_bundle(std::path::Path::new(outputs), &out)?;
    println!("wrote {} outputs to {outputs}", out.len());
    Ok(())
}
