//! `lutmax` CLI — the serving binary.
//!
//! Subcommands:
//!   info                       artifact inventory + LUT summary
//!   serve  [--task ...]        start the coordinator and run a load test
//!   softmax [--mode --prec]    one-shot LUT softmax through PJRT
//!
//! Experiments and paper tables live in the `exp` binary.

use anyhow::{anyhow, Result};
use lutmax::config::{Args, ServerConfig};
use lutmax::coordinator::{Coordinator, Payload, Reply, RouteTable};
use lutmax::runtime::Tensor;
use lutmax::testkit::Rng;
use lutmax::workload;

fn main() -> Result<()> {
    let args = Args::from_env(&[
        "artifacts",
        "max-batch",
        "batch-timeout-us",
        "workers",
        "queue-depth",
        "task",
        "variant",
        "requests",
        "rate",
        "mode",
        "prec",
        "trace-out",
        "stats-json",
    ])?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("info");
    match cmd {
        "info" => info(&args),
        "serve" => serve(&args),
        "softmax" => softmax(&args),
        other => Err(anyhow!(
            "unknown command {other:?}; expected info | serve | softmax"
        )),
    }
}

fn config(args: &Args) -> Result<ServerConfig> {
    let mut cfg = ServerConfig {
        artifacts: lutmax::artifacts_dir(),
        ..Default::default()
    };
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn info(args: &Args) -> Result<()> {
    let cfg = config(args)?;
    let m = lutmax::runtime::Manifest::load(&cfg.artifacts)?;
    println!("artifacts dir : {}", m.dir.display());
    println!("artifacts     : {}", m.artifacts.len());
    let mut kinds = std::collections::BTreeMap::new();
    for a in m.artifacts.values() {
        *kinds.entry(a.kind.clone()).or_insert(0usize) += 1;
    }
    for (k, n) in kinds {
        println!("  {k:<8} {n}");
    }
    println!("models        : {:?}", m.param_order.keys().collect::<Vec<_>>());
    for p in lutmax::lut::ALL_PRECISIONS {
        let r = lutmax::lut::rexp_tables(p, None);
        let l = lutmax::lut::lut2d_tables(p, None);
        println!(
            "LUT {:>5}: rexp {:>4} B   2d-lut {:>5} B",
            p.name(),
            r.total_bytes(),
            l.total_bytes()
        );
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let mut cfg = config(args)?;
    let trace_out = args.opt("trace-out");
    let stats_out = args.opt("stats-json");
    // --trace-out arms the engine's wall-clock trace sink; metrics are
    // always on (the registry is the scheduler's source of truth)
    if trace_out.is_some() {
        cfg.trace = true;
    }
    let task = args.opt("task").unwrap_or("translate");
    let variant = args
        .opt("variant")
        .unwrap_or(match task {
            "attention" => "attn:rexp:uint8",
            "decode" => "decode:rexp:uint8:g2",
            _ => "nmt14__ptqd__rexp__uint8",
        })
        .to_string();
    let requests = args.opt_usize("requests", 64)?;
    let rate = args.opt_f64("rate", 200.0)?;

    let mut routes = RouteTable::default();
    match task {
        "translate" => routes.translate = Some(variant.clone()),
        "classify" => routes.classify = Some(variant.clone()),
        "detect" => routes.detect = Some(variant.clone()),
        // e.g. --variant softmax__rexp__uint8 or --variant cpu:rexp:uint8
        "softmax" => routes.softmax = Some(variant.clone()),
        // artifact-free fused integer attention, e.g. --variant attn:rexp:uint8
        // (the variant passes through verbatim; bad specs fail loudly at
        // AttentionPipeline::load)
        "attention" => routes.attention = Some(variant.clone()),
        // artifact-free streaming decode, e.g. --variant decode:rexp:uint8:g2
        "decode" => routes.decode = Some(variant.clone()),
        other => return Err(anyhow!("unknown task {other:?}")),
    }
    println!("starting coordinator: task={task} variant={variant}");
    let coordinator = Coordinator::start(cfg, routes)?;

    let mut rng = Rng::new(7);
    if task == "decode" {
        // arm kernel-level LUT range telemetry for the smoke window:
        // sample every softmax call so the zero-clamp check is exhaustive
        lutmax::obs::range::set_sampling(1);
        lutmax::obs::range::reset();
        serve_decode(&coordinator, &mut rng, &variant, requests, rate)?;
        report_lut_ranges()?;
        write_obs_artifacts(&coordinator, trace_out, stats_out)?;
        lutmax::obs::range::set_sampling(0);
        coordinator.shutdown()?;
        // second scenario: an arena several times smaller than the
        // session demand — the scheduler must evict/restore, not fail
        serve_decode_overcommit(config(args)?, &mut rng)?;
        // third scenario: the same route with a live fault schedule —
        // injected failures must degrade typed, never lose a reply
        return serve_decode_faults(config(args)?, &mut rng);
    }
    let gaps = workload::poisson_arrivals_us(&mut rng, requests, rate);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for gap in gaps {
        std::thread::sleep(std::time::Duration::from_micros(gap));
        let payload = match task {
            "translate" => Payload::Translate(workload::random_src_row(&mut rng, 20, 64)),
            "classify" => Payload::Classify(workload::random_cls_row(&mut rng, 24, 64)),
            "softmax" => {
                Payload::Softmax(Tensor::f32(vec![4, 64], rng.normal_vec(4 * 64, 2.0)))
            }
            "attention" => {
                let shape = lutmax::attention::AttnShape::square(1, 4, 64, 32);
                let (q, k, v) = workload::attn_qkv(&mut rng, &shape, 1.0);
                let mask = workload::attn_mask(&mut rng, &shape);
                let (causal, pad_lens) = match mask {
                    lutmax::attention::AttnMask::Dense => (false, None),
                    lutmax::attention::AttnMask::Causal => (true, None),
                    lutmax::attention::AttnMask::Padding(lens) => (false, Some(lens)),
                };
                Payload::Attention { q, k, v, causal, pad_lens }
            }
            _ => Payload::Detect(workload::random_image(&mut rng, 32, 3)),
        };
        match coordinator.submit(payload) {
            Ok(rx) => pending.push(rx),
            Err(e) => println!("rejected: {e}"),
        }
    }
    let mut ok = 0;
    for rx in pending {
        match rx.recv() {
            Ok(Reply::Error(e)) => println!("error: {e}"),
            Ok(_) => ok += 1,
            Err(_) => println!("dropped"),
        }
    }
    let dt = t0.elapsed();
    let stats = coordinator.stats()?;
    println!(
        "served {ok}/{requests} in {:.2}s ({:.1} req/s)",
        dt.as_secs_f64(),
        ok as f64 / dt.as_secs_f64()
    );
    for (task, m) in &stats.per_task {
        if m.requests == 0 {
            continue;
        }
        println!(
            "  {task:<10} n={:<5} mean batch {:.2}  latency p50 {} us  p99 {} us",
            m.requests,
            m.mean_batch_size(),
            m.latency.percentile_us(0.50),
            m.latency.percentile_us(0.99),
        );
    }
    println!("  pjrt executions: {}", stats.executions);
    coordinator.shutdown()
}

/// Session-ful decode load test: open a handful of sessions, stream
/// `steps` Poisson-paced single-token steps round-robin across them, then
/// close every session (pages must come back). Used by the CI smoke
/// (`lutmax serve --task decode`), so it FAILS if any step errors.
fn serve_decode(
    c: &Coordinator,
    rng: &mut Rng,
    variant: &str,
    steps: usize,
    rate: f64,
) -> Result<()> {
    let (h, d) = (4usize, 32usize);
    // the route's gG fixes the stored-head count the server accepts;
    // generate matching traffic (absent: MHA)
    let g = lutmax::attention::parse_decode_route(variant)
        .ok()
        .and_then(|r| r.kv_heads)
        .unwrap_or(h);
    let sessions = (steps / 8).clamp(1, 8);
    let t0 = std::time::Instant::now();
    let mut ids = Vec::with_capacity(sessions);
    for _ in 0..sessions {
        match c.call(Payload::DecodeOpen)? {
            Reply::Session(id) => ids.push(id),
            Reply::Error(e) => return Err(anyhow!("open failed: {e}")),
            other => return Err(anyhow!("unexpected open reply {other:?}")),
        }
    }
    // chunked prefill: seed every session with a short prompt block (the
    // open → prefill → step lifecycle the route serves in production)
    let prefill_tokens = 3usize;
    for &id in &ids {
        let (q, k, v) = workload::decode_prefill_chunk(rng, prefill_tokens, h, g, d, 1.0);
        match c.call(Payload::DecodePrefill { session: id, q, k, v })? {
            Reply::Prefill(t) => {
                if t.dims != vec![prefill_tokens, h, d] {
                    return Err(anyhow!("prefill reply has shape {:?}", t.dims));
                }
            }
            Reply::Error(e) => return Err(anyhow!("prefill failed: {e}")),
            other => return Err(anyhow!("unexpected prefill reply {other:?}")),
        }
    }
    let gaps = workload::poisson_arrivals_us(rng, steps, rate);
    let mut pending = Vec::with_capacity(steps);
    for (i, gap) in gaps.into_iter().enumerate() {
        std::thread::sleep(std::time::Duration::from_micros(gap));
        let (q, k, v) = workload::decode_qkv_step(rng, h, g, d, 1.0);
        match c.submit(Payload::DecodeStep { session: ids[i % ids.len()], q, k, v }) {
            Ok(rx) => pending.push(rx),
            Err(e) => println!("rejected: {e}"),
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(Reply::Token(_)) => ok += 1,
            Ok(Reply::Exhausted { pages, free_pages, retry_after_rounds }) => println!(
                "backpressure: kv pool exhausted ({free_pages} of {pages} pages free; \
                 retry after {retry_after_rounds} rounds)"
            ),
            Ok(Reply::Error(e)) => println!("error: {e}"),
            Ok(other) => println!("unexpected step reply {other:?}"),
            Err(_) => println!("dropped"),
        }
    }
    let mut pages = 0usize;
    for id in ids {
        match c.call(Payload::DecodeClose(id))? {
            Reply::Closed { pages: p } => pages += p,
            other => return Err(anyhow!("close failed: {other:?}")),
        }
    }
    let dt = t0.elapsed();
    println!(
        "decode: {ok}/{steps} steps over {sessions} sessions in {:.2}s ({:.1} steps/s); \
         {pages} KV pages freed on close",
        dt.as_secs_f64(),
        ok as f64 / dt.as_secs_f64()
    );
    let stats = c.stats()?;
    if let Some(m) = stats.per_task.get("decode") {
        println!(
            "  decode     n={:<5} mean batch {:.2}  latency p50 {} us  p99 {} us",
            m.requests,
            m.mean_batch_size(),
            m.latency.percentile_us(0.50),
            m.latency.percentile_us(0.99),
        );
        println!("  sched      {}", m.sched.summary());
    }
    println!("  pjrt executions: {}", stats.executions);
    if ok != steps {
        return Err(anyhow!("{} of {steps} decode steps failed", steps - ok));
    }
    if pages == 0 {
        return Err(anyhow!("sessions streamed {steps} steps but freed no KV pages"));
    }
    Ok(())
}

/// Print the LUT range telemetry window and assert the paper's premise
/// held over the smoke traffic: normalized (~N(0,1) logit) inputs must
/// produce ZERO saturated LUT addresses in either pass — a clamp here
/// means the quantization/threshold geometry regressed.
fn report_lut_ranges() -> Result<()> {
    let r = lutmax::obs::range::snapshot();
    println!(
        "  lut ranges sampled_calls={} pass1_clamped={} pass2_clamped={}",
        r.sampled_calls, r.pass1_clamped, r.pass2_clamped
    );
    if let Some((lo, hi)) = r.diff {
        println!("    numerator  m_q - v_q  in [{lo}, {hi}] (LUT-index units)");
    }
    if let Some((lo, hi)) = r.denom {
        println!("    denominator row sums  in [{lo}, {hi}]");
    }
    if r.sampled_calls == 0 {
        return Err(anyhow!("range telemetry was armed but sampled no softmax calls"));
    }
    if r.pass1_clamped != 0 || r.pass2_clamped != 0 {
        return Err(anyhow!(
            "normalized inputs clamped LUT indices (pass1 {} pass2 {}): range premise violated",
            r.pass1_clamped,
            r.pass2_clamped
        ));
    }
    Ok(())
}

/// Write and validate the `--trace-out` / `--stats-json` artifacts. The
/// stats snapshot must reconcile byte-for-byte with the scheduler's
/// `Counters::summary()` line; the trace must parse back as a chrome
/// `trace_event` document.
fn write_obs_artifacts(
    c: &Coordinator,
    trace_out: Option<&str>,
    stats_out: Option<&str>,
) -> Result<()> {
    if trace_out.is_none() && stats_out.is_none() {
        return Ok(());
    }
    use lutmax::config::Json;
    use lutmax::coordinator::Counters;
    let sched = c
        .stats()?
        .per_task
        .get("decode")
        .map(|m| m.sched)
        .ok_or_else(|| anyhow!("no decode metrics for the obs artifacts"))?;
    let snap = c.observability()?;
    if let Some(path) = stats_out {
        let stats = snap.stats_json.ok_or_else(|| anyhow!("no decode route: no stats json"))?;
        let text = stats.to_string_pretty();
        let parsed = Json::parse(&text).map_err(|e| anyhow!("stats json round-trip: {e}"))?;
        let got = Counters::from_stats_json(&parsed)
            .ok_or_else(|| anyhow!("stats json is missing its counters object"))?;
        if got.summary() != sched.summary() {
            return Err(anyhow!(
                "stats json does not reconcile with the sched summary:\n  file: {}\n  live: {}",
                got.summary(),
                sched.summary()
            ));
        }
        std::fs::write(path, &text)?;
        println!("  stats json -> {path} (reconciles with the sched summary)");
    }
    if let Some(path) = trace_out {
        let trace = snap
            .trace_json
            .ok_or_else(|| anyhow!("trace sink was not armed (pass --trace-out at startup)"))?;
        let text = trace.to_string_pretty();
        let parsed = Json::parse(&text).map_err(|e| anyhow!("trace round-trip: {e}"))?;
        let events = parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace is missing traceEvents"))?;
        if events.is_empty() {
            return Err(anyhow!("trace has no events after a served decode run"));
        }
        std::fs::write(path, &text)?;
        println!(
            "  trace -> {path} ({} events; open in chrome://tracing or Perfetto)",
            events.len()
        );
    }
    Ok(())
}

/// Overcommit smoke: a 4-page arena (64 KV slots) serving 6 sessions x
/// (3-token prefill + 12 steps) = 90 resident tokens of demand. The
/// continuous-batching scheduler must evict and transparently restore
/// sessions — every step still answers `Token` — and the arena must
/// come back whole: a fresh session prefills all 64 slots afterwards.
fn serve_decode_overcommit(cfg: ServerConfig, rng: &mut Rng) -> Result<()> {
    let (h, g, d) = (4usize, 2usize, 32usize);
    let variant = "decode:rexp:uint8:g2:p4";
    let (sessions, steps) = (6usize, 12usize);
    let mut routes = RouteTable::default();
    routes.decode = Some(variant.to_string());
    println!("overcommit smoke: variant={variant} sessions={sessions} steps/session={steps}");
    let c = Coordinator::start(cfg, routes)?;
    let mut ids = Vec::with_capacity(sessions);
    for _ in 0..sessions {
        match c.call(Payload::DecodeOpen)? {
            Reply::Session(id) => ids.push(id),
            other => return Err(anyhow!("open failed: {other:?}")),
        }
    }
    // six 1-page prompts already outgrow the 4-page arena: eviction
    // starts here, and no prefill may fail
    for &id in &ids {
        let (q, k, v) = workload::decode_prefill_chunk(rng, 3, h, g, d, 1.0);
        match c.call(Payload::DecodePrefill { session: id, q, k, v })? {
            Reply::Prefill(_) => {}
            other => return Err(anyhow!("prefill failed under overcommit: {other:?}")),
        }
    }
    // steps in all-sessions waves; every one must answer Token
    let mut ok = 0usize;
    for _ in 0..steps {
        let mut pending = Vec::with_capacity(sessions);
        for &id in &ids {
            let (q, k, v) = workload::decode_qkv_step(rng, h, g, d, 1.0);
            pending.push(c.submit(Payload::DecodeStep { session: id, q, k, v })?);
        }
        for rx in pending {
            match rx.recv() {
                Ok(Reply::Token(_)) => ok += 1,
                Ok(other) => return Err(anyhow!("step failed under overcommit: {other:?}")),
                Err(_) => return Err(anyhow!("step reply dropped")),
            }
        }
    }
    for id in ids {
        match c.call(Payload::DecodeClose(id))? {
            Reply::Closed { .. } => {}
            other => return Err(anyhow!("close failed: {other:?}")),
        }
    }
    // the arena must round-trip: a fresh session can prefill EVERY slot
    let id = match c.call(Payload::DecodeOpen)? {
        Reply::Session(id) => id,
        other => return Err(anyhow!("open failed: {other:?}")),
    };
    let (q, k, v) = workload::decode_prefill_chunk(rng, 64, h, g, d, 1.0);
    match c.call(Payload::DecodePrefill { session: id, q, k, v })? {
        Reply::Prefill(_) => {}
        other => return Err(anyhow!("64-token prefill after reclaim failed: {other:?}")),
    }
    match c.call(Payload::DecodeClose(id))? {
        Reply::Closed { pages: 4 } => {}
        other => return Err(anyhow!("full-arena close must free 4 pages, got {other:?}")),
    }
    let stats = c.stats()?;
    let m = stats.per_task.get("decode").ok_or_else(|| anyhow!("no decode metrics"))?;
    println!("  sched      {}", m.sched.summary());
    if m.sched.evicted == 0 {
        return Err(anyhow!("90 tokens through a 64-slot arena must evict at least once"));
    }
    if m.sched.exhausted != 0 {
        return Err(anyhow!("no session outgrows the arena alone; exhaustion must stay 0"));
    }
    println!(
        "overcommit smoke: {ok} steps served, {} evictions, {} restores",
        m.sched.evicted, m.sched.requeued
    );
    c.shutdown()
}

/// Fault smoke: the decode route with a live chaos schedule (`:f7`
/// arms spurious KV alloc failures, contained worker panics and
/// slowdowns, injected deadline sheds). 4 sessions x (prefill + 16
/// steps) of traffic; injected failures must come back as TYPED
/// degradation replies (`Error`/`Shed`/`Exhausted`) on the faulted
/// request alone — zero dropped replies, zero non-faulted steps lost,
/// closes always answered, and the serving loop must survive every
/// contained panic.
fn serve_decode_faults(cfg: ServerConfig, rng: &mut Rng) -> Result<()> {
    lutmax::faults::silence_injected_panics();
    let (h, g, d) = (4usize, 2usize, 32usize);
    let variant = "decode:rexp:uint8:g2:p8:f7";
    let (sessions, steps) = (4usize, 16usize);
    let mut routes = RouteTable::default();
    routes.decode = Some(variant.to_string());
    println!("fault smoke: variant={variant} sessions={sessions} steps/session={steps}");
    let c = Coordinator::start(cfg, routes)?;
    let mut ids = Vec::with_capacity(sessions);
    for _ in 0..sessions {
        match c.call(Payload::DecodeOpen)? {
            Reply::Session(id) => ids.push(id),
            other => return Err(anyhow!("open failed: {other:?}")),
        }
    }
    let (mut submitted, mut ok, mut faulted) = (0usize, 0usize, 0usize);
    for &id in &ids {
        let (q, k, v) = workload::decode_prefill_chunk(rng, 3, h, g, d, 1.0);
        submitted += 1;
        match c.call(Payload::DecodePrefill { session: id, q, k, v })? {
            Reply::Prefill(_) => ok += 1,
            Reply::Error(_) | Reply::Shed { .. } | Reply::Exhausted { .. } => faulted += 1,
            other => return Err(anyhow!("unexpected prefill reply {other:?}")),
        }
    }
    for _ in 0..steps {
        let mut pending = Vec::with_capacity(sessions);
        for &id in &ids {
            let (q, k, v) = workload::decode_qkv_step(rng, h, g, d, 1.0);
            submitted += 1;
            pending.push(c.submit(Payload::DecodeStep { session: id, q, k, v })?);
        }
        for rx in pending {
            match rx.recv() {
                Ok(Reply::Token(_)) => ok += 1,
                Ok(Reply::Error(_) | Reply::Shed { .. } | Reply::Exhausted { .. }) => {
                    faulted += 1
                }
                Ok(other) => return Err(anyhow!("unexpected step reply {other:?}")),
                Err(_) => return Err(anyhow!("a faulted step LOST its reply")),
            }
        }
    }
    for id in ids {
        match c.call(Payload::DecodeClose(id))? {
            Reply::Closed { .. } => {}
            other => return Err(anyhow!("close failed under faults: {other:?}")),
        }
    }
    let stats = c.stats()?;
    let m = stats.per_task.get("decode").ok_or_else(|| anyhow!("no decode metrics"))?;
    println!("  sched      {}", m.sched.summary());
    if ok + faulted != submitted {
        return Err(anyhow!(
            "{} of {submitted} requests vanished without a typed reply",
            submitted - ok - faulted
        ));
    }
    if ok == 0 {
        return Err(anyhow!("a 1-in-11 fault schedule must leave most steps serving"));
    }
    println!("fault smoke: {ok} served, {faulted} typed-degraded, 0 lost of {submitted}");
    c.shutdown()
}

fn softmax(args: &Args) -> Result<()> {
    let cfg = config(args)?;
    let mode = args.opt("mode").unwrap_or("rexp");
    let prec = args.opt("prec").unwrap_or("uint8");
    // --cpu forces the row-parallel software fallback; it also kicks in
    // (with a notice) when the artifact is not in the manifest
    let name = if args.flag("cpu") {
        format!("cpu:{mode}:{prec}")
    } else {
        let artifact = format!("softmax__{mode}__{prec}");
        let have = lutmax::runtime::Manifest::load(&cfg.artifacts)
            .map(|m| m.artifacts.contains_key(&artifact))
            .unwrap_or(false);
        if have {
            artifact
        } else {
            println!("(artifact {artifact} not found; serving via cpu:{mode}:{prec})");
            format!("cpu:{mode}:{prec}")
        }
    };
    let mut routes = RouteTable::default();
    routes.softmax = Some(name.clone());
    let coordinator = Coordinator::start(cfg, routes)?;
    let mut rng = Rng::new(1);
    let x = Tensor::f32(vec![4, 64], rng.normal_vec(4 * 64, 2.0));
    match coordinator.call(Payload::Softmax(x))? {
        Reply::Softmax(t) => {
            let row = t.row_f32(0)?;
            let sum: f32 = row.iter().sum();
            println!("{name}: row0 sum = {sum:.4}, first 8 = {:?}", &row[..8]);
        }
        Reply::Error(e) => return Err(anyhow!(e)),
        _ => unreachable!(),
    }
    coordinator.shutdown()
}
