//! Property-testing substrate (proptest is unavailable offline).
//!
//! A small deterministic PRNG (splitmix64 core + xoshiro256**) plus
//! generator helpers and a [`check`] runner that reports the failing seed
//! so any counterexample is reproducible with `PROP_SEED=<n> cargo test`.

/// xoshiro256** seeded via splitmix64 — deterministic, fast, no deps.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to spread the seed into the xoshiro state
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// uniform in [0, 1)
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// uniform integer in [lo, hi] inclusive
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// standard normal via Box-Muller
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// vector of N(0, scale) f32 values
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Run `f` over `cases` seeded cases; panics with the offending seed on
/// failure. Set `PROP_SEED` to re-run one specific case.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().expect("PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        f(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = 0xC0FF_EE00 + case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!(
                "property {name} failed at case {case}; rerun with PROP_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn int_bounds_inclusive() {
        let mut r = Rng::new(2);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.int(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 7, |_| n += 1);
        assert_eq!(n, 7);
    }
}
