//! Property-testing substrate (proptest is unavailable offline).
//!
//! A small deterministic PRNG (splitmix64 core + xoshiro256**) plus
//! generator helpers and a [`check`] runner that reports the failing seed
//! so any counterexample is reproducible with `PROP_SEED=<n> cargo test`.
//!
//! Also home of the **differential conformance sweep**
//! ([`conformance_sweep`]): one deterministic case table over
//! {mode, prec, affine (dyadic / non-dyadic), L, H, G, page_size, mask,
//! wave sessions S, arrival schedule, fault schedule, prefix-split
//! spans, spill victim policy} that
//! `rust/tests/integration_conformance.rs` drives
//! through every standing cross-layer invariant — including the
//! group-major-vs-head-major decode differential (both sweep orders
//! bit-identical across single-step, chunked-prefill and S-session
//! batched-wave variants of every case). Future PRs extend THIS table (a new
//! axis, a wider range) instead of re-deriving ad-hoc per-test
//! generators; `CONFORMANCE_FULL=1` (the CI `test-heavy` gate) widens
//! the budget.

use crate::lut::Precision;
use crate::softmax::Mode;

/// xoshiro256** seeded via splitmix64 — deterministic, fast, no deps.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to spread the seed into the xoshiro state
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// uniform in [0, 1)
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// uniform integer in [lo, hi] inclusive
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// standard normal via Box-Muller
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// vector of N(0, scale) f32 values
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Run `f` over `cases` seeded cases; panics with the offending seed on
/// failure. Set `PROP_SEED` to re-run one specific case.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().expect("PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        f(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = 0xC0FF_EE00 + case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!(
                "property {name} failed at case {case}; rerun with PROP_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Mask axis of the conformance sweep (mirrors
/// `crate::attention::AttnMask` without depending on it — tests map it,
/// generating PAD lengths from the case seed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskKind {
    Dense,
    Causal,
    Padding,
}

/// One point of the differential conformance sweep. Every field is
/// derived deterministically from the case index, so a failing case
/// reproduces from its `Debug` printout alone; `seed` feeds the
/// per-case data [`Rng`].
#[derive(Clone, Debug, PartialEq)]
pub struct ConformanceCase {
    pub mode: Mode,
    pub prec: Precision,
    /// dyadic affine scales make i8 dequantization exact in f32 — the
    /// precondition of the bit-exactness invariants; non-dyadic cases
    /// exercise the fixed-point `IntMap` path
    pub dyadic: bool,
    pub scale: f32,
    pub zero_point: i32,
    /// softmax batch rows
    pub rows: usize,
    /// softmax row length
    pub n: usize,
    /// query heads (H)
    pub heads: usize,
    /// stored K/V heads (G ∈ {1, H/2, H}, divides H)
    pub kv_heads: usize,
    pub d_head: usize,
    /// decode sequence length (T)
    pub seq_len: usize,
    pub page_size: usize,
    pub mask: MaskKind,
    /// concurrent decode sessions of the batched-wave variant — the
    /// group-major-vs-head-major differential drives every case through
    /// single steps, chunked prefills AND one `DecodeBatch` wave of this
    /// many sessions, asserting the two sweep orders bit-identical
    pub sessions: usize,
    /// arrival-schedule seed for the continuous-batching invariant: the
    /// scheduler differential derives an adversarial admit/evict/resume
    /// interleaving of the case's sessions from this seed (on an
    /// overcommitted arena) and asserts every reply bit-identical to
    /// serial per-session replay
    pub arrival: u64,
    /// fault-schedule seed for the chaos invariant (invariant 8): its
    /// bits select which fault sites a deterministic
    /// `crate::faults::FaultPlan` arms over the case's traffic; the
    /// invariant asserts non-faulted sessions replay bit-identically,
    /// every injected fault surfaces as exactly one typed reply, and
    /// the arena's free list round-trips
    pub faults: u64,
    /// requested prefix-split span count for the split-decode invariant
    /// (invariant 9): `1` = unsplit, `2` = two spans, `0` = per-page
    /// sentinel (as many spans as resident pages; the kernel clamps the
    /// request to the page count)
    pub spans: usize,
    /// victim-policy selector for the spill/drain invariant
    /// (invariant 10): indexes {YoungestId, Lru, LargestFirst,
    /// CheapestSpill} — the case's overcommit traffic is driven under
    /// that policy, mid-trace drain/restart and a forced
    /// `SpillCorrupt` replay fallback included, and every reply must
    /// stay bit-identical to serial per-session replay
    pub spill: usize,
    pub seed: u64,
}

/// `true` when the heavy CI sweep budget is requested
/// (`CONFORMANCE_FULL=1`, the `make test-heavy` gate).
pub fn conformance_full() -> bool {
    std::env::var("CONFORMANCE_FULL").map_or(false, |v| v == "1")
}

/// The deterministic conformance case table. The discrete axes ({mode} ×
/// {prec} × {dyadic} × {page_size} × {mask}) rotate round-robin so even
/// the small budget touches every value of every axis; sizes and affines
/// come from the per-case PRNG. Small budget under plain `cargo test -q`;
/// `CONFORMANCE_FULL=1` widens both the case count and the size ranges.
pub fn conformance_sweep() -> Vec<ConformanceCase> {
    let full = conformance_full();
    let budget = if full { 96 } else { 16 };
    let modes = [Mode::Rexp, Mode::Lut2d];
    let precs = [Precision::Uint8, Precision::Int16, Precision::Uint4, Precision::Uint2];
    let dyadic_scales = [1.0f32, 0.5, 0.25, 0.0625, 2.0];
    let nondyadic_scales = [0.37f32, 0.1, 0.75, 1.3];
    let page_sizes = [8usize, 64];
    let masks = [MaskKind::Dense, MaskKind::Causal, MaskKind::Padding];
    let max_seq = if full { 40 } else { 24 };
    let mut out = Vec::with_capacity(budget);
    for i in 0..budget {
        let mut rng = Rng::new(0x5EED_0000 + i as u64);
        // decomposed strides so the discrete axes CROSS instead of
        // collapsing: {mode × dyadic × prec} is a full 2×2×4 product every
        // 16 cases, and page/mask strides are chosen so both modes see
        // every page size and mask within the small budget
        let dyadic = (i / 2) % 2 == 0;
        let heads = *rng.choice(&[1usize, 2, 4, 8]);
        let mut groupings = vec![heads];
        if heads > 1 {
            groupings.push(1);
            groupings.push(heads / 2);
        }
        out.push(ConformanceCase {
            mode: modes[i % modes.len()],
            prec: precs[(i / 4) % precs.len()],
            dyadic,
            scale: if dyadic {
                *rng.choice(&dyadic_scales)
            } else {
                *rng.choice(&nondyadic_scales)
            },
            zero_point: rng.int(-24, 24) as i32,
            rows: rng.usize(1, if full { 16 } else { 8 }),
            n: rng.usize(1, if full { 128 } else { 96 }),
            heads,
            kv_heads: *rng.choice(&groupings),
            d_head: *rng.choice(&[4usize, 8, 16]),
            seq_len: rng.usize(3, max_seq),
            page_size: page_sizes[(i / 3) % page_sizes.len()],
            mask: masks[i % masks.len()],
            // drawn after d_head/seq_len so earlier fields reproduce
            // pre-PR-5 sweeps …
            sessions: rng.usize(1, if full { 6 } else { 4 }),
            // … and the arrival seed drawn after `sessions` so PR-5
            // sweeps reproduce too (each new axis appends to the draw
            // order, never reshuffles it)
            arrival: rng.next_u64(),
            // fault axis appended after `arrival`, same append-only rule
            faults: rng.next_u64(),
            // span axis appended after `faults` (same append-only rule);
            // rotates {unsplit, two spans, per-page} so every sweep
            // exercises all three split shapes
            spans: [1usize, 2, 0][rng.usize(0, 2)],
            // spill axis appended after `spans` (same append-only rule):
            // which eviction victim policy invariant 10 drives the case
            // under
            spill: rng.usize(0, 3),
            seed: 0xC0DE_0000 + i as u64,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conformance_sweep_is_deterministic_and_well_formed() {
        let a = conformance_sweep();
        let b = conformance_sweep();
        assert_eq!(a, b, "two sweeps must be identical");
        assert!(a.len() >= 16);
        let mut dyadic_seen = (false, false);
        for c in &a {
            assert!(c.heads >= 1 && c.kv_heads >= 1);
            assert_eq!(c.heads % c.kv_heads, 0, "{c:?}");
            assert!((1..=6).contains(&c.sessions), "{c:?}");
            // at least two distinct arrival seeds across the table (the
            // axis genuinely varies) — checked below over the whole sweep
            assert!(c.n >= 1 && c.rows >= 1 && c.seq_len >= 3);
            assert!(c.scale > 0.0);
            assert!(matches!(c.page_size, 8 | 64));
            if c.dyadic {
                dyadic_seen.0 = true;
                assert!(
                    [1.0f32, 0.5, 0.25, 0.0625, 2.0].contains(&c.scale),
                    "{c:?} scale not dyadic"
                );
            } else {
                dyadic_seen.1 = true;
            }
        }
        assert!(dyadic_seen.0 && dyadic_seen.1, "both affine classes swept");
        // every discrete axis value appears even at the small budget —
        // and mode × dyadic genuinely crosses (both engines get both
        // affine classes)
        for m in [Mode::Rexp, Mode::Lut2d] {
            for dy in [true, false] {
                assert!(
                    a.iter().any(|c| c.mode == m && c.dyadic == dy),
                    "{m:?} dyadic={dy} missing from the sweep"
                );
            }
        }
        for p in [Precision::Uint8, Precision::Int16, Precision::Uint4, Precision::Uint2] {
            assert!(a.iter().any(|c| c.prec == p), "{p:?} missing");
        }
        for mk in [MaskKind::Dense, MaskKind::Causal, MaskKind::Padding] {
            assert!(a.iter().any(|c| c.mask == mk));
        }
        let distinct_arrivals: std::collections::HashSet<u64> =
            a.iter().map(|c| c.arrival).collect();
        assert!(distinct_arrivals.len() > 1, "arrival axis must vary");
        let distinct_faults: std::collections::HashSet<u64> =
            a.iter().map(|c| c.faults).collect();
        assert!(distinct_faults.len() > 1, "fault axis must vary");
        for c in &a {
            assert!(matches!(c.spans, 0 | 1 | 2), "{c:?} spans out of range");
        }
        let distinct_spans: std::collections::HashSet<usize> =
            a.iter().map(|c| c.spans).collect();
        assert!(distinct_spans.len() > 1, "span axis must vary");
        for c in &a {
            assert!(c.spill <= 3, "{c:?} spill policy selector out of range");
        }
        let distinct_spills: std::collections::HashSet<usize> =
            a.iter().map(|c| c.spill).collect();
        assert!(distinct_spills.len() > 1, "spill axis must vary");
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn int_bounds_inclusive() {
        let mut r = Rng::new(2);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.int(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 7, |_| n += 1);
        assert_eq!(n, 7);
    }
}
