//! Datapath op cost table.
//!
//! Relative costs (area in adder-equivalents, energy in adder-op units,
//! latency in cycles) follow the ratios used in the ASIC softmax designs
//! the paper compares against: a w-bit array multiplier is ~w/2 adder
//! areas, an SRT/non-restoring divider is ~2 multipliers of area and
//! iterates ~w cycles unless fully pipelined, an exp unit (LUT + degree-2
//! polynomial) costs a couple of multipliers, and ROM reads are cheap and
//! single-cycle. Shifts and MSB "wiring" selections are free (that is the
//! 2D-LUT method's point).

/// A datapath operation of a softmax unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// w-bit add / subtract / compare (also max-scan steps)
    Add,
    /// w-bit integer multiply
    Mul,
    /// w-bit divide (iterative, non-pipelined)
    Div,
    /// transcendental exp evaluation (poly+LUT unit, as in [17]/[32])
    ExpUnit,
    /// transcendental ln evaluation (log-LUT + fit, as in [35])
    LnUnit,
    /// LUT/ROM read of a w-bit entry
    LutRead,
    /// bit shift / MSB selection — wiring only
    Shift,
}

/// Cost triple of one op at bit-width `w`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cost {
    /// area in adder-equivalents (amortized per instantiated unit)
    pub area: f64,
    /// energy per operation, adder-op units
    pub energy: f64,
    /// latency in cycles
    pub latency: u32,
    /// true if a new op can issue every cycle (pipelined); false = the
    /// unit stalls `latency` cycles per op (iterative divider)
    pub pipelined: bool,
}

impl OpKind {
    pub fn cost(self, w: u32) -> Cost {
        let wf = w as f64;
        match self {
            OpKind::Add => Cost { area: 1.0, energy: 1.0, latency: 1, pipelined: true },
            OpKind::Mul => Cost {
                area: wf / 2.0,
                energy: wf / 2.0,
                latency: 1,
                pipelined: true,
            },
            OpKind::Div => Cost {
                area: wf,
                energy: wf * 1.5,
                latency: w.max(4),
                pipelined: false,
            },
            OpKind::ExpUnit => Cost {
                area: wf * 1.5,
                energy: wf,
                latency: 2,
                pipelined: true,
            },
            OpKind::LnUnit => Cost {
                area: wf * 1.2,
                energy: wf * 0.8,
                latency: 2,
                pipelined: true,
            },
            OpKind::LutRead => Cost { area: 0.0, energy: 0.5, latency: 1, pipelined: true },
            OpKind::Shift => Cost { area: 0.0, energy: 0.0, latency: 0, pipelined: true },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divider_dominates_area_and_latency() {
        let w = 8;
        let div = OpKind::Div.cost(w);
        let mul = OpKind::Mul.cost(w);
        let add = OpKind::Add.cost(w);
        assert!(div.area > mul.area && mul.area > add.area);
        assert!(div.latency > add.latency);
        assert!(!div.pipelined);
    }

    #[test]
    fn wiring_is_free() {
        let s = OpKind::Shift.cost(16);
        assert_eq!(s.area, 0.0);
        assert_eq!(s.latency, 0);
    }

    #[test]
    fn costs_scale_with_width() {
        assert!(OpKind::Mul.cost(16).area > OpKind::Mul.cost(4).area);
        assert!(OpKind::Div.cost(15).latency > OpKind::Div.cost(4).latency);
    }
}
