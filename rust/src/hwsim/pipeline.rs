//! Lane-pipelined execution model.
//!
//! `lanes` parallel element pipelines stream a row's elements through the
//! pass-1 op chain, the per-row ops run once, then pass 2 streams again.
//! Pipelined ops issue every cycle (latency = fill only); non-pipelined
//! ops (the iterative divider) block their lane for `latency` cycles per
//! element — which is precisely why divider-based designs lose.

use super::design::Design;

#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// elements per row (the softmax reduction length)
    pub n: usize,
    /// number of rows
    pub rows: usize,
    /// parallel element lanes
    pub lanes: usize,
}

#[derive(Clone, Debug)]
pub struct SimReport {
    pub design: &'static str,
    pub cycles: u64,
    pub energy: f64,
    /// datapath area (adder-equivalents) for all lanes
    pub area: f64,
    pub lut_bytes: usize,
    pub elems: u64,
    /// K/V bytes swept out of the paged cache (decode models only, 0
    /// elsewhere). Proportional to the stored-head count `G`, never the
    /// query-head count `H`: the group-major kernel reads each page once
    /// per group per step.
    pub kv_bytes_read: u64,
    pub has_divider: bool,
    pub has_multiplier: bool,
}

impl SimReport {
    pub fn cycles_per_elem(&self) -> f64 {
        self.cycles as f64 / self.elems as f64
    }

    pub fn energy_per_elem(&self) -> f64 {
        self.energy / self.elems as f64
    }

    /// Publish the report's charges into a [`MetricsRegistry`] under the
    /// SAME series the serving layer measures (`kv_bytes_read_total`,
    /// `hwsim_*`; see [`crate::obs::names`]) — simulated and observed
    /// traffic compare label-for-label.
    pub fn export(&self, reg: &mut crate::obs::MetricsRegistry) {
        use crate::obs::names;
        reg.add(names::KV_BYTES_READ, self.kv_bytes_read);
        reg.add(names::HWSIM_CYCLES, self.cycles);
        // energy is a float charge; registry counters are integral, so
        // the exported series carries whole energy units (pJ-equivalent)
        reg.add(names::HWSIM_ENERGY, self.energy as u64);
    }
}

/// Cycles for `count` elements through an op chain on one lane.
fn chain_cycles(design: &Design, ops: &[super::units::OpKind], count: u64, w: u32) -> u64 {
    if count == 0 || ops.is_empty() {
        return 0;
    }
    let _ = design;
    let mut fill: u64 = 0; // pipeline depth
    let mut stall: u64 = 1; // issue interval (cycles between elements)
    for op in ops {
        let c = op.cost(w);
        fill += c.latency as u64;
        if !c.pipelined {
            stall = stall.max(c.latency as u64);
        }
    }
    fill + (count - 1) * stall
}

/// Simulate `rows` rows of `n` elements; returns aggregate report.
pub fn simulate(design: &Design, cfg: SimConfig) -> SimReport {
    let w = design.prec.w();
    let elems = (cfg.rows * cfg.n) as u64;
    let per_lane = cfg.n.div_ceil(cfg.lanes) as u64;

    let mut cycles: u64 = 0;
    let mut energy: f64 = 0.0;
    for _row in 0..cfg.rows {
        // pass 1: lanes stream elements; row time = slowest lane
        cycles += chain_cycles(design, &design.per_elem_pass1, per_lane, w);
        // per-row normalizer prep (sequential)
        cycles += design
            .per_row
            .iter()
            .map(|o| o.cost(w).latency as u64)
            .sum::<u64>();
        // pass 2
        cycles += chain_cycles(design, &design.per_elem_pass2, per_lane, w);
    }
    for op in design
        .per_elem_pass1
        .iter()
        .chain(&design.per_elem_pass2)
    {
        energy += op.cost(w).energy * elems as f64;
    }
    for op in &design.per_row {
        energy += op.cost(w).energy * cfg.rows as f64;
    }

    SimReport {
        design: design.name(),
        cycles,
        energy,
        area: design.area_per_lane() * cfg.lanes as f64,
        lut_bytes: design.lut_bytes,
        elems,
        kv_bytes_read: 0,
        has_divider: design.has_divider(),
        has_multiplier: design.has_multiplier(),
    }
}

/// Attention-block shapes for the cycle model (one problem =
/// `heads` × (`len_q`·`len_k` scores over `d_head`-deep dots)).
#[derive(Clone, Copy, Debug)]
pub struct AttnSimConfig {
    pub heads: usize,
    pub len_q: usize,
    pub len_k: usize,
    pub d_head: usize,
    /// parallel MAC / softmax element lanes
    pub lanes: usize,
}

impl AttnSimConfig {
    fn score_elems(&self) -> u64 {
        (self.heads * self.len_q * self.len_k) as u64
    }

    fn mac_ops(&self) -> u64 {
        // QK^T and probs×V each run len_q·len_k·d_head MACs per head
        2 * self.score_elems() * self.d_head as u64
    }
}

/// Cycle model of the attention block around a softmax `design` — the
/// hwsim mirror of [`crate::attention::FusedAttention`].
///
/// Per head: a QK^T MAC pass, the two-pass softmax unit over `len_q`
/// rows of `len_k` scores (the existing [`simulate`] model), and a
/// probs×V MAC pass. `fused = false` models the unfused compose instead:
/// the probability matrix is materialized and re-read (one buffer write
/// and one read per score element), and each boundary pays a
/// dequant/requant multiply — the data movement §2 of the paper calls
/// out, and what [`crate::attention::ComposedAttention`] does in
/// software. `fused = true` streams `sig_int` straight into the V-MAC.
pub fn simulate_attention(design: &Design, cfg: AttnSimConfig, fused: bool) -> SimReport {
    use super::units::OpKind::{Add, LutRead, Mul};
    let w = design.prec.w();
    let sm = simulate(
        design,
        SimConfig { n: cfg.len_k, rows: cfg.len_q, lanes: cfg.lanes },
    );
    let per_lane = |count: u64, ops: &[super::units::OpKind]| -> u64 {
        chain_cycles(design, ops, count.div_ceil(cfg.lanes as u64), w)
    };
    let head_macs = (cfg.len_q * cfg.len_k * cfg.d_head) as u64;
    let mac_cost = Mul.cost(w).energy + Add.cost(w).energy;
    // per head: QK^T MAC pass + two-pass softmax + probs×V MAC pass
    let mut cycles = cfg.heads as u64 * (2 * per_lane(head_macs, &[Mul, Add]) + sm.cycles);
    let mut energy = cfg.mac_ops() as f64 * mac_cost + cfg.heads as f64 * sm.energy;
    if !fused {
        // probability-matrix round-trip per head: buffer write + read per
        // score element (modelled as LUT-port traffic), plus a dequant
        // multiply on the way out and a requant multiply on the way back
        let head_elems = (cfg.len_q * cfg.len_k) as u64;
        cycles += cfg.heads as u64 * 2 * per_lane(head_elems, &[LutRead, Mul]);
        energy +=
            2.0 * cfg.score_elems() as f64 * (LutRead.cost(w).energy + Mul.cost(w).energy);
    }
    SimReport {
        design: design.name(),
        cycles,
        energy,
        area: design.area_per_lane() * cfg.lanes as f64,
        lut_bytes: design.lut_bytes,
        elems: cfg.score_elems(),
        kv_bytes_read: 0,
        has_divider: design.has_divider(),
        has_multiplier: design.has_multiplier(),
    }
}

/// Head-parallel aggregate of the attention block: `units` independent
/// attention units each take a contiguous block of heads — the hwsim
/// mirror of `FusedAttention::run_par` scattering head-blocks across the
/// worker pool. Latency is the slowest unit's block; area/LUT storage per
/// unit; energy unchanged.
pub fn simulate_attention_parallel(
    design: &Design,
    cfg: AttnSimConfig,
    fused: bool,
    units: usize,
) -> SimReport {
    let full = simulate_attention(design, cfg, fused);
    let units = units.max(1).min(cfg.heads.max(1));
    if units <= 1 {
        return full;
    }
    let block = cfg.heads.div_ceil(units);
    let units_used = cfg.heads.div_ceil(block);
    let slowest = simulate_attention(design, AttnSimConfig { heads: block, ..cfg }, fused);
    SimReport {
        cycles: slowest.cycles,
        area: full.area * units_used as f64,
        lut_bytes: full.lut_bytes * units_used,
        ..full
    }
}

/// Streaming-decode shapes for the cycle model: `seq_len` single-token
/// steps, the key prefix growing `1..=seq_len`, K/V gathered from
/// fixed-size pages stored **once per group** (`q_heads / kv_heads` query
/// heads share each stored head).
#[derive(Clone, Copy, Debug)]
pub struct DecodeSimConfig {
    pub q_heads: usize,
    /// stored K/V heads (G ≤ q_heads; G == q_heads is MHA)
    pub kv_heads: usize,
    /// generated tokens (decode steps)
    pub seq_len: usize,
    pub d_head: usize,
    /// tokens per KV page
    pub page_size: usize,
    /// parallel MAC / softmax element lanes
    pub lanes: usize,
}

impl DecodeSimConfig {
    /// score elements over the whole decode: `Σ_{t=1..L} q_heads · t`
    fn score_elems(&self) -> u64 {
        (self.q_heads * self.seq_len * (self.seq_len + 1) / 2) as u64
    }
}

/// Fixed per-page activation cost of the KV gather (page-table lookup +
/// row open), in cycles — the price of paging vs a monolithic buffer.
const PAGE_TOUCH_CYCLES: u64 = 2;

/// Cycle model of streaming decode around a softmax `design` — the hwsim
/// mirror of [`crate::attention::DecodeAttention`] over
/// [`crate::kv::KvPool`], **group-major** like the software sweep.
///
/// Per step `t` (prefix length `t`): a `q·K^T` MAC pass and a `sig×V` MAC
/// pass for every **query** head, a single-row softmax per query head
/// (the existing [`simulate`] model), and the page gather — the sweep
/// unit is one stored-head *group*, which reads its K and V bytes
/// exactly once per step (`2 · kv_heads · t · d_head` LUT-port reads,
/// recorded in [`SimReport::kv_bytes_read`]) and opens each of its
/// resident pages once (`kv_heads · ceil(t / page_size)` fixed
/// [`PAGE_TOUCH_CYCLES`]). Before the PR 5 group-major kernel the
/// software re-gathered pages once per *query* head (an `H/G` read
/// amplification the model did not charge); with the sweep restructured,
/// model and kernel agree: K/V traffic scales with `G`, never `H`, so
/// grouped-query heads cut decode's dominant memory traffic by
/// `q_heads / kv_heads` in bandwidth, not just storage — the trade the
/// `decode_groupmajor/*` bench labels track in software.
pub fn simulate_decode(design: &Design, cfg: DecodeSimConfig) -> SimReport {
    use super::units::OpKind::{Add, LutRead, Mul};
    let w = design.prec.w();
    let per_lane = |count: u64, ops: &[super::units::OpKind]| -> u64 {
        chain_cycles(design, ops, count.div_ceil(cfg.lanes as u64), w)
    };
    let mac_cost = Mul.cost(w).energy + Add.cost(w).energy;
    let mut cycles: u64 = 0;
    let mut energy: f64 = 0.0;
    let mut kv_bytes: u64 = 0;
    for t in 1..=cfg.seq_len {
        // QK^T + sig×V MAC passes per query head
        let macs = (cfg.q_heads * t * cfg.d_head) as u64;
        cycles += 2 * per_lane(macs, &[Mul, Add]);
        energy += 2.0 * macs as f64 * mac_cost;
        // one softmax row of length t per query head
        let sm = simulate(design, SimConfig { n: t, rows: cfg.q_heads, lanes: cfg.lanes });
        cycles += sm.cycles;
        energy += sm.energy;
        // paged K/V gather: each group sweeps its bytes and pages ONCE
        let gather = (2 * cfg.kv_heads * t * cfg.d_head) as u64;
        kv_bytes += gather;
        cycles += per_lane(gather, &[LutRead]);
        cycles +=
            cfg.kv_heads as u64 * (t as u64).div_ceil(cfg.page_size as u64) * PAGE_TOUCH_CYCLES;
        energy += gather as f64 * LutRead.cost(w).energy;
    }
    SimReport {
        design: design.name(),
        cycles,
        energy,
        area: design.area_per_lane() * cfg.lanes as f64,
        lut_bytes: design.lut_bytes,
        elems: cfg.score_elems(),
        kv_bytes_read: kv_bytes,
        has_divider: design.has_divider(),
        has_multiplier: design.has_multiplier(),
    }
}

/// Fixed per-span activation cost of the prefix-split sweep, in cycles:
/// planning the span's page range and owning/zeroing its partial buffers
/// — the hwsim mirror of the wave layer fanning one group task into
/// span units.
const SPAN_SETUP_CYCLES: u64 = 8;

/// [`simulate_decode`] with the prefix-split sweep: `spans` span units
/// per stored-head group per step, merged per query row — the hwsim
/// mirror of `DecodeAttention::step_split` and the wave layer's
/// S×G×spans units.
///
/// The MAC / softmax / gather work and the K/V traffic are **invariant**
/// in the span count: a group's spans partition its pages, so splitting
/// never re-reads ([`SimReport::kv_bytes_read`] and [`SimReport::elems`]
/// match the unsplit run exactly, as the software conformance invariant
/// demands bit-identical output). What splitting *costs* is the span
/// fan-out ([`SPAN_SETUP_CYCLES`] per span unit per step) and the merge
/// fold — each query row re-accumulates its spans' partial V sums
/// (`spans · d_head` adds per row per step) behind the global-max
/// reduction. `spans = 1` is the unsplit model, identical to
/// [`simulate_decode`]. What splitting *buys* — span workers on
/// parallel lanes — is a latency question composed via
/// [`simulate_row_parallel`]-style sharding; this model charges the
/// extra work honestly so that trade is visible.
pub fn simulate_decode_split(design: &Design, cfg: DecodeSimConfig, spans: usize) -> SimReport {
    use super::units::OpKind::Add;
    let base = simulate_decode(design, cfg);
    let spans = spans.max(1) as u64;
    if spans == 1 {
        return base;
    }
    let w = design.prec.w();
    let steps = cfg.seq_len as u64;
    let setup = cfg.kv_heads as u64 * spans * SPAN_SETUP_CYCLES;
    let folds = cfg.q_heads as u64 * spans * cfg.d_head as u64;
    let fold_cycles = chain_cycles(design, &[Add], folds.div_ceil(cfg.lanes as u64), w);
    SimReport {
        cycles: base.cycles + steps * (setup + fold_cycles),
        energy: base.energy + steps as f64 * folds as f64 * Add.cost(w).energy,
        ..base
    }
}

/// Fixed per-wave scheduling cost of a decode serving round, in cycles:
/// waking the head-task pool, fetching page tables and setting up the
/// page gather. Paid once per *round* when rounds are batched
/// ([`simulate_decode_batched`]), once per *session* per round when they
/// are not — the amortization the software `DecodeBatch` wave buys.
const WAVE_SETUP_CYCLES: u64 = 64;

/// Cycle model of `sessions` concurrent streaming-decode sessions served
/// round-robin — the hwsim mirror of the coordinator's `DecodeStepBatch`
/// rounds over [`crate::attention::DecodeBatch`].
///
/// Every session runs the full [`simulate_decode`] work (the MAC /
/// softmax / gather cycles and energy scale by `sessions` — batching
/// never changes the computed work, just as the software wave is
/// bit-identical to serial steps). The difference is scheduling:
/// `batched = true` pays [`WAVE_SETUP_CYCLES`] once per serving round
/// (one wave covers all sessions' head rows); `batched = false` models
/// PR 3's per-request scatters, paying it once per session per round.
/// The cycle delta is exactly `(S − 1) · seq_len · WAVE_SETUP_CYCLES`.
pub fn simulate_decode_batched(
    design: &Design,
    cfg: DecodeSimConfig,
    sessions: usize,
    batched: bool,
) -> SimReport {
    let slots = if batched { sessions.max(1) } else { 1 };
    simulate_decode_sched(design, cfg, sessions, slots)
}

/// Cycle model of a **budgeted** continuous-batching scheduler: `sessions`
/// concurrent decode sessions served in rounds of at most `round_slots`
/// sessions each — the hwsim mirror of `coordinator::scheduler` capping
/// round occupancy with `max_batch_total_tokens` (and evict/requeue
/// churn shrinking the effective wave).
///
/// Computed work (MACs, softmax, K/V gather) is invariant in the
/// schedule, exactly as the software contract demands — only the wave
/// wake count moves: each serving round pays one [`WAVE_SETUP_CYCLES`]
/// per *slot group*, so a full decode costs
/// `seq_len · ceil(S / round_slots)` wakes. `round_slots >= S` is the
/// fully batched wave; `round_slots == 1` degenerates to per-request
/// scatters ([`simulate_decode_batched`] delegates both ends here).
pub fn simulate_decode_sched(
    design: &Design,
    cfg: DecodeSimConfig,
    sessions: usize,
    round_slots: usize,
) -> SimReport {
    let one = simulate_decode(design, cfg);
    let s = sessions.max(1) as u64;
    let slots = (round_slots.max(1) as u64).min(s);
    let rounds = cfg.seq_len as u64;
    let wakes = rounds * s.div_ceil(slots);
    SimReport {
        cycles: s * one.cycles + wakes * WAVE_SETUP_CYCLES,
        energy: s as f64 * one.energy,
        elems: s * one.elems,
        kv_bytes_read: s * one.kv_bytes_read,
        ..one
    }
}

/// Fixed per-row activation cost of a replay-log append during a spill
/// restore: sequence bookkeeping and the valid-length bump — the price
/// of re-materializing a session token by token instead of
/// block-copying its page images.
const REPLAY_ROW_SETUP_CYCLES: u64 = 4;

/// Cycle model of restoring one evicted-to-host decode session — the
/// hwsim mirror of the coordinator's spill ladder
/// (`DecodePipeline`'s restore path over `kv::spill::SpillStore`).
///
/// A spilled session holds `tokens` tokens of K/V for `kv_heads` stored
/// heads: `2 · kv_heads · tokens · d_head` bytes either way, recorded in
/// [`SimReport::kv_bytes_read`] — **the bytes that land back in the
/// arena are invariant in the rung taken**, just as the software's
/// restore is bit-identical whichever encoding survives. What moves is
/// *how* they land:
///
/// * `replay = false` — the checksummed copy-back rung: the page images
///   stream host→arena as one bulk byte run (a fused read + checksum
///   fold per byte), and each destination page is opened once
///   (`kv_heads · ceil(tokens / page_size)` × [`PAGE_TOUCH_CYCLES`]).
/// * `replay = true` — the row-log fallback rung: every token replays as
///   its own append — a short per-row byte stream whose pipeline fill
///   never amortizes, a fixed [`REPLAY_ROW_SETUP_CYCLES`] of sequence
///   bookkeeping, and a tail-page touch per stored head per token
///   (`kv_heads · tokens` × [`PAGE_TOUCH_CYCLES`], page size can't
///   help).
///
/// So the fallback pays per *token* what copy-back pays per *page* —
/// the asymmetry the `decode_sched_spill*` bench labels measure in
/// software. The trade runs the other way in energy: copy-back's
/// checksum fold charges an add per byte, replay only bumps a counter
/// per row.
pub fn simulate_decode_spill(
    design: &Design,
    cfg: DecodeSimConfig,
    tokens: usize,
    replay: bool,
) -> SimReport {
    use super::units::OpKind::{Add, LutRead};
    let w = design.prec.w();
    let per_lane = |count: u64, ops: &[super::units::OpKind]| -> u64 {
        chain_cycles(design, ops, count.div_ceil(cfg.lanes as u64), w)
    };
    let bytes = (2 * cfg.kv_heads * tokens * cfg.d_head) as u64;
    let row_bytes = (2 * cfg.kv_heads * cfg.d_head) as u64;
    let mut cycles: u64;
    let mut energy = bytes as f64 * LutRead.cost(w).energy;
    if replay {
        cycles = tokens as u64 * (per_lane(row_bytes, &[LutRead]) + REPLAY_ROW_SETUP_CYCLES);
        cycles += (cfg.kv_heads * tokens) as u64 * PAGE_TOUCH_CYCLES;
        energy += tokens as f64 * Add.cost(w).energy;
    } else {
        cycles = per_lane(bytes, &[LutRead, Add]);
        cycles += cfg.kv_heads as u64
            * (tokens as u64).div_ceil(cfg.page_size as u64)
            * PAGE_TOUCH_CYCLES;
        energy += bytes as f64 * Add.cost(w).energy;
    }
    SimReport {
        design: design.name(),
        cycles,
        energy,
        area: design.area_per_lane() * cfg.lanes as f64,
        lut_bytes: design.lut_bytes,
        elems: bytes,
        kv_bytes_read: bytes,
        has_divider: design.has_divider(),
        has_multiplier: design.has_multiplier(),
    }
}

/// Row-parallel aggregate: `units` independent softmax units each process
/// a contiguous block of rows — the hwsim mirror of
/// [`crate::softmax::ParSoftmax`]'s sharding. Latency is the slowest
/// unit's block; area and LUT storage are instantiated per unit; total
/// energy is unchanged (same work, spread out).
pub fn simulate_row_parallel(design: &Design, cfg: SimConfig, units: usize) -> SimReport {
    let full = simulate(design, cfg);
    let units = units.max(1).min(cfg.rows.max(1));
    if units <= 1 {
        return full;
    }
    let block = cfg.rows.div_ceil(units);
    let units_used = cfg.rows.div_ceil(block);
    let slowest = simulate(design, SimConfig { rows: block, ..cfg });
    SimReport {
        cycles: slowest.cycles,
        area: full.area * units_used as f64,
        lut_bytes: full.lut_bytes * units_used,
        ..full
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::design::{Design, DesignKind};
    use crate::lut::Precision;

    fn sim(kind: DesignKind, lanes: usize) -> SimReport {
        let d = Design::new(kind, Precision::Uint8);
        simulate(&d, SimConfig { n: 128, rows: 64, lanes })
    }

    #[test]
    fn paper_designs_beat_divider_designs() {
        let div = sim(DesignKind::ExactDivider, 4);
        let rexp = sim(DesignKind::Rexp, 4);
        let l2d = sim(DesignKind::Lut2d, 4);
        assert!(rexp.cycles < div.cycles, "rexp {} div {}", rexp.cycles, div.cycles);
        assert!(l2d.cycles <= rexp.cycles);
        assert!(rexp.energy_per_elem() < div.energy_per_elem());
    }

    #[test]
    fn divider_stall_dominates() {
        // the iterative divider's issue interval (w cycles) should make the
        // exact design ~w/2x slower in pass 2 terms at large n
        let div = sim(DesignKind::ExactDivider, 1);
        let l2d = sim(DesignKind::Lut2d, 1);
        let ratio = div.cycles as f64 / l2d.cycles as f64;
        assert!(ratio > 1.5, "ratio {ratio}");
    }

    #[test]
    fn lanes_scale_throughput() {
        let one = sim(DesignKind::Rexp, 1);
        let four = sim(DesignKind::Rexp, 4);
        assert!(four.cycles < one.cycles);
        // area grows with lanes
        assert!(four.area > one.area);
    }

    #[test]
    fn energy_accounts_all_elements() {
        let r = sim(DesignKind::Rexp, 2);
        assert_eq!(r.elems, 128 * 64);
        assert!(r.energy > 0.0);
    }

    #[test]
    fn row_parallel_units_scale_latency_and_area() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = SimConfig { n: 128, rows: 64, lanes: 4 };
        let one = simulate_row_parallel(&d, cfg, 1);
        assert_eq!(one.cycles, simulate(&d, cfg).cycles);
        let four = simulate_row_parallel(&d, cfg, 4);
        // 64 rows / 4 units = 16 rows per unit: exactly 1/4 the row loop
        assert_eq!(four.cycles * 4, one.cycles);
        assert_eq!(four.area, one.area * 4.0);
        assert_eq!(four.lut_bytes, one.lut_bytes * 4);
        assert_eq!(four.energy, one.energy);
        assert_eq!(four.elems, one.elems);
        // more units than rows clamps to rows
        let huge = simulate_row_parallel(&d, SimConfig { n: 16, rows: 3, lanes: 1 }, 64);
        assert_eq!(
            huge.cycles,
            simulate(&d, SimConfig { n: 16, rows: 1, lanes: 1 }).cycles
        );
    }

    #[test]
    fn fused_attention_beats_unfused_in_cycles_and_energy() {
        let cfg = AttnSimConfig { heads: 8, len_q: 64, len_k: 64, d_head: 32, lanes: 4 };
        for kind in [DesignKind::Rexp, DesignKind::Lut2d] {
            let d = Design::new(kind, Precision::Uint8);
            let fused = simulate_attention(&d, cfg, true);
            let unfused = simulate_attention(&d, cfg, false);
            assert!(
                fused.cycles < unfused.cycles,
                "{kind:?}: fused {} unfused {}",
                fused.cycles,
                unfused.cycles
            );
            assert!(fused.energy < unfused.energy);
            assert_eq!(fused.elems, 8 * 64 * 64);
        }
    }

    #[test]
    fn attention_lanes_and_heads_scale() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = AttnSimConfig { heads: 8, len_q: 64, len_k: 64, d_head: 32, lanes: 2 };
        let wide = simulate_attention(&d, AttnSimConfig { lanes: 8, ..cfg }, true);
        let narrow = simulate_attention(&d, cfg, true);
        assert!(wide.cycles < narrow.cycles);
        // head-parallel units shard latency/area like ParSoftmax shards rows
        let one = simulate_attention_parallel(&d, cfg, true, 1);
        assert_eq!(one.cycles, narrow.cycles);
        let four = simulate_attention_parallel(&d, cfg, true, 4);
        assert_eq!(four.cycles * 4, one.cycles, "8 heads / 4 units = 2 per unit");
        assert_eq!(four.area, one.area * 4.0);
        assert_eq!(four.energy, one.energy);
        // more units than heads clamps to heads
        let many = simulate_attention_parallel(&d, cfg, true, 64);
        assert_eq!(
            many.cycles,
            simulate_attention(&d, AttnSimConfig { heads: 1, ..cfg }, true).cycles
        );
    }

    #[test]
    fn attention_model_prefers_paper_designs() {
        let cfg = AttnSimConfig { heads: 4, len_q: 32, len_k: 32, d_head: 16, lanes: 4 };
        let div = simulate_attention(&Design::new(DesignKind::ExactDivider, Precision::Uint8), cfg, true);
        let rexp = simulate_attention(&Design::new(DesignKind::Rexp, Precision::Uint8), cfg, true);
        assert!(rexp.cycles < div.cycles);
        assert!(rexp.energy < div.energy);
    }

    #[test]
    fn decode_grouped_heads_cut_gather_traffic() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 8,
            seq_len: 64,
            d_head: 32,
            page_size: 16,
            lanes: 4,
        };
        let mha = simulate_decode(&d, cfg);
        let gqa = simulate_decode(&d, DecodeSimConfig { kv_heads: 2, ..cfg });
        let mqa = simulate_decode(&d, DecodeSimConfig { kv_heads: 1, ..cfg });
        assert!(gqa.cycles < mha.cycles, "gqa {} mha {}", gqa.cycles, mha.cycles);
        assert!(gqa.energy < mha.energy);
        assert!(mqa.cycles < gqa.cycles, "fewer stored heads, less gather");
        // same score work either way
        assert_eq!(mha.elems, gqa.elems);
        assert_eq!(mha.elems, (8 * 64 * 65 / 2) as u64);
    }

    #[test]
    fn decode_kv_bytes_read_scale_with_groups_not_heads() {
        // the PR 5 model fix: K/V read traffic is per stored-head group,
        // so query-head count must not move it — only G (and the prefix)
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 2,
            seq_len: 32,
            d_head: 16,
            page_size: 8,
            lanes: 4,
        };
        let base = simulate_decode(&d, cfg);
        // closed form: Σ_{t=1..L} 2·G·t·d
        let want: u64 = (1..=32u64).map(|t| 2 * 2 * t * 16).sum();
        assert_eq!(base.kv_bytes_read, want);
        // doubling query heads doubles MAC/softmax work but not reads
        let twice_h = simulate_decode(&d, DecodeSimConfig { q_heads: 16, ..cfg });
        assert_eq!(twice_h.kv_bytes_read, base.kv_bytes_read, "H must not move K/V traffic");
        assert!(twice_h.cycles > base.cycles, "H still pays MAC/softmax cycles");
        // doubling stored heads doubles the reads
        let twice_g = simulate_decode(&d, DecodeSimConfig { kv_heads: 4, ..cfg });
        assert_eq!(twice_g.kv_bytes_read, 2 * base.kv_bytes_read);
        // non-decode models report no paged-cache traffic
        assert_eq!(simulate(&d, SimConfig { n: 16, rows: 4, lanes: 2 }).kv_bytes_read, 0);
        // and the batched-wave delta formula is untouched by the model
        // fix: still exactly (S−1)·L·WAVE_SETUP_CYCLES, with per-session
        // traffic scaling by S on both sides
        for s in [2usize, 8] {
            let b = simulate_decode_batched(&d, cfg, s, true);
            let ser = simulate_decode_batched(&d, cfg, s, false);
            assert_eq!(ser.cycles - b.cycles, (s as u64 - 1) * 32 * WAVE_SETUP_CYCLES);
            assert_eq!(b.kv_bytes_read, s as u64 * base.kv_bytes_read);
            assert_eq!(b.kv_bytes_read, ser.kv_bytes_read);
        }
    }

    #[test]
    fn split_decode_charges_fanout_and_merge_but_not_traffic() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 2,
            seq_len: 32,
            d_head: 32,
            page_size: 16,
            lanes: 4,
        };
        let base = simulate_decode(&d, cfg);
        // spans = 1 is the unsplit model, identical
        let one = simulate_decode_split(&d, cfg, 1);
        assert_eq!(one.cycles, base.cycles);
        assert_eq!(one.energy, base.energy);
        // splitting pays fan-out + merge in cycles AND energy...
        let two = simulate_decode_split(&d, cfg, 2);
        let four = simulate_decode_split(&d, cfg, 4);
        assert!(two.cycles > base.cycles);
        assert!(two.energy > base.energy);
        assert!(four.cycles > two.cycles, "more spans, more merge work");
        assert!(four.energy > two.energy);
        // ...but never re-reads or re-scores: traffic and score elements
        // are span-invariant, like the software's bit-identity contract
        assert_eq!(four.kv_bytes_read, base.kv_bytes_read);
        assert_eq!(four.elems, base.elems);
    }

    #[test]
    fn decode_cycles_grow_superlinearly_with_length() {
        // every step re-reads the whole prefix, so doubling the generated
        // length must more than double total cycles
        let d = Design::new(DesignKind::Lut2d, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 4,
            kv_heads: 4,
            seq_len: 32,
            d_head: 32,
            page_size: 16,
            lanes: 4,
        };
        let short = simulate_decode(&d, cfg);
        let long = simulate_decode(&d, DecodeSimConfig { seq_len: 64, ..cfg });
        assert!(long.cycles > 2 * short.cycles);
    }

    #[test]
    fn batched_decode_rounds_amortize_the_wave_setup() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 2,
            seq_len: 32,
            d_head: 32,
            page_size: 16,
            lanes: 4,
        };
        for s in [1usize, 4, 16] {
            let batched = simulate_decode_batched(&d, cfg, s, true);
            let serial = simulate_decode_batched(&d, cfg, s, false);
            // same computed work either way — batching is scheduling only
            assert_eq!(batched.energy, serial.energy, "s={s}");
            assert_eq!(batched.elems, serial.elems);
            assert_eq!(batched.elems, s as u64 * (8 * 32 * 33 / 2) as u64);
            // one wake per round instead of one per session per round
            assert_eq!(
                serial.cycles - batched.cycles,
                (s as u64 - 1) * 32 * WAVE_SETUP_CYCLES,
                "s={s}"
            );
            if s == 1 {
                assert_eq!(batched.cycles, serial.cycles, "one session: nothing to amortize");
            } else {
                assert!(batched.cycles < serial.cycles, "s={s}");
            }
        }
        // energy/elem is flat in S; cycles/elem strictly improves with
        // batching at S > 1
        let b16 = simulate_decode_batched(&d, cfg, 16, true);
        let s16 = simulate_decode_batched(&d, cfg, 16, false);
        assert!(b16.cycles_per_elem() < s16.cycles_per_elem());
        assert_eq!(b16.energy_per_elem(), s16.energy_per_elem());
    }

    #[test]
    fn sched_round_slots_interpolate_between_serial_and_batched() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 2,
            seq_len: 32,
            d_head: 32,
            page_size: 16,
            lanes: 4,
        };
        let s = 16usize;
        let batched = simulate_decode_batched(&d, cfg, s, true);
        let serial = simulate_decode_batched(&d, cfg, s, false);
        // the endpoints ARE the old model
        assert_eq!(simulate_decode_sched(&d, cfg, s, s).cycles, batched.cycles);
        assert_eq!(simulate_decode_sched(&d, cfg, s, 64).cycles, batched.cycles, "slots clamp to S");
        assert_eq!(simulate_decode_sched(&d, cfg, s, 1).cycles, serial.cycles);
        assert_eq!(simulate_decode_sched(&d, cfg, s, 0).cycles, serial.cycles, "slots clamp to 1");
        // budgeted rounds sit strictly between, monotone in slot count
        let mut prev = serial.cycles;
        for slots in [2usize, 4, 8] {
            let r = simulate_decode_sched(&d, cfg, s, slots);
            assert!(r.cycles < prev, "slots={slots}: {} !< {prev}", r.cycles);
            assert!(r.cycles > batched.cycles, "slots={slots}");
            // exactly seq_len · ceil(S/slots) wave wakes
            let wakes = 32 * (s as u64).div_ceil(slots as u64);
            assert_eq!(r.cycles, batched.cycles - 32 * WAVE_SETUP_CYCLES + wakes * WAVE_SETUP_CYCLES);
            // schedule is never allowed to move the computed work
            assert_eq!(r.energy, batched.energy);
            assert_eq!(r.elems, batched.elems);
            assert_eq!(r.kv_bytes_read, batched.kv_bytes_read);
            prev = r.cycles;
        }
    }

    #[test]
    fn spill_copyback_amortizes_what_replay_pays_per_token() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 2,
            seq_len: 32,
            d_head: 32,
            page_size: 16,
            lanes: 4,
        };
        let copy = simulate_decode_spill(&d, cfg, 64, false);
        let rep = simulate_decode_spill(&d, cfg, 64, true);
        // the fallback rung pays per token what copy-back pays per page
        assert!(rep.cycles > copy.cycles, "replay {} copy {}", rep.cycles, copy.cycles);
        // ...but the bytes landing in the arena are rung-invariant, like
        // the software's bit-identical restore contract
        assert_eq!(rep.kv_bytes_read, copy.kv_bytes_read);
        assert_eq!(rep.elems, copy.elems);
        assert_eq!(copy.kv_bytes_read, (2 * 2 * 64 * 32) as u64, "2·G·T·d");
        // the checksum fold is the energy price of the fast rung
        assert!(copy.energy > rep.energy);
        // the gap widens linearly in the restored prefix
        let copy2 = simulate_decode_spill(&d, cfg, 128, false);
        let rep2 = simulate_decode_spill(&d, cfg, 128, true);
        assert_eq!(copy2.kv_bytes_read, 2 * copy.kv_bytes_read);
        assert!(rep2.cycles - copy2.cycles > rep.cycles - copy.cycles);
        // restore traffic stores G heads — query-head count must not move it
        let more_h = simulate_decode_spill(&d, DecodeSimConfig { q_heads: 16, ..cfg }, 64, false);
        assert_eq!(more_h.cycles, copy.cycles);
        assert_eq!(more_h.kv_bytes_read, copy.kv_bytes_read);
        // smaller pages cost copy-back more opens; replay touches the
        // tail per token regardless, so page size can't move it
        let small = DecodeSimConfig { page_size: 4, ..cfg };
        assert!(simulate_decode_spill(&d, small, 64, false).cycles > copy.cycles);
        assert_eq!(simulate_decode_spill(&d, small, 64, true).cycles, rep.cycles);
        // an empty session restores for free on either rung
        assert_eq!(simulate_decode_spill(&d, cfg, 0, false).cycles, 0);
        assert_eq!(simulate_decode_spill(&d, cfg, 0, true).cycles, 0);
    }

    #[test]
    fn decode_smaller_pages_pay_more_page_touches() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 4,
            kv_heads: 2,
            seq_len: 64,
            d_head: 32,
            page_size: 64,
            lanes: 4,
        };
        let big = simulate_decode(&d, cfg);
        let small = simulate_decode(&d, DecodeSimConfig { page_size: 4, ..cfg });
        assert!(small.cycles > big.cycles);
        assert_eq!(small.energy, big.energy, "page size is a latency knob, not work");
    }

    #[test]
    fn export_publishes_charges_under_the_measured_series_names() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = DecodeSimConfig {
            q_heads: 8,
            kv_heads: 2,
            seq_len: 32,
            d_head: 32,
            page_size: 16,
            lanes: 4,
        };
        let r = simulate_decode(&d, cfg);
        let mut reg = crate::obs::MetricsRegistry::new();
        r.export(&mut reg);
        use crate::obs::names;
        assert_eq!(reg.counter(names::KV_BYTES_READ), r.kv_bytes_read);
        assert_eq!(reg.counter(names::HWSIM_CYCLES), r.cycles);
        assert!(r.kv_bytes_read > 0, "decode models charge KV sweeps");
        // exports accumulate like any other counter
        r.export(&mut reg);
        assert_eq!(reg.counter(names::KV_BYTES_READ), 2 * r.kv_bytes_read);
    }

    #[test]
    fn log_transform_between_divider_and_ours() {
        let div = sim(DesignKind::ExactDivider, 2);
        let log = sim(DesignKind::LogTransform, 2);
        let rexp = sim(DesignKind::Rexp, 2);
        assert!(log.cycles < div.cycles);
        assert!(rexp.cycles <= log.cycles);
    }
}
