//! Lane-pipelined execution model.
//!
//! `lanes` parallel element pipelines stream a row's elements through the
//! pass-1 op chain, the per-row ops run once, then pass 2 streams again.
//! Pipelined ops issue every cycle (latency = fill only); non-pipelined
//! ops (the iterative divider) block their lane for `latency` cycles per
//! element — which is precisely why divider-based designs lose.

use super::design::Design;

#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// elements per row (the softmax reduction length)
    pub n: usize,
    /// number of rows
    pub rows: usize,
    /// parallel element lanes
    pub lanes: usize,
}

#[derive(Clone, Debug)]
pub struct SimReport {
    pub design: &'static str,
    pub cycles: u64,
    pub energy: f64,
    /// datapath area (adder-equivalents) for all lanes
    pub area: f64,
    pub lut_bytes: usize,
    pub elems: u64,
    pub has_divider: bool,
    pub has_multiplier: bool,
}

impl SimReport {
    pub fn cycles_per_elem(&self) -> f64 {
        self.cycles as f64 / self.elems as f64
    }

    pub fn energy_per_elem(&self) -> f64 {
        self.energy / self.elems as f64
    }
}

/// Cycles for `count` elements through an op chain on one lane.
fn chain_cycles(design: &Design, ops: &[super::units::OpKind], count: u64, w: u32) -> u64 {
    if count == 0 || ops.is_empty() {
        return 0;
    }
    let _ = design;
    let mut fill: u64 = 0; // pipeline depth
    let mut stall: u64 = 1; // issue interval (cycles between elements)
    for op in ops {
        let c = op.cost(w);
        fill += c.latency as u64;
        if !c.pipelined {
            stall = stall.max(c.latency as u64);
        }
    }
    fill + (count - 1) * stall
}

/// Simulate `rows` rows of `n` elements; returns aggregate report.
pub fn simulate(design: &Design, cfg: SimConfig) -> SimReport {
    let w = design.prec.w();
    let elems = (cfg.rows * cfg.n) as u64;
    let per_lane = cfg.n.div_ceil(cfg.lanes) as u64;

    let mut cycles: u64 = 0;
    let mut energy: f64 = 0.0;
    for _row in 0..cfg.rows {
        // pass 1: lanes stream elements; row time = slowest lane
        cycles += chain_cycles(design, &design.per_elem_pass1, per_lane, w);
        // per-row normalizer prep (sequential)
        cycles += design
            .per_row
            .iter()
            .map(|o| o.cost(w).latency as u64)
            .sum::<u64>();
        // pass 2
        cycles += chain_cycles(design, &design.per_elem_pass2, per_lane, w);
    }
    for op in design
        .per_elem_pass1
        .iter()
        .chain(&design.per_elem_pass2)
    {
        energy += op.cost(w).energy * elems as f64;
    }
    for op in &design.per_row {
        energy += op.cost(w).energy * cfg.rows as f64;
    }

    SimReport {
        design: design.name(),
        cycles,
        energy,
        area: design.area_per_lane() * cfg.lanes as f64,
        lut_bytes: design.lut_bytes,
        elems,
        has_divider: design.has_divider(),
        has_multiplier: design.has_multiplier(),
    }
}

/// Row-parallel aggregate: `units` independent softmax units each process
/// a contiguous block of rows — the hwsim mirror of
/// [`crate::softmax::ParSoftmax`]'s sharding. Latency is the slowest
/// unit's block; area and LUT storage are instantiated per unit; total
/// energy is unchanged (same work, spread out).
pub fn simulate_row_parallel(design: &Design, cfg: SimConfig, units: usize) -> SimReport {
    let full = simulate(design, cfg);
    let units = units.max(1).min(cfg.rows.max(1));
    if units <= 1 {
        return full;
    }
    let block = cfg.rows.div_ceil(units);
    let units_used = cfg.rows.div_ceil(block);
    let slowest = simulate(design, SimConfig { rows: block, ..cfg });
    SimReport {
        cycles: slowest.cycles,
        area: full.area * units_used as f64,
        lut_bytes: full.lut_bytes * units_used,
        ..full
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::design::{Design, DesignKind};
    use crate::lut::Precision;

    fn sim(kind: DesignKind, lanes: usize) -> SimReport {
        let d = Design::new(kind, Precision::Uint8);
        simulate(&d, SimConfig { n: 128, rows: 64, lanes })
    }

    #[test]
    fn paper_designs_beat_divider_designs() {
        let div = sim(DesignKind::ExactDivider, 4);
        let rexp = sim(DesignKind::Rexp, 4);
        let l2d = sim(DesignKind::Lut2d, 4);
        assert!(rexp.cycles < div.cycles, "rexp {} div {}", rexp.cycles, div.cycles);
        assert!(l2d.cycles <= rexp.cycles);
        assert!(rexp.energy_per_elem() < div.energy_per_elem());
    }

    #[test]
    fn divider_stall_dominates() {
        // the iterative divider's issue interval (w cycles) should make the
        // exact design ~w/2x slower in pass 2 terms at large n
        let div = sim(DesignKind::ExactDivider, 1);
        let l2d = sim(DesignKind::Lut2d, 1);
        let ratio = div.cycles as f64 / l2d.cycles as f64;
        assert!(ratio > 1.5, "ratio {ratio}");
    }

    #[test]
    fn lanes_scale_throughput() {
        let one = sim(DesignKind::Rexp, 1);
        let four = sim(DesignKind::Rexp, 4);
        assert!(four.cycles < one.cycles);
        // area grows with lanes
        assert!(four.area > one.area);
    }

    #[test]
    fn energy_accounts_all_elements() {
        let r = sim(DesignKind::Rexp, 2);
        assert_eq!(r.elems, 128 * 64);
        assert!(r.energy > 0.0);
    }

    #[test]
    fn row_parallel_units_scale_latency_and_area() {
        let d = Design::new(DesignKind::Rexp, Precision::Uint8);
        let cfg = SimConfig { n: 128, rows: 64, lanes: 4 };
        let one = simulate_row_parallel(&d, cfg, 1);
        assert_eq!(one.cycles, simulate(&d, cfg).cycles);
        let four = simulate_row_parallel(&d, cfg, 4);
        // 64 rows / 4 units = 16 rows per unit: exactly 1/4 the row loop
        assert_eq!(four.cycles * 4, one.cycles);
        assert_eq!(four.area, one.area * 4.0);
        assert_eq!(four.lut_bytes, one.lut_bytes * 4);
        assert_eq!(four.energy, one.energy);
        assert_eq!(four.elems, one.elems);
        // more units than rows clamps to rows
        let huge = simulate_row_parallel(&d, SimConfig { n: 16, rows: 3, lanes: 1 }, 64);
        assert_eq!(
            huge.cycles,
            simulate(&d, SimConfig { n: 16, rows: 1, lanes: 1 }).cycles
        );
    }

    #[test]
    fn log_transform_between_divider_and_ours() {
        let div = sim(DesignKind::ExactDivider, 2);
        let log = sim(DesignKind::LogTransform, 2);
        let rexp = sim(DesignKind::Rexp, 2);
        assert!(log.cycles < div.cycles);
        assert!(rexp.cycles <= log.cycles);
    }
}
