//! Candidate softmax unit designs as datapath op sequences.
//!
//! Every design computes a length-`n` row in two passes over the elements
//! (the streaming structure shared by all published units):
//!
//!   pass 1, per element: normalize + exponentiate + accumulate
//!   pass 2, per row:     prepare the normalizer once
//!   pass 2, per element: produce the output
//!
//! The designs:
//! * `ExactDivider`   — baseline Eq.(2): exp unit + divider.
//! * `LogTransform`   — [32]/[35]: ln-LUT + subtract + exp, no divider.
//! * `BasicSplit`     — [26]: LUT-decomposed exp with recovery multiplies,
//!                      divider still present.
//! * `Rexp`           — paper §4.1: two LUT reads + ONE multiply.
//! * `Lut2d`          — paper §4.2: LUT reads + wiring only.

use super::units::OpKind;
use crate::lut::{lut2d_tables, rexp_tables, Precision};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DesignKind {
    ExactDivider,
    LogTransform,
    BasicSplit,
    Rexp,
    Lut2d,
}

/// A softmax unit design: op recipes + LUT storage.
#[derive(Clone, Debug)]
pub struct Design {
    pub kind: DesignKind,
    pub prec: Precision,
    /// ops applied to every element in pass 1
    pub per_elem_pass1: Vec<OpKind>,
    /// ops applied once per row between the passes
    pub per_row: Vec<OpKind>,
    /// ops applied to every element in pass 2
    pub per_elem_pass2: Vec<OpKind>,
    /// LUT/ROM storage the design instantiates
    pub lut_bytes: usize,
}

impl Design {
    pub fn new(kind: DesignKind, prec: Precision) -> Self {
        use OpKind::*;
        match kind {
            DesignKind::ExactDivider => Self {
                kind,
                prec,
                // max-subtract, exp, accumulate
                per_elem_pass1: vec![Add, ExpUnit, Add],
                per_row: vec![],
                // divide by the accumulated sum
                per_elem_pass2: vec![Div],
                lut_bytes: 0,
            },
            DesignKind::LogTransform => Self {
                kind,
                prec,
                per_elem_pass1: vec![Add, ExpUnit, Add],
                // ln(sum) once per row
                per_row: vec![LnUnit],
                // exp(x - lnsum): subtract + exp
                per_elem_pass2: vec![Add, ExpUnit],
                lut_bytes: lut_log_bytes(prec),
            },
            DesignKind::BasicSplit => Self {
                kind,
                prec,
                // split exponent: 2 LUT reads + recovery multiply + acc
                per_elem_pass1: vec![Add, LutRead, LutRead, Mul, Add],
                per_row: vec![],
                per_elem_pass2: vec![Div],
                lut_bytes: 2 * 32 * prec.bytes_per_entry(),
            },
            DesignKind::Rexp => {
                let t = rexp_tables(prec, None);
                Self {
                    kind,
                    prec,
                    // max-subtract (wiring-adjacent index) + LUT + acc
                    per_elem_pass1: vec![Add, Shift, LutRead, Add],
                    // alpha lookup once per row (MSB wiring + read)
                    per_row: vec![Shift, LutRead],
                    // one multiply + shift per element
                    per_elem_pass2: vec![Mul, Shift],
                    lut_bytes: t.total_bytes(),
                }
            }
            DesignKind::Lut2d => {
                let t = lut2d_tables(prec, None);
                Self {
                    kind,
                    prec,
                    per_elem_pass1: vec![Add, Shift, LutRead, Add],
                    per_row: vec![Shift],
                    // row-decode ROM + 2-D LUT read, addressed by wiring —
                    // no arithmetic on the datapath
                    per_elem_pass2: vec![Shift, LutRead, LutRead],
                    lut_bytes: t.total_bytes(),
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self.kind {
            DesignKind::ExactDivider => "exact_divider",
            DesignKind::LogTransform => "log_transform[32]",
            DesignKind::BasicSplit => "basic_split[26]",
            DesignKind::Rexp => "rexp(ours)",
            DesignKind::Lut2d => "lut2d(ours)",
        }
    }

    /// Does the design instantiate a divider? (the paper's headline)
    pub fn has_divider(&self) -> bool {
        self.all_ops().any(|o| o == OpKind::Div)
    }

    /// Does pass 2 use a data-dependent multiplier?
    pub fn has_multiplier(&self) -> bool {
        self.all_ops().any(|o| o == OpKind::Mul)
    }

    fn all_ops(&self) -> impl Iterator<Item = OpKind> + '_ {
        self.per_elem_pass1
            .iter()
            .chain(&self.per_row)
            .chain(&self.per_elem_pass2)
            .copied()
    }

    /// Total unit area in adder-equivalents: one instance of each distinct
    /// functional unit per lane (ROM area accounted via bytes separately).
    pub fn area_per_lane(&self) -> f64 {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        let mut area = 0.0;
        for op in self.all_ops() {
            if seen.insert(op) {
                area += op.cost(self.prec.w()).area;
            }
        }
        area
    }
}

fn lut_log_bytes(prec: Precision) -> usize {
    // [35]-style log LUT sized like the exp table
    prec.exp_len() * prec.bytes_per_entry()
}

/// The design grid used by the HW experiment and bench.
pub fn all_designs(prec: Precision) -> Vec<Design> {
    [
        DesignKind::ExactDivider,
        DesignKind::LogTransform,
        DesignKind::BasicSplit,
        DesignKind::Rexp,
        DesignKind::Lut2d,
    ]
    .into_iter()
    .map(|k| Design::new(k, prec))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_designs_have_no_divider() {
        for p in crate::lut::ALL_PRECISIONS {
            assert!(!Design::new(DesignKind::Rexp, p).has_divider());
            assert!(!Design::new(DesignKind::Lut2d, p).has_divider());
            assert!(Design::new(DesignKind::ExactDivider, p).has_divider());
            assert!(Design::new(DesignKind::BasicSplit, p).has_divider());
            assert!(!Design::new(DesignKind::LogTransform, p).has_divider());
        }
    }

    #[test]
    fn lut2d_has_no_multiplier_either() {
        let d = Design::new(DesignKind::Lut2d, Precision::Uint8);
        assert!(!d.has_multiplier());
        let r = Design::new(DesignKind::Rexp, Precision::Uint8);
        assert!(r.has_multiplier()); // exactly one Mul stage
    }

    #[test]
    fn lut_bytes_match_paper() {
        assert_eq!(Design::new(DesignKind::Rexp, Precision::Uint8).lut_bytes, 24);
        assert_eq!(Design::new(DesignKind::Lut2d, Precision::Uint8).lut_bytes, 761);
    }

    #[test]
    fn area_ordering_matches_claims() {
        let p = Precision::Uint8;
        let div = Design::new(DesignKind::ExactDivider, p).area_per_lane();
        let rexp = Design::new(DesignKind::Rexp, p).area_per_lane();
        let l2d = Design::new(DesignKind::Lut2d, p).area_per_lane();
        assert!(rexp < div, "rexp {rexp} vs divider {div}");
        assert!(l2d < rexp, "lut2d {l2d} vs rexp {rexp}");
    }
}
