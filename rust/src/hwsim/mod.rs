//! Hardware simulator: cycle / area / energy model of softmax units.
//!
//! The paper's claims are architectural — "no divider", "~700 B of LUT",
//! "minimal overhead, almost free in a DRAM-based accelerator". This
//! module makes them measurable on a common cost model: each candidate
//! design is decomposed into datapath ops whose relative area/energy/
//! latency follow the published ASIC softmax implementations ([32], [8],
//! [35]); a simple lane-pipelined execution model then yields cycles per
//! row, total area and energy per element.
//!
//! Absolute numbers are not the point (we have no 65 nm library); the
//! *ordering and factors* between designs are what the paper argues.

mod design;
mod pipeline;
mod units;

pub use design::{all_designs, Design, DesignKind};
pub use pipeline::{
    simulate, simulate_attention, simulate_attention_parallel, simulate_decode,
    simulate_decode_batched, simulate_decode_sched, simulate_decode_spill, simulate_decode_split,
    simulate_row_parallel, AttnSimConfig, DecodeSimConfig, SimConfig, SimReport,
};
pub use units::{Cost, OpKind};
