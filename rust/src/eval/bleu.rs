//! Corpus BLEU with the `multi-bleu.perl` conventions the paper uses:
//! up-to-4-gram modified precision, geometric mean, brevity penalty,
//! corpus-level statistics (not sentence-averaged).

use std::collections::HashMap;

const MAX_N: usize = 4;

/// n-gram counts of a token sequence
fn ngram_counts(toks: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if toks.len() >= n {
        for w in toks.windows(n) {
            *m.entry(w).or_default() += 1;
        }
    }
    m
}

/// Corpus BLEU over (hypothesis, reference) token pairs; returns percent
/// (0..100) like multi-bleu.perl.
pub fn bleu_corpus(pairs: &[(Vec<i32>, Vec<i32>)]) -> f64 {
    let mut match_n = [0usize; MAX_N];
    let mut total_n = [0usize; MAX_N];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (hyp, rf) in pairs {
        hyp_len += hyp.len();
        ref_len += rf.len();
        for n in 1..=MAX_N {
            let h = ngram_counts(hyp, n);
            let r = ngram_counts(rf, n);
            for (g, c) in &h {
                let rc = r.get(g).copied().unwrap_or(0);
                match_n[n - 1] += (*c).min(rc);
            }
            total_n[n - 1] += hyp.len().saturating_sub(n - 1);
        }
    }
    if hyp_len == 0 {
        return 0.0;
    }
    // smoothed log precision (multi-bleu returns 0 when any count is 0;
    // we use the standard +epsilon floor to keep short-corpus runs stable)
    let mut logp = 0.0f64;
    for n in 0..MAX_N {
        if total_n[n] == 0 {
            return 0.0;
        }
        let p = match_n[n] as f64 / total_n[n] as f64;
        if p == 0.0 {
            return 0.0;
        }
        logp += p.ln();
    }
    logp /= MAX_N as f64;
    let bp = if hyp_len > ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    100.0 * bp * logp.exp()
}

/// Single-pair BLEU convenience.
pub fn bleu(hyp: &[i32], rf: &[i32]) -> f64 {
    bleu_corpus(&[(hyp.to_vec(), rf.to_vec())])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let s = vec![5, 6, 7, 8, 9, 10];
        assert!((bleu(&s, &s) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_hypothesis_is_0() {
        assert_eq!(bleu(&[], &[1, 2, 3]), 0.0);
    }

    #[test]
    fn disjoint_is_0() {
        assert_eq!(bleu(&[1, 2, 3, 4, 5], &[6, 7, 8, 9, 10]), 0.0);
    }

    #[test]
    fn brevity_penalty_applies() {
        // hypothesis = exact prefix of the reference: precisions are 1 but
        // BP < 1 must bite
        let rf = vec![4, 5, 6, 7, 8, 9, 10, 11];
        let hyp = rf[..6].to_vec();
        let b = bleu(&hyp, &rf);
        assert!(b < 100.0 && b > 50.0, "{b}");
    }

    #[test]
    fn corpus_vs_sentence_stats() {
        // corpus BLEU pools counts; one bad pair hurts less than averaging
        let good = (vec![1, 2, 3, 4, 5, 6], vec![1, 2, 3, 4, 5, 6]);
        let bad = (vec![9, 9, 9, 9], vec![1, 2, 3, 4]);
        let pooled = bleu_corpus(&[good.clone(), bad]);
        assert!(pooled > 0.0 && pooled < 100.0);
    }

    #[test]
    fn partial_overlap_monotone() {
        let rf = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let h1 = vec![1, 2, 3, 4, 9, 9, 9, 9];
        let h2 = vec![1, 2, 3, 4, 5, 6, 9, 9];
        assert!(bleu(&h2, &rf) > bleu(&h1, &rf));
    }
}
