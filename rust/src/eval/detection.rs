//! Detection metrics: COCO-style Average Precision / Average Recall over
//! IoU thresholds 0.50:0.95, evaluated on set predictions (detr_lite).

/// A predicted box: class, confidence, center-format coords in [0, 1].
#[derive(Clone, Copy, Debug)]
pub struct DetectionBox {
    pub image: usize,
    pub class: usize,
    pub score: f64,
    pub cx: f64,
    pub cy: f64,
    pub w: f64,
    pub h: f64,
}

/// A ground-truth object.
#[derive(Clone, Copy, Debug)]
pub struct GroundTruth {
    pub image: usize,
    pub class: usize,
    pub cx: f64,
    pub cy: f64,
    pub w: f64,
    pub h: f64,
}

fn iou(a: (f64, f64, f64, f64), b: (f64, f64, f64, f64)) -> f64 {
    let (ax0, ay0, ax1, ay1) = (a.0 - a.2 / 2.0, a.1 - a.3 / 2.0, a.0 + a.2 / 2.0, a.1 + a.3 / 2.0);
    let (bx0, by0, bx1, by1) = (b.0 - b.2 / 2.0, b.1 - b.3 / 2.0, b.0 + b.2 / 2.0, b.1 + b.3 / 2.0);
    let ix = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
    let iy = (ay1.min(by1) - ay0.max(by0)).max(0.0);
    let inter = ix * iy;
    let union = (ax1 - ax0) * (ay1 - ay0) + (bx1 - bx0) * (by1 - by0) - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

/// AP at one IoU threshold for one class (VOC-style all-point interpolation).
fn ap_single(dets: &[&DetectionBox], gts: &[&GroundTruth], thr: f64) -> Option<f64> {
    if gts.is_empty() {
        return None; // class absent from ground truth: skipped in the mean
    }
    let mut order: Vec<usize> = (0..dets.len()).collect();
    order.sort_by(|&a, &b| dets[b].score.total_cmp(&dets[a].score));
    let mut matched = vec![false; gts.len()];
    let mut tp = Vec::with_capacity(dets.len());
    for &di in &order {
        let d = dets[di];
        let mut best = 0.0;
        let mut best_g = None;
        for (gi, g) in gts.iter().enumerate() {
            if g.image != d.image || matched[gi] {
                continue;
            }
            let v = iou((d.cx, d.cy, d.w, d.h), (g.cx, g.cy, g.w, g.h));
            if v > best {
                best = v;
                best_g = Some(gi);
            }
        }
        if best >= thr {
            matched[best_g.unwrap()] = true;
            tp.push(true);
        } else {
            tp.push(false);
        }
    }
    // precision-recall sweep
    let mut cum_tp = 0usize;
    let mut prec_at_recall = Vec::new();
    for (i, &hit) in tp.iter().enumerate() {
        if hit {
            cum_tp += 1;
            prec_at_recall.push((
                cum_tp as f64 / gts.len() as f64,
                cum_tp as f64 / (i + 1) as f64,
            ));
        }
    }
    // all-point interpolation: AP = sum over recall steps of max precision to the right
    let mut ap = 0.0;
    let mut prev_r = 0.0;
    for i in 0..prec_at_recall.len() {
        let (r, _) = prec_at_recall[i];
        let pmax = prec_at_recall[i..]
            .iter()
            .map(|&(_, p)| p)
            .fold(0.0, f64::max);
        ap += (r - prev_r) * pmax;
        prev_r = r;
    }
    Some(ap)
}

/// Recall at one threshold (fraction of GT matched by any detection).
fn recall_single(dets: &[&DetectionBox], gts: &[&GroundTruth], thr: f64) -> Option<f64> {
    if gts.is_empty() {
        return None;
    }
    let mut order: Vec<usize> = (0..dets.len()).collect();
    order.sort_by(|&a, &b| dets[b].score.total_cmp(&dets[a].score));
    let mut matched = vec![false; gts.len()];
    for &di in &order {
        let d = dets[di];
        let mut best = 0.0;
        let mut best_g = None;
        for (gi, g) in gts.iter().enumerate() {
            if g.image != d.image || matched[gi] {
                continue;
            }
            let v = iou((d.cx, d.cy, d.w, d.h), (g.cx, g.cy, g.w, g.h));
            if v > best {
                best = v;
                best_g = Some(gi);
            }
        }
        if best >= thr {
            matched[best_g.unwrap()] = true;
        }
    }
    Some(matched.iter().filter(|&&m| m).count() as f64 / gts.len() as f64)
}

/// Full evaluation report (the paper's Table 6/7 metric set).
#[derive(Clone, Copy, Debug, Default)]
pub struct DetEval {
    /// mean AP over IoU 0.50:0.05:0.95 (COCO "AP")
    pub ap: f64,
    pub ap50: f64,
    pub ap75: f64,
    /// mean AR over IoU 0.50:0.05:0.95
    pub ar: f64,
    pub ar50: f64,
    pub ar75: f64,
}

/// Evaluate predictions vs ground truth, macro-averaged over classes.
pub fn average_precision(
    dets: &[DetectionBox],
    gts: &[GroundTruth],
    num_classes: usize,
) -> DetEval {
    let thrs: Vec<f64> = (0..10).map(|i| 0.5 + 0.05 * i as f64).collect();
    let mut ap_sum = 0.0;
    let mut ap50_sum = 0.0;
    let mut ap75_sum = 0.0;
    let mut ar_sum = 0.0;
    let mut ar50_sum = 0.0;
    let mut ar75_sum = 0.0;
    let mut classes = 0usize;
    for c in 0..num_classes {
        let d: Vec<&DetectionBox> = dets.iter().filter(|d| d.class == c).collect();
        let g: Vec<&GroundTruth> = gts.iter().filter(|g| g.class == c).collect();
        if g.is_empty() {
            continue;
        }
        classes += 1;
        let mut aps = Vec::new();
        let mut ars = Vec::new();
        for &t in &thrs {
            aps.push(ap_single(&d, &g, t).unwrap_or(0.0));
            ars.push(recall_single(&d, &g, t).unwrap_or(0.0));
        }
        ap_sum += aps.iter().sum::<f64>() / thrs.len() as f64;
        ap50_sum += aps[0];
        ap75_sum += aps[5];
        ar_sum += ars.iter().sum::<f64>() / thrs.len() as f64;
        ar50_sum += ars[0];
        ar75_sum += ars[5];
    }
    if classes == 0 {
        return DetEval::default();
    }
    let k = classes as f64;
    DetEval {
        ap: ap_sum / k,
        ap50: ap50_sum / k,
        ap75: ap75_sum / k,
        ar: ar_sum / k,
        ar50: ar50_sum / k,
        ar75: ar75_sum / k,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(image: usize, class: usize, score: f64, c: (f64, f64, f64, f64)) -> DetectionBox {
        DetectionBox { image, class, score, cx: c.0, cy: c.1, w: c.2, h: c.3 }
    }

    fn g(image: usize, class: usize, c: (f64, f64, f64, f64)) -> GroundTruth {
        GroundTruth { image, class, cx: c.0, cy: c.1, w: c.2, h: c.3 }
    }

    #[test]
    fn iou_identical_is_one() {
        assert!((iou((0.5, 0.5, 0.2, 0.2), (0.5, 0.5, 0.2, 0.2)) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        assert_eq!(iou((0.2, 0.2, 0.1, 0.1), (0.8, 0.8, 0.1, 0.1)), 0.0);
    }

    #[test]
    fn perfect_detection_ap_one() {
        let gts = vec![g(0, 0, (0.5, 0.5, 0.2, 0.2)), g(1, 0, (0.3, 0.3, 0.4, 0.4))];
        let dets = vec![
            d(0, 0, 0.9, (0.5, 0.5, 0.2, 0.2)),
            d(1, 0, 0.8, (0.3, 0.3, 0.4, 0.4)),
        ];
        let e = average_precision(&dets, &gts, 1);
        assert!((e.ap - 1.0).abs() < 1e-9, "{e:?}");
        assert!((e.ar - 1.0).abs() < 1e-9);
    }

    #[test]
    fn no_detections_ap_zero() {
        let gts = vec![g(0, 0, (0.5, 0.5, 0.2, 0.2))];
        let e = average_precision(&[], &gts, 1);
        assert_eq!(e.ap, 0.0);
    }

    #[test]
    fn false_positive_lowers_precision_not_recall() {
        let gts = vec![g(0, 0, (0.5, 0.5, 0.2, 0.2))];
        let dets = vec![
            d(0, 0, 0.9, (0.5, 0.5, 0.2, 0.2)),
            d(0, 0, 0.95, (0.1, 0.1, 0.05, 0.05)), // high-scoring FP
        ];
        let e = average_precision(&dets, &gts, 1);
        assert!(e.ap < 1.0);
        assert!((e.ar50 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn loose_box_passes_50_fails_75() {
        // IoU ~ 0.58: counts at 0.50, not at 0.75
        let gts = vec![g(0, 0, (0.5, 0.5, 0.30, 0.30))];
        let dets = vec![d(0, 0, 0.9, (0.55, 0.5, 0.30, 0.30))];
        let e = average_precision(&dets, &gts, 1);
        assert!(e.ap50 > 0.9, "{e:?}");
        assert!(e.ap75 < 0.1, "{e:?}");
    }

    #[test]
    fn class_confusion_is_penalized() {
        let gts = vec![g(0, 1, (0.5, 0.5, 0.2, 0.2))];
        let dets = vec![d(0, 0, 0.9, (0.5, 0.5, 0.2, 0.2))]; // wrong class
        let e = average_precision(&dets, &gts, 2);
        assert_eq!(e.ap, 0.0);
    }
}
