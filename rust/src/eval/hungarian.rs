//! Hungarian algorithm (Kuhn-Munkres, O(n^3) Jonker-style potentials) for
//! minimum-cost bipartite assignment — the DETR matcher substrate.

/// Solve min-cost assignment for an `n x m` cost matrix (row-major).
/// Returns `assign[row] = Some(col)` for the min(n, m) matched rows.
pub fn hungarian_min(cost: &[f64], n: usize, m: usize) -> Vec<Option<usize>> {
    if n == 0 || m == 0 {
        return vec![None; n];
    }
    // pad to square with transposition handled by working on rows <= cols
    let transpose = n > m;
    let (rows, cols) = if transpose { (m, n) } else { (n, m) };
    let at = |r: usize, c: usize| -> f64 {
        if transpose {
            cost[c * m + r]
        } else {
            cost[r * m + c]
        }
    };

    // potentials + matching (1-based internal arrays, classic formulation)
    let inf = f64::INFINITY;
    let mut u = vec![0.0; rows + 1];
    let mut v = vec![0.0; cols + 1];
    let mut p = vec![0usize; cols + 1]; // p[col] = row matched to col
    let mut way = vec![0usize; cols + 1];
    for i in 1..=rows {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; cols + 1];
        let mut used = vec![false; cols + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=cols {
                if used[j] {
                    continue;
                }
                let cur = at(i0 - 1, j - 1) - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=cols {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut row_to_col = vec![None; rows];
    for j in 1..=cols {
        if p[j] != 0 {
            row_to_col[p[j] - 1] = Some(j - 1);
        }
    }
    if transpose {
        // row_to_col maps cost-columns -> cost-rows; invert
        let mut out = vec![None; n];
        for (c, r) in row_to_col.into_iter().enumerate() {
            if let Some(r) = r {
                out[r] = Some(c);
            }
        }
        out
    } else {
        row_to_col
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn total(cost: &[f64], m: usize, assign: &[Option<usize>]) -> f64 {
        assign
            .iter()
            .enumerate()
            .filter_map(|(r, c)| c.map(|c| cost[r * m + c]))
            .sum()
    }

    #[test]
    fn identity_diagonal() {
        // strongly diagonal-dominant: optimal is the diagonal
        let cost = vec![
            0.0, 9.0, 9.0, //
            9.0, 0.0, 9.0, //
            9.0, 9.0, 0.0,
        ];
        let a = hungarian_min(&cost, 3, 3);
        assert_eq!(a, vec![Some(0), Some(1), Some(2)]);
    }

    #[test]
    fn classic_example() {
        // known optimum 5 (1+2+2? -> rows pick 2,1,2)
        let cost = vec![
            4.0, 1.0, 3.0, //
            2.0, 0.0, 5.0, //
            3.0, 2.0, 2.0,
        ];
        let a = hungarian_min(&cost, 3, 3);
        assert_eq!(total(&cost, 3, &a), 5.0);
    }

    #[test]
    fn rectangular_more_rows() {
        let cost = vec![
            1.0, 10.0, //
            10.0, 1.0, //
            5.0, 5.0,
        ];
        let a = hungarian_min(&cost, 3, 2);
        // only two rows can be matched
        let matched: Vec<_> = a.iter().flatten().collect();
        assert_eq!(matched.len(), 2);
        assert_eq!(total(&cost, 2, &a), 2.0);
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        testkit::check("hungarian vs brute force", 40, |rng| {
            let n = rng.usize(1, 5);
            let m = rng.usize(1, 5);
            let cost: Vec<f64> = (0..n * m).map(|_| rng.f64() * 10.0).collect();
            let a = hungarian_min(&cost, n, m);
            let got = total(&cost, m, &a);
            // brute force over column permutations of min(n,m) size
            let best = brute(&cost, n, m);
            assert!((got - best).abs() < 1e-9, "got {got}, best {best}");
        });
    }

    fn brute(cost: &[f64], n: usize, m: usize) -> f64 {
        let k = n.min(m);
        let cols: Vec<usize> = (0..m).collect();
        let rows: Vec<usize> = (0..n).collect();
        let mut best = f64::INFINITY;
        // choose k rows (if n > m) and permute columns
        fn perms(items: &[usize]) -> Vec<Vec<usize>> {
            if items.len() <= 1 {
                return vec![items.to_vec()];
            }
            let mut out = Vec::new();
            for (i, &x) in items.iter().enumerate() {
                let rest: Vec<usize> =
                    items.iter().enumerate().filter(|(j, _)| *j != i).map(|(_, &v)| v).collect();
                for mut p in perms(&rest) {
                    p.insert(0, x);
                    out.push(p);
                }
            }
            out
        }
        fn combos(items: &[usize], k: usize) -> Vec<Vec<usize>> {
            if k == 0 {
                return vec![vec![]];
            }
            if items.len() < k {
                return vec![];
            }
            let mut out = Vec::new();
            for i in 0..items.len() {
                for mut c in combos(&items[i + 1..], k - 1) {
                    c.insert(0, items[i]);
                    out.push(c);
                }
            }
            out
        }
        for rsel in combos(&rows, k) {
            for csel in combos(&cols, k) {
                for cp in perms(&csel) {
                    let s: f64 = rsel.iter().zip(&cp).map(|(&r, &c)| cost[r * m + c]).sum();
                    best = best.min(s);
                }
            }
        }
        best
    }
}
