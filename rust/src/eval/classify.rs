//! Classification metrics: accuracy (SST-2 analog) and binary F1 on the
//! positive class (MRPC analog — the paper follows GLUE and reports F1
//! because MRPC is 68/32 imbalanced).

#[derive(Clone, Copy, Debug, Default)]
pub struct ClassifyReport {
    pub total: usize,
    pub correct: usize,
    pub tp: usize,
    pub fp: usize,
    pub fn_: usize,
}

impl ClassifyReport {
    pub fn from_preds(preds: &[i32], labels: &[i32]) -> Self {
        assert_eq!(preds.len(), labels.len());
        let mut r = Self { total: preds.len(), ..Default::default() };
        for (&p, &l) in preds.iter().zip(labels) {
            if p == l {
                r.correct += 1;
            }
            match (p, l) {
                (1, 1) => r.tp += 1,
                (1, 0) => r.fp += 1,
                (0, 1) => r.fn_ += 1,
                _ => {}
            }
        }
        r
    }

    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.correct as f64 / self.total as f64
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }

    pub fn f1(&self) -> f64 {
        let (p, r) = (self.precision(), self.recall());
        if p + r == 0.0 {
            return 0.0;
        }
        2.0 * p * r / (p + r)
    }
}

/// accuracy in percent (Table 2's SST-2 column)
pub fn accuracy(preds: &[i32], labels: &[i32]) -> f64 {
    ClassifyReport::from_preds(preds, labels).accuracy() * 100.0
}

/// binary F1 in percent (Table 2's MRPC column)
pub fn f1_binary(preds: &[i32], labels: &[i32]) -> f64 {
    ClassifyReport::from_preds(preds, labels).f1() * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect() {
        let l = vec![0, 1, 1, 0];
        assert_eq!(accuracy(&l, &l), 100.0);
        assert_eq!(f1_binary(&l, &l), 100.0);
    }

    #[test]
    fn all_wrong() {
        let p = vec![1, 0, 0, 1];
        let l = vec![0, 1, 1, 0];
        assert_eq!(accuracy(&p, &l), 0.0);
        assert_eq!(f1_binary(&p, &l), 0.0);
    }

    #[test]
    fn f1_counts() {
        // tp=1 (idx0), fp=1 (idx1), fn=1 (idx2), tn=1 (idx3)
        let p = vec![1, 1, 0, 0];
        let l = vec![1, 0, 1, 0];
        let r = ClassifyReport::from_preds(&p, &l);
        assert_eq!((r.tp, r.fp, r.fn_), (1, 1, 1));
        assert!((r.f1() - 0.5).abs() < 1e-9);
        assert_eq!(r.accuracy(), 0.5);
    }

    #[test]
    fn degenerate_no_positive_predictions() {
        let p = vec![0, 0];
        let l = vec![1, 1];
        assert_eq!(f1_binary(&p, &l), 0.0);
    }
}
