//! Evaluation metrics: BLEU (multi-bleu semantics), classification
//! accuracy/F1, and COCO-style AP/AR with Hungarian-free greedy IoU
//! matching over set predictions.

mod bleu;
mod classify;
mod detection;
mod hungarian;

pub use bleu::{bleu, bleu_corpus};
pub use classify::{accuracy, f1_binary, ClassifyReport};
pub use detection::{average_precision, DetEval, DetectionBox, GroundTruth};
pub use hungarian::hungarian_min;
