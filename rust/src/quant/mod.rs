//! Integer quantization substrate (PTQ-D int8 per-tensor affine, mirroring
//! `python/compile/quant.py` / torch dynamic quantization defaults).

/// int8 range of the affine scheme
pub const QMIN: i32 = -128;
pub const QMAX: i32 = 127;

/// Per-tensor affine parameters covering `[min(x), max(x)] U {0}`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Affine {
    pub scale: f32,
    pub zero_point: i32,
}

impl Affine {
    pub fn fit(x: &[f32]) -> Self {
        let (mut lo, mut hi) = (0.0f32, 0.0f32);
        for &v in x {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let scale = ((hi - lo) / (QMAX - QMIN) as f32).max(1e-12);
        let zp = (QMIN as f32 - lo / scale).round().clamp(QMIN as f32, QMAX as f32);
        Self { scale, zero_point: zp as i32 }
    }

    #[inline]
    pub fn quantize(&self, v: f32) -> i8 {
        ((v / self.scale).round() as i32 + self.zero_point).clamp(QMIN, QMAX) as i8
    }

    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }
}

/// Quantize a tensor; returns (int8 data, affine params).
pub fn quantize(x: &[f32]) -> (Vec<i8>, Affine) {
    let a = Affine::fit(x);
    (x.iter().map(|&v| a.quantize(v)).collect(), a)
}

/// Quantize into a caller-provided buffer with fixed affine params
/// (used by `attention::QuantTensor::quantize_with`, which pins the
/// affine instead of fitting it — e.g. the dyadic-scale bit-exactness
/// tests). The serving ingress currently uses the allocating
/// [`quantize`]; switch it to this + pooled buffers if per-request
/// allocation ever shows up in profiles.
pub fn quantize_into(x: &[f32], a: Affine, out: &mut [i8]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = a.quantize(v);
    }
}

/// Dequantize into a caller-provided buffer (the unfused baseline's
/// explicit int8 -> f32 materialization pass).
pub fn dequantize_into(q: &[i8], a: Affine, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for (o, &v) in out.iter_mut().zip(q) {
        *o = a.dequantize(v);
    }
}

/// Quantize-dequantize round trip ("fake quant") — the graph-side op.
pub fn fake_quant(x: &[f32]) -> Vec<f32> {
    let a = Affine::fit(x);
    x.iter().map(|&v| a.dequantize(a.quantize(v))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        testkit::check("affine roundtrip", 25, |rng| {
            let n = rng.usize(2, 200);
            let x = rng.normal_vec(n, 2.0);
            let a = Affine::fit(&x);
            for &v in &x {
                let err = (a.dequantize(a.quantize(v)) - v).abs();
                assert!(err <= a.scale * 0.5 + 1e-6, "err {err} scale {}", a.scale);
            }
        });
    }

    #[test]
    fn zero_maps_near_zero() {
        // affine with zero in range keeps 0 representable within half a step
        let x = vec![-1.0, 0.0, 3.0];
        let a = Affine::fit(&x);
        assert!(a.dequantize(a.quantize(0.0)).abs() <= a.scale * 0.5);
    }

    #[test]
    fn constant_tensor() {
        let (q, a) = quantize(&[0.5; 8]);
        for &v in &q {
            assert!((a.dequantize(v) - 0.5).abs() <= a.scale);
        }
    }

    #[test]
    fn into_variants_match_allocating_paths() {
        let mut rng = testkit::Rng::new(3);
        let x = rng.normal_vec(96, 1.5);
        let (q, a) = quantize(&x);
        let mut qb = vec![0i8; x.len()];
        quantize_into(&x, a, &mut qb);
        assert_eq!(q, qb);
        let mut fb = vec![0.0f32; x.len()];
        dequantize_into(&q, a, &mut fb);
        for (&qi, &fi) in q.iter().zip(&fb) {
            assert_eq!(a.dequantize(qi), fi);
        }
    }

    #[test]
    fn fake_quant_idempotent() {
        let mut rng = testkit::Rng::new(2);
        let x = rng.normal_vec(64, 1.0);
        let once = fake_quant(&x);
        let twice = fake_quant(&once);
        for (a, b) in once.iter().zip(&twice) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
