//! Model pipelines over the PJRT engine: translate / classify / detect.
//!
//! These are shared by the serving loop AND the experiment harness (the
//! `exp` binary evaluates Table 2/6/7 through exactly the code that
//! serves requests). The rust side owns the autoregressive decode loop;
//! the artifacts are single fixed-shape steps.

use anyhow::{anyhow, Result};

use crate::eval::DetectionBox;
use crate::runtime::{Engine, ModelRunner, Tensor};
use crate::softmax::{SoftmaxEngine, SoftmaxExact};
use crate::workload::{BOS, EOS, PAD};

/// NMT encoder + decode-step pair with greedy decoding.
pub struct NmtPipeline {
    enc: ModelRunner,
    dec: ModelRunner,
    pub batch: usize,
    pub max_src: usize,
    pub max_tgt: usize,
    pub variant: String,
}

impl NmtPipeline {
    /// `variant` e.g. `"nmt14__ptqd__rexp__uint8"`.
    pub fn load(engine: &Engine, variant: &str) -> Result<Self> {
        let enc = engine.model_runner(&format!("{variant}__enc"))?;
        let dec = engine.model_runner(&format!("{variant}__dec"))?;
        let m = &engine.manifest;
        Ok(Self {
            enc,
            dec,
            batch: m.batch_nmt,
            max_src: m.nmt_max_src,
            max_tgt: m.nmt_max_tgt,
            variant: variant.to_string(),
        })
    }

    /// Greedy-decode a set of padded source rows (any count; batched and
    /// tail-padded internally). Returns EOS-terminated outputs, BOS/PAD
    /// stripped.
    pub fn translate(&self, engine: &Engine, src_rows: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let mut out = Vec::with_capacity(src_rows.len());
        for chunk in src_rows.chunks(self.batch) {
            out.extend(self.translate_batch(engine, chunk)?);
        }
        Ok(out)
    }

    fn translate_batch(&self, engine: &Engine, rows: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let b = self.batch;
        let mut src = vec![PAD; b * self.max_src];
        for (i, row) in rows.iter().enumerate() {
            if row.len() != self.max_src {
                return Err(anyhow!(
                    "source row {} has length {}, expected {}",
                    i,
                    row.len(),
                    self.max_src
                ));
            }
            src[i * self.max_src..(i + 1) * self.max_src].copy_from_slice(row);
        }
        let src_t = Tensor::i32(vec![b, self.max_src], src);
        let memory = engine
            .run_model(&self.enc, &[src_t.clone()])?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("encoder produced no output"))?;

        // §Perf: memory + src stay device-resident across the decode loop;
        // only the (small) growing tgt tensor is uploaded per step.
        let memory_dev = engine.host_to_device(&memory)?;
        let src_dev = engine.host_to_device(&src_t)?;

        let vocab = engine.manifest.nmt_vocab;
        let mut tgt = vec![PAD; b * self.max_tgt];
        for i in 0..b {
            tgt[i * self.max_tgt] = BOS;
        }
        let mut done = vec![false; rows.len()];
        for t in 1..self.max_tgt {
            let tgt_t = Tensor::i32(vec![b, self.max_tgt], tgt.clone());
            let logits = engine
                .run_model_mixed(
                    &self.dec,
                    &[None, None, Some(&tgt_t)],
                    &[&memory_dev, &src_dev],
                )?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("decoder produced no output"))?;
            let lv = logits.as_f32()?;
            // logits shape (b, max_tgt, vocab); position t-1 predicts token t
            for (i, d) in done.iter_mut().enumerate() {
                if *d {
                    continue;
                }
                let base = (i * self.max_tgt + (t - 1)) * vocab;
                let row = &lv[base..base + vocab];
                let mut best = 0usize;
                for (k, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = k;
                    }
                }
                let tok = best as i32;
                tgt[i * self.max_tgt + t] = tok;
                if tok == EOS {
                    *d = true;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }

        Ok((0..rows.len())
            .map(|i| {
                let row = &tgt[i * self.max_tgt..(i + 1) * self.max_tgt];
                row[1..]
                    .iter()
                    .copied()
                    .take_while(|&t| t != EOS && t != PAD)
                    .collect()
            })
            .collect())
    }
}

/// Encoder-classifier pipeline (sst2 / mrpc variants).
pub struct ClsPipeline {
    runner: ModelRunner,
    pub batch: usize,
    pub max_len: usize,
    pub variant: String,
}

impl ClsPipeline {
    pub fn load(engine: &Engine, variant: &str) -> Result<Self> {
        let runner = engine.model_runner(&format!("{variant}__cls"))?;
        let max_len = runner
            .meta
            .inputs
            .first()
            .map(|(d, _)| d[1])
            .ok_or_else(|| anyhow!("classifier artifact has no inputs"))?;
        Ok(Self {
            batch: engine.manifest.batch_cls,
            runner,
            max_len,
            variant: variant.to_string(),
        })
    }

    pub fn classify(&self, engine: &Engine, rows: &[Vec<i32>]) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.batch) {
            let mut toks = vec![PAD; self.batch * self.max_len];
            for (i, row) in chunk.iter().enumerate() {
                toks[i * self.max_len..(i + 1) * self.max_len].copy_from_slice(row);
            }
            let logits = engine
                .run_model(&self.runner, &[Tensor::i32(vec![self.batch, self.max_len], toks)])?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("classifier produced no output"))?;
            let lv = logits.as_f32()?;
            let classes = logits.dims[1];
            for i in 0..chunk.len() {
                let row = &lv[i * classes..(i + 1) * classes];
                let mut best = 0usize;
                for (k, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = k;
                    }
                }
                out.push(best as i32);
            }
        }
        Ok(out)
    }
}

/// Set-prediction detector pipeline (detr / detr_dc5 variants).
pub struct DetPipeline {
    runner: ModelRunner,
    pub batch: usize,
    pub image_dims: Vec<usize>,
    pub num_classes: usize,
    pub score_threshold: f64,
    pub variant: String,
}

impl DetPipeline {
    pub fn load(engine: &Engine, variant: &str) -> Result<Self> {
        let runner = engine.model_runner(&format!("{variant}__det"))?;
        let image_dims = runner
            .meta
            .inputs
            .first()
            .map(|(d, _)| d[1..].to_vec())
            .ok_or_else(|| anyhow!("detector artifact has no inputs"))?;
        Ok(Self {
            batch: engine.manifest.batch_detr,
            runner,
            image_dims,
            num_classes: 3,
            score_threshold: 0.30,
            variant: variant.to_string(),
        })
    }

    /// Run detection over images; returns kept boxes per image, with
    /// image indices assigned sequentially from `first_image_id`.
    pub fn detect(
        &self,
        engine: &Engine,
        images: &[Tensor],
        first_image_id: usize,
    ) -> Result<Vec<DetectionBox>> {
        let mut out = Vec::new();
        let pix: usize = self.image_dims.iter().product();
        for (ci, chunk) in images.chunks(self.batch).enumerate() {
            let mut data = vec![0.0f32; self.batch * pix];
            for (i, img) in chunk.iter().enumerate() {
                data[i * pix..(i + 1) * pix].copy_from_slice(img.as_f32()?);
            }
            let mut dims = vec![self.batch];
            dims.extend(&self.image_dims);
            let outputs = engine.run_model(&self.runner, &[Tensor::f32(dims, data)])?;
            let (cls_logits, boxes) = (&outputs[0], &outputs[1]);
            let q = cls_logits.dims[1];
            let c1 = cls_logits.dims[2]; // num_classes + 1
            let lv = cls_logits.as_f32()?;
            let bv = boxes.as_f32()?;
            let mut probs = vec![0.0f32; lv.len()];
            SoftmaxExact.run(lv, c1, &mut probs);
            for (i, _) in chunk.iter().enumerate() {
                let image = first_image_id + ci * self.batch + i;
                for qi in 0..q {
                    let p = &probs[(i * q + qi) * c1..(i * q + qi + 1) * c1];
                    // argmax over REAL classes (last class = no-object)
                    let mut best = 0usize;
                    for k in 1..self.num_classes {
                        if p[k] > p[best] {
                            best = k;
                        }
                    }
                    let score = p[best] as f64;
                    let no_obj = p[self.num_classes] as f64;
                    if score < self.score_threshold || no_obj > score {
                        continue;
                    }
                    let b = &bv[(i * q + qi) * 4..(i * q + qi + 1) * 4];
                    out.push(DetectionBox {
                        image,
                        class: best,
                        score,
                        cx: b[0] as f64,
                        cy: b[1] as f64,
                        w: b[2] as f64,
                        h: b[3] as f64,
                    });
                }
            }
        }
        Ok(out)
    }
}
