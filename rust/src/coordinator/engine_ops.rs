//! Model pipelines over the PJRT engine: translate / classify / detect.
//!
//! These are shared by the serving loop AND the experiment harness (the
//! `exp` binary evaluates Table 2/6/7 through exactly the code that
//! serves requests). The rust side owns the autoregressive decode loop;
//! the artifacts are single fixed-shape steps.

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, HashSet};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::metrics::Counters;
use super::request::{Payload, Reply};
use super::scheduler::{self, SchedConfig, VictimPolicy};
use crate::attention::{
    self, AttnMask, AttnScratch, AttnShape, DecodeAttention, DecodeBatch, DecodeStepTask,
    FusedAttention, QuantTensor, WaveError, DECODE_AFFINE,
};
use crate::eval::DetectionBox;
use crate::faults::{FaultPlan, FaultSite};
use crate::kv::spill::SpillStore;
use crate::kv::{HeadGroups, KvConfig, KvError, KvPool, KvSeq};
use crate::lut::Precision;
use crate::obs::{names, ObsHub, TraceClock};
use crate::quant;
use crate::runtime::{mode_tables, Engine, ModelRunner, Tensor};
use crate::softmax::{self, Mode, ParSoftmax, Scratch, SoftmaxEngine, SoftmaxExact};
use crate::workload::{BOS, EOS, PAD};

/// NMT encoder + decode-step pair with greedy decoding.
pub struct NmtPipeline {
    enc: ModelRunner,
    dec: ModelRunner,
    pub batch: usize,
    pub max_src: usize,
    pub max_tgt: usize,
    pub variant: String,
}

impl NmtPipeline {
    /// `variant` e.g. `"nmt14__ptqd__rexp__uint8"`.
    pub fn load(engine: &Engine, variant: &str) -> Result<Self> {
        let enc = engine.model_runner(&format!("{variant}__enc"))?;
        let dec = engine.model_runner(&format!("{variant}__dec"))?;
        let m = &engine.manifest;
        Ok(Self {
            enc,
            dec,
            batch: m.batch_nmt,
            max_src: m.nmt_max_src,
            max_tgt: m.nmt_max_tgt,
            variant: variant.to_string(),
        })
    }

    /// Greedy-decode a set of padded source rows (any count; batched and
    /// tail-padded internally). Returns EOS-terminated outputs, BOS/PAD
    /// stripped.
    pub fn translate(&self, engine: &Engine, src_rows: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let mut out = Vec::with_capacity(src_rows.len());
        for chunk in src_rows.chunks(self.batch) {
            out.extend(self.translate_batch(engine, chunk)?);
        }
        Ok(out)
    }

    fn translate_batch(&self, engine: &Engine, rows: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let b = self.batch;
        let mut src = vec![PAD; b * self.max_src];
        for (i, row) in rows.iter().enumerate() {
            if row.len() != self.max_src {
                return Err(anyhow!(
                    "source row {} has length {}, expected {}",
                    i,
                    row.len(),
                    self.max_src
                ));
            }
            src[i * self.max_src..(i + 1) * self.max_src].copy_from_slice(row);
        }
        let src_t = Tensor::i32(vec![b, self.max_src], src);
        let memory = engine
            .run_model(&self.enc, &[src_t.clone()])?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("encoder produced no output"))?;

        // §Perf: memory + src stay device-resident across the decode loop;
        // only the (small) growing tgt tensor is uploaded per step.
        let memory_dev = engine.host_to_device(&memory)?;
        let src_dev = engine.host_to_device(&src_t)?;

        let vocab = engine.manifest.nmt_vocab;
        let mut tgt = vec![PAD; b * self.max_tgt];
        for i in 0..b {
            tgt[i * self.max_tgt] = BOS;
        }
        let mut done = vec![false; rows.len()];
        for t in 1..self.max_tgt {
            let tgt_t = Tensor::i32(vec![b, self.max_tgt], tgt.clone());
            let logits = engine
                .run_model_mixed(
                    &self.dec,
                    &[None, None, Some(&tgt_t)],
                    &[&memory_dev, &src_dev],
                )?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("decoder produced no output"))?;
            let lv = logits.as_f32()?;
            // logits shape (b, max_tgt, vocab); position t-1 predicts token t
            for (i, d) in done.iter_mut().enumerate() {
                if *d {
                    continue;
                }
                let base = (i * self.max_tgt + (t - 1)) * vocab;
                let row = &lv[base..base + vocab];
                let mut best = 0usize;
                for (k, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = k;
                    }
                }
                let tok = best as i32;
                tgt[i * self.max_tgt + t] = tok;
                if tok == EOS {
                    *d = true;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }

        Ok((0..rows.len())
            .map(|i| {
                let row = &tgt[i * self.max_tgt..(i + 1) * self.max_tgt];
                row[1..]
                    .iter()
                    .copied()
                    .take_while(|&t| t != EOS && t != PAD)
                    .collect()
            })
            .collect())
    }
}

/// Encoder-classifier pipeline (sst2 / mrpc variants).
pub struct ClsPipeline {
    runner: ModelRunner,
    pub batch: usize,
    pub max_len: usize,
    pub variant: String,
}

impl ClsPipeline {
    pub fn load(engine: &Engine, variant: &str) -> Result<Self> {
        let runner = engine.model_runner(&format!("{variant}__cls"))?;
        let max_len = runner
            .meta
            .inputs
            .first()
            .map(|(d, _)| d[1])
            .ok_or_else(|| anyhow!("classifier artifact has no inputs"))?;
        Ok(Self {
            batch: engine.manifest.batch_cls,
            runner,
            max_len,
            variant: variant.to_string(),
        })
    }

    pub fn classify(&self, engine: &Engine, rows: &[Vec<i32>]) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.batch) {
            let mut toks = vec![PAD; self.batch * self.max_len];
            for (i, row) in chunk.iter().enumerate() {
                toks[i * self.max_len..(i + 1) * self.max_len].copy_from_slice(row);
            }
            let logits = engine
                .run_model(&self.runner, &[Tensor::i32(vec![self.batch, self.max_len], toks)])?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("classifier produced no output"))?;
            let lv = logits.as_f32()?;
            let classes = logits.dims[1];
            for i in 0..chunk.len() {
                let row = &lv[i * classes..(i + 1) * classes];
                let mut best = 0usize;
                for (k, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = k;
                    }
                }
                out.push(best as i32);
            }
        }
        Ok(out)
    }
}

/// Set-prediction detector pipeline (detr / detr_dc5 variants).
pub struct DetPipeline {
    runner: ModelRunner,
    pub batch: usize,
    pub image_dims: Vec<usize>,
    pub num_classes: usize,
    pub score_threshold: f64,
    pub variant: String,
}

impl DetPipeline {
    pub fn load(engine: &Engine, variant: &str) -> Result<Self> {
        let runner = engine.model_runner(&format!("{variant}__det"))?;
        let image_dims = runner
            .meta
            .inputs
            .first()
            .map(|(d, _)| d[1..].to_vec())
            .ok_or_else(|| anyhow!("detector artifact has no inputs"))?;
        Ok(Self {
            batch: engine.manifest.batch_detr,
            runner,
            image_dims,
            num_classes: 3,
            score_threshold: 0.30,
            variant: variant.to_string(),
        })
    }

    /// Run detection over images; returns kept boxes per image, with
    /// image indices assigned sequentially from `first_image_id`.
    pub fn detect(
        &self,
        engine: &Engine,
        images: &[Tensor],
        first_image_id: usize,
    ) -> Result<Vec<DetectionBox>> {
        let mut out = Vec::new();
        let pix: usize = self.image_dims.iter().product();
        for (ci, chunk) in images.chunks(self.batch).enumerate() {
            let mut data = vec![0.0f32; self.batch * pix];
            for (i, img) in chunk.iter().enumerate() {
                data[i * pix..(i + 1) * pix].copy_from_slice(img.as_f32()?);
            }
            let mut dims = vec![self.batch];
            dims.extend(&self.image_dims);
            let outputs = engine.run_model(&self.runner, &[Tensor::f32(dims, data)])?;
            let (cls_logits, boxes) = (&outputs[0], &outputs[1]);
            let q = cls_logits.dims[1];
            let c1 = cls_logits.dims[2]; // num_classes + 1
            let lv = cls_logits.as_f32()?;
            let bv = boxes.as_f32()?;
            let mut probs = vec![0.0f32; lv.len()];
            SoftmaxExact.run(lv, c1, &mut probs);
            for (i, _) in chunk.iter().enumerate() {
                let image = first_image_id + ci * self.batch + i;
                for qi in 0..q {
                    let p = &probs[(i * q + qi) * c1..(i * q + qi + 1) * c1];
                    // argmax over REAL classes (last class = no-object)
                    let mut best = 0usize;
                    for k in 1..self.num_classes {
                        if p[k] > p[best] {
                            best = k;
                        }
                    }
                    let score = p[best] as f64;
                    let no_obj = p[self.num_classes] as f64;
                    if score < self.score_threshold || no_obj > score {
                        continue;
                    }
                    let b = &bv[(i * q + qi) * 4..(i * q + qi + 1) * 4];
                    out.push(DetectionBox {
                        image,
                        class: best,
                        score,
                        cx: b[0] as f64,
                        cy: b[1] as f64,
                        w: b[2] as f64,
                        h: b[3] as f64,
                    });
                }
            }
        }
        Ok(out)
    }
}

/// Standalone softmax serving pipeline — built ONCE at server startup,
/// like [`NmtPipeline`]. Everything the old per-request `softmax_call`
/// rebuilt on every request (manifest lookup, LUT operand tensors,
/// host→device table staging) is cached here, and a whole ready batch is
/// coalesced into padded `execute` calls: one PJRT execution per
/// artifact-shaped chunk instead of one per request.
///
/// Route specs:
/// * an artifact name (e.g. `"softmax__rexp__uint8"`) → PJRT backend;
/// * `"cpu:<mode>:<prec[:aN]>"` (e.g. `"cpu:rexp:uint8"`) → CPU fallback
///   through the row-parallel [`ParSoftmax`] software engine (no
///   artifacts or PJRT needed).
pub struct SoftmaxPipeline {
    pub variant: String,
    backend: SoftmaxBackend,
}

enum SoftmaxBackend {
    Pjrt {
        exe: Rc<xla::PjRtLoadedExecutable>,
        /// LUT operand tensors, staged device-side once at load
        tables: Vec<xla::PjRtBuffer>,
        /// artifact batch shape
        rows: usize,
        cols: usize,
    },
    Cpu {
        engine: ParSoftmax,
        /// engine-thread-resident scratch: batched requests reuse one
        /// allocation instead of a fresh `Scratch` per request
        scratch: RefCell<Scratch>,
    },
}

impl SoftmaxPipeline {
    /// Build the pipeline for a route spec; `workers` sizes the CPU
    /// fallback's worker pool (from `ServerConfig::workers`).
    pub fn load(engine: &Engine, spec: &str, workers: usize) -> Result<Self> {
        if let Some(rest) = spec.strip_prefix("cpu:") {
            let (mode_s, prec_s) = rest
                .split_once(':')
                .ok_or_else(|| anyhow!("cpu softmax route {spec:?}: want cpu:<mode>:<prec>"))?;
            let mode = Mode::parse(mode_s)
                .ok_or_else(|| anyhow!("cpu softmax route {spec:?}: unknown mode {mode_s:?}"))?;
            let (prec, alpha_len) = Precision::parse_spec(prec_s)
                .ok_or_else(|| anyhow!("cpu softmax route {spec:?}: bad precision {prec_s:?}"))?;
            let inner: Arc<dyn SoftmaxEngine> = Arc::from(softmax::engine(mode, prec, alpha_len));
            return Ok(Self {
                variant: spec.to_string(),
                backend: SoftmaxBackend::Cpu {
                    engine: ParSoftmax::with_workers(inner, workers.max(1)),
                    scratch: RefCell::new(Scratch::new()),
                },
            });
        }

        let meta = engine.manifest.artifact(spec)?.clone();
        let dims = &meta
            .inputs
            .first()
            .ok_or_else(|| anyhow!("{spec}: softmax artifact has no inputs"))?
            .0;
        if dims.len() != 2 {
            bail!("{spec}: expected a 2-D input signature, got {dims:?}");
        }
        let (rows, cols) = (dims[0], dims[1]);
        let table_tensors = mode_tables(&meta.mode, &meta.spec)?;
        if table_tensors.len() != meta.tables {
            bail!(
                "{spec}: manifest declares {} table operands, lut substrate built {}",
                meta.tables,
                table_tensors.len()
            );
        }
        let tables = table_tensors
            .iter()
            .map(|t| engine.host_to_device(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            variant: spec.to_string(),
            backend: SoftmaxBackend::Pjrt {
                exe: engine.compile(spec)?,
                tables,
                rows,
                cols,
            },
        })
    }

    /// Serve a coalesced batch: per-request results so one malformed
    /// payload cannot fail its batchmates.
    pub fn run_batch(&self, engine: &Engine, xs: &[&Tensor]) -> Vec<Result<Tensor>> {
        match &self.backend {
            SoftmaxBackend::Cpu { engine: par, scratch } => {
                cpu_batch(par, &mut scratch.borrow_mut(), xs)
            }
            SoftmaxBackend::Pjrt { exe, tables, rows, cols } => {
                pjrt_batch(engine, exe, tables, *rows, *cols, xs)
            }
        }
    }
}

/// One attention request, borrowed out of a [`super::Payload::Attention`].
pub struct AttnRequest<'a> {
    pub q: &'a Tensor,
    pub k: &'a Tensor,
    pub v: &'a Tensor,
    pub causal: bool,
    pub pad_lens: Option<&'a [usize]>,
}

/// Integer-native attention serving pipeline — route
/// `"attn:<mode>:<prec[:aN]>"` (e.g. `"attn:rexp:uint8"`). Needs no
/// artifacts and no PJRT: Q/K/V are quantized per-tensor at ingress
/// ([`crate::quant::Affine`]) and the fused i8 kernel's B×H head-blocks
/// are scattered across a [`ParSoftmax`] worker pool built once at load
/// (`ServerConfig::workers` sizes it, like the CPU softmax route).
pub struct AttentionPipeline {
    pub variant: String,
    kernel: FusedAttention,
    pool: ParSoftmax,
}

impl AttentionPipeline {
    pub fn load(spec: &str, workers: usize) -> Result<Self> {
        let (mode, prec, alpha_len) = attention::parse_route(spec).ok_or_else(|| {
            anyhow!("attention route {spec:?}: want attn:<rexp|lut2d>:<prec[:aN]>")
        })?;
        // the pool's wrapped engine is not on the attention hot path (heads
        // go through `scatter`), but build it with the kernel's effective
        // alpha so any future softmax traffic on this pool agrees with it
        let alpha = Some(alpha_len.unwrap_or(attention::ATTN_ALPHA_LEN));
        Ok(Self {
            variant: spec.to_string(),
            kernel: FusedAttention::new(mode, prec, alpha_len)?,
            pool: softmax::engine_parallel(mode, prec, alpha, Some(workers.max(1))),
        })
    }

    /// Serve a coalesced batch; per-request results so one malformed
    /// payload cannot fail its batchmates. Each request's heads fan out
    /// across the pool (requests are processed in order — head-blocks,
    /// not requests, are the parallel unit).
    pub fn run_batch(&self, reqs: &[AttnRequest]) -> Vec<Result<Tensor>> {
        reqs.iter().map(|r| self.run_one(r)).collect()
    }

    fn run_one(&self, r: &AttnRequest) -> Result<Tensor> {
        let (shape, mask) = validate_attention_payload(r)?;
        let q = QuantTensor::quantize(r.q.as_f32()?);
        let k = QuantTensor::quantize(r.k.as_f32()?);
        let v = QuantTensor::quantize(r.v.as_f32()?);
        let mut out = vec![0.0f32; shape.q_len()];
        self.kernel.run_par(&q, &k, &v, &shape, &mask, &self.pool, &mut out);
        Ok(Tensor::f32(r.q.dims.clone(), out))
    }
}

/// Pages in the decode pipeline's shared KV arena, and tokens per page.
/// At (g2, d64) defaults that is 4096 × 16 tokens × 2 heads × 64 B ≈ 8 MiB
/// per K/V arena — thousands of short sessions or hundreds of long ones.
const DECODE_POOL_PAGES: usize = 4096;
const DECODE_PAGE_SIZE: usize = 16;

/// An injected allocation fault is transient (the next draw usually
/// passes): retry this many times before sacrificing a real session to
/// eviction over a spurious failure.
const MAX_SPURIOUS_RETRIES: usize = 4;

/// Decode batches are few-row (one softmax row per query head per step),
/// so the route's worker pool runs a lower inline-vs-pool threshold than
/// the default batch-serving policy.
const DECODE_MIN_ROWS_PER_SHARD: usize = 2;

/// Streaming decode serving pipeline — route
/// `"decode:<mode>:<prec>[:aN][:gG][:pP][:fS]"` (e.g.
/// `"decode:rexp:uint8:g2"`; `fS` arms the deterministic fault plan
/// [`FaultPlan::seeded`]). Artifact-free like the attention route.
/// Holds the session table (session id → [`KvSeq`] page table) and one
/// shared [`KvPool`] arena; the pool is sized lazily from the first
/// step's `(G, d_head)` shape (later sessions must match — one pool
/// serves one model geometry), with `pP` overriding the arena's page
/// count.
///
/// Session lifecycle: [`super::Payload::DecodeOpen`] →
/// [`Reply::Session`]; optional chunked prefill
/// ([`super::Payload::DecodePrefill`] → [`Reply::Prefill`], the whole
/// prompt in one atomic block append + fused sweep); N ×
/// [`super::Payload::DecodeStep`] → [`Reply::Token`] each;
/// [`super::Payload::DecodeClose`] → [`Reply::Closed`] with the pages
/// reclaimed.
///
/// Serving is **continuously batched**: [`DecodePipeline::run_batch`]
/// hands every ready batch to the scheduler ([`super::scheduler`]),
/// which assembles serving rounds under explicit budgets
/// ([`SchedConfig`]: KV free pages, total tokens, prefill MACs) —
/// opens, chunked prefills, decode steps and closes mix in one round
/// instead of opens/prefills acting as barriers between step runs. Each
/// round's steps go down as ONE [`DecodeBatch`] head-scatter wave over
/// all `S × H` head rows.
///
/// Under KV pressure the scheduler **spills a victim session to the
/// host** ([`SessionKv::Spilled`]): the victim — picked by the route's
/// [`VictimPolicy`] — has its pages copied verbatim (blocks, byte sums,
/// affines, checksummed) into the host-side [`SpillStore`], its pages
/// return to the free list, and the session is transparently restored
/// by bit-exact copy-back when its next request is admitted. A checksum
/// mismatch (or an injected [`FaultSite::SpillCorrupt`]) demotes the
/// restore to the spill's replay-row log — still bit-identical; only
/// when both encodings are unusable does the session die, with a typed
/// [`Reply::Error`]. Only a request that alone exceeds the arena fails
/// admission, and then with the typed, retryable [`Reply::Exhausted`]
/// (see the wire contract in [`super::request`]); batchmates are never
/// affected. [`Self::drain`] spills *every* live session and hands the
/// store out so a restarted pipeline ([`Self::adopt_spill`]) resumes
/// them bit-identically.
pub struct DecodePipeline {
    pub variant: String,
    decode: DecodeAttention,
    pool: ParSoftmax,
    /// `gG` in the route pins the stored-head count requests must carry
    route_kv_heads: Option<usize>,
    /// KV arena pages (route `pP`, default [`DECODE_POOL_PAGES`])
    route_pages: usize,
    kv: RefCell<Option<KvPool>>,
    sessions: RefCell<HashMap<u64, SessionKv>>,
    next_session: Cell<u64>,
    scratch: RefCell<AttnScratch>,
    /// recycled `(q, k, v)` i8 staging triples for wave slots — per-step
    /// ingress quantization must not put heap allocation on the per-token
    /// hot path (the reply's `out` buffer is the one unavoidable
    /// allocation: the reply owns it)
    spare_bufs: RefCell<Vec<(Vec<i8>, Vec<i8>, Vec<i8>)>>,
    /// continuous-batching knobs (see [`SchedConfig`])
    sched_cfg: Cell<SchedConfig>,
    /// observability hub: the metrics registry (always on — the source
    /// of truth behind [`Self::sched_counters`]), plus the optional
    /// trace sink and wall-clock stage timing ([`Self::set_trace`] /
    /// [`Self::set_stage_timing`])
    obs: RefCell<ObsHub>,
    /// the route's deterministic fault plan (`:fS` in the route spec, or
    /// [`Self::set_fault_plan`]); installed into the worker pool
    /// immediately and the KV arena when it binds
    faults: Cell<FaultPlan>,
    /// engine-batch tick, advanced once per [`Self::run_batch`] — the
    /// idle-session TTL reaper's clock
    tick: Cell<u64>,
    /// session id → tick it was last addressed (reaper bookkeeping)
    last_used: RefCell<HashMap<u64, u64>>,
    /// sessions whose client hung up (a reply send failed): reap-eligible
    /// on the next batch regardless of TTL
    dead: RefCell<HashSet<u64>>,
    /// host-side store of spilled sessions' pages (evict-to-host; see
    /// [`crate::kv::spill`]) — populated by pressure evictions and
    /// [`Self::drain`], drained by restores and closes
    spill: RefCell<SpillStore>,
    /// monotone restore-attempt counter: the
    /// [`FaultSite::SpillCorrupt`] site's draw index
    spill_seq: Cell<u64>,
}

/// A decode session's KV residency state.
enum SessionKv {
    /// opened; no step/prefill has bound the head geometry yet
    Unbound,
    /// resident: the session owns pages in the shared arena
    Live(KvSeq),
    /// taken out of the table for the duration of a wave — the eviction
    /// paths must never pick an in-flight session
    InFlight,
    /// spilled to the host under KV pressure (or by a drain): pages
    /// reclaimed, their verbatim bytes parked in the pipeline's
    /// [`SpillStore`] under this session's id. A later admission
    /// restores by bit-exact copy-back (replay-log fallback on checksum
    /// mismatch); geometry and size ride here so admission probes need
    /// not consult the store
    Spilled { groups: HeadGroups, tokens: usize },
}

/// One admitted wave entry: the session's sequence (taken out of the
/// table for the duration of the round) plus its quantized step rows.
struct WaveSlot {
    idx: usize,
    session: u64,
    seq: KvSeq,
    q: Vec<i8>,
    k: Vec<i8>,
    v: Vec<i8>,
    out: Vec<f32>,
}

impl DecodePipeline {
    pub fn load(spec: &str, workers: usize) -> Result<Self> {
        let route = attention::parse_decode_route(spec).map_err(|e| {
            anyhow!("decode route {spec:?}: {e} (want decode:<rexp|lut2d>:<prec>[:aN][:gG][:pP][:fS])")
        })?;
        // as for the attention route: the pool's wrapped engine is off the
        // decode hot path (heads go through `scatter`), but keep its alpha
        // consistent with the kernel's
        let alpha = Some(route.alpha_len.unwrap_or(attention::ATTN_ALPHA_LEN));
        let inner: Arc<dyn SoftmaxEngine> = Arc::from(softmax::engine(route.mode, route.prec, alpha));
        let pipe = Self {
            variant: spec.to_string(),
            decode: DecodeAttention::new(route.mode, route.prec, route.alpha_len)?,
            pool: ParSoftmax::with_policy(inner, workers.max(1), DECODE_MIN_ROWS_PER_SHARD),
            route_kv_heads: route.kv_heads,
            route_pages: route.pages.unwrap_or(DECODE_POOL_PAGES),
            kv: RefCell::new(None),
            sessions: RefCell::new(HashMap::new()),
            next_session: Cell::new(1),
            scratch: RefCell::new(AttnScratch::new()),
            spare_bufs: RefCell::new(Vec::new()),
            sched_cfg: Cell::new(SchedConfig::default()),
            obs: RefCell::new(ObsHub::new()),
            faults: Cell::new(FaultPlan::none()),
            tick: Cell::new(0),
            last_used: RefCell::new(HashMap::new()),
            dead: RefCell::new(HashSet::new()),
            spill: RefCell::new(SpillStore::new()),
            spill_seq: Cell::new(0),
        };
        if let Some(seed) = route.fault_seed {
            pipe.set_fault_plan(FaultPlan::seeded(seed));
        }
        Ok(pipe)
    }

    /// Install the route's deterministic fault plan: the worker pool's
    /// injection schedule resets immediately; the KV arena's resets now
    /// if bound, else when the first step/prefill binds it.
    pub fn set_fault_plan(&self, plan: FaultPlan) {
        self.faults.set(plan);
        self.pool.set_fault_plan(plan);
        if let Some(kvp) = self.kv.borrow_mut().as_mut() {
            kvp.set_fault_plan(plan);
        }
    }

    /// The route's active fault plan ([`FaultPlan::none`] by default).
    pub fn fault_plan(&self) -> FaultPlan {
        self.faults.get()
    }

    /// Serve one ready batch of decode payloads through the
    /// continuous-batching scheduler: rounds are assembled under the
    /// route's [`SchedConfig`] budgets, preserving every session's own
    /// arrival order (see [`super::scheduler`]). Each batch advances the
    /// reaper tick; sessions idle for `idle_ttl_batches` batches (or
    /// marked dead by [`Self::note_dead_reply`]) are closed afterwards,
    /// their pages reclaimed.
    pub fn run_batch(&self, batch: &[&Payload]) -> Vec<Reply> {
        let tick = self.tick.get() + 1;
        self.tick.set(tick);
        let replies = scheduler::run(self, batch);
        {
            // touch only sessions still in the table (a close already
            // scrubbed its bookkeeping)
            let sessions = self.sessions.borrow();
            let mut lu = self.last_used.borrow_mut();
            let mut touch = |s: u64| {
                if sessions.contains_key(&s) {
                    lu.insert(s, tick);
                }
            };
            for p in batch {
                match p {
                    Payload::DecodeStep { session, .. }
                    | Payload::DecodePrefill { session, .. } => touch(*session),
                    Payload::DecodeClose(s) => touch(*s),
                    _ => {}
                }
            }
            for r in &replies {
                if let Reply::Session(id) = r {
                    touch(*id);
                }
            }
        }
        let reap_t = self.obs.borrow_mut().stage_begin("reap");
        let reaped = self.reap_idle(tick);
        self.obs.borrow_mut().stage_end(
            names::ROUND_REAP_US,
            reap_t,
            &[("reaped", reaped as i64)],
        );
        self.publish_kv_gauges();
        replies
    }

    /// Publish the arena's occupancy gauges (free pages, resident
    /// tokens, tail-page fragmentation) — once per engine batch, so the
    /// registry snapshot always reflects the post-batch arena.
    fn publish_kv_gauges(&self) {
        let (free, total) = match self.kv_pages() {
            Some(ft) => ft,
            None => return,
        };
        let page_size = self
            .kv
            .borrow()
            .as_ref()
            .map_or(DECODE_PAGE_SIZE, |p| p.config().page_size);
        let resident = self.resident_tokens();
        let allocated_slots = (total - free) * page_size;
        let mut obs = self.obs.borrow_mut();
        obs.gauge_set(names::KV_PAGES_TOTAL, total as i64);
        obs.gauge_set(names::KV_PAGES_FREE, free as i64);
        obs.gauge_set(names::KV_RESIDENT_TOKENS, resident as i64);
        obs.gauge_set(
            names::KV_FRAGMENTATION_TOKENS,
            allocated_slots.saturating_sub(resident) as i64,
        );
    }

    /// Record that a reply to `session` could not be delivered (the
    /// client hung up): the session is reap-eligible on the next batch.
    pub fn note_dead_reply(&self, session: u64) {
        self.obs.borrow_mut().inc(names::SCHED_DEAD_REPLIES);
        self.dead.borrow_mut().insert(session);
    }

    /// Close sessions that are dead (client hung up) or idle past the
    /// route's TTL, returning their pages to the arena. Returns the
    /// victim count.
    fn reap_idle(&self, tick: u64) -> usize {
        let ttl = self.sched_cfg.get().idle_ttl_batches as u64;
        let victims: Vec<u64> = {
            let dead = self.dead.borrow();
            let lu = self.last_used.borrow();
            self.sessions
                .borrow()
                .keys()
                .copied()
                .filter(|id| {
                    dead.contains(id)
                        || (ttl > 0
                            && tick.saturating_sub(lu.get(id).copied().unwrap_or(tick)) >= ttl)
                })
                .collect()
        };
        let reaped = victims.len();
        for id in victims {
            self.close(id);
            let mut obs = self.obs.borrow_mut();
            obs.inc(names::SCHED_REAPED);
            obs.event("reap_session", &[("session", id as i64)]);
        }
        // prune hang-up marks whose session is already gone (e.g. the
        // close itself got the dead reply) so the set cannot grow forever
        let sessions = self.sessions.borrow();
        self.dead.borrow_mut().retain(|id| sessions.contains_key(id));
        reaped
    }

    /// The route's scheduler knobs.
    pub fn sched_config(&self) -> SchedConfig {
        self.sched_cfg.get()
    }

    pub fn set_sched_config(&self, cfg: SchedConfig) {
        self.sched_cfg.set(cfg);
    }

    /// Snapshot of the route's scheduler counters — a projection of the
    /// metrics registry ([`Counters::from_registry`]), so the summary
    /// line and the registry can never drift.
    pub fn sched_counters(&self) -> Counters {
        Counters::from_registry(&self.obs.borrow().metrics)
    }

    pub(super) fn obs_mut(&self) -> std::cell::RefMut<'_, ObsHub> {
        self.obs.borrow_mut()
    }

    /// Arm a trace sink on the route ([`TraceClock::Wall`] for serving
    /// timelines, [`TraceClock::Logical`] for deterministic replay
    /// assertions). Replaces any prior sink.
    pub fn set_trace(&self, clock: TraceClock) {
        self.obs.borrow_mut().set_trace(clock);
    }

    /// Enable wall-clock per-stage latency histograms (`round_*_us`).
    /// Off by default so pure-pipeline runs stay clock-free.
    pub fn set_stage_timing(&self, on: bool) {
        self.obs.borrow_mut().set_timing(on);
    }

    /// Drop all recorded trace events, keeping the sink armed (benches
    /// bound per-iteration memory with this). No-op without a sink.
    pub fn reset_trace(&self) {
        if let Some(t) = self.obs.borrow_mut().trace_mut() {
            t.clear();
        }
    }

    /// chrome://tracing JSON of the recorded events, `None` when no
    /// sink is armed. Does not drain the sink.
    pub fn trace_json(&self) -> Option<crate::config::Json> {
        self.obs.borrow().trace().map(|t| t.to_json())
    }

    /// How many trace events carry `name` (0 without a sink) — the
    /// fault-reconciliation tests count `"fault"` markers.
    pub fn trace_event_count(&self, name: &str) -> usize {
        self.obs.borrow().trace().map_or(0, |t| t.count(name))
    }

    /// JSON snapshot of the route's metrics registry (`--stats-json`).
    /// The process-wide LUT range window ([`crate::obs::range`]) is
    /// published into a clone at export time, so repeated snapshots
    /// never double-count it into the live registry.
    pub fn metrics_json(&self) -> crate::config::Json {
        let mut reg = self.obs.borrow().metrics.clone();
        crate::obs::range::publish(&mut reg);
        reg.to_json()
    }

    /// Prometheus text exposition of the route's metrics registry (same
    /// export-time LUT range publication as [`Self::metrics_json`]).
    pub fn metrics_prometheus(&self) -> String {
        let mut reg = self.obs.borrow().metrics.clone();
        crate::obs::range::publish(&mut reg);
        reg.to_prometheus()
    }

    /// Record a queue-wait sample keyed by session class (the server
    /// loop calls this per dequeued decode payload).
    pub fn record_queue_wait(&self, prefill_class: bool, us: u64) {
        let name = if prefill_class {
            names::QUEUE_WAIT_PREFILL_US
        } else {
            names::QUEUE_WAIT_STEP_US
        };
        self.obs.borrow_mut().metrics.observe_us(name, us);
    }

    /// Pages the arena's free list holds right now (the configured page
    /// count while the pool is still unbound).
    pub(super) fn free_pages_now(&self) -> usize {
        self.kv.borrow().as_ref().map_or(self.route_pages, |p| p.free_pages())
    }

    /// Total pages of the route's arena.
    pub(super) fn total_pages(&self) -> usize {
        self.route_pages
    }

    /// Pages `session` currently owns (0 when unknown, unbound or
    /// spilled) — the scheduler's close-credit probe.
    pub(super) fn session_pages(&self, session: u64) -> usize {
        match self.sessions.borrow().get(&session) {
            Some(SessionKv::Live(s)) => s.pages().len(),
            _ => 0,
        }
    }

    /// Tokens resident in the arena across all live sessions — the
    /// scheduler's per-round occupancy accounting.
    pub(super) fn resident_tokens(&self) -> usize {
        self.sessions
            .borrow()
            .values()
            .map(|st| match st {
                SessionKv::Live(s) => s.len(),
                _ => 0,
            })
            .sum()
    }

    /// What admitting `new_tokens` more tokens for `session` would cost:
    /// pages to allocate (including the copy-back of a spilled session's
    /// whole prefix) and resident tokens after the round. Unknown /
    /// in-flight sessions cost nothing (they resolve to errors at
    /// execution).
    pub(super) fn admit_cost(&self, session: u64, new_tokens: usize) -> AdmitCost {
        let sessions = self.sessions.borrow();
        let kv = self.kv.borrow();
        let ps = kv.as_ref().map_or(DECODE_PAGE_SIZE, |p| p.config().page_size);
        match sessions.get(&session) {
            Some(SessionKv::Live(s)) => AdmitCost {
                pages: kv
                    .as_ref()
                    .map_or(new_tokens.div_ceil(ps), |p| p.pages_needed(s, new_tokens)),
                tokens_after: s.len() + new_tokens,
            },
            Some(SessionKv::Spilled { tokens, .. }) => AdmitCost {
                pages: (tokens + new_tokens).div_ceil(ps),
                tokens_after: tokens + new_tokens,
            },
            Some(SessionKv::Unbound) => AdmitCost {
                pages: new_tokens.div_ceil(ps),
                tokens_after: new_tokens,
            },
            Some(SessionKv::InFlight) | None => AdmitCost { pages: 0, tokens_after: 0 },
        }
    }

    /// Spill the policy-picked victim session not in `exclude` to the
    /// host store (see [`spill_victim_session`]). Returns the victim
    /// and pages freed.
    pub(super) fn evict_victim(&self, exclude: &HashSet<u64>) -> Option<(u64, usize)> {
        let mut sessions = self.sessions.borrow_mut();
        let mut kv = self.kv.borrow_mut();
        let kvp = kv.as_mut()?;
        let r = spill_victim_session(
            &mut sessions,
            kvp,
            &mut self.spill.borrow_mut(),
            exclude,
            self.sched_cfg.get().victim_policy,
            &self.last_used.borrow(),
        );
        if let Some((victim, pages)) = r {
            let mut obs = self.obs.borrow_mut();
            obs.evicted(names::EVICT_ADMISSION);
            obs.inc(names::SCHED_SPILLED);
            obs.event("evict", &[("session", victim as i64), ("pages", pages as i64)]);
            obs.event("spill", &[("session", victim as i64), ("pages", pages as i64)]);
        }
        r
    }

    /// open → [`Reply::Session`]
    pub fn open(&self) -> Reply {
        let id = self.next_session.get();
        self.next_session.set(id + 1);
        self.sessions.borrow_mut().insert(id, SessionKv::Unbound);
        Reply::Session(id)
    }

    /// Map a pipeline error to its wire reply: KV exhaustion becomes the
    /// typed, retryable [`Reply::Exhausted`]; everything else stays a
    /// stringly [`Reply::Error`].
    fn error_reply(&self, e: &anyhow::Error) -> Reply {
        match e.downcast_ref::<KvError>() {
            Some(&KvError::Exhausted { pages, free_pages }) => {
                self.obs.borrow_mut().inc(names::SCHED_EXHAUSTED);
                // the engine has no view of the waiting queue here; one
                // round is the floor of the scheduler's drain estimate
                // (see `scheduler::retry_after`)
                Reply::Exhausted { pages, free_pages, retry_after_rounds: 1 }
            }
            None => Reply::Error(e.to_string()),
        }
    }

    /// One batched step round: all steps of a round, replies in item
    /// order. Unique sessions go down as ONE [`DecodeBatch`]
    /// head-scatter wave; repeated sessions split into consecutive waves
    /// so same-session steps keep arrival order (cross-session order is
    /// unobservable — see the wire contract in [`super::request`]). The
    /// scheduler admits one step per session per round, so its rounds
    /// are always single waves.
    pub fn step_batch(&self, items: &[(u64, &Tensor, &Tensor, &Tensor)]) -> Vec<Reply> {
        self.step_batch_excluding(items, &HashSet::new())
    }

    /// [`Self::step_batch`] with an eviction exclude set: mid-wave and
    /// restore evictions spare `keep` (the scheduler passes the round's
    /// sessions, whose pages its admission accounting already credited
    /// or reserved — evicting one would spend them twice).
    pub(super) fn step_batch_excluding(
        &self,
        items: &[(u64, &Tensor, &Tensor, &Tensor)],
        keep: &HashSet<u64>,
    ) -> Vec<Reply> {
        let mut replies: Vec<Option<Reply>> = items.iter().map(|_| None).collect();
        let mut remaining: Vec<usize> = (0..items.len()).collect();
        while !remaining.is_empty() {
            let mut seen: std::collections::HashSet<u64> = std::collections::HashSet::new();
            let mut wave: Vec<usize> = Vec::new();
            let mut rest: Vec<usize> = Vec::new();
            for &i in &remaining {
                if seen.insert(items[i].0) {
                    wave.push(i);
                } else {
                    rest.push(i);
                }
            }
            self.step_wave_round(items, &wave, &mut replies, keep);
            remaining = rest;
        }
        // each wave resolves every slot it was handed, so an empty slot
        // is an internal invariant breach — typed and counted, never a
        // wire-reachable panic
        replies
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                r.unwrap_or_else(|| {
                    debug_assert!(false, "step {i} left unresolved by its wave");
                    self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
                    Reply::Error(format!("internal: step {i} left unresolved by its wave"))
                })
            })
            .collect()
    }

    /// One unique-session wave of a batched step round. Wave sequences
    /// are taken out of the session table ([`SessionKv::InFlight`]), so
    /// the mid-wave eviction hook — which mutates *other* table entries
    /// — can never alias a sequence the wave borrows.
    fn step_wave_round(
        &self,
        items: &[(u64, &Tensor, &Tensor, &Tensor)],
        wave: &[usize],
        replies: &mut [Option<Reply>],
        keep: &HashSet<u64>,
    ) {
        let mut sessions = self.sessions.borrow_mut();
        let mut kv_ref = self.kv.borrow_mut();
        let mut slots: Vec<WaveSlot> = Vec::with_capacity(wave.len());
        for &i in wave {
            let (session, q, k, v) = items[i];
            match self.admit_step(&mut sessions, &mut kv_ref, session, q, k, v, keep) {
                Ok((seq, qb, kb, vb, out)) => {
                    slots.push(WaveSlot { idx: i, session, seq, q: qb, k: kb, v: vb, out })
                }
                Err(e) => replies[i] = Some(self.error_reply(&e)),
            }
        }
        if slots.is_empty() {
            return;
        }
        // admitted steps imply a bound pool; an unbound one here is an
        // internal invariant breach — typed and counted, never a panic
        let Some(kvp) = kv_ref.as_mut() else {
            debug_assert!(false, "pool bound by admitted steps");
            let mut obs = self.obs.borrow_mut();
            for slot in slots {
                obs.inc(names::SCHED_UNRESOLVED);
                replies[slot.idx] =
                    Some(Reply::Error("internal: KV pool unbound mid-wave".into()));
            }
            return;
        };
        let mut scr = self.scratch.borrow_mut();
        let mut tasks: Vec<DecodeStepTask<'_>> = slots
            .iter_mut()
            .map(|s| DecodeStepTask {
                seq: &mut s.seq,
                q: &s.q,
                q_affine: DECODE_AFFINE,
                k_row: &s.k,
                v_row: &s.v,
                out: &mut s.out,
            })
            .collect();
        // mid-wave safety net: a page-boundary append the admission
        // accounting did not foresee spills the policy-picked idle
        // session instead of starving the step (wave sessions are
        // in-flight and thus never picked; `keep` spares the round's
        // other sessions). With a fault plan armed, a failed append gets
        // a few bare retries first — an injected fault is spurious and
        // eviction would sacrifice a real session to it
        let mut spurious_retries = 0usize;
        let policy = self.sched_cfg.get().victim_policy;
        let (results, stats) = DecodeBatch::new(&self.decode)
            .with_split_min_tokens(self.sched_cfg.get().split_min_tokens)
            .step_wave_with_stats(
            kvp,
            &mut tasks,
            &self.pool,
            &mut scr,
            |kv, _| {
                if !self.faults.get().is_none() && spurious_retries < MAX_SPURIOUS_RETRIES {
                    spurious_retries += 1;
                    return true;
                }
                let r = spill_victim_session(
                    &mut sessions,
                    kv,
                    &mut self.spill.borrow_mut(),
                    keep,
                    policy,
                    &self.last_used.borrow(),
                );
                if let Some((victim, pages)) = r {
                    let mut obs = self.obs.borrow_mut();
                    obs.evicted(names::EVICT_STEP);
                    obs.inc(names::SCHED_SPILLED);
                    obs.event("evict", &[("session", victim as i64), ("pages", pages as i64)]);
                    obs.event("spill", &[("session", victim as i64), ("pages", pages as i64)]);
                }
                r.is_some()
            },
        );
        drop(tasks);
        {
            // wave traffic under the hwsim charge-model names, so
            // simulated and measured runs compare label-for-label
            let mut obs = self.obs.borrow_mut();
            obs.add(names::KV_BYTES_READ, stats.kv_bytes);
            obs.add(names::WAVE_ROWS, stats.rows as u64);
            obs.add(names::WAVE_MACS, stats.macs as u64);
            obs.add(names::WAVE_SPAN_UNITS, stats.span_units as u64);
            obs.add(names::WAVE_SPLIT_TASKS, stats.split_tasks as u64);
            obs.inc(if stats.inline { names::WAVE_INLINE } else { names::WAVE_SCATTER });
        }
        let mut spare_bufs = self.spare_bufs.borrow_mut();
        for (slot, res) in slots.into_iter().zip(results) {
            let reply = match res {
                Ok(()) => Reply::Token(Tensor::f32(items[slot.idx].1.dims.clone(), slot.out)),
                Err(WaveError::Kv(KvError::Exhausted { pages, free_pages })) => {
                    self.obs.borrow_mut().inc(names::SCHED_EXHAUSTED);
                    Reply::Exhausted { pages, free_pages, retry_after_rounds: 1 }
                }
                // the panic was contained to this slot: the append
                // landed (state advanced, output lost), batchmates are
                // untouched — one typed reply, no retry (see the wire
                // contract's failure-semantics table)
                Err(WaveError::Panicked) => {
                    let mut obs = self.obs.borrow_mut();
                    obs.inc(names::SCHED_PANICKED);
                    obs.event("fault", &[("session", slot.session as i64)]);
                    Reply::Error(WaveError::Panicked.to_string())
                }
            };
            // hand the sequence back to the session table (untouched when
            // the append failed — the step is retryable), and the staging
            // buffers back to the recycle pool
            spare_bufs.push((slot.q, slot.k, slot.v));
            match sessions.get_mut(&slot.session) {
                Some(st) => *st = SessionKv::Live(slot.seq),
                // an admitted session cannot vanish mid-wave (closes are
                // serialized with rounds) — counted, pages returned, the
                // reply still goes out
                None => {
                    debug_assert!(false, "admitted session vanished mid-wave");
                    self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
                    kvp.close(slot.seq);
                }
            }
            replies[slot.idx] = Some(reply);
        }
    }

    /// Validate + bind one step and take its sequence out of the table
    /// ([`SessionKv::InFlight`]) for the wave, restoring it from the
    /// replay log first if the session was evicted; quantizes the step's
    /// rows with the route's fixed dyadic affine (the per-page
    /// quantization contract; see [`attention::DECODE_AFFINE`]).
    #[allow(clippy::type_complexity)]
    #[allow(clippy::too_many_arguments)]
    fn admit_step(
        &self,
        sessions: &mut HashMap<u64, SessionKv>,
        kv_ref: &mut Option<KvPool>,
        session: u64,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        keep: &HashSet<u64>,
    ) -> Result<(KvSeq, Vec<i8>, Vec<i8>, Vec<i8>, Vec<f32>)> {
        let (h, g, d) = validate_decode_step(q, k, v)?;
        if let Some(want) = self.route_kv_heads {
            if g != want {
                bail!("decode step carries {g} kv heads but the route fixes g{want}");
            }
        }
        let slot = sessions
            .get_mut(&session)
            .ok_or_else(|| anyhow!("unknown decode session {session}"))?;
        bind_decode_pool(kv_ref, g, d, self.route_pages, self.faults.get())?;
        bind_session_heads(slot, h, g)?;
        let Some(kvp) = kv_ref.as_mut() else {
            debug_assert!(false, "pool bound above");
            self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
            bail!("internal: KV pool unbound after bind");
        };
        // staging buffers are recycled across rounds (step_wave_round
        // returns them); only the reply-owned `out` is freshly allocated.
        // Quantize BEFORE taking the sequence out of the table: a bad
        // tensor is then a typed error, never a leaked in-flight slot
        let (mut qb, mut kb, mut vb) =
            self.spare_bufs.borrow_mut().pop().unwrap_or_default();
        qb.clear();
        qb.resize(h * d, 0);
        quant::quantize_into(q.as_f32()?, DECODE_AFFINE, &mut qb);
        kb.clear();
        kb.resize(g * d, 0);
        quant::quantize_into(k.as_f32()?, DECODE_AFFINE, &mut kb);
        vb.clear();
        vb.resize(g * d, 0);
        quant::quantize_into(v.as_f32()?, DECODE_AFFINE, &mut vb);
        let seq = match std::mem::replace(slot, SessionKv::InFlight) {
            SessionKv::Live(s) => s,
            SessionKv::Spilled { groups, tokens } => {
                self.restore_session(sessions, kvp, session, groups, tokens, keep)?
            }
            SessionKv::Unbound | SessionKv::InFlight => {
                unreachable!("bound above; one step per session per wave")
            }
        };
        Ok((seq, qb, kb, vb, vec![0.0f32; h * d]))
    }

    /// chunked prefill → [`Reply::Prefill`] (`(T', H, d)` like the query;
    /// row `t` bit-identical to the `t`-th single step's [`Reply::Token`])
    pub fn prefill(&self, session: u64, q: &Tensor, k: &Tensor, v: &Tensor) -> Reply {
        self.prefill_excluding(session, q, k, v, &HashSet::new())
    }

    /// [`Self::prefill`] with an eviction exclude set: the retry-loop
    /// and restore evictions spare `keep` (the scheduler passes the
    /// round's sessions — see `scheduler::execute`).
    pub(super) fn prefill_excluding(
        &self,
        session: u64,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        keep: &HashSet<u64>,
    ) -> Reply {
        self.try_prefill(session, q, k, v, keep)
            .unwrap_or_else(|e| self.error_reply(&e))
    }

    fn try_prefill(
        &self,
        session: u64,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        keep: &HashSet<u64>,
    ) -> Result<Reply> {
        let (t, h, g, d) = validate_decode_prefill(q, k, v)?;
        if let Some(want) = self.route_kv_heads {
            if g != want {
                bail!("decode prefill carries {g} kv heads but the route fixes g{want}");
            }
        }
        let mut sessions = self.sessions.borrow_mut();
        let mut kv_ref = self.kv.borrow_mut();
        let slot = sessions
            .get_mut(&session)
            .ok_or_else(|| anyhow!("unknown decode session {session}"))?;
        bind_decode_pool(&mut kv_ref, g, d, self.route_pages, self.faults.get())?;
        bind_session_heads(slot, h, g)?;
        let Some(kvp) = kv_ref.as_mut() else {
            debug_assert!(false, "pool bound above");
            self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
            bail!("internal: KV pool unbound after bind");
        };
        let mut seq = match std::mem::replace(slot, SessionKv::InFlight) {
            SessionKv::Live(s) => s,
            SessionKv::Spilled { groups, tokens } => {
                self.restore_session(&mut sessions, kvp, session, groups, tokens, keep)?
            }
            SessionKv::Unbound | SessionKv::InFlight => {
                unreachable!("bound above; sessions are not in-flight here")
            }
        };
        let mut qb = vec![0i8; t * h * d];
        quant::quantize_into(q.as_f32()?, DECODE_AFFINE, &mut qb);
        let mut kb = vec![0i8; t * g * d];
        quant::quantize_into(k.as_f32()?, DECODE_AFFINE, &mut kb);
        let mut vb = vec![0i8; t * g * d];
        quant::quantize_into(v.as_f32()?, DECODE_AFFINE, &mut vb);
        let mut out = vec![0.0f32; t * h * d];
        let mut scr = self.scratch.borrow_mut();
        // a prompt chunk is the route's most parallelizable payload
        // (T'×H independent rows): scatter its head sweeps over the pool.
        // A chunk the free list cannot cover evicts younger sessions
        // (the chunk append is atomic, so each retry starts clean); only
        // a chunk no eviction can make room for fails, typed. With a
        // fault plan armed, a failed append gets a few bare retries
        // before eviction — injected faults are spurious
        let mut spurious_retries = 0usize;
        let policy = self.sched_cfg.get().victim_policy;
        let result = loop {
            match self.decode.prefill_chunk_par(
                kvp,
                &mut seq,
                &qb,
                DECODE_AFFINE,
                &kb,
                &vb,
                &self.pool,
                &mut out,
                &mut scr,
            ) {
                Ok(()) => break Ok(()),
                // a panicked sweep already appended the chunk: state
                // advanced, output lost — retrying would double-append
                Err(WaveError::Panicked) => break Err(WaveError::Panicked),
                Err(WaveError::Kv(e)) => {
                    if !self.faults.get().is_none() && spurious_retries < MAX_SPURIOUS_RETRIES {
                        spurious_retries += 1;
                        continue;
                    }
                    let evicted = spill_victim_session(
                        &mut sessions,
                        kvp,
                        &mut self.spill.borrow_mut(),
                        keep,
                        policy,
                        &self.last_used.borrow(),
                    );
                    if let Some((victim, pages)) = evicted {
                        let mut obs = self.obs.borrow_mut();
                        obs.evicted(names::EVICT_PREFILL);
                        obs.inc(names::SCHED_SPILLED);
                        obs.event(
                            "evict",
                            &[("session", victim as i64), ("pages", pages as i64)],
                        );
                        obs.event(
                            "spill",
                            &[("session", victim as i64), ("pages", pages as i64)],
                        );
                    } else {
                        break Err(WaveError::Kv(e));
                    }
                }
            }
        };
        match sessions.get_mut(&session) {
            Some(slot) => *slot = SessionKv::Live(seq),
            // the in-flight slot cannot vanish during its own prefill —
            // counted, pages returned, a typed error goes out
            None => {
                debug_assert!(false, "in-flight slot vanished during prefill");
                self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
                kvp.close(seq);
                bail!("internal: session {session} vanished during prefill");
            }
        }
        match result {
            Ok(()) => Ok(Reply::Prefill(Tensor::f32(q.dims.clone(), out))),
            Err(WaveError::Panicked) => {
                let mut obs = self.obs.borrow_mut();
                obs.inc(names::SCHED_PANICKED);
                obs.event("fault", &[("session", session as i64)]);
                Ok(Reply::Error(WaveError::Panicked.to_string()))
            }
            Err(WaveError::Kv(e)) => Err(e.into()),
        }
    }

    /// Restore a spilled session's pages from the host store (the
    /// session's slot is in-flight while this runs), spilling still
    /// other sessions if the free list cannot cover it. The fast path
    /// is the checksummed **bit-exact copy-back**
    /// ([`SpillStore::restore_copy_back`]); a checksum mismatch — or an
    /// injected [`FaultSite::SpillCorrupt`] hit — demotes to replaying
    /// the spill's independent `[t][g][d]` row log through
    /// [`KvPool::append_block`], which rebuilds the same bytes token by
    /// token. On a retryable failure the slot reverts to `Spilled` with
    /// the record untouched; only when the host copy is corrupt AND the
    /// replay log is unusable does the session die, with a typed error
    /// — never a panic (`docs/RELIABILITY.md` walks the ladder).
    fn restore_session(
        &self,
        sessions: &mut HashMap<u64, SessionKv>,
        kvp: &mut KvPool,
        session: u64,
        groups: HeadGroups,
        tokens: usize,
        keep: &HashSet<u64>,
    ) -> Result<KvSeq> {
        let policy = self.sched_cfg.get().victim_policy;
        // one SpillCorrupt draw per restore attempt: a hit simulates a
        // rotted host copy, forcing the replay-log rung
        let draw = self.spill_seq.get();
        self.spill_seq.set(draw + 1);
        let injected = self.faults.get().should_fault(FaultSite::SpillCorrupt, draw);
        let intact = match self.spill.borrow().session(session) {
            Some(rec) => rec.intact() && !injected,
            // Spilled state ⟺ store record is the subsystem's core
            // invariant; a missing record is unrecoverable
            None => {
                debug_assert!(false, "spilled session {session} has no store record");
                sessions.remove(&session);
                self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
                bail!("internal: spilled session {session} has no host record");
            }
        };
        let mut spurious_retries = 0usize;
        if intact {
            loop {
                let r = self.spill.borrow_mut().restore_copy_back(kvp, session);
                match r {
                    Some(Ok(seq)) => {
                        debug_assert_eq!(seq.len(), tokens);
                        let mut obs = self.obs.borrow_mut();
                        obs.inc(names::SCHED_SPILL_RESTORED);
                        obs.inc(names::SCHED_REQUEUED);
                        obs.event(
                            "spill_restore",
                            &[("session", session as i64), ("tokens", tokens as i64)],
                        );
                        obs.event(
                            "restore",
                            &[("session", session as i64), ("tokens", tokens as i64)],
                        );
                        return Ok(seq);
                    }
                    // retryable exhaustion: the record and arena are
                    // untouched (copy-back is atomic) — bare-retry
                    // injected faults, then spill other sessions
                    Some(Err(e)) => {
                        if !self.faults.get().is_none()
                            && spurious_retries < MAX_SPURIOUS_RETRIES
                        {
                            spurious_retries += 1;
                            continue;
                        }
                        if !self.evict_for_restore(sessions, kvp, keep, policy) {
                            if let Some(slot) = sessions.get_mut(&session) {
                                *slot = SessionKv::Spilled { groups, tokens };
                            }
                            return Err(e.into());
                        }
                    }
                    None => {
                        debug_assert!(false, "spilled session {session} has no store record");
                        sessions.remove(&session);
                        self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
                        bail!("internal: spilled session {session} has no host record");
                    }
                }
            }
        }
        // fallback rung: the host copy is rotted (or injected as such) —
        // replay the independent row log, token by token, same bytes
        let rows = {
            let store = self.spill.borrow();
            store
                .session(session)
                .and_then(|r| r.replay_rows().map(|(k, v)| (k.to_vec(), v.to_vec())))
        };
        let Some((kl, vl)) = rows else {
            // both encodings dead: the ladder's terminal rung — the
            // session is lost, typed, never a panic
            self.spill.borrow_mut().remove(session);
            sessions.remove(&session);
            self.obs.borrow_mut().event("spill_lost", &[("session", session as i64)]);
            bail!("spilled session {session} lost: host copy corrupt and replay log unusable");
        };
        let mut seq = KvSeq::new(groups, DECODE_AFFINE, DECODE_AFFINE);
        loop {
            match kvp.append_block(&mut seq, &kl, &vl) {
                Ok(()) => {
                    debug_assert_eq!(seq.len(), tokens);
                    self.spill.borrow_mut().remove(session);
                    let mut obs = self.obs.borrow_mut();
                    obs.inc(names::SCHED_SPILL_FALLBACK);
                    obs.inc(names::SCHED_REQUEUED);
                    obs.event(
                        "spill_fallback",
                        &[("session", session as i64), ("tokens", tokens as i64)],
                    );
                    obs.event(
                        "restore",
                        &[("session", session as i64), ("tokens", tokens as i64)],
                    );
                    return Ok(seq);
                }
                Err(e) => {
                    if !self.faults.get().is_none() && spurious_retries < MAX_SPURIOUS_RETRIES {
                        spurious_retries += 1;
                        continue;
                    }
                    if !self.evict_for_restore(sessions, kvp, keep, policy) {
                        if let Some(slot) = sessions.get_mut(&session) {
                            *slot = SessionKv::Spilled { groups, tokens };
                        }
                        return Err(e.into());
                    }
                }
            }
        }
    }

    /// One restore-pressure eviction: spill the policy-picked victim
    /// (the restoring session is in-flight and never picked; `keep`
    /// spares the round's admitted sessions). `false` when nothing is
    /// evictable.
    fn evict_for_restore(
        &self,
        sessions: &mut HashMap<u64, SessionKv>,
        kvp: &mut KvPool,
        keep: &HashSet<u64>,
        policy: VictimPolicy,
    ) -> bool {
        let r = spill_victim_session(
            sessions,
            kvp,
            &mut self.spill.borrow_mut(),
            keep,
            policy,
            &self.last_used.borrow(),
        );
        if let Some((victim, pages)) = r {
            let mut obs = self.obs.borrow_mut();
            obs.evicted(names::EVICT_RESTORE);
            obs.inc(names::SCHED_SPILLED);
            obs.event("evict", &[("session", victim as i64), ("pages", pages as i64)]);
            obs.event("spill", &[("session", victim as i64), ("pages", pages as i64)]);
        }
        r.is_some()
    }

    /// close → [`Reply::Closed`], pages returned to the arena. A session
    /// closed while spilled holds no arena pages and reports `pages: 0`
    /// — an ops number, not part of the bit-identity contract; its host
    /// spill record dies with the close.
    pub fn close(&self, session: u64) -> Reply {
        self.last_used.borrow_mut().remove(&session);
        self.dead.borrow_mut().remove(&session);
        match self.sessions.borrow_mut().remove(&session) {
            None => Reply::Error(format!("unknown decode session {session}")),
            Some(SessionKv::Live(s)) => match self.kv.borrow_mut().as_mut() {
                Some(pool) => Reply::Closed { pages: pool.close(s) },
                // a live session with no bound pool is an internal
                // invariant breach — typed and counted, never a panic
                None => {
                    debug_assert!(false, "live sessions imply a bound pool");
                    self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
                    Reply::Error(format!("internal: session {session} live with no KV pool"))
                }
            },
            Some(SessionKv::Spilled { .. }) => {
                self.spill.borrow_mut().remove(session);
                Reply::Closed { pages: 0 }
            }
            // unbound and in-flight sessions hold no pages
            Some(_) => Reply::Closed { pages: 0 },
        }
    }

    /// `(free, total)` pages of the route's KV arena — `None` until the
    /// first step/prefill binds the pool. Test/ops probe for the
    /// free-list round-trip invariant.
    pub fn kv_pages(&self) -> Option<(usize, usize)> {
        self.kv.borrow().as_ref().map(|p| (p.free_pages(), p.config().pages))
    }

    /// Gracefully drain the route: spill EVERY live session's pages to
    /// the host store (verbatim, checksummed), record still-unbound
    /// sessions as open ids, and hand the whole store out. Afterwards
    /// the session table is empty and the arena's free list is full —
    /// the caller can drop this pipeline and later feed the report's
    /// store to a fresh one via [`Self::adopt_spill`], which resumes
    /// every session bit-identically (conformance invariant 10). Call
    /// between rounds only (the server's control loop does).
    pub fn drain(&self) -> DrainReport {
        let mut sessions = self.sessions.borrow_mut();
        let mut kv = self.kv.borrow_mut();
        let mut store = self.spill.borrow_mut();
        let mut ids: Vec<u64> = sessions.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let Some(state) = sessions.remove(&id) else { continue };
            match state {
                SessionKv::Live(seq) => match kv.as_mut() {
                    Some(kvp) => {
                        let tokens = seq.len();
                        let pages = store.spill(kvp, id, seq);
                        let mut obs = self.obs.borrow_mut();
                        obs.inc(names::SCHED_SPILLED);
                        obs.event(
                            "spill",
                            &[
                                ("session", id as i64),
                                ("pages", pages as i64),
                                ("tokens", tokens as i64),
                            ],
                        );
                    }
                    None => {
                        debug_assert!(false, "live sessions imply a bound pool");
                        self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
                    }
                },
                // already host-side: the record rides the store as-is
                SessionKv::Spilled { .. } => {}
                SessionKv::Unbound => store.note_open(id),
                SessionKv::InFlight => {
                    // drains run between rounds; an in-flight slot here
                    // is an invariant breach — the session is dropped,
                    // counted, never a panic
                    debug_assert!(false, "drain must run between rounds");
                    self.obs.borrow_mut().inc(names::SCHED_UNRESOLVED);
                }
            }
        }
        // per-session bookkeeping dies with the table
        self.last_used.borrow_mut().clear();
        self.dead.borrow_mut().clear();
        let spill = std::mem::take(&mut *store);
        DrainReport {
            sessions_spilled: spill.len(),
            pages_spilled: spill.pages_held(),
            tokens_spilled: spill.tokens_held(),
            sessions_open: spill.open_sessions().len(),
            next_session: self.next_session.get(),
            spill,
        }
    }

    /// Adopt a drain's [`DrainReport`]: every spilled session reappears
    /// as [`SessionKv::Spilled`] (restored bit-identically on its next
    /// step/prefill), every recorded open session as
    /// [`SessionKv::Unbound`], and the id counter resumes from the
    /// drained pipeline's — so post-restart opens mint exactly the ids
    /// an undrained run would have. Replaces this route's store —
    /// intended for a freshly built pipeline (restart).
    pub fn adopt_spill(&self, report: DrainReport) {
        let DrainReport { next_session, spill: store, .. } = report;
        let mut sessions = self.sessions.borrow_mut();
        let mut max_id =
            self.next_session.get().saturating_sub(1).max(next_session.saturating_sub(1));
        for id in store.open_sessions() {
            sessions.entry(id).or_insert(SessionKv::Unbound);
            max_id = max_id.max(id);
        }
        for id in store.ids_sorted() {
            if let Some(rec) = store.session(id) {
                sessions.insert(
                    id,
                    SessionKv::Spilled { groups: rec.groups(), tokens: rec.tokens() },
                );
                max_id = max_id.max(id);
            }
        }
        self.next_session.set(max_id + 1);
        *self.spill.borrow_mut() = store;
    }

    /// Sessions currently parked in the host spill store (test/ops probe).
    pub fn spilled_sessions(&self) -> usize {
        self.spill.borrow().len()
    }

    /// Chaos hook (see [`SpillStore::corrupt`]): rot `session`'s host
    /// copy so its next restore demotes to the replay log; with
    /// `wipe_replay` the log dies too — the both-encodings-dead terminal
    /// case. `false` when the session has no spill record.
    pub fn corrupt_spill(&self, session: u64, wipe_replay: bool) -> bool {
        self.spill.borrow_mut().corrupt(session, wipe_replay)
    }
}

/// What a graceful drain moved host-side (see [`DecodePipeline::drain`]
/// / [`super::Coordinator::drain`]): the counts, plus the
/// [`SpillStore`] itself — plain host memory a restarted pipeline
/// re-adopts ([`DecodePipeline::adopt_spill`]) to resume every session
/// bit-identically.
#[derive(Debug, Default)]
pub struct DrainReport {
    /// sessions whose pages now live in the store
    pub sessions_spilled: usize,
    /// pages the store holds (all returned to the arena's free list)
    pub pages_spilled: usize,
    /// tokens those pages held
    pub tokens_spilled: usize,
    /// open-but-unbound sessions riding the store as ids only
    pub sessions_open: usize,
    /// the drained pipeline's next session id (adoption advances past it)
    pub next_session: u64,
    /// every spilled session's pages, verbatim and checksummed
    pub spill: SpillStore,
}

/// Check (or lazily create, `pages` big) the route's shared KV arena for
/// a step/prefill of geometry `(g, d)`. A freshly bound pool inherits
/// the route's fault plan.
fn bind_decode_pool(
    kv_ref: &mut Option<KvPool>,
    g: usize,
    d: usize,
    pages: usize,
    faults: FaultPlan,
) -> Result<()> {
    if let Some(p) = kv_ref.as_ref() {
        let cfg = *p.config();
        if cfg.kv_heads != g || cfg.d_head != d {
            bail!(
                "decode step shape (g{g}, d{d}) incompatible with the pool's (g{}, d{})",
                cfg.kv_heads,
                cfg.d_head
            );
        }
    } else {
        let mut pool = KvPool::new(KvConfig {
            pages,
            page_size: DECODE_PAGE_SIZE,
            kv_heads: g,
            d_head: d,
        });
        pool.set_fault_plan(faults);
        *kv_ref = Some(pool);
    }
    Ok(())
}

/// What admitting more tokens for a session would cost — the
/// scheduler's admission probe, built on [`KvPool::pages_needed`] /
/// [`KvPool::pages_needed_for_step`] so exhaustion is predicted at
/// admit time.
pub(super) struct AdmitCost {
    /// pages the admission must allocate (an evicted session's whole
    /// prefix counts: restore precedes the new tokens)
    pub pages: usize,
    /// the session's resident tokens after the round
    pub tokens_after: usize,
}

/// Pick one resident victim under `policy` and spill it to `store`:
/// its pages are copied host-side verbatim (blocks, byte sums, affines,
/// checksummed, with an independent replay-row log — see
/// [`crate::kv::spill`]), returned to the free list, and the session
/// parked as [`SessionKv::Spilled`]. In-flight and already-spilled
/// sessions are never picked; sessions in `exclude` are spared; ties
/// break toward the **youngest** (largest) id, which also makes
/// [`VictimPolicy::YoungestId`] the degenerate everything-ties case.
/// Returns the victim id and pages freed, `None` when nothing is
/// evictable.
fn spill_victim_session(
    sessions: &mut HashMap<u64, SessionKv>,
    kvp: &mut KvPool,
    store: &mut SpillStore,
    exclude: &HashSet<u64>,
    policy: VictimPolicy,
    last_used: &HashMap<u64, u64>,
) -> Option<(u64, usize)> {
    let victim = sessions
        .iter()
        .filter_map(|(id, st)| match st {
            SessionKv::Live(s) if !exclude.contains(id) && !s.pages().is_empty() => {
                Some((*id, s.pages().len()))
            }
            _ => None,
        })
        // largest key wins; the id rides as the tuple's low-order
        // component so every tie breaks toward the youngest id
        .max_by_key(|&(id, pages)| match policy {
            VictimPolicy::YoungestId => (0u64, id),
            VictimPolicy::Lru => (u64::MAX - last_used.get(&id).copied().unwrap_or(0), id),
            VictimPolicy::LargestFirst => (pages as u64, id),
            VictimPolicy::CheapestSpill => (u64::MAX - pages as u64, id),
        })
        .map(|(id, _)| id)?;
    let seq = match sessions.get_mut(&victim) {
        Some(state) if matches!(state, SessionKv::Live(_)) => {
            match std::mem::replace(state, SessionKv::Unbound) {
                SessionKv::Live(seq) => seq,
                _ => unreachable!("matched Live above"),
            }
        }
        _ => {
            debug_assert!(false, "victim picked above is live");
            return None;
        }
    };
    let (groups, tokens) = (*seq.groups(), seq.len());
    let pages = store.spill(kvp, victim, seq);
    sessions.insert(victim, SessionKv::Spilled { groups, tokens });
    Some((victim, pages))
}

/// Check (or bind, on the first step/prefill) a session's head geometry.
fn bind_session_heads(slot: &mut SessionKv, h: usize, g: usize) -> Result<()> {
    let bound = match slot {
        SessionKv::Unbound => None,
        SessionKv::Live(s) => Some(*s.groups()),
        SessionKv::Spilled { groups, .. } => Some(*groups),
        SessionKv::InFlight => unreachable!("sessions are not in-flight at admission"),
    };
    match bound {
        Some(sg) => {
            if sg.q_heads() != h || sg.kv_heads() != g {
                bail!(
                    "decode step heads (H{h}, g{g}) do not match the session's (H{}, g{})",
                    sg.q_heads(),
                    sg.kv_heads()
                );
            }
        }
        None => {
            *slot = SessionKv::Live(KvSeq::new(HeadGroups::new(h, g)?, DECODE_AFFINE, DECODE_AFFINE));
        }
    }
    Ok(())
}

/// A decode step must be 2-D f32: q `(H, d)`, k/v `(G, d)` with matching
/// depth, non-zero dims, and `G` dividing `H`. Returns `(H, G, d)`.
fn validate_decode_step(q: &Tensor, k: &Tensor, v: &Tensor) -> Result<(usize, usize, usize)> {
    let (qd, kd, vd) = (&q.dims, &k.dims, &v.dims);
    if qd.len() != 2 || kd.len() != 2 || vd.len() != 2 {
        bail!("decode step must be 2-D (heads, d_head), got q{qd:?} k{kd:?} v{vd:?}");
    }
    if kd != vd {
        bail!("k/v step shapes must match, got {kd:?} vs {vd:?}");
    }
    if qd[1] != kd[1] {
        bail!("q depth {} incompatible with k/v depth {}", qd[1], kd[1]);
    }
    if qd.iter().any(|&x| x == 0) || kd.iter().any(|&x| x == 0) {
        bail!("decode step has a zero dimension: q{qd:?} k/v{kd:?}");
    }
    let (h, g, d) = (qd[0], kd[0], qd[1]);
    if g > h || h % g != 0 {
        bail!("kv heads ({g}) must evenly divide query heads ({h})");
    }
    q.as_f32()?;
    k.as_f32()?;
    v.as_f32()?;
    Ok((h, g, d))
}

/// A decode prefill chunk must be 3-D f32: q `(T', H, d)`, k/v
/// `(T', G, d)` with `T' >= 1`, matching depth and chunk length, non-zero
/// dims, and `G` dividing `H`. Returns `(T', H, G, d)`.
fn validate_decode_prefill(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
) -> Result<(usize, usize, usize, usize)> {
    let (qd, kd, vd) = (&q.dims, &k.dims, &v.dims);
    if qd.len() != 3 || kd.len() != 3 || vd.len() != 3 {
        bail!("decode prefill must be 3-D (tokens, heads, d_head), got q{qd:?} k{kd:?} v{vd:?}");
    }
    if kd != vd {
        bail!("k/v prefill shapes must match, got {kd:?} vs {vd:?}");
    }
    if qd[0] != kd[0] {
        bail!("q chunk length {} incompatible with k/v chunk length {}", qd[0], kd[0]);
    }
    if qd[2] != kd[2] {
        bail!("q depth {} incompatible with k/v depth {}", qd[2], kd[2]);
    }
    if qd.iter().any(|&x| x == 0) || kd.iter().any(|&x| x == 0) {
        bail!("decode prefill has a zero dimension: q{qd:?} k/v{kd:?}");
    }
    let (t, h, g, d) = (qd[0], qd[1], kd[1], qd[2]);
    if g > h || h % g != 0 {
        bail!("kv heads ({g}) must evenly divide query heads ({h})");
    }
    q.as_f32()?;
    k.as_f32()?;
    v.as_f32()?;
    Ok((t, h, g, d))
}

/// Attention payloads must be 4-D `(B,H,L,d)` / `(B,H,S,d)` f32 with
/// matching batch/head/depth, and PAD lengths (if any) one per batch.
fn validate_attention_payload(r: &AttnRequest) -> Result<(AttnShape, AttnMask)> {
    let (qd, kd, vd) = (&r.q.dims, &r.k.dims, &r.v.dims);
    if qd.len() != 4 || kd.len() != 4 || vd.len() != 4 {
        bail!("attention payload must be 4-D (B,H,L,d), got q{qd:?} k{kd:?} v{vd:?}");
    }
    if kd != vd {
        bail!("k/v shapes must match, got {kd:?} vs {vd:?}");
    }
    if qd[0] != kd[0] || qd[1] != kd[1] || qd[3] != kd[3] {
        bail!("q {qd:?} incompatible with k/v {kd:?} (batch/heads/depth must match)");
    }
    if qd.iter().any(|&d| d == 0) || kd.iter().any(|&d| d == 0) {
        bail!("attention payload has a zero dimension: q{qd:?} k/v{kd:?}");
    }
    r.q.as_f32()?;
    r.k.as_f32()?;
    r.v.as_f32()?;
    let shape = AttnShape {
        batch: qd[0],
        heads: qd[1],
        len_q: qd[2],
        len_k: kd[2],
        d_head: qd[3],
    };
    let mask = match (r.causal, r.pad_lens) {
        (true, Some(_)) => bail!("causal and pad_lens are mutually exclusive"),
        (true, None) => AttnMask::Causal,
        (false, Some(lens)) => {
            if lens.len() != shape.batch {
                bail!("pad_lens has {} entries for batch {}", lens.len(), shape.batch);
            }
            AttnMask::Padding(lens.to_vec())
        }
        (false, None) => AttnMask::Dense,
    };
    Ok((shape, mask))
}

/// CPU fallback: coalesce same-width requests into one row-concatenated
/// `ParSoftmax` call (rows are independent, so the split-back is exact),
/// reusing the pipeline's scratch across the whole batch. Small batched
/// requests thereby reach the worker pool's fan-out threshold together.
fn cpu_batch(par: &ParSoftmax, scratch: &mut Scratch, xs: &[&Tensor]) -> Vec<Result<Tensor>> {
    let mut results: Vec<Option<Result<Tensor>>> = xs.iter().map(|_| None).collect();
    // group valid requests by row width, preserving order within a group
    let mut groups: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
    for (i, x) in xs.iter().enumerate() {
        match validate_softmax_payload(x, None) {
            Ok(n) => groups.entry(n).or_default().push(i),
            Err(e) => results[i] = Some(Err(e)),
        }
    }
    for (n, idxs) in groups {
        // a payload that fails dtype extraction here (validated above,
        // so this is defensive) errors individually instead of panicking
        // the engine thread for the whole batch
        let mut ok_idxs = Vec::with_capacity(idxs.len());
        let mut data = Vec::new();
        for &i in &idxs {
            match xs[i].as_f32() {
                Ok(d) => {
                    data.extend_from_slice(d);
                    ok_idxs.push(i);
                }
                Err(e) => results[i] = Some(Err(e)),
            }
        }
        if ok_idxs.is_empty() {
            continue;
        }
        let mut out = vec![0.0f32; data.len()];
        par.run_with(&data, n, &mut out, scratch);
        let mut off = 0;
        for &i in &ok_idxs {
            let len = xs[i].len();
            results[i] = Some(Ok(Tensor::f32(
                xs[i].dims.clone(),
                out[off..off + len].to_vec(),
            )));
            off += len;
        }
    }
    results
        .into_iter()
        .map(|r| r.expect("every request resolved"))
        .collect()
}

/// A softmax payload must be 2-D with non-zero columns (and match the
/// artifact width when one is fixed). Returns the row length.
fn validate_softmax_payload(x: &Tensor, want_cols: Option<usize>) -> Result<usize> {
    if x.dims.len() != 2 {
        bail!("softmax payload must be 2-D, got {:?}", x.dims);
    }
    let n = x.dims[1];
    if n == 0 {
        bail!("softmax payload has zero-length rows");
    }
    if let Some(c) = want_cols {
        if n != c {
            bail!("softmax payload {:?} incompatible with artifact width {c}", x.dims);
        }
    }
    x.as_f32()?;
    Ok(n)
}

fn pjrt_batch(
    engine: &Engine,
    exe: &xla::PjRtLoadedExecutable,
    tables: &[xla::PjRtBuffer],
    rows: usize,
    cols: usize,
    xs: &[&Tensor],
) -> Vec<Result<Tensor>> {
    // validate up front; invalid requests error individually
    // (shape_errs[i].is_none() <=> request i is in `work`)
    let mut shape_errs: Vec<Option<anyhow::Error>> = Vec::with_capacity(xs.len());
    let mut work: Vec<usize> = Vec::new();
    for (i, x) in xs.iter().enumerate() {
        match validate_softmax_payload(x, Some(cols)) {
            Ok(_) => {
                shape_errs.push(None);
                work.push(i);
            }
            Err(e) => {
                shape_errs.push(Some(e));
            }
        }
    }

    let run = || -> Result<Vec<Vec<f32>>> {
        let mut bufs: Vec<Vec<f32>> = work.iter().map(|&i| vec![0.0f32; xs[i].len()]).collect();
        let mut input = vec![0.0f32; rows * cols];
        // (work slot, request row) for each filled chunk row
        let mut chunk: Vec<(usize, usize)> = Vec::with_capacity(rows);
        for (wi, &i) in work.iter().enumerate() {
            let data = xs[i].as_f32()?;
            for ri in 0..xs[i].dims[0] {
                let c = chunk.len();
                input[c * cols..(c + 1) * cols]
                    .copy_from_slice(&data[ri * cols..(ri + 1) * cols]);
                chunk.push((wi, ri));
                if chunk.len() == rows {
                    flush_chunk(engine, exe, tables, rows, cols, &input, &mut chunk, &mut bufs)?;
                }
            }
        }
        if !chunk.is_empty() {
            // zero the padding rows left over from the previous chunk
            for r in chunk.len()..rows {
                input[r * cols..(r + 1) * cols].fill(0.0);
            }
            flush_chunk(engine, exe, tables, rows, cols, &input, &mut chunk, &mut bufs)?;
        }
        Ok(bufs)
    };

    match run() {
        Ok(bufs) => {
            let mut outs = bufs.into_iter();
            xs.iter()
                .enumerate()
                .map(|(i, x)| match shape_errs[i].take() {
                    None => Ok(Tensor::f32(x.dims.clone(), outs.next().expect("one buf per ok"))),
                    Some(e) => Err(e),
                })
                .collect()
        }
        Err(e) => {
            // execution failure fails the whole coalesced batch
            let msg = e.to_string();
            (0..xs.len())
                .map(|i| match shape_errs[i].take() {
                    None => Err(anyhow!("{msg}")),
                    Some(e) => Err(e),
                })
                .collect()
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn flush_chunk(
    engine: &Engine,
    exe: &xla::PjRtLoadedExecutable,
    tables: &[xla::PjRtBuffer],
    rows: usize,
    cols: usize,
    input: &[f32],
    chunk: &mut Vec<(usize, usize)>,
    bufs: &mut [Vec<f32>],
) -> Result<()> {
    let t = Tensor::f32(vec![rows, cols], input.to_vec());
    let in_buf = engine.host_to_device(&t)?;
    let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + tables.len());
    args.push(&in_buf);
    args.extend(tables.iter());
    let out = engine
        .run_exe(exe, &args)?
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("softmax artifact returned nothing"))?;
    let ov = out.as_f32()?;
    for (ci, &(wi, ri)) in chunk.iter().enumerate() {
        bufs[wi][ri * cols..(ri + 1) * cols].copy_from_slice(&ov[ci * cols..(ci + 1) * cols]);
    }
    chunk.clear();
    Ok(())
}
