//! Serving metrics: counters and log-bucketed latency histograms.
//!
//! The histogram type and the source of truth for the scheduler counters
//! both live in [`crate::obs`] since the registry unification;
//! [`Counters`] remains the stable snapshot struct (`serve` prints its
//! [`Counters::summary`] line and tests depend on the exact bytes), but
//! it is **derived** from a [`MetricsRegistry`] via
//! [`Counters::from_registry`] so the two can never drift.

use crate::config::Json;
use crate::obs::{names, MetricsRegistry};

pub use crate::obs::Histogram;

/// Continuous-batching scheduler counters — a snapshot struct so `serve`
/// (and tests) can read one coherent stats line per run. The engine
/// maintains the registry; this is its fixed-field projection, copied
/// into [`Metrics::sched`] after every decode batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// serving rounds executed (each = one wave + its prefills/closes)
    pub rounds: u64,
    /// decode steps admitted into rounds
    pub admitted_steps: u64,
    /// prefill chunks admitted into rounds
    pub admitted_prefills: u64,
    /// sessions evicted to reclaim KV pages
    pub evicted: u64,
    /// evicted sessions re-admitted (restored) into a later round
    pub requeued: u64,
    /// requests answered with typed exhaustion (session alone exceeds
    /// the arena — eviction could not help)
    pub exhausted: u64,
    /// Σ over rounds of KV tokens resident after the round (occupancy)
    pub occupancy_tokens: u64,
    /// Σ over rounds of sessions served in the round
    pub occupancy_sessions: u64,
    /// deepest waiting queue observed at round assembly
    pub peak_queue_depth: u64,
    /// requests shed unexecuted (deadline overrun — organic or injected —
    /// or bounded waiting queue); answered with `Reply::Shed`
    pub shed: u64,
    /// steps/prefills whose sweep task panicked (contained: only the
    /// owning session's request failed, answered with `Reply::Error`)
    pub panicked: u64,
    /// idle sessions closed by the TTL reaper (pages reclaimed)
    pub reaped: u64,
    /// replies dropped because the client hung up (receiver gone); the
    /// session becomes reap-eligible
    pub dead_replies: u64,
    /// requests a finished round failed to resolve — a scheduler
    /// invariant breach (debug builds assert instead of counting);
    /// answered with `Reply::Error` rather than a panic
    pub unresolved: u64,
}

/// (field, registry series) pairs backing the registry projection — one
/// table so `from_registry` and `from_stats_json` read the same names.
const COUNTER_NAMES: [&str; 13] = [
    names::SCHED_ROUNDS,
    names::SCHED_STEPS,
    names::SCHED_PREFILLS,
    names::SCHED_EVICTED,
    names::SCHED_REQUEUED,
    names::SCHED_EXHAUSTED,
    names::SCHED_OCC_TOKENS,
    names::SCHED_OCC_SESSIONS,
    names::SCHED_SHED,
    names::SCHED_PANICKED,
    names::SCHED_REAPED,
    names::SCHED_DEAD_REPLIES,
    names::SCHED_UNRESOLVED,
];

impl Counters {
    fn from_values(v: [u64; 13], peak: u64) -> Self {
        Self {
            rounds: v[0],
            admitted_steps: v[1],
            admitted_prefills: v[2],
            evicted: v[3],
            requeued: v[4],
            exhausted: v[5],
            occupancy_tokens: v[6],
            occupancy_sessions: v[7],
            shed: v[8],
            panicked: v[9],
            reaped: v[10],
            dead_replies: v[11],
            unresolved: v[12],
            peak_queue_depth: peak,
        }
    }

    /// Project the registry's `sched_*` series into the snapshot struct.
    pub fn from_registry(reg: &MetricsRegistry) -> Self {
        let mut v = [0u64; 13];
        for (slot, name) in v.iter_mut().zip(COUNTER_NAMES) {
            *slot = reg.counter(name);
        }
        Self::from_values(v, reg.gauge(names::SCHED_QUEUE_PEAK).max(0) as u64)
    }

    /// Rebuild the snapshot from a `--stats-json` document (the
    /// serialized [`MetricsRegistry::to_json`] form) — `serve` uses this
    /// to prove the written snapshot reconciles with the summary line.
    pub fn from_stats_json(stats: &Json) -> Option<Self> {
        let counters = stats.get("counters")?;
        let read = |name: &str| counters.get(name).and_then(Json::as_i64).unwrap_or(0) as u64;
        let mut v = [0u64; 13];
        for (slot, name) in v.iter_mut().zip(COUNTER_NAMES) {
            *slot = read(name);
        }
        let peak = stats
            .get("gauges")
            .and_then(|g| g.get(names::SCHED_QUEUE_PEAK))
            .and_then(Json::as_i64)
            .unwrap_or(0)
            .max(0) as u64;
        Some(Self::from_values(v, peak))
    }

    /// mean sessions served per round
    pub fn mean_round_sessions(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.occupancy_sessions as f64 / self.rounds as f64
    }

    /// mean KV tokens resident per round
    pub fn mean_round_tokens(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.occupancy_tokens as f64 / self.rounds as f64
    }

    /// One-line human summary for `serve` stats output.
    pub fn summary(&self) -> String {
        format!(
            "rounds={} steps={} prefills={} evicted={} requeued={} exhausted={} \
             occ_sessions={:.2} occ_tokens={:.1} peak_queue={} \
             shed={} panicked={} reaped={} dead={} unresolved={}",
            self.rounds,
            self.admitted_steps,
            self.admitted_prefills,
            self.evicted,
            self.requeued,
            self.exhausted,
            self.mean_round_sessions(),
            self.mean_round_tokens(),
            self.peak_queue_depth,
            self.shed,
            self.panicked,
            self.reaped,
            self.dead_replies,
            self.unresolved,
        )
    }
}

/// Per-task serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub rejected: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub latency: Histogram,
    pub queue_wait: Histogram,
    /// decode-route scheduler counters (zero for other tasks)
    pub sched: Counters,
}

impl Metrics {
    pub fn new() -> Self {
        Self { latency: Histogram::new(), queue_wait: Histogram::new(), ..Default::default() }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batched_requests as f64 / self.batches as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_size_mean() {
        let mut m = Metrics::new();
        m.batches = 2;
        m.batched_requests = 12;
        assert_eq!(m.mean_batch_size(), 6.0);
    }

    fn fixture() -> Counters {
        Counters {
            rounds: 4,
            admitted_steps: 10,
            admitted_prefills: 2,
            evicted: 1,
            requeued: 1,
            exhausted: 0,
            occupancy_tokens: 100,
            occupancy_sessions: 10,
            peak_queue_depth: 7,
            shed: 3,
            panicked: 2,
            reaped: 1,
            dead_replies: 5,
            unresolved: 6,
        }
    }

    fn registry_for(c: &Counters) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.add(names::SCHED_ROUNDS, c.rounds);
        r.add(names::SCHED_STEPS, c.admitted_steps);
        r.add(names::SCHED_PREFILLS, c.admitted_prefills);
        r.add(names::SCHED_EVICTED, c.evicted);
        r.add(names::SCHED_REQUEUED, c.requeued);
        r.add(names::SCHED_EXHAUSTED, c.exhausted);
        r.add(names::SCHED_OCC_TOKENS, c.occupancy_tokens);
        r.add(names::SCHED_OCC_SESSIONS, c.occupancy_sessions);
        r.add(names::SCHED_SHED, c.shed);
        r.add(names::SCHED_PANICKED, c.panicked);
        r.add(names::SCHED_REAPED, c.reaped);
        r.add(names::SCHED_DEAD_REPLIES, c.dead_replies);
        r.add(names::SCHED_UNRESOLVED, c.unresolved);
        r.gauge_max(names::SCHED_QUEUE_PEAK, c.peak_queue_depth as i64);
        r
    }

    #[test]
    fn counters_snapshot_means_and_summary() {
        let c = Counters::default();
        assert_eq!(c.mean_round_sessions(), 0.0);
        assert_eq!(c.mean_round_tokens(), 0.0);
        let c = fixture();
        assert_eq!(c.mean_round_sessions(), 2.5);
        assert_eq!(c.mean_round_tokens(), 25.0);
        let s = c.summary();
        assert!(s.contains("rounds=4"), "{s}");
        assert!(s.contains("evicted=1"), "{s}");
        assert!(s.contains("peak_queue=7"), "{s}");
        assert!(s.contains("shed=3"), "{s}");
        assert!(s.contains("panicked=2"), "{s}");
        assert!(s.contains("reaped=1"), "{s}");
        assert!(s.contains("dead=5"), "{s}");
        assert!(s.contains("unresolved=6"), "{s}");
    }

    #[test]
    fn registry_projection_roundtrips_every_field() {
        let want = fixture();
        let reg = registry_for(&want);
        assert_eq!(Counters::from_registry(&reg), want);
        // the empty registry projects to the zero snapshot
        assert_eq!(Counters::from_registry(&MetricsRegistry::new()), Counters::default());
    }

    #[test]
    fn stats_json_roundtrips_through_serialization() {
        let want = fixture();
        let reg = registry_for(&want);
        let text = reg.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let got = Counters::from_stats_json(&parsed).unwrap();
        assert_eq!(got, want);
        assert_eq!(got.summary(), want.summary());
        assert!(Counters::from_stats_json(&Json::Null).is_none());
    }
}
