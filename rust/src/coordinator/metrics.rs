//! Serving metrics: counters and log-bucketed latency histograms.

use std::time::Duration;

/// Log2-bucketed latency histogram (1 us .. ~1 h), lock-free enough for a
/// single-writer engine thread; readers take a snapshot clone.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Percentile estimate from bucket boundaries (upper bound of bucket).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (self.count as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }
}

/// Per-task serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub rejected: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub latency: Histogram,
    pub queue_wait: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self { latency: Histogram::new(), queue_wait: Histogram::new(), ..Default::default() }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batched_requests as f64 / self.batches as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = Histogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(0.5) <= h.percentile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn batch_size_mean() {
        let mut m = Metrics::new();
        m.batches = 2;
        m.batched_requests = 12;
        assert_eq!(m.mean_batch_size(), 6.0);
    }
}
