//! Serving metrics: counters and log-bucketed latency histograms.

use std::time::Duration;

/// Log2-bucketed latency histogram (1 us .. ~1 h), lock-free enough for a
/// single-writer engine thread; readers take a snapshot clone.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Percentile estimate from bucket boundaries (upper bound of bucket).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (self.count as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }
}

/// Continuous-batching scheduler counters — a snapshot struct so `serve`
/// (and tests) can read one coherent stats line per run. Maintained by
/// `coordinator::scheduler` per decode route; copied into
/// [`Metrics::sched`] after every decode batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// serving rounds executed (each = one wave + its prefills/closes)
    pub rounds: u64,
    /// decode steps admitted into rounds
    pub admitted_steps: u64,
    /// prefill chunks admitted into rounds
    pub admitted_prefills: u64,
    /// sessions evicted to reclaim KV pages
    pub evicted: u64,
    /// evicted sessions re-admitted (restored) into a later round
    pub requeued: u64,
    /// requests answered with typed exhaustion (session alone exceeds
    /// the arena — eviction could not help)
    pub exhausted: u64,
    /// Σ over rounds of KV tokens resident after the round (occupancy)
    pub occupancy_tokens: u64,
    /// Σ over rounds of sessions served in the round
    pub occupancy_sessions: u64,
    /// deepest waiting queue observed at round assembly
    pub peak_queue_depth: u64,
    /// requests shed unexecuted (deadline overrun — organic or injected —
    /// or bounded waiting queue); answered with `Reply::Shed`
    pub shed: u64,
    /// steps/prefills whose sweep task panicked (contained: only the
    /// owning session's request failed, answered with `Reply::Error`)
    pub panicked: u64,
    /// idle sessions closed by the TTL reaper (pages reclaimed)
    pub reaped: u64,
    /// replies dropped because the client hung up (receiver gone); the
    /// session becomes reap-eligible
    pub dead_replies: u64,
}

impl Counters {
    /// mean sessions served per round
    pub fn mean_round_sessions(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.occupancy_sessions as f64 / self.rounds as f64
    }

    /// mean KV tokens resident per round
    pub fn mean_round_tokens(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.occupancy_tokens as f64 / self.rounds as f64
    }

    /// One-line human summary for `serve` stats output.
    pub fn summary(&self) -> String {
        format!(
            "rounds={} steps={} prefills={} evicted={} requeued={} exhausted={} \
             occ_sessions={:.2} occ_tokens={:.1} peak_queue={} \
             shed={} panicked={} reaped={} dead={}",
            self.rounds,
            self.admitted_steps,
            self.admitted_prefills,
            self.evicted,
            self.requeued,
            self.exhausted,
            self.mean_round_sessions(),
            self.mean_round_tokens(),
            self.peak_queue_depth,
            self.shed,
            self.panicked,
            self.reaped,
            self.dead_replies,
        )
    }
}

/// Per-task serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub rejected: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub latency: Histogram,
    pub queue_wait: Histogram,
    /// decode-route scheduler counters (zero for other tasks)
    pub sched: Counters,
}

impl Metrics {
    pub fn new() -> Self {
        Self { latency: Histogram::new(), queue_wait: Histogram::new(), ..Default::default() }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batched_requests as f64 / self.batches as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = Histogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(0.5) <= h.percentile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn batch_size_mean() {
        let mut m = Metrics::new();
        m.batches = 2;
        m.batched_requests = 12;
        assert_eq!(m.mean_batch_size(), 6.0);
    }

    #[test]
    fn histogram_bucket_edges() {
        // 1 us lands in bucket 0 -> percentile reports its upper bound 2
        let mut h = Histogram::new();
        h.record(Duration::from_micros(1));
        assert_eq!(h.percentile_us(1.0), 2);
        assert_eq!(h.max_us(), 1);
        // an exact power of two (1024 us) lands in bucket 10 -> bound 2048
        let mut h = Histogram::new();
        h.record(Duration::from_micros(1024));
        assert_eq!(h.percentile_us(0.5), 2048);
        // sub-microsecond samples clamp to 1 us (bucket 0), never panic
        let mut h = Histogram::new();
        h.record(Duration::ZERO);
        assert_eq!(h.percentile_us(1.0), 2);
        assert_eq!(h.mean_us(), 1.0);
        // huge samples saturate the last bucket (31) -> bound 1 << 32
        let mut h = Histogram::new();
        h.record(Duration::from_micros(1 << 40));
        assert_eq!(h.percentile_us(1.0), 1u64 << 32);
    }

    #[test]
    fn counters_snapshot_means_and_summary() {
        let c = Counters::default();
        assert_eq!(c.mean_round_sessions(), 0.0);
        assert_eq!(c.mean_round_tokens(), 0.0);
        let c = Counters {
            rounds: 4,
            admitted_steps: 10,
            admitted_prefills: 2,
            evicted: 1,
            requeued: 1,
            exhausted: 0,
            occupancy_tokens: 100,
            occupancy_sessions: 10,
            peak_queue_depth: 7,
            shed: 3,
            panicked: 2,
            reaped: 1,
            dead_replies: 5,
        };
        assert_eq!(c.mean_round_sessions(), 2.5);
        assert_eq!(c.mean_round_tokens(), 25.0);
        let s = c.summary();
        assert!(s.contains("rounds=4"), "{s}");
        assert!(s.contains("evicted=1"), "{s}");
        assert!(s.contains("peak_queue=7"), "{s}");
        assert!(s.contains("shed=3"), "{s}");
        assert!(s.contains("panicked=2"), "{s}");
        assert!(s.contains("reaped=1"), "{s}");
        assert!(s.contains("dead=5"), "{s}");
    }
}
