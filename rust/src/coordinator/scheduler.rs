//! Continuous-batching decode scheduler: token-budget admission,
//! eviction/requeue on KV exhaustion, and starvation-free serving
//! rounds.
//!
//! [`run`] is the decode route's router loop. It holds the ready batch
//! as a waiting queue and serves it as a sequence of **rounds**; each
//! round admits waiting opens / chunked prefills / decode steps /
//! closes into the *current* wave under the [`SchedConfig`] budgets,
//! instead of the old barrier loop (steps coalesced only between
//! opens/prefills, which flushed everything). The admitted steps go
//! down as ONE [`crate::attention::DecodeBatch`] head-scatter wave;
//! prefills ride along in the same round through `prefill_chunk_par`.
//!
//! # Round assembly
//!
//! Items are scanned in arrival order. The first time a session is
//! seen in a pass it is either admitted or skipped — either way the
//! session is *blocked* for the rest of the pass, so at most one item
//! per session enters a round and every session's requests execute in
//! its own arrival order (the bit-identity precondition; see the wire
//! contract in [`super::request`]). Admission is budgeted:
//!
//! * **pages** — the item's [`KvPool`](crate::kv::KvPool) cost
//!   (admission probes `pages_needed*`) must fit the free list, plus
//!   the pages admitted closes will free (closes execute first), minus
//!   pages already reserved this round;
//! * **`max_batch_total_tokens`** — Σ resident-tokens-after-round over
//!   the admitted sessions;
//! * **`max_batch_prefill_tokens`** — Σ admitted prefill chunk tokens
//!   (the round's MAC budget);
//! * **prefill priority** — when the waiting prefill queue outweighs
//!   the waiting steps (`waiting_served_ratio`) or its token mass
//!   reaches `max_waiting_tokens`, a round admits only prefills (and
//!   opens/closes), draining the prompt queue before decode resumes.
//!
//! Budgets shape rounds, they never starve: an item exceeding a budget
//! alone is still admitted alone, and a prefill-only pass that admits
//! nothing falls back to a normal pass. The **front** item of a pass
//! has eviction privilege — if its pages don't fit, resident sessions
//! are picked by the route's [`VictimPolicy`] and spilled to host
//! (pages copied verbatim off-arena and reclaimed; see
//! `SessionKv::Spilled` in `engine_ops` and [`crate::kv::spill`]) until
//! it fits. Only when no victim remains — the request alone exceeds the
//! arena — does it resolve as typed, retryable
//! [`Reply::Exhausted`]. Every pass
//! therefore admits or resolves at least its front item, which is the
//! no-starvation argument: the queue strictly shrinks or executes.
//! Admission accounting stays valid through execution because every
//! eviction a round performs — at admission *and* inside the execute
//! phase (prefill retry, mid-wave append, restore) — excludes the
//! round's own sessions: a credited close or reserved step whose pages
//! were spent twice would otherwise exhaust a step the round had
//! already funded.
//!
//! # Graceful degradation
//!
//! Two overload valves turn "the engine is drowning" into typed,
//! per-request [`Reply::Shed`] instead of unbounded queueing:
//!
//! * **bounded waiting queue** — with `max_waiting_items > 0`, steps
//!   and prefills beyond the bound shed immediately on batch ingress
//!   (`waited_rounds: 0`); opens and closes always stay (the control
//!   plane never sheds).
//! * **per-request deadline** — with `deadline_rounds > 0`, a step or
//!   prefill that has waited more than that many serving rounds sheds
//!   with the rounds it waited.
//!
//! A shed request **never executed** — the session is untouched and a
//! retry is safe (see "Failure semantics" in [`super::request`]). Both
//! valves (and admission exhaustion) carry a `retry_after_rounds` hint
//! — the waiting-queue depth divided by the round token budget, the
//! rounds after which the backlog that caused the rejection should have
//! drained — so clients back off proportionally instead of hammering
//! the next round. The
//! route's [`crate::faults::FaultPlan`] can also fire an injected
//!   deadline overrun ([`FaultSite::SchedDeadline`]); each firing sheds
//! exactly ONE oldest waiting sheddable item, so chaos tests can count
//! one typed reply per injected fault. Shedding only shrinks the
//! queue, so the no-starvation argument above is unchanged.
//!
//! # Observability
//!
//! Each round is recorded through the pipeline's [`crate::obs::ObsHub`]
//! as a `round` span with `admit` / `prefill` / `wave` child spans, plus
//! `step` / `evict` / `restore` / `shed` / `fault` instant markers (see
//! `docs/OBSERVABILITY.md` for the taxonomy). The trace records the
//! schedule; it never steers it — with no sink armed every hook is one
//! `Option` test, and admission decisions read none of it.

use std::collections::HashSet;

use super::engine_ops::DecodePipeline;
use super::request::{Payload, Reply};
use crate::faults::FaultSite;
use crate::obs::names;
use crate::runtime::Tensor;

/// Which resident session the front item's eviction privilege spills
/// when its pages don't fit. Every policy is deterministic (ties break
/// toward the youngest id, preserving the historical flavor) and honors
/// the round's exclude set; the choice moves *which* sessions pay the
/// spill/restore churn, never the reply bytes — pinned by the
/// victim-policy differential test in `integration_decode_batch.rs`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VictimPolicy {
    /// the session with the highest id — the PR 6 behavior, biased
    /// toward the most recently opened session
    #[default]
    YoungestId,
    /// least recently *used*: the session whose last admitted round is
    /// oldest — protects hot sessions at the cost of tracking touches
    Lru,
    /// the most resident pages — frees the most capacity per spill, at
    /// the largest copy-back bill when the victim returns
    LargestFirst,
    /// the fewest resident pages — the cheapest single spill (and the
    /// cheapest restore), at the cost of possibly needing several
    CheapestSpill,
}

/// Continuous-batching knobs of a decode route. Defaults suit the
/// default 4096-page arena; property/chaos tests shrink them alongside
/// `pP` route overrides via `DecodePipeline::set_sched_config`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchedConfig {
    /// cap on Σ resident tokens (after the round) across the sessions
    /// served in one round — bounds a round's KV sweep traffic
    pub max_batch_total_tokens: usize,
    /// cap on Σ prefill chunk tokens admitted into one round — the MAC
    /// budget that keeps prompt ingest from stalling decode latency
    pub max_batch_prefill_tokens: usize,
    /// drain the prefill queue when waiting prefills ≥ ratio × waiting
    /// steps
    pub waiting_served_ratio: f64,
    /// ... or when the waiting prefills' token mass reaches this
    pub max_waiting_tokens: usize,
    /// shed a step/prefill that has waited more than this many serving
    /// rounds ([`Reply::Shed`]); 0 disables the deadline
    pub deadline_rounds: usize,
    /// shed steps/prefills beyond this many waiting items at batch
    /// ingress (opens/closes always stay); 0 leaves the queue unbounded
    pub max_waiting_items: usize,
    /// reap sessions idle for this many engine batches (see
    /// `DecodePipeline::run_batch`); 0 disables the reaper
    pub idle_ttl_batches: usize,
    /// prefix-split threshold for decode waves: a step whose session
    /// holds at least this many resident tokens sweeps its prefix as
    /// page-aligned spans (`DecodeBatch::with_split_min_tokens`), so one
    /// long-context session stops monopolizing a round's wall clock; 0
    /// (the default) keeps the unsplit sweep
    pub split_min_tokens: usize,
    /// which resident session the eviction privilege spills when the
    /// front item's pages don't fit
    pub victim_policy: VictimPolicy,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self {
            max_batch_total_tokens: 4096,
            max_batch_prefill_tokens: 512,
            waiting_served_ratio: 1.2,
            max_waiting_tokens: 256,
            deadline_rounds: 0,
            max_waiting_items: 0,
            idle_ttl_batches: 0,
            split_min_tokens: 0,
            victim_policy: VictimPolicy::default(),
        }
    }
}

/// The `retry_after_rounds` hint carried by [`Reply::Shed`] and
/// [`Reply::Exhausted`]: rounds until a waiting queue of `queue_items`
/// should have drained under the round token budget. Worst case each
/// queued item needs one resident token, so a round retires up to
/// `max_batch_total_tokens` of them; the no-starvation floor guarantees
/// at least one, hence the `max(1)`. Deterministic, so chaos replays
/// reproduce the hint bit-for-bit.
fn retry_after(cfg: &SchedConfig, queue_items: usize) -> usize {
    queue_items.div_ceil(cfg.max_batch_total_tokens.max(1)).max(1)
}

/// A waiting-queue item, borrowed out of the ready batch.
enum Item<'a> {
    Open,
    Close(u64),
    Prefill {
        session: u64,
        q: &'a Tensor,
        k: &'a Tensor,
        v: &'a Tensor,
        /// chunk length when well-formed; malformed chunks cost 0 and
        /// fail with their shape error at execution
        tokens: usize,
    },
    Step {
        session: u64,
        q: &'a Tensor,
        k: &'a Tensor,
        v: &'a Tensor,
    },
}

impl Item<'_> {
    fn session(&self) -> Option<u64> {
        match self {
            Item::Open => None,
            Item::Close(s) => Some(*s),
            Item::Prefill { session, .. } | Item::Step { session, .. } => Some(*session),
        }
    }
}

/// One assembled round: admitted item indices (arrival order) plus how
/// many items the pass resolved in place (typed exhaustion).
struct Round {
    admitted: Vec<usize>,
    resolved: usize,
}

/// Serve one ready batch of decode payloads, continuously batched.
/// Replies are index-aligned with `batch`.
pub(super) fn run(pipe: &DecodePipeline, batch: &[&Payload]) -> Vec<Reply> {
    let items: Vec<Item<'_>> = batch
        .iter()
        .map(|p| match p {
            Payload::DecodeOpen => Item::Open,
            Payload::DecodeClose(s) => Item::Close(*s),
            Payload::DecodePrefill { session, q, k, v } => Item::Prefill {
                session: *session,
                q,
                k,
                v,
                tokens: if q.dims.len() == 3 { q.dims[0] } else { 0 },
            },
            Payload::DecodeStep { session, q, k, v } => {
                Item::Step { session: *session, q, k, v }
            }
            _ => unreachable!("router sends only decode payloads here"),
        })
        .collect();

    let cfg = pipe.sched_config();
    let mut replies: Vec<Option<Reply>> = batch.iter().map(|_| None).collect();
    let mut pending: Vec<usize> = (0..items.len()).collect();

    let sheddable =
        |i: usize| matches!(items[i], Item::Step { .. } | Item::Prefill { .. });
    let shed = |pipe: &DecodePipeline,
                replies: &mut [Option<Reply>],
                i: usize,
                waited: u64,
                depth: usize| {
        let mut obs = pipe.obs_mut();
        obs.inc(names::SCHED_SHED);
        obs.event("shed", &[("item", i as i64), ("waited", waited as i64)]);
        drop(obs);
        replies[i] = Some(Reply::Shed {
            waited_rounds: waited as usize,
            retry_after_rounds: retry_after(&cfg, depth),
        });
    };

    // bounded waiting queue: steps/prefills beyond the bound shed at
    // ingress, unexecuted; opens/closes (the control plane) always stay
    if cfg.max_waiting_items > 0 && pending.len() > cfg.max_waiting_items {
        let depth = pending.len();
        let mut kept = 0usize;
        for &i in &pending {
            if kept < cfg.max_waiting_items || !sheddable(i) {
                kept += 1;
            } else {
                shed(pipe, &mut replies, i, 0, depth);
            }
        }
        pending.retain(|&i| replies[i].is_none());
    }

    // rounds each still-pending item has waited (deadline accounting)
    let mut ages: Vec<u64> = vec![0; items.len()];
    // one fault draw per scheduling pass, independent of round outcomes
    let mut deadline_draws: u64 = 0;

    while !pending.is_empty() {
        // organic deadline overrun: shed what waited past the deadline
        if cfg.deadline_rounds > 0 {
            let depth = pending.len();
            for &i in &pending {
                if sheddable(i) && ages[i] > cfg.deadline_rounds as u64 {
                    shed(pipe, &mut replies, i, ages[i], depth);
                }
            }
            pending.retain(|&i| replies[i].is_none());
            if pending.is_empty() {
                break;
            }
        }
        // injected deadline overrun: each firing sheds exactly ONE item
        // — the oldest waiting sheddable one — so chaos accounting can
        // pin one typed reply per fault
        if pipe.fault_plan().should_fault(FaultSite::SchedDeadline, deadline_draws) {
            if let Some(&i) = pending.iter().find(|&&i| sheddable(i)) {
                // exactly one `fault` marker per injected firing, next to
                // the one typed `Reply::Shed` it produces
                pipe.obs_mut().event("fault", &[("item", i as i64)]);
                shed(pipe, &mut replies, i, ages[i], pending.len());
                pending.retain(|&i| replies[i].is_none());
            }
        }
        deadline_draws += 1;
        if pending.is_empty() {
            break;
        }
        pipe.obs_mut().gauge_max(names::SCHED_QUEUE_PEAK, pending.len() as i64);
        let round_t = pipe.obs_mut().stage_begin("round");
        // prefill priority: pause decode rounds to drain the prompt
        // queue when it outweighs the waiting steps
        let (mut wp_tokens, mut wp, mut ws) = (0usize, 0usize, 0usize);
        for &i in &pending {
            match &items[i] {
                Item::Prefill { tokens, .. } => {
                    wp += 1;
                    wp_tokens += *tokens;
                }
                Item::Step { .. } => ws += 1;
                _ => {}
            }
        }
        let prefill_priority = wp > 0
            && (wp_tokens >= cfg.max_waiting_tokens
                || wp as f64 >= cfg.waiting_served_ratio * ws as f64);

        let admit_t = pipe.obs_mut().stage_begin("admit");
        let mut round = assemble(pipe, &cfg, &items, &pending, &mut replies, prefill_priority);
        if round.admitted.is_empty() && round.resolved == 0 {
            // the prefill-only pass admitted nothing (every prefill sat
            // behind a blocked session): fall back to a normal pass,
            // whose front item always admits or resolves
            round = assemble(pipe, &cfg, &items, &pending, &mut replies, false);
        }
        pipe.obs_mut().stage_end(
            names::ROUND_ADMIT_US,
            admit_t,
            &[("admitted", round.admitted.len() as i64), ("resolved", round.resolved as i64)],
        );
        debug_assert!(
            !round.admitted.is_empty() || round.resolved > 0,
            "every round must make progress"
        );
        if !round.admitted.is_empty() {
            execute(pipe, &items, &round.admitted, &mut replies, &ages);
        }
        pending.retain(|&i| replies[i].is_none());
        for &i in &pending {
            ages[i] += 1;
        }
        pipe.obs_mut().stage_end(names::ROUND_US, round_t, &[("queue", pending.len() as i64)]);
    }
    // every scheduling path above resolves its items (the no-starvation
    // argument), so an unresolved slot is an internal invariant breach —
    // answer it typed and counted instead of panicking the wire loop
    replies
        .into_iter()
        .enumerate()
        .map(|(i, r)| {
            r.unwrap_or_else(|| {
                debug_assert!(false, "request {i} left unresolved by the round loop");
                pipe.obs_mut().inc(names::SCHED_UNRESOLVED);
                Reply::Error(format!("internal: request {i} left unresolved by the scheduler"))
            })
        })
        .collect()
}

/// One admission pass over the pending queue (see the module docs).
fn assemble(
    pipe: &DecodePipeline,
    cfg: &SchedConfig,
    items: &[Item<'_>],
    pending: &[usize],
    replies: &mut [Option<Reply>],
    prefill_only: bool,
) -> Round {
    let mut round = Round { admitted: Vec::new(), resolved: 0 };
    // sessions with an item already considered this pass: later items
    // of the same session must wait (per-session FIFO)
    let mut blocked: HashSet<u64> = HashSet::new();
    // sessions with an item ADMITTED this round — eviction must spare
    // them (a close's pages are already credited; a step/prefill's
    // sequence is about to be used)
    let mut in_round: HashSet<u64> = HashSet::new();
    let mut reserved_pages = 0usize;
    let mut close_credit = 0usize;
    let mut round_tokens = 0usize;
    let mut prefill_tokens = 0usize;
    let mut cost_items = 0usize;

    for &i in pending {
        let item = &items[i];
        if let Some(s) = item.session() {
            if blocked.contains(&s) {
                continue;
            }
            blocked.insert(s);
        }
        match item {
            Item::Open => round.admitted.push(i),
            Item::Close(s) => {
                // a close funds the round: its pages are credited now
                // and actually freed first at execution
                close_credit += pipe.session_pages(*s);
                in_round.insert(*s);
                round.admitted.push(i);
            }
            Item::Step { session, .. } | Item::Prefill { session, .. } => {
                let is_prefill = matches!(item, Item::Prefill { .. });
                if prefill_only && !is_prefill {
                    continue; // stays blocked: FIFO preserved
                }
                let new_tokens = match item {
                    Item::Prefill { tokens, .. } => *tokens,
                    _ => 1,
                };
                let cost = pipe.admit_cost(*session, new_tokens);
                // token budgets shape the round; an item exceeding a
                // budget alone is still admitted alone
                if cost_items > 0
                    && round_tokens + cost.tokens_after > cfg.max_batch_total_tokens
                {
                    continue;
                }
                if is_prefill
                    && prefill_tokens > 0
                    && prefill_tokens + new_tokens > cfg.max_batch_prefill_tokens
                {
                    continue;
                }
                // page budget against the free list + admitted closes
                let available =
                    |p: &DecodePipeline| (p.free_pages_now() + close_credit).saturating_sub(reserved_pages);
                if cost.pages > available(pipe) {
                    if cost_items > 0 {
                        continue; // only the front item may evict
                    }
                    // front item: spill policy-picked victims until it fits
                    let mut exclude = in_round.clone();
                    exclude.insert(*session);
                    let mut fits = true;
                    while cost.pages > available(pipe) {
                        if pipe.evict_victim(&exclude).is_none() {
                            fits = false;
                            break;
                        }
                    }
                    if !fits {
                        // nothing left to evict: the request alone
                        // exceeds the arena — typed backpressure, the
                        // session untouched and the queue unblocked
                        pipe.obs_mut().inc(names::SCHED_EXHAUSTED);
                        replies[i] = Some(Reply::Exhausted {
                            pages: pipe.total_pages(),
                            free_pages: pipe.free_pages_now(),
                            retry_after_rounds: retry_after(cfg, pending.len()),
                        });
                        round.resolved += 1;
                        continue;
                    }
                }
                reserved_pages += cost.pages;
                round_tokens += cost.tokens_after;
                if is_prefill {
                    prefill_tokens += new_tokens;
                }
                cost_items += 1;
                in_round.insert(*session);
                round.admitted.push(i);
            }
        }
    }
    round
}

/// Execute one assembled round: closes first (they fund the credited
/// pages), then opens (ids in arrival order), then prefills, then ALL
/// admitted steps as one wave. Cross-session reorder within a round is
/// unobservable — a round holds at most one item per session.
///
/// The round's sessions are threaded into every execute-phase eviction
/// (prefill retry, mid-wave append, evicted-session restore) as an
/// exclude set: admission credited a close's pages and reserved a
/// step/prefill's pages **assuming every round session survives to its
/// own item**, so evicting one mid-round would spend the same pages
/// twice and surface `KvError::Exhausted` on a step the accounting had
/// already funded.
fn execute(
    pipe: &DecodePipeline,
    items: &[Item<'_>],
    admitted: &[usize],
    replies: &mut [Option<Reply>],
    ages: &[u64],
) {
    let round_sessions: HashSet<u64> =
        admitted.iter().filter_map(|&i| items[i].session()).collect();
    for &i in admitted {
        if let Item::Close(s) = &items[i] {
            replies[i] = Some(pipe.close(*s));
        }
    }
    for &i in admitted {
        if matches!(items[i], Item::Open) {
            replies[i] = Some(pipe.open());
        }
    }
    let prefill_t = pipe.obs_mut().stage_begin("prefill");
    let mut prefills = 0u64;
    for &i in admitted {
        if let Item::Prefill { session, q, k, v, .. } = &items[i] {
            replies[i] = Some(pipe.prefill_excluding(*session, q, k, v, &round_sessions));
            prefills += 1;
        }
    }
    pipe.obs_mut().stage_end(names::ROUND_PREFILL_US, prefill_t, &[("prefills", prefills as i64)]);
    let wave: Vec<usize> = admitted
        .iter()
        .copied()
        .filter(|&i| matches!(items[i], Item::Step { .. }))
        .collect();
    if !wave.is_empty() {
        let wave_items: Vec<(u64, &Tensor, &Tensor, &Tensor)> = wave
            .iter()
            .map(|&i| match &items[i] {
                Item::Step { session, q, k, v } => (*session, *q, *k, *v),
                _ => unreachable!("filtered to steps above"),
            })
            .collect();
        // per-session step markers: who served this wave, the pages they
        // hold, and how many rounds they waited in the queue
        if pipe.obs_mut().trace().is_some() {
            for &i in &wave {
                if let Item::Step { session, .. } = &items[i] {
                    let pages = pipe.session_pages(*session) as i64;
                    let waited = ages[i] as i64;
                    pipe.obs_mut().event(
                        "step",
                        &[("session", *session as i64), ("pages", pages), ("waited", waited)],
                    );
                }
            }
        }
        let wave_t = pipe.obs_mut().stage_begin("wave");
        let results = pipe.step_batch_excluding(&wave_items, &round_sessions);
        pipe.obs_mut().stage_end(names::ROUND_WAVE_US, wave_t, &[("steps", wave.len() as i64)]);
        for (&i, r) in wave.iter().zip(results) {
            replies[i] = Some(r);
        }
    }
    let resident = pipe.resident_tokens() as u64;
    let mut obs = pipe.obs_mut();
    obs.inc(names::SCHED_ROUNDS);
    obs.add(names::SCHED_STEPS, wave.len() as u64);
    obs.add(names::SCHED_PREFILLS, prefills);
    obs.add(names::SCHED_OCC_SESSIONS, wave.len() as u64 + prefills);
    obs.add(names::SCHED_OCC_TOKENS, resident);
}
