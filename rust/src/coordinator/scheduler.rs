//! Continuous-batching decode scheduler: token-budget admission,
//! eviction/requeue on KV exhaustion, and starvation-free serving
//! rounds.
//!
//! [`run`] is the decode route's router loop. It holds the ready batch
//! as a waiting queue and serves it as a sequence of **rounds**; each
//! round admits waiting opens / chunked prefills / decode steps /
//! closes into the *current* wave under the [`SchedConfig`] budgets,
//! instead of the old barrier loop (steps coalesced only between
//! opens/prefills, which flushed everything). The admitted steps go
//! down as ONE [`crate::attention::DecodeBatch`] head-scatter wave;
//! prefills ride along in the same round through `prefill_chunk_par`.
//!
//! # Round assembly
//!
//! Items are scanned in arrival order. The first time a session is
//! seen in a pass it is either admitted or skipped — either way the
//! session is *blocked* for the rest of the pass, so at most one item
//! per session enters a round and every session's requests execute in
//! its own arrival order (the bit-identity precondition; see the wire
//! contract in [`super::request`]). Admission is budgeted:
//!
//! * **pages** — the item's [`KvPool`](crate::kv::KvPool) cost
//!   (admission probes `pages_needed*`) must fit the free list, plus
//!   the pages admitted closes will free (closes execute first), minus
//!   pages already reserved this round;
//! * **`max_batch_total_tokens`** — Σ resident-tokens-after-round over
//!   the admitted sessions;
//! * **`max_batch_prefill_tokens`** — Σ admitted prefill chunk tokens
//!   (the round's MAC budget);
//! * **prefill priority** — when the waiting prefill queue outweighs
//!   the waiting steps (`waiting_served_ratio`) or its token mass
//!   reaches `max_waiting_tokens`, a round admits only prefills (and
//!   opens/closes), draining the prompt queue before decode resumes.
//!
//! Budgets shape rounds, they never starve: an item exceeding a budget
//! alone is still admitted alone, and a prefill-only pass that admits
//! nothing falls back to a normal pass. The **front** item of a pass
//! has eviction privilege — if its pages don't fit, the youngest
//! resident sessions are evicted (replay-logged, pages reclaimed; see
//! `SessionKv::Evicted` in `engine_ops`) until it fits. Only when no
//! victim remains — the request alone exceeds the arena — does it
//! resolve as typed, retryable [`Reply::Exhausted`]. Every pass
//! therefore admits or resolves at least its front item, which is the
//! no-starvation argument: the queue strictly shrinks or executes.

use std::collections::HashSet;

use super::engine_ops::DecodePipeline;
use super::request::{Payload, Reply};
use crate::runtime::Tensor;

/// Continuous-batching knobs of a decode route. Defaults suit the
/// default 4096-page arena; property/chaos tests shrink them alongside
/// `pP` route overrides via `DecodePipeline::set_sched_config`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchedConfig {
    /// cap on Σ resident tokens (after the round) across the sessions
    /// served in one round — bounds a round's KV sweep traffic
    pub max_batch_total_tokens: usize,
    /// cap on Σ prefill chunk tokens admitted into one round — the MAC
    /// budget that keeps prompt ingest from stalling decode latency
    pub max_batch_prefill_tokens: usize,
    /// drain the prefill queue when waiting prefills ≥ ratio × waiting
    /// steps
    pub waiting_served_ratio: f64,
    /// ... or when the waiting prefills' token mass reaches this
    pub max_waiting_tokens: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self {
            max_batch_total_tokens: 4096,
            max_batch_prefill_tokens: 512,
            waiting_served_ratio: 1.2,
            max_waiting_tokens: 256,
        }
    }
}

/// A waiting-queue item, borrowed out of the ready batch.
enum Item<'a> {
    Open,
    Close(u64),
    Prefill {
        session: u64,
        q: &'a Tensor,
        k: &'a Tensor,
        v: &'a Tensor,
        /// chunk length when well-formed; malformed chunks cost 0 and
        /// fail with their shape error at execution
        tokens: usize,
    },
    Step {
        session: u64,
        q: &'a Tensor,
        k: &'a Tensor,
        v: &'a Tensor,
    },
}

impl Item<'_> {
    fn session(&self) -> Option<u64> {
        match self {
            Item::Open => None,
            Item::Close(s) => Some(*s),
            Item::Prefill { session, .. } | Item::Step { session, .. } => Some(*session),
        }
    }
}

/// One assembled round: admitted item indices (arrival order) plus how
/// many items the pass resolved in place (typed exhaustion).
struct Round {
    admitted: Vec<usize>,
    resolved: usize,
}

/// Serve one ready batch of decode payloads, continuously batched.
/// Replies are index-aligned with `batch`.
pub(super) fn run(pipe: &DecodePipeline, batch: &[&Payload]) -> Vec<Reply> {
    let items: Vec<Item<'_>> = batch
        .iter()
        .map(|p| match p {
            Payload::DecodeOpen => Item::Open,
            Payload::DecodeClose(s) => Item::Close(*s),
            Payload::DecodePrefill { session, q, k, v } => Item::Prefill {
                session: *session,
                q,
                k,
                v,
                tokens: if q.dims.len() == 3 { q.dims[0] } else { 0 },
            },
            Payload::DecodeStep { session, q, k, v } => {
                Item::Step { session: *session, q, k, v }
            }
            _ => unreachable!("router sends only decode payloads here"),
        })
        .collect();

    let cfg = pipe.sched_config();
    let mut replies: Vec<Option<Reply>> = batch.iter().map(|_| None).collect();
    let mut pending: Vec<usize> = (0..items.len()).collect();

    while !pending.is_empty() {
        {
            let mut c = pipe.counters_mut();
            c.peak_queue_depth = c.peak_queue_depth.max(pending.len() as u64);
        }
        // prefill priority: pause decode rounds to drain the prompt
        // queue when it outweighs the waiting steps
        let (mut wp_tokens, mut wp, mut ws) = (0usize, 0usize, 0usize);
        for &i in &pending {
            match &items[i] {
                Item::Prefill { tokens, .. } => {
                    wp += 1;
                    wp_tokens += *tokens;
                }
                Item::Step { .. } => ws += 1;
                _ => {}
            }
        }
        let prefill_priority = wp > 0
            && (wp_tokens >= cfg.max_waiting_tokens
                || wp as f64 >= cfg.waiting_served_ratio * ws as f64);

        let mut round = assemble(pipe, &cfg, &items, &pending, &mut replies, prefill_priority);
        if round.admitted.is_empty() && round.resolved == 0 {
            // the prefill-only pass admitted nothing (every prefill sat
            // behind a blocked session): fall back to a normal pass,
            // whose front item always admits or resolves
            round = assemble(pipe, &cfg, &items, &pending, &mut replies, false);
        }
        debug_assert!(
            !round.admitted.is_empty() || round.resolved > 0,
            "every round must make progress"
        );
        if !round.admitted.is_empty() {
            execute(pipe, &items, &round.admitted, &mut replies);
        }
        pending.retain(|&i| replies[i].is_none());
    }
    replies.into_iter().map(|r| r.expect("every request resolved")).collect()
}

/// One admission pass over the pending queue (see the module docs).
fn assemble(
    pipe: &DecodePipeline,
    cfg: &SchedConfig,
    items: &[Item<'_>],
    pending: &[usize],
    replies: &mut [Option<Reply>],
    prefill_only: bool,
) -> Round {
    let mut round = Round { admitted: Vec::new(), resolved: 0 };
    // sessions with an item already considered this pass: later items
    // of the same session must wait (per-session FIFO)
    let mut blocked: HashSet<u64> = HashSet::new();
    // sessions with an item ADMITTED this round — eviction must spare
    // them (a close's pages are already credited; a step/prefill's
    // sequence is about to be used)
    let mut in_round: HashSet<u64> = HashSet::new();
    let mut reserved_pages = 0usize;
    let mut close_credit = 0usize;
    let mut round_tokens = 0usize;
    let mut prefill_tokens = 0usize;
    let mut cost_items = 0usize;

    for &i in pending {
        let item = &items[i];
        if let Some(s) = item.session() {
            if blocked.contains(&s) {
                continue;
            }
            blocked.insert(s);
        }
        match item {
            Item::Open => round.admitted.push(i),
            Item::Close(s) => {
                // a close funds the round: its pages are credited now
                // and actually freed first at execution
                close_credit += pipe.session_pages(*s);
                in_round.insert(*s);
                round.admitted.push(i);
            }
            Item::Step { session, .. } | Item::Prefill { session, .. } => {
                let is_prefill = matches!(item, Item::Prefill { .. });
                if prefill_only && !is_prefill {
                    continue; // stays blocked: FIFO preserved
                }
                let new_tokens = match item {
                    Item::Prefill { tokens, .. } => *tokens,
                    _ => 1,
                };
                let cost = pipe.admit_cost(*session, new_tokens);
                // token budgets shape the round; an item exceeding a
                // budget alone is still admitted alone
                if cost_items > 0
                    && round_tokens + cost.tokens_after > cfg.max_batch_total_tokens
                {
                    continue;
                }
                if is_prefill
                    && prefill_tokens > 0
                    && prefill_tokens + new_tokens > cfg.max_batch_prefill_tokens
                {
                    continue;
                }
                // page budget against the free list + admitted closes
                let available =
                    |p: &DecodePipeline| (p.free_pages_now() + close_credit).saturating_sub(reserved_pages);
                if cost.pages > available(pipe) {
                    if cost_items > 0 {
                        continue; // only the front item may evict
                    }
                    // front item: evict youngest sessions until it fits
                    let mut exclude = in_round.clone();
                    exclude.insert(*session);
                    let mut fits = true;
                    while cost.pages > available(pipe) {
                        if pipe.evict_youngest(&exclude).is_none() {
                            fits = false;
                            break;
                        }
                    }
                    if !fits {
                        // nothing left to evict: the request alone
                        // exceeds the arena — typed backpressure, the
                        // session untouched and the queue unblocked
                        let mut c = pipe.counters_mut();
                        c.exhausted += 1;
                        drop(c);
                        replies[i] = Some(Reply::Exhausted {
                            pages: pipe.total_pages(),
                            free_pages: pipe.free_pages_now(),
                        });
                        round.resolved += 1;
                        continue;
                    }
                }
                reserved_pages += cost.pages;
                round_tokens += cost.tokens_after;
                if is_prefill {
                    prefill_tokens += new_tokens;
                }
                cost_items += 1;
                in_round.insert(*session);
                round.admitted.push(i);
            }
        }
    }
    round
}

/// Execute one assembled round: closes first (they fund the credited
/// pages), then opens (ids in arrival order), then prefills, then ALL
/// admitted steps as one wave. Cross-session reorder within a round is
/// unobservable — a round holds at most one item per session.
fn execute(
    pipe: &DecodePipeline,
    items: &[Item<'_>],
    admitted: &[usize],
    replies: &mut [Option<Reply>],
) {
    for &i in admitted {
        if let Item::Close(s) = &items[i] {
            replies[i] = Some(pipe.close(*s));
        }
    }
    for &i in admitted {
        if matches!(items[i], Item::Open) {
            replies[i] = Some(pipe.open());
        }
    }
    let mut prefills = 0u64;
    for &i in admitted {
        if let Item::Prefill { session, q, k, v, .. } = &items[i] {
            replies[i] = Some(pipe.prefill(*session, q, k, v));
            prefills += 1;
        }
    }
    let wave: Vec<usize> = admitted
        .iter()
        .copied()
        .filter(|&i| matches!(items[i], Item::Step { .. }))
        .collect();
    if !wave.is_empty() {
        let wave_items: Vec<(u64, &Tensor, &Tensor, &Tensor)> = wave
            .iter()
            .map(|&i| match &items[i] {
                Item::Step { session, q, k, v } => (*session, *q, *k, *v),
                _ => unreachable!("filtered to steps above"),
            })
            .collect();
        for (&i, r) in wave.iter().zip(pipe.step_batch(&wave_items)) {
            replies[i] = Some(r);
        }
    }
    let resident = pipe.resident_tokens() as u64;
    let mut c = pipe.counters_mut();
    c.rounds += 1;
    c.admitted_steps += wave.len() as u64;
    c.admitted_prefills += prefills;
    c.occupancy_sessions += wave.len() as u64 + prefills;
    c.occupancy_tokens += resident;
}
